# Shared discrete-event simulation substrate (DESIGN.md §3).  Both the
# Kubernetes cluster simulator (repro.cluster) and the TPU serving fleet
# (repro.serving.fleet) are thin domain adapters over this core.
from repro.sim.events import EventQueue
from repro.sim.core import (ArrayServerPool, CompletionLog, ServerPool,
                            SimCore, WindowAccumulator, WindowedExporter,
                            account_busy, drain_window, waterfill_placement)
from repro.sim.chaos import ChaosConfig, ChaosSchedule
