"""Event-heap discrete-event core shared by the cluster sim and the TPU
serving fleet (DESIGN.md §3).

The seed engines selected a server for every task with an O(P) scan
(``min(pods, key=...)``) and undid mis-dispatches with O(n)
``completed.remove``.  This core replaces both:

* ``ServerPool`` — per-group lazy heaps that reproduce the seed selection
  order *exactly* (same tie-breaking) at O(log P) per dispatch:

  - ``free``   : ready & idle servers, keyed by insertion sequence, so ties
                 among idle servers resolve in creation (pid/rid) order like
                 the seed's first-minimal list scan;
  - ``busy``   : ready & occupied servers, keyed (selection key, seq) —
                 the seed's ``min(max(free_at, t))`` over busy servers;
  - ``pending``: not-yet-ready servers, selectable only when no ready
                 server exists (the cluster sim's queue-on-spinning-up
                 fallback), keyed (selection key, seq); a companion
                 ``ready_heap`` keyed ready_at promotes them.

  Single-phase pools (``two_phase=False``, the fleet) skip the pending
  distinction: the selection key already folds ready_at in.

  Entries are invalidated lazily via per-server version counters, so drain,
  death and key updates are O(1) and stale heap entries are skipped on pop.

* ``EventQueue`` — heap-ordered failure/straggler/recovery injection
  (see events.py).

* ``WindowedExporter`` — the per-group windowed metric exporter (the
  Prometheus-adapter stand-in): per-window task counters, raw sample log
  and a configurable moving average over the last ``ma_windows`` samples.

* append-only completion logging — redispatch mutates the task record in
  place; the ``_logged`` guard keeps the record single-entry without the
  seed's O(n) ``list.remove``.

The pool is duck-typed: any object with ``dead``/``draining`` attributes can
be registered; pool bookkeeping lives in ``_pool_*`` attributes attached at
registration.

Fleet-scale layer (DESIGN.md §3, "Fleet scale"): the heap path above is
O(log P) per dispatch but still pays one Python iteration per event, which
caps experiments around 10³ servers.  For 10⁴–10⁵ servers the same
selection semantics are re-implemented on flat numpy arrays:

* ``ArrayServerPool`` — selection state (key / ready_at / live) in
  preallocated arrays; same priority order as ``ServerPool`` (idle in
  creation order -> earliest busy -> earliest pending);
* ``drain_window`` — drains a sorted same-window arrival batch in
  vectorised idle chunks (one numpy round per chunk instead of one Python
  iteration per task); completion-sequence-exact vs. per-event dispatch
  for a fixed pool with homogeneous server speeds (server *attribution*
  may differ when a busy server frees mid-chunk — both candidates are
  idle, so starts and completions are unchanged);
* ``CompletionLog`` — preallocated structured-numpy completion log
  (append-only, amortised O(1), slice-queryable by control window);
* ``WindowAccumulator`` — vectorised per-window busy-time accounting
  (``account_busy`` as array math over interval batches).
"""
from __future__ import annotations

import heapq
from collections import defaultdict

import numpy as np

from repro.sim.events import EventQueue

_READY, _PENDING = "ready", "pending"


def account_busy(busy: dict, start: float, end: float, window_s: float):
    """Credit [start, end) busy time into per-window buckets."""
    i0, i1 = int(start // window_s), int(end // window_s)
    for i in range(i0, i1 + 1):
        lo = max(start, i * window_s)
        hi = min(end, (i + 1) * window_s)
        if hi > lo:
            busy[i] += hi - lo


def grow_to(arr: np.ndarray, need: int, fill=0) -> np.ndarray:
    """Return ``arr`` or a doubled-capacity copy covering ``need`` slots —
    the one growth policy every flat-array store here shares."""
    cap = len(arr)
    if need <= cap:
        return arr
    while cap < need:
        cap *= 2
    buf = np.full(cap, fill, arr.dtype) if fill else np.zeros(cap, arr.dtype)
    buf[:len(arr)] = arr
    return buf


class ServerPool:
    """Heap-based server selection for one scaling group."""

    def __init__(self, two_phase: bool = True):
        self.two_phase = two_phase
        self.n_live = 0
        self._seq = 0
        self._free: list[tuple[int, int, object]] = []      # (seq, ver, s)
        self._busy: list[tuple[float, int, int, object]] = []
        self._pending: list[tuple[float, int, int, object]] = []
        self._ready_heap: list[tuple[float, int, object]] = []

    # ------------------------------------------------------------ intern --
    @staticmethod
    def _alive(s) -> bool:
        return not s.dead and not s.draining

    def _valid(self, s, ver: int, phase: str) -> bool:
        return (self._alive(s) and s._pool_version == ver
                and s._pool_phase == phase)

    def _push(self, s):
        if s._pool_phase == _READY:
            heapq.heappush(self._busy,
                           (s._pool_key, s._pool_seq, s._pool_version, s))
        else:
            heapq.heappush(self._pending,
                           (s._pool_key, s._pool_seq, s._pool_version, s))

    # ------------------------------------------------------------ public --
    def add(self, s, t: float, key: float, ready_at: float):
        """Register a server.  ``key`` is its selection key (the cluster's
        ``free_at``, the fleet's ``max(min(slot_free_at), ready_at)``)."""
        s._pool_seq = self._seq
        self._seq += 1
        s._pool_version = 0
        s._pool_key = key
        s._pool_live = True
        if self.two_phase and ready_at > t:
            s._pool_phase = _PENDING
            heapq.heappush(self._ready_heap, (ready_at, s._pool_seq, s))
        else:
            s._pool_phase = _READY
        self._push(s)
        self.n_live += 1

    def update(self, s, key: float):
        """Re-key a server after a dispatch changed its horizon."""
        s._pool_key = key
        s._pool_version += 1
        self._push(s)

    def invalidate(self, s):
        """Server drained or died — caller has already set the flag."""
        s._pool_version += 1
        if getattr(s, "_pool_live", False):
            s._pool_live = False
            self.n_live -= 1

    def reset(self, s, key: float):
        """Force a server ready-now (e.g. pre-warmed initial capacity)."""
        s._pool_phase = _READY
        self.update(s, key)

    def select(self, t: float):
        """Pop the server the seed scan would pick at time ``t``.

        The caller *must* hand the server back via ``update`` (or
        ``invalidate``) after recording the dispatch — selection removes the
        live heap entry.
        """
        # 1. promote pending servers whose ready_at has passed (not
        #    version-checked: fallback dispatches bump versions but must not
        #    cancel promotion)
        while self._ready_heap and self._ready_heap[0][0] <= t:
            _, _, s = heapq.heappop(self._ready_heap)
            if self._alive(s) and s._pool_phase == _PENDING:
                s._pool_phase = _READY
                s._pool_version += 1
                self._push(s)
        # 2. ready servers whose key horizon has passed are idle: move them
        #    to the free heap where ties resolve in creation order
        while self._busy and self._busy[0][0] <= t:
            _, seq, ver, s = heapq.heappop(self._busy)
            if self._valid(s, ver, _READY):
                s._pool_version += 1
                heapq.heappush(self._free, (seq, s._pool_version, s))
        # 3. selection priority: idle ready -> earliest busy ready ->
        #    earliest pending (two-phase only)
        while self._free:
            _, ver, s = heapq.heappop(self._free)
            if self._valid(s, ver, _READY):
                return s
        while self._busy:
            _, _, ver, s = heapq.heappop(self._busy)
            if self._valid(s, ver, _READY):
                return s
        while self._pending:
            _, _, ver, s = heapq.heappop(self._pending)
            if self._valid(s, ver, _PENDING):
                return s
        return None


class WindowedExporter:
    """Windowed metric readout: per-group arrival counters + raw sample log
    + ``ma_windows``-sample moving average (the Prometheus rate()/avg
    emulation; ma_windows=1 disables smoothing)."""

    def __init__(self, window_s: float, ma_windows: int = 4):
        self.window_s = window_s
        self.ma_windows = max(int(ma_windows), 1)
        self.samples: dict[str, list[tuple[float, np.ndarray]]] = \
            defaultdict(list)
        self._counts: dict[str, int] = defaultdict(int)
        self._raw: dict[str, list[np.ndarray]] = defaultdict(list)

    def window_index(self, t: float) -> int:
        return int((t - 1e-9) // self.window_s)

    def count(self, group: str, n: int = 1):
        self._counts[group] += n

    def take_count(self, group: str) -> int:
        n = self._counts.get(group, 0)
        self._counts[group] = 0
        return n

    def push(self, group: str, t: float, raw: np.ndarray) -> np.ndarray:
        """Store a raw reading, return the smoothed exporter value."""
        self._raw[group].append(np.asarray(raw, np.float64))
        # only the trailing MA window is ever read back — don't let the raw
        # log shadow-copy the samples log on long runs
        self._raw[group] = self._raw[group][-self.ma_windows:]
        ma = np.mean(self._raw[group], axis=0)
        self.samples[group].append((t, ma))
        return ma

    # --------------------------------------------- overlapped-read API ----
    # The staged control plane's collect stage reads the exporter while the
    # sim side keeps pushing (async ticks, DESIGN.md §5): both methods are
    # pure reads over the append-only samples log, so an overlapped reader
    # never races the writer and never consumes another reader's data.
    def latest(self, group: str):
        """Most recent ``(t, smoothed)`` sample for ``group``; ``None``
        before the first push."""
        s = self.samples.get(group)
        return s[-1] if s else None

    def read_new(self, group: str, cursor: int = 0):
        """``(samples appended at/after cursor, new cursor)`` — each reader
        holds its own cursor, nothing is popped or mutated."""
        s = self.samples.get(group)
        if not s:
            return [], 0
        return s[cursor:], len(s)


class SimCore:
    """Registry + pools + events + exporter: the shared substrate a domain
    adapter (ClusterSim, ServingFleet) drives."""

    def __init__(self, window_s: float, two_phase: bool = True,
                 ma_windows: int = 4):
        self.window_s = window_s
        self.two_phase = two_phase
        self.servers: list = []
        self.by_group: dict[str, list] = defaultdict(list)
        self.pools: dict[str, ServerPool] = {}
        self.events = EventQueue()
        self.exporter = WindowedExporter(window_s, ma_windows)

    def pool(self, group: str) -> ServerPool:
        if group not in self.pools:
            self.pools[group] = ServerPool(self.two_phase)
        return self.pools[group]

    def add_server(self, s, group: str, t: float, key: float,
                   ready_at: float):
        self.servers.append(s)
        self.by_group[group].append(s)
        self.pool(group).add(s, t, key, ready_at)

    def live(self, group: str):
        return [s for s in self.by_group[group]
                if not s.dead and not s.draining]

    def n_live(self, group: str) -> int:
        return self.pool(group).n_live

    def log_completion(self, log: list, rec):
        """Append-only completion log: a redispatched record is mutated in
        place and must not be double-counted (no O(n) list.remove)."""
        if not getattr(rec, "_logged", False):
            rec._logged = True
            log.append(rec)

    def account_busy(self, busy: dict, start: float, end: float):
        account_busy(busy, start, end, self.window_s)


# ===================================================================== #
#  Fleet-scale substrate: array-backed pool, log and accounting          #
# ===================================================================== #

COMPLETION_DTYPE = np.dtype([
    ("arrival", np.float64),
    ("start", np.float64),
    ("completion", np.float64),
    ("service", np.float64),
    ("server", np.int64),        # domain server id (pod pid / replica rid)
    ("kind", np.int16),          # workload kind code
    ("group", np.int16),         # scaling-group (zone / fleet) code
    ("redispatched", np.bool_),
])


class CompletionLog:
    """Preallocated structured-numpy completion log.

    Replaces the per-task Python object list on the fleet-scale path:
    appends are amortised O(1) (capacity doubling), batch appends are one
    array copy, redispatch mutates rows in place (``amend``), and the log
    is slice-queryable by control window — the driver calls
    ``seal_window`` once per tick and ``window_rows(w)`` returns the rows
    dispatched in window ``w`` as a zero-copy view.

    **Streaming mode** (``streaming=True``): the full log holds ~43 B per
    event, which caps runs near 10⁸ events.  Streaming keeps only the most
    recent ``retain_windows`` sealed windows of raw rows; each older window
    is folded into a per-window aggregate (count, redispatch count,
    response-time sum / sum-of-squares / min / max) on ``seal_window`` and
    its rows are compacted away, so resident memory is bounded by the
    busiest ``retain_windows``-window span regardless of run length.
    ``stats()`` / ``window_stats(w)`` read flushed and retained windows
    uniformly; ``len()`` still counts every event ever appended.  Caveats:
    ``response_times()``/``view()`` see retained rows only, and in-place
    ``amend`` (failure re-dispatch) can only reach retained rows — size
    ``retain_windows`` to cover the longest service time.
    """

    def __init__(self, capacity: int = 1024, streaming: bool = False,
                 retain_windows: int = 8):
        self._buf = np.zeros(max(int(capacity), 16), COMPLETION_DTYPE)
        self.n = 0
        self._offsets: list[int] = [0]   # row offset where window w begins
        self.streaming = bool(streaming)
        self.retain_windows = max(int(retain_windows), 1)
        self._first_window = 0           # windows folded into _win_stats
        self._n_flushed = 0              # rows compacted out of the buffer
        self._win_stats: list[tuple] = []
        self._warned_inflight = False

    def _grow(self, need: int):
        cap = len(self._buf)
        while cap < need:
            cap *= 2
        if cap != len(self._buf):
            buf = np.zeros(cap, COMPLETION_DTYPE)
            buf[:self.n] = self._buf[:self.n]
            self._buf = buf

    # ------------------------------------------------------------ write --
    def append_batch(self, arrival, start, completion, service, server,
                     kind=0, group=0, redispatched=False) -> slice:
        """Append ``len(arrival)`` rows at once; returns their row slice."""
        k = len(arrival)
        self._grow(self.n + k)
        rows = self._buf[self.n:self.n + k]
        rows["arrival"], rows["start"] = arrival, start
        rows["completion"], rows["service"] = completion, service
        rows["server"], rows["kind"] = server, kind
        rows["group"], rows["redispatched"] = group, redispatched
        out = slice(self.n, self.n + k)
        self.n += k
        return out

    def append(self, arrival, start, completion, service, server,
               kind=0, group=0) -> int:
        self._grow(self.n + 1)
        self._buf[self.n] = (arrival, start, completion, service, server,
                             kind, group, False)
        self.n += 1
        return self.n - 1

    def amend(self, idx, **fields):
        """In-place row mutation (failure / straggler re-dispatch)."""
        for name, val in fields.items():
            self._buf[name][idx] = val

    # ------------------------------------------------------------- read --
    def seal_window(self):
        """Mark the end of the current control window's appends.  In
        streaming mode, windows falling off the retention span are folded
        into per-window aggregates and their rows compacted away."""
        self._offsets.append(self.n)
        if self.streaming:
            excess = len(self._offsets) - 1 - self.retain_windows
            if excess > 0:
                self._flush(excess)

    def _flush(self, k: int):
        """Fold the oldest ``k`` sealed windows into stats, drop their
        rows (one array copy over the retained span).  Rows whose booked
        completion is still in flight relative to the newest retained
        arrival become invisible to ``amend`` (failure re-dispatch) once
        flushed — warn so the operator can widen ``retain_windows``."""
        cut = self._offsets[k]
        if cut and self.n:
            now_proxy = float(self._buf[:self.n]["arrival"].max())
            if (self._buf[:cut]["completion"] > now_proxy).any() \
                    and not self._warned_inflight:
                self._warned_inflight = True
                import warnings
                warnings.warn(
                    "CompletionLog streaming flush dropped rows whose "
                    "completion is still in flight; in-place amendment "
                    "(failure re-dispatch) cannot reach them — increase "
                    "retain_windows to cover the longest service time",
                    RuntimeWarning, stacklevel=3)
        for w in range(k):
            rows = self._buf[self._offsets[w]:self._offsets[w + 1]]
            self._win_stats.append(self._aggregate(rows))
        if cut:
            self._buf[:self.n - cut] = self._buf[cut:self.n]
            self.n -= cut
            self._n_flushed += cut
        self._offsets = [o - cut for o in self._offsets[k:]]
        self._first_window += k

    @staticmethod
    def _aggregate(rows: np.ndarray) -> tuple:
        resp = rows["completion"] - rows["arrival"]
        r = resp[np.isfinite(resp)]
        return (len(rows), int(np.count_nonzero(rows["redispatched"])),
                float(r.sum()), float((r * r).sum()),
                float(r.min()) if len(r) else np.inf,
                float(r.max()) if len(r) else -np.inf)

    def window_rows(self, w: int) -> np.ndarray:
        """Rows dispatched in sealed window ``w`` (zero-copy view; empty
        for windows already flushed to stats in streaming mode)."""
        lw = w - self._first_window
        if lw < 0 or lw + 1 >= len(self._offsets):
            return self._buf[self.n:self.n]
        return self._buf[self._offsets[lw]:self._offsets[lw + 1]]

    def window_stats(self, w: int) -> dict:
        """Aggregate stats for window ``w`` — identical shape whether the
        window is still raw or already flushed (streaming mode)."""
        lw = w - self._first_window
        agg = (self._win_stats[w] if lw < 0
               else self._aggregate(self.window_rows(w)))
        return self._stats_dict(agg)

    @staticmethod
    def _stats_dict(agg: tuple) -> dict:
        n, redis, s, ss, mn, mx = agg
        ok = n > 0 and np.isfinite(mn)
        mean = s / n if n else float("nan")
        var = max(ss / n - mean * mean, 0.0) if n else float("nan")
        return {"count": n, "redispatched": redis,
                "resp_mean": mean if ok else float("nan"),
                "resp_std": float(np.sqrt(var)) if ok else float("nan"),
                "resp_min": mn if ok else float("nan"),
                "resp_max": mx if ok else float("nan")}

    def window_percentile(self, w: int, q: float = 95.0) -> float:
        """``q``-th percentile of the response times of the requests
        dispatched in sealed window ``w`` — the SLA ground truth the
        serving fleet publishes to the control plane (metric slot 1,
        ``ServingFleet.sample``) and the guardrail A/B bench scores
        violation seconds against.  NaN when the window has no finished
        rows or was already flushed in streaming mode (use
        ``window_stats`` there)."""
        rows = self.window_rows(w)
        resp = rows["completion"] - rows["arrival"]
        resp = resp[np.isfinite(resp)]
        return float(np.percentile(resp, q)) if resp.size else float("nan")

    def totals(self) -> tuple:
        """Whole-run raw aggregate ``(n, redispatched, sum, sumsq, min,
        max)`` over flushed windows + retained rows — the mergeable form
        of ``stats()``: fold several logs' totals elementwise (sum the
        first four, min/max the last two), then ``_stats_dict`` the
        result.  Exact in streaming mode; the federation driver uses it
        for cross-fleet completion stats at 10⁶ pods."""
        aggs = list(self._win_stats) + [self._aggregate(self.view())]
        return (sum(a[0] for a in aggs), sum(a[1] for a in aggs),
                sum(a[2] for a in aggs), sum(a[3] for a in aggs),
                min((a[4] for a in aggs), default=np.inf),
                max((a[5] for a in aggs), default=-np.inf))

    def stats(self) -> dict:
        """Whole-run aggregate over flushed windows + retained rows."""
        return self._stats_dict(self.totals())

    @property
    def n_flushed(self) -> int:
        """Rows compacted out of the buffer so far (streaming mode) —
        view-local row index ``i`` corresponds to the ``n_flushed + i``-th
        row ever appended, so side-car arrays indexed in append order can
        stay aligned by dropping their own first ``n_flushed`` entries."""
        return self._n_flushed

    def view(self) -> np.ndarray:
        return self._buf[:self.n]

    def response_times(self, kind: int | None = None) -> np.ndarray:
        """Response times of the *retained* rows (= everything in full-log
        mode; the trailing retention span in streaming mode — use
        ``stats()`` for whole-run numbers there)."""
        rows = self.view()
        mask = np.isfinite(rows["completion"])
        if kind is not None:
            mask &= rows["kind"] == kind
        rows = rows[mask]
        return rows["completion"] - rows["arrival"]

    def __len__(self):
        """Every event ever appended (flushed rows included)."""
        return self._n_flushed + self.n


class WindowAccumulator:
    """Vectorised per-window busy-time accounting for one scaling group.

    The heap path credits [start, end) intervals into per-server Python
    dicts (``account_busy``) and sums over servers at sample time — O(P)
    per tick.  At fleet scale the exporter only ever reads the *group*
    total, so this accumulates straight into a preallocated per-window
    array: ``add_batch`` is a handful of numpy ops per interval-span
    offset (service times rarely span more than 2 windows) and ``get`` is
    O(1) at sample time.
    """

    def __init__(self, window_s: float, n_windows: int = 256):
        self.window_s = window_s
        self._buf = np.zeros(max(int(n_windows), 8))

    def _ensure(self, w: int):
        if w >= len(self._buf):
            cap = len(self._buf)
            while cap <= w:
                cap *= 2
            buf = np.zeros(cap)
            buf[:len(self._buf)] = self._buf
            self._buf = buf

    def add_batch(self, starts: np.ndarray, ends: np.ndarray,
                  sign: float = 1.0):
        """Credit (``sign=1``) or cancel (``sign=-1``) interval batches."""
        if len(starts) == 0:
            return
        w = self.window_s
        i0 = (np.asarray(starts) // w).astype(np.int64)
        i1 = (np.asarray(ends) // w).astype(np.int64)
        self._ensure(int(i1.max()))
        for d in range(int((i1 - i0).max()) + 1):
            win = i0 + d
            m = win <= i1
            if not m.any():
                break
            lo = np.maximum(starts[m], win[m] * w)
            hi = np.minimum(ends[m], (win[m] + 1) * w)
            contrib = np.maximum(hi - lo, 0.0)
            np.add.at(self._buf, win[m], sign * contrib)

    def add(self, start: float, end: float, sign: float = 1.0):
        self.add_batch(np.asarray([start]), np.asarray([end]), sign)

    def get(self, w: int) -> float:
        return float(self._buf[w]) if 0 <= w < len(self._buf) else 0.0


class ArrayServerPool:
    """Flat-array server pool for fleet-scale groups (10⁴–10⁵ servers).

    Selection state lives in preallocated numpy arrays instead of heaps of
    Python tuples; slots are assigned in registration order, so the slot
    index doubles as the seed's insertion-sequence tie-breaker.  The
    selection priority is identical to ``ServerPool``:

    - idle  (live, ``ready_at <= t``, ``key <= t``)  -> lowest slot;
    - busy  (live, ``ready_at <= t``, ``key > t``)   -> min key, tie slot;
    - pending (live, ``ready_at > t``)               -> min key, tie slot.

    ``select`` is O(P) in numpy (the busy/overload fallback); the hot path
    is ``idle_slots`` + caller-side vectorised chunk assignment
    (``drain_window``), which amortises the per-event Python cost across
    whole arrival chunks.
    """

    def __init__(self, capacity: int = 256):
        cap = max(int(capacity), 16)
        self.key = np.full(cap, np.inf)
        self.ready = np.full(cap, np.inf)
        self.live = np.zeros(cap, np.bool_)
        self.n = 0
        self.n_live = 0

    def _grow(self):
        cap = len(self.key) * 2
        for name in ("key", "ready"):
            buf = np.full(cap, np.inf)
            buf[:self.n] = getattr(self, name)[:self.n]
            setattr(self, name, buf)
        live = np.zeros(cap, np.bool_)
        live[:self.n] = self.live[:self.n]
        self.live = live

    # ------------------------------------------------------------ write --
    def add(self, t: float, key: float, ready_at: float) -> int:
        if self.n == len(self.key):
            self._grow()
        slot = self.n
        self.key[slot] = key
        self.ready[slot] = ready_at
        self.live[slot] = True
        self.n += 1
        self.n_live += 1
        return slot

    def add_batch(self, k: int, key, ready_at) -> np.ndarray:
        """Register ``k`` servers at once (one array write instead of k
        Python calls — the bulk scale-up hot path).  ``key``/``ready_at``
        may be scalars or (k,) arrays; returns the new slot indices."""
        while self.n + k > len(self.key):
            self._grow()
        slots = np.arange(self.n, self.n + k)
        self.key[slots] = key
        self.ready[slots] = ready_at
        self.live[slots] = True
        self.n += k
        self.n_live += k
        return slots

    def update(self, slot: int, key: float):
        self.key[slot] = key

    def invalidate(self, slots):
        """Drain/death: drop slots from selection (vectorised)."""
        slots = np.atleast_1d(slots)
        was = self.live[slots]
        self.live[slots] = False
        self.n_live -= int(np.count_nonzero(was))

    def make_ready(self, slots, t: float):
        """Force slots ready-now (pre-warmed capacity)."""
        slots = np.atleast_1d(slots)
        self.ready[slots] = t
        self.key[slots] = t

    # ------------------------------------------------------------- read --
    def live_slots(self) -> np.ndarray:
        return np.flatnonzero(self.live[:self.n])

    def ready_live_count(self, t: float) -> int:
        return int(np.count_nonzero(self.live[:self.n]
                                    & (self.ready[:self.n] <= t)))

    def idle_slots(self, t: float, limit: int) -> np.ndarray:
        """Live, ready and idle slots at ``t``, ascending slot order."""
        m = (self.live[:self.n] & (self.ready[:self.n] <= t)
             & (self.key[:self.n] <= t))
        return np.flatnonzero(m)[:limit]

    def select(self, t: float) -> int:
        """Single-server selection with the exact ``ServerPool`` priority
        (the overload / spin-up fallback path); -1 when the pool is empty."""
        live = self.live[:self.n]
        key, ready = self.key[:self.n], self.ready[:self.n]
        ready_m = live & (ready <= t)
        idle = np.flatnonzero(ready_m & (key <= t))
        if idle.size:
            return int(idle[0])
        busy = np.flatnonzero(ready_m)
        if busy.size:
            return int(busy[np.argmin(key[busy])])
        pend = np.flatnonzero(live & (ready > t))
        if pend.size:
            return int(pend[np.argmin(key[pend])])
        return -1


def _emit_greedy_order(free, unit, counts, k_eff: int
                       ) -> tuple[np.ndarray, np.ndarray]:
    """Order the already-selected ``counts`` placements exactly as the
    sequential greedy would emit them: slot values descending, node index
    ascending on ties.  O(k log k) — the output's own size."""
    n = len(counts)
    node = np.repeat(np.arange(n), counts)
    j = np.arange(k_eff) - np.repeat(np.cumsum(counts) - counts, counts)
    v = free[node] - j * unit
    order = np.lexsort((node, -v))
    return node[order], counts


def _waterfill_lexsort(free, unit: float, u: np.ndarray, k_eff: int
                       ) -> tuple[np.ndarray, np.ndarray]:
    """Slot-enumeration fallback (exact for arbitrary float capacities):
    materialise every candidate slot value and lexsort.  Capping each
    node's slot list at ``k_eff`` bounds it to O(n*k) — bitwise-identical
    output, since no node can receive more than k placements."""
    n = len(free)
    u = np.minimum(u, k_eff)
    total = int(u.sum())
    node = np.repeat(np.arange(n), u)
    j = np.arange(total) - np.repeat(np.cumsum(u) - u, u)
    v = free[node] - j * unit
    order = np.lexsort((node, -v))[:k_eff]
    seq = node[order]
    return seq, np.bincount(seq, minlength=n)


def waterfill_placement(free, unit: float, k: int
                        ) -> tuple[np.ndarray, np.ndarray]:
    """Plan ``k`` unit-sized placements over a node free-capacity array
    with the exact semantics of ``k`` sequential greedy picks (argmax of
    current free capacity, first index on ties, minus ``unit`` after each
    pick) — but as ONE vectorised program: water-filling.

    Each node ``i`` with free capacity ``f_i`` contributes the "slot
    values" ``f_i - j*unit`` for ``j in [0, floor(f_i/unit))`` — the free
    capacity the sequential greedy would see just before placing its
    (j+1)-th pod there.  The greedy picks exactly the ``k`` largest slot
    values (ties broken by node index ascending), i.e. everything above a
    *water level*.  On integral capacities (the cluster's millicore
    bookkeeping) that level is found by an exact integer binary search:
    ``count_ge(v)`` — how many slots sit at or above level ``v`` — is a
    monotone O(nodes) reduction, so the whole plan costs
    O(nodes · log capacity + k log k) instead of enumerating O(total pod
    capacity) (or the earlier O(nodes·k)) candidate slots.  Non-integral
    capacities keep the exact lexsort fallback.

    Returns ``(node_seq, counts)``: ``node_seq`` is the node index of each
    placement in sequential-greedy order (length <= k — capacity may run
    out), ``counts`` the per-node placement totals.  Bitwise parity with
    the sequential loop (and with the lexsort formulation) is
    property-checked in tests/test_columnar.py.
    """
    free = np.asarray(free, np.float64)
    n = len(free)
    u = np.maximum(np.floor(free / unit), 0.0).astype(np.int64)
    k_eff = min(int(k), int(u.sum()))
    if k_eff <= 0:
        return np.zeros(0, np.int64), np.zeros(n, np.int64)
    if unit != np.floor(unit) or not np.all(free == np.floor(free)):
        return _waterfill_lexsort(free, unit, u, k_eff)
    f = free.astype(np.int64)
    un = np.int64(unit)

    def count_ge(v: int) -> int:
        # slots of node i at/above v: j <= (f_i - v)/unit, capped at u_i
        c = (f - v) // un + 1
        return int(np.minimum(np.maximum(c, 0), u).sum())

    # largest water level v* still covering k_eff slots (all slot values
    # are >= 1: f_i >= u_i*unit implies f_i - (u_i-1)*unit >= unit)
    lo, hi = np.int64(1), f.max()
    while lo < hi:
        mid = (lo + hi + 1) >> 1
        if count_ge(mid) >= k_eff:
            lo = mid
        else:
            hi = mid - 1
    v = lo
    # every slot strictly above the level is taken; the remainder comes
    # from slots exactly at the level, in node-index order (the greedy's
    # tie-break)
    counts = np.minimum(np.maximum((f - (v + 1)) // un + 1, 0), u)
    r = k_eff - int(counts.sum())
    if r > 0:
        tie = (f >= v) & ((f - v) % un == 0) & (counts < u)
        counts[np.flatnonzero(tie)[:r]] += 1
    return _emit_greedy_order(free, unit, counts, k_eff)


def drain_window(pool: ArrayServerPool, times: np.ndarray, service_fn,
                 on_cold=None, cold_timeout_s: float = 60.0):
    """Drain one window's sorted arrival batch through an array pool in
    vectorised idle chunks.

    Each round gathers every idle slot at the chunk head's arrival time
    and assigns the next ``k`` arrivals to them in (arrival order ->
    creation order) — one numpy round instead of ``k`` Python dispatches.
    A slot idle at the chunk head stays idle until assigned, so every
    chunk task starts at its own arrival time, exactly as per-event
    dispatch; when no slot is idle a vectorised *busy round* assigns the
    next r arrivals to the r earliest busy-slot horizons (sorted by
    (key, slot) — the per-event min-key/first-index pick) in one numpy
    pass: the round is capped before any slot could go idle or any
    pending server could become ready (``searchsorted`` against the
    earliest horizon), and committed only over the prefix where each
    next horizon precedes every earlier completion in the round
    (otherwise the per-event oracle would reuse a just-committed slot,
    or take it as idle).  A cut round hands its remaining already-drawn
    service times to a carry buffer and re-enters the outer loop — the
    freed slots are re-gathered by the next idle/busy round with the
    carried draws consumed first, so the RNG stream stays aligned with
    sequential dispatch and NO per-event Python path remains on the
    drain.  With homogeneous server speeds the resulting (start,
    service, completion) sequence is *identical* to one-at-a-time
    dispatch for a fixed pool (tests/test_fleet_scale.py
    property-checks this, overload included).

    ``service_fn(slots, i0, i1)`` returns service times for tasks
    ``i0:i1`` assigned to ``slots`` — it must draw any randomness for
    tasks in index order so the RNG stream matches sequential dispatch
    (numpy ``Generator`` batch draws equal scalar draws).  ``on_cold(t)``
    may register a new server and return its slot (the cluster's
    cold-zone safety net); tasks that still find no server get
    ``slot == -1``, ``completion = t + cold_timeout_s`` and NaN
    start/service, like the seed's dropped-task sentinel.

    Returns ``(slots, starts, completions, services)`` arrays.
    """
    n = len(times)
    slots = np.empty(n, np.int64)
    starts = np.full(n, np.nan)
    comps = np.empty(n, np.float64)
    svcs = np.full(n, np.nan)
    carry = np.zeros(0, np.float64)   # drawn-but-uncommitted service times

    def take_sv(sl, i0, i1):
        # consume carried draws (tasks whose service time already left
        # the RNG in a cut busy round) before drawing fresh ones —
        # task-index order is preserved, so the stream stays sequential
        nonlocal carry
        need = i1 - i0
        m = carry.size
        if m == 0:
            return np.asarray(service_fn(sl, i0, i1), np.float64)
        if need <= m:
            out, carry = carry[:need], carry[need:]
            return out
        out = np.concatenate([
            carry, np.asarray(service_fn(sl[m:], i0 + m, i1), np.float64)])
        carry = carry[:0]
        return out

    i = 0
    while i < n:
        t0 = float(times[i])
        idle = pool.idle_slots(t0, n - i)
        k = len(idle)
        if k:
            # idle slots at t0 stay idle until assigned: start == arrival
            st = times[i:i + k]
            sv = take_sv(idle, i, i + k)
            cm = st + sv
            pool.key[idle] = cm
            slots[i:i + k] = idle
            starts[i:i + k], comps[i:i + k] = st, cm
            svcs[i:i + k] = sv
            i += k
            continue
        # ---- vectorised busy round: no idle slot at the chunk head ----
        live = pool.live[:pool.n]
        key = pool.key[:pool.n]
        ready = pool.ready[:pool.n]
        busy = np.flatnonzero(live & (ready <= t0))
        if busy.size > 1:
            # the round is exact only while no unassigned slot can go
            # idle (t < min busy horizon) and no pending server can come
            # up (t < min pending ready)
            t_lim = key[busy].min()
            pend = ready[live & (ready > t0)]
            if pend.size:
                t_lim = min(t_lim, pend.min())
            r0 = min(int(np.searchsorted(times[i:], t_lim, side="left")),
                     busy.size)
            if r0 > 1:
                order = np.argsort(key[busy], kind="stable")[:r0]
                hs = busy[order]               # (key, slot)-sorted horizons
                hk = key[hs]
                ts = times[i:i + r0]
                # one batch draw for the whole round, task-index order —
                # numpy Generator batch draws equal scalar draws, so the
                # stream matches per-event dispatch
                sv = take_sv(hs, i, i + r0)
                st = np.maximum(ts, hk)
                cm = st + sv
                run_min = np.minimum.accumulate(cm)
                # valid prefix: the per-event oracle assigns task j to
                # h[j] iff h[j]'s horizon strictly precedes every earlier
                # completion of the round (else it reuses a committed
                # slot, or takes it as idle)
                viol = np.flatnonzero(hk[1:] >= run_min[:-1])
                r = int(viol[0]) + 1 if viol.size else r0
                pool.key[hs[:r]] = cm[:r]
                slots[i:i + r] = hs[:r]
                starts[i:i + r], comps[i:i + r] = st[:r], cm[:r]
                svcs[i:i + r] = sv[:r]
                i += r
                if r < r0:
                    # cut: the remaining drawn service times go back to
                    # the carry front (their tasks precede any older
                    # leftover); the outer loop re-gathers the freed
                    # slots through the normal idle/busy rounds
                    carry = (np.concatenate([sv[r:], carry])
                             if carry.size else sv[r:].copy())
                continue
        s = pool.select(t0)
        if s < 0 and on_cold is not None:
            s = on_cold(t0)
        if s < 0:
            slots[i] = -1
            comps[i] = t0 + cold_timeout_s
            i += 1
            continue
        st = max(t0, float(pool.key[s]), float(pool.ready[s]))
        sv = float(take_sv(np.asarray([s]), i, i + 1)[0])
        pool.key[s] = st + sv
        slots[i], starts[i] = s, st
        comps[i], svcs[i] = st + sv, sv
        i += 1
    return slots, starts, comps, svcs
