"""Event-heap discrete-event core shared by the cluster sim and the TPU
serving fleet (DESIGN.md §3).

The seed engines selected a server for every task with an O(P) scan
(``min(pods, key=...)``) and undid mis-dispatches with O(n)
``completed.remove``.  This core replaces both:

* ``ServerPool`` — per-group lazy heaps that reproduce the seed selection
  order *exactly* (same tie-breaking) at O(log P) per dispatch:

  - ``free``   : ready & idle servers, keyed by insertion sequence, so ties
                 among idle servers resolve in creation (pid/rid) order like
                 the seed's first-minimal list scan;
  - ``busy``   : ready & occupied servers, keyed (selection key, seq) —
                 the seed's ``min(max(free_at, t))`` over busy servers;
  - ``pending``: not-yet-ready servers, selectable only when no ready
                 server exists (the cluster sim's queue-on-spinning-up
                 fallback), keyed (selection key, seq); a companion
                 ``ready_heap`` keyed ready_at promotes them.

  Single-phase pools (``two_phase=False``, the fleet) skip the pending
  distinction: the selection key already folds ready_at in.

  Entries are invalidated lazily via per-server version counters, so drain,
  death and key updates are O(1) and stale heap entries are skipped on pop.

* ``EventQueue`` — heap-ordered failure/straggler/recovery injection
  (see events.py).

* ``WindowedExporter`` — the per-group windowed metric exporter (the
  Prometheus-adapter stand-in): per-window task counters, raw sample log
  and a configurable moving average over the last ``ma_windows`` samples.

* append-only completion logging — redispatch mutates the task record in
  place; the ``_logged`` guard keeps the record single-entry without the
  seed's O(n) ``list.remove``.

The pool is duck-typed: any object with ``dead``/``draining`` attributes can
be registered; pool bookkeeping lives in ``_pool_*`` attributes attached at
registration.
"""
from __future__ import annotations

import heapq
from collections import defaultdict

import numpy as np

from repro.sim.events import EventQueue

_READY, _PENDING = "ready", "pending"


def account_busy(busy: dict, start: float, end: float, window_s: float):
    """Credit [start, end) busy time into per-window buckets."""
    i0, i1 = int(start // window_s), int(end // window_s)
    for i in range(i0, i1 + 1):
        lo = max(start, i * window_s)
        hi = min(end, (i + 1) * window_s)
        if hi > lo:
            busy[i] += hi - lo


class ServerPool:
    """Heap-based server selection for one scaling group."""

    def __init__(self, two_phase: bool = True):
        self.two_phase = two_phase
        self.n_live = 0
        self._seq = 0
        self._free: list[tuple[int, int, object]] = []      # (seq, ver, s)
        self._busy: list[tuple[float, int, int, object]] = []
        self._pending: list[tuple[float, int, int, object]] = []
        self._ready_heap: list[tuple[float, int, object]] = []

    # ------------------------------------------------------------ intern --
    @staticmethod
    def _alive(s) -> bool:
        return not s.dead and not s.draining

    def _valid(self, s, ver: int, phase: str) -> bool:
        return (self._alive(s) and s._pool_version == ver
                and s._pool_phase == phase)

    def _push(self, s):
        if s._pool_phase == _READY:
            heapq.heappush(self._busy,
                           (s._pool_key, s._pool_seq, s._pool_version, s))
        else:
            heapq.heappush(self._pending,
                           (s._pool_key, s._pool_seq, s._pool_version, s))

    # ------------------------------------------------------------ public --
    def add(self, s, t: float, key: float, ready_at: float):
        """Register a server.  ``key`` is its selection key (the cluster's
        ``free_at``, the fleet's ``max(min(slot_free_at), ready_at)``)."""
        s._pool_seq = self._seq
        self._seq += 1
        s._pool_version = 0
        s._pool_key = key
        s._pool_live = True
        if self.two_phase and ready_at > t:
            s._pool_phase = _PENDING
            heapq.heappush(self._ready_heap, (ready_at, s._pool_seq, s))
        else:
            s._pool_phase = _READY
        self._push(s)
        self.n_live += 1

    def update(self, s, key: float):
        """Re-key a server after a dispatch changed its horizon."""
        s._pool_key = key
        s._pool_version += 1
        self._push(s)

    def invalidate(self, s):
        """Server drained or died — caller has already set the flag."""
        s._pool_version += 1
        if getattr(s, "_pool_live", False):
            s._pool_live = False
            self.n_live -= 1

    def reset(self, s, key: float):
        """Force a server ready-now (e.g. pre-warmed initial capacity)."""
        s._pool_phase = _READY
        self.update(s, key)

    def select(self, t: float):
        """Pop the server the seed scan would pick at time ``t``.

        The caller *must* hand the server back via ``update`` (or
        ``invalidate``) after recording the dispatch — selection removes the
        live heap entry.
        """
        # 1. promote pending servers whose ready_at has passed (not
        #    version-checked: fallback dispatches bump versions but must not
        #    cancel promotion)
        while self._ready_heap and self._ready_heap[0][0] <= t:
            _, _, s = heapq.heappop(self._ready_heap)
            if self._alive(s) and s._pool_phase == _PENDING:
                s._pool_phase = _READY
                s._pool_version += 1
                self._push(s)
        # 2. ready servers whose key horizon has passed are idle: move them
        #    to the free heap where ties resolve in creation order
        while self._busy and self._busy[0][0] <= t:
            _, seq, ver, s = heapq.heappop(self._busy)
            if self._valid(s, ver, _READY):
                s._pool_version += 1
                heapq.heappush(self._free, (seq, s._pool_version, s))
        # 3. selection priority: idle ready -> earliest busy ready ->
        #    earliest pending (two-phase only)
        while self._free:
            _, ver, s = heapq.heappop(self._free)
            if self._valid(s, ver, _READY):
                return s
        while self._busy:
            _, _, ver, s = heapq.heappop(self._busy)
            if self._valid(s, ver, _READY):
                return s
        while self._pending:
            _, _, ver, s = heapq.heappop(self._pending)
            if self._valid(s, ver, _PENDING):
                return s
        return None


class WindowedExporter:
    """Windowed metric readout: per-group arrival counters + raw sample log
    + ``ma_windows``-sample moving average (the Prometheus rate()/avg
    emulation; ma_windows=1 disables smoothing)."""

    def __init__(self, window_s: float, ma_windows: int = 4):
        self.window_s = window_s
        self.ma_windows = max(int(ma_windows), 1)
        self.samples: dict[str, list[tuple[float, np.ndarray]]] = \
            defaultdict(list)
        self._counts: dict[str, int] = defaultdict(int)
        self._raw: dict[str, list[np.ndarray]] = defaultdict(list)

    def window_index(self, t: float) -> int:
        return int((t - 1e-9) // self.window_s)

    def count(self, group: str, n: int = 1):
        self._counts[group] += n

    def take_count(self, group: str) -> int:
        n = self._counts.get(group, 0)
        self._counts[group] = 0
        return n

    def push(self, group: str, t: float, raw: np.ndarray) -> np.ndarray:
        """Store a raw reading, return the smoothed exporter value."""
        self._raw[group].append(np.asarray(raw, np.float64))
        # only the trailing MA window is ever read back — don't let the raw
        # log shadow-copy the samples log on long runs
        self._raw[group] = self._raw[group][-self.ma_windows:]
        ma = np.mean(self._raw[group], axis=0)
        self.samples[group].append((t, ma))
        return ma


class SimCore:
    """Registry + pools + events + exporter: the shared substrate a domain
    adapter (ClusterSim, ServingFleet) drives."""

    def __init__(self, window_s: float, two_phase: bool = True,
                 ma_windows: int = 4):
        self.window_s = window_s
        self.two_phase = two_phase
        self.servers: list = []
        self.by_group: dict[str, list] = defaultdict(list)
        self.pools: dict[str, ServerPool] = {}
        self.events = EventQueue()
        self.exporter = WindowedExporter(window_s, ma_windows)

    def pool(self, group: str) -> ServerPool:
        if group not in self.pools:
            self.pools[group] = ServerPool(self.two_phase)
        return self.pools[group]

    def add_server(self, s, group: str, t: float, key: float,
                   ready_at: float):
        self.servers.append(s)
        self.by_group[group].append(s)
        self.pool(group).add(s, t, key, ready_at)

    def live(self, group: str):
        return [s for s in self.by_group[group]
                if not s.dead and not s.draining]

    def n_live(self, group: str) -> int:
        return self.pool(group).n_live

    def log_completion(self, log: list, rec):
        """Append-only completion log: a redispatched record is mutated in
        place and must not be double-counted (no O(n) list.remove)."""
        if not getattr(rec, "_logged", False):
            rec._logged = True
            log.append(rec)

    def account_busy(self, busy: dict, start: float, end: float):
        account_busy(busy, start, end, self.window_s)
