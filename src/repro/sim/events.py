"""Time-ordered event queue for the sim core.

The seed simulators kept injected events (failures, recoveries, straggler
on/off) in a plain list and re-scanned the whole list every control tick —
O(E) per tick.  This is a heap: ``pop_due`` returns the fired events in
(time, insertion) order at O(k log E) for k fired events, which also makes
the firing order deterministic when several events share a timestamp.
"""
from __future__ import annotations

import heapq
import itertools


class EventQueue:
    """Min-heap of (t, seq, kind, payload) events."""

    def __init__(self):
        self._heap: list[tuple[float, int, str, dict]] = []
        self._seq = itertools.count()

    def push(self, t: float, kind: str, **payload):
        heapq.heappush(self._heap, (t, next(self._seq), kind, payload))

    def push_batch(self, ts, kind: str, payloads=None):
        """Schedule one event per entry of ``ts`` (fleet-scale scenario
        injection: arrays of failure/straggler times in one call).
        ``payloads`` is an optional parallel list of payload dicts."""
        for i, t in enumerate(ts):
            payload = payloads[i] if payloads is not None else {}
            heapq.heappush(self._heap,
                           (float(t), next(self._seq), kind, payload))

    def pop_due(self, t: float) -> list[tuple[float, str, dict]]:
        """All events with fire time <= t, in (time, insertion) order."""
        fired = []
        while self._heap and self._heap[0][0] <= t:
            ft, _, kind, payload = heapq.heappop(self._heap)
            fired.append((ft, kind, payload))
        return fired

    def peek_t(self) -> float | None:
        return self._heap[0][0] if self._heap else None

    def __len__(self):
        return len(self._heap)
