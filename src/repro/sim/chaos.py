# Seeded fault-injection engine (DESIGN.md §13, docs/resilience.md).
#
# A ChaosSchedule is a flat, time-sorted structured array of injection
# events, generated once from a seed and then merely *replayed* by the
# federation driver — so the same seed always yields the same storm, and
# an empty schedule is a bitwise no-op on the run.  Four event kinds:
#
#   NODE_FAIL   spatially-correlated node-failure storms.  A global
#               two-state Markov driver (OFF->ON with `storm_start_p`
#               per window, ON->OFF with `storm_stop_p`, so burst
#               lengths are geometric) gates per-zone kill events; each
#               zone joins a given storm with probability `storm_zone_p`
#               drawn once at storm onset, which is what correlates the
#               failures across zones.
#   BLACKOUT    metric-exporter outage for one target: the exporter
#               keeps republishing its last sample for `arg` seconds,
#               so the controller sees a frozen (stale) metric row.
#   STALL       forecaster stall: the next fused forecast dispatch is
#               delayed by `arg` seconds, exercising the control-plane
#               forecast deadline.
#   SHARD_CRASH one control-plane shard loses its columnar state and
#               restarts `arg` ticks later from its last snapshot.
#
# The schedule is composable (`merge`) and replayable (`reset`); its
# `signature()` hashes the packed event array for determinism tests.
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

NODE_FAIL = 0
BLACKOUT = 1
STALL = 2
SHARD_CRASH = 3

KIND_NAMES = {NODE_FAIL: "node_fail", BLACKOUT: "blackout",
              STALL: "stall", SHARD_CRASH: "shard_crash"}

CHAOS_DTYPE = np.dtype([("t", np.float64), ("kind", np.int32),
                        ("target", np.int32), ("arg", np.float64)])


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Knobs for the four generators.  All rates default to *off* so a
    default config produces an empty (quiet) schedule."""

    window_s: float = 15.0
    # correlated node-failure storms
    storm_start_p: float = 0.0        # per-window OFF->ON probability
    storm_stop_p: float = 0.25        # per-window ON->OFF (mean burst 1/p windows)
    storm_zone_p: float = 0.6         # P(a zone joins a given storm)
    storm_kill_lo: float = 0.05       # per ON-window kill fraction bounds
    storm_kill_hi: float = 0.25
    # metric-exporter blackouts
    blackout_rate_per_h: float = 0.0  # per-target Poisson start rate
    blackout_lo_s: float = 60.0
    blackout_hi_s: float = 300.0
    # forecaster stalls
    stall_rate_per_h: float = 0.0
    stall_s: float = 1.0
    # shard / controller crash-restart
    crash_rate_per_h: float = 0.0
    crash_down_ticks: int = 1


def _empty_events() -> np.ndarray:
    return np.zeros(0, dtype=CHAOS_DTYPE)


def _pack(ts, kinds, targets, args) -> np.ndarray:
    ev = np.zeros(len(ts), dtype=CHAOS_DTYPE)
    ev["t"] = ts
    ev["kind"] = kinds
    ev["target"] = targets
    ev["arg"] = args
    return ev


class ChaosSchedule:
    """Immutable, seed-deterministic event tape.

    `pop_due(t)` advances an internal cursor and returns every event
    with ``ev.t <= t`` not yet delivered; `reset()` rewinds the cursor
    so the same schedule can drive an A/B pair of runs.
    """

    def __init__(self, events: np.ndarray, *, n_zones: int, seed=None,
                 cfg: ChaosConfig | None = None):
        order = np.lexsort((events["target"], events["kind"], events["t"]))
        self.events = events[order]
        self.n_zones = int(n_zones)
        self.seed = seed
        self.cfg = cfg
        self._cur = 0

    # -- construction ---------------------------------------------------
    @classmethod
    def quiet(cls, n_zones: int = 0) -> "ChaosSchedule":
        return cls(_empty_events(), n_zones=n_zones)

    @classmethod
    def build(cls, cfg: ChaosConfig, *, n_zones: int, t_end: float,
              seed: int, n_shards: int = 1) -> "ChaosSchedule":
        w = float(cfg.window_s)
        n_win = int(np.ceil(t_end / w))
        # independent child streams per generator so adding one kind of
        # chaos never perturbs another kind's draws
        streams = [np.random.default_rng(s)
                   for s in np.random.SeedSequence(seed).spawn(4)]
        parts = [
            cls._storm_events(cfg, streams[0], n_zones, n_win),
            cls._blackout_events(cfg, streams[1], n_zones, t_end),
            cls._point_events(streams[2], cfg.stall_rate_per_h, t_end,
                              STALL, 1, cfg.stall_s),
            cls._point_events(streams[3], cfg.crash_rate_per_h, t_end,
                              SHARD_CRASH, max(n_shards, 1),
                              float(cfg.crash_down_ticks)),
        ]
        events = np.concatenate([p for p in parts if p.size] or
                                [_empty_events()])
        return cls(events, n_zones=n_zones, seed=seed, cfg=cfg)

    @staticmethod
    def _storm_events(cfg, rng, n_zones, n_win) -> np.ndarray:
        if cfg.storm_start_p <= 0.0 or n_zones == 0 or n_win == 0:
            return _empty_events()
        w = float(cfg.window_s)
        ts, targets, args = [], [], []
        on = False
        joined = np.zeros(n_zones, dtype=bool)
        for wi in range(n_win):
            u = rng.random()
            if not on:
                if u < cfg.storm_start_p:
                    on = True
                    # spatial correlation: membership drawn once per storm
                    joined = rng.random(n_zones) < cfg.storm_zone_p
                    if not joined.any():
                        joined[rng.integers(n_zones)] = True
                else:
                    continue
            elif u < cfg.storm_stop_p:
                on = False
                continue
            zs = np.flatnonzero(joined)
            fracs = rng.uniform(cfg.storm_kill_lo, cfg.storm_kill_hi,
                                zs.size)
            # land just inside the window so the tick at the window's
            # close observes the carnage
            t_evt = wi * w + 0.25 * w
            ts.extend([t_evt] * zs.size)
            targets.extend(zs.tolist())
            args.extend(fracs.tolist())
        return _pack(ts, NODE_FAIL, targets, args)

    @staticmethod
    def _blackout_events(cfg, rng, n_zones, t_end) -> np.ndarray:
        if cfg.blackout_rate_per_h <= 0.0 or n_zones == 0:
            return _empty_events()
        rate_s = cfg.blackout_rate_per_h / 3600.0
        ts, targets, args = [], [], []
        for z in range(n_zones):
            n = rng.poisson(rate_s * t_end)
            if n == 0:
                continue
            starts = np.sort(rng.uniform(0.0, t_end, n))
            durs = rng.uniform(cfg.blackout_lo_s, cfg.blackout_hi_s, n)
            ts.extend(starts.tolist())
            targets.extend([z] * n)
            args.extend(durs.tolist())
        return _pack(ts, BLACKOUT, targets, args)

    @staticmethod
    def _point_events(rng, rate_per_h, t_end, kind, n_targets,
                      arg) -> np.ndarray:
        if rate_per_h <= 0.0:
            return _empty_events()
        n = rng.poisson(rate_per_h / 3600.0 * t_end)
        if n == 0:
            return _empty_events()
        ts = np.sort(rng.uniform(0.0, t_end, n))
        targets = rng.integers(0, n_targets, n)
        return _pack(ts.tolist(), kind, targets.tolist(), [arg] * n)

    # -- replay ---------------------------------------------------------
    def reset(self) -> None:
        self._cur = 0

    def pop_due(self, t: float) -> np.ndarray:
        """Events with ``ev.t <= t`` not yet delivered, in time order."""
        hi = int(np.searchsorted(self.events["t"], t, side="right"))
        due = self.events[self._cur:hi]
        self._cur = hi
        return due

    # -- composition / identity -----------------------------------------
    def merge(self, other: "ChaosSchedule") -> "ChaosSchedule":
        ev = np.concatenate([self.events, other.events])
        return ChaosSchedule(ev, n_zones=max(self.n_zones, other.n_zones))

    def signature(self) -> str:
        h = hashlib.sha256()
        h.update(np.int64(self.n_zones).tobytes())
        h.update(self.events.tobytes())
        return h.hexdigest()

    def __len__(self) -> int:
        return int(self.events.size)

    def __eq__(self, other) -> bool:
        return (isinstance(other, ChaosSchedule)
                and self.n_zones == other.n_zones
                and self.events.shape == other.events.shape
                and bool(np.all(self.events == other.events)))

    def __repr__(self) -> str:
        kinds = {KIND_NAMES[k]: int(n) for k, n in
                 zip(*np.unique(self.events["kind"], return_counts=True))}
        return f"ChaosSchedule(n={len(self)}, zones={self.n_zones}, {kinds})"
