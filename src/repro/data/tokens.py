"""Deterministic synthetic LM data pipeline.

Markov-chain token streams give a learnable distribution (loss decreases
under training — asserted by the integration tests) while staying fully
offline and reproducible.  Batches are sharded over the mesh's batch axes
via device_put when a mesh is supplied; per-step determinism is keyed on
(seed, step), so a restarted job resumes with identical data order.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


class SyntheticLMData:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, order: int = 1, mesh=None, rules=None):
        self.vocab, self.seq_len, self.batch = vocab, seq_len, global_batch
        self.seed = seed
        self.mesh, self.rules = mesh, rules
        rng = np.random.default_rng(seed)
        # sparse-ish Markov transition: each token strongly prefers ~4 successors
        k = 4
        self._succ = rng.integers(0, vocab, (vocab, k))

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        toks = np.empty((self.batch, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, self.batch)
        choices = rng.integers(0, self._succ.shape[1],
                               (self.batch, self.seq_len))
        noise = rng.random((self.batch, self.seq_len)) < 0.1
        rand_tok = rng.integers(0, self.vocab, (self.batch, self.seq_len))
        for t in range(self.seq_len):
            nxt = self._succ[toks[:, t], choices[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)
        batch = {"tokens": jnp.asarray(toks[:, :-1]),
                 "labels": jnp.asarray(toks[:, 1:])}
        if self.mesh is not None:
            from repro.distributed.sharding import named_sharding
            sh = named_sharding(("batch", "seq"), batch["tokens"].shape,
                                self.rules, self.mesh)
            batch = {k: jax.device_put(v, sh) for k, v in batch.items()}
        return batch

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
