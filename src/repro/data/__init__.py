from repro.data.tokens import SyntheticLMData
