"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000 — alternating local(4096-window)/global attention, logit
soft-caps, post-norms, tied + scaled embeddings.  [arXiv:2408.00118; hf]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b", family="dense",
        n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
        d_ff=14336, vocab=256000, sliding_window=4096, local_global_period=2,
        attn_softcap=50.0, final_softcap=30.0, post_norm=True,
        tie_embeddings=True, embed_scale=True, mlp_act="gelu",
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, sliding_window=32, attn_impl="naive",
        remat="none",
    )


register("gemma2-9b", full, smoke)
