"""mamba2-780m [ssm]: 48L d_model=1536 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm",
        n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0, head_dim=0,
        d_ff=0, vocab=50280, ssm_state=128, ssm_heads=48, ssm_head_dim=64,
        ssm_expand=2, ssm_chunk=128, tie_embeddings=True,
        kv_seq_shard=True,       # adopted: EXPERIMENTS.md §Perf D1
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=3, d_model=64, vocab=256, ssm_state=16, ssm_heads=4,
        ssm_head_dim=32, ssm_chunk=32, remat="none",
    )


register("mamba2-780m", full, smoke)
