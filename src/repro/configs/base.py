"""Architecture config system: exact assigned configs + reduced smoke variants.

``get_config(arch_id)`` returns the full published config;
``smoke_config(arch_id)`` returns a CPU-runnable reduction of the same family.
Input-shape cells (train_4k / prefill_32k / decode_32k / long_500k) are shared
by all LM archs; applicability is encoded per arch (see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family = "dense"
    # transformer core
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab: int = 1024
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    attn_bias: bool = False                 # qwen1.5-style qkv bias
    # attention variants
    sliding_window: int | None = None       # SWA width (h2o-danube / gemma2 local)
    local_global_period: int | None = None  # gemma2: alternate local/global layers
    attn_softcap: float | None = None       # gemma2 attention logit soft-cap
    final_softcap: float | None = None      # gemma2 final logit soft-cap
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    moe_impl: Literal["tp", "ep"] = "tp"    # tensor- vs expert-parallel experts
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_conv: int = 4
    # hybrid (zamba2): shared transformer block every `shared_period` ssm layers
    shared_period: int = 0
    n_shared_blocks: int = 0                # alternating shared blocks (zamba2: 2)
    # enc-dec (seamless)
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    # modality frontend stub: 'none' | 'audio' (frame embeds) | 'vision' (patch embeds)
    frontend: str = "none"
    frontend_seq: int = 0                   # prefix positions fed as embeddings
    # runtime / distribution
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: Literal["none", "full", "dots", "dots_all"] = "full"
    attn_impl: Literal["naive", "blocked", "pallas"] = "blocked"
    attn_block_q: int = 512
    attn_block_kv: int = 1024
    fsdp: bool = False                      # shard params over data axis too
    opt_moments_dtype: str = "float32"      # bf16 for llama3-405b to fit HBM
    norm_eps: float = 1e-6
    mlp_act: Literal["silu", "gelu", "relu"] = "silu"
    post_norm: bool = False                 # gemma2 post-layer norms
    embed_scale: bool = False               # gemma2 sqrt(d_model) embed scaling
    kv_repeat: int = 1                      # runtime KV-head replication so the
                                            # kv dim divides the model axis
    kv_cache_dtype: str = "bfloat16"        # 'int8' enables quantized KV cache
    decode_embed_shard: bool = False        # decode: shard activations on d over
                                            # 'data' => weight-stationary 2D FSDP
                                            # (all-reduce activations, never
                                            # all-gather weights per token)
    seq_shard_resid: bool = False           # Megatron-SP: shard the residual
                                            # stream (and the remat-saved stack)
                                            # over 'model' on the seq dim
    kv_seq_shard: bool = False              # long-context decode: shard the KV
                                            # cache seq dim over 'data' (batch=1
                                            # leaves that axis idle)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:  # ssm inner width
        return self.ssm_expand * self.d_model

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: dict[str, "tuple"] = {}


def register(arch_id: str, full_fn, smoke_fn):
    _REGISTRY[arch_id] = (full_fn, smoke_fn)


def get_config(arch_id: str) -> ModelConfig:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id][0]()


def smoke_config(arch_id: str) -> ModelConfig:
    _ensure_loaded()
    return _REGISTRY[arch_id][1]()


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """long_500k needs sub-quadratic attention (DESIGN.md §4)."""
    if shape.name == "long_500k":
        subquad = (
            cfg.family in ("ssm", "hybrid")
            or (cfg.sliding_window is not None and cfg.local_global_period is None)
        )
        if not subquad:
            return False, "pure full attention: long_500k skipped per DESIGN.md"
    if cfg.family == "encdec" and shape.kind == "train" and shape.seq_len > 100_000:
        return False, "enc-dec long-context not defined"
    return True, ""


def _ensure_loaded():
    if _REGISTRY:
        return
    import importlib
    for mod in (
        "zamba2_2p7b", "h2o_danube_1p8b", "llama3_405b", "codeqwen15_7b",
        "gemma2_9b", "phi35_moe", "granite_moe_1b", "mamba2_780m",
        "seamless_m4t_medium", "pixtral_12b",
    ):
        importlib.import_module(f"repro.configs.{mod}")
