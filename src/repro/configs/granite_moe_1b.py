"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, 32 experts top-8.  Vocab padded to 49408 * for model-axis
sharding (layers.VOCAB_PAD).  [hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
        d_ff=512, vocab=49155, n_experts=32, top_k=8, d_ff_expert=512,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab=515, n_experts=8, top_k=4, d_ff_expert=64,
        attn_impl="naive", remat="none",
    )


register("granite-moe-1b-a400m", full, smoke)
