"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 — mistral-nemo decoder backbone; the pixtral-ViT frontend is a
STUB (input_specs() provides precomputed patch embeddings as a 1024-position
prefix).  [hf:mistralai/Pixtral-12B-2409; unverified]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="dense",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=131072, rope_theta=1_000_000.0,
        frontend="vision", frontend_seq=1024,
        seq_shard_resid=True,    # adopted: EXPERIMENTS.md §Perf C1
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, frontend_seq=8, seq_shard_resid=False,
        attn_impl="naive", remat="none",
    )


register("pixtral-12b", full, smoke)
