"""codeqwen1.5-7b [dense]: 32L d_model=4096 32H (kv=32) d_ff=13440
vocab=92416 — qwen1.5 arch (attention qkv bias).  [hf:Qwen/CodeQwen1.5-7B]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
        d_ff=13440, vocab=92416, attn_bias=True, rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=160, vocab=512, attn_impl="naive", remat="none",
    )


register("codeqwen1.5-7b", full, smoke)
