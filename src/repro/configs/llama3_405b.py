"""llama3-405b [dense]: 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256.  [arXiv:2407.21783; unverified]

FSDP + TP sharding; bf16 optimizer moments to fit 16 GB/chip HBM at 256 chips.
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b", family="dense",
        n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, head_dim=128,
        d_ff=53248, vocab=128256, rope_theta=500_000.0,
        fsdp=True, opt_moments_dtype="bfloat16",
        kv_cache_dtype="int8",   # adopted: EXPERIMENTS.md §Perf A1
        seq_shard_resid=True,    # adopted: EXPERIMENTS.md §Perf C1/A4
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab=512, fsdp=False, opt_moments_dtype="float32",
        kv_cache_dtype="bfloat16", seq_shard_resid=False,
        attn_impl="naive", remat="none",
    )


register("llama3-405b", full, smoke)
