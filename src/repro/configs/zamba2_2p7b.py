"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64 — Mamba2 backbone + 2 alternating shared attention blocks
applied every 2 Mamba layers.  [arXiv:2411.15242; hf]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
        d_ff=10240, vocab=32000, ssm_state=64, ssm_heads=80, ssm_head_dim=64,
        ssm_expand=2, ssm_chunk=64,  # chunk: EXPERIMENTS.md §Perf B1
        shared_period=2, n_shared_blocks=2,
        tie_embeddings=True,
        kv_seq_shard=True,       # adopted: EXPERIMENTS.md §Perf D1
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, ssm_state=16, ssm_heads=4, ssm_head_dim=32,
        ssm_chunk=32, attn_impl="naive", remat="none",
    )


register("zamba2-2.7b", full, smoke)
