"""h2o-danube-1.8b [dense]: 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000 — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; hf]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b", family="dense",
        n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=80,
        d_ff=6912, vocab=32000, sliding_window=4096,
        kv_seq_shard=True,       # adopted: EXPERIMENTS.md §Perf D1
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, sliding_window=32, attn_impl="naive",
        remat="none",
    )


register("h2o-danube-1.8b", full, smoke)
