"""seamless-m4t-medium [audio]: 12L d_model=1024 16H (kv=16) d_ff=4096
vocab=256206 — enc-dec; the audio frontend is a STUB (input_specs() provides
precomputed frame embeddings).  [arXiv:2308.11596; hf]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="encdec",
        n_layers=24, n_enc_layers=12, n_dec_layers=12,
        d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
        d_ff=4096, vocab=256206, frontend="audio", mlp_act="relu",
    )


def smoke() -> ModelConfig:
    return full().replace(
        n_layers=4, n_enc_layers=2, n_dec_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab=512, attn_impl="naive",
        remat="none",
    )


register("seamless-m4t-medium", full, smoke)
