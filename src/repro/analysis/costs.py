"""Analytic FLOP / HBM-byte model per (arch x shape) cell.

XLA counts a ``lax.scan`` body once (verified 8x undercount on an 8-step
scan — see EXPERIMENTS.md §Dry-run), so HLO cost_analysis cannot price the
layer-scanned models directly; instead this module computes *executed* FLOPs
analytically (including causal-masking waste, remat recompute and MoE
capacity) and was validated against exact HLO counts on small UNROLLED
configs (tests/test_costs.py keeps the two within tolerance).

Terms reported per device on the (data=16, model=16) pod:
    compute_s    = executed_flops / chips / 197e12      (bf16 peak, v5e)
    memory_s     = hbm_bytes / chips / 819e9
    collective_s = wire_bytes_per_device / 50e9          (from the dry-run HLO)
MODEL_FLOPS = 6·N·D (train) or 2·N_active·D (serve); usefulness =
MODEL_FLOPS / executed_flops.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.layers import padded_vocab

PEAK_FLOPS = 197e12      # bf16 / chip, TPU v5e
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link

CHIPS = 256              # single-pod 16x16 (roofline table is single-pod)


def _attn_kv_len(cfg: ModelConfig, S: int, window: int | None) -> int:
    """Executed kv positions per query in the blocked XLA path."""
    if window is None:
        return S
    return min(S, window + 2 * cfg.attn_block_kv)


def _per_token_layer_flops(cfg: ModelConfig, S: int, kind: str) -> float:
    """Forward FLOPs per token for ONE pattern step (may hold >1 layer)."""
    d, Dh = cfg.d_model, cfg.head_dim
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads * cfg.kv_repeat
    f = 0.0

    def attn(window):
        qkvo = 2 * d * Dh * (2 * Hq + 2 * Hkv)
        kv_len = _attn_kv_len(cfg, S, window) if kind != "decode" else (
            min(S, window) if window else S)
        sc = 2 * 2 * kv_len * Hq * Dh
        return qkvo + sc

    def mlp():
        return 6 * d * cfg.d_ff

    def moe():
        r = 2 * d * cfg.n_experts
        eff = cfg.top_k * cfg.capacity_factor
        return r + eff * 6 * d * cfg.d_ff_expert

    def mamba():
        di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
        proj = 2 * d * (2 * di + 2 * N + H) + 2 * di * d
        conv = 2 * cfg.ssm_conv * (di + 2 * N)
        L = cfg.ssm_chunk
        if kind == "decode":
            ssd = 6 * N * P * H           # state update + readout per head
        else:
            ssd = H * (2 * L * N + 2 * L * P + 6 * N * P)
        return proj + conv + ssd

    if cfg.family == "encdec":
        # every (source, target) position pair runs one enc / dec layer stack;
        # cross-attention scores span S_src (== S here)
        enc = cfg.n_enc_layers * (attn(None) + mlp())
        cross = 2 * d * Dh * (2 * Hq + 2 * Hkv) + 2 * 2 * S * Hq * Dh
        dec = cfg.n_dec_layers * (attn(None) + cross + mlp())
        return enc + dec, 1

    from repro.models.transformer import _pattern
    pattern, n_steps = _pattern(cfg)
    for k in pattern:
        if k == "mamba":
            f += mamba()
        elif k == "local":
            f += attn(cfg.sliding_window) + mlp()
        elif k == "global":
            f += attn(None) + mlp()
        else:
            f += attn(cfg.sliding_window) + (moe() if cfg.family == "moe"
                                             else mlp())
    if cfg.family == "hybrid":
        f += attn(None) + mlp() + 2 * (2 * d) * d   # shared block + concat proj
    return f, n_steps


def param_counts(cfg: ModelConfig) -> tuple[int, int]:
    """(N_total, N_flops) from the spec tree.  N_flops is the 6·N·D-effective
    count: MoE activates top_k of n_experts; zamba2's SHARED blocks contribute
    one invocation of compute per pattern step from a single stored copy
    (parameter sharing != compute sharing — without this correction the
    usefulness ratio blames the architecture for its own design)."""
    from repro.models.params import param_count
    from repro.models.registry import build_model
    model = build_model(cfg)
    n_total = param_count(model.specs())
    n_active = n_total
    if cfg.family == "moe":
        expert = 3 * cfg.d_model * cfg.d_ff_expert * cfg.n_experts * cfg.n_layers
        n_active = n_total - expert + expert * cfg.top_k / cfg.n_experts
    if cfg.family == "hybrid":
        from repro.models.transformer import _pattern, shared_block_specs
        _, n_steps = _pattern(cfg)
        shared_one = param_count(shared_block_specs(cfg))
        stored = shared_one * max(cfg.n_shared_blocks, 1)
        n_active = n_total - stored + shared_one * n_steps
    return int(n_total), int(n_active)


@dataclasses.dataclass
class CellCost:
    executed_flops: float        # total, all chips
    model_flops: float
    hbm_bytes: float             # total, all chips
    tokens: int

    def terms(self, wire_bytes_per_device: float, chips: int = CHIPS) -> dict:
        comp = self.executed_flops / chips / PEAK_FLOPS
        mem = self.hbm_bytes / chips / HBM_BW
        coll = wire_bytes_per_device / LINK_BW
        dom = max(("compute", comp), ("memory", mem), ("collective", coll),
                  key=lambda kv: kv[1])
        useful = self.model_flops / max(self.executed_flops, 1.0)
        ideal = self.model_flops / chips / PEAK_FLOPS
        return {
            "compute_s": comp, "memory_s": mem, "collective_s": coll,
            "dominant": dom[0], "dominant_s": dom[1],
            "usefulness": useful,
            "roofline_fraction": ideal / max(dom[1], 1e-30),
        }


def analytic_cell(cfg: ModelConfig, shape: ShapeSpec) -> CellCost:
    B, S = shape.global_batch, shape.seq_len
    n_total, n_active = param_counts(cfg)
    pv = padded_vocab(cfg.vocab)

    if shape.kind == "decode":
        tokens = B                     # one new token per sequence
        per_tok, n_steps = _per_token_layer_flops(cfg, S, "decode")
        fwd = tokens * (per_tok * n_steps + 2 * cfg.d_model * pv)
        executed = fwd
        model = 2 * n_active * tokens
        # params read once + full KV/state cache traffic + small writes
        kv_bytes = _cache_bytes(cfg, B, S)
        hbm = n_total * 2 + kv_bytes
        return CellCost(executed, model, hbm, tokens)

    tokens = B * S
    per_tok, n_steps = _per_token_layer_flops(cfg, S, shape.kind)
    fwd = tokens * (per_tok * n_steps + 2 * cfg.d_model * pv)
    if shape.kind == "train":
        mult = {"none": 3.0, "full": 4.0, "dots": 4.0, "dots_all": 3.1}[cfg.remat]
        executed = fwd * mult
        model = 6 * n_active * tokens
        opt_bytes = n_total * (4 + 16 if cfg.opt_moments_dtype == "float32"
                               else 4 + 8)
        act_stack = n_steps * tokens * cfg.d_model * 2
        hbm = n_total * 2 * 3 + opt_bytes + act_stack * 2
    else:                              # prefill
        executed = fwd
        model = 2 * n_active * tokens
        hbm = n_total * 2 + _cache_bytes(cfg, B, S) + tokens * cfg.d_model * 2 * n_steps
    return CellCost(executed, model, hbm, tokens)


def _cache_bytes(cfg: ModelConfig, B: int, S: int) -> float:
    kv_el = 1 if cfg.kv_cache_dtype == "int8" else 2
    from repro.models.transformer import _pattern
    if cfg.family == "encdec":
        n_attn = cfg.n_dec_layers
        cross = cfg.n_dec_layers * B * S * cfg.n_kv_heads * cfg.kv_repeat * \
            cfg.head_dim * 2 * 2
        return cross + n_attn * B * S * cfg.n_kv_heads * cfg.kv_repeat * \
            cfg.head_dim * 2 * kv_el
    pattern, n_steps = _pattern(cfg)
    n_attn = sum(1 for k in pattern if k != "mamba") * n_steps
    n_mamba = sum(1 for k in pattern if k == "mamba") * n_steps
    if cfg.family == "hybrid":
        n_attn += n_steps              # shared block invocations
    Hkv = cfg.n_kv_heads * cfg.kv_repeat
    attn_b = n_attn * B * S * Hkv * cfg.head_dim * 2 * kv_el
    if cfg.sliding_window and not cfg.local_global_period:
        attn_b = n_attn * B * min(S, cfg.sliding_window) * Hkv * \
            cfg.head_dim * 2 * kv_el
    ssm_b = n_mamba * B * (cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
                           * 4 + cfg.ssm_conv * cfg.d_inner * 2)
    return attn_b + ssm_b
