"""Jittable step functions (train / prefill / decode) with full shardings.

Each builder returns ``(fn, example_args)`` where every abstract arg carries a
NamedSharding, so ``jax.jit(fn).lower(*args)`` is the complete AOT story used
by both the dry-run and the real launchers.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch import specs as SP
from repro.models.params import abstract_params
from repro.models.registry import build_model
from repro.training.optimizer import AdamWConfig, adamw_update


def _replicated(mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P
    return NamedSharding(mesh, P())


def abstract_opt_state(specs, opt_cfg: AdamWConfig, mesh, rules):
    moments = abstract_params(specs, jnp.dtype(opt_cfg.moments_dtype),
                              mesh, rules)
    step = jax.ShapeDtypeStruct((), jnp.int32, sharding=_replicated(mesh))
    return {"mu": moments, "nu": moments, "step": step}


def make_train_step(cfg: ModelConfig, mesh, rules, opt_cfg: AdamWConfig | None = None):
    model = build_model(cfg)
    opt_cfg = opt_cfg or AdamWConfig(
        moments_dtype=cfg.opt_moments_dtype)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = model.loss(p, batch, mesh=mesh, rules=rules)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_params, new_opt, om = adamw_update(grads, opt_state, params, opt_cfg)
        out_metrics = {"loss": loss, **{k: v for k, v in metrics.items()},
                       **om}
        return new_params, new_opt, out_metrics

    return model, opt_cfg, train_step


def make_prefill_step(cfg: ModelConfig, mesh, rules):
    model = build_model(cfg)

    if cfg.family == "encdec":
        def prefill_step(params, batch):
            enc_out = model.encode(params, batch["frames"], mesh=mesh,
                                   rules=rules)
            B, S = batch["tokens"].shape
            cache = model.init_dec_cache(params, enc_out, B, max_len=S,
                                         prefilled=0)
            return enc_out[:, -1], cache
        return model, prefill_step

    def prefill_step(params, batch):
        n_pos = batch["tokens"].shape[1] + (
            cfg.frontend_seq if cfg.frontend == "vision" else 0)
        logits, cache = model.prefill(
            params, batch["tokens"], max_len=n_pos,
            extra_embeds=batch.get("extra_embeds"), mesh=mesh, rules=rules)
        return logits, cache

    return model, prefill_step


def make_decode_step(cfg: ModelConfig, mesh, rules):
    model = build_model(cfg)

    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens, mesh=mesh, rules=rules)

    return model, decode_step


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, rules):
    """Assemble (fn, abstract_args) for one (arch x shape x mesh) cell."""
    kind, batch = SP.input_specs(cfg, shape, mesh, rules)
    if kind == "train":
        model, opt_cfg, fn = make_train_step(cfg, mesh, rules)
        params = model.abstract(jnp.bfloat16, mesh, rules)
        opt = abstract_opt_state(model.specs(), opt_cfg, mesh, rules)
        return fn, (params, opt, batch)
    if kind == "prefill":
        model, fn = make_prefill_step(cfg, mesh, rules)
        params = model.abstract(jnp.bfloat16, mesh, rules)
        return fn, (params, batch)
    model, fn = make_decode_step(cfg, mesh, rules)
    params = model.abstract(jnp.bfloat16, mesh, rules)
    return fn, (params, batch["cache"], batch["tokens"])
