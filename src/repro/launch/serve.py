"""Serving launcher: continuous-batching decode engine on a smoke config.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m \
        --requests 16 --max-new 24
"""
import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--slots", type=int, default=8)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import smoke_config
    from repro.models.registry import build_model
    from repro.serving import ContinuousBatcher, DecodeEngine, Request

    cfg = smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), jnp.float32)
    engine = DecodeEngine(cfg, params, slots=args.slots,
                          max_len=args.prompt_len + args.max_new + 8)
    batcher = ContinuousBatcher(engine)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        batcher.submit(Request(i, rng.integers(0, cfg.vocab, args.prompt_len),
                               args.max_new))
    done = batcher.drain()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, {engine.steps} decode steps)")


if __name__ == "__main__":
    main()
