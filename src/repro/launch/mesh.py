"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never module-level state) so that
importing this module does not touch jax device initialisation — the dry-run
sets XLA_FLAGS before first jax init; smoke tests and benches see 1 device.

Single pod : (data=16, model=16)            — 256 chips (TPU v5e pod slice)
Multi-pod  : (pod=2, data=16, model=16)     — 512 chips, DCN 'pod' axis
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh for elastic re-meshing (e.g. after dropping a failed
    data slice: (15, 16) instead of (16, 16))."""
    return jax.make_mesh(shape, axes)


def rules_for(cfg, mesh, kind: str = "train"):
    """Pick the sharding-rule table for a config on a mesh."""
    from repro.distributed import sharding as sh
    rules = sh.MULTIPOD_RULES if "pod" in mesh.shape else sh.DEFAULT_RULES
    if getattr(cfg, "fsdp", False):
        rules = sh.fsdp_rules(rules)
    if getattr(cfg, "moe_impl", "tp") == "ep":
        rules = sh.ep_rules(rules)
    if getattr(cfg, "seq_shard_resid", False) and kind == "train":
        rules = dict(rules) | {"resid_seq": ("model",)}
    if getattr(cfg, "kv_seq_shard", False) and kind == "decode":
        rules = dict(rules) | {"kv_seq": ("data",)}
    if getattr(cfg, "decode_embed_shard", False) and kind == "decode":
        # weight-stationary decode: contract d over 'data'; GSPMD emits an
        # activation all-reduce instead of per-token weight all-gathers
        rules = dict(rules) | {"embed": ("data",)}
    return rules


def kv_repeat_for(cfg, mesh) -> int:
    """KV-head replication factor so the kv-head dim divides the model axis."""
    if cfg.n_kv_heads <= 0:
        return 1
    import math
    A = mesh.shape.get("model", 1)
    g = math.gcd(cfg.n_kv_heads, A)
    r = A // g
    # never repeat beyond the q-head count
    return min(r, max(cfg.n_heads // cfg.n_kv_heads, 1))
