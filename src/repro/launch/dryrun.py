import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct inputs on 512 placeholder host devices.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b \
        --shape train_4k [--multipod] [--out artifacts/dryrun]

Per cell it records memory_analysis(), cost_analysis() (per-device), and the
collective-op inventory parsed from the optimized HLO (with while-body
trip-count correction for the layer scan) into a JSON artifact consumed by
benchmarks/roofline.py and EXPERIMENTS.md.
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}


def parse_collectives(hlo_text: str, scan_trip_counts: dict[str, int]):
    """Sum collective operand bytes per computation.  Ops inside while-body
    computations are multiplied by the layer-scan trip count (XLA text shows
    the body once; jax's scan lowers to while with known length).

    Returns list of dicts: {op, dtype, bytes, group_size, computation, mult}.
    """
    results = []
    current_comp = "main"
    op_re = re.compile(
        r"^\s*(?:ROOT\s+)?%?[\w.\-]+ = \(?([a-z0-9]+)\[([\d,]*)\][^=]*?"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
        r"(?:-start|-done)?\(")
    comp_re = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\([^)]*\)\s*->")
    rg_re = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
    rg_list_re = re.compile(r"replica_groups=\{([^}]*)\}")
    for line in hlo_text.splitlines():
        mc = comp_re.match(line)
        if mc:
            current_comp = mc.group(1)
            continue
        m = op_re.match(line)
        if not m:
            continue
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        if "-done" in line.split("=")[1][:60] and "-start" not in line:
            # the -done op restates the shape; count only -start (or plain)
            if f"{op}-done" in line:
                continue
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        for d in filter(None, dims.split(",")):
            nbytes *= int(d)
        gs = None
        mg = rg_re.search(line)
        if mg:
            gs = int(mg.group(2))
        else:
            mg2 = rg_list_re.search(line)
            if mg2 and mg2.group(1):
                first = mg2.group(1).split("}")[0].split("{")[-1]
                gs = len([x for x in first.split(",") if x.strip() != ""])
        mult = 1
        lowered_name = current_comp.lower()
        if "while" in lowered_name or "body" in lowered_name:
            mult = scan_trip_counts.get("default", 1)
        results.append({"op": op, "dtype": dtype, "bytes": nbytes,
                        "group_size": gs or 1, "computation": current_comp,
                        "mult": mult})
    return results


def wire_bytes(colls) -> float:
    """Bytes crossing links per device, using standard ring factors."""
    total = 0.0
    for c in colls:
        n = max(c["group_size"], 1)
        if n == 1:
            continue
        if c["op"] == "all-reduce":
            f = 2 * (n - 1) / n
        elif c["op"] in ("all-gather", "reduce-scatter"):
            f = (n - 1) / n
        elif c["op"] == "all-to-all":
            f = (n - 1) / n
        else:  # collective-permute
            f = 1.0
        total += c["bytes"] * f * c["mult"]
    return total


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             overrides: dict | None = None) -> dict:
    from repro.configs import get_config, SHAPES
    from repro.configs.base import shape_applicable
    from repro.launch.mesh import make_production_mesh, rules_for, kv_repeat_for
    from repro.launch.steps import build_cell

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "status": "skipped"}

    ok, why = shape_applicable(cfg, shape)
    if not ok:
        rec["skip_reason"] = why
        _save(out_dir, cell_id, rec)
        print(f"[dryrun] {cell_id}: SKIP ({why})")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = cfg.replace(kv_repeat=kv_repeat_for(cfg, mesh))
    if overrides:
        cfg = cfg.replace(**overrides)
        rec["overrides"] = overrides
    rules = rules_for(cfg, mesh, kind=shape.kind)

    t0 = time.time()
    fn, args = build_cell(cfg, shape, mesh, rules)
    with mesh:
        lowered = jax.jit(fn).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    hlo = compiled.as_text()
    n_steps = _scan_len(cfg)
    colls = parse_collectives(hlo, {"default": n_steps})

    rec.update({
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_per_device": ma.argument_size_in_bytes
            + ma.temp_size_in_bytes,
        },
        "cost": {"flops_per_device": ca.get("flops", 0.0),
                 "bytes_per_device": ca.get("bytes accessed", 0.0)},
        "collectives": {
            "count": len(colls),
            "wire_bytes_per_device": wire_bytes(colls),
            "by_op": _group(colls),
            "scan_mult": n_steps,
        },
        "kv_repeat": cfg.kv_repeat,
    })
    _save(out_dir, cell_id, rec)
    gb = rec["memory"]["peak_per_device"] / 2**30
    print(f"[dryrun] {cell_id}: OK compile={t_compile:.1f}s "
          f"peak/dev={gb:.2f}GiB flops/dev={rec['cost']['flops_per_device']:.3e} "
          f"wire/dev={rec['collectives']['wire_bytes_per_device']:.3e}B")
    return rec


def _scan_len(cfg) -> int:
    if not cfg.scan_layers:
        return 1
    if cfg.family == "encdec":
        return cfg.n_dec_layers  # enc and dec scans have the same order
    from repro.models.transformer import _pattern
    return _pattern(cfg)[1]


def _group(colls):
    agg = {}
    for c in colls:
        k = c["op"]
        a = agg.setdefault(k, {"count": 0, "bytes": 0.0, "bytes_x_mult": 0.0})
        a["count"] += 1
        a["bytes"] += c["bytes"]
        a["bytes_x_mult"] += c["bytes"] * c["mult"]
    return agg


def _save(out_dir: Path, cell_id: str, rec: dict):
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{cell_id}.json").write_text(json.dumps(rec, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (e.g. kv_cache_dtype=int8)")
    args = ap.parse_args()
    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v
    try:
        rec = run_cell(args.arch, args.shape, args.multipod, Path(args.out),
                       overrides or None)
        sys.exit(0 if rec["status"] in ("ok", "skipped") else 1)
    except Exception:
        traceback.print_exc()
        cell_id = (f"{args.arch}__{args.shape}__"
                   f"{'2x16x16' if args.multipod else '16x16'}")
        _save(Path(args.out), cell_id,
              {"arch": args.arch, "shape": args.shape, "status": "error",
               "error": traceback.format_exc()[-2000:]})
        sys.exit(1)


if __name__ == "__main__":
    main()
