"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
device-sharded, no allocation.  The dry-run lowers against these.

``input_specs(cfg, shape, mesh, rules)`` returns (step_kind, kwargs) where
kwargs are the abstract arguments of the corresponding step function.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import named_sharding
from repro.models.registry import build_model


def _sds(shape, dtype, axes, mesh, rules):
    sh = named_sharding(axes, shape, rules, mesh)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def _abstract_tree(concrete_fn, axes_tree, mesh, rules):
    """eval_shape a cache-builder and attach shardings from an axes tree."""
    shapes = jax.eval_shape(concrete_fn)

    def attach(sds, axes):
        sh = named_sharding(axes, sds.shape, rules, mesh)
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh)

    return jax.tree.map(attach, shapes, axes_tree)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, mesh, rules) -> dict:
    """Training / prefill batch inputs."""
    B, S = shape.global_batch, shape.seq_len
    tok_axes = ("batch", "seq")
    out = {}
    if cfg.family == "encdec":
        out["frames"] = _sds((B, S, cfg.d_model), jnp.bfloat16,
                             ("batch", "seq", "embed"), mesh, rules)
        out["tokens"] = _sds((B, S), jnp.int32, tok_axes, mesh, rules)
        if shape.kind == "train":
            out["labels"] = _sds((B, S), jnp.int32, tok_axes, mesh, rules)
        return out
    n_txt = S - cfg.frontend_seq if cfg.frontend == "vision" else S
    out["tokens"] = _sds((B, n_txt), jnp.int32, tok_axes, mesh, rules)
    if cfg.frontend == "vision":
        out["extra_embeds"] = _sds((B, cfg.frontend_seq, cfg.d_model),
                                   jnp.bfloat16, ("batch", "seq", "embed"),
                                   mesh, rules)
    if shape.kind == "train":
        out["labels"] = _sds((B, n_txt), jnp.int32, tok_axes, mesh, rules)
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeSpec, mesh, rules) -> dict:
    """serve_step inputs: one new token + a KV cache of seq_len."""
    B, S = shape.global_batch, shape.seq_len
    model = build_model(cfg)
    out = {"tokens": _sds((B, 1), jnp.int32, ("batch", "seq"), mesh, rules)}
    if cfg.family == "encdec":
        from repro.models.encdec import encdec_cache_axes
        params_abs = model.abstract(jnp.bfloat16, mesh, rules)
        enc_abs = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        out["cache"] = _abstract_tree(
            lambda: model.init_dec_cache(
                jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params_abs),
                jnp.zeros((B, S, cfg.d_model), jnp.bfloat16),
                B, max_len=S, prefilled=S - 1),
            encdec_cache_axes(cfg), mesh, rules)
    else:
        from repro.models.transformer import init_decode_cache, decode_cache_axes
        out["cache"] = _abstract_tree(
            lambda: init_decode_cache(cfg, B, S, prefilled=S - 1),
            decode_cache_axes(cfg), mesh, rules)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh, rules):
    if shape.kind == "decode":
        return "decode", decode_specs(cfg, shape, mesh, rules)
    if shape.kind == "prefill":
        return "prefill", batch_specs(cfg, shape, mesh, rules)
    return "train", batch_specs(cfg, shape, mesh, rules)
