"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
        --smoke --steps 50 --batch 8 --seq 128 [--ckpt-dir ckpts/granite]

``--smoke`` selects the reduced config (CPU-runnable); the full configs are
for TPU fleets (the dry-run proves their distribution).  ``--fail-at N``
injects a failure to demonstrate checkpoint-restart.
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--fail-at", type=int, action="append", default=[])
    args = ap.parse_args()

    from repro.configs import get_config, smoke_config
    from repro.training.train_loop import TrainConfig, train

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tc = TrainConfig(steps=args.steps, global_batch=args.batch,
                     seq_len=args.seq, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every)
    _, history = train(cfg, tc, fail_at=set(args.fail_at))
    if history:
        print(f"final loss: {history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
