from repro.cluster.topology import Node, Topology, paper_topology
from repro.cluster.simulator import (ClusterSim, SimConfig, Task, PodState,
                                     AutoscalerBinding)
