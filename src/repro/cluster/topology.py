"""Cluster topology — nodes and zones per paper Table 2.

| Role    | Tier  | CPU/millicores | RAM/GB | Number |
|---------|-------|----------------|--------|--------|
| Control | Cloud | 4000           | 4      | 1      |
| Worker  | Cloud | 3000           | 3      | 2      |
| Worker  | Edge  | 2000           | 2      | 2/zone |

Two edge zones (paper Fig. 2/5).  The control node hosts the Prometheus
stack and the autoscalers (paper §3.2.3) and takes no worker pods.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Node:
    name: str
    zone: str           # 'cloud' | 'edge-0' | 'edge-1'
    cpu_m: int          # millicores
    ram_mb: int
    schedulable: bool = True
    failed: bool = False
    # straggler: multiplier < 1.0 slows every pod on the node
    speed_factor: float = 1.0

    def __post_init__(self):
        self.alloc_m = 0  # scheduled millicores

    @property
    def free_m(self) -> int:
        return 0 if self.failed else self.cpu_m - self.alloc_m


@dataclasses.dataclass
class Topology:
    nodes: list[Node]

    def zone_nodes(self, zone: str) -> list[Node]:
        return [n for n in self.nodes
                if n.zone == zone and n.schedulable and not n.failed]

    def zone_capacity_m(self, zone: str) -> int:
        return sum(n.cpu_m for n in self.zone_nodes(zone))

    def max_replicas(self, zone: str, pod_cpu_m: int) -> int:
        """'Calculate max_replicas limited by system resources' (Alg. 1)."""
        return sum(n.cpu_m // pod_cpu_m for n in self.zone_nodes(zone))


def paper_topology(n_edge_zones: int = 2) -> Topology:
    nodes = [Node("control", "control", 4000, 4096, schedulable=False)]
    nodes += [Node(f"cloud-{i}", "cloud", 3000, 3072) for i in range(2)]
    for z in range(n_edge_zones):
        nodes += [Node(f"edge{z}-{i}", f"edge-{z}", 2000, 2048)
                  for i in range(2)]
    return Topology(nodes)


def fleet_topology(pods_per_zone: int, zones: list[str] | None = None,
                   pods_per_node: int = 64, pod_cpu_m: int = 500) -> Topology:
    """Fleet-scale topology: enough homogeneous worker nodes per zone to
    host ``pods_per_zone`` pods of ``pod_cpu_m`` each (DESIGN.md §3,
    "Fleet scale" — the 10⁴–10⁵-pod bench substrate).  Node size is
    expressed in pods (64 x 500m = a 32-core worker)."""
    zones = zones or ["fleet-0"]
    node_cpu_m = pods_per_node * pod_cpu_m
    n_nodes = -(-pods_per_zone // pods_per_node)    # ceil
    nodes = []
    for z in zones:
        nodes += [Node(f"{z}-n{i}", z, node_cpu_m, node_cpu_m // 2)
                  for i in range(n_nodes)]
    return Topology(nodes)
