"""Discrete-event cluster simulator — the paper's Kubernetes testbed in-process.

Exact queueing model: every worker pod is a FIFO server with its own
``free_at`` horizon; a task arriving at ``t`` is dispatched to the
least-backlogged ready pod of its zone, starts at ``max(t, free_at)`` and
completes after its service time (no time-stepping — response times are
exact).  Pod startup latency is what makes *proactive* scaling matter: a
reactive scaler only reacts after queues build, and new capacity arrives
``startup_s`` later (paper §2.2).

Implements: scheduling with node capacity limits (Table 2), graceful drain on
scale-down, node failure + recovery with task re-dispatch, straggler nodes
(speed_factor), per-zone windowed metric exporters ([CPU, RAM, NetIn, NetOut,
RequestRate] — the Prometheus adapter of Fig. 3), and autoscaler bindings
driving either the PPA or the HPA baseline.

Since the sim-core refactor (DESIGN.md §3) this class is a thin domain
adapter over ``repro.sim.SimCore``: pod selection is heap-based (O(log P)
instead of the seed's O(P) scan, with identical tie-breaking), injected
events live on a heap, and the completion log is append-only.  Seeded runs
reproduce the seed engine's response-time distributions exactly
(tests/test_control_plane.py).

Fleet-scale batch mode (DESIGN.md §3, "Fleet scale"): passing a
``WindowedArrivals`` trace to ``run`` switches the sim onto the vectorised
substrate — per-zone ``ArrayServerPool``s drained one window chunk at a
time (``drain_window``), a structured-numpy ``CompletionLog`` instead of
per-task objects, and ``WindowAccumulator`` zone-level busy accounting
instead of per-pod dicts.  Pods are pure array rows (no ``PodState``
objects on the hot path — ``sim.pods`` materialises views on demand), and
scale-ups are ONE vectorised water-filling plan over the node free-CPU
array per decision (``waterfill_placement``, DESIGN.md §6) instead of a
per-pod argmax loop.  This scales runs to 10⁴–10⁵ pods
(benchmarks/bench_fleet_scale.py); for a *single-zone* trace with
homogeneous node speeds the batched drain produces the *identical*
completion sequence as per-event dispatch (tests/test_fleet_scale.py).
Known deviations: multi-zone traces consume the service-jitter stream one
zone chunk at a time instead of in global arrival order, so completions
are statistically identical but not bitwise vs. the per-event engine;
pod *attribution* of a task may differ when a busy pod frees mid-chunk
(starts/completions unchanged); and on the failure path, re-dispatch
order follows log order instead of pod order and a dead pod's
already-executed busy time stays in the zone-level metric (the per-event
path drops the pod's whole busy history).
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict

import numpy as np

from repro.cluster.topology import Node, Topology, paper_topology
from repro.core.metrics import Snapshot
from repro.sim import (ArrayServerPool, CompletionLog, SimCore,
                       WindowAccumulator, drain_window, waterfill_placement)
from repro.sim.core import grow_to
from repro.workloads.fleet_scale import WindowedArrivals


@dataclasses.dataclass
class Task:
    arrival: float
    kind: str              # 'sort' | 'eigen'
    zone: str              # serving zone ('cloud' for eigen)
    service_s: float
    start: float = math.nan
    completion: float = math.nan
    pod_id: int = -1
    redispatched: bool = False

    @property
    def response(self) -> float:
        return self.completion - self.arrival


@dataclasses.dataclass
class PodState:
    pid: int
    zone: str
    node: Node
    cpu_m: int
    created: float
    ready_at: float
    free_at: float = 0.0
    draining: bool = False
    dead: bool = False
    busy: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    queue: list = dataclasses.field(default_factory=list)  # inflight tasks

    def available(self, t: float) -> bool:
        return (not self.draining and not self.dead and t >= self.ready_at)


@dataclasses.dataclass
class SimConfig:
    pod_cpu_m: int = 500
    startup_s: float = 10.0
    control_interval_s: float = 15.0
    sort_service_s: float = 0.45
    eigen_service_s: float = 12.0
    service_jitter: float = 0.08           # lognormal sigma
    ram_per_pod_mb: float = 256.0
    straggler_redispatch_factor: float = 4.0   # deadline = factor * service
    seed: int = 0
    # batch-mode CompletionLog memory policy: streaming folds windows older
    # than ``log_retain_windows`` into per-window stats (10⁸-event runs stay
    # bounded); the full in-memory log is the default
    log_streaming: bool = False
    log_retain_windows: int = 8


@dataclasses.dataclass
class AutoscalerBinding:
    zone: str
    scaler: object          # PPA | HPA (duck-typed)
    kind: str               # 'ppa' | 'hpa'
    min_replicas: int = 1


class ClusterSim:
    def __init__(self, topo: Topology | None = None,
                 cfg: SimConfig | None = None):
        self.topo = topo or paper_topology()
        self.cfg = cfg or SimConfig()
        self.rng = np.random.default_rng(self.cfg.seed)
        self.core = SimCore(self.cfg.control_interval_s, two_phase=True,
                            ma_windows=4)
        self._next_pid = 0
        self.completed: list[Task] = []
        self.samples = self.core.exporter.samples
        self.replica_log: dict[str, list[tuple[float, int]]] = defaultdict(list)
        self.rir_log: dict[str, list[tuple[float, float]]] = defaultdict(list)
        # fleet-scale batch mode (activated by run(WindowedArrivals, ...))
        self._vec = False
        self.completed_log: CompletionLog | None = None

    # ------------------------------------------------------------ pods -----
    @property
    def pods(self) -> list[PodState]:
        """Every pod ever scheduled, in pid order.  Heap mode returns the
        live registry; batch mode materialises ``PodState`` *views* from
        the columnar slot arrays on demand (pods are pure array rows on
        the hot path — this accessor is for tests and diagnostics)."""
        if not self._vec:
            return self.core.servers
        for z in self._apools:
            self._sync_nodes(z)
        out = [self._make_pod(z, s) for z in self._apools
               for s in range(self._apools[z].n)]
        out.sort(key=lambda p: p.pid)
        return out

    def _sync_nodes(self, zone: str):
        """Materialise the zone's ``Node`` views from the columnar node
        arrays (batch mode keeps alloc in ``_znode_alloc`` on the hot
        path; the objects only matter to tests/diagnostics)."""
        for n, alloc in zip(self._znodes[zone], self._znode_alloc[zone]):
            n.alloc_m = int(alloc)

    def _make_pod(self, zone: str, slot: int) -> PodState:
        pool = self._apools[zone]
        ni = int(self._slot_node[zone][slot])
        return PodState(int(self._slot_pid[zone][slot]), zone,
                        self._znodes[zone][ni], self.cfg.pod_cpu_m,
                        created=float(self._slot_created[zone][slot]),
                        ready_at=float(pool.ready[slot]),
                        free_at=float(pool.key[slot]),
                        draining=bool(self._slot_draining[zone][slot]),
                        dead=bool(self._slot_dead[zone][slot]))

    def _schedule_pod(self, zone: str, t: float) -> PodState | None:
        """Bin-pack a worker pod onto the zone node with most free capacity."""
        nodes = self.topo.zone_nodes(zone)
        nodes = [n for n in nodes if n.free_m >= self.cfg.pod_cpu_m]
        if not nodes:
            return None
        node = max(nodes, key=lambda n: n.free_m)
        node.alloc_m += self.cfg.pod_cpu_m
        pod = PodState(self._next_pid, zone, node, self.cfg.pod_cpu_m,
                       created=t, ready_at=t + self.cfg.startup_s,
                       free_at=t + self.cfg.startup_s)
        self._next_pid += 1
        self.core.add_server(pod, zone, t, key=pod.free_at,
                             ready_at=pod.ready_at)
        return pod

    def _drain_pod(self, pod: PodState):
        pod.draining = True
        pod.node.alloc_m -= pod.cpu_m
        self.core.pool(pod.zone).invalidate(pod)

    def zone_pods(self, zone: str, t: float | None = None):
        if self._vec:
            pool = self._apools.get(zone)
            if pool is None:
                return []
            self._sync_nodes(zone)
            slots = pool.live_slots()
            if t is not None:
                slots = slots[pool.ready[slots] <= t]
            return [self._make_pod(zone, int(s)) for s in slots]
        ps = self.core.live(zone)
        if t is not None:
            ps = [p for p in ps if p.available(t)]
        return ps

    def _n_live(self, zone: str) -> int:
        """Live-pod count without materialising the pod list (the control
        loop calls this every tick; at 10⁵ pods a list build is O(P))."""
        if self._vec:
            pool = self._apools.get(zone)
            return pool.n_live if pool is not None else 0
        return len(self.zone_pods(zone))

    def scale_to(self, zone: str, n: int, t: float):
        if self._vec:
            return self._vec_scale_to(zone, n, t)
        cur = self.core.live(zone)
        if len(cur) < n:
            for _ in range(n - len(cur)):
                if self._schedule_pod(zone, t) is None:
                    break
        elif len(cur) > n:
            # remove the newest pods first (graceful drain)
            for pod in sorted(cur, key=lambda p: -p.created)[:len(cur) - n]:
                self._drain_pod(pod)

    def make_ready_now(self, zone: str | None = None, t: float = 0.0):
        """Mark current pods ready at ``t`` (pre-warmed initial capacity —
        the paper's runs start with warm pods, startup latency applies only
        to scale-ups)."""
        if self._vec:
            for z in ([zone] if zone is not None else list(self._apools)):
                pool = self._apools[z]
                pool.make_ready(pool.live_slots(), t)
            return
        pods = self.pods if zone is None else self.core.by_group[zone]
        for p in pods:
            if not p.dead and not p.draining:
                p.ready_at = p.free_at = t
                self.core.pool(p.zone).reset(p, t)

    # ------------------------------------------------------- dispatching ---
    def _service_time(self, kind: str, node: Node) -> float:
        base = (self.cfg.sort_service_s if kind == "sort"
                else self.cfg.eigen_service_s)
        jit = float(self.rng.lognormal(0.0, self.cfg.service_jitter))
        return base * jit / max(node.speed_factor, 1e-3)

    def dispatch(self, task: Task, t: float):
        pod = self.core.pool(task.zone).select(t)
        if pod is None:
            # zone cold: best effort — spin one up (Kubernetes would have
            # min_replicas >= 1, so this is a safety net)
            pod = self._schedule_pod(task.zone, t)
            if pod is None:
                task.completion = t + 60.0  # dropped/timeout sentinel
                self.core.log_completion(self.completed, task)
                return
        service = self._service_time(task.kind, pod.node)
        start = max(t, pod.free_at, pod.ready_at)
        task.start, task.service_s = start, service
        task.completion = start + service
        task.pod_id = pod.pid
        pod.free_at = task.completion
        self.core.account_busy(pod.busy, start, task.completion)
        pod.queue.append(task)
        self.core.pool(task.zone).update(pod, pod.free_at)
        self.core.log_completion(self.completed, task)
        self.core.exporter.count(task.zone)

    # ------------------------------------------------------ failures etc ---
    def inject_node_failure(self, t: float, node_name: str,
                            recover_after: float | None = None):
        self.core.events.push(t, "fail", node=node_name)
        if recover_after is not None:
            self.core.events.push(t + recover_after, "recover", node=node_name)

    def inject_straggler(self, t: float, node_name: str, factor: float,
                         duration: float):
        self.core.events.push(t, "slow", node=node_name, factor=factor)
        self.core.events.push(t + duration, "slow", node=node_name, factor=1.0)

    def _apply_events(self, t: float):
        if self._vec:
            return self._vec_apply_events(t)
        for _, kind, arg in self.core.events.pop_due(t):
            node = next(n for n in self.topo.nodes if n.name == arg["node"])
            if kind == "fail":
                node.failed = True
                # Mark every pod on the node dead *first*: the seed engine
                # re-dispatched each dead pod's tasks while sibling pods on
                # the same failed node were still schedulable, so orphans
                # could land on a pod about to die in the same event.  It
                # also zeroed node.alloc_m inside the per-pod loop and
                # mutated structures mid-iteration.
                victims = [p for p in self.pods if p.node is node
                           and not p.dead]
                orphans: list[Task] = []
                for p in victims:
                    p.dead = True
                    if not p.draining:
                        node.alloc_m -= p.cpu_m
                    self.core.pool(p.zone).invalidate(p)
                    orphans.extend(task for task in p.queue
                                   if task.completion > t
                                   and not task.redispatched)
                    p.queue.clear()
                for task in orphans:
                    task.redispatched = True
                    self.dispatch(task, t)
            elif kind == "recover":
                node.failed = False
            elif kind == "slow":
                node.speed_factor = arg["factor"]

    # --------------------------------------------------------- metrics -----
    def sample_zone(self, zone: str, t: float) -> Snapshot:
        """Window [t-w, t) exporter readout -> [CPU, RAM, NetIn, NetOut, rate]."""
        if self._vec:
            return self._vec_sample_zone(zone, t)
        w = self.cfg.control_interval_s
        exporter = self.core.exporter
        win = exporter.window_index(t)
        pods = [p for p in self.core.by_group[zone] if not p.dead]
        cpu_used_m = sum(p.busy.get(win, 0.0) / w * p.cpu_m for p in pods)
        # container RSS ~ worker-pool base + task working set (load-coupled,
        # so the forecaster's RAM feature is comparable between the static
        # pretraining collection and the autoscaled run)
        busy_avg = cpu_used_m / max(self.cfg.pod_cpu_m, 1)
        ram = self.cfg.ram_per_pod_mb * busy_avg
        n_req = exporter.take_count(zone)
        rate = n_req / w
        net_in, net_out = n_req * 2.0, n_req * 1.0     # KB, synthetic
        # RIR_t = CPU_idle / CPU_requested   (paper Eq. 4)
        requested = sum(p.cpu_m for p in pods if p.available(t))
        if requested > 0:
            rir = max(requested - cpu_used_m, 0.0) / requested
            self.rir_log[zone].append((t, rir))
        for p in pods:
            # bound per-pod inflight logs: finished tasks are only needed
            # until their window closes (failure re-dispatch looks at
            # unfinished tasks only)
            if p.queue:
                p.queue = [q for q in p.queue if q.completion > t]
        # Prometheus-faithful export: rate()/avg over a 1-minute window
        # (4 control windows), not the raw 15 s instantaneous value
        raw = np.array([cpu_used_m, ram, net_in, net_out, rate])
        ma = exporter.push(zone, t, raw)
        return Snapshot(t, ma)

    # ------------------------------------------------------------- run -----
    def run(self, tasks: list[tuple[float, str, str]],
            bindings, t_end: float, initial_replicas: int = 2):
        """tasks: sorted (arrival_t, kind, zone).  Runs arrivals + control
        ticks in time order; returns self for chaining.

        ``bindings`` is either a list of per-zone ``AutoscalerBinding`` (the
        paper's one-loop-per-target layout) or a batched ``FleetController``
        (core/controller.py) driving all its targets with a single forecast
        dispatch per tick.

        ``tasks`` may instead be a ``WindowedArrivals`` trace, which
        switches the whole run onto the fleet-scale vectorised path:
        completions land in ``self.completed_log`` (a structured-numpy
        ``CompletionLog``) rather than ``self.completed``."""
        if isinstance(tasks, WindowedArrivals):
            self._vec_init(tasks)
        if getattr(bindings, "is_batched", False):
            controller = bindings
            zone_min = {z: controller.min_replicas(z)
                        for z in controller.target_names}
            control_tick = self._batched_control(controller, zone_min)
        else:
            zone_min = {b.zone: b.min_replicas for b in bindings}
            control_tick = self._per_zone_control(bindings)
        for zone, min_rep in zone_min.items():
            self.scale_to(zone, max(initial_replicas, min_rep), 0.0)
            self.make_ready_now(zone)        # initial pods are ready at t=0
        if self._vec:
            return self._drive_vec(tasks, t_end, control_tick)
        return self._drive(tasks, t_end, control_tick)

    def _drive(self, tasks, t_end: float, control_tick):
        """Shared time-stepping skeleton: events, arrivals, one control
        callback per tick, trailing-arrival drain."""
        cfg = self.cfg
        ticks = np.arange(cfg.control_interval_s, t_end,
                          cfg.control_interval_s)
        ti = 0
        for tick in ticks:
            self._apply_events(tick)
            while ti < len(tasks) and tasks[ti][0] <= tick:
                at, kind, zone = tasks[ti]
                self.dispatch(Task(at, kind, zone, 0.0), at)
                ti += 1
            control_tick(tick)
        while ti < len(tasks) and tasks[ti][0] <= t_end:
            at, kind, zone = tasks[ti]
            self.dispatch(Task(at, kind, zone, 0.0), at)
            ti += 1
        return self

    def _per_zone_control(self, bindings):
        """The paper's layout: one scaler invocation per zone per tick."""
        def control_tick(tick: float):
            for b in bindings:
                snap = self.sample_zone(b.zone, tick)
                cur = self._n_live(b.zone)
                max_rep = self.topo.max_replicas(b.zone, self.cfg.pod_cpu_m)
                if b.kind == "ppa":
                    b.scaler.observe(snap)
                    res = b.scaler.control_step(tick, max_rep, cur)
                    desired = max(res.replicas, b.min_replicas)
                    b.scaler.maybe_update(tick)
                else:
                    recent = np.stack([v for _, v in
                                       self.samples[b.zone]][-4:])
                    desired = b.scaler.decide(tick, recent, max_rep, cur)
                self.scale_to(b.zone, desired, tick)
                self.replica_log[b.zone].append((tick, desired))
        return control_tick

    def _batched_control(self, controller, zone_min: dict):
        """Batched control plane: sample all zones, then one
        ``controller.control_step`` answers every target at once."""
        def control_tick(tick: float):
            cur, max_r = {}, {}
            for z in zone_min:
                controller.observe(z, self.sample_zone(z, tick))
                cur[z] = self._n_live(z)
                max_r[z] = self.topo.max_replicas(z, self.cfg.pod_cpu_m)
            results = controller.control_step(tick, max_r, cur)
            for z in zone_min:
                desired = max(results[z].replicas, zone_min[z])
                self.scale_to(z, desired, tick)
                self.replica_log[z].append((tick, desired))
            controller.maybe_update(tick)
        return control_tick

    # ===================================================================== #
    #  Fleet-scale vectorised path (DESIGN.md §3, "Fleet scale")            #
    # ===================================================================== #
    def _vec_init(self, arr: WindowedArrivals):
        if self.core.servers or self._next_pid:
            raise ValueError("batch mode must start from an empty sim")
        cfg = self.cfg
        if abs(arr.window_s - cfg.control_interval_s) > 1e-9:
            raise ValueError("WindowedArrivals.window_s must equal "
                             "control_interval_s")
        self._vec = True
        self._kind_names = arr.kind_names
        # same rule as _service_time: 'sort' gets sort_service_s, any
        # other kind gets eigen_service_s
        self._kind_base = np.array([cfg.sort_service_s if k == "sort"
                                    else cfg.eigen_service_s
                                    for k in arr.kind_names])
        self.completed_log = CompletionLog(
            streaming=cfg.log_streaming,
            retain_windows=cfg.log_retain_windows)
        self._apools: dict[str, ArrayServerPool] = {}
        # pods are pure array rows in batch mode: per-slot metadata lives
        # in flat per-zone arrays (no PodState objects on the hot path)
        self._slot_speed: dict[str, np.ndarray] = {}
        self._slot_created: dict[str, np.ndarray] = {}
        self._slot_node: dict[str, np.ndarray] = {}
        self._slot_pid: dict[str, np.ndarray] = {}
        self._slot_dead: dict[str, np.ndarray] = {}
        self._slot_draining: dict[str, np.ndarray] = {}
        self._znodes: dict[str, list[Node]] = {}
        self._znode_free: dict[str, np.ndarray] = {}
        self._znode_speed: dict[str, np.ndarray] = {}
        # node state is fully columnar in batch mode (like pods): alloc /
        # capacity / failed live in flat arrays, and the ``Node`` objects
        # are materialised lazily (``_sync_nodes``) for tests/diagnostics
        self._znode_alloc: dict[str, np.ndarray] = {}
        self._znode_cap: dict[str, np.ndarray] = {}
        self._znode_failed: dict[str, np.ndarray] = {}
        self._zone_busy: dict[str, WindowAccumulator] = {}
        self._zone_code: dict[str, int] = {}

    def _vec_zone(self, zone: str) -> ArrayServerPool:
        if zone not in self._apools:
            self._apools[zone] = ArrayServerPool()
            self._slot_speed[zone] = np.ones(64)
            self._slot_created[zone] = np.zeros(64)
            self._slot_node[zone] = np.zeros(64, np.int64)
            self._slot_pid[zone] = np.full(64, -1, np.int64)
            self._slot_dead[zone] = np.zeros(64, np.bool_)
            self._slot_draining[zone] = np.zeros(64, np.bool_)
            self._znodes[zone] = list(self.topo.zone_nodes(zone))
            self._znode_free[zone] = np.array(
                [float(n.free_m) for n in self._znodes[zone]])
            self._znode_speed[zone] = np.array(
                [float(n.speed_factor) for n in self._znodes[zone]])
            self._znode_alloc[zone] = np.array(
                [float(n.alloc_m) for n in self._znodes[zone]])
            self._znode_cap[zone] = np.array(
                [float(n.cpu_m) for n in self._znodes[zone]])
            self._znode_failed[zone] = np.array(
                [bool(n.failed) for n in self._znodes[zone]])
            self._zone_busy[zone] = WindowAccumulator(
                self.cfg.control_interval_s)
            self._zone_code.setdefault(zone, len(self._zone_code))
        return self._apools[zone]

    def _vec_append_slots(self, zone: str, slots: np.ndarray,
                          node_seq: np.ndarray, pids: np.ndarray, t: float):
        """Bulk slot-metadata append: one array write per column for a
        whole placement batch."""
        need = int(slots[-1]) + 1
        for name in ("_slot_speed", "_slot_created", "_slot_node",
                     "_slot_pid", "_slot_dead", "_slot_draining"):
            arrs = getattr(self, name)
            arrs[zone] = grow_to(arrs[zone], need)
        self._slot_speed[zone][slots] = self._znode_speed[zone][node_seq]
        self._slot_created[zone][slots] = t
        self._slot_node[zone][slots] = node_seq
        self._slot_pid[zone][slots] = pids
        self._slot_dead[zone][slots] = False
        self._slot_draining[zone][slots] = False

    def _vec_schedule_pod(self, zone: str, t: float) -> int | None:
        """Single-pod array-mode scheduling (the cold-zone / re-dispatch
        safety net): argmax over the zone's node free-CPU array — the same
        first-max choice as the seed's ``max(free_m)`` scan.  Bulk
        scale-ups never loop this; they go through ``_vec_scale_up``."""
        self._vec_zone(zone)
        free = self._znode_free[zone]
        if free.size == 0:
            return None
        ni = int(np.argmax(free))
        if free[ni] < self.cfg.pod_cpu_m:
            return None
        self._znode_alloc[zone][ni] += self.cfg.pod_cpu_m
        free[ni] -= self.cfg.pod_cpu_m
        return int(self._vec_register(zone, np.array([ni]), t)[0])

    def _vec_register(self, zone: str, node_seq: np.ndarray, t: float
                      ) -> np.ndarray:
        """Register placements (node bookkeeping already done): pool slots
        + metadata columns + pid allocation, all batched."""
        k = len(node_seq)
        pool = self._apools[zone]
        ready = t + self.cfg.startup_s
        slots = pool.add_batch(k, key=ready, ready_at=ready)
        pids = np.arange(self._next_pid, self._next_pid + k, dtype=np.int64)
        self._next_pid += k
        self._vec_append_slots(zone, slots, node_seq, pids, t)
        return slots

    def _vec_scale_up(self, zone: str, k: int, t: float) -> int:
        """Bulk build-out: ONE vectorised water-filling plan over the node
        free-CPU array per scale-up decision (placement parity with the
        sequential argmax loop is property-tested), then one batched pool
        / metadata append.  Returns the number of pods actually placed
        (capacity may run out)."""
        self._vec_zone(zone)
        free = self._znode_free[zone]
        seq, counts = waterfill_placement(free, self.cfg.pod_cpu_m, k)
        if not len(seq):
            return 0
        # node state stays columnar: one array op, no loop over touched
        # nodes (Node objects materialise lazily via _sync_nodes)
        free -= counts * float(self.cfg.pod_cpu_m)
        self._znode_alloc[zone] += counts * float(self.cfg.pod_cpu_m)
        self._vec_register(zone, seq, t)
        return len(seq)

    def _vec_drain_slots(self, zone: str, slots: np.ndarray):
        """Graceful drain of a slot batch: one metadata write + one pool
        invalidate; node bookkeeping touches only affected nodes."""
        slots = np.atleast_1d(np.asarray(slots))
        self._slot_draining[zone][slots] = True
        counts = np.bincount(self._slot_node[zone][slots],
                             minlength=len(self._znodes[zone]))
        alloc = self._znode_alloc[zone]
        alloc -= counts * float(self.cfg.pod_cpu_m)
        # failed nodes stay at free=0; everyone else re-derives from the
        # columnar invariant free = cap - alloc (one vectorised op)
        ok = ~self._znode_failed[zone]
        self._znode_free[zone][ok] = self._znode_cap[zone][ok] - alloc[ok]
        self._apools[zone].invalidate(slots)

    def _vec_scale_to(self, zone: str, n: int, t: float):
        pool = self._vec_zone(zone)
        cur = pool.n_live
        if cur < n:
            self._vec_scale_up(zone, n - cur, t)
        elif cur > n:
            # newest-created first, creation order within equal created —
            # the same choice as the heap path's stable sort on -created
            slots = pool.live_slots()
            order = np.argsort(-self._slot_created[zone][slots],
                               kind="stable")
            self._vec_drain_slots(zone, slots[order][:cur - n])

    # -------------------------------------------------- batched dispatch --
    def _vec_dispatch_window(self, zone: str, times: np.ndarray,
                             kinds: np.ndarray):
        """Drain one (window, zone) arrival chunk through the array pool:
        vectorised idle rounds, batch completion logging, batch busy
        accounting — the per-event Python loop amortised away."""
        pool = self._vec_zone(zone)
        cfg = self.cfg

        def service_fn(slots, i0, i1):
            jit = self.rng.lognormal(0.0, cfg.service_jitter, i1 - i0)
            speed = self._slot_speed[zone]      # re-read: on_cold may grow
            return (self._kind_base[kinds[i0:i1]] * jit
                    / np.maximum(speed[slots], 1e-3))

        def on_cold(t):
            s = self._vec_schedule_pod(zone, t)
            return -1 if s is None else s

        slots, starts, comps, svcs = drain_window(
            pool, times, service_fn, on_cold, cold_timeout_s=60.0)
        ok = slots >= 0
        self._zone_busy[zone].add_batch(starts[ok], comps[ok])
        pids = np.full(len(slots), -1, np.int64)
        pids[ok] = self._slot_pid[zone][slots[ok]]
        self.completed_log.append_batch(times, starts, comps, svcs, pids,
                                        kinds, self._zone_code[zone])
        self.core.exporter.count(zone, int(np.count_nonzero(ok)))

    def _drive_vec(self, arr: WindowedArrivals, t_end: float, control_tick):
        cfg = self.cfg
        ticks = np.arange(cfg.control_interval_s, t_end,
                          cfg.control_interval_s)
        for j, tick in enumerate(ticks):
            self._apply_events(float(tick))
            for zone, times, kinds in arr.window_chunks(j + 1):
                self._vec_dispatch_window(zone, times, kinds)
            self.completed_log.seal_window()
            control_tick(float(tick))
        # exclusive lower bound: with no ticks at all, drain from t=0 too
        t_last = float(ticks[-1]) if len(ticks) else -1.0
        for zone, times, kinds in arr.tail_chunks(t_last, t_end):
            self._vec_dispatch_window(zone, times, kinds)
        self.completed_log.seal_window()
        return self

    # ------------------------------------------------- failures, metrics --
    def _vec_redispatch(self, rows: np.ndarray, t: float):
        """Re-dispatch orphaned completion-log rows in place."""
        log = self.completed_log
        zone_of = {c: z for z, c in self._zone_code.items()}
        for r in rows:
            zone = zone_of[int(log.view()["group"][r])]
            pool = self._apools[zone]
            slot = pool.select(t)
            if slot < 0:
                s = self._vec_schedule_pod(zone, t)
                slot = -1 if s is None else s
            if slot < 0:
                log.amend(r, start=np.nan, completion=t + 60.0,
                          service=np.nan, server=-1, redispatched=True)
                continue
            start = max(t, float(pool.key[slot]), float(pool.ready[slot]))
            kind = int(log.view()["kind"][r])
            jit = float(self.rng.lognormal(0.0, self.cfg.service_jitter))
            speed = max(float(self._slot_speed[zone][slot]), 1e-3)
            service = float(self._kind_base[kind]) * jit / speed
            comp = start + service
            pool.key[slot] = comp
            self._zone_busy[zone].add(start, comp)
            log.amend(r, start=start, completion=comp, service=service,
                      server=int(self._slot_pid[zone][slot]),
                      redispatched=True)
            self.core.exporter.count(zone)

    def _vec_apply_events(self, t: float):
        for _, kind, arg in self.core.events.pop_due(t):
            node = next(n for n in self.topo.nodes if n.name == arg["node"])
            zone = node.zone
            known = zone in self._znodes and node in self._znodes[zone]
            if kind == "fail":
                node.failed = True
                if not known:
                    continue
                ni = self._znodes[zone].index(node)
                self._znode_failed[zone][ni] = True
                self._znode_free[zone][ni] = 0.0
                pool = self._apools[zone]
                dead = self._slot_dead[zone]
                on_node = self._slot_node[zone][:pool.n] == ni
                victims = np.flatnonzero(on_node & ~dead[:pool.n])
                dead[victims] = True
                self._znode_alloc[zone][ni] -= self.cfg.pod_cpu_m * int(
                    np.count_nonzero(~self._slot_draining[zone][victims]))
                if victims.size:
                    pool.invalidate(victims)
                    vpids = self._slot_pid[zone][victims]
                    rows = self.completed_log.view()
                    orphan = np.flatnonzero(
                        np.isin(rows["server"], vpids)
                        & (rows["completion"] > t) & ~rows["redispatched"])
                    if orphan.size:
                        # cancel the un-executed remainder of each orphan's
                        # old interval, then re-dispatch in log order
                        st = np.maximum(rows["start"][orphan], t)
                        self._zone_busy[zone].add_batch(
                            st, rows["completion"][orphan], sign=-1.0)
                        self._vec_redispatch(orphan, t)
            elif kind == "recover":
                node.failed = False
                if known:
                    ni = self._znodes[zone].index(node)
                    self._znode_failed[zone][ni] = False
                    self._znode_free[zone][ni] = (
                        self._znode_cap[zone][ni]
                        - self._znode_alloc[zone][ni])
            elif kind == "slow":
                node.speed_factor = arg["factor"]
                if known:
                    ni = self._znodes[zone].index(node)
                    self._znode_speed[zone][ni] = arg["factor"]
                    pool = self._apools[zone]
                    on_node = self._slot_node[zone][:pool.n] == ni
                    self._slot_speed[zone][:pool.n][on_node] = arg["factor"]

    def _vec_sample_zone(self, zone: str, t: float) -> Snapshot:
        cfg = self.cfg
        w = cfg.control_interval_s
        exporter = self.core.exporter
        win = exporter.window_index(t)
        pool = self._vec_zone(zone)
        busy_s = self._zone_busy[zone].get(win)
        cpu_used_m = busy_s / w * cfg.pod_cpu_m
        busy_avg = cpu_used_m / max(cfg.pod_cpu_m, 1)
        ram = cfg.ram_per_pod_mb * busy_avg
        n_req = exporter.take_count(zone)
        rate = n_req / w
        net_in, net_out = n_req * 2.0, n_req * 1.0
        requested = cfg.pod_cpu_m * pool.ready_live_count(t)
        if requested > 0:
            rir = max(requested - cpu_used_m, 0.0) / requested
            self.rir_log[zone].append((t, rir))
        raw = np.array([cpu_used_m, ram, net_in, net_out, rate])
        return Snapshot(t, exporter.push(zone, t, raw))

    # ------------------------------------------------------------ stats ----
    def response_times(self, kind: str | None = None) -> np.ndarray:
        if self._vec:
            if kind is not None and kind not in self._kind_names:
                return np.zeros(0)           # same as the per-event path
            kc = None if kind is None else self._kind_names.index(kind)
            return np.asarray(self.completed_log.response_times(kc))
        ts = [t.response for t in self.completed
              if (kind is None or t.kind == kind) and math.isfinite(t.completion)]
        return np.asarray(ts)

    def rir_stats(self, zones: list[str]) -> tuple[float, float]:
        vals = np.concatenate([[v for _, v in self.rir_log[z]]
                               for z in zones if self.rir_log[z]])
        return float(vals.mean()), float(vals.std())
