"""Mamba2 (SSD — state-space duality) block in pure JAX.

The sequence dimension is processed in chunks (cfg.ssm_chunk): the
intra-chunk term is a masked quadratic form computed on the MXU, the
inter-chunk recurrence is a ``lax.scan`` over per-chunk states — exactly the
structure the Pallas kernel (repro.kernels.ssd_scan) implements on TPU with
the state carried in VMEM scratch across sequential grid steps.

Head layout: x (B, S, H, P), shared B/C projections (n_groups = 1):
    h_t = exp(Δ_t A) h_{t-1} + Δ_t x_t ⊗ B_t        (state h: (P, N))
    y_t = h_t C_t + D x_t
Sharding: H over 'model'; B/C (N) replicated; no collectives inside the scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import Spec


# ----------------------------------------------------------------- specs ---
def mamba_specs(cfg) -> dict:
    d, di, H, P, N = cfg.d_model, cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    cw = cfg.ssm_conv
    return {
        "w_z": Spec((d, di), ("fsdp", "mlp")),
        "w_x": Spec((d, di), ("fsdp", "mlp")),
        "w_B": Spec((d, N), ("fsdp", None)),
        "w_C": Spec((d, N), ("fsdp", None)),
        "w_dt": Spec((d, H), ("fsdp", "heads")),
        "dt_bias": Spec((H,), ("heads",), init="zeros"),
        "A_log": Spec((H,), ("heads",), init="zeros"),
        "D": Spec((H,), ("heads",), init="ones"),
        "conv_x": Spec((cw, di), (None, "mlp"), scale=0.5),
        "conv_B": Spec((cw, N), (None, None), scale=0.5),
        "conv_C": Spec((cw, N), (None, None), scale=0.5),
        "norm": Spec((di,), ("mlp",), init="ones"),
        "w_out": Spec((di, d), ("mlp", "fsdp")),
    }


# ------------------------------------------------------------ primitives ---
def causal_depthwise_conv(x: jax.Array, w: jax.Array,
                          state: jax.Array | None = None):
    """x (B, S, C), w (K, C) depthwise causal conv + silu.
    If state (B, K-1, C) is given (decode), prepend it; returns (y, new_state)."""
    B, S, C = x.shape
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    # sum_k w[k] * x[t - (K-1) + k]
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for k in range(K):
        y = y + xp[:, k:k + S].astype(jnp.float32) * w[k].astype(jnp.float32)
    new_state = xp[:, -(K - 1):] if K > 1 else xp[:, :0]
    return jax.nn.silu(y).astype(x.dtype), new_state


def ssd_chunked(x, dt, A, Bm, Cm, D, chunk: int, h0=None):
    """SSD forward.
    x  (B,S,H,P)  dt (B,S,H)  A (H,)<0  Bm/Cm (B,S,N)  D (H,)
    Returns y (B,S,H,P), final state (B,H,P,N)."""
    Bb, S, H, P = x.shape
    N = Bm.shape[-1]
    nc = S // chunk
    assert S % chunk == 0, (S, chunk)
    xc = x.reshape(Bb, nc, chunk, H, P)
    dtc = dt.reshape(Bb, nc, chunk, H)
    Bc = Bm.reshape(Bb, nc, chunk, N)
    Cc = Cm.reshape(Bb, nc, chunk, N)

    da = dtc * A                                     # log-decay (B,nc,L,H)
    cum = jnp.cumsum(da, axis=2)                     # within-chunk cumsum
    total = cum[:, :, -1]                            # (B,nc,H)

    # intra-chunk: M[t,s] = exp(cum[t]-cum[s]) * (C_t·B_s), causal
    CB = jnp.einsum("bcln,bcmn->bclm", Cc, Bc,
                    preferred_element_type=jnp.float32)     # (B,nc,L,L)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (B,nc,L,L,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    # exp() only on the masked region: unmasked seg > 0 overflows to inf and
    # poisons the BACKWARD pass (inf * 0 = nan in the where-gradient)
    decay = jnp.where(mask, jnp.exp(jnp.where(mask, seg, 0.0)), 0.0)
    M = CB[..., None] * decay                               # (B,nc,L,L,H)
    xdt = xc * dtc[..., None]                               # (B,nc,L,H,P)
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", M, xdt.astype(jnp.float32))

    # chunk states: sum_s exp(total - cum[s]) * dt_s x_s ⊗ B_s
    decay_end = jnp.exp(total[:, :, None] - cum)            # (B,nc,L,H)
    states = jnp.einsum("bclh,bclhp,bcln->bchpn",
                        decay_end, xdt.astype(jnp.float32), Bc)

    # inter-chunk recurrence over nc
    h_init = (jnp.zeros((Bb, H, P, N), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))

    def step(h, inp):
        st, tot = inp                                       # (B,H,P,N), (B,H)
        h_prev = h
        h = jnp.exp(tot)[:, :, None, None] * h + st
        return h, h_prev

    (h_final, h_prevs) = jax.lax.scan(
        step, h_init,
        (states.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)              # (B,nc,H,P,N)

    # inter-chunk contribution: C_t · (exp(cum[t]) * h_prev)
    y_inter = jnp.einsum("bcln,bchpn,bclh->bclhp",
                         Cc.astype(jnp.float32), h_prevs, jnp.exp(cum))
    y = y_intra + y_inter + D[None, None, :, None] * xc.astype(jnp.float32)
    return y.reshape(Bb, S, H, P).astype(x.dtype), h_final


def ssd_decode_step(x, dt, A, Bm, Cm, D, h):
    """Single-token state update.  x (B,H,P), dt (B,H), Bm/Cm (B,N),
    h (B,H,P,N) -> y (B,H,P), h_new."""
    da = jnp.exp(dt * A)                                    # (B,H)
    hx = jnp.einsum("bhp,bn->bhpn", (x * dt[..., None]).astype(jnp.float32),
                    Bm.astype(jnp.float32))
    h_new = da[:, :, None, None] * h + hx
    y = jnp.einsum("bhpn,bn->bhp", h_new, Cm.astype(jnp.float32))
    y = y + D[None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), h_new


# ----------------------------------------------------------- full block ----
def _proj_ssm_inputs(p, u, cfg):
    """Shared by prefill and decode: project and split."""
    z = u @ p["w_z"].astype(u.dtype)
    x = u @ p["w_x"].astype(u.dtype)
    Bm = u @ p["w_B"].astype(u.dtype)
    Cm = u @ p["w_C"].astype(u.dtype)
    dt = jax.nn.softplus(
        (u @ p["w_dt"].astype(u.dtype)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    return z, x, Bm, Cm, dt


def mamba_block(p, u, cfg, cache=None):
    """u (B,S,d).  cache None (train/prefill from scratch) or dict with
    'conv_x','conv_B','conv_C' (B,K-1,·) and 'state' (B,H,P,N) for chunked
    continuation; returns (out, new_cache)."""
    B, S, d = u.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, x, Bm, Cm, dt = _proj_ssm_inputs(p, u, cfg)
    c = cache or {}
    x, cs_x = causal_depthwise_conv(x, p["conv_x"], c.get("conv_x"))
    Bm, cs_B = causal_depthwise_conv(Bm, p["conv_B"], c.get("conv_B"))
    Cm, cs_C = causal_depthwise_conv(Cm, p["conv_C"], c.get("conv_C"))
    # pad S to a chunk multiple; dt=0 on the tail makes the padded steps an
    # exact identity on the state (decay exp(0·A)=1, contribution Δ·x=0)
    pad = (-S) % cfg.ssm_chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    xh = x.reshape(B, S + pad, H, P)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h = ssd_chunked(xh, dt, A, Bm, Cm,
                       p["D"].astype(jnp.float32), cfg.ssm_chunk,
                       h0=c.get("state"))
    y = y[:, :S].reshape(B, S, cfg.d_inner)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    from repro.models.layers import rmsnorm
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["w_out"].astype(u.dtype)
    new_cache = {"conv_x": cs_x, "conv_B": cs_B, "conv_C": cs_C, "state": h}
    return out, new_cache


def mamba_decode(p, u, cfg, cache):
    """u (B,1,d) single token; cache as above."""
    B, _, d = u.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, x, Bm, Cm, dt = _proj_ssm_inputs(p, u, cfg)
    x, cs_x = causal_depthwise_conv(x, p["conv_x"], cache["conv_x"])
    Bm, cs_B = causal_depthwise_conv(Bm, p["conv_B"], cache["conv_B"])
    Cm, cs_C = causal_depthwise_conv(Cm, p["conv_C"], cache["conv_C"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h = ssd_decode_step(x[:, 0].reshape(B, H, P), dt[:, 0], A,
                           Bm[:, 0], Cm[:, 0], p["D"].astype(jnp.float32),
                           cache["state"])
    y = y.reshape(B, 1, cfg.d_inner)
    from repro.models.layers import rmsnorm
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["w_out"].astype(u.dtype)
    return out, {"conv_x": cs_x, "conv_B": cs_B, "conv_C": cs_C, "state": h}


def init_ssm_cache(cfg, batch: int, dtype=jnp.bfloat16) -> dict:
    K = cfg.ssm_conv
    return {
        "conv_x": jnp.zeros((batch, K - 1, cfg.d_inner), dtype),
        "conv_B": jnp.zeros((batch, K - 1, cfg.ssm_state), dtype),
        "conv_C": jnp.zeros((batch, K - 1, cfg.ssm_state), dtype),
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                            cfg.ssm_state), jnp.float32),
    }
