"""Shared transformer building blocks: RMSNorm, RoPE, embeddings, gated MLP.

Conventions: activations in ``cfg.compute_dtype`` (bf16 on TPU), norm and
softmax statistics accumulated in f32.  Vocab embeddings are padded to a
multiple of VOCAB_PAD so the vocab dim shards over the 16-way model axis and
stays 128-lane aligned on the MXU (granite's 49 155 → 49 408 etc.).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.models.params import Spec

VOCAB_PAD = 2048  # lcm(model_axis=16, MXU lane=128)


def padded_vocab(vocab: int) -> int:
    return ((vocab + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD


# ---------------------------------------------------------------- norms ----
def rmsnorm_spec(d: int, name_axes=("embed",)) -> Spec:
    return Spec((d,), name_axes, init="ones")


def rmsnorm(w: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """bf16-native RMSNorm: only the variance reduction runs in f32.

    Casting the whole stream to f32 (the naive formulation) makes every
    residual cotangent an f32 buffer — at (B=16,S=4096,d=2560) that is
    671 MiB per co-live buffer in the backward pass and dominated the
    train-step HBM footprint (see EXPERIMENTS.md §Perf iteration 1)."""
    var = jnp.einsum("...d,...d->...", x, x,
                     preferred_element_type=jnp.float32) / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps)[..., None].astype(x.dtype)
    return x * inv * w.astype(x.dtype)


# ----------------------------------------------------------------- rope ----
@functools.partial(jax.jit, static_argnames=("dim", "theta"))
def _rope_freqs(positions: jax.Array, dim: int, theta: float):
    half = dim // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    D = x.shape[-1]
    cos, sin = _rope_freqs(positions, D, theta)          # (..., S, D/2)
    cos = cos[..., None, :]                               # (..., S, 1, D/2)
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------- grad barrier ------
@jax.custom_vjp
def grad_barrier(x):
    """Identity that forces the cotangent back to the primal dtype.

    f32-accumulating einsums (norm variance, attention scores) make their
    transposes produce f32 cotangents; once one f32 contribution joins the
    residual-stream gradient, the whole backward carry — and the remat-saved
    per-layer residual stack — is promoted to f32 (observed: a hoisted
    f32[L,B,S,d] convert of the full saved stack, 15 GiB at h2o/train_4k).
    Placing this barrier on the scan carry pins the stream cotangent to bf16.
    """
    return x


def _gb_fwd(x):
    return x, jnp.zeros((0,), x.dtype)   # dtype token (residual must be a jax type)


def _gb_bwd(token, g):
    return (g.astype(token.dtype),)


grad_barrier.defvjp(_gb_fwd, _gb_bwd)


# ------------------------------------------------------------- softcap -----
def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ----------------------------------------------------------- embedding -----
def embed_specs(vocab: int, d: int) -> dict:
    pv = padded_vocab(vocab)
    return {"embedding": Spec((pv, d), ("vocab", "fsdp"), init="embed", scale=1.0)}


def embed_lookup(emb: jax.Array, tokens: jax.Array, compute_dtype) -> jax.Array:
    # one-hot-free gather; tokens guaranteed < true vocab <= padded rows
    return jnp.take(emb, tokens, axis=0).astype(compute_dtype)


def unembed_logits(emb_or_w: jax.Array, x: jax.Array, true_vocab: int,
                   final_cap: float | None = None) -> jax.Array:
    """x: (..., d) -> logits (..., padded_vocab) with pad positions masked."""
    logits = jnp.einsum("...d,vd->...v", x, emb_or_w.astype(x.dtype))
    logits = softcap(logits, final_cap)
    pv = emb_or_w.shape[0]
    if pv != true_vocab:
        mask = jnp.arange(pv) < true_vocab
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    return logits


# ----------------------------------------------------------------- mlp -----
def mlp_specs(d: int, d_ff: int) -> dict:
    return {
        "w_gate": Spec((d, d_ff), ("fsdp", "mlp")),
        "w_up": Spec((d, d_ff), ("fsdp", "mlp")),
        "w_down": Spec((d_ff, d), ("mlp", "fsdp")),
    }


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def mlp(p: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    h = _act(act)(x @ p["w_gate"].astype(x.dtype)) * (x @ p["w_up"].astype(x.dtype))
    return h @ p["w_down"].astype(x.dtype)


# ---------------------------------------------------------------- loss -----
def cross_entropy(logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None):
    """logits (..., V) CE; labels int32; optional 0/1 mask.

    Shard-friendly: the gold logit is extracted with an iota==label product
    (stays partitioned on a vocab-sharded axis; ``take_along_axis`` would
    force an all-gather of the full logits), and logsumexp is the shifted
    stable form whose reductions partial-reduce per shard."""
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    shifted = logits - m
    logz = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    onehot = labels[..., None] == jnp.arange(logits.shape[-1])
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
