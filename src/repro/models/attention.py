"""Attention: GQA with causal / sliding-window / soft-cap variants.

Three implementations share one math definition (``ref`` semantics):

* ``naive``   — full score matrix; smoke tests and small shapes.
* ``blocked`` — memory-proper online-softmax attention in pure JAX
                (lax.scan over q-blocks × kv-blocks).  This is the XLA path
                the dry-run compiles at 32k/500k sequence lengths.  For
                sliding-window attention the inner loop runs only over the
                O(window) kv-blocks selected with a dynamic slice, so SWA is
                genuinely sub-quadratic, not masked-quadratic.
* ``pallas``  — the TPU flash kernel in repro.kernels (selected by ops.py).

Shapes: q (B, Sq, Hq, D); k, v (B, Skv, Hkv, D); Hq % Hkv == 0 (GQA).
Positions are absolute: q_offset is the position of q[:, 0]; kv positions are
``arange(Skv)``; entries with k_pos >= kv_valid are masked (cache padding).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(q_pos, k_pos, *, causal, window, kv_valid):
    """q_pos (bq,), k_pos (bkv,) -> bool (bq, bkv)."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    if kv_valid is not None:
        m &= k_pos[None, :] < kv_valid
    return m


def _scores(qblk, kblk, scale, cap):
    # qblk (B, bq, Hkv, G, D), kblk (B, bkv, Hkv, D) -> (B, Hkv, G, bq, bkv) f32
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk,
                   preferred_element_type=jnp.float32) * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    return s


def naive_attention(q, k, v, *, causal=True, window=None, cap=None,
                    q_offset=0, kv_valid=None, scale=None):
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = _scores(qg, k, scale, cap)                       # (B,Hkv,G,Sq,Skv)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    m = _mask(q_pos, k_pos, causal=causal, window=window, kv_valid=kv_valid)
    s = jnp.where(m[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return o.reshape(B, Sq, Hq, D)


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def blocked_attention(q, k, v, *, causal=True, window=None, cap=None,
                      q_offset=0, kv_valid=None, block_q=512, block_kv=1024,
                      scale=None):
    B, Sq0, Hq, D = q.shape
    _, Skv0, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    block_q = min(block_q, Sq0) if Sq0 >= 16 else Sq0
    block_kv = min(block_kv, Skv0) if Skv0 >= 16 else Skv0

    q, Sq = _pad_to(q, 1, block_q)
    k, Skv = _pad_to(k, 1, block_kv)
    v, _ = _pad_to(v, 1, block_kv)
    kv_valid_eff = Skv if kv_valid is None else kv_valid

    nq = q.shape[1] // block_q
    nk = k.shape[1] // block_kv
    qb = q.reshape(B, nq, block_q, Hkv, G, D).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(B, nk, block_kv, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, block_kv, Hkv, D).transpose(1, 0, 2, 3, 4)

    if window is not None:
        # only the kv-blocks overlapping [q_start - window + 1, q_end] matter
        nw = min(nk, (window + block_q - 1) // block_kv + 2)
    else:
        nw = nk

    @jax.checkpoint
    def q_step(_, inp):
        i, qblk = inp                                    # qblk (B,bq,Hkv,G,D)
        q_start = q_offset + i * block_q
        if window is not None and nw < nk:
            first = jnp.clip((q_start - (window - 1)) // block_kv, 0, nk - nw)
        else:
            first = jnp.int32(0)
        kwin = jax.lax.dynamic_slice_in_dim(kb, first, nw, axis=0)
        vwin = jax.lax.dynamic_slice_in_dim(vb, first, nw, axis=0)
        q_pos = q_start + jnp.arange(block_q)

        m0 = jnp.full((B, Hkv, G, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, block_q, D), jnp.float32)

        def kv_step(carry, kv):
            mprev, lse, acc = carry
            j, kblk, vblk = kv
            k_pos = (first + j) * block_kv + jnp.arange(block_kv)
            s = _scores(qblk, kblk, scale, cap)          # (B,Hkv,G,bq,bkv)
            msk = _mask(q_pos, k_pos, causal=causal, window=window,
                        kv_valid=kv_valid_eff)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            mnew = jnp.maximum(mprev, s.max(-1))
            p = jnp.exp(s - mnew[..., None])
            alpha = jnp.exp(mprev - mnew)
            lse = lse * alpha + p.sum(-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
                            preferred_element_type=jnp.float32)
            acc = acc * alpha[..., None] + pv
            return (mnew, lse, acc), None

        (mf, lf, af), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nw), kwin, vwin))
        out = af / jnp.maximum(lf, 1e-30)[..., None]     # (B,Hkv,G,bq,D)
        out = out.transpose(0, 3, 1, 2, 4)               # (B,bq,Hkv,G,D)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    o = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * block_q, Hq, D)
    return o[:, :Sq0]


def decode_attention(q, k_cache, v_cache, *, kv_valid, window=None, cap=None,
                     scale=None):
    """Single/few-token decode against a cache.  q (B, T, Hq, D) with T small;
    kv_valid (B,) or scalar = number of valid cache entries; queries are the
    last T positions (q_pos = kv_valid - T + t)."""
    B, T, Hq, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, T, Hkv, G, D)
    s = _scores(qg, k_cache, scale, cap)                 # (B,Hkv,G,T,S)
    kv_valid = jnp.asarray(kv_valid)
    kv_valid_b = jnp.broadcast_to(kv_valid, (B,))
    q_pos = kv_valid_b[:, None] - T + jnp.arange(T)[None, :]   # (B,T)
    k_pos = jnp.arange(S)
    m = k_pos[None, None, :] <= q_pos[:, :, None]              # causal (B,T,S)
    if window is not None:
        m &= (q_pos[:, :, None] - k_pos[None, None, :]) < window
    s = jnp.where(m[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgts,bshd->bthgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, T, Hq, D)


def attention(q, k, v, *, impl="blocked", **kw):
    if impl == "naive":
        kw.pop("block_q", None), kw.pop("block_kv", None)
        return naive_attention(q, k, v, **kw)
    if impl == "blocked":
        return blocked_attention(q, k, v, **kw)
    if impl == "pallas":
        from repro.kernels import ops
        return ops.flash_attention(q, k, v, **kw)
    raise ValueError(f"unknown attention impl {impl!r}")
