"""Encoder-decoder LM (seamless-m4t backbone).

Encoder consumes precomputed frame embeddings (the audio frontend is a STUB
per the assignment — ``input_specs()`` supplies (B, S_src, d_model) arrays).
Decoder = causal self-attn + cross-attn + MLP.  Both stacks scan over layers.

Decode caches: per-layer self KV cache (append) + cross KV computed once from
the encoder output at prefill time.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.attention import attention
from repro.models.params import Spec, init_params, abstract_params
from repro.models.transformer import (
    attn_specs, mlp_specs_full, attn_sublayer, mlp_sublayer)


def cross_attn_specs(cfg: ModelConfig) -> dict:
    d, Hq, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "ln": Spec((d,), ("norm",), init="ones"),
        "w_q": Spec((d, Hq, Dh), ("fsdp", "heads", None)),
        "w_k": Spec((d, Hkv, Dh), ("fsdp", "kv_heads", None)),
        "w_v": Spec((d, Hkv, Dh), ("fsdp", "kv_heads", None)),
        "w_o": Spec((Hq, Dh, d), ("heads", None, "fsdp")),
    }


def _stack(specs, n: int):
    def one(s: Spec) -> Spec:
        return Spec((n,) + s.shape, ("layers",) + s.axes, init=s.init,
                    scale=s.scale, dtype=s.dtype)
    return jax.tree.map(one, specs, is_leaf=lambda x: isinstance(x, Spec))


def encdec_specs(cfg: ModelConfig) -> dict:
    enc_layer = {"attn": attn_specs(cfg), "mlp": mlp_specs_full(cfg)}
    dec_layer = {"attn": attn_specs(cfg), "cross": cross_attn_specs(cfg),
                 "mlp": mlp_specs_full(cfg)}
    return {
        "embed": L.embed_specs(cfg.vocab, cfg.d_model),
        "enc_blocks": _stack(enc_layer, cfg.n_enc_layers),
        "dec_blocks": _stack(dec_layer, cfg.n_dec_layers),
        "enc_norm": Spec((cfg.d_model,), ("norm",), init="ones"),
        "final_norm": Spec((cfg.d_model,), ("norm",), init="ones"),
        "lm_head": Spec((L.padded_vocab(cfg.vocab), cfg.d_model),
                        ("vocab", "fsdp")),
    }


def _cross_kv(p, enc_out, cfg):
    dt = enc_out.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["w_k"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["w_v"].astype(dt))
    if cfg.kv_repeat > 1:
        k = jnp.repeat(k, cfg.kv_repeat, axis=2)
        v = jnp.repeat(v, cfg.kv_repeat, axis=2)
    return k, v


def cross_sublayer(p, x, cfg, *, enc_out=None, kv=None, mesh=None, rules=None):
    """Cross attention; kv precomputed (decode) or derived from enc_out."""
    dt = x.dtype
    xn = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", xn, p["w_q"].astype(dt))
    if kv is None:
        k, v = _cross_kv(p, enc_out, cfg)
    else:
        k, v = kv
    o = attention(q, k, v, impl=cfg.attn_impl, causal=False, window=None,
                  cap=None, block_q=cfg.attn_block_q,
                  block_kv=cfg.attn_block_kv)
    o = jnp.einsum("bshk,hkd->bsd", o, p["w_o"].astype(dt))
    return x + o


def encdec_cache_axes(cfg: ModelConfig) -> dict:
    self_ax = {"k": ("layers", "batch", "kv_seq", "act_kv_heads", None),
               "v": ("layers", "batch", "kv_seq", "act_kv_heads", None),
               "len": ("layers", "batch")}
    if cfg.kv_cache_dtype == "int8":
        self_ax["k_scale"] = ("layers", "batch", "kv_seq", "act_kv_heads", None)
        self_ax["v_scale"] = ("layers", "batch", "kv_seq", "act_kv_heads", None)
    return {"cross_k": ("layers", "batch", None, "act_kv_heads", None),
            "cross_v": ("layers", "batch", None, "act_kv_heads", None),
            "self": self_ax}


@dataclasses.dataclass
class EncDecLM:
    cfg: ModelConfig

    def specs(self):
        return encdec_specs(self.cfg)

    def init(self, key, dtype=jnp.float32):
        return init_params(self.specs(), key, dtype)

    def abstract(self, dtype=jnp.bfloat16, mesh=None, rules=None):
        return abstract_params(self.specs(), dtype, mesh, rules)

    # ---------------------------------------------------------- encoder ----
    def encode(self, params, frames, *, mesh=None, rules=None):
        cfg = self.cfg
        x = frames.astype(jnp.dtype(cfg.compute_dtype))

        def body(x, p):
            x, _ = attn_sublayer(p["attn"], x, cfg, window=None, causal=False,
                                 mesh=mesh, rules=rules)
            x = mlp_sublayer(p["mlp"], x, cfg, mesh=mesh, rules=rules)
            return x, None

        if cfg.remat != "none":
            body = jax.checkpoint(body)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        else:
            for i in range(cfg.n_enc_layers):
                p = jax.tree.map(lambda a: a[i], params["enc_blocks"])
                x, _ = body(x, p)
        return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)

    # ---------------------------------------------------------- decoder ----
    def _dec_body(self, mode, enc_out, mesh, rules):
        cfg = self.cfg

        def body(carry, inp):
            x = carry
            p, cache = inp
            csl = None if cache is None else cache.get("self")
            x, nc = attn_sublayer(p["attn"], x, cfg, window=None,
                                  cache=csl if mode == "decode" else None,
                                  mode=mode, mesh=mesh, rules=rules)
            kv = None
            if mode == "decode":
                kv = (cache["cross_k"], cache["cross_v"])
            x = cross_sublayer(p["cross"], x, cfg, enc_out=enc_out, kv=kv,
                               mesh=mesh, rules=rules)
            x = mlp_sublayer(p["mlp"], x, cfg, mesh=mesh, rules=rules)
            out_cache = None
            if mode == "decode":
                out_cache = dict(cache)
                out_cache["self"] = nc
            elif mode == "prefill":
                out_cache = {"self": nc}
            return x, out_cache

        return body

    def loss(self, params, batch, *, mesh=None, rules=None):
        """batch: frames (B,Ss,d), tokens (B,St), labels (B,St)."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"], mesh=mesh, rules=rules)
        x = L.embed_lookup(params["embed"]["embedding"], batch["tokens"],
                           jnp.dtype(cfg.compute_dtype))
        body = self._dec_body("train", enc_out, mesh, rules)
        if cfg.remat != "none":
            body = jax.checkpoint(body)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(lambda c, p: body(c, (p, None)), x,
                                params["dec_blocks"])
        else:
            for i in range(cfg.n_dec_layers):
                p = jax.tree.map(lambda a: a[i], params["dec_blocks"])
                x, _ = body(x, (p, None))
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = L.unembed_logits(params["lm_head"], x, cfg.vocab, None)
        ce = L.cross_entropy(logits, batch["labels"], batch.get("mask"))
        return ce, {"ce": ce, "aux": jnp.float32(0)}

    # ------------------------------------------------------------ decode ---
    def init_dec_cache(self, params, enc_out, batch, max_len, prefilled=0):
        cfg = self.cfg
        Hkv = cfg.n_kv_heads * cfg.kv_repeat
        kvdt = jnp.int8 if cfg.kv_cache_dtype == "int8" else jnp.bfloat16

        def per_layer(p):
            ck, cv = _cross_kv(p["cross"], enc_out, cfg)
            c = {"cross_k": ck, "cross_v": cv,
                 "self": {"k": jnp.zeros((batch, max_len, Hkv, cfg.head_dim), kvdt),
                          "v": jnp.zeros((batch, max_len, Hkv, cfg.head_dim), kvdt),
                          "len": jnp.full((batch,), prefilled, jnp.int32)}}
            if cfg.kv_cache_dtype == "int8":
                c["self"]["k_scale"] = jnp.zeros((batch, max_len, Hkv, 1), jnp.float32)
                c["self"]["v_scale"] = jnp.zeros((batch, max_len, Hkv, 1), jnp.float32)
            return c

        # build per-layer cross KV by scanning the stacked cross params
        def mk(carry, p):
            return carry, per_layer(p)

        _, cache = jax.lax.scan(mk, None, params["dec_blocks"])
        return cache

    def decode_step(self, params, cache, tokens, *, mesh=None, rules=None):
        cfg = self.cfg
        x = L.embed_lookup(params["embed"]["embedding"], tokens,
                           jnp.dtype(cfg.compute_dtype))
        body = self._dec_body("decode", None, mesh, rules)
        if cfg.scan_layers:
            x, new_cache = jax.lax.scan(body, x, (params["dec_blocks"], cache))
        else:
            ncs = []
            for i in range(cfg.n_dec_layers):
                p = jax.tree.map(lambda a: a[i], params["dec_blocks"])
                csl = jax.tree.map(lambda a: a[i], cache)
                x, nc = body(x, (p, csl))
                ncs.append(nc)
            new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = L.unembed_logits(params["lm_head"], x, cfg.vocab, None)
        return logits, new_cache
