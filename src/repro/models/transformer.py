"""Decoder-only LM covering the dense / moe / ssm / hybrid families.

Depth is organised as ``n_steps`` repetitions of a per-arch *pattern*:

    dense, moe      : ("block",)                  n_steps = n_layers
    gemma2          : ("local", "global")         n_steps = n_layers // 2
    ssm (mamba2)    : ("mamba",)                  n_steps = n_layers
    hybrid (zamba2) : ("mamba", "mamba", SHARED)  n_steps = n_layers // 2

Pattern params are stacked along a leading 'layers' dim and the whole depth
runs as one ``lax.scan`` (HLO size O(1) in depth — llama3's 126 layers lower
as a single scanned body).  ``cfg.scan_layers=False`` switches to a python
loop over the same stacked params for exact-FLOP calibration compiles.

Zamba2's SHARED transformer block (2 alternating copies, applied after every
pattern step on concat(hidden, initial-embedding)) lives outside the stacked
params and is index-selected inside the scan body.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import moe as M
from repro.models.attention import attention, decode_attention
from repro.models.params import Spec, init_params, abstract_params


# ================================================================ specs ====
def attn_specs(cfg: ModelConfig) -> dict:
    d, Hq, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    sp = {
        "ln": Spec((d,), ("norm",), init="ones"),
        "w_q": Spec((d, Hq, Dh), ("fsdp", "heads", None)),
        "w_k": Spec((d, Hkv, Dh), ("fsdp", "kv_heads", None)),
        "w_v": Spec((d, Hkv, Dh), ("fsdp", "kv_heads", None)),
        "w_o": Spec((Hq, Dh, d), ("heads", None, "fsdp")),
    }
    if cfg.attn_bias:
        sp["b_q"] = Spec((Hq, Dh), ("heads", None), init="zeros")
        sp["b_k"] = Spec((Hkv, Dh), ("kv_heads", None), init="zeros")
        sp["b_v"] = Spec((Hkv, Dh), ("kv_heads", None), init="zeros")
    if cfg.post_norm:
        sp["ln_post"] = Spec((d,), ("norm",), init="ones")
    return sp


def mlp_specs_full(cfg: ModelConfig) -> dict:
    sp = {"ln": Spec((cfg.d_model,), ("norm",), init="ones")}
    sp.update(L.mlp_specs(cfg.d_model, cfg.d_ff))
    if cfg.post_norm:
        sp["ln_post"] = Spec((cfg.d_model,), ("norm",), init="ones")
    return sp


def _pattern(cfg: ModelConfig) -> tuple[list[str], int]:
    if cfg.family == "ssm":
        return ["mamba"], cfg.n_layers
    if cfg.family == "hybrid":
        assert cfg.shared_period == 2
        return ["mamba", "mamba"], cfg.n_layers // 2
    if cfg.local_global_period:
        return ["local", "global"], cfg.n_layers // cfg.local_global_period
    return ["block"], cfg.n_layers


def _sub_specs(cfg: ModelConfig, kind: str) -> dict:
    if kind == "mamba":
        return {"mamba": S.mamba_specs(cfg)}
    sp = {"attn": attn_specs(cfg)}
    if cfg.family == "moe":
        sp["moe"] = M.moe_specs(cfg)
        sp["ln_moe"] = Spec((cfg.d_model,), ("norm",), init="ones")
    else:
        sp["mlp"] = mlp_specs_full(cfg)
    return sp


def _stack(specs, n: int):
    def one(s: Spec) -> Spec:
        return Spec((n,) + s.shape, ("layers",) + s.axes, init=s.init,
                    scale=s.scale, dtype=s.dtype)
    return jax.tree.map(one, specs, is_leaf=lambda x: isinstance(x, Spec))


def shared_block_specs(cfg: ModelConfig) -> dict:
    """Zamba2 shared block: concat(h, embed0) -> proj -> attn+mlp."""
    d = cfg.d_model
    return {
        "w_in": Spec((2 * d, d), (None, "fsdp")),
        "attn": attn_specs(cfg),
        "mlp": mlp_specs_full(cfg),
    }


def lm_specs(cfg: ModelConfig) -> dict:
    pattern, n_steps = _pattern(cfg)
    step = {f"s{i}_{k}": _sub_specs(cfg, k) for i, k in enumerate(pattern)}
    sp: dict[str, Any] = {
        "embed": L.embed_specs(cfg.vocab, cfg.d_model),
        "blocks": _stack(step, n_steps),
        "final_norm": Spec((cfg.d_model,), ("norm",), init="ones"),
    }
    if not cfg.tie_embeddings:
        sp["lm_head"] = Spec((L.padded_vocab(cfg.vocab), cfg.d_model),
                             ("vocab", "fsdp"))
    if cfg.family == "hybrid":
        sp["shared"] = _stack(shared_block_specs(cfg),
                              max(cfg.n_shared_blocks, 1))
    return sp


# ============================================================ sublayers ====
def _qkv(p, x, cfg):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["w_k"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["w_v"].astype(dt))
    if cfg.attn_bias:
        q = q + p["b_q"].astype(dt)
        k = k + p["b_k"].astype(dt)
        v = v + p["b_v"].astype(dt)
    if cfg.kv_repeat > 1:
        k = jnp.repeat(k, cfg.kv_repeat, axis=2)
        v = jnp.repeat(v, cfg.kv_repeat, axis=2)
    return q, k, v


def attn_sublayer(p, x, cfg, *, window, q_offset=0, cache=None, mode="train",
                  causal=True, mesh=None, rules=None):
    """Pre-norm attention residual sublayer.  cache: None (train/prefill) or
    {'k','v','len'} for decode append.  Returns (x_out, new_cache); in
    prefill mode new_cache = {'k','v'} (post-rope) for decode-cache assembly."""
    from repro.distributed.sharding import shard_activation
    xn = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    q, k, v = _qkv(p, xn, cfg)
    if mesh is not None:
        q = shard_activation(q, ("batch", None, "act_heads", None), rules, mesh)
        k = shard_activation(k, ("batch", None, "act_kv_heads", None), rules, mesh)
        v = shard_activation(v, ("batch", None, "act_kv_heads", None), rules, mesh)
    new_cache = None
    if cache is None:
        positions = q_offset + jnp.arange(x.shape[1])
        q = L.apply_rope(q, positions[None, :], cfg.rope_theta)
        k = L.apply_rope(k, positions[None, :], cfg.rope_theta)
        o = attention(q, k, v, impl=cfg.attn_impl, causal=causal,
                      window=window, cap=cfg.attn_softcap, q_offset=q_offset,
                      block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv)
        if mode == "prefill":
            new_cache = {"k": k, "v": v}
    else:
        pos = cache["len"]                            # (B,) per-slot lengths
        positions = pos[:, None] + jnp.arange(x.shape[1])[None, :]
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        ck, cv = _cache_append(cache, k, v, cfg)
        o = decode_attention(q, ck, cv, kv_valid=pos + 1, window=window,
                             cap=cfg.attn_softcap)
        new_cache = dict(cache)
        new_cache["len"] = pos + 1
    o = jnp.einsum("bshk,hkd->bsd", o, p["w_o"].astype(x.dtype))
    if cfg.post_norm:
        o = L.rmsnorm(p["ln_post"], o, cfg.norm_eps)
    return x + o, new_cache


def _quant_kv(k):
    scale = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    return (k.astype(jnp.float32) / scale).round().astype(jnp.int8), scale


def _dequant_kv(kq, scale, dtype):
    return (kq.astype(jnp.float32) * scale).astype(dtype)


def _row_update(buf, val, pos):
    """buf (B,S,H,D) <- val (B,T,H,D) written at per-row positions (B,)."""
    return jax.vmap(
        lambda b, x, p: jax.lax.dynamic_update_slice_in_dim(b, x, p, 0)
    )(buf, val, pos)


def _cache_append(cache, k, v, cfg):
    """Write k,v (B,T,H,D) at per-slot positions cache['len'] (B,); return
    full dequantized cache arrays for attention (continuous batching: every
    slot owns an independent sequence length)."""
    pos = cache["len"]
    if cfg.kv_cache_dtype == "int8":
        kq, ks = _quant_kv(k)
        vq, vs = _quant_kv(v)
        cache["k"] = _row_update(cache["k"], kq, pos)
        cache["v"] = _row_update(cache["v"], vq, pos)
        cache["k_scale"] = _row_update(cache["k_scale"], ks, pos)
        cache["v_scale"] = _row_update(cache["v_scale"], vs, pos)
        ck = _dequant_kv(cache["k"], cache["k_scale"], k.dtype)
        cv = _dequant_kv(cache["v"], cache["v_scale"], v.dtype)
    else:
        cache["k"] = _row_update(cache["k"], k.astype(cache["k"].dtype), pos)
        cache["v"] = _row_update(cache["v"], v.astype(cache["v"].dtype), pos)
        ck, cv = cache["k"], cache["v"]
    return ck, cv


def mlp_sublayer(p, x, cfg, mesh=None, rules=None):
    from repro.distributed.sharding import shard_activation
    xn = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    h = L.mlp(p, xn, cfg.mlp_act)
    if cfg.post_norm:
        h = L.rmsnorm(p["ln_post"], h, cfg.norm_eps)
    if mesh is not None:
        h = shard_activation(h, ("batch", None, "embed"), rules, mesh)
    return x + h


# ============================================================ block step ===
def make_block_step(cfg: ModelConfig, mode: str, mesh=None, rules=None,
                    shared_params=None, embed0=None):
    """Returns step(x_and_extras, step_params, step_idx, cache_slice)
    -> (x, new_cache_slice, aux).  mode: 'train' | 'prefill' | 'decode'."""
    pattern, _ = _pattern(cfg)
    window_for = {
        "local": cfg.sliding_window,
        "global": None,
        "block": cfg.sliding_window,
        "shared": None,
    }

    def step(carry, step_params, step_idx, cache_slice):
        x, q_offset = carry
        x = L.grad_barrier(x)
        if mesh is not None:
            from repro.distributed.sharding import shard_activation
            x = shard_activation(x, ("batch", "resid_seq", "embed"),
                                 rules, mesh)
        aux = jnp.float32(0)
        new_cache = {}
        for i, kind in enumerate(pattern):
            p = step_params[f"s{i}_{kind}"]
            ckey = f"s{i}"
            csl = None if cache_slice is None else cache_slice.get(ckey)
            if kind == "mamba":
                if mode == "decode":
                    dx, nc = S.mamba_decode(p["mamba"], x, cfg, csl)
                else:
                    dx, nc = S.mamba_block(p["mamba"], x, cfg, cache=csl)
                x = x + dx
                new_cache[ckey] = nc
            else:
                cache_in = csl if mode == "decode" else None
                x, nc = attn_sublayer(p["attn"], x, cfg,
                                      window=window_for[kind],
                                      q_offset=q_offset, cache=cache_in,
                                      mode=mode, mesh=mesh, rules=rules)
                if nc is not None:
                    new_cache[ckey] = nc
                if cfg.family == "moe":
                    xn = L.rmsnorm(p["ln_moe"], x, cfg.norm_eps)
                    dx, a = M.moe_block(p["moe"], xn, cfg, mesh=mesh, rules=rules)
                    x = x + dx
                    aux = aux + a
                else:
                    x = mlp_sublayer(p["mlp"], x, cfg, mesh=mesh, rules=rules)
        if cfg.family == "hybrid":
            sel = jax.tree.map(
                lambda a: a[step_idx % max(cfg.n_shared_blocks, 1)],
                shared_params)
            xi = jnp.concatenate([x, embed0], axis=-1)
            xi = xi @ sel["w_in"].astype(x.dtype)
            csl = None if cache_slice is None else cache_slice.get("shared")
            cache_in = csl if mode == "decode" else None
            h, nc = attn_sublayer(sel["attn"], xi, cfg, window=None,
                                  q_offset=q_offset, cache=cache_in,
                                  mode=mode, mesh=mesh, rules=rules)
            h = mlp_sublayer(sel["mlp"], h, cfg, mesh=mesh, rules=rules)
            x = x + (h - xi)      # residual contribution of the shared block
            if nc is not None:
                new_cache["shared"] = nc
        return (x, q_offset), (new_cache or None), aux

    return step


# ============================================================== caches =====
def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int,
                      prefilled: int = 0) -> dict:
    """Stacked (n_steps, ...) cache pytree for the scanned decode step."""
    pattern, n_steps = _pattern(cfg)
    Hkv = cfg.n_kv_heads * cfg.kv_repeat
    kvdt = jnp.int8 if cfg.kv_cache_dtype == "int8" else jnp.bfloat16

    def attn_cache():
        c = {"k": jnp.zeros((batch, max_len, Hkv, cfg.head_dim), kvdt),
             "v": jnp.zeros((batch, max_len, Hkv, cfg.head_dim), kvdt),
             "len": jnp.full((batch,), prefilled, jnp.int32)}
        if cfg.kv_cache_dtype == "int8":
            c["k_scale"] = jnp.zeros((batch, max_len, Hkv, 1), jnp.float32)
            c["v_scale"] = jnp.zeros((batch, max_len, Hkv, 1), jnp.float32)
        return c

    step_cache: dict[str, Any] = {}
    for i, kind in enumerate(pattern):
        if kind == "mamba":
            step_cache[f"s{i}"] = S.init_ssm_cache(cfg, batch)
        else:
            step_cache[f"s{i}"] = attn_cache()
    if cfg.family == "hybrid":
        step_cache["shared"] = attn_cache()
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_steps,) + a.shape).copy()
        if a.ndim > 0 else jnp.full((n_steps,), a), step_cache)


def _merge_prefill_cache(cfg, B, S, max_len, raw):
    """raw: stacked (n_steps, ...) prefill outputs — attn {'k','v'} (L,B,S,H,D)
    and/or mamba conv/state caches.  Builds the decode cache with len=S."""
    cache = init_decode_cache(cfg, B, max_len, prefilled=S)

    def fill_kv(dst_key, src, c):
        pad = max_len - src.shape[2]
        if cfg.kv_cache_dtype == "int8":
            q, sc = _quant_kv(src)
            c[dst_key] = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            c[dst_key + "_scale"] = jnp.pad(
                sc, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        else:
            c[dst_key] = jnp.pad(
                src.astype(c[dst_key].dtype),
                ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))

    for key, src in raw.items():
        if src is None:
            continue
        if "k" in src and "v" in src and "state" not in src:
            fill_kv("k", src["k"], cache[key])
            fill_kv("v", src["v"], cache[key])
        else:  # mamba cache carried through directly
            for f in ("conv_x", "conv_B", "conv_C"):
                cache[key][f] = src[f].astype(cache[key][f].dtype)
            cache[key]["state"] = src["state"]
    return cache


def decode_cache_axes(cfg: ModelConfig) -> dict:
    """Logical-axes pytree mirroring init_decode_cache (for sharding specs)."""
    pattern, _ = _pattern(cfg)

    def attn_axes():
        ax = {"k": ("layers", "batch", "kv_seq", "act_kv_heads", None),
              "v": ("layers", "batch", "kv_seq", "act_kv_heads", None),
              "len": ("layers", "batch")}
        if cfg.kv_cache_dtype == "int8":
            ax["k_scale"] = ("layers", "batch", "kv_seq", "act_kv_heads", None)
            ax["v_scale"] = ("layers", "batch", "kv_seq", "act_kv_heads", None)
        return ax

    ssm_axes = {
        "conv_x": ("layers", "batch", None, "act_mlp"),
        "conv_B": ("layers", "batch", None, None),
        "conv_C": ("layers", "batch", None, None),
        "state": ("layers", "batch", "act_heads", None, None),
    }
    axes: dict[str, Any] = {}
    for i, kind in enumerate(pattern):
        axes[f"s{i}"] = dict(ssm_axes) if kind == "mamba" else attn_axes()
    if cfg.family == "hybrid":
        axes["shared"] = attn_axes()
    return axes


# ========================================================== full model =====
@dataclasses.dataclass
class DecoderLM:
    cfg: ModelConfig

    # ---- params
    def specs(self):
        return lm_specs(self.cfg)

    def init(self, key, dtype=jnp.float32):
        return init_params(self.specs(), key, dtype)

    def abstract(self, dtype=jnp.bfloat16, mesh=None, rules=None):
        return abstract_params(self.specs(), dtype, mesh, rules)

    # ---- embedding frontend
    def _embed_inputs(self, params, tokens, extra_embeds, cdt):
        x = L.embed_lookup(params["embed"]["embedding"], tokens, cdt)
        if self.cfg.embed_scale:
            x = x * jnp.sqrt(jnp.float32(self.cfg.d_model)).astype(cdt)
        if extra_embeds is not None:
            x = jnp.concatenate([extra_embeds.astype(cdt), x], axis=1)
        return x

    # ---- forward (train / prefill shared body)
    def forward(self, params, tokens, *, extra_embeds=None, mode="train",
                mesh=None, rules=None, q_offset=0):
        cfg = self.cfg
        cdt = jnp.dtype(cfg.compute_dtype)
        x = self._embed_inputs(params, tokens, extra_embeds, cdt)
        if mesh is not None:
            from repro.distributed.sharding import shard_activation
            x = shard_activation(x, ("batch", "seq", "embed"), rules, mesh)
        embed0 = x if cfg.family == "hybrid" else None
        step = make_block_step(cfg, mode, mesh, rules,
                               shared_params=params.get("shared"),
                               embed0=embed0)

        def body(carry, sp_and_idx):
            sp, idx = sp_and_idx
            carry, _, aux = step(carry, sp, idx, None)
            return carry, aux

        if cfg.remat != "none" and mode == "train":
            policy = {"dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                      "dots_all": jax.checkpoint_policies.dots_saveable,
                      "full": None}[cfg.remat]
            body = jax.checkpoint(body, policy=policy)

        _, n_steps = _pattern(cfg)
        idxs = jnp.arange(n_steps)
        carry = (x, q_offset)
        if cfg.scan_layers:
            carry, auxs = jax.lax.scan(body, carry, (params["blocks"], idxs))
            aux = auxs.sum()
        else:
            aux = jnp.float32(0)
            for i in range(n_steps):
                sp = jax.tree.map(lambda a: a[i], params["blocks"])
                carry, a = body(carry, (sp, i))
                aux = aux + a
        x = carry[0]
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        head = (params["embed"]["embedding"] if cfg.tie_embeddings
                else params["lm_head"])
        logits = L.unembed_logits(head, x, cfg.vocab, cfg.final_softcap)
        return logits, aux

    def loss(self, params, batch, *, mesh=None, rules=None):
        """batch: tokens (B,S) int32, labels (B,S) int32, mask optional,
        extra_embeds optional (VLM prefix)."""
        logits, aux = self.forward(
            params, batch["tokens"], extra_embeds=batch.get("extra_embeds"),
            mode="train", mesh=mesh, rules=rules)
        if batch.get("extra_embeds") is not None:
            logits = logits[:, -batch["tokens"].shape[1]:]
        ce = L.cross_entropy(logits, batch["labels"], batch.get("mask"))
        return ce + 0.01 * aux, {"ce": ce, "aux": aux}

    # ---- prefill: forward pass that also returns a ready decode cache
    def prefill(self, params, tokens, *, max_len=None, extra_embeds=None,
                mesh=None, rules=None):
        cfg = self.cfg
        cdt = jnp.dtype(cfg.compute_dtype)
        x = self._embed_inputs(params, tokens, extra_embeds, cdt)
        S = x.shape[1]
        B = x.shape[0]
        max_len = max_len or S
        step = make_block_step(cfg, "prefill", mesh, rules,
                               shared_params=params.get("shared"),
                               embed0=x if cfg.family == "hybrid" else None)
        _, n_steps = _pattern(cfg)

        def body(carry, sp_and_idx):
            sp, idx = sp_and_idx
            carry, new_c, _ = step(carry, sp, idx, None)
            return carry, new_c

        carry = (x, 0)
        if cfg.scan_layers:
            carry, raw = jax.lax.scan(body, carry,
                                      (params["blocks"], jnp.arange(n_steps)))
        else:
            rs = []
            for i in range(n_steps):
                sp = jax.tree.map(lambda a: a[i], params["blocks"])
                carry, rc = body(carry, (sp, i))
                rs.append(rc)
            raw = jax.tree.map(lambda *xs: jnp.stack(xs), *rs)

        cache = _merge_prefill_cache(cfg, B, S, max_len, raw)
        x = L.rmsnorm(params["final_norm"], carry[0], cfg.norm_eps)
        head = (params["embed"]["embedding"] if cfg.tie_embeddings
                else params["lm_head"])
        logits = L.unembed_logits(head, x[:, -1:], cfg.vocab, cfg.final_softcap)
        return logits, cache

    # ---- decode
    def decode_step(self, params, cache, tokens, *, mesh=None, rules=None):
        """tokens (B, 1) -> logits (B, 1, V); cache updated in place."""
        cfg = self.cfg
        cdt = jnp.dtype(cfg.compute_dtype)
        x = self._embed_inputs(params, tokens, None, cdt)
        step = make_block_step(cfg, "decode", mesh, rules,
                               shared_params=params.get("shared"),
                               embed0=x if cfg.family == "hybrid" else None)
        _, n_steps = _pattern(cfg)

        def body(carry, inp):
            sp, idx, csl = inp
            carry, new_c, _ = step(carry, sp, idx, csl)
            return carry, new_c

        carry = (x, jnp.int32(0))
        if cfg.scan_layers:
            carry, new_cache = jax.lax.scan(
                body, carry, (params["blocks"], jnp.arange(n_steps), cache))
        else:
            ncs = []
            for i in range(n_steps):
                sp = jax.tree.map(lambda a: a[i], params["blocks"])
                csl = jax.tree.map(lambda a: a[i], cache)
                carry, nc = body(carry, (sp, i, csl))
                ncs.append(nc)
            new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
        x = L.rmsnorm(params["final_norm"], carry[0], cfg.norm_eps)
        head = (params["embed"]["embedding"] if cfg.tie_embeddings
                else params["lm_head"])
        logits = L.unembed_logits(head, x, cfg.vocab, cfg.final_softcap)
        return logits, new_cache
