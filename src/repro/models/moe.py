"""Mixture-of-Experts layer: top-k router + capacity-bounded sort dispatch.

Dispatch is gather/scatter-based (argsort by expert id, truncate to capacity)
rather than GShard one-hot einsums — on TPU the one-hot dispatch matmul burns
MXU flops proportional to tokens·E·capacity·d; gathers keep HLO FLOPs close
to the useful 2·N_active·D (visible in the roofline usefulness ratio).

Locality: dispatch runs per batch row (vmap over B), and B is sharded over
'data' — so routing never crosses devices.  Expert weights are sharded either

* TP  (default): every device holds a slice of every expert's ffn dim
  ('expert_mlp' → 'model'); no token movement, all-reduce on the output.
* EP: whole experts live on model-axis shards ('experts' → 'model'); GSPMD
  inserts the all-to-all for the (E, C, d) buffers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import Spec


def moe_specs(cfg) -> dict:
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    return {
        "w_router": Spec((d, E), ("fsdp", None)),
        "w_gate": Spec((E, d, ff), ("experts", "fsdp", "expert_mlp")),
        "w_up": Spec((E, d, ff), ("experts", "fsdp", "expert_mlp")),
        "w_down": Spec((E, ff, d), ("experts", "expert_mlp", "fsdp")),
    }


def _capacity(tokens: int, top_k: int, n_experts: int, factor: float) -> int:
    cap = int(tokens * top_k * factor / n_experts) + 1
    return max(4, min(cap, tokens))  # floor avoids degenerate decode shapes


def route_and_dispatch(x_row, logits_row, top_k: int, capacity: int, E: int):
    """Per-group dispatch.  x_row (S, d), logits_row (S, E) ->
    expert_in (E, C, d), combine info (idx (E,C), weight (E,C), valid (E,C))."""
    S, d = x_row.shape
    probs = jax.nn.softmax(logits_row.astype(jnp.float32), axis=-1)
    top_w, top_e = jax.lax.top_k(probs, top_k)              # (S, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1)                              # (S*k,)
    flat_w = top_w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(S), top_k)

    order = jnp.argsort(flat_e, stable=True)                # group by expert
    se, sw, st = flat_e[order], flat_w[order], flat_tok[order]
    # position within expert segment
    pos_in_e = jnp.arange(S * top_k) - jnp.searchsorted(se, se, side="left")
    keep = pos_in_e < capacity
    slot = jnp.where(keep, se * capacity + pos_in_e, E * capacity)  # drop sink

    idx = jnp.full((E * capacity + 1,), S, jnp.int32)       # S = pad token row
    wgt = jnp.zeros((E * capacity + 1,), jnp.float32)
    idx = idx.at[slot].set(st.astype(jnp.int32), mode="drop")
    wgt = wgt.at[slot].set(sw, mode="drop")
    idx = idx[:-1].reshape(E, capacity)
    wgt = wgt[:-1].reshape(E, capacity)

    x_pad = jnp.concatenate([x_row, jnp.zeros((1, d), x_row.dtype)], 0)
    expert_in = x_pad[idx]                                  # (E, C, d)
    return expert_in, idx, wgt


def combine(expert_out, idx, wgt, S: int):
    """expert_out (E, C, d) -> (S, d) weighted scatter-add."""
    E, C, d = expert_out.shape
    contrib = expert_out.astype(jnp.float32) * wgt[..., None]
    out = jnp.zeros((S + 1, d), jnp.float32)
    out = out.at[idx.reshape(-1)].add(contrib.reshape(E * C, d), mode="drop")
    return out[:S]


def moe_block(p, x, cfg, mesh=None, rules=None):
    """x (B, S, d) -> (B, S, d); load-balance aux loss returned alongside."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    cap = _capacity(S, k, E, cfg.capacity_factor)
    logits = x @ p["w_router"].astype(x.dtype)              # (B, S, E)

    ein, idx, wgt = jax.vmap(
        lambda xr, lr: route_and_dispatch(xr, lr, k, cap, E))(x, logits)
    # ein (B, E, C, d): under EP, constrain expert dim onto the model axis so
    # GSPMD materialises the all-to-all instead of gathering everything.
    if mesh is not None and rules is not None:
        from repro.distributed.sharding import shard_activation
        ein = shard_activation(ein, ("batch", "act_experts", None, None),
                               rules, mesh)

    from repro.models.layers import _act
    act = _act(cfg.mlp_act)
    h = act(jnp.einsum("becd,edf->becf", ein, p["w_gate"].astype(ein.dtype)))
    h = h * jnp.einsum("becd,edf->becf", ein, p["w_up"].astype(ein.dtype))
    eout = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(ein.dtype))
    if mesh is not None and rules is not None:
        from repro.distributed.sharding import shard_activation
        eout = shard_activation(eout, ("batch", "act_experts", None, None),
                                rules, mesh)

    out = jax.vmap(lambda eo, i, w: combine(eo, i, w, S))(eout, idx, wgt)

    # Switch-style load-balance aux loss
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    me = probs.mean(axis=(0, 1))                            # (E,)
    top1 = jnp.argmax(logits, -1)
    ce = jnp.zeros((E,), jnp.float32).at[top1.reshape(-1)].add(1.0) / (B * S)
    aux = E * jnp.sum(me * ce)
    return out.astype(x.dtype), aux
