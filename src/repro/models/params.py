"""Parameter spec DSL: one declaration drives init, abstract shapes, and sharding.

A model defines ``param_specs(cfg) -> nested dict of Spec``.  From that single
source of truth we derive:

* ``init_params``      — PRNG-initialised concrete arrays,
* ``abstract_params``  — ShapeDtypeStructs (optionally device-sharded) for
                         AOT lowering in the multi-pod dry-run,
* ``param_count``      — exact parameter count for the roofline's 6·N·D,
* partition specs      — via ``distributed.sharding.tree_pspecs``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis names per dim
    init: str = "normal"                  # normal | zeros | ones | embed
    scale: float | None = None            # stddev override (normal/embed)
    dtype: Any = None                     # override param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, Spec)


def _fan_in(shape: tuple[int, ...]) -> int:
    # For stacked-layer params (leading 'layers' dim) fan-in excludes dim 0;
    # callers tag it via axes, but a safe heuristic: use second-to-last dim.
    if len(shape) >= 2:
        return shape[-2]
    return shape[-1]


def init_one(key: jax.Array, spec: Spec, dtype) -> jax.Array:
    dt = spec.dtype or dtype
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "embed":
        scale = spec.scale if spec.scale is not None else 1.0
        return (scale * jax.random.normal(key, spec.shape, jnp.float32)).astype(dt)
    scale = spec.scale if spec.scale is not None else 1.0 / math.sqrt(max(_fan_in(spec.shape), 1))
    return (scale * jax.random.normal(key, spec.shape, jnp.float32)).astype(dt)


def init_params(specs, key: jax.Array, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [init_one(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(specs, dtype=jnp.bfloat16, mesh=None, rules=None):
    from repro.distributed.sharding import named_sharding

    def one(s: Spec):
        dt = s.dtype or dtype
        if mesh is not None and rules is not None:
            sh = named_sharding(s.axes, s.shape, rules, mesh)
            return jax.ShapeDtypeStruct(s.shape, dt, sharding=sh)
        return jax.ShapeDtypeStruct(s.shape, dt)

    return jax.tree.map(one, specs, is_leaf=_is_spec)


def param_count(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=_is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))


def param_bytes(specs, dtype=jnp.bfloat16) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=_is_spec)
    total = 0
    for s in leaves:
        dt = np.dtype(s.dtype or dtype)
        total += int(np.prod(s.shape)) * dt.itemsize
    return total


def tree_axes(specs):
    """Tree of logical-axes tuples (for optimizer-state sharding etc.)."""
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)
