"""build_model(cfg) -> DecoderLM | EncDecLM."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models.transformer import DecoderLM
from repro.models.encdec import EncDecLM


def build_model(cfg: ModelConfig):
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    return DecoderLM(cfg)
