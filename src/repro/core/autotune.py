"""Auto-optimization of the PPA's hyperparameters — the paper's §7 future
work, implemented: "running the application with a set of possible metrics,
with a designated module of the PPA modeling collected running data with
different methods automatically; the best model can then be selected among
candidate models using validation techniques."

``autotune(series)`` walk-forward-validates every candidate forecaster on
the collected metric history, picks the best per deployment, and selects the
key metric by validation predictability — removing the manual choices the
paper's §5.3 spent three experiments on.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.forecaster import (ARIMAD1Forecaster, ARMAForecaster,
                                   EnsembleForecaster, Forecaster,
                                   LSTMForecaster)

DEFAULT_CANDIDATES: dict[str, Callable[[], Forecaster]] = {
    "arma": lambda: ARMAForecaster(),
    "arima_d1": lambda: ARIMAD1Forecaster(),
    "lstm_w1": lambda: LSTMForecaster(window=1),
    "lstm_w4": lambda: LSTMForecaster(window=4),
    "ensemble": lambda: EnsembleForecaster(n_members=3, window=4, epochs=80),
}


@dataclasses.dataclass
class AutoTuneReport:
    best_kind: str
    val_mse: dict            # kind -> normalized one-step val MSE (key metric)
    key_metric_idx: int
    key_metric_scores: dict  # metric idx -> normalized predictability
    model: Forecaster


def _walk_forward_mse(model: Forecaster, series: np.ndarray, start: int,
                      metric_idx: int, stride: int = 1) -> float:
    errs = []
    W = max(model.window, 2)
    for i in range(start, len(series) - 1, stride):
        try:
            pred, _ = model.predict(series[i - W + 1:i + 1])
        except Exception:
            return float("inf")
        errs.append((pred[metric_idx] - series[i + 1, metric_idx]) ** 2)
    return float(np.mean(errs)) if errs else float("inf")


def autotune(series: np.ndarray, *, candidates=None, val_frac: float = 0.33,
             key_metric_candidates: tuple[int, ...] = (0, 4),
             stride: int = 2) -> AutoTuneReport:
    """series: (T, N_METRICS) collected history.  Returns the refitted best
    model + the validated key-metric choice."""
    candidates = candidates or DEFAULT_CANDIDATES
    split = int(len(series) * (1 - val_frac))
    split = max(split, 16)

    fitted: dict[str, Forecaster] = {}
    val_mse: dict[str, float] = {}
    for name, factory in candidates.items():
        m = factory()
        m.fit(series[:split], from_scratch=True)
        fitted[name] = m
        var = max(float(series[split:, 0].var()), 1e-9)
        val_mse[name] = _walk_forward_mse(m, series, split, 0, stride) / var

    best_kind = min(val_mse, key=val_mse.get)

    # key-metric selection: which candidate metric is most predictable
    # (normalized) with the winning model class?
    key_scores: dict[int, float] = {}
    best_model = fitted[best_kind]
    for idx in key_metric_candidates:
        var = max(float(series[split:, idx].var()), 1e-9)
        key_scores[idx] = _walk_forward_mse(best_model, series, split, idx,
                                            stride) / var
    key_idx = min(key_scores, key=key_scores.get)

    # refit the winner on the full history
    final = candidates[best_kind]()
    final.fit(series, from_scratch=True)
    return AutoTuneReport(best_kind=best_kind, val_mse=val_mse,
                          key_metric_idx=key_idx,
                          key_metric_scores=key_scores, model=final)
