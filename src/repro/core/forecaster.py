"""Workload forecasters — the PPA's injectable predictive models, in pure JAX.

The paper evaluates statsmodels ARMA(1,1,1) (= ARIMA with one difference) and
a Keras LSTM(50)+ReLU-dense model.  Both are reimplemented here as jit'd JAX
programs following the model protocol of §4.2.2: input = the last ``window``
rows of [CPU, RAM, NetIn, NetOut, Custom], output = the next row.  A deep
ensemble wrapper provides the Bayesian confidence path of Algorithm 1.

All forecasters implement:
    fit(series (T, M), from_scratch=bool)   — (re)train
    predict(recent (W, M)) -> (mean (M,), std (M,) | None)
    predict_batch(recents (Z, T, M)) -> (means (Z, M), stds (Z, M) | None)
    valid() / is_bayesian / save(path) / load(path)

``predict_batch`` is the batched control plane's hot path (DESIGN.md §5):
one model serving Z scaling targets answers all of them in a single device
dispatch.  With ``use_pallas=True`` that dispatch is the fused
block-batched sequence kernel (``kernels/lstm_seq.py``, DESIGN.md §7):
the whole W-step window runs inside ONE kernel with (h, c) resident in
VMEM scratch, for both the shared-weights layout (``lstm_forward``) and
the stacked per-target layout (``_lstm_forward_stacked`` — Z independently
trained LSTMs, batched-GEMV gate matmuls).  The kernel carries a
checkpoint-style custom VJP, so the fit paths differentiate through it.
"""
from __future__ import annotations

import functools
import pickle
from collections import defaultdict
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import N_METRICS
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


# ------------------------------------------------------------------ base ---
class Forecaster:
    window: int = 1
    is_bayesian: bool = False

    def fit(self, series: np.ndarray, from_scratch: bool = False): ...
    def predict(self, recent: np.ndarray): ...
    def valid(self) -> bool: return True

    def predict_batch(self, recents):
        """recents: (Z, T, M) array or length-Z list of (T, M) windows ->
        (means (Z, M), stds (Z, M) | None).  Base implementation loops
        ``predict``; subclasses override with a truly batched path."""
        means, stds = [], []
        for r in recents:
            mean, std = self.predict(np.asarray(r))
            means.append(mean)
            stds.append(std)
        batched_std = (np.stack(stds) if all(s is not None for s in stds)
                       else None)
        return np.stack(means), batched_std

    def save(self, path):
        Path(path).parent.mkdir(parents=True, exist_ok=True)
        with open(path, "wb") as f:
            pickle.dump(self.__getstate__(), f)

    def load(self, path):
        with open(path, "rb") as f:
            self.__setstate__(pickle.load(f))
        return self


# --------------------------------------------------------------- scaling ---
Z_CLIP = 10.0   # z-score clamp shared by every transform path


def transform_stacked(wins: np.ndarray, mean: np.ndarray, std: np.ndarray
                      ) -> np.ndarray:
    """``Scaler.transform`` broadcast over stacked per-target stats:
    wins (Z, W, M), mean/std (Z, M) -> (Z, W, M).  The vectorised control
    plane routes through this single definition so its arithmetic can
    never diverge from the scalar decision path."""
    return np.clip((wins - mean[:, None]) / std[:, None], -Z_CLIP, Z_CLIP)


class Scaler:
    """Per-metric standardisation (the paper's ScalerLink companion)."""

    def __init__(self):
        self.mean = np.zeros(N_METRICS)
        self.std = np.ones(N_METRICS)
        self.fitted = False

    def fit(self, series: np.ndarray):
        self.mean = series.mean(0)
        # relative floor: a constant training column (e.g. RAM with a fixed
        # replica count) must not blow up z-scores at serve time
        self.std = np.maximum(series.std(0), 0.01 * (np.abs(self.mean) + 1.0))
        self.fitted = True

    def transform(self, x):
        return np.clip((x - self.mean) / self.std, -Z_CLIP, Z_CLIP)
    def inverse(self, x):    return x * self.std + self.mean
    def inverse_std(self, s): return s * self.std


# ------------------------------------------------------------------ LSTM ---
def _lstm_init(key, n_in: int, hidden: int, n_out: int):
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / np.sqrt(hidden)
    return {
        "Wx": jax.random.normal(k1, (n_in, 4 * hidden)) * s,
        "Wh": jax.random.normal(k2, (hidden, 4 * hidden)) * s,
        "b": jnp.zeros((4 * hidden,)),
        "Wo": jax.random.normal(k3, (hidden, n_out)) * s,
        "bo": jnp.zeros((n_out,)),
    }


def _attn_init(key, n_in: int, hidden: int, n_out: int):
    """Attention-Double-LSTM parameters: two LSTM layers bridged by a
    window-length temporal-attention block (query projection ``Wa``)."""
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    s = 1.0 / np.sqrt(hidden)
    return {
        "Wx1": jax.random.normal(k1, (n_in, 4 * hidden)) * s,
        "Wh1": jax.random.normal(k2, (hidden, 4 * hidden)) * s,
        "b1": jnp.zeros((4 * hidden,)),
        "Wa": jax.random.normal(k3, (hidden, hidden)) * s,
        "Wx2": jax.random.normal(k4, (hidden, 4 * hidden)) * s,
        "Wh2": jax.random.normal(k5, (hidden, 4 * hidden)) * s,
        "b2": jnp.zeros((4 * hidden,)),
        "Wo": jax.random.normal(k6, (hidden, n_out)) * s,
        "bo": jnp.zeros((n_out,)),
    }


def _attn_body(params, xs):
    """Pure-jnp Attention-Double-LSTM forward: xs (B, W, M) -> (B, n_out).
    Op-for-op ``kernels/ref.attn_lstm_seq`` with dict params — the XLA
    (non-Pallas) serving/fit path of ``AttnLSTMForecaster``; the fused
    kernel's custom-VJP backward replays the same math, so both paths train
    with identical gradients.

    Stage 1: first LSTM scan keeping every hidden state; stage 2: temporal
    attention (query = final hidden state @ Wa, scaled-dot scores over the
    window, softmax weights reweight the hidden sequence); stage 3: second
    LSTM scan over the reweighted sequence + ReLU-dense head."""
    B = xs.shape[0]
    H = params["Wh1"].shape[-2]
    h = jnp.zeros((B, H))
    c = jnp.zeros((B, H))

    def step1(carry, x):
        h, c = carry
        gates = x @ params["Wx1"] + h @ params["Wh1"] + params["b1"]
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    (h1, _), hs = jax.lax.scan(step1, (h, c), jnp.swapaxes(xs, 0, 1))
    hs = jnp.swapaxes(hs, 0, 1)                          # (B, W, H)
    q = h1 @ params["Wa"]                                # (B, H)
    scores = jnp.sum(hs * q[:, None, :], axis=-1) * (H ** -0.5)
    alpha = jax.nn.softmax(scores, axis=-1)              # (B, W)
    ctx = alpha[:, :, None] * hs                         # reweighted sequence

    h = jnp.zeros((B, H))
    c = jnp.zeros((B, H))

    def step2(carry, a):
        h, c = carry
        gates = a @ params["Wx2"] + h @ params["Wh2"] + params["b2"]
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), None

    (h2, _), _ = jax.lax.scan(step2, (h, c), jnp.swapaxes(ctx, 0, 1))
    return jax.nn.relu(h2) @ params["Wo"] + params["bo"]


# architecture registry: arch name -> (param init, ordered leaf names).
# ``arch`` is threaded as a STATIC argument through every jitted forward /
# fit below, so one function tree serves the whole forecaster zoo — adding
# an architecture means an init + a forward body + one entry here, not a
# parallel copy of the stacking/fit/device-residency protocol.
ARCH_INITS = {"lstm": _lstm_init, "attn": _attn_init}
ARCH_PARAM_LEAVES = {
    "lstm": ("Wx", "Wh", "b", "Wo", "bo"),
    "attn": ("Wx1", "Wh1", "b1", "Wa", "Wx2", "Wh2", "b2", "Wo", "bo"),
}


def lstm_cell(params, h, c, x):
    """One LSTM step, pure jnp.  x (..., n_in); h, c (..., H).  The Pallas
    path no longer routes through here: ``use_pallas=True`` dispatches the
    whole window to the fused sequence kernel in ``lstm_forward`` (the
    single-step ``kernels/ops.lstm_cell`` remains for the bench's legacy
    comparison lane)."""
    gates = x @ params["Wx"] + h @ params["Wh"] + params["b"]
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, c


@functools.partial(jax.jit, static_argnames=("use_pallas", "arch"))
def lstm_forward(params, xs, *, use_pallas: bool = False,
                 arch: str = "lstm"):
    """xs (B, W, M) -> prediction (B, M).

    ``use_pallas=True`` routes through the fused whole-window sequence
    kernel (``kernels/lstm_seq.py`` for ``arch="lstm"``,
    ``kernels/attn_lstm_seq.py`` for ``arch="attn"``): one dispatch keeps
    (h, c) — and for attn the whole hidden-state history + attention —
    resident in VMEM scratch across the W timesteps instead of re-launching
    a cell kernel per scan step.  Both kernels are differentiable
    (checkpoint-style custom VJP replaying the jnp reference), so every
    fit-path forward rides them too."""
    if arch == "attn":
        if use_pallas:
            from repro.kernels import ops
            return ops.attn_lstm_seq(
                params["Wx1"], params["Wh1"], params["b1"], params["Wa"],
                params["Wx2"], params["Wh2"], params["b2"],
                params["Wo"], params["bo"], xs)
        return _attn_body(params, xs)
    if use_pallas:
        from repro.kernels import ops
        return ops.lstm_seq(params["Wx"], params["Wh"], params["b"],
                            params["Wo"], params["bo"], xs)
    B = xs.shape[0]
    H = params["Wh"].shape[0]
    h = jnp.zeros((B, H))
    c = jnp.zeros((B, H))

    def step(carry, x):
        h, c = carry
        h, c = lstm_cell(params, h, c, x)
        return (h, c), None

    (h, c), _ = jax.lax.scan(step, (h, c), jnp.swapaxes(xs, 0, 1))
    return jax.nn.relu(h) @ params["Wo"] + params["bo"]


@functools.partial(jax.jit, static_argnames=("opt_cfg", "epochs",
                                             "use_pallas", "arch"))
def _lstm_fit(params, opt_state, X, Y, opt_cfg, epochs, use_pallas=False,
              arch="lstm"):
    def loss_fn(p):
        pred = lstm_forward(p, X, use_pallas=use_pallas, arch=arch)
        return jnp.mean((pred - Y) ** 2)

    def epoch(carry, _):
        params, opt_state = carry
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, _ = adamw_update(grads, opt_state, params, opt_cfg)
        return (params, opt_state), loss

    (params, opt_state), losses = jax.lax.scan(
        epoch, (params, opt_state), None, length=epochs)
    return params, opt_state, losses


class LSTMForecaster(Forecaster):
    """Paper §5.3.1: LSTM(50) + ReLU dense head, MSE loss, Adam.

    ``residual=True`` regresses the per-step delta (prediction = last value +
    net output) — the net degrades to persistence when uncertain, which keeps
    it robust when the serving regime drifts from the collection regime.

    ``arch``/``PARAM_LEAVES`` are the class's entry in the architecture
    registry: every stacked-protocol consumer (stack signature, batched
    fits, the device plane's weight cache) keys on them instead of on the
    concrete class, so subclasses that swap the forward body
    (``AttnLSTMForecaster``) inherit the whole protocol."""

    arch: str = "lstm"
    PARAM_LEAVES: tuple = ARCH_PARAM_LEAVES["lstm"]

    def __init__(self, window: int = 1, hidden: int = 50, epochs: int = 150,
                 finetune_epochs: int = 30, lr: float = 1e-2, seed: int = 0,
                 residual: bool = True, use_pallas: bool = False):
        self.window, self.hidden = window, hidden
        self.epochs, self.finetune_epochs = epochs, finetune_epochs
        self.residual = residual
        self.use_pallas = use_pallas
        self.opt_cfg = AdamWConfig(lr=lr, weight_decay=0.0, clip_norm=None,
                                   warmup_steps=0, total_steps=10**9,
                                   min_lr_ratio=1.0)
        self._seed = seed
        self.params = self._init_params(jax.random.PRNGKey(seed))
        self.scaler = Scaler()
        self._fitted = False
        self._fit_count = 0   # generation counter (stacked-batch cache key)

    def _init_params(self, key):
        return ARCH_INITS[self.arch](key, N_METRICS, self.hidden, N_METRICS)

    def _windows(self, series):
        z = self.scaler.transform(series)
        W = self.window
        X = np.stack([z[i:i + W] for i in range(len(z) - W)])
        Y = z[W:] - z[W - 1:-1] if self.residual else z[W:]
        return jnp.asarray(X), jnp.asarray(Y)

    def fit(self, series: np.ndarray, from_scratch: bool = False):
        if len(series) < self.window + 8:
            return self
        if from_scratch or not self._fitted:
            self.scaler.fit(series)
            # the model's own seed, not a shared constant: ensemble members
            # refit from scratch must stay diverse (the Bayesian std path)
            self.params = self._init_params(
                jax.random.PRNGKey(getattr(self, "_seed", 0)))
            epochs = self.epochs
        else:
            epochs = self.finetune_epochs
        X, Y = self._windows(series)
        opt = adamw_init(self.params, self.opt_cfg)
        self.params, _, losses = _lstm_fit(self.params, opt, X, Y,
                                           self.opt_cfg, epochs,
                                           self.use_pallas, self.arch)
        self._fitted = True
        self._fit_count += 1
        self.last_losses = np.asarray(losses)
        return self

    def predict(self, recent: np.ndarray):
        if not self._fitted:
            raise RuntimeError("model not fitted")
        z = self.scaler.transform(recent[-self.window:])
        pred = lstm_forward(self.params, jnp.asarray(z)[None],
                            use_pallas=self.use_pallas, arch=self.arch)[0]
        pred = np.asarray(pred)
        if self.residual:
            pred = z[-1] + pred
        return self.scaler.inverse(pred), None

    def predict_batch(self, recents):
        """One device dispatch for Z targets sharing this model: the window
        batch (Z, W, M) rides ``lstm_forward``'s batch axis (which the
        Pallas kernel tiles), instead of Z separate dispatches.  The scaler
        transform is broadcast over the whole batch (one numpy program, not
        Z per-target calls) — elementwise identical to per-target
        ``transform``."""
        if not self._fitted:
            raise RuntimeError("model not fitted")
        if isinstance(recents, np.ndarray) and recents.ndim == 3:
            wins = np.asarray(recents, np.float64)[:, -self.window:]
        else:
            wins = np.stack([np.asarray(r, np.float64)[-self.window:]
                             for r in recents])
        z = self.scaler.transform(wins)
        pred = np.asarray(lstm_forward(self.params, jnp.asarray(z),
                                       use_pallas=self.use_pallas,
                                       arch=self.arch))
        if self.residual:
            pred = z[:, -1] + pred
        return self.scaler.inverse(pred), None

    def valid(self):
        if not self._fitted:
            return False
        # params only change on fit — memoize the finiteness sweep per fit
        # generation (it is a control-plane per-tick hot path)
        cached = getattr(self, "_valid_cache", None)
        if cached is not None and cached[0] == self._fit_count:
            return cached[1]
        ok = all(bool(np.isfinite(np.asarray(v)).all())
                 for v in jax.tree.leaves(self.params))
        self._valid_cache = (self._fit_count, ok)
        return ok

    def __getstate__(self):
        d = dict(self.__dict__)
        d["params"] = jax.tree.map(np.asarray, self.params)
        return d

    def __setstate__(self, d):
        self.__dict__.update(d)
        self.params = jax.tree.map(jnp.asarray, d["params"])


class AttnLSTMForecaster(LSTMForecaster):
    """Attention-Double-LSTM (PAPERS.md, "Mitigating Temporal Blindness in
    Kubernetes Autoscaling"): a first LSTM encodes the window, temporal
    attention over its hidden states re-weights the sequence, and a second
    LSTM + ReLU-dense head reads the re-weighted context.  The attention
    lets the model key on burst onsets anywhere in the window, where the
    plain LSTM's single final hidden state is "temporally blind" on
    bursty / serverless traces.

    Everything else — the stacked per-target protocol, batched fits, the
    device plane's epoch-keyed weight cache, the fused Pallas serving path
    (``kernels/attn_lstm_seq.py``) — is inherited via the ``arch``
    registry; this class only swaps the architecture entry and the default
    window (attention needs history to attend over)."""

    arch = "attn"
    PARAM_LEAVES = ARCH_PARAM_LEAVES["attn"]

    def __init__(self, window: int = 8, **kw):
        super().__init__(window=window, **kw)


# ----------------------------------------------------- stacked batching ---
def lstm_stack_signature(m: "LSTMForecaster") -> tuple:
    """The architecture attributes that must match for params to stack on
    one leading axis — the single definition every stackability check uses
    (fitting additionally requires a matching ``opt_cfg``).  Leads with
    ``arch`` so different forward bodies (lstm vs attn) can never stack
    into one dispatch."""
    return (m.arch, m.window, m.hidden, m.residual, m.use_pallas)


def stack_params(models) -> dict:
    """Stack Z models' parameter pytrees on a new leading axis — the
    one construction every stacked-batch cache (per-target, fused, member)
    shares; each cache keeps its own invalidation key.  The stack happens
    in host numpy (one upload of the stacked leaf), not as a Z-operand
    XLA concatenate — at Z >= 10^4 jnp.stack would hand the compiler tens
    of thousands of operands."""
    return jax.tree.map(
        lambda *leaves: jnp.asarray(np.stack([np.asarray(x) for x in leaves])),
        *[m.params for m in models])


def stack_scaler_stats(models) -> tuple[np.ndarray, np.ndarray]:
    """(mean (Z, M), std (Z, M)) stacks for ``transform_stacked``."""
    return (np.stack([m.scaler.mean for m in models]),
            np.stack([m.scaler.std for m in models]))


def stacked_forward(stacked_params, xs, *, use_pallas: bool = False,
                    arch: str = "lstm"):
    """Pure (unjitted) stacked per-target forward body: pytree with
    leading target axis Z, xs (Z, W, M) -> (Z, M).  Split out of
    ``_lstm_forward_stacked`` so callers that build their own dispatch
    wrapper — the device plane's ``jax.jit``/``shard_map`` programs
    (core/device_plane.py) — trace the SAME math instead of nesting jits.
    The Pallas path routes through ``ops.lstm_seq_stacked_local`` /
    ``ops.attn_lstm_seq_stacked_local`` (the shard_map-compatible entries:
    local block shapes, no jit boundary).

    The XLA path elides the first timestep's recurrent terms: with
    h0 = c0 = 0 the ``h @ Wh`` matmul and the ``sigmoid(f) * c`` forget
    term are exactly zero, so step 1 reduces to the input projection —
    at window=1 (the forecaster default) that removes the dominant
    batched GEMV from the whole dispatch.  The elision is value-exact
    (identical at window=1; later steps may differ from the scan-only
    graph at f32 fusion-rounding level, within forecast parity
    tolerances).  The training path (``lstm_forward``) keeps the plain
    scan so fit losses and gradients are untouched."""
    if arch == "attn":
        if use_pallas:
            from repro.kernels import ops
            return ops.attn_lstm_seq_stacked_local(
                stacked_params["Wx1"], stacked_params["Wh1"],
                stacked_params["b1"], stacked_params["Wa"],
                stacked_params["Wx2"], stacked_params["Wh2"],
                stacked_params["b2"], stacked_params["Wo"],
                stacked_params["bo"], xs)
        return jax.vmap(lambda p, x: _attn_body(p, x[None])[0])(
            stacked_params, xs)
    if use_pallas:
        from repro.kernels import ops
        return ops.lstm_seq_stacked_local(
            stacked_params["Wx"], stacked_params["Wh"], stacked_params["b"],
            stacked_params["Wo"], stacked_params["bo"], xs)

    def fwd(p, x):
        gates = x[0] @ p["Wx"] + p["b"]
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        if x.shape[0] > 1:
            def step(carry, xw):
                h, c = carry
                return lstm_cell(p, h, c, xw), None
            (h, c), _ = jax.lax.scan(step, (h, c), x[1:])
        return jax.nn.relu(h) @ p["Wo"] + p["bo"]
    return jax.vmap(fwd)(stacked_params, xs)


@functools.partial(jax.jit, static_argnames=("use_pallas", "arch"))
def _lstm_forward_stacked(stacked_params, xs, *, use_pallas: bool = False,
                          arch: str = "lstm"):
    """stacked_params: pytree with leading target axis Z; xs (Z, W, M) ->
    (Z, M).  One device dispatch for all Z targets: the Pallas path is the
    fused block-batched sequence kernel (per-row weights, batched-GEMV
    gate matmuls, W-step fori_loop in VMEM scratch); the XLA path vmaps
    the scan forward."""
    return stacked_forward(stacked_params, xs, use_pallas=use_pallas,
                           arch=arch)


def lstm_predict_batch_stacked(models: list["LSTMForecaster"], recents,
                               cache: dict | None = None):
    """Batched forecast across Z *independently trained* per-target LSTMs:
    stack the parameter pytrees on a new leading axis and vmap the forward —
    one device dispatch instead of Z (core/controller.py's per-target
    mode).  Models must share architecture/window/residual settings.

    Stacking + host->device upload dominates the tick cost, so pass a
    ``cache`` dict to reuse the stacked pytree across ticks; it is re-stacked
    only when a model is (re)fit (tracked via each model's fit generation).
    """
    m0 = models[0]
    sig = lstm_stack_signature(m0)
    if not all(lstm_stack_signature(m) == sig for m in models):
        raise ValueError("stacked batching needs homogeneous models")
    z = np.stack([m.scaler.transform(np.asarray(r, np.float64)[-m0.window:])
                  for m, r in zip(models, recents)])
    key = tuple((id(m), getattr(m, "_fit_count", 0)) for m in models)
    if cache is not None and cache.get("key") == key:
        stacked = cache["stacked"]
    else:
        stacked = stack_params(models)
        if cache is not None:
            cache["key"] = key
            cache["stacked"] = stacked
            # hold strong refs: id() keys are only unique while the models
            # they were taken from stay alive (address reuse after gc would
            # otherwise let a fresh model hit a stale cache entry)
            cache["models"] = list(models)
    preds = np.asarray(_lstm_forward_stacked(stacked, jnp.asarray(z),
                                             use_pallas=m0.use_pallas,
                                             arch=m0.arch))
    if m0.residual:
        preds = z[:, -1] + preds
    means = np.stack([m.scaler.inverse(p)
                      for m, p in zip(models, preds)])
    return means, None


@functools.partial(jax.jit, static_argnames=("opt_cfg", "epochs",
                                             "use_pallas", "arch"))
def _lstm_fit_stacked(stacked_params, stacked_opt, X, Y, opt_cfg, epochs,
                      use_pallas=False, arch="lstm"):
    """Fit Z independently parameterised models in ONE dispatch: params/opt
    state stacked on a leading target axis, X (Z, N, W, M), Y (Z, N, M);
    vmap of the scalar ``_lstm_fit`` epoch scan."""
    def fit_one(p, o, x, y):
        return _lstm_fit(p, o, x, y, opt_cfg, epochs, use_pallas, arch)
    return jax.vmap(fit_one)(stacked_params, stacked_opt, X, Y)


@functools.partial(jax.jit, static_argnames=("opt_cfg", "epochs",
                                             "use_pallas", "arch"))
def _lstm_fit_stacked_masked(stacked_params, stacked_opt, X, Y, W, opt_cfg,
                             epochs, use_pallas=False, arch="lstm"):
    """``_lstm_fit_stacked`` with a per-window weight mask ``W`` (Z, N):
    ragged histories pad their window batches to a common N and zero the
    padding's loss weight, so unequal-length targets still refit in ONE
    vmapped dispatch.  With ``W[i] = 1`` on the real windows the weighted
    loss equals the unpadded per-target MSE exactly, so gradients (and the
    whole epoch scan) match the sequential fit."""
    def fit_one(p, o, x, y, w):
        def loss_fn(pp):
            pred = lstm_forward(pp, x, use_pallas=use_pallas, arch=arch)
            se = jnp.sum(w[:, None] * (pred - y) ** 2)
            return se / (jnp.sum(w) * y.shape[-1])

        def epoch(carry, _):
            params, opt_state = carry
            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, opt_state, _ = adamw_update(grads, opt_state, params,
                                                opt_cfg)
            return (params, opt_state), loss

        (params, opt_state), losses = jax.lax.scan(
            epoch, (p, o), None, length=epochs)
        return params, opt_state, losses
    return jax.vmap(fit_one)(stacked_params, stacked_opt, X, Y, W)


class BatchFitResult:
    """Deferred application of a batched fit.

    The device compute happens at construction (``lstm_fit_batch_stacked``);
    ``apply()`` installs the new params / scalers / fit counters on the
    models.  The split exists for the async control plane: ``compute`` runs
    on a worker thread without mutating any model, ``apply`` runs on the
    control thread between ticks, so an in-flight forecast never reads a
    half-updated model.
    """

    def __init__(self):
        self._groups: list[tuple] = []   # (models, scalers, params, losses)

    def add(self, models, scalers, stacked_params, losses):
        self._groups.append((models, scalers, stacked_params, losses))

    def block_until_ready(self):
        for _, _, stacked, _ in self._groups:
            jax.tree.leaves(stacked)[0].block_until_ready()
        return self

    def apply(self):
        for models, scalers, stacked, losses in self._groups:
            losses = np.asarray(losses)
            for i, m in enumerate(models):
                m.scaler = scalers[i]
                m.params = jax.tree.map(lambda leaf, i=i: leaf[i], stacked)
                m._fitted = True
                m._fit_count += 1
                m._valid_cache = None
                m.last_losses = losses[i]
        return self


def lstm_fit_batch_stacked(models: list["LSTMForecaster"], serieses,
                           from_scratch: bool = False, apply: bool = True):
    """Batched counterpart of Z sequential ``LSTMForecaster.fit`` calls:
    stack the parameter pytrees and training windows on a leading target
    axis and vmap the whole epoch scan — P2/P3 refits of all Z targets are
    one jitted dispatch instead of Z (the Updater cadence item, DESIGN.md
    §5).

    Preconditions for stacking: homogeneous architecture (window / hidden /
    residual / use_pallas / opt_cfg).  Unequal-length histories stay on the
    vmapped path via pad-and-mask (``_lstm_fit_stacked_masked``): each
    group's window batches are zero-padded to the longest target and the
    padding carries zero loss weight, so ragged fits match their sequential
    counterparts.  A list of ``EnsembleForecaster``s is flattened to its
    members (E members x Z targets on the one batch axis).  Returns
    ``None`` only when the models genuinely can't stack (heterogeneous
    architectures / non-LSTM types) — the caller falls back to sequential
    fits.  Otherwise returns a ``BatchFitResult`` (already applied unless
    ``apply=False``; models needing full-epoch scratch training and models
    needing finetune epochs are grouped, one dispatch per group — a single
    dispatch in the homogeneous steady state).
    """
    if models and all(type(m) is EnsembleForecaster for m in models):
        # E x Z: every ensemble's members ride the same stacked batch axis,
        # each member fitting on its ensemble's series
        flat = [mm for m in models for mm in m.members]
        flat_series = [s for m, s in zip(models, serieses)
                       for _ in m.members]
        return lstm_fit_batch_stacked(flat, flat_series, from_scratch,
                                      apply)
    if not models or not all(isinstance(m, LSTMForecaster) for m in models):
        return None
    m0 = models[0]
    sig = lstm_stack_signature(m0) + (m0.opt_cfg,)
    if not all(lstm_stack_signature(m) + (m.opt_cfg,) == sig
               for m in models):
        return None
    serieses = [np.asarray(s, np.float64) for s in serieses]
    if len({s.shape[1:] for s in serieses}) != 1:
        return None                      # metric dimension must agree
    result = BatchFitResult()
    W = m0.window
    # fit()'s minimum-history gate, per target: short histories no-op
    # sequentially, so they are simply excluded from the batch
    eligible = [(m, s) for m, s in zip(models, serieses)
                if len(s) >= W + 8]
    if not eligible:
        return result.apply() if apply else result
    groups: dict[tuple, list[tuple]] = defaultdict(list)
    for m, s in eligible:
        scratch = from_scratch or not m._fitted
        groups[(m.epochs if scratch else m.finetune_epochs,
                scratch)].append((m, s))
    for (epochs, scratch), pairs in groups.items():
        ms, Xs, Ys, ps, scalers = [], [], [], [], []
        for m, s in pairs:
            if scratch:
                sc = Scaler()
                sc.fit(s)
                p = m._init_params(jax.random.PRNGKey(
                    getattr(m, "_seed", 0)))
            else:
                sc, p = m.scaler, m.params
            z = sc.transform(s)
            Xs.append(np.stack([z[i:i + W] for i in range(len(z) - W)]))
            Ys.append(z[W:] - z[W - 1:-1] if m.residual else z[W:])
            ms.append(m)
            ps.append(p)
            scalers.append(sc)
        stacked_p = jax.tree.map(lambda *ls: jnp.stack(ls), *ps)
        stacked_o = jax.tree.map(lambda *ls: jnp.stack(ls),
                                 *[adamw_init(p, m0.opt_cfg) for p in ps])
        lens = {len(x) for x in Xs}
        if len(lens) == 1:
            new_p, _, losses = _lstm_fit_stacked(
                stacked_p, stacked_o, jnp.asarray(np.stack(Xs)),
                jnp.asarray(np.stack(Ys)), m0.opt_cfg, epochs,
                m0.use_pallas, m0.arch)
        else:
            # ragged: pad to the longest window batch, mask the padding
            n_max = max(lens)
            Xp = np.zeros((len(Xs), n_max) + Xs[0].shape[1:])
            Yp = np.zeros((len(Ys), n_max) + Ys[0].shape[1:])
            Wt = np.zeros((len(Xs), n_max))
            for i, (x, y) in enumerate(zip(Xs, Ys)):
                Xp[i, :len(x)] = x
                Yp[i, :len(y)] = y
                Wt[i, :len(x)] = 1.0
            new_p, _, losses = _lstm_fit_stacked_masked(
                stacked_p, stacked_o, jnp.asarray(Xp), jnp.asarray(Yp),
                jnp.asarray(Wt), m0.opt_cfg, epochs, m0.use_pallas,
                m0.arch)
        result.add(ms, scalers, new_p, losses)
    return result.apply() if apply else result


# ------------------------------------------------------------------ ARMA ---
@functools.partial(jax.jit, static_argnames=("steps",))
def _arima_fit_one(d, steps: int = 400, lr: float = 5e-2):
    """Fit ARMA(1,1) on the series d (T,) by conditional least squares:
    d_t = mu + phi d_{t-1} + theta eps_{t-1} + eps_t.  (Used on levels for
    the paper-faithful Eq. 3 model, or on first differences for the
    beyond-paper ARIMA(1,1,1) variant.)"""
    def css(theta_vec):
        mu, phi, th = theta_vec

        def step(eps_prev, pair):
            d_prev, d_t = pair
            pred = mu + phi * d_prev + th * eps_prev
            eps = d_t - pred
            return eps, eps

        _, eps = jax.lax.scan(step, 0.0, (d[:-1], d[1:]))
        return jnp.mean(eps ** 2)

    theta = jnp.zeros((3,))
    m = jnp.zeros((3,))
    v = jnp.zeros((3,))

    def opt_step(carry, i):
        theta, m, v = carry
        g = jax.grad(css)(theta)
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh = m / (1 - 0.9 ** (i + 1.0))
        vh = v / (1 - 0.999 ** (i + 1.0))
        theta = theta - lr * mh / (jnp.sqrt(vh) + 1e-8)
        theta = jnp.clip(theta, -0.98, 0.98)  # stationarity guard
        return (theta, m, v), None

    (theta, _, _), _ = jax.lax.scan(opt_step, (theta, m, v),
                                    jnp.arange(steps))
    # final eps state for forecasting
    def step(eps_prev, pair):
        d_prev, d_t = pair
        eps = d_t - (theta[0] + theta[1] * d_prev + theta[2] * eps_prev)
        return eps, None

    eps_T, _ = jax.lax.scan(step, 0.0, (d[:-1], d[1:]))
    return theta, eps_T, css(theta)


class ARMAForecaster(Forecaster):
    """Paper-faithful Eq. 3: ARMA(1,1) on metric LEVELS, per metric.

        y_t = mu + eps_t + theta_1 eps_{t-1} + phi_1 y_{t-1}

    Fit once on the pretraining distribution, this model exhibits exactly
    the 'significant shifts' under load-regime change the paper reports in
    §6.1 (the mean term is anchored to the training regime)."""

    differenced = False   # ARIMAD1Forecaster flips this (beyond-paper)

    def __init__(self, window: int = 1, steps: int = 400):
        self.window = window
        self.steps = steps
        self.scaler = Scaler()
        self.theta = None      # (M, 3)
        self.eps_T = None      # (M,)
        self._fitted = False

    def _series_for_fit(self, z):
        return np.diff(z, axis=0) if self.differenced else z

    def fit(self, series: np.ndarray, from_scratch: bool = False):
        if len(series) < 8:
            return self
        self.scaler.fit(series)
        z = self._series_for_fit(self.scaler.transform(series))
        thetas, epss = [], []
        for m in range(z.shape[1]):
            th, eT, _ = _arima_fit_one(jnp.asarray(z[:, m]), self.steps)
            thetas.append(np.asarray(th))
            epss.append(float(eT))
        self.theta = np.stack(thetas)
        self.eps_T = np.asarray(epss)
        self._fitted = True
        return self

    def predict(self, recent: np.ndarray):
        if not self._fitted:
            raise RuntimeError("model not fitted")
        z = self.scaler.transform(recent)
        mu, phi, th = self.theta[:, 0], self.theta[:, 1], self.theta[:, 2]
        if self.differenced:
            d_last = z[-1] - z[-2] if len(z) >= 2 else np.zeros_like(z[-1])
            y_next = z[-1] + mu + phi * d_last + th * self.eps_T
        else:
            y_next = mu + phi * z[-1] + th * self.eps_T
        return self.scaler.inverse(y_next), None

    def predict_batch(self, recents):
        """Closed-form one-step forecast vectorised over Z targets — pure
        numpy, no per-target loop."""
        if not self._fitted:
            raise RuntimeError("model not fitted")
        z = np.stack([self.scaler.transform(
            np.asarray(r, np.float64)[-2:]) for r in recents])   # (Z, <=2, M)
        mu, phi, th = self.theta[:, 0], self.theta[:, 1], self.theta[:, 2]
        if self.differenced:
            d_last = (z[:, -1] - z[:, -2] if z.shape[1] >= 2
                      else np.zeros_like(z[:, -1]))
            y_next = z[:, -1] + mu + phi * d_last + th * self.eps_T
        else:
            y_next = mu + phi * z[:, -1] + th * self.eps_T
        return self.scaler.inverse(y_next), None

    def valid(self):
        return self._fitted and np.isfinite(self.theta).all()

    def __getstate__(self): return dict(self.__dict__)
    def __setstate__(self, d): self.__dict__.update(d)


class ARIMAD1Forecaster(ARMAForecaster):
    """Beyond-paper: ARIMA(1,1,1) (first-differenced ARMA(1,1)).  On the
    Prometheus 1-minute-MA metric this persistence-anchored variant turns
    out to beat both paper models — recorded in EXPERIMENTS.md."""
    differenced = True


# -------------------------------------------------------------- ensemble ---
@functools.partial(jax.jit, static_argnames=("use_pallas", "arch"))
def _lstm_forward_members(stacked_params, xs, *, use_pallas: bool = False,
                          arch: str = "lstm"):
    """stacked_params: pytree with leading member axis E; xs (E, Z, W, M) ->
    (E, Z, M) — members vmapped, targets on ``lstm_forward``'s own batch
    axis, so E members x Z targets is one device dispatch (on the Pallas
    path each member's fused sequence kernel is batched by the vmap)."""
    def fwd(p, x):
        return lstm_forward(p, x, use_pallas=use_pallas, arch=arch)
    return jax.vmap(fwd)(stacked_params, xs)


class EnsembleForecaster(Forecaster):
    """Deep ensemble of LSTMs — the Bayesian path of Algorithm 1: predictive
    std across members is the (un)certainty compared against the PPA's
    confidence threshold."""

    is_bayesian = True

    def __init__(self, n_members: int = 4, **kw):
        self.members = [LSTMForecaster(seed=i, **kw) for i in range(n_members)]
        self.window = self.members[0].window
        self._stack_cache: dict = {}

    def fit(self, series, from_scratch: bool = False):
        """All E members in ONE vmapped dispatch (their param pytrees ride
        ``lstm_fit_batch_stacked``'s batch axis, matching what
        ``predict_batch`` does for the forward); heterogeneous member
        architectures fall back to the member loop."""
        if lstm_fit_batch_stacked(self.members,
                                  [series] * len(self.members),
                                  from_scratch) is None:
            for m in self.members:
                m.fit(series, from_scratch=from_scratch)
        return self

    def predict(self, recent):
        preds = np.stack([m.predict(recent)[0] for m in self.members])
        return preds.mean(0), preds.std(0)

    def predict_batch(self, recents):
        """E members x Z targets in a SINGLE dispatch: member param pytrees
        stacked on one leading axis, each member's scaler-transformed
        (Z, W, M) window batch stacked alongside, ``lstm_forward`` vmapped
        over the member axis.  The stacked params are cached per member fit
        generation.  Falls back to one dispatch per member when members are
        non-stackable (heterogeneous architecture)."""
        ms = self.members
        m0 = ms[0]
        sig = lstm_stack_signature(m0)
        if not all(isinstance(m, LSTMForecaster) and m._fitted
                   and lstm_stack_signature(m) == sig for m in ms):
            preds = np.stack([m.predict_batch(recents)[0] for m in ms])
            return preds.mean(0), preds.std(0)
        if isinstance(recents, np.ndarray) and recents.ndim == 3:
            wins = np.asarray(recents, np.float64)[:, -m0.window:]
        else:
            wins = np.stack([np.asarray(r, np.float64)[-m0.window:]
                             for r in recents])
        z = np.stack([m.scaler.transform(wins) for m in ms])  # (E, Z, W, M)
        cache = getattr(self, "_stack_cache", None)
        if cache is None:
            cache = self._stack_cache = {}
        gens = tuple(m._fit_count for m in ms)
        if cache.get("gens") != gens:
            cache["gens"] = gens
            cache["stacked"] = stack_params(ms)
        preds = np.asarray(_lstm_forward_members(
            cache["stacked"], jnp.asarray(z), use_pallas=m0.use_pallas,
            arch=m0.arch))
        if m0.residual:
            preds = z[:, :, -1] + preds
        means = np.stack([m.scaler.inverse(p) for m, p in zip(ms, preds)])
        return means.mean(0), means.std(0)

    def valid(self):
        return all(m.valid() for m in self.members)

    def __getstate__(self):
        return {"members": [m.__getstate__() for m in self.members]}

    def __setstate__(self, d):
        # reconstruct members from scratch: __setstate__ runs on a bare
        # instance (pickle/deepcopy skip __init__), so self.members does
        # not exist yet
        self._stack_cache = {}
        members = []
        for s in d["members"]:
            m = LSTMForecaster.__new__(LSTMForecaster)
            m.__setstate__(s)
            members.append(m)
        self.members = members
        self.window = members[0].window if members else 1


def make_forecaster(kind: str, **kw) -> Forecaster:
    """The paper's ModelType argument (mirrors ``make_policy``):
    'lstm' | 'attn' (Attention-Double-LSTM) | 'arma' (paper Eq. 3) |
    'arima_d1' (beyond-paper) | 'ensemble'."""
    if kind == "lstm":
        return LSTMForecaster(**kw)
    if kind == "attn":
        return AttnLSTMForecaster(**kw)
    if kind in ("arma", "arima"):
        return ARMAForecaster(**kw)
    if kind == "arima_d1":
        return ARIMAD1Forecaster(**kw)
    if kind == "ensemble":
        return EnsembleForecaster(**kw)
    raise ValueError(f"unknown forecaster kind {kind!r}")
