"""Batched multi-target PPA control plane (DESIGN.md §5).

The paper runs one control loop per scaling target; the seed reproduced
that literally — Z zones cost Z jitted forecast dispatches per tick.  The
``FleetController`` stacks all targets' metric windows into one (Z, W, M)
tensor and answers every target with a **single** device dispatch per tick:

* shared-model mode — one forecaster serves all targets through
  ``Forecaster.predict_batch`` (the fused Pallas sequence kernel tiles
  the batch dimension, so 8–64 zones ride one kernel launch);
* per-target mode — independently trained per-target LSTMs are answered
  through ``lstm_predict_batch_stacked`` (parameter pytrees stacked on a
  leading axis, vmapped forward); non-stackable models fall back to a
  per-target loop, preserving Algorithm 1 semantics.

Decisions are routed through ``Evaluator.decide_from_prediction`` and the
same ``ScaleDownStabilizer`` the scalar PPA uses, so batched and per-target
decisions are identical by construction (tests/test_control_plane.py
asserts equivalence on seeded multi-zone traces).

The tick itself is composed from the staged pipeline of
``core/control_plane.py`` (formulate -> batched forecast -> evaluate ->
actuate); ``ShardedControlPlane`` there runs the same stages sharded,
double-buffered and with off-critical-path batched refits for Z >> 10^3.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.control_plane import (Guardrail, Tick, as_replica_map,
                                      prediction_mse, stage_actuate,
                                      stage_degrade, stage_evaluate,
                                      stage_forecast, stage_formulate,
                                      stage_guard, validate_targets)
from repro.core.evaluator import Evaluator, EvalResult
from repro.core.forecaster import (Forecaster, LSTMForecaster,
                                   lstm_predict_batch_stacked,
                                   lstm_stack_signature)
from repro.core.metrics import MetricsHistory, Snapshot
from repro.core.policies import Policy
from repro.core.ppa import PPAConfig, ScaleDownStabilizer
from repro.core.updater import Updater


@dataclasses.dataclass
class TargetSpec:
    """One scaling target (zone / serving pool) under the controller."""
    name: str
    policy: Policy
    min_replicas: int = 1
    model: Forecaster | None = None    # per-target model; None -> shared


class _TargetState:
    def __init__(self, spec: TargetSpec, cfg: PPAConfig):
        self.spec = spec
        self.history = MetricsHistory()
        self.stabilizer = ScaleDownStabilizer(cfg.stabilization_s)
        self.recent: list[np.ndarray] = []
        self.decisions: list[EvalResult] = []
        self.predictions: list[tuple[float, np.ndarray]] = []
        # reactive guardrail (None when cfg.guard is unset — the default,
        # purely proactive plane)
        self.guard = (Guardrail(cfg.guard, spec.policy)
                      if getattr(cfg, "guard", None) is not None else None)
        # time of the last *fresh* observation (a blacked-out exporter
        # republishing its last sample does not advance this) — the
        # stale-metric TTL's anchor (DESIGN.md §13)
        self.last_seen = -np.inf


class FleetController:
    """Multi-target Formulator -> batched Evaluator -> scale requests."""

    is_batched = True

    def __init__(self, cfg: PPAConfig, targets: list[TargetSpec],
                 model: Forecaster | None = None,
                 updater: Updater | None = None):
        self.per_target_models = validate_targets(targets, model, updater)
        self.cfg = cfg
        self.model = model
        self.updater = updater
        self.targets: dict[str, _TargetState] = {
            t.name: _TargetState(t, cfg) for t in targets}
        # one policy-agnostic evaluator per target (the policy differs)
        self._evaluators = {
            t.name: Evaluator(t.policy, cfg.key_metric_idx,
                              cfg.confidence_threshold) for t in targets}
        self._last_update_t = 0.0
        self._stack_cache: dict = {}   # stacked-params reuse across ticks
        self._deg_stale = 0            # target-ticks held on stale metrics
        # last fresh-tick decision per target: the degraded hold's anchor
        # (stage_degrade) — k8s keeps desiredReplicas on missing metrics
        self._deg_last: dict[str, int] = {}

    # ------------------------------------------------------------ access --
    @property
    def target_names(self) -> list[str]:
        return list(self.targets)

    def min_replicas(self, name: str) -> int:
        return self.targets[name].spec.min_replicas

    def model_for(self, name: str) -> Forecaster | None:
        return (self.targets[name].spec.model if self.per_target_models
                else self.model)

    def decisions(self, name: str) -> list[EvalResult]:
        return self.targets[name].decisions

    def predictions(self, name: str) -> list[tuple[float, np.ndarray]]:
        return self.targets[name].predictions

    def guard_stats(self) -> dict:
        """Cumulative guardrail override counts across all targets (zeros
        when ``cfg.guard`` is unset)."""
        guards = [st.guard for st in self.targets.values()
                  if st.guard is not None]
        return {"up_overrides": sum(g.up_fired for g in guards),
                "down_overrides": sum(g.down_fired for g in guards)}

    def degraded_stats(self) -> dict:
        """Degraded-mode counters, same keys as
        ``ShardedControlPlane.degraded_stats`` (the scalar twin only has
        the stale-TTL path — no shards to fail over, no async forecast to
        deadline)."""
        return {"stale_targets": self._deg_stale,
                "reactive_fallbacks": self._deg_stale,
                "deadline_skips": 0, "failovers": 0,
                "recovery_ticks": 0, "snapshots": 0}

    # -------------------------------------------------------- formulator --
    def observe(self, name: str, snap: Snapshot, fresh: bool = True):
        """``fresh=False`` records a republished (stale) sample: the
        window still shifts — that is what the exporter actually served —
        but the target's freshness clock does not advance."""
        st = self.targets[name]
        st.history.append(snap)
        st.recent.append(snap.values)
        if fresh:
            st.last_seen = snap.t
        model = self.model_for(name)
        window = model.window if model is not None else 1
        st.recent = st.recent[-max(window + 1, 8):]

    def _stale_names(self, t: float) -> set:
        """Targets whose last fresh observation is older than the
        resilience TTL (empty when resilience is off — the quiet no-op)."""
        res = getattr(self.cfg, "resilience", None)
        if res is None or not np.isfinite(res.stale_ttl_s):
            return set()
        return {n for n, st in self.targets.items()
                if t - st.last_seen > res.stale_ttl_s}

    # ----------------------------------------------------------- predict --
    def _predictable(self, name: str, recent=None) -> bool:
        """``recent`` overrides the live window with a tick snapshot —
        candidacy must be judged on the same data the forecast will read,
        or an async tick's interleaved observations could flip it."""
        model = self.model_for(name)
        try:
            n_rows = (len(recent) if recent is not None
                      else len(self.targets[name].recent))
            return (model is not None and model.valid()
                    and n_rows >= model.window + 1)
        except Exception:
            return False

    def _predict_all(self, names: list[str], recents_map: dict | None = None
                     ) -> dict:
        """One batched forecast for every predictable target.  Returns
        {name: (mean, std, is_bayesian)}; missing names -> reactive.
        ``recents_map`` lets the formulate stage supply already-stacked
        windows (stage_forecast) instead of re-stacking here."""
        if recents_map is not None:
            cand = [n for n in names
                    if self._predictable(n, recents_map[n])]
        else:
            cand = [n for n in names if self._predictable(n)]
        if not cand:
            return {}
        if recents_map is not None:
            recents = [recents_map[n] for n in cand]
        else:
            recents = [np.stack(self.targets[n].recent) for n in cand]
        try:
            if not self.per_target_models:
                means, stds = self.model.predict_batch(recents)
                bayes = self.model.is_bayesian
            else:
                models = [self.model_for(n) for n in cand]
                if (all(isinstance(m, LSTMForecaster) for m in models)
                        and len(set(lstm_stack_signature(m)
                                    for m in models)) == 1):
                    means, stds = lstm_predict_batch_stacked(
                        models, recents, cache=self._stack_cache)
                    bayes = False
                else:
                    # heterogeneous models: per-target fallback, still one
                    # control-plane pass (Algorithm 1 semantics preserved)
                    out = {}
                    for n, m, r in zip(cand, models, recents):
                        try:
                            mean, std = m.predict(r)
                            out[n] = (mean, std, m.is_bayesian)
                        except Exception:
                            pass
                    return out
        except Exception:
            # Robust: batched model failure -> every target falls back to
            # its current metric (same guarantee as Evaluator.evaluate)
            return {}
        if stds is None:
            stds = [None] * len(cand)
        return {n: (means[i], stds[i], bayes) for i, n in enumerate(cand)}

    # -------------------------------------------------------- control loop -
    def control_step(self, t: float, max_replicas, current_replicas,
                     actuator=None) -> dict[str, EvalResult]:
        """One batched tick, composed from the staged pipeline
        (core/control_plane.py): formulate -> batched forecast -> evaluate
        -> guard -> actuate.  max_replicas / current_replicas are
        {name: int} (or a single int broadcast to all targets)."""
        names = self.target_names
        tick = Tick(t=t, names=names,
                    max_r=as_replica_map(max_replicas, names),
                    cur_r=as_replica_map(current_replicas, names))
        stage_formulate(self, tick)
        stage_forecast(self, tick)
        stage_evaluate(self, tick)
        stage_degrade(self, tick)
        stage_guard(self, tick)
        return stage_actuate(tick, actuator)

    # --------------------------------------------------------- update loop -
    def maybe_update(self, t: float):
        if self.updater is None:
            return
        if t - self._last_update_t < self.cfg.update_interval_s:
            return
        self._last_update_t = t
        if self.per_target_models:
            # one vmapped batch refit for every eligible target when the
            # models stack (Updater.update_batch falls back to sequential
            # fits otherwise) — P2/P3 updates are a single dispatch
            names = self.target_names
            models = [self.targets[n].spec.model for n in names]
            hists = [self.targets[n].history for n in names]
            self.updater.update_batch(models, hists, t, targets=names)
            for n, m in zip(names, models):
                self.targets[n].spec.model = m
        else:
            # pooled cross-target training for the shared model (windows
            # spanning a target boundary are a small, documented artefact)
            merged = MetricsHistory()
            for st in self.targets.values():
                for tt, row in zip(st.history.times(), st.history.series()):
                    merged.append(Snapshot(float(tt), row))
            n_rows = len(merged)
            self.model = self.updater.update(self.model, merged, t)
            if len(merged) < n_rows:   # updater consumed (and cleared) it
                for st in self.targets.values():
                    st.history.clear()

    # --------------------------------------------------------- evaluation --
    def prediction_mse(self, name: str, actual_series: np.ndarray,
                       actual_times: np.ndarray,
                       metric_idx: int | None = None) -> float:
        """Per-target one-step-ahead MSE (paper Figs. 7-8)."""
        idx = self.cfg.key_metric_idx if metric_idx is None else metric_idx
        return prediction_mse(self.targets[name].predictions,
                              actual_series, actual_times, idx)
