# The paper's primary contribution: the Proactive Pod Autoscaler (PPA) and
# its substrate — forecasters, Evaluator (Alg. 1), static policies, Updater
# (3 update policies), and the reactive HPA baseline (Eq. 1).
from repro.core.metrics import (METRIC_NAMES, N_METRICS, KEY_CPU, KEY_CUSTOM,
                                MetricsHistory, Snapshot)
from repro.core.forecaster import (Forecaster, LSTMForecaster,
                                   AttnLSTMForecaster,
                                   ARMAForecaster, ARIMAD1Forecaster,
                                   EnsembleForecaster, make_forecaster)
from repro.core.policies import (ThresholdPolicy, TargetUtilizationPolicy,
                                 SLAPolicy, GuardrailConfig,
                                 ResilienceConfig,
                                 make_policy, policy_vectorizable)
from repro.core.evaluator import Evaluator, EvalResult
from repro.core.updater import Updater, UpdatePolicy
from repro.core.hpa import HPA
from repro.core.ppa import PPA, PPAConfig, ScaleDownStabilizer
from repro.core.controller import FleetController, TargetSpec
from repro.core.control_plane import (ShardedControlPlane, Tick, TickResult,
                                      Guardrail, shard_assignment,
                                      stage_collect, stage_formulate,
                                      stage_forecast, stage_evaluate,
                                      stage_degrade, stage_guard,
                                      stage_actuate)
