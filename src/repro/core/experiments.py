"""Experiment harness for the paper's §5 protocol, shared by benchmarks,
examples and tests.

Pipeline (mirrors §5.3): (1) collect a pretraining metric series by running
the example application with generous static provisioning (the paper's "10 h
on a single unconstrained node", 1800 records); (2) pretrain the seed model;
(3) run the autoscaled scenario; (4) report prediction MSE, response-time
distributions and RIR.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.cluster import (AutoscalerBinding, ClusterSim, SimConfig,
                           paper_topology)
from repro.core import (HPA, PPA, PPAConfig, MetricsHistory, ThresholdPolicy,
                        Updater, UpdatePolicy)

ZONES = ("edge-0", "edge-1", "cloud")

# Calibrated operating point (EXPERIMENTS.md §Reproduction-calibration):
# pod startup 25 s (docker pull + Celery worker boot), Prometheus-faithful
# 1-minute moving-average exporter, NASA trace scale 3.5 (peak within the
# Table-2 capacity, as the paper rescales), per-pod targets = 70 %.
DEFAULT_SIM = dict(seed=1, startup_s=25.0)
NASA_SCALE = 3.5


def unconstrained_topology() -> "Topology":
    """The paper pretrains on 'a single unconstrained node' (§5.3.1)."""
    from repro.cluster.topology import Node, Topology
    return Topology([
        Node("control", "control", 4000, 4096, schedulable=False),
        Node("cloud-big", "cloud", 32000, 32768),
        Node("e0-big", "edge-0", 32000, 32768),
        Node("e1-big", "edge-1", 32000, 32768)])


def collect_series(tasks, t_end, replicas: int = 8,
                   cfg: SimConfig | None = None,
                   unconstrained: bool = True):
    """Static-provisioning run -> {zone: (T, 5) series} for pretraining."""
    topo = unconstrained_topology() if unconstrained else paper_topology()
    if unconstrained:
        replicas = max(replicas, 24)
    sim = ClusterSim(topo, cfg or SimConfig(seed=42))
    for z in ZONES:
        sim.scale_to(z, replicas, 0.0)
    sim.make_ready_now()
    w = sim.cfg.control_interval_s
    ticks = np.arange(w, t_end, w)
    ti = 0
    for tick in ticks:
        while ti < len(tasks) and tasks[ti][0] <= tick:
            at, kind, zone = tasks[ti]
            from repro.cluster.simulator import Task
            sim.dispatch(Task(at, kind, zone, 0.0), at)
            ti += 1
        for z in ZONES:
            sim.sample_zone(z, tick)
    return {z: np.stack([v for _, v in sim.samples[z]]) for z in ZONES}


@dataclasses.dataclass
class ScenarioResult:
    sim: ClusterSim
    ppas: dict
    mse: dict               # zone -> prediction MSE on the key metric
    mse_norm: dict          # zone -> MSE / realized key-metric variance
    sort_mean: float
    sort_std: float
    eigen_mean: float
    eigen_std: float
    rir_edge: tuple[float, float]
    rir_cloud: tuple[float, float]

    def summary(self) -> dict:
        return {
            "sort_mean_s": self.sort_mean, "sort_std_s": self.sort_std,
            "eigen_mean_s": self.eigen_mean, "eigen_std_s": self.eigen_std,
            "rir_edge": self.rir_edge[0], "rir_edge_std": self.rir_edge[1],
            "rir_cloud": self.rir_cloud[0], "rir_cloud_std": self.rir_cloud[1],
            "mse": {k: float(v) for k, v in self.mse.items()},
            "mse_norm": {k: float(v) for k, v in self.mse_norm.items()},
        }


def run_scenario(tasks, t_end, *, scaler: str = "ppa", model_kind: str = "lstm",
                 update_policy: UpdatePolicy = UpdatePolicy.FINETUNE,
                 key_metric_idx: int = 0, threshold: float = 350.0,
                 rate_threshold: float = 1.0,
                 pretrain: dict[str, np.ndarray] | None = None,
                 update_interval_s: float = 3600.0,
                 min_replicas: int = 1, sim_cfg: SimConfig | None = None,
                 confidence_threshold: float = float("inf"),
                 stabilization_s: float = 120.0, tolerance: float = 0.0,
                 window: int = 4,
                 failures: list | None = None) -> ScenarioResult:
    sim = ClusterSim(paper_topology(), sim_cfg or SimConfig(**DEFAULT_SIM))
    for ev in failures or []:
        kind = ev[0]
        if kind == "fail":
            sim.inject_node_failure(*ev[1:])
        else:
            sim.inject_straggler(*ev[1:])
    binds, ppas = [], {}
    for z in ZONES:
        if key_metric_idx == 0:
            thr = threshold
        else:
            # request-rate key metric: per-zone capacity differs (sort vs
            # eigen service time); target 70 % of one pod's throughput
            svc = (sim.cfg.eigen_service_s if z == "cloud"
                   else sim.cfg.sort_service_s)
            thr = rate_threshold * 0.7 / svc
        if scaler == "ppa":
            kw = ({} if model_kind in ("arma", "arima", "arima_d1")
                  else {"window": window})
            cfg = PPAConfig(key_metric_idx=key_metric_idx, threshold=thr,
                            update_interval_s=update_interval_s,
                            confidence_threshold=confidence_threshold,
                            min_replicas=min_replicas,
                            stabilization_s=stabilization_s,
                            forecaster=model_kind, forecaster_kw=kw)
            model = cfg.build_forecaster()
            if pretrain is not None and z in pretrain:
                model.fit(pretrain[z], from_scratch=True)
            ppa = PPA(cfg,
                      model, ThresholdPolicy(thr, min_replicas, tolerance),
                      Updater(update_policy), MetricsHistory())
            binds.append(AutoscalerBinding(z, ppa, "ppa", min_replicas))
            ppas[z] = ppa
        else:
            binds.append(AutoscalerBinding(
                z, HPA(thr, key_metric_idx, min_replicas), "hpa",
                min_replicas))
    sim.run(tasks, binds, t_end, initial_replicas=min_replicas)

    mse, mse_norm = {}, {}
    for z, ppa in ppas.items():
        arr = sim.samples[z]
        times = np.array([t for t, _ in arr])
        series = np.stack([v for _, v in arr])
        mse[z] = ppa.prediction_mse(series, times, metric_idx=key_metric_idx)
        var = max(float(series[:, key_metric_idx].var()), 1e-9)
        mse_norm[z] = mse[z] / var

    rs = sim.response_times("sort")
    re_ = sim.response_times("eigen")
    return ScenarioResult(
        sim=sim, ppas=ppas, mse=mse, mse_norm=mse_norm,
        sort_mean=float(rs.mean()) if len(rs) else float("nan"),
        sort_std=float(rs.std()) if len(rs) else float("nan"),
        eigen_mean=float(re_.mean()) if len(re_) else float("nan"),
        eigen_std=float(re_.std()) if len(re_) else float("nan"),
        rir_edge=sim.rir_stats(["edge-0", "edge-1"]),
        rir_cloud=sim.rir_stats(["cloud"]))


def welch_t(a: np.ndarray, b: np.ndarray) -> tuple[float, float]:
    """Welch's t statistic + normal-approx two-sided p (n is large here)."""
    ma, mb = a.mean(), b.mean()
    va, vb = a.var(ddof=1) / len(a), b.var(ddof=1) / len(b)
    t = (ma - mb) / np.sqrt(va + vb + 1e-12)
    from math import erfc, sqrt
    p = erfc(abs(t) / sqrt(2.0))
    return float(t), float(p)
