"""Metric snapshots + history store (the paper's *metrics history file*).

The model protocol (paper §4.2.2) fixes the metric vector as
[CPU, RAM, NetIn, NetOut, Custom]; models predict all five, one is the *key
metric*.  ``MetricsHistory`` is the rolling store the Formulator appends to
and the Updater trains from (and clears, per the paper's update loop).
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import numpy as np

METRIC_NAMES = ("cpu", "ram", "net_in", "net_out", "custom")
N_METRICS = len(METRIC_NAMES)
KEY_CPU = 0
KEY_CUSTOM = 4  # e.g. request rate


@dataclasses.dataclass
class Snapshot:
    t: float
    values: np.ndarray  # (N_METRICS,)

    def __post_init__(self):
        self.values = np.asarray(self.values, np.float64)
        assert self.values.shape == (N_METRICS,)


class MetricsHistory:
    """Rolling metric store with optional on-disk persistence."""

    def __init__(self, path: str | Path | None = None, max_len: int = 1_000_000):
        self.path = Path(path) if path else None
        self.max_len = max_len
        self._t: list[float] = []
        self._rows: list[np.ndarray] = []
        if self.path and self.path.exists():
            data = json.loads(self.path.read_text())
            self._t = list(data["t"])
            self._rows = [np.asarray(r, np.float64) for r in data["rows"]]

    def append(self, snap: Snapshot):
        self.append_row(snap.t, snap.values)

    def append_row(self, t: float, values: np.ndarray):
        """``append`` without the Snapshot wrapper — the batched observe
        path (control_plane.observe_batch) records Z rows per tick and the
        per-row dataclass construction is measurable at Z >= 10^3."""
        self._t.append(float(t))
        self._rows.append(values)
        if len(self._rows) > self.max_len:
            self._t = self._t[-self.max_len:]
            self._rows = self._rows[-self.max_len:]

    def series(self) -> np.ndarray:
        """(T, N_METRICS) float64."""
        if not self._rows:
            return np.zeros((0, N_METRICS))
        return np.stack(self._rows)

    def times(self) -> np.ndarray:
        return np.asarray(self._t)

    def __len__(self):
        return len(self._rows)

    def clear(self):
        """The paper's Updater removes the history file after each update."""
        self._t, self._rows = [], []
        if self.path and self.path.exists():
            self.path.unlink()

    def save(self):
        if self.path:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self.path.write_text(json.dumps(
                {"t": self._t, "rows": [r.tolist() for r in self._rows]}))
