"""The Updater and its three model-update policies (paper §4.2.3):

  P1 NEVER     — keep the injected seed model for the whole run.
  P2 SCRATCH   — drop the old model each update loop, retrain from scratch
                 (same architecture) on the accumulated history.
  P3 FINETUNE  — continue training the old model for a few extra epochs on
                 the history collected since the last update.

After an update the Updater re-saves the model file and clears the metrics
history, exactly as in the paper's workflow (§4.1.2).

Beyond the paper's single-target loop, ``update_batch`` refits a whole
fleet of per-target models: homogeneous stacked LSTMs go through ONE
vmapped dispatch (``lstm_fit_batch_stacked``), heterogeneous model sets
fall back to sequential fits with identical bookkeeping.  The
``begin_update_batch`` / ``_PendingUpdate`` split lets the sharded control
plane run the compute phase on a worker thread off the tick critical path
(DESIGN.md §5).  ``model_path`` may be a per-target template containing
``{target}`` so Z targets persist to Z files instead of overwriting one.
"""
from __future__ import annotations

import enum
import time

from repro.core.forecaster import Forecaster, lstm_fit_batch_stacked
from repro.core.metrics import MetricsHistory


class UpdatePolicy(enum.Enum):
    NEVER = 1
    SCRATCH = 2
    FINETUNE = 3


class Updater:
    def __init__(self, policy: UpdatePolicy, model_path=None,
                 min_records: int = 16):
        self.policy = policy
        self.model_path = model_path
        self.min_records = min_records
        self.n_updates = 0
        self.last_update_t: float | None = None

    # ------------------------------------------------------------- paths --
    def path_for(self, target: str | None = None):
        """Resolve the save path for one target.  ``model_path`` may be a
        per-target template with a ``{target}`` placeholder, so per-target
        persistence writes Z files instead of Z targets overwriting one."""
        if not self.model_path:
            return None
        path = str(self.model_path)
        if "{target}" in path:
            if target is None:
                # a template only makes sense on the per-target path; a
                # silent 'None' filename would look like a good save
                raise ValueError("model_path template requires a target "
                                 "name (update(..., target=...))")
            return path.format(target=target)
        return path

    # ------------------------------------------------------ single target --
    def update(self, model: Forecaster, history: MetricsHistory,
               t: float | None = None, target: str | None = None
               ) -> Forecaster:
        if self.policy is UpdatePolicy.NEVER:
            history.clear()
            return model
        series = history.series()
        if len(series) < self.min_records:
            return model
        model.fit(series, from_scratch=(self.policy is UpdatePolicy.SCRATCH))
        if self.model_path:
            model.save(self.path_for(target))
        history.clear()
        self.n_updates += 1
        self.last_update_t = t if t is not None else time.time()
        return model

    # ------------------------------------------------------------ batched --
    def begin_update_batch(self, models: list[Forecaster],
                           histories: list[MetricsHistory],
                           t: float | None = None,
                           targets: list[str] | None = None):
        """Snapshot phase of a batched update: applies the policy gates,
        snapshots each eligible history's series and clears it (so samples
        arriving while the refit is in flight accumulate for the *next*
        cycle), and returns a ``_PendingUpdate`` — or ``None`` when nothing
        is due.  ``pending.compute()`` is thread-safe (mutates no model);
        ``pending.commit()`` installs the result and must run on the
        control thread."""
        if self.policy is UpdatePolicy.NEVER:
            for h in histories:
                h.clear()
            return None
        serieses = [h.series() for h in histories]
        idx = [i for i, s in enumerate(serieses)
               if len(s) >= self.min_records]
        if not idx:
            return None
        for i in idx:
            histories[i].clear()
        return _PendingUpdate(
            self, [models[i] for i in idx], [serieses[i] for i in idx],
            [targets[i] if targets else None for i in idx], t)

    def update_batch(self, models: list[Forecaster],
                     histories: list[MetricsHistory],
                     t: float | None = None,
                     targets: list[str] | None = None) -> list[Forecaster]:
        """Synchronous batched ``update``: P2/P3 refits of all eligible
        targets in one vmapped dispatch when the models stack, sequential
        fits otherwise.  Models are updated in place and returned."""
        pending = self.begin_update_batch(models, histories, t, targets)
        if pending is not None:
            pending.compute()
            pending.commit()
        return models


class _PendingUpdate:
    """A batched model update split into ``compute`` (worker-thread-safe:
    reads model params/scalers, mutates nothing) and ``commit`` (installs
    new params, saves, bumps counters — control thread only)."""

    def __init__(self, updater: Updater, models, serieses, targets, t):
        self.updater = updater
        self.models = models
        self.serieses = serieses
        self.targets = targets
        self.t = t
        self.from_scratch = updater.policy is UpdatePolicy.SCRATCH
        self.batched: bool | None = None   # set by compute()
        self._fit = None

    def compute(self):
        self._fit = lstm_fit_batch_stacked(
            self.models, self.serieses, self.from_scratch, apply=False)
        self.batched = self._fit is not None
        if self._fit is not None:
            self._fit.block_until_ready()
        return self

    def commit(self):
        if self.batched is None:
            self.compute()
        if self._fit is not None:
            self._fit.apply()
        else:
            # non-stackable (heterogeneous archs / unequal histories):
            # sequential fits, identical bookkeeping
            for m, s in zip(self.models, self.serieses):
                m.fit(s, from_scratch=self.from_scratch)
        u = self.updater
        if u.model_path:
            for m, tgt in zip(self.models, self.targets):
                m.save(u.path_for(tgt))
        u.n_updates += len(self.models)
        u.last_update_t = self.t if self.t is not None else time.time()
        return self.models
