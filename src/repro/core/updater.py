"""The Updater and its three model-update policies (paper §4.2.3):

  P1 NEVER     — keep the injected seed model for the whole run.
  P2 SCRATCH   — drop the old model each update loop, retrain from scratch
                 (same architecture) on the accumulated history.
  P3 FINETUNE  — continue training the old model for a few extra epochs on
                 the history collected since the last update.

After an update the Updater re-saves the model file and clears the metrics
history, exactly as in the paper's workflow (§4.1.2).
"""
from __future__ import annotations

import enum
import time

from repro.core.forecaster import Forecaster
from repro.core.metrics import MetricsHistory


class UpdatePolicy(enum.Enum):
    NEVER = 1
    SCRATCH = 2
    FINETUNE = 3


class Updater:
    def __init__(self, policy: UpdatePolicy, model_path=None,
                 min_records: int = 16):
        self.policy = policy
        self.model_path = model_path
        self.min_records = min_records
        self.n_updates = 0
        self.last_update_t: float | None = None

    def update(self, model: Forecaster, history: MetricsHistory,
               t: float | None = None) -> Forecaster:
        if self.policy is UpdatePolicy.NEVER:
            history.clear()
            return model
        series = history.series()
        if len(series) < self.min_records:
            return model
        model.fit(series, from_scratch=(self.policy is UpdatePolicy.SCRATCH))
        if self.model_path:
            model.save(self.model_path)
        history.clear()
        self.n_updates += 1
        self.last_update_t = t if t is not None else time.time()
        return model
