"""Static scaling policies (paper §4.2.1).

The default is the HPA threshold rule of Eq. (1):
    NumOfReplicas = ceil(CurrentMetricValue / PredefinedMetricValue)
applied to the *predicted* key metric.  Policies are injectable — any
callable (key_metric_value, state) -> int works, mirroring the paper's
customizable Static Policies.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

Policy = Callable[[float, dict], int]


@dataclasses.dataclass(frozen=True)
class ThresholdPolicy:
    """ceil(metric / threshold), clamped to [min_replicas, inf), with the
    same +-tolerance dead-band Kubernetes applies to HPA decisions (the PPA
    issues its requests through the same control plane)."""
    threshold: float
    min_replicas: int = 1
    tolerance: float = 0.1

    def __call__(self, key_metric: float, state: dict | None = None) -> int:
        cur = (state or {}).get("current", self.min_replicas)
        if not math.isfinite(key_metric):
            return max(cur, self.min_replicas)
        if cur > 0 and abs(key_metric / (self.threshold * cur) - 1.0) <= self.tolerance:
            return max(cur, self.min_replicas)
        n = math.ceil(max(key_metric, 0.0) / self.threshold)
        return max(n, self.min_replicas)


@dataclasses.dataclass(frozen=True)
class TargetUtilizationPolicy:
    """K8s-style: replicas = ceil(current * (util / target)); needs per-pod
    utilisation in state."""
    target: float  # e.g. 0.7 (70% of requested cpu)
    min_replicas: int = 1

    def __call__(self, util_ratio: float, state: dict | None = None) -> int:
        cur = (state or {}).get("current", self.min_replicas)
        if not math.isfinite(util_ratio) or util_ratio <= 0:
            return max(cur, self.min_replicas)
        return max(math.ceil(cur * util_ratio / self.target), self.min_replicas)


def make_policy(kind: str, **kw) -> Policy:
    if kind == "threshold":
        return ThresholdPolicy(**kw)
    if kind == "target":
        return TargetUtilizationPolicy(**kw)
    raise ValueError(kind)
