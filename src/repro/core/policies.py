"""Static scaling policies (paper §4.2.1).

The default is the HPA threshold rule of Eq. (1):
    NumOfReplicas = ceil(CurrentMetricValue / PredefinedMetricValue)
applied to the *predicted* key metric.  Policies are injectable — any
callable (key_metric_value, state) -> int works, mirroring the paper's
customizable Static Policies.

Columnar policy engine (DESIGN.md §6): every built-in policy also carries
a *vectorised* form — ``stack`` folds a group of same-type policy
instances into flat parameter arrays, and ``evaluate_batch`` answers a
whole ``(Z,)`` batch of (key metric, current replicas) pairs with numpy
arithmetic that is elementwise identical to ``__call__``.  The sharded
control plane groups each shard's targets by policy type and runs one
``evaluate_batch`` per *type* per tick (a dispatch table), so
heterogeneous policy sets cost O(#types) array programs instead of O(Z)
per-target Python calls.  Property tests in tests/test_columnar.py pin
batched == scalar over NaN/inf/negative inputs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

Policy = Callable[[float, dict], int]

# replica-count ceiling applied before the int64 cast: a huge-but-finite
# forecast would otherwise overflow the cast (undefined, can go negative);
# decisions are min()'d with max_replicas right after, so any clamp far
# above real fleet sizes is decision-equivalent to the scalar path
_N_CLAMP = float(2**62)


def _as_int_replicas(n: np.ndarray) -> np.ndarray:
    return np.minimum(n, _N_CLAMP).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class ThresholdPolicy:
    """ceil(metric / threshold), clamped to [min_replicas, inf), with the
    same +-tolerance dead-band Kubernetes applies to HPA decisions (the PPA
    issues its requests through the same control plane)."""
    threshold: float
    min_replicas: int = 1
    tolerance: float = 0.1

    def __call__(self, key_metric: float, state: dict | None = None) -> int:
        cur = (state or {}).get("current", self.min_replicas)
        if not math.isfinite(key_metric):
            return max(cur, self.min_replicas)
        if cur > 0 and abs(key_metric / (self.threshold * cur) - 1.0) <= self.tolerance:
            return max(cur, self.min_replicas)
        n = math.ceil(max(key_metric, 0.0) / self.threshold)
        return max(n, self.min_replicas)

    # ------------------------------------------------- columnar fast path --
    @staticmethod
    def stack(policies: list["ThresholdPolicy"]) -> dict:
        """Fold a group of ThresholdPolicy instances into flat arrays for
        ``evaluate_batch`` (the control plane stacks once at construction)."""
        return {
            "threshold": np.array([p.threshold for p in policies], np.float64),
            "min_replicas": np.array([p.min_replicas for p in policies],
                                     np.int64),
            "tolerance": np.array([p.tolerance for p in policies], np.float64),
        }

    @staticmethod
    def evaluate_batch(stacked: dict, key: np.ndarray, cur: np.ndarray
                       ) -> np.ndarray:
        """Vectorised ``__call__`` over (Z,) key-metric / current-replica
        arrays — elementwise identical to the scalar rule, dead-band and
        non-finite fallback included."""
        thr, minr = stacked["threshold"], stacked["min_replicas"]
        tol = stacked["tolerance"]
        with np.errstate(divide="ignore", invalid="ignore"):
            dead = (cur > 0) & (np.abs(key / (thr * cur) - 1.0) <= tol)
        n = np.maximum(np.ceil(np.maximum(key, 0.0) / thr), minr)
        return _as_int_replicas(np.where(dead | ~np.isfinite(key),
                                         np.maximum(cur, minr), n))


@dataclasses.dataclass(frozen=True)
class TargetUtilizationPolicy:
    """K8s-style: replicas = ceil(current * (util / target)); needs per-pod
    utilisation in state."""
    target: float  # e.g. 0.7 (70% of requested cpu)
    min_replicas: int = 1

    def __call__(self, util_ratio: float, state: dict | None = None) -> int:
        cur = (state or {}).get("current", self.min_replicas)
        if not math.isfinite(util_ratio) or util_ratio <= 0:
            return max(cur, self.min_replicas)
        return max(math.ceil(cur * util_ratio / self.target), self.min_replicas)

    # ------------------------------------------------- columnar fast path --
    @staticmethod
    def stack(policies: list["TargetUtilizationPolicy"]) -> dict:
        return {
            "target": np.array([p.target for p in policies], np.float64),
            "min_replicas": np.array([p.min_replicas for p in policies],
                                     np.int64),
        }

    @staticmethod
    def evaluate_batch(stacked: dict, key: np.ndarray, cur: np.ndarray
                       ) -> np.ndarray:
        tgt, minr = stacked["target"], stacked["min_replicas"]
        with np.errstate(invalid="ignore"):
            n = np.maximum(np.ceil(cur * key / tgt), minr)
        reactive = ~np.isfinite(key) | (key <= 0)
        return _as_int_replicas(np.where(reactive, np.maximum(cur, minr), n))


def policy_vectorizable(policy) -> bool:
    """True when ``policy``'s *type* carries the columnar protocol
    (``stack`` + ``evaluate_batch``) — the sharded plane's dispatch-table
    eligibility check.  Instances of subclasses qualify only if they
    define their own pair (an overridden ``__call__`` with inherited batch
    arithmetic would silently diverge)."""
    cls = type(policy)
    if cls in (ThresholdPolicy, TargetUtilizationPolicy):
        return True
    return ("stack" in cls.__dict__ and "evaluate_batch" in cls.__dict__
            and callable(cls.__dict__["stack"])
            and callable(cls.__dict__["evaluate_batch"]))


def make_policy(kind: str, **kw) -> Policy:
    if kind == "threshold":
        return ThresholdPolicy(**kw)
    if kind == "target":
        return TargetUtilizationPolicy(**kw)
    raise ValueError(kind)
