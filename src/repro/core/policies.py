"""Static scaling policies (paper §4.2.1) and the SLA / guardrail family.

The default is the HPA threshold rule of Eq. (1):
    NumOfReplicas = ceil(CurrentMetricValue / PredefinedMetricValue)
applied to the *predicted* key metric.  Policies are injectable — any
callable (key_metric_value, state) -> int works, mirroring the paper's
customizable Static Policies.

Columnar policy engine (DESIGN.md §6): every built-in policy also carries
a *vectorised* form — ``stack`` folds a group of same-type policy
instances into flat parameter arrays, and ``evaluate_batch`` answers a
whole ``(Z,)`` batch of (key metric, current replicas) pairs with numpy
arithmetic that is elementwise identical to ``__call__``.  The sharded
control plane groups each shard's targets by policy type and runs one
``evaluate_batch`` per *type* per tick (a dispatch table), so
heterogeneous policy sets cost O(#types) array programs instead of O(Z)
per-target Python calls.  Property tests in tests/test_columnar.py pin
batched == scalar over NaN/inf/negative inputs.

Two additions beyond the paper (DESIGN.md §10, docs/guardrail.md):

* :class:`SLAPolicy` — an SLA-constrained policy in the style of the
  Gupta et al. edge-autoscaling work: the key metric is a windowed p95
  response latency (fed from the serving sim's ``CompletionLog``, see
  ``serving/fleet.py``) and the policy scales multiplicatively toward a
  latency *objective* instead of a utilisation setpoint.  It speaks the
  same ``stack``/``evaluate_batch`` protocol, so 10³⁺ SLA-governed
  targets stay on the columnar shard / device-mesh path.
* :class:`GuardrailConfig` — parameters for the reactive guardrail
  stage (collect→formulate→forecast→evaluate→**guard**→actuate) that
  overrides a proactive decision when realised load diverges from the
  forecast the decision acted on.  The stage itself lives in
  ``core/control_plane.py`` (scalar :class:`~repro.core.control_plane.
  Guardrail` oracle + the vectorised shard form).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

Policy = Callable[[float, dict], int]

# replica-count ceiling applied before the int64 cast: a huge-but-finite
# forecast would otherwise overflow the cast (undefined, can go negative);
# decisions are min()'d with max_replicas right after, so any clamp far
# above real fleet sizes is decision-equivalent to the scalar path
_N_CLAMP = float(2**62)


def _as_int_replicas(n: np.ndarray) -> np.ndarray:
    return np.minimum(n, _N_CLAMP).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class ThresholdPolicy:
    """ceil(metric / threshold), clamped to [min_replicas, inf), with the
    same +-tolerance dead-band Kubernetes applies to HPA decisions (the PPA
    issues its requests through the same control plane)."""
    threshold: float
    min_replicas: int = 1
    tolerance: float = 0.1

    def __call__(self, key_metric: float, state: dict | None = None) -> int:
        cur = (state or {}).get("current", self.min_replicas)
        if not math.isfinite(key_metric):
            return max(cur, self.min_replicas)
        if cur > 0 and abs(key_metric / (self.threshold * cur) - 1.0) <= self.tolerance:
            return max(cur, self.min_replicas)
        n = math.ceil(max(key_metric, 0.0) / self.threshold)
        return max(n, self.min_replicas)

    # ------------------------------------------------- columnar fast path --
    @staticmethod
    def stack(policies: list["ThresholdPolicy"]) -> dict:
        """Fold a group of ThresholdPolicy instances into flat arrays for
        ``evaluate_batch`` (the control plane stacks once at construction)."""
        return {
            "threshold": np.array([p.threshold for p in policies], np.float64),
            "min_replicas": np.array([p.min_replicas for p in policies],
                                     np.int64),
            "tolerance": np.array([p.tolerance for p in policies], np.float64),
        }

    @staticmethod
    def evaluate_batch(stacked: dict, key: np.ndarray, cur: np.ndarray
                       ) -> np.ndarray:
        """Vectorised ``__call__`` over (Z,) key-metric / current-replica
        arrays — elementwise identical to the scalar rule, dead-band and
        non-finite fallback included."""
        thr, minr = stacked["threshold"], stacked["min_replicas"]
        tol = stacked["tolerance"]
        with np.errstate(divide="ignore", invalid="ignore"):
            dead = (cur > 0) & (np.abs(key / (thr * cur) - 1.0) <= tol)
        n = np.maximum(np.ceil(np.maximum(key, 0.0) / thr), minr)
        return _as_int_replicas(np.where(dead | ~np.isfinite(key),
                                         np.maximum(cur, minr), n))


@dataclasses.dataclass(frozen=True)
class TargetUtilizationPolicy:
    """K8s-style: replicas = ceil(current * (util / target)); needs per-pod
    utilisation in state."""
    target: float  # e.g. 0.7 (70% of requested cpu)
    min_replicas: int = 1

    def __call__(self, util_ratio: float, state: dict | None = None) -> int:
        cur = (state or {}).get("current", self.min_replicas)
        if not math.isfinite(util_ratio) or util_ratio <= 0:
            return max(cur, self.min_replicas)
        return max(math.ceil(cur * util_ratio / self.target), self.min_replicas)

    # ------------------------------------------------- columnar fast path --
    @staticmethod
    def stack(policies: list["TargetUtilizationPolicy"]) -> dict:
        """Fold same-type instances into flat parameter arrays."""
        return {
            "target": np.array([p.target for p in policies], np.float64),
            "min_replicas": np.array([p.min_replicas for p in policies],
                                     np.int64),
        }

    @staticmethod
    def evaluate_batch(stacked: dict, key: np.ndarray, cur: np.ndarray
                       ) -> np.ndarray:
        """Whole-batch ``__call__`` — elementwise identical, including
        the reactive hold on missing signal."""
        tgt, minr = stacked["target"], stacked["min_replicas"]
        with np.errstate(invalid="ignore"):
            n = np.maximum(np.ceil(cur * key / tgt), minr)
        reactive = ~np.isfinite(key) | (key <= 0)
        return _as_int_replicas(np.where(reactive, np.maximum(cur, minr), n))


@dataclasses.dataclass(frozen=True)
class SLAPolicy:
    """SLA-constrained policy: scale toward a p95-latency objective.

    The key metric is a windowed p95 response latency (seconds) rather
    than a utilisation/throughput setpoint — the serving sim publishes it
    per control window from its ``CompletionLog`` (metric slot 1, see
    ``ServingFleet.sample``).  Semantics, after Gupta et al.'s
    SLA-constrained edge autoscaler:

    * ``p95 > target_p95``      → scale up ``ceil(cur * p95/target_p95)``
      (multiplicative, under the M/M/c-style assumption that latency
      scales roughly inversely with replica count near saturation);
    * ``p95 < down_margin*target_p95`` → scale down
      ``ceil(cur * ratio / down_margin)`` — proportional, but anchored to
      the *margin* rather than the target so the policy lands safely
      inside the hold band instead of oscillating around the objective;
    * otherwise (inside the band, or no signal: non-finite / ``<= 0``
      p95, e.g. an idle window) → hold.

    ``evaluate_batch`` is elementwise identical to ``__call__`` so
    Z=10³⁺ SLA targets ride the columnar shard and device-mesh path.
    """
    target_p95: float
    min_replicas: int = 1
    down_margin: float = 0.7

    def __call__(self, p95: float, state: dict | None = None) -> int:
        cur = (state or {}).get("current", self.min_replicas)
        if not math.isfinite(p95) or p95 <= 0:
            return max(cur, self.min_replicas)
        ratio = p95 / self.target_p95
        if ratio > 1.0:
            n = math.ceil(cur * ratio)
        elif ratio < self.down_margin:
            n = math.ceil(cur * ratio / self.down_margin)
        else:
            n = cur
        return max(n, self.min_replicas)

    # ------------------------------------------------- columnar fast path --
    @staticmethod
    def stack(policies: list["SLAPolicy"]) -> dict:
        """Fold a group of SLAPolicy instances into flat parameter arrays
        for ``evaluate_batch``."""
        return {
            "target_p95": np.array([p.target_p95 for p in policies],
                                   np.float64),
            "min_replicas": np.array([p.min_replicas for p in policies],
                                     np.int64),
            "down_margin": np.array([p.down_margin for p in policies],
                                    np.float64),
        }

    @staticmethod
    def evaluate_batch(stacked: dict, key: np.ndarray, cur: np.ndarray
                       ) -> np.ndarray:
        """Vectorised ``__call__`` over (Z,) p95 / current-replica arrays
        — elementwise identical to the scalar rule, hold band and
        no-signal fallback included."""
        tgt, minr = stacked["target_p95"], stacked["min_replicas"]
        margin = stacked["down_margin"]
        with np.errstate(divide="ignore", invalid="ignore"):
            ratio = key / tgt
            n_up = np.ceil(cur * ratio)
            n_down = np.ceil(cur * ratio / margin)
        n = np.where(ratio > 1.0, n_up,
                     np.where(ratio < margin, n_down, cur))
        hold = ~np.isfinite(key) | (key <= 0)
        return _as_int_replicas(np.maximum(np.where(hold, cur, n), minr))


@dataclasses.dataclass(frozen=True)
class GuardrailConfig:
    """Parameters for the reactive guardrail stage (DESIGN.md §10).

    The guard compares the *realised* key metric of the current tick
    against the forecast the previous decision acted on; the relative
    error is ``(realised - predicted) / max(|predicted|, eps)``.  While
    the error stays inside ``[-band, +band]`` the proactive decision
    passes through untouched (and, when the guard is quiet, the stage
    costs a handful of vector compares — see the ``guardrail_overhead``
    bench lane).  Outside the band the guard overrides the decision with
    a threshold-style reactive correction re-evaluated on the realised
    metric:

    * **Scale-up fast path** (``err > band`` — forecast undershot, e.g.
      a flash crowd): override immediately with
      ``policy(realised * headroom)``, taking the max against the
      proactive decision so the guard never scales *below* the plan.
    * **Stabilised scale-down** (``err < -band`` — forecast overshot):
      only after ``down_ticks`` *consecutive* overshooting ticks, and
      taking the min against the proactive decision.  The consecutive-
      tick counter is the reactive analogue of the proactive path's
      ``ScaleDownStabilizer``; guard corrections deliberately do NOT
      enter that stabiliser's ring, so a reactive trim cannot suppress
      later proactive scale-downs.

    ``headroom`` > 1 over-provisions the reactive scale-up (the usual
    hybrid-autoscaler safety factor); ``eps`` floors the denominator so
    a near-zero forecast still yields a finite error.
    """
    band: float = 0.25
    headroom: float = 1.0
    down_ticks: int = 3
    eps: float = 1e-9


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Degraded-mode knobs for the control plane (DESIGN.md §13,
    docs/resilience.md).  Defaults are all-off sentinels so a config with
    ``resilience=None`` *or* a default instance changes nothing.

    * ``stale_ttl_s`` — per-target metric freshness TTL: once a target's
      last *fresh* observation is older than this, it drops out of the
      forecast batch (NaN-masked candidacy), its decision **holds** the
      current replica count (the Kubernetes missing-metrics rule: never
      act on data you do not have), and its guardrail idles for the tick.
    * ``forecast_deadline_s`` — wall-clock budget for the fused forecast
      dispatch; an overrun discards the forecast and serves the whole
      tick reactively instead of blocking actuation on a stalled model.
    * ``snapshot_every`` — shard-state snapshot cadence in ticks (0 =
      never): ring + counters + stabilizer + guard state, cheap copies a
      crashed shard restores from with bounded staleness.
    """
    stale_ttl_s: float = math.inf
    forecast_deadline_s: float = math.inf
    snapshot_every: int = 0


def policy_vectorizable(policy) -> bool:
    """True when ``policy``'s *type* carries the columnar protocol
    (``stack`` + ``evaluate_batch``) — the sharded plane's dispatch-table
    eligibility check.  Instances of subclasses qualify only if they
    define their own pair (an overridden ``__call__`` with inherited batch
    arithmetic would silently diverge)."""
    cls = type(policy)
    if cls in (ThresholdPolicy, TargetUtilizationPolicy, SLAPolicy):
        return True
    return ("stack" in cls.__dict__ and "evaluate_batch" in cls.__dict__
            and callable(cls.__dict__["stack"])
            and callable(cls.__dict__["evaluate_batch"]))


def make_policy(kind: str, **kw) -> Policy:
    """Build a built-in policy by name: ``"threshold"``, ``"target"``
    (utilisation) or ``"sla"`` (p95 objective)."""
    if kind == "threshold":
        return ThresholdPolicy(**kw)
    if kind == "target":
        return TargetUtilizationPolicy(**kw)
    if kind == "sla":
        return SLAPolicy(**kw)
    raise ValueError(kind)
