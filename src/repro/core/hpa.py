"""Reactive Horizontal Pod Autoscaler — the paper's baseline (Eq. 1).

    NumOfReplicas = ceil(CurrentMetricValue / PredefinedMetricValue)

Includes the two stock Kubernetes behaviours that matter for fidelity:
a +-`tolerance` dead-band around the current desired value and a
scale-down stabilization window (downscale uses the max recommendation
over the trailing window).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass
class HPA:
    threshold: float
    key_metric_idx: int = 0
    min_replicas: int = 1
    tolerance: float = 0.1
    stabilization_s: float = 300.0
    # Stock HPA consumes metrics via metrics-server / prometheus-adapter;
    # scrape + aggregation makes its view 1-2 control windows stale
    # (k8s v1.20 defaults: 15 s sync + 30 s metric resolution).  The PPA
    # (built on the Custom Pod Autoscaler) fetches from the adapter directly
    # each loop and patches the scale subresource without behaviour gating.
    staleness_windows: int = 2
    # k8s v1.20 default scaleUp behaviour: at most max(4 pods, 100%) per
    # stabilization period — HPA cannot jump straight to a burst's demand.
    max_scale_up_pods: int = 4
    max_scale_up_factor: float = 2.0

    def __post_init__(self):
        self._recs: list[tuple[float, int]] = []

    def decide(self, t: float, recent: np.ndarray, max_replicas: int,
               current_replicas: int) -> int:
        idx = max(-self.staleness_windows - 1, -len(recent))
        metric = float(recent[idx, self.key_metric_idx])
        desired = max(self.min_replicas,
                      math.ceil(max(metric, 0.0) / self.threshold))
        # tolerance dead-band (k8s: skip scaling if |ratio - 1| < tolerance)
        if current_replicas > 0:
            ratio = metric / (self.threshold * current_replicas)
            if abs(ratio - 1.0) <= self.tolerance:
                desired = current_replicas
        self._recs.append((t, desired))
        self._recs = [(tt, d) for tt, d in self._recs
                      if tt >= t - self.stabilization_s]
        if desired < current_replicas:  # scale-down stabilization
            desired = max(d for _, d in self._recs)
        if desired > current_replicas:  # scale-up rate limiting
            cap = max(current_replicas + self.max_scale_up_pods,
                      int(current_replicas * self.max_scale_up_factor))
            desired = min(desired, cap)
        return min(max(desired, self.min_replicas), max_replicas)
