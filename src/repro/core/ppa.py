"""The Proactive Pod Autoscaler: Formulator -> Evaluator -> scale request,
plus the model-update loop (paper §4.1, Fig. 4).

The PPA is scaling-target-agnostic: it receives metric snapshots from any
metric source (the simulated Prometheus adapter of repro.cluster, or the
serving fleet's own exporter) and emits desired replica counts.  The target
(`ScaleTarget`) applies them — Kubernetes worker pods in the faithful
reproduction, TPU decode replica groups in the serving integration.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.evaluator import Evaluator, EvalResult
from repro.core.forecaster import Forecaster
from repro.core.metrics import MetricsHistory, Snapshot
from repro.core.policies import GuardrailConfig, Policy, ResilienceConfig
from repro.core.updater import Updater


@dataclasses.dataclass
class PPAConfig:
    control_interval_s: float = 15.0      # paper: ControlInterval
    update_interval_s: float = 3600.0     # paper: UpdateInterval (1 h in §5.3.2)
    key_metric_idx: int = 0               # KeyMetric (0 = CPU)
    threshold: float = 500.0              # Threshold on the key metric
    confidence_threshold: float = math.inf
    min_replicas: int = 1
    # Kubernetes applies its scale-down stabilization behaviour to any
    # autoscaler's requests (HPA gets the same); proactivity acts on the
    # up-scaling side where the startup latency lives.
    stabilization_s: float = 300.0
    # hybrid reactive-proactive guardrail (DESIGN.md §10): None = purely
    # proactive (the paper's PPA); a GuardrailConfig arms the guard stage
    # in FleetController / ShardedControlPlane (the scalar PPA below stays
    # paper-faithful and ignores it)
    guard: GuardrailConfig | None = None
    # degraded-mode handling (DESIGN.md §13, docs/resilience.md): None =
    # trust every metric and wait forever for forecasts (the paper's
    # assumption); a ResilienceConfig arms stale-metric TTL fallback, the
    # forecast deadline and shard snapshot/failover in FleetController /
    # ShardedControlPlane (the scalar PPA below stays paper-faithful)
    resilience: ResilienceConfig | None = None
    # forecaster selection (the paper's ModelType): a ``make_forecaster``
    # kind plus its constructor kwargs.  Scenario drivers that build one
    # model per target call ``build_forecaster()`` instead of hard-coding
    # a class, so switching the zoo entry ("lstm" / "attn" / "arma" /
    # "arima_d1" / "ensemble") is a config change
    forecaster: str = "lstm"
    forecaster_kw: dict = dataclasses.field(default_factory=dict)

    def build_forecaster(self) -> Forecaster:
        """Instantiate this config's forecaster (``make_forecaster``)."""
        from repro.core.forecaster import make_forecaster
        return make_forecaster(self.forecaster, **self.forecaster_kw)


class ScaleDownStabilizer:
    """Kubernetes scale-down stabilization: a downscale request is clamped
    to the max recommendation over the trailing window.  Factored out of
    PPA so the batched FleetController applies the identical behaviour
    per target (core/controller.py)."""

    def __init__(self, window_s: float):
        self.window_s = window_s
        self._recs: list[tuple[float, int]] = []

    def apply(self, t: float, desired: int, current_replicas: int,
              max_replicas: int) -> int:
        self._recs.append((t, desired))
        self._recs = [(tt, d) for tt, d in self._recs
                      if tt >= t - self.window_s]
        if desired < current_replicas:
            desired = min(max(d for _, d in self._recs), max_replicas)
        return desired


class PPA:
    """One PPA instance per scaling target (per zone, per serving pool)."""

    def __init__(self, cfg: PPAConfig, model: Forecaster, policy: Policy,
                 updater: Updater, history: MetricsHistory | None = None):
        self.cfg = cfg
        self.model = model
        self.policy = policy
        self.updater = updater
        self.history = history or MetricsHistory()
        self.evaluator = Evaluator(policy, cfg.key_metric_idx,
                                   cfg.confidence_threshold)
        self._recent: list[np.ndarray] = []
        self._last_update_t = 0.0
        self.decisions: list[EvalResult] = []
        self.predictions: list[tuple[float, np.ndarray]] = []  # for MSE eval
        self.stabilizer = ScaleDownStabilizer(cfg.stabilization_s)

    # ---------------------------------------------------------- formulator -
    def observe(self, snap: Snapshot):
        """Formulator: extract + store metrics (control-loop step 1)."""
        self.history.append(snap)
        self._recent.append(snap.values)
        self._recent = self._recent[-max(self.model.window + 1, 8):]

    # -------------------------------------------------------- control loop -
    def control_step(self, t: float, max_replicas: int,
                     current_replicas: int) -> EvalResult:
        recent = np.stack(self._recent) if self._recent else np.zeros((1, 5))
        res = self.evaluator.evaluate(recent, self.model, max_replicas,
                                      current_replicas)
        if res.raw_prediction is not None:
            self.predictions.append((t, res.raw_prediction))
        # scale-down stabilization (k8s behaviour layer)
        res.replicas = self.stabilizer.apply(t, res.replicas,
                                             current_replicas, max_replicas)
        self.decisions.append(res)
        return res

    # --------------------------------------------------------- update loop -
    def maybe_update(self, t: float):
        if t - self._last_update_t >= self.cfg.update_interval_s:
            self.model = self.updater.update(self.model, self.history, t)
            self._last_update_t = t

    # --------------------------------------------------------- evaluation --
    def prediction_mse(self, actual_series: np.ndarray,
                       actual_times: np.ndarray,
                       metric_idx: int | None = None) -> float:
        """MSE between one-step-ahead predictions and realised metrics
        (paper Figs. 7-8).  Predictions at time t target the next sample."""
        if not self.predictions:
            return float("nan")
        idx = self.cfg.key_metric_idx if metric_idx is None else metric_idx
        errs = []
        for t, pred in self.predictions:
            j = np.searchsorted(actual_times, t, side="right")
            if j < len(actual_series):
                errs.append((pred[idx] - actual_series[j, idx]) ** 2)
        return float(np.mean(errs)) if errs else float("nan")
