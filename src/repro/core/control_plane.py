"""Staged, sharded, async control plane (DESIGN.md §5, "Sharded async").

PR 1 batched Z targets into one forecast dispatch per tick, but the tick
itself stayed a monolithic synchronous function and model refits stalled
the whole loop — capping the control plane near ~10^3 targets.  This module
splits the tick into explicit stages

    collect -> formulate -> batched forecast -> evaluate -> guard -> actuate

shared by ``FleetController`` (which now composes them, core/controller.py)
and the ``ShardedControlPlane`` below, which takes the plane past 10^3
targets.  The ``guard`` stage is the hybrid reactive-proactive layer
(DESIGN.md §10, docs/guardrail.md): armed with ``PPAConfig.guard``
(a :class:`~repro.core.policies.GuardrailConfig`), each tick compares the
realised key metric against the forecast the *previous* decision acted on
and, when the relative error leaves the configured band, overrides the
proactive decision with a threshold-style reactive correction — a
scale-up fast path for forecast undershoot (flash crowds) and a
consecutive-tick-stabilised trim for sustained overshoot.  The scalar
:class:`Guardrail` below is the semantics oracle; ``_VecShard`` carries
the elementwise-identical vectorised form so guarded planes stay on the
columnar shard / device-mesh path (guard state is per-shard arrays that
ride the shard views).

The sharded plane scales the staged tick past 10^3 targets with:

* **sharding** — targets are partitioned across S controller shards by a
  deterministic crc32 hash (NOT Python's per-process-salted ``hash``) or an
  explicit assignment map; each shard forecasts on stacked (Z/S, W, M)
  tensors over columnar host state (ring-buffered metric windows,
  vectorised scaler / ScaleDownStabilizer arithmetic, and a per-policy
  dispatch table — one ``Policy.evaluate_batch`` per policy *type* per
  tick), so a tick costs O(S) array programs instead of O(Z) per-target
  object calls even for heterogeneous policy sets;
* **double-buffered async ticks** — ``begin_tick`` snapshots each shard's
  formulated windows and dispatches its forecast on a worker pool; the
  driver keeps collecting window-(t+1) metrics while window-t forecasts are
  in flight, and ``finish_tick`` is the only barrier (at actuation);
* **off-critical-path refits** — ``maybe_update`` snapshots histories and
  submits ONE vmapped batch fit for all Z per-target LSTMs
  (``lstm_fit_batch_stacked``) to the pool; finished fits are installed
  between ticks (``poll_updates``), so P2/P3 updates never stall the loop.

Decision semantics are identical to ``FleetController`` by construction:
the vectorised fast path reproduces ``Evaluator.decide_from_prediction`` +
each policy's scalar ``__call__`` + ``ScaleDownStabilizer`` elementwise,
and the few shards whose targets still don't vectorise (heterogeneous
models, custom policy callables without the ``stack``/``evaluate_batch``
protocol) fall back to an embedded ``FleetController``.
``tests/test_sharded_plane`` asserts seeded decision equivalence for any
shard count, async on or off.
"""
from __future__ import annotations

import collections.abc as cabc
import dataclasses
import time
import zlib
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FuturesTimeout

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.evaluator import EvalResult
from repro.core.forecaster import (LSTMForecaster, _lstm_forward_stacked,
                                   lstm_stack_signature, stack_params,
                                   stack_scaler_stats, transform_stacked)
from repro.core.metrics import N_METRICS, MetricsHistory, Snapshot
from repro.core.policies import policy_vectorizable

# ======================================================================= #
#  The staged tick pipeline (composed by FleetController and the shards)  #
# ======================================================================= #


@dataclasses.dataclass
class Tick:
    """Context flowing through one control tick's stages."""
    t: float
    names: list[str]
    max_r: dict[str, int]
    cur_r: dict[str, int]
    recents: dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    preds: dict = dataclasses.field(default_factory=dict)
    results: dict[str, EvalResult] = dataclasses.field(default_factory=dict)
    # targets whose metrics are past the resilience TTL this tick — they
    # skip the forecast batch, hold their replica count (stage_degrade)
    # and idle their guardrail (DESIGN.md §13); empty when resilience off
    stale: set = dataclasses.field(default_factory=set)


def as_replica_map(val, names) -> dict[str, int]:
    """Broadcast a scalar replica bound to every target.  An ndarray is
    taken positionally in ``names`` order (the columnar federation driver
    passes (F,) bound arrays, DESIGN.md §12)."""
    if isinstance(val, dict):
        return {n: int(val[n]) for n in names}
    if isinstance(val, np.ndarray):
        if len(val) != len(names):
            raise ValueError("replica bound array length != target count")
        return {n: int(v) for n, v in zip(names, val)}
    return {n: int(val) for n in names}


def validate_targets(targets, model, updater) -> bool:
    """Shared constructor validation for ``FleetController`` and
    ``ShardedControlPlane``; returns the per-target-models flag."""
    if not targets:
        raise ValueError("control plane needs at least one target")
    per_target = [t.model is not None for t in targets]
    if any(per_target) and not all(per_target):
        raise ValueError("either every target has its own model "
                         "(per-target mode) or none does (shared mode)")
    per_target_models = all(per_target)
    if not per_target_models and model is None:
        raise ValueError("shared mode needs a model")
    path = getattr(updater, "model_path", None) if updater else None
    if per_target_models and path and "{target}" not in str(path):
        # one shared path would make Z targets overwrite each other's
        # saved weights (Updater.path_for resolves the template)
        raise ValueError("per-target mode needs a per-target model_path "
                         "template (use a '{target}' placeholder), not "
                         "one shared path")
    return per_target_models


def stage_collect(ctrl, exporter, groups=None, cursors=None) -> dict:
    """Pull newly exported samples into the controller's history via the
    exporter's cursor API (``WindowedExporter.read_new``) — pure reads over
    the append-only samples log, so an async tick can keep collecting while
    the previous window's forecast is in flight.  Returns the advanced
    cursors (pass them back on the next call)."""
    groups = list(groups) if groups is not None else list(ctrl.target_names)
    cursors = {} if cursors is None else cursors
    for g in groups:
        new, cursors[g] = exporter.read_new(g, cursors.get(g, 0))
        for ts, row in new:
            ctrl.observe(g, Snapshot(float(ts), np.asarray(row, np.float64)))
    return cursors


def stage_formulate(ctrl, tick: Tick) -> Tick:
    """Stack each target's recent metric rows into its forecast window."""
    for n in tick.names:
        st = ctrl.targets[n]
        tick.recents[n] = (np.stack(st.recent) if st.recent
                           else np.zeros((1, N_METRICS)))
    return tick


def stage_forecast(ctrl, tick: Tick) -> Tick:
    """One batched forecast dispatch for every predictable target.
    Targets past the stale-metric TTL drop out of the forecast batch
    entirely (the scalar twin of the shard's NaN-masked candidacy)."""
    if hasattr(ctrl, "_stale_names"):
        tick.stale = ctrl._stale_names(tick.t)
    names = (tick.names if not tick.stale
             else [n for n in tick.names if n not in tick.stale])
    tick.preds = ctrl._predict_all(names, tick.recents)
    return tick


def stage_evaluate(ctrl, tick: Tick) -> Tick:
    """Algorithm 1's decision half + scale-down stabilization per target."""
    for n in tick.names:
        st = ctrl.targets[n]
        mean, std, bayes = tick.preds.get(n, (None, None, False))
        res = ctrl._evaluators[n].decide_from_prediction(
            tick.recents[n], mean, std, bayes, tick.max_r[n], tick.cur_r[n])
        if res.raw_prediction is not None:
            st.predictions.append((tick.t, res.raw_prediction))
        res.replicas = st.stabilizer.apply(tick.t, res.replicas,
                                           tick.cur_r[n], tick.max_r[n])
        st.decisions.append(res)
        tick.results[n] = res
    return tick


class Guardrail:
    """Scalar reactive guardrail for ONE target — the semantics oracle the
    vectorised shard form (``_VecShard._guard_apply``) is property-tested
    against (tests/test_guardrail.py).

    Per tick, ``apply`` compares the realised key metric against the
    forecast the previous decision acted on (``prev_key``, armed by
    ``arm``; NaN = previous tick was reactive / first tick → guard idle)
    and overrides the proactive decision when the relative error leaves
    ``cfg.band``:

    * ``err > band`` (undershoot): immediate reactive scale-up —
      ``min(max(proactive, policy(realised*headroom)), max_replicas)``;
    * ``err < -band`` (overshoot): after ``cfg.down_ticks`` *consecutive*
      overshooting ticks, reactive trim
      ``min(proactive, policy(realised*headroom))``;
    * in-band / idle: pass through (and reset the consecutive counter).

    Corrections never enter the proactive ``ScaleDownStabilizer`` ring, so
    a reactive trim cannot suppress later proactive scale-downs."""

    def __init__(self, cfg, policy):
        self.cfg = cfg
        self.policy = policy
        self.prev_key = float("nan")
        self.down_ct = 0
        self.up_fired = 0
        self.down_fired = 0

    def apply(self, realised: float, proactive: int, cur: int,
              max_replicas: int) -> int:
        """Return the guarded replica count for this tick."""
        g = self.cfg
        prev = self.prev_key
        if not np.isfinite(prev):
            self.down_ct = 0
            return proactive
        err = (realised - prev) / max(abs(prev), g.eps)
        if err > g.band:
            self.down_ct = 0
            n_react = self.policy(realised * g.headroom, {"current": cur})
            self.up_fired += 1
            return min(max(proactive, int(n_react)), max_replicas)
        if err < -g.band:
            self.down_ct += 1
            if self.down_ct >= g.down_ticks:
                self.down_ct = 0
                n_react = self.policy(realised * g.headroom,
                                      {"current": cur})
                self.down_fired += 1
                return min(proactive, int(n_react))
            return proactive
        self.down_ct = 0
        return proactive

    def arm(self, key: float):
        """Record the forecast this tick's decision acted on (NaN when the
        decision was reactive — the next tick's guard then stays idle)."""
        self.prev_key = float(key)


def stage_degrade(ctrl, tick: Tick) -> Tick:
    """Degraded-mode hold (between evaluate and guard, DESIGN.md §13):
    a stale target's decision is pinned to the last decision made on
    fresh metrics — the Kubernetes missing-metrics rule: keep the
    desired replica count, never scale on data you do not have.
    Holding at the *current* count instead would ratchet a blacked-out
    fleet down as node failures eat its live replicas.  Falls back to
    the current count before any fresh decision exists.  No-op when
    nothing is stale (resilience off / all fresh)."""
    last = getattr(ctrl, "_deg_last", None) or {}
    for n in tick.stale:
        tick.results[n].replicas = last.get(n, tick.cur_r[n])
    if tick.stale and hasattr(ctrl, "_deg_stale"):
        ctrl._deg_stale += len(tick.stale)
    return tick


def stage_guard(ctrl, tick: Tick) -> Tick:
    """Reactive guardrail stage (between evaluate and actuate): override
    each guarded target's decision when realised load left the error band
    of the forecast the previous decision acted on, then arm the guard
    with this tick's forecast.  A controller without per-target guards
    (``cfg.guard is None``) passes through untouched.  A stale target's
    guard idles for the tick — its "realised" metric is the republished
    stale sample, not evidence about the forecast.  As the last stage
    before actuation it also records each fresh target's final decision
    — the anchor ``stage_degrade`` holds at on later stale ticks."""
    k = ctrl.cfg.key_metric_idx
    last = getattr(ctrl, "_deg_last", None)
    for n in tick.names:
        g = getattr(ctrl.targets[n], "guard", None)
        if n in tick.stale:
            if g is not None:
                g.down_ct = 0
                g.arm(float("nan"))
            continue
        res = tick.results[n]
        if g is not None:
            realised = float(tick.recents[n][-1, k])
            res.replicas = g.apply(realised, res.replicas, tick.cur_r[n],
                                   tick.max_r[n])
            g.arm(res.key_metric if res.predicted else float("nan"))
        if last is not None:
            last[n] = res.replicas
    return tick


def stage_actuate(tick: Tick, actuator=None) -> dict[str, EvalResult]:
    """Apply the decisions through an optional ``actuator(name, replicas)``
    callback — the only stage with side effects outside the controller; the
    async plane barriers exactly here."""
    if actuator is not None:
        for n, res in tick.results.items():
            actuator(n, res.replicas)
    return tick.results


def prediction_mse(predictions, actual_series, actual_times, idx) -> float:
    """One-step-ahead MSE of a (t, prediction) log (paper Figs. 7-8)."""
    if not predictions:
        return float("nan")
    errs = []
    for t, pred in predictions:
        j = np.searchsorted(actual_times, t, side="right")
        if j < len(actual_series):
            errs.append((pred[idx] - actual_series[j, idx]) ** 2)
    return float(np.mean(errs)) if errs else float("nan")


# ======================================================================= #
#  Sharding                                                               #
# ======================================================================= #


def shard_assignment(names, n_shards: int, assignment=None
                     ) -> dict[str, int]:
    """Deterministic target->shard map.  An explicit ``assignment`` entry
    wins; everything else hashes with crc32, which is stable across
    processes (Python's ``hash`` is salted per run)."""
    out = {}
    for n in names:
        s = assignment.get(n) if assignment else None
        if s is None:
            s = zlib.crc32(n.encode()) % n_shards
        if not 0 <= int(s) < n_shards:
            raise ValueError(f"target {n!r} assigned to shard {s} "
                             f"outside [0, {n_shards})")
        out[n] = int(s)
    return out


def _vectorizable(specs, shared_model) -> bool:
    """True when a shard's targets run on the columnar fast path: every
    policy carries the vectorised protocol (``stack``/``evaluate_batch`` —
    heterogeneous *types* are fine, the shard dispatches per type) and
    (shared mode) any batched forecaster, or (per-target mode) homogeneous
    stackable models (plain LSTM or any ``arch``-registry subclass, e.g.
    the Attention-Double-LSTM)."""
    if not all(policy_vectorizable(s.policy) for s in specs):
        return False
    if shared_model is not None:
        return True
    models = [s.model for s in specs]
    if not all(isinstance(m, LSTMForecaster) for m in models):
        return False
    sig = lstm_stack_signature(models[0])
    return all(lstm_stack_signature(m) == sig for m in models)


def predict_from_stack(cache, idx, wins, m0, n_total: int,
                       use_pallas: bool | None = None) -> np.ndarray:
    """Transform -> stacked forward -> residual -> inverse, from a
    stacked-params cache: the ONE implementation behind both the per-shard
    and fused dispatch paths (their elementwise equivalence to the scalar
    decision path is this module's central invariant).

    ``idx`` indexes the candidate targets into the cache's arrays;
    ``wins`` is their gathered (C, W, M) window batch; ``n_total`` is the
    cache's full target count (``idx`` covering it skips the gather).
    ``use_pallas`` overrides the models' own flag (the plane-level config
    knob): ``True`` routes the dispatch through the fused block-batched
    Pallas sequence kernel (DESIGN.md §7)."""
    mean_s = cache["mean"][idx]
    std_s = cache["std"][idx]
    z = transform_stacked(wins, mean_s, std_s)
    stacked = (cache["stacked"] if len(idx) == n_total
               else jax.tree.map(lambda leaf: leaf[idx], cache["stacked"]))
    preds = np.asarray(_lstm_forward_stacked(
        stacked, jnp.asarray(z),
        use_pallas=m0.use_pallas if use_pallas is None else use_pallas,
        arch=m0.arch))
    if m0.residual:
        preds = z[:, -1] + preds
    return preds * std_s + mean_s


class _Immediate:
    """Future stand-in for the synchronous path."""

    def __init__(self, value):
        self._value = value

    def result(self):
        return self._value


# ======================================================================= #
#  Columnar shard (the fast path)                                         #
# ======================================================================= #


class _VecShard:
    """One shard's Zs targets on columnar state: a (Zs, R, M) metric ring,
    stacked scaler/params caches, and vectorised policy + stabilizer math
    that is elementwise-identical to the per-target scalar objects."""

    vectorized = True

    def __init__(self, cfg, specs, model, use_pallas: bool | None = None):
        self.cfg = cfg
        self.use_pallas = use_pallas     # None = inherit from the models
        self.specs = list(specs)
        self.names = [s.name for s in specs]
        self.index = {n: i for i, n in enumerate(self.names)}
        Zs = len(self.names)
        self.model = model                                   # shared or None
        self.models = None if model is not None else [s.model for s in specs]
        self.window = (model.window if model is not None
                       else self.models[0].window)
        self.R = max(self.window + 1, 8)
        self.ring = np.zeros((Zs, self.R, N_METRICS))
        self.count = np.zeros(Zs, np.int64)
        self.histories = [MetricsHistory() for _ in specs]
        # per-policy dispatch table: group target indices by policy TYPE and
        # stack each group's parameters once — decide() then runs ONE
        # evaluate_batch per type per tick (heterogeneous policy sets cost
        # O(#types) array programs, never O(Zs) per-target Python)
        by_type: dict[type, list[int]] = {}
        for i, s in enumerate(specs):
            by_type.setdefault(type(s.policy), []).append(i)
        self._pol_groups = [
            (cls, np.asarray(idxs, np.int64),
             cls.stack([specs[i].policy for i in idxs]))
            for cls, idxs in by_type.items()]
        # vectorised scale-down stabilizer: preallocated sliding buffer of
        # the last K ticks' (t, clamped desired).  Ticks arrive in time
        # order, so expired entries fall off the front (tail pointer) and
        # new ticks append at the back — no per-tick Python list rebuild;
        # compaction on wrap amortises to O(1) per tick.
        self._stab_t = np.full(16, -np.inf)
        self._stab_n = np.zeros((16, Zs), np.int64)
        self._stab_lo = 0
        self._stab_hi = 0
        # reactive guardrail state (DESIGN.md §10): forecast each decision
        # acted on (NaN = unarmed) + consecutive-overshoot counters; rides
        # the shard views, so the device-mesh path guards for free
        self._grd = getattr(cfg, "guard", None)
        self._grd_prev = np.full(Zs, np.nan)
        self._grd_down = np.zeros(Zs, np.int64)
        self.guard_up = 0
        self.guard_down = 0
        # degraded mode (DESIGN.md §13): per-target time of the last
        # *fresh* observation (stale republished rows shift the ring but
        # not this clock) + cumulative held-on-stale target-tick counter
        self._res = getattr(cfg, "resilience", None)
        self._last_seen = np.full(Zs, -np.inf)
        self.stale_held = 0
        # last fresh-tick decision per target (-1 = none yet): the
        # degraded hold's anchor — k8s keeps desiredReplicas when metrics
        # go missing; holding at the live count instead would ratchet a
        # blacked-out fleet down as node failures eat its replicas
        self._deg_last = np.full(Zs, -1, np.int64)
        self._stack_cache: dict = {}
        # columnar tick records: (t, replicas, key, predicted, conf, max_r,
        # means | None, cand); EvalResults materialise lazily from these
        self.ticks: list[tuple] = []
        self._dec_cache: dict[str, list] = {}
        self._pred_cache: dict[str, tuple[int, list]] = {}

    # ------------------------------------------------------------ collect --
    # ``keep_history`` is set by the plane: histories only feed the
    # updater, so a plane without one skips Z list appends per tick
    keep_history = True

    def observe(self, name: str, snap: Snapshot, fresh: bool = True):
        i = self.index[name]
        self.ring[i, :-1] = self.ring[i, 1:]
        self.ring[i, -1] = snap.values
        self.count[i] += 1
        if fresh:
            self._last_seen[i] = snap.t
        if self.keep_history:
            self.histories[i].append(snap)

    def observe_batch(self, t: float, rows: np.ndarray, fresh=None):
        """One ring shift for the whole shard instead of Zs row shifts.
        ``fresh`` (bool (Zs,), None = all fresh) marks which rows are
        genuine new samples — a blacked-out exporter's republished row
        shifts the ring but not the freshness clock."""
        self.ring[:, :-1] = self.ring[:, 1:]
        self.ring[:, -1] = rows
        self.count += 1
        if fresh is None:
            self._last_seen[:] = t
        else:
            self._last_seen[fresh] = t
        if self.keep_history:
            for i, h in enumerate(self.histories):
                h.append_row(t, rows[i])

    # device-mode collect: the metric ring lives on the device mesh
    # (core/device_plane.py), so the shard keeps only counts + histories
    def observe_meta(self, name: str, snap: Snapshot, fresh: bool = True):
        i = self.index[name]
        self.count[i] += 1
        if fresh:
            self._last_seen[i] = snap.t
        if self.keep_history:
            self.histories[i].append(snap)

    def observe_meta_batch(self, t: float, rows: np.ndarray, fresh=None):
        self.count += 1
        if fresh is None:
            self._last_seen[:] = t
        else:
            self._last_seen[fresh] = t
        if self.keep_history:
            for i, h in enumerate(self.histories):
                h.append_row(t, rows[i])

    def stale_mask(self, t: float):
        """(Zs,) bool: targets whose last fresh observation is older than
        the resilience TTL — or None when the TTL is off (the quiet path
        stays bitwise untouched)."""
        res = self._res
        if res is None or not np.isfinite(res.stale_ttl_s):
            return None
        return (t - self._last_seen) > res.stale_ttl_s

    # ---------------------------------------------------------- formulate --
    def snapshot(self):
        """Copy the formulated window batch — the tick's double buffer: the
        driver may keep observing the next window while this snapshot's
        forecast is in flight."""
        return self.ring.copy(), self.count.copy()

    # ----------------------------------------------------------- forecast --
    def forecast(self, state, stale=None):
        """Batched forecast over the snapshot.  Returns (means, stds, bayes,
        cand): means (Zs, M) with NaN rows for reactive targets.  Reads
        models/scalers only — safe on a worker thread.  ``stale`` (bool
        (Zs,) or None) drops TTL-expired targets out of the forecast batch
        before the gather — they ride the reactive path this tick."""
        ring, count = state
        Zs = len(self.names)
        means = np.full((Zs, N_METRICS), np.nan)
        stds = None
        bayes = False
        cand = np.zeros(Zs, bool)
        if self.model is not None:
            try:
                ok = self.model.valid()
            except Exception:
                ok = False
            if ok:
                cand = count >= self.model.window + 1
                if stale is not None:
                    cand = cand & ~stale
            if cand.any():
                try:
                    mm, ss = self.model.predict_batch(ring[cand])
                    means[cand] = mm
                    bayes = self.model.is_bayesian
                    if ss is not None:
                        stds = np.full((Zs, N_METRICS), np.nan)
                        stds[cand] = ss
                except Exception:
                    # robust: batched model failure -> every target reactive
                    means[:] = np.nan
                    stds = None
                    cand = np.zeros(Zs, bool)
        else:
            gens = tuple(m._fit_count for m in self.models)
            cache = self._stack_cache
            if cache.get("gens") != gens:
                valid = np.array([self._model_ok(m) for m in self.models])
                cache.clear()
                cache["gens"] = gens
                cache["valid"] = valid
                if valid.any():
                    cache["stacked"] = stack_params(self.models)
                    cache["mean"], cache["std"] = \
                        stack_scaler_stats(self.models)
            cand = cache["valid"] & (count >= self.window + 1)
            if stale is not None:
                cand = cand & ~stale
            if cand.any():
                try:
                    means[cand] = self._predict_stacked(ring, cand)
                except Exception:
                    means[:] = np.nan
                    cand = np.zeros(Zs, bool)
        return means, stds, bayes, cand

    @staticmethod
    def _model_ok(m) -> bool:
        try:
            return bool(m.valid())
        except Exception:
            return False

    def _predict_stacked(self, ring, cand):
        """Vectorised ``lstm_predict_batch_stacked``: broadcast scaler
        transform + one vmapped forward for the shard's candidates."""
        m0 = self.models[0]
        idx = np.flatnonzero(cand)
        return predict_from_stack(self._stack_cache, idx,
                                  ring[idx, -m0.window:, :], m0,
                                  len(self.models),
                                  use_pallas=self.use_pallas)

    # ----------------------------------------------------------- evaluate --
    def decide(self, t, state, preds, max_r, cur_r, stale=None):
        """Vectorised Evaluator.decide_from_prediction + per-type policy
        dispatch + ScaleDownStabilizer — the arithmetic matches the scalar
        objects elementwise (property-tested in tests/test_sharded_plane.py
        and tests/test_columnar.py).  ``stale`` rows hold their current
        replica count and idle their guardrail (the columnar twin of
        ``stage_degrade`` + the guard's stale skip)."""
        ring, count = state
        means, stds, bayes, cand = preds
        k = self.cfg.key_metric_idx
        Zs = len(self.names)
        cur = self._as_array(cur_r)
        maxr = self._as_array(max_r)
        current_key = np.where(count > 0, ring[:, -1, k], 0.0)
        mk = means[:, k]
        conf = np.ones(Zs, bool)
        if bayes and stds is not None:
            conf[cand] = stds[cand, k] <= self.cfg.confidence_threshold
        predicted = cand & conf & np.isfinite(mk)
        key = np.where(predicted, mk, current_key)
        # static policies: one evaluate_batch per policy TYPE (the dispatch
        # table built at construction) — elementwise identical to the
        # scalar __call__ each Evaluator would make
        if len(self._pol_groups) == 1:
            cls, _, stacked = self._pol_groups[0]
            n = cls.evaluate_batch(stacked, key, cur)
        else:
            n = np.empty(Zs, np.int64)
            for cls, idx, stacked in self._pol_groups:
                n[idx] = cls.evaluate_batch(stacked, key[idx], cur[idx])
        n = np.minimum(n, maxr)
        # ScaleDownStabilizer, vectorised (shared timestamps per tick):
        # the ring keeps exactly the entries the old list filter kept
        # (tt >= t - stabilization_s, current tick included), and the max
        # is ONE reduction over the live span
        maxrec = self._stab_push(t, n)
        final = np.where(n < cur, np.minimum(maxrec, maxr), n)
        if stale is not None and stale.any():
            # degraded hold: never scale on a metric past its TTL — pin
            # at the last fresh-tick decision (fallback: live count)
            hold = np.where(self._deg_last >= 0, self._deg_last, cur)
            final = np.where(stale, hold, final)
            self.stale_held += int(stale.sum())
        if self._grd is not None:
            final = self._guard_apply(final, current_key, cur, maxr,
                                      key, predicted, stale)
        self._deg_last = (final.copy() if stale is None
                          else np.where(stale, self._deg_last, final))
        rec = (t, final, key, predicted, conf, maxr,
               means if cand.any() else None, cand)
        self.ticks.append(rec)
        return rec

    def _guard_apply(self, final, realised, cur, maxr, key, predicted,
                     stale=None) -> np.ndarray:
        """Vectorised :class:`Guardrail` — elementwise identical to the
        scalar oracle (tests/test_guardrail.py).  When every target is
        in-band (the steady state) this costs a handful of (Zs,) compares
        and NO policy evaluation — the <10% quiet-tick overhead bar of the
        ``guardrail_overhead`` bench lane.  Stale rows count as unarmed:
        a republished stale sample is not evidence about the forecast."""
        g = self._grd
        armed = np.isfinite(self._grd_prev)
        if stale is not None:
            armed = armed & ~stale
        if armed.any():
            with np.errstate(invalid="ignore"):
                err = ((realised - self._grd_prev)
                       / np.maximum(np.abs(self._grd_prev), g.eps))
            up = armed & (err > g.band)
            low = armed & (err < -g.band)
            # consecutive-overshoot counter: the reactive analogue of the
            # proactive path's ScaleDownStabilizer
            self._grd_down = np.where(low, self._grd_down + 1, 0)
            down = low & (self._grd_down >= g.down_ticks)
            fire = up | down
            if fire.any():
                n_react = self._react_eval(realised * g.headroom, cur)
                up_n = np.minimum(np.maximum(final, n_react), maxr)
                down_n = np.minimum(final, n_react)
                final = np.where(up, up_n, np.where(down, down_n, final))
                self.guard_up += int(up.sum())
                self.guard_down += int(down.sum())
                self._grd_down[down] = 0
        else:
            self._grd_down.fill(0)
        self._grd_prev = np.where(predicted, key, np.nan)
        return final

    def _react_eval(self, metric: np.ndarray, cur: np.ndarray) -> np.ndarray:
        """Reactive policy re-evaluation on the realised metric, through
        the same per-type dispatch table as the proactive path (only runs
        on ticks where the guard fires)."""
        if len(self._pol_groups) == 1:
            cls, _, stacked = self._pol_groups[0]
            return cls.evaluate_batch(stacked, metric, cur)
        n = np.empty(len(self.names), np.int64)
        for cls, idx, stacked in self._pol_groups:
            n[idx] = cls.evaluate_batch(stacked, metric[idx], cur[idx])
        return n

    def _stab_push(self, t: float, n: np.ndarray) -> np.ndarray:
        """Append this tick's clamped desired counts to the stabilizer
        ring, expire entries older than the stabilization window, return
        the windowed per-target max."""
        lo, hi = self._stab_lo, self._stab_hi
        cut = t - self.cfg.stabilization_s
        while lo < hi and self._stab_t[lo] < cut:
            lo += 1
        if hi == len(self._stab_t):            # back of the buffer reached
            span = hi - lo
            if 2 * (span + 1) > len(self._stab_t):
                cap = 2 * len(self._stab_t)
                tbuf = np.full(cap, -np.inf)
                nbuf = np.zeros((cap, self._stab_n.shape[1]), np.int64)
                tbuf[:span] = self._stab_t[lo:hi]
                nbuf[:span] = self._stab_n[lo:hi]
                self._stab_t, self._stab_n = tbuf, nbuf
            else:                              # compact the live span left
                self._stab_t[:span] = self._stab_t[lo:hi].copy()
                self._stab_n[:span] = self._stab_n[lo:hi].copy()
            lo, hi = 0, span
        self._stab_t[hi] = t
        self._stab_n[hi] = n
        self._stab_lo, self._stab_hi = lo, hi + 1
        return self._stab_n[lo:hi + 1].max(axis=0)

    def _as_array(self, val) -> np.ndarray:
        if isinstance(val, dict):
            return np.array([int(val[n]) for n in self.names], np.int64)
        if isinstance(val, np.ndarray):   # shard-local slice, names order
            if len(val) != len(self.names):
                raise ValueError("replica bound array length != shard size")
            return np.asarray(val, np.int64)
        return np.full(len(self.names), int(val), np.int64)

    # ------------------------------------------------------------ readout --
    def result_for(self, name: str, rec) -> EvalResult:
        return self._eval_result(rec, self.index[name])

    @staticmethod
    def _eval_result(rec, i: int) -> EvalResult:
        t, reps, key, pred, conf, maxr, means, cand = rec
        raw = (means[i].copy() if means is not None and cand[i] else None)
        return EvalResult(replicas=int(reps[i]), key_metric=float(key[i]),
                          predicted=bool(pred[i]),
                          confidence_ok=bool(conf[i]),
                          max_replicas=int(maxr[i]), raw_prediction=raw)

    def decisions(self, name: str) -> list[EvalResult]:
        i = self.index[name]
        cache = self._dec_cache.setdefault(name, [])
        for rec in self.ticks[len(cache):]:
            cache.append(self._eval_result(rec, i))
        return cache

    def predictions(self, name: str) -> list[tuple[float, np.ndarray]]:
        i = self.index[name]
        seen, cache = self._pred_cache.get(name, (0, []))
        for rec in self.ticks[seen:]:
            t, _, _, _, _, _, means, cand = rec
            if means is not None and cand[i]:
                cache.append((t, means[i].copy()))
        self._pred_cache[name] = (len(self.ticks), cache)
        return cache

    def guard_counts(self) -> tuple[int, int]:
        return self.guard_up, self.guard_down

    def degraded_counts(self) -> int:
        return self.stale_held

    # ------------------------------------------------------- failover ------
    def state_snapshot(self) -> dict:
        """Cheap copy of everything a restarted shard process needs: the
        metric ring, freshness clocks, the stabilizer's live span and the
        guard arrays.  Decision logs stay out — they are plane-side
        observability, not process state (DESIGN.md §13)."""
        lo, hi = self._stab_lo, self._stab_hi
        return {"ring": self.ring.copy(), "count": self.count.copy(),
                "last_seen": self._last_seen.copy(),
                "stab_t": self._stab_t[lo:hi].copy(),
                "stab_n": self._stab_n[lo:hi].copy(),
                "grd_prev": self._grd_prev.copy(),
                "grd_down": self._grd_down.copy(),
                "deg_last": self._deg_last.copy()}

    def restore(self, snap: dict) -> None:
        """Rebuild columnar state from a snapshot (bounded staleness: any
        window observed after the snapshot was taken is lost, exactly as a
        crashed process would lose it)."""
        self.ring[:] = snap["ring"]
        self.count[:] = snap["count"]
        self._last_seen[:] = snap["last_seen"]
        span = len(snap["stab_t"])
        self._stab_t[:span] = snap["stab_t"]
        self._stab_n[:span] = snap["stab_n"]
        self._stab_lo, self._stab_hi = 0, span
        self._grd_prev[:] = snap["grd_prev"]
        self._grd_down[:] = snap["grd_down"]
        self._deg_last[:] = snap["deg_last"]

    def wipe(self) -> None:
        """Simulate the shard process dying: ring, counters, stabilizer
        and guard state all reset (the decision log survives — it lives
        with the plane, not the process)."""
        self.ring[:] = 0.0
        self.count[:] = 0
        self._last_seen[:] = -np.inf
        self._stab_t[:] = -np.inf
        self._stab_n[:] = 0
        self._stab_lo = self._stab_hi = 0
        self._grd_prev[:] = np.nan
        self._grd_down[:] = 0
        self._deg_last[:] = -1

    def target_models(self):
        return list(self.models) if self.models is not None else None


# ======================================================================= #
#  Heterogeneous shard (embedded FleetController fallback)                #
# ======================================================================= #


class _CtrlShard:
    """Last-resort shard for target sets the columnar path can't take —
    since the per-policy dispatch table this is only heterogeneous /
    non-stackable model sets and custom policy callables that don't carry
    the ``stack``/``evaluate_batch`` protocol.  Delegates to an embedded
    ``FleetController`` running the same staged tick; it doubles as the
    scalar parity oracle in tests."""

    vectorized = False

    def __init__(self, cfg, specs, model):
        from repro.core.controller import FleetController
        self.ctrl = FleetController(cfg, list(specs), model=model)
        self.names = [s.name for s in specs]

    def observe(self, name, snap, fresh=True):
        self.ctrl.observe(name, snap, fresh=fresh)

    def observe_batch(self, t, rows, fresh=None):
        for i, (n, row) in enumerate(zip(self.names, rows)):
            self.ctrl.observe(n, Snapshot(t, row),
                              fresh=True if fresh is None else bool(fresh[i]))

    def stale_mask(self, t):
        """The scalar twin's stale token: a set of names (``None`` when
        the TTL is off), consumed by this shard's own forecast/decide."""
        names = self.ctrl._stale_names(t)
        return names if names else None

    def snapshot(self):
        out = {}
        for n in self.names:
            st = self.ctrl.targets[n]
            out[n] = (np.stack(st.recent) if st.recent
                      else np.zeros((1, N_METRICS)))
        return out

    def forecast(self, state, stale=None):
        names = (self.names if not stale
                 else [n for n in self.names if n not in stale])
        return self.ctrl._predict_all(names, state)

    def decide(self, t, state, preds, max_r, cur_r, stale=None):
        tick = Tick(t=t, names=self.names,
                    max_r=as_replica_map(max_r, self.names),
                    cur_r=as_replica_map(cur_r, self.names))
        tick.recents = state
        tick.preds = preds
        tick.stale = set(stale) if stale else set()
        stage_evaluate(self.ctrl, tick)
        stage_degrade(self.ctrl, tick)
        stage_guard(self.ctrl, tick)
        return tick.results

    def degraded_counts(self) -> int:
        return self.ctrl._deg_stale

    def guard_counts(self) -> tuple[int, int]:
        guards = [st.guard for st in self.ctrl.targets.values()
                  if getattr(st, "guard", None) is not None]
        return (sum(g.up_fired for g in guards),
                sum(g.down_fired for g in guards))

    def result_for(self, name, rec) -> EvalResult:
        return rec[name]

    def decisions(self, name):
        return self.ctrl.decisions(name)

    def predictions(self, name):
        return self.ctrl.predictions(name)

    @property
    def histories(self):
        return [self.ctrl.targets[n].history for n in self.names]

    def target_models(self):
        if not self.ctrl.per_target_models:
            return None
        return [self.ctrl.targets[n].spec.model for n in self.names]


# ======================================================================= #
#  The sharded plane                                                      #
# ======================================================================= #


def _bound_slice(val, idx):
    """Per-shard view of a replica bound: plane-order ndarrays are sliced
    to the shard's rows; dicts and scalars pass through (the shard
    resolves them by name / broadcast)."""
    return val[idx] if isinstance(val, np.ndarray) else val


class TickResult(cabc.Mapping):
    """Mapping name -> EvalResult over one tick, materialised lazily from
    the shards' columnar records (building Z dataclasses per tick is the
    single-controller path's dominant host cost at Z >= 10^3)."""

    def __init__(self, plane, per_shard, t):
        self._plane = plane
        self._per_shard = per_shard          # list of (shard, record)
        self._by_shard = {id(s): rec for s, rec in per_shard}
        self.t = t
        self._cache: dict[str, EvalResult] = {}

    def __getitem__(self, name: str) -> EvalResult:
        res = self._cache.get(name)
        if res is None:
            shard = self._plane._shard_of[name]
            res = shard.result_for(name, self._by_shard[id(shard)])
            self._cache[name] = res
        return res

    def __iter__(self):
        return iter(self._plane._names)

    def __len__(self):
        return len(self._plane._names)

    def replicas_array(self) -> np.ndarray:
        """The tick's decided replica counts as one (Z,) int64 array in
        plane target order — the columnar readout: vectorized shards
        contribute their decision column directly (zero per-target
        ``EvalResult`` objects), fallback shards are gathered per name."""
        out = np.empty(len(self._plane._names), np.int64)
        for shard, idx in self._plane._shard_rows:
            rec = self._by_shard[id(shard)]
            if shard.vectorized:
                out[idx] = rec[1]
            else:
                out[idx] = [rec[n].replicas for n in shard.names]
        return out


class ShardedControlPlane:
    """S-shard staged control plane with double-buffered async ticks and
    off-critical-path batched refits.  API-compatible with
    ``FleetController`` (observe / control_step / maybe_update / decisions)
    plus the staged surface: ``observe_batch``, ``begin_tick`` /
    ``finish_tick``, ``poll_updates`` / ``flush_updates``."""

    is_batched = True

    def __init__(self, cfg, targets, model=None, updater=None,
                 n_shards: int = 1, assignment=None,
                 async_ticks: bool = False, async_updates: bool | None = None,
                 coalesce_dispatch: bool = True,
                 max_workers: int | None = None,
                 use_pallas: bool | None = None,
                 device_mesh=None):
        """``use_pallas`` (None = inherit from the models) forces the
        per-target stacked forecast dispatches — fused gang and per-shard
        alike — on (True) or off (False) the fused Pallas sequence kernel
        (DESIGN.md §7).  Shared-model planes keep the model's own flag
        (its ``predict_batch`` owns the dispatch).

        ``device_mesh`` (None = host state, the default) maps the plane
        onto a JAX device mesh (DESIGN.md §9): an int takes that many
        local devices, a 1-D ``('shards',)`` ``Mesh`` is used as given.
        The metric ring, stacked weights and scaler stats then live
        device-resident between ticks; ``coalesce_dispatch`` picks gang
        jit (GSPMD) vs per-device ``shard_map`` dispatch.  Requires the
        homogeneous per-target stacked-LSTM shape (the fused gang set)."""
        self.per_target_models = validate_targets(targets, model, updater)
        self.cfg = cfg
        self.use_pallas = use_pallas
        self.model = model
        self.updater = updater
        self.n_shards = int(n_shards)
        if self.n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.async_ticks = bool(async_ticks)
        self.async_updates = (self.async_ticks if async_updates is None
                              else bool(async_updates))
        self._names = [t.name for t in targets]
        self._min_r = {t.name: t.min_replicas for t in targets}
        self.assign = shard_assignment(self._names, self.n_shards,
                                       assignment)
        by_shard: dict[int, list] = {}
        for t in targets:
            by_shard.setdefault(self.assign[t.name], []).append(t)
        self.shards = []
        self._shard_rows: list[tuple[object, np.ndarray]] = []
        self._shard_of: dict[str, object] = {}
        pos = self._pos = {n: i for i, n in enumerate(self._names)}
        for s in sorted(by_shard):
            specs = by_shard[s]
            shard = (_VecShard(cfg, specs, model, use_pallas=use_pallas)
                     if _vectorizable(specs, model)
                     else _CtrlShard(cfg, specs, model))
            self.shards.append(shard)
            self._shard_rows.append(
                (shard, np.array([pos[sp.name] for sp in specs], np.int64)))
            for sp in specs:
                self._shard_of[sp.name] = shard
        # one worker per shard, plus a dedicated slot for the refit compute
        # so an in-flight update never queues ahead of a tick's forecast
        workers = len(self.shards) + (1 if self.async_updates else 0)
        self._pool = (ThreadPoolExecutor(
            max_workers=max_workers or max(workers, 1),
            thread_name_prefix="ctrl-plane")
            if (self.async_ticks or self.async_updates) else None)
        self._pending = None             # in-flight tick
        self._refit = None               # (t, future|None, _PendingUpdate)
        self._last_update_t = 0.0
        self.refit_log: list[dict] = []  # wall-clock overlap bookkeeping
        # degraded mode (DESIGN.md §13, armed by cfg.resilience): shard
        # snapshot ring for failover, crash countdowns + buffered rows for
        # reactive serving while a shard is down, the next-tick forecast
        # stall (chaos STALL events) and the observability counters behind
        # degraded_stats()
        self._res = getattr(cfg, "resilience", None)
        S = len(self.shards)
        self._shard_index = {id(s): i for i, s in enumerate(self.shards)}
        self._shard_snaps: list = [None] * S
        self._crash_left = np.zeros(S, np.int64)
        self._crash_rows: list = [None] * S
        self._stall_s = 0.0
        self._ticks_done = 0
        self._deg = {"deadline_skips": 0, "deadline_reactive": 0,
                     "crash_reactive": 0, "failovers": 0,
                     "recovery_ticks": 0, "snapshots": 0}
        # fused (coalesced) dispatch: on a single accelerator the S logical
        # shards gang their forecast tensors into ONE device dispatch per
        # tick (per-shard dispatch overhead dominates otherwise); with
        # coalesce_dispatch=False every shard dispatches its own (Z/S, W, M)
        # batch — the multi-device deployment shape
        self._offsets, off = [], 0
        for shard in self.shards:
            self._offsets.append(off)
            off += len(shard.names)
        self._all_models = None
        fused = coalesce_dispatch and all(s.vectorized for s in self.shards)
        if fused and self.per_target_models:
            models = [m for s in self.shards for m in s.target_models()]
            sig = lstm_stack_signature(models[0])
            fused = all(lstm_stack_signature(m) == sig for m in models)
            if fused:
                self._all_models = models
        self._fused = fused
        self._fused_cache: dict = {}
        # fused-cache invalidation: model params only change through the
        # plane's own update loop, so an epoch counter (bumped on refit
        # commit) replaces a per-tick O(Z) fit-generation sweep
        self._models_epoch = 0
        if updater is None:
            # histories only feed the updater — skip Z appends per tick
            for shard in self.shards:
                if shard.vectorized:
                    shard.keep_history = False
        # device-mesh mode: forecast state (ring / weights / scalers)
        # lives on the mesh, host keeps counts + last rows for evaluate
        self._engine = None
        if device_mesh is not None:
            from repro.core.device_plane import engine_for_plane
            self._engine, self._dev_models = engine_for_plane(
                self, device_mesh, coalesce_dispatch)
            self._fused = False          # the engine owns dispatch
            Z = len(self._names)
            self._dev_counts = np.zeros(Z, np.int64)
            self._dev_last = np.zeros((Z, N_METRICS))
            self._dev_last_seen = np.full(Z, -np.inf)
            self._dev_keep_history = any(s.keep_history
                                         for s in self.shards)
            # contiguous-block assignments (the deployment shape) feed
            # decide through zero-copy slice views instead of per-shard
            # fancy-index gathers of the joined prediction batch
            self._shard_cuts = [
                slice(int(idx[0]), int(idx[-1]) + 1)
                if idx.size and np.array_equal(
                    idx, np.arange(idx[0], idx[0] + idx.size))
                else idx
                for _, idx in self._shard_rows]

    # ------------------------------------------------------------ access --
    @property
    def target_names(self) -> list[str]:
        """All target names, in construction order."""
        return list(self._names)

    def min_replicas(self, name: str) -> int:
        """The target's ``TargetSpec.min_replicas`` floor."""
        return self._min_r[name]

    def model_for(self, name: str):
        """The forecaster serving ``name`` (the shared model, or the
        target's own in per-target mode)."""
        if not self.per_target_models:
            return self.model
        models = self._shard_of[name].target_models()
        return models[self._shard_of[name].names.index(name)]

    def decisions(self, name: str) -> list[EvalResult]:
        """Per-tick decision log for one target (post-guard finals)."""
        return self._shard_of[name].decisions(name)

    def predictions(self, name: str) -> list[tuple[float, np.ndarray]]:
        """``(t, predicted_metrics)`` log for forecast-based ticks."""
        return self._shard_of[name].predictions(name)

    def prediction_mse(self, name, actual_series, actual_times,
                       metric_idx=None) -> float:
        """Forecast MSE for one target against a realised series (the
        paper's accuracy readout; defaults to the key metric)."""
        idx = self.cfg.key_metric_idx if metric_idx is None else metric_idx
        return prediction_mse(self.predictions(name), actual_series,
                              actual_times, idx)

    def guard_stats(self) -> dict:
        """Cumulative guardrail override counts across every shard:
        ``{"up_overrides", "down_overrides"}`` (zeros when the plane runs
        without a guard, i.e. ``cfg.guard is None``)."""
        up = down = 0
        for s in self.shards:
            u, d = s.guard_counts()
            up += u
            down += d
        return {"up_overrides": up, "down_overrides": down}

    # ----------------------------------------------------------- collect --
    def observe(self, name: str, snap: Snapshot, fresh: bool = True):
        """Collect one metric snapshot for one target (the scalar feed;
        ``observe_batch`` is the columnar fast path).  ``fresh=False``
        records a republished (blacked-out exporter) sample: the window
        still shifts, but the target's staleness clock does not advance."""
        if self._engine is not None:
            i = self._pos[name]
            self._engine.push_row(i, snap.values)
            self._dev_counts[i] += 1
            self._dev_last[i] = snap.values
            if fresh:
                self._dev_last_seen[i] = snap.t
            self._shard_of[name].observe_meta(name, snap, fresh=fresh)
            return
        shard = self._shard_of[name]
        if self._crash_left[self._shard_index[id(shard)]] > 0:
            return   # the crashed shard process missed this sample
        shard.observe(name, snap, fresh=fresh)

    def observe_batch(self, t: float, values, fresh=None):
        """Batched collect: ``values`` is {name: row} or a (Z, M) array in
        target-list order — one ring shift per shard instead of Z calls
        (device mode: ONE device-resident ring shift for the whole plane,
        the tick's single host->device row upload).  ``fresh`` is an
        optional (Z,) bool mask — False rows are republished stale samples
        whose staleness clocks must not advance.  Rows addressed to a
        crashed shard are buffered so the failover tick can serve them
        reactively (the shard's own window died with the process)."""
        if isinstance(values, dict):
            rows = np.asarray([values[n] for n in self._names], np.float64)
        else:
            rows = np.asarray(values, np.float64)
        if fresh is not None:
            fresh = np.asarray(fresh, bool)
        if self._engine is not None:
            self._engine.push_rows(rows)
            self._dev_counts += 1
            self._dev_last[:] = rows
            if fresh is None:
                self._dev_last_seen[:] = t
            else:
                self._dev_last_seen[fresh] = t
            if self._dev_keep_history:
                for shard, idx in self._shard_rows:
                    shard.observe_meta_batch(
                        t, rows[idx],
                        fresh=None if fresh is None else fresh[idx])
            return
        for si, (shard, idx) in enumerate(self._shard_rows):
            if self._crash_left[si] > 0:
                self._crash_rows[si] = rows[idx].copy()
                continue
            shard.observe_batch(t, rows[idx],
                                fresh=None if fresh is None else fresh[idx])

    # -------------------------------------------------------- control loop -
    def begin_tick(self, t: float, max_replicas, current_replicas):
        """Formulate + dispatch forecasts (double buffer): snapshots every
        shard's windows and hands the forecast work to the worker pool in
        async mode — fused (one gang dispatch for all shards) or per shard.
        Observations arriving after ``begin_tick`` belong to the next
        window and cannot affect this tick's decisions."""
        if self._pending is not None:
            raise RuntimeError("previous tick not finished "
                               "(finish_tick barrier missing)")
        go_async = self._pool is not None and self.async_ticks
        stall = self._stall_s       # one-shot forecaster stall (chaos)
        self._stall_s = 0.0
        wall0 = time.monotonic()    # forecast-deadline anchor
        if self._engine is not None:
            # device mode: refresh the device weight caches iff the refit
            # epoch moved (between ticks, so no in-flight reader), then
            # snapshot = the immutable current ring buffer + host counts.
            # Later pushes build NEW device buffers — the double buffer
            # costs no copy.
            self._engine.refresh(self._dev_models, self._models_epoch)
            ring_ref = self._engine.snapshot()
            counts = self._dev_counts.copy()
            state = (self._dev_last.copy(), counts)
            res = self._res
            stale = None
            if res is not None and np.isfinite(res.stale_ttl_s):
                stale = (t - self._dev_last_seen) > res.stale_ttl_s
            fut = (self._pool.submit(self._stall_then, stall,
                                     self._engine.forecast, ring_ref,
                                     counts, stale)
                   if go_async
                   else _Immediate(self._stall_then(
                       stall, self._engine.forecast, ring_ref, counts,
                       stale)))
            self._pending = (t, max_replicas, current_replicas, state,
                             [fut], [stale], wall0)
            return self
        states = [shard.snapshot() for shard in self.shards]
        stales = self._stale_masks(t)
        if self._fused:
            preps = self._prepare_fused(states, stales)
            fut = (self._pool.submit(self._stall_then, stall,
                                     self._forecast_fused, preps)
                   if go_async
                   else _Immediate(self._stall_then(stall,
                                                    self._forecast_fused,
                                                    preps)))
            futs = [fut]
        else:
            futs = []
            for si, (shard, state) in enumerate(zip(self.shards, states)):
                if self._crash_left[si] > 0:
                    futs.append(_Immediate(None))   # served reactively
                    continue
                stale_s = None if stales is None else stales[si]
                futs.append(self._pool.submit(self._stall_then, stall,
                                              shard.forecast, state,
                                              stale_s)
                            if go_async
                            else _Immediate(self._stall_then(
                                stall, shard.forecast, state, stale_s)))
        self._pending = (t, max_replicas, current_replicas, states, futs,
                         stales, wall0)
        return self

    def finish_tick(self) -> TickResult:
        """The actuation barrier: joins the in-flight forecasts (bounded by
        the resilience forecast deadline — an overrun drops the whole tick
        to the reactive path), evaluates and stabilises every shard —
        crashed shards are served reactively from buffered driver rows (or
        held) — and installs any finished refit."""
        if self._pending is None:
            raise RuntimeError("no tick in flight (call begin_tick first)")
        t, max_r, cur_r, states, futs, stales, wall0 = self._pending
        self._pending = None
        res = self._res
        deadline = (res.forecast_deadline_s if res is not None
                    else float("inf"))
        if self._engine is not None:
            # device mode: one joined (Z, M) prediction batch; evaluate
            # stays the shards' columnar host math, fed a fabricated
            # 1-row ring so ``ring[:, -1, k]`` still reads the last row
            last, counts = states
            out = self._join(futs[0], wall0, deadline)
            Z = len(self._names)
            if out is None:
                self._deg["deadline_skips"] += 1
                self._deg["deadline_reactive"] += Z
                means_full = np.full((Z, N_METRICS), np.nan)
                cand_full = np.zeros(Z, bool)
            else:
                means_full, cand_full = out
            stale_full = stales[0]
            per_shard = []
            for (shard, _), idx in zip(self._shard_rows,
                                       self._shard_cuts):
                state_s = (last[idx][:, None, :], counts[idx])
                preds_s = (means_full[idx], None, False, cand_full[idx])
                rec = shard.decide(
                    t, state_s, preds_s, _bound_slice(max_r, idx),
                    _bound_slice(cur_r, idx),
                    stale=None if stale_full is None else stale_full[idx])
                per_shard.append((shard, rec))
            self._ticks_done += 1
            if res is not None:
                self._tick_epilogue()
            self.poll_updates()
            return TickResult(self, per_shard, t)
        deadline_hit = False
        if self._fused:
            out = self._join(futs[0], wall0, deadline)
            deadline_hit = out is None
            preds_list = ([None] * len(self.shards) if deadline_hit
                          else out)
        else:
            preds_list = []
            for si, f in enumerate(futs):
                if self._crash_left[si] > 0:
                    preds_list.append(None)   # crash branch below
                    continue
                out = self._join(f, wall0, deadline)
                if out is None:
                    deadline_hit = True
                preds_list.append(out)
        per_shard = []
        deadline_reactive = 0
        for si, ((shard, idx), state) in enumerate(zip(self._shard_rows,
                                                       states)):
            if self._crash_left[si] > 0:
                per_shard.append(
                    (shard, self._crash_decide(si, shard, t, max_r, cur_r,
                                               idx)))
                continue
            preds = preds_list[si]
            if preds is None:   # forecast missed the deadline -> reactive
                preds = self._reactive_preds_for(shard)
                deadline_reactive += len(shard.names)
            rec = shard.decide(t, state, preds,
                               _bound_slice(max_r, idx),
                               _bound_slice(cur_r, idx),
                               stale=None if stales is None else stales[si])
            per_shard.append((shard, rec))
        if deadline_hit:
            self._deg["deadline_skips"] += 1
            self._deg["deadline_reactive"] += deadline_reactive
        self._ticks_done += 1
        if res is not None:
            self._tick_epilogue()
        self.poll_updates()
        return TickResult(self, per_shard, t)

    # ----------------------------------------------------- degraded mode --
    def _stale_masks(self, t: float):
        """Per-shard staleness tokens at tick time ``t`` (None = the TTL is
        off, the quiet fast path).  Vectorized shards yield bool arrays,
        scalar shards name-sets — each shard's own ``stale_mask`` shape."""
        res = self._res
        if res is None or not np.isfinite(res.stale_ttl_s):
            return None
        return [shard.stale_mask(t) for shard in self.shards]

    @staticmethod
    def _stall_then(stall: float, fn, *args):
        """Run ``fn`` after an injected forecaster stall (chaos STALL
        events model a hiccuping inference service; zero stall is the
        permanent no-op fast path)."""
        if stall > 0.0:
            time.sleep(stall)
        return fn(*args)

    @staticmethod
    def _join(fut, wall0: float, deadline: float):
        """Join a forecast future against the tick's wall-clock deadline;
        returns None when the budget is spent (the caller serves the tick
        reactively — the forecast result is discarded, exactly what a
        control loop that cannot wait must do)."""
        if not np.isfinite(deadline):
            return fut.result()
        if isinstance(fut, _Immediate):   # sync mode: work already done
            return (fut.result()
                    if time.monotonic() - wall0 <= deadline else None)
        try:
            left = deadline - (time.monotonic() - wall0)
            return fut.result(timeout=max(left, 0.0))
        except FuturesTimeout:
            return None

    @staticmethod
    def _reactive_preds_for(shard):
        """An all-reactive prediction batch in the shard's own shape: no
        candidates, so every target falls through to the realised-metric
        policy path (Evaluator's missing-prediction rule)."""
        if not shard.vectorized:
            return {}
        Zs = len(shard.names)
        return (np.full((Zs, N_METRICS), np.nan), None, False,
                np.zeros(Zs, bool))

    def _crash_decide(self, si: int, shard, t: float, max_r, cur_r, idx):
        """Serve a crashed shard's targets for one tick: reactively from
        the driver rows buffered since the crash (the shard's own window
        died with the process), or a plain hold at the current count when
        nothing has arrived yet.  Either way the fleet keeps receiving
        decisions while the failover rebuilds."""
        Zs = len(shard.names)
        self._deg["crash_reactive"] += Zs
        maxr = shard._as_array(_bound_slice(max_r, idx))
        cur = shard._as_array(_bound_slice(cur_r, idx))
        buf = self._crash_rows[si]
        if buf is None:
            rec = (t, cur.copy(), np.zeros(Zs),
                   np.zeros(Zs, bool), np.ones(Zs, bool), maxr, None,
                   np.zeros(Zs, bool))
            shard.ticks.append(rec)
            return rec
        state = (buf[:, None, :], np.ones(Zs, np.int64))
        return shard.decide(t, state, self._reactive_preds_for(shard),
                            maxr, cur)

    def _tick_epilogue(self):
        """Per-tick resilience bookkeeping: crashed-shard countdowns (a
        shard that reaches zero restores from its last snapshot — the
        failover) and the periodic snapshot cadence."""
        res = self._res
        for si in np.flatnonzero(self._crash_left > 0):
            self._deg["recovery_ticks"] += 1
            self._crash_left[si] -= 1
            if self._crash_left[si] == 0:
                snap = self._shard_snaps[si]
                if snap is not None:
                    self.shards[si].restore(snap)
                self._deg["failovers"] += 1
                self._crash_rows[si] = None
        if res.snapshot_every > 0 \
                and self._ticks_done % res.snapshot_every == 0:
            for si, shard in enumerate(self.shards):
                if shard.vectorized and self._crash_left[si] == 0:
                    self._shard_snaps[si] = shard.state_snapshot()
                    self._deg["snapshots"] += 1

    def crash_shard(self, si: int, down_ticks: int | None = None):
        """Chaos entry point: kill shard ``si``'s working state (ring,
        stabilizer, guard) as a crash-restart would.  For ``down_ticks``
        ticks its targets are served reactively / held; then the shard
        restores from the last periodic snapshot (bounded staleness) and
        resumes the proactive path."""
        if self._engine is not None:
            raise RuntimeError("crash_shard: device mode keeps forecast "
                               "state mesh-resident, not per shard")
        res = self._res
        if res is None or res.snapshot_every <= 0:
            raise RuntimeError("crash_shard needs cfg.resilience with "
                               "snapshot_every > 0 (no snapshot, no "
                               "failover)")
        si = int(si)
        shard = self.shards[si]
        if not shard.vectorized:
            raise RuntimeError("crash_shard: scalar shards have no "
                               "snapshot/restore surface")
        shard.wipe()
        self._crash_left[si] = max(int(down_ticks or 1), 1)
        self._crash_rows[si] = None

    def inject_forecast_stall(self, seconds: float):
        """Chaos entry point: the NEXT tick's forecast sleeps ``seconds``
        before running — with a resilience deadline armed, the tick rides
        the reactive path instead of blocking actuation."""
        self._stall_s = max(float(seconds), 0.0)

    def abort_tick(self):
        """Controller crash-restart mid-flight: drop the in-flight tick
        without actuating (the forecast future is abandoned; shard windows
        were snapshotted at begin so nothing is torn).  The next
        begin_tick starts clean — crash-safety for the staged loop."""
        self._pending = None

    def degraded_stats(self) -> dict:
        """Cumulative degraded-mode counters: targets held on stale
        metrics, ticks served reactively (stale + crash + deadline), the
        failover and snapshot machinery — ``FleetController`` exposes the
        same keys, so A/B harnesses read one dict shape."""
        stale = sum(s.degraded_counts() for s in self.shards)
        d = self._deg
        return {"stale_targets": stale,
                "reactive_fallbacks": (stale + d["crash_reactive"]
                                       + d["deadline_reactive"]),
                "deadline_skips": d["deadline_skips"],
                "failovers": d["failovers"],
                "recovery_ticks": d["recovery_ticks"],
                "snapshots": d["snapshots"]}

    # ------------------------------------------------------ fused dispatch -
    def _refresh_fused_cache(self) -> dict:
        """Cache of the globally stacked params + scaler stats for the
        fused per-target path, invalidated by the plane's refit epoch (an
        O(1) check per tick; refits through the plane's own update loop
        bump the epoch on commit)."""
        models = self._all_models
        cache = self._fused_cache
        if cache.get("epoch") != self._models_epoch:
            valid = np.array([_VecShard._model_ok(m) for m in models])
            cache.clear()
            cache["epoch"] = self._models_epoch
            cache["valid"] = valid
            if valid.any():
                cache["stacked"] = stack_params(models)
                cache["mean"], cache["std"] = stack_scaler_stats(models)
        return cache

    def _prepare_fused(self, states, stales=None) -> list[tuple]:
        """Control-thread half of the fused forecast: candidate masks and
        window gathers (cheap copies); the transforms and the device
        dispatch run in ``_forecast_fused`` (overlappable).  ``stales``
        drops TTL-expired targets out of the candidate set before the
        gather — stale windows never reach the device."""
        preps = []
        if self.per_target_models:
            cache = self._refresh_fused_cache()
            for si, (shard, (ring, count), off) in enumerate(
                    zip(self.shards, states, self._offsets)):
                Zs = len(shard.names)
                cand = (cache["valid"][off:off + Zs]
                        & (count >= shard.window + 1))
                if stales is not None and stales[si] is not None:
                    cand = cand & ~stales[si]
                idx = np.flatnonzero(cand)
                preps.append((cand, idx + off,
                              ring[idx, -shard.window:, :]))
        else:
            try:
                ok = bool(self.model.valid())
            except Exception:
                ok = False
            need = self.model.window + 1
            for si, (shard, (ring, count)) in enumerate(
                    zip(self.shards, states)):
                cand = (count >= need) & ok
                if stales is not None and stales[si] is not None:
                    cand = cand & ~stales[si]
                idx = np.flatnonzero(cand)
                preps.append((cand, idx, ring[idx]))
        return preps

    def _forecast_fused(self, preps) -> list[tuple]:
        """Worker half: ONE device dispatch answers every shard's
        candidates; results are split back per shard as the same
        (means, stds, bayes, cand) tuples ``_VecShard.forecast`` returns."""
        counts = [len(p[2]) for p in preps]
        means_g = stds_g = None
        bayes = False
        if sum(counts):
            wins = np.concatenate([p[2] for p in preps if len(p[2])])
            try:
                if self.per_target_models:
                    g_idx = np.concatenate([p[1] for p in preps
                                            if len(p[1])])
                    means_g = predict_from_stack(
                        self._fused_cache, g_idx, wins,
                        self._all_models[0], len(self._all_models),
                        use_pallas=self.use_pallas)
                else:
                    means_g, stds_g = self.model.predict_batch(wins)
                    bayes = self.model.is_bayesian
            except Exception:
                # robust: a failed gang dispatch -> every target reactive
                means_g = stds_g = None
                bayes = False
        out, off = [], 0
        for shard, (cand, _, w), k in zip(self.shards, preps, counts):
            Zs = len(shard.names)
            means = np.full((Zs, N_METRICS), np.nan)
            stds = None
            if means_g is None:
                out.append((means, None, False, np.zeros(Zs, bool)))
                continue
            if k:
                means[cand] = means_g[off:off + k]
                if stds_g is not None:
                    stds = np.full((Zs, N_METRICS), np.nan)
                    stds[cand] = stds_g[off:off + k]
                off += k
            out.append((means, stds, bayes, cand))
        return out

    def control_step(self, t: float, max_replicas, current_replicas
                     ) -> TickResult:
        """Synchronous tick: begin + finish back to back."""
        self.begin_tick(t, max_replicas, current_replicas)
        return self.finish_tick()

    # --------------------------------------------------------- update loop -
    def maybe_update(self, t: float):
        """Non-blocking model update.  Per-target mode snapshots histories
        and submits ONE vmapped batch refit of all Z targets to the worker
        pool (sync mode runs it inline); shared mode runs the pooled
        cross-target fit inline (an in-place shared-model fit cannot safely
        overlap in-flight forecasts)."""
        self.poll_updates()
        if self.updater is None:
            return
        if self._pending is not None:
            # mid-tick (between begin_tick and finish_tick): the inline
            # branches below mutate params/scalers a worker forecast may
            # be reading — defer; the timer hasn't advanced, so the next
            # between-ticks call picks the update up
            return
        if t - self._last_update_t < self.cfg.update_interval_s:
            return
        if self._refit is not None:
            return    # previous refit still in flight; retry next tick
        self._last_update_t = t
        if self.per_target_models:
            models, hists, names = [], [], []
            for shard in self.shards:
                models.extend(shard.target_models())
                hists.extend(shard.histories)
                names.extend(shard.names)
            pending = self.updater.begin_update_batch(models, hists, t,
                                                      targets=names)
            if pending is None:
                return
            wall = time.monotonic()
            if self._pool is not None and self.async_updates:
                self._refit = (wall, self._pool.submit(pending.compute),
                               pending)
            else:
                pending.compute()
                pending.commit()
                self._models_epoch += 1
                self.refit_log.append(
                    {"t": t, "submitted": wall,
                     "applied": time.monotonic(),
                     "batched": bool(pending.batched), "async": False})
        else:
            merged = MetricsHistory()
            all_hists = [h for shard in self.shards
                         for h in shard.histories]
            for h in all_hists:
                for tt, row in zip(h.times(), h.series()):
                    merged.append_row(float(tt), row)
            n_rows = len(merged)
            self.model = self.updater.update(self.model, merged, t)
            self._models_epoch += 1
            for shard in self.shards:
                if shard.vectorized:
                    shard.model = self.model
                else:
                    shard.ctrl.model = self.model
            if len(merged) < n_rows:     # updater consumed (cleared) it
                for h in all_hists:
                    h.clear()

    def invalidate_models(self):
        """Force a rebuild of the fused stacked-params cache.  Only needed
        when per-target models are refit OUTSIDE the plane's update loop
        (the plane's own refits bump the epoch on commit)."""
        self._models_epoch += 1

    def poll_updates(self, wait: bool = False) -> bool:
        """Install a finished background refit (between ticks).  Returns
        True when a refit was applied."""
        if self._refit is None:
            return False
        if self._pending is not None:
            # never install while a tick is in flight: a sequential-fallback
            # commit mutates scalers in place under a live forecast
            return False
        wall, fut, pending = self._refit
        if not (wait or fut.done()):
            return False
        self._refit = None               # cleared first: a failed compute
        try:                             # must not wedge every later tick
            fut.result()
        except Exception:
            # robustness guarantee: a failed refit is dropped and the plane
            # keeps serving with the previous params (the snapshot history
            # is lost, like a crashed out-of-band trainer)
            self.refit_log.append(
                {"t": pending.t, "submitted": wall,
                 "applied": time.monotonic(), "failed": True,
                 "batched": False, "async": True})
            return False
        pending.commit()                 # install on the control thread
        self._models_epoch += 1
        self.refit_log.append(
            {"t": pending.t, "submitted": wall,
             "applied": time.monotonic(),
             "batched": bool(pending.batched), "async": True})
        return True

    def flush_updates(self) -> bool:
        """Barrier for in-flight refits (end of run / tests)."""
        return self.poll_updates(wait=True)

    @property
    def refit_inflight(self) -> bool:
        """True while a background batch refit has not yet committed."""
        return self._refit is not None

    def shutdown(self):
        """Join the worker pool (pending refits/forecasts complete)."""
        if self._pool is not None:
            self._pool.shutdown(wait=True)
