"""Device-mesh execution engine for the sharded control plane (DESIGN.md §9).

``ShardedControlPlane`` keeps its tick state in host numpy: a (Zs, R, M)
metric ring per shard, f64 scaler transforms, and a ``predict_from_stack``
that re-uploads the window batch (and gathers stacked weights) every tick.
Once dispatch is fused that host round-trip IS the tick wall at Z >= 10^4.
This module moves the forecast half of the tick onto a JAX device mesh:

* **mesh** — one physical axis ``('shards',)`` over D local devices
  (``distributed.sharding.control_mesh``); the plane's Z-target axis is
  partitioned over it with ``NamedSharding``/``PartitionSpec``.
* **device-resident state** — the metric ring (Zp, R, M) f32, the stacked
  LSTM weight pytree, and the stacked scaler stats live on the mesh
  BETWEEN ticks.  Per tick the host uploads one (Zp, M) row batch and
  downloads one (Zp, M) prediction batch; the ring shifts in place on
  device (``jnp`` functional update — the old buffer stays valid, which
  is exactly the double-buffer snapshot the async tick needs for free).
* **two dispatch policies** — ``coalesce_dispatch=True`` gangs the whole
  plane into ONE jitted program and lets GSPMD partition it over the mesh;
  ``False`` routes the per-shard path through ``jax.shard_map`` so each
  device runs its own block program (the multi-device deployment shape).
* **invalidate-on-refit-commit** — stacked weights/scalers re-stack and
  re-upload only when the plane's refit epoch moves (the same epoch the
  fused host cache keys on), never per tick.

Bitwise device-count invariance: every per-target computation here is
row-independent (batched GEMV per target, no cross-target reductions), so
partitioning the Z axis over 1, 2 or 8 devices cannot change any row's
numerics — ``tests/test_device_plane.py`` asserts tick results are
bitwise identical across D.  Against the host plane the engine computes
in f32 end-to-end (the host path standardises in f64), so equivalence is
decision-level + allclose, like the Pallas kernel path.

The reactive guardrail stage (DESIGN.md §10, docs/guardrail.md) composes
with this engine for free: guard state (``_grd_prev`` armed forecasts,
consecutive-overshoot counters) lives in per-shard host arrays inside
``_VecShard`` and the plane's device-mode ``finish_tick`` feeds each
shard's ``decide`` through the same zero-copy shard views (``_shard_cuts``)
as the unguarded plane — the guard reads the realised key metric from the
host-tracked last-row buffer and never touches the device ring, so the
D-invariance and tick-transfer budget above are unchanged (bitwise
invariance with the guard armed is asserted in tests/test_guardrail.py).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.forecaster import (ARCH_PARAM_LEAVES, Z_CLIP,
                                   lstm_stack_signature, stack_scaler_stats,
                                   stacked_forward)
from repro.core.metrics import N_METRICS
from repro.distributed.sharding import CONTROL_AXIS, control_mesh

FORCE_HOST_DEVICES_FLAG = "--xla_force_host_platform_device_count"


def force_host_devices_env(n: int = 8, env: dict | None = None) -> dict:
    """Environment for a subprocess that should see ``n`` virtual CPU
    devices — the forced-host-device trick CI uses to exercise the mesh
    plane without accelerators.  Must be set before jax initialises, hence
    the subprocess (tests/conftest.py re-execs through this)."""
    out = dict(os.environ if env is None else env)
    flags = [f for f in out.get("XLA_FLAGS", "").split()
             if not f.startswith(FORCE_HOST_DEVICES_FLAG)]
    flags.append(f"{FORCE_HOST_DEVICES_FLAG}={int(n)}")
    out["XLA_FLAGS"] = " ".join(flags)
    out.setdefault("JAX_PLATFORMS", "cpu")
    return out


def _pad_to(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


class DevicePlaneEngine:
    """Device-resident forecast state + dispatch for one control plane.

    The plane (core/control_plane.py) keeps owning collect / evaluate /
    actuate on host numpy; this engine owns exactly the state that used to
    cross the host-device boundary every tick: the metric ring, the
    stacked per-target LSTM params and the stacked scaler stats.

    The engine computes predictions for ALL rows and the plane masks
    non-candidates with NaN on host — a host-side candidate gather would
    reintroduce the per-tick device round-trip, and an all-rows program
    keeps shapes static across ticks (one compile).
    """

    def __init__(self, Z: int, window: int, residual: bool,
                 use_pallas: bool, *, device_mesh=None,
                 coalesce_dispatch: bool = True, ring_rows: int | None = None,
                 arch: str = "lstm"):
        self.mesh = (device_mesh if device_mesh is not None
                     and not isinstance(device_mesh, int)
                     else control_mesh(device_mesh))
        if tuple(self.mesh.axis_names) != (CONTROL_AXIS,):
            raise ValueError("device plane needs a 1-D ('shards',) mesh "
                             f"(got axes {self.mesh.axis_names})")
        self.n_devices = int(self.mesh.devices.size)
        self.Z = int(Z)
        self.Zp = _pad_to(max(self.Z, self.n_devices), self.n_devices)
        self.window = int(window)
        self.residual = bool(residual)
        self.use_pallas = bool(use_pallas)
        self.arch = str(arch)
        self.param_leaves = ARCH_PARAM_LEAVES[self.arch]
        self.R = int(ring_rows if ring_rows is not None
                     else max(self.window + 1, 8))
        self.coalesce = bool(coalesce_dispatch)
        self._s_rows = NamedSharding(self.mesh, P(CONTROL_AXIS, None))
        self._s_ring = NamedSharding(self.mesh, P(CONTROL_AXIS, None, None))
        self.ring = jax.device_put(
            np.zeros((self.Zp, self.R, N_METRICS), np.float32), self._s_ring)
        # reused host staging buffer for the per-tick row upload (pad rows
        # beyond Z are never candidates, so zeros are fine)
        self._row_buf = np.zeros((self.Zp, N_METRICS), np.float32)
        self.epoch: int | None = None     # refit epoch of the device caches
        self._stacked = None              # device pytree, leading Zp axis
        self._mean = self._std = None     # device (Zp, M) f32
        self._valid = np.zeros(self.Z, bool)
        self._push = jax.jit(self._push_fn)
        self._push_row = jax.jit(self._push_row_fn)
        self._fwd = self._build_forward()

    # ----------------------------------------------------- ring updates --
    @staticmethod
    def _push_fn(ring, rows):
        # functional shift: the returned buffer replaces self.ring; any
        # snapshot reference taken before the push stays valid (this is
        # the async tick's double buffer, no copy needed)
        return jnp.concatenate([ring[:, 1:], rows[:, None, :]], axis=1)

    @staticmethod
    def _push_row_fn(ring, i, row):
        shifted = jnp.concatenate([ring[i, 1:], row[None, :]], axis=0)
        return ring.at[i].set(shifted)

    def push_rows(self, rows: np.ndarray):
        """One whole-plane ring shift on device: uploads a single (Zp, M)
        f32 row batch (the tick's only host->device transfer)."""
        self._row_buf[:self.Z] = rows
        if self.R == 1:
            # window-1 ring: the shift is the identity, so the upload IS
            # the new ring — no shift dispatch (device_put builds a fresh
            # buffer, so earlier snapshots stay valid)
            self.ring = jax.device_put(
                self._row_buf[:, None, :], self._s_ring)
            return
        dev_rows = jax.device_put(self._row_buf, self._s_rows)
        self.ring = self._push(self.ring, dev_rows)

    def push_row(self, i: int, row: np.ndarray):
        """Single-target observe (the scalar ``observe`` API)."""
        self.ring = self._push_row(self.ring, jnp.int32(i),
                                   jnp.asarray(row, jnp.float32))

    def snapshot(self):
        """The formulated window state — an immutable device array ref;
        later pushes build new buffers and never mutate it."""
        return self.ring

    # ------------------------------------------------------ weight cache --
    def refresh(self, models, epoch: int):
        """Re-stack + re-upload params/scaler stats iff the plane's refit
        epoch moved (invalidate-on-refit-commit).  Runs on the control
        thread between ticks, so no in-flight forecast can read a
        half-installed stack."""
        if self.epoch == epoch:
            return
        self._valid = np.array(
            [self._model_ok(m) for m in models], bool)
        stacked_np = {}
        for leaf in self.param_leaves:
            arrs = [np.asarray(m.params[leaf], np.float32) for m in models]
            buf = np.zeros((self.Zp,) + arrs[0].shape, np.float32)
            buf[:self.Z] = np.stack(arrs)
            stacked_np[leaf] = buf
        mean, std = stack_scaler_stats(models)
        mean_p = np.zeros((self.Zp, N_METRICS), np.float32)
        std_p = np.ones((self.Zp, N_METRICS), np.float32)
        mean_p[:self.Z] = mean
        std_p[:self.Z] = std
        self._stacked = jax.tree.map(
            lambda leaf: jax.device_put(leaf, self._s_leaf(leaf)),
            stacked_np)
        self._mean = jax.device_put(mean_p, self._s_rows)
        self._std = jax.device_put(std_p, self._s_rows)
        self.epoch = epoch

    def _s_leaf(self, leaf: np.ndarray) -> NamedSharding:
        return NamedSharding(
            self.mesh, P(CONTROL_AXIS, *(None,) * (leaf.ndim - 1)))

    @staticmethod
    def _model_ok(m) -> bool:
        try:
            return bool(m.valid())
        except Exception:
            return False

    # --------------------------------------------------------- dispatch --
    def _build_forward(self):
        W, residual, use_pallas = self.window, self.residual, self.use_pallas
        arch = self.arch

        def body(stacked, mean, std, ring):
            win = ring[:, -W:, :]
            z = jnp.clip((win - mean[:, None, :]) / std[:, None, :],
                         -Z_CLIP, Z_CLIP)
            net = stacked_forward(stacked, z, use_pallas=use_pallas,
                                  arch=arch)
            if residual:
                net = z[:, -1, :] + net
            return net * std + mean

        if self.coalesce:
            # gang dispatch: ONE program, GSPMD partitions the Z axis over
            # the mesh following the argument shardings
            return jax.jit(body)
        # per-shard dispatch: shard_map runs the block program per device
        # (PartitionSpecs shorter than an array's rank replicate the
        # trailing dims; the stacked-params dict takes P('shards') as a
        # pytree prefix)
        return jax.jit(shard_map(
            body, mesh=self.mesh,
            in_specs=(P(CONTROL_AXIS), P(CONTROL_AXIS), P(CONTROL_AXIS),
                      P(CONTROL_AXIS)),
            out_specs=P(CONTROL_AXIS)))

    def forecast(self, ring_ref, counts: np.ndarray, stale=None):
        """Forecast every target from a ring snapshot: returns
        ``(means (Z, M) f32 with NaN rows for non-candidates, cand (Z,))``.
        Reads only device caches + the immutable snapshot — safe on a
        worker thread while the driver keeps pushing next-window rows.
        ``stale`` (optional (Z,) bool, DESIGN.md §13) masks TTL-expired
        targets out of the candidate set host-side, so their NaN means
        route them down the reactive path — and a full-plane blackout
        skips the device dispatch entirely."""
        cand = self._valid & (counts >= self.window + 1)
        if stale is not None:
            cand = cand & ~stale
        if not cand.any():
            return np.full((self.Z, N_METRICS), np.nan, np.float32), cand
        try:
            out = self._fwd(self._stacked, self._mean, self._std, ring_ref)
            if cand.all():
                # steady state: every row is a candidate, skip the mask
                means = np.asarray(out)[:self.Z]
            else:
                means = np.full((self.Z, N_METRICS), np.nan, np.float32)
                means[cand] = np.asarray(out)[:self.Z][cand]
        except Exception:
            # robust: a failed gang dispatch -> every target reactive
            return np.full((self.Z, N_METRICS), np.nan, np.float32), \
                np.zeros(self.Z, bool)
        return means, cand


def engine_for_plane(plane, device_mesh, coalesce_dispatch: bool
                     ) -> tuple[DevicePlaneEngine, list]:
    """Validate a ``ShardedControlPlane``'s target set for the device path
    and build its engine + plane-order model list.  The device plane only
    takes the homogeneous per-target stacked-LSTM shape — exactly the set
    the fused gang path accepts."""
    if not plane.per_target_models:
        raise ValueError("device_mesh needs per-target models (a shared "
                         "model owns its own predict_batch dispatch)")
    if not all(s.vectorized for s in plane.shards):
        raise ValueError("device_mesh needs every shard on the columnar "
                         "path (vectorisable policies + stackable LSTMs)")
    # plane-order model list without an O(Z^2) per-name lookup
    models = [None] * len(plane.target_names)
    for shard, idx in plane._shard_rows:
        tm = shard.target_models()
        for j, gi in enumerate(idx):
            models[gi] = tm[j]
    sig = lstm_stack_signature(models[0])
    if not all(lstm_stack_signature(m) == sig for m in models):
        raise ValueError("device_mesh needs homogeneous stackable models "
                         "across shards")
    m0 = models[0]
    use_pallas = (m0.use_pallas if plane.use_pallas is None
                  else plane.use_pallas)
    # ring sized to exactly the forward window: the plane tracks counts
    # and last rows on host, so deeper device history is dead weight the
    # per-tick push shift would pay for (8x at window=1 vs the default)
    engine = DevicePlaneEngine(
        len(models), m0.window, m0.residual, use_pallas,
        device_mesh=device_mesh, coalesce_dispatch=coalesce_dispatch,
        ring_rows=m0.window, arch=m0.arch)
    return engine, models
