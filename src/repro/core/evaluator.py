"""The Evaluator — faithful implementation of paper Algorithm 1.

    Get current_metrics;
    Calculate max_replicas limited by system resources;
    model <- Load(model_file)
    if model.isValid():
        key_metric <- Predict(model, current_metrics)
        if model.isBayesian() and confidence < threshold:
            key_metric <- current_key_metric
    else:
        key_metric <- current_key_metric
    num_replicas <- Static_Policies(key_metric)
    if num_replicas > max_replicas: num_replicas <- max_replicas

Guarantees (tested property-style in tests/test_evaluator.py):
  proactive, limitation-aware, robust (falls back to the current metric on
  any model failure), model-agnostic, confidence-considered.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.forecaster import Forecaster
from repro.core.policies import Policy


@dataclasses.dataclass
class EvalResult:
    replicas: int
    key_metric: float
    predicted: bool            # False = reactive fallback
    confidence_ok: bool
    max_replicas: int
    raw_prediction: np.ndarray | None = None


class Evaluator:
    def __init__(self, policy: Policy, key_metric_idx: int,
                 confidence_threshold: float = math.inf):
        self.policy = policy
        self.key_idx = key_metric_idx
        self.conf_threshold = confidence_threshold

    def evaluate(self, recent: np.ndarray, model: Forecaster | None,
                 max_replicas: int, current_replicas: int) -> EvalResult:
        """recent: (>=window, N_METRICS) latest metric rows (last = current)."""
        mean = std = None
        is_bayesian = False
        if model is not None:
            try:
                if model.valid() and len(recent) >= model.window + 1:
                    mean, std = model.predict(recent)
                    is_bayesian = model.is_bayesian
            except Exception:
                # Robust: model file being updated / corrupted -> reactive
                mean = std = None
        return self.decide_from_prediction(recent, mean, std, is_bayesian,
                                           max_replicas, current_replicas)

    def decide_from_prediction(self, recent: np.ndarray,
                               mean: np.ndarray | None,
                               std: np.ndarray | None, is_bayesian: bool,
                               max_replicas: int,
                               current_replicas: int) -> EvalResult:
        """Algorithm 1's decision half, with the prediction supplied by the
        caller — the batched control plane (core/controller.py) computes one
        ``predict_batch`` for all targets and routes each row through here,
        so batched and per-target decisions are identical by construction.
        ``mean=None`` means no/failed prediction -> reactive fallback."""
        current_key = float(recent[-1, self.key_idx])
        key_metric = current_key
        predicted = False
        conf_ok = True
        if mean is not None:
            if is_bayesian and std is not None:
                # "confident enough over the preset threshold"
                conf_ok = float(std[self.key_idx]) <= self.conf_threshold
            if conf_ok and np.isfinite(mean[self.key_idx]):
                key_metric = float(mean[self.key_idx])
                predicted = True
        n = self.policy(key_metric, {"current": current_replicas})
        n = min(n, max_replicas)
        return EvalResult(replicas=n, key_metric=key_metric,
                          predicted=predicted, confidence_ok=conf_ok,
                          max_replicas=max_replicas, raw_prediction=mean)
