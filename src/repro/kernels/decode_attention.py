"""Flash-decode for TPU (Pallas): single-query attention against a long KV
cache.  Grid = (B, Hq, ns) with the cache-sequence axis last (sequential);
the (m, l, acc) running state is carried in VMEM scratch across cache
blocks, so an arbitrarily long cache streams through a fixed VMEM budget.
kv_valid masks cache padding (per batch row)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import compat

NEG_INF = -1e30


def _kernel(valid_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, cap, window, block_s, ns):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (1, D) row
    k = k_ref[0]                                   # (bs, D)
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)                # (1, bs)
    valid = valid_ref[0]
    k_pos = j * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
    mask = k_pos < valid
    if window is not None:
        mask &= (valid - 1 - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
    m_ref[...] = m_new

    @pl.when(j == ns - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = out[0].astype(o_ref.dtype)


def decode_attention(q, k, v, *, kv_valid, cap=None, window=None, scale=None,
                     block_s=256, interpret=False):
    """q (B, Hq, D); k, v (B, Hkv, S, D); kv_valid (B,) int32
    -> (B, Hq, D)."""
    B, Hq, D = q.shape
    _, Hkv, S, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    block_s = min(block_s, S)
    assert S % block_s == 0
    ns = S // block_s
    valid = jnp.broadcast_to(jnp.asarray(kv_valid, jnp.int32).reshape(-1),
                             (B,)).reshape(B, 1)

    kernel = functools.partial(_kernel, scale=scale, cap=cap, window=window,
                               block_s=block_s, ns=ns)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, ns),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, j: (b, 0)),
            pl.BlockSpec((1, 1, D), lambda b, h, j: (b, h, 0)),
            pl.BlockSpec((1, block_s, D), lambda b, h, j, G=G: (b * (k.shape[1]) + h // G, j, 0)),
            pl.BlockSpec((1, block_s, D), lambda b, h, j, G=G: (b * (k.shape[1]) + h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, j: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(valid, q.reshape(B, Hq, D), k.reshape(B * Hkv, S, D),
      v.reshape(B * Hkv, S, D))
    return out
