"""Flash attention for TPU (Pallas): online-softmax blocked attention with
GQA, causal / sliding-window masks and gemma2 logit soft-cap.

TPU-native layout: grid = (B·Hq, nq, nk) with the kv dimension LAST so it is
the sequential (``arbitrary``) axis — the running (m, l, acc) state lives in
VMEM scratch and persists across kv steps, exactly the HBM→VMEM streaming
structure flash attention wants on the MXU.  Block shapes are multiples of
128 on the lane dim; the q/kv tiles are the BlockSpec unit so XLA pipelines
the HBM loads behind the matmuls.

GQA is handled in the index maps (kv head = q head // G) — no materialised
repeat.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import compat

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, cap, q_offset, kv_valid,
            block_q, block_kv, nk):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal / windowed block skipping: a kv block that is entirely masked
    # contributes nothing — skip its matmuls (halves MXU work for causal,
    # makes SWA O(window) instead of masked-O(S))
    needed = jnp.bool_(True)
    if causal:
        first_q = q_offset + i * block_q          # block fully above diagonal
        needed &= j * block_kv <= first_q + block_q - 1
    if window is not None:
        first_q = q_offset + i * block_q          # block fully left of window
        needed &= (j + 1) * block_kv - 1 >= first_q - (window - 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0]                              # (bq, D)
        k = k_ref[0]                              # (bkv, D)
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if cap is not None:
            s = cap * jnp.tanh(s / cap)

        q_pos = q_offset + i * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0)
        k_pos = j * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        mask = jnp.ones((block_q, block_kv), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        if kv_valid is not None:
            mask &= k_pos < kv_valid
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                       # (bq,)
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        # fully-masked rows have m_new == NEG_INF and exp(s-m)=1: mask p
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = out.astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, cap=None,
                    q_offset=0, kv_valid=None, scale=None,
                    block_q=128, block_kv=128, interpret=False):
    """q (B, Hq, Sq, D); k, v (B, Hkv, Skv, D) -> (B, Hq, Sq, D)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    assert Sq % block_q == 0 and Skv % block_kv == 0, (Sq, Skv)
    nq, nk = Sq // block_q, Skv // block_kv

    qr = q.reshape(B * Hq, Sq, D)
    kr = k.reshape(B * Hkv, Skv, D)
    vr = v.reshape(B * Hkv, Skv, D)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, cap=cap,
        q_offset=q_offset, kv_valid=kv_valid, block_q=block_q,
        block_kv=block_kv, nk=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_kv, D), lambda h, i, j, G=G: (h // G, j, 0)),
            pl.BlockSpec((1, block_kv, D), lambda h, i, j, G=G: (h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, Hq, Sq, D)
