"""Fused block-batched Attention-Double-LSTM *sequence* kernel (Pallas) —
the second-generation forecast hot path of the PPA control plane.

``lstm_seq.py`` fused the plain whole-window LSTM; this module fuses the
Attention-Double-LSTM architecture (PAPERS.md, "Mitigating Temporal
Blindness in Kubernetes Autoscaling"): per block of batch rows, ONE
``pallas_call`` runs

1. the first LSTM pass over the W-step window, writing every hidden state
   into a (block_b, W, H) VMEM scratch history next to the (h, c)
   registers;
2. window-length temporal attention over that history — the query
   projection, the scaled-dot scores, the softmax and the reweighted
   context sequence all stay resident in VMEM (the window is small enough
   that nothing spills to HBM);
3. the second LSTM pass over the reweighted sequence plus the ReLU-dense
   head.

Two layouts, mirroring ``lstm_seq``:

* ``attn_lstm_seq``          — shared weights: xs (B, W, M) -> (B, n_out);
  gate/attention matmuls are plain GEMMs on the MXU;
* ``attn_lstm_seq_stacked``  — per-row weights with a leading target axis:
  xs (Z, W, M), every param leaf (Z, ...) -> (Z, n_out); matmuls are
  batched GEMVs via ``dot_general`` (Z independently trained per-target
  forecasters in ONE dispatch).

Both carry the checkpoint-style ``jax.custom_vjp``: the forward saves only
its inputs and the backward replays the pure-jnp reference
(``ref.attn_lstm_seq``) under ``jax.vjp`` — gradients are exactly those of
the non-Pallas formulation, so the fit paths (``_lstm_fit`` /
``lstm_fit_batch_stacked``) train through the kernel unchanged.  On CPU the
kernels run with ``interpret=True`` (CI parity vs ``ref.py``); on TPU they
compile to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import compat, ref

# dot_general dims for per-row weights: (bb, K) x (bb, K, N) -> (bb, N)
_BATCHED_GEMV = (((1,), (1,)), ((0,), (0,)))


def _gates(c, gx, gh, b, *, hidden):
    """Shared gate math: pre-activations -> (h', c') in f32."""
    gates = gx + gh + b
    i = jax.nn.sigmoid(gates[:, 0 * hidden:1 * hidden])
    f = jax.nn.sigmoid(gates[:, 1 * hidden:2 * hidden])
    g = jnp.tanh(gates[:, 2 * hidden:3 * hidden])
    o = jax.nn.sigmoid(gates[:, 3 * hidden:4 * hidden])
    c2 = f * c + i * g
    return o * jnp.tanh(c2), c2


def _attn_seq_kernel(xs_ref, wx1_ref, wh1_ref, b1_ref, wa_ref, wx2_ref,
                     wh2_ref, b2_ref, wo_ref, bo_ref, out_ref,
                     h_ref, c_ref, hs_ref, *, window, hidden):
    """Shared-weights block: xs (bb, W, M); weights whole in VMEM; the
    hidden-state history, attention scores/softmax and reweighted context
    never leave VMEM."""
    h_ref[...] = jnp.zeros_like(h_ref)
    c_ref[...] = jnp.zeros_like(c_ref)
    xs = xs_ref[...].astype(jnp.float32)
    wx1 = wx1_ref[...]
    wh1 = wh1_ref[...]
    b1 = b1_ref[...].astype(jnp.float32)
    wx2 = wx2_ref[...]
    wh2 = wh2_ref[...]
    b2 = b2_ref[...].astype(jnp.float32)

    def step1(t, carry):
        x = jax.lax.dynamic_index_in_dim(xs, t, axis=1, keepdims=False)
        gx = jax.lax.dot(x, wx1, preferred_element_type=jnp.float32)
        gh = jax.lax.dot(h_ref[...], wh1,
                         preferred_element_type=jnp.float32)
        h2, c2 = _gates(c_ref[...], gx, gh, b1, hidden=hidden)
        h_ref[...] = h2
        c_ref[...] = c2
        hs_ref[:, pl.ds(t, 1), :] = h2[:, None, :]
        return carry

    jax.lax.fori_loop(0, window, step1, 0)

    # temporal attention over the in-VMEM hidden history
    hs = hs_ref[...]                                     # (bb, W, H)
    q = jax.lax.dot(h_ref[...], wa_ref[...],
                    preferred_element_type=jnp.float32)  # (bb, H)
    scores = jnp.sum(hs * q[:, None, :], axis=-1) * (hidden ** -0.5)
    alpha = jax.nn.softmax(scores, axis=-1)              # (bb, W)
    ctx = alpha[:, :, None] * hs                         # (bb, W, H)

    # second LSTM pass over the reweighted sequence (reuse (h, c) scratch)
    h_ref[...] = jnp.zeros_like(h_ref)
    c_ref[...] = jnp.zeros_like(c_ref)

    def step2(t, carry):
        a = jax.lax.dynamic_index_in_dim(ctx, t, axis=1, keepdims=False)
        gx = jax.lax.dot(a, wx2, preferred_element_type=jnp.float32)
        gh = jax.lax.dot(h_ref[...], wh2,
                         preferred_element_type=jnp.float32)
        h2, c2 = _gates(c_ref[...], gx, gh, b2, hidden=hidden)
        h_ref[...] = h2
        c_ref[...] = c2
        return carry

    jax.lax.fori_loop(0, window, step2, 0)
    head = jax.lax.dot(jax.nn.relu(h_ref[...]), wo_ref[...],
                       preferred_element_type=jnp.float32)
    out_ref[...] = (head + bo_ref[...].astype(jnp.float32)
                    ).astype(out_ref.dtype)


def _attn_seq_stacked_kernel(xs_ref, wx1_ref, wh1_ref, b1_ref, wa_ref,
                             wx2_ref, wh2_ref, b2_ref, wo_ref, bo_ref,
                             out_ref, h_ref, c_ref, hs_ref,
                             *, window, hidden):
    """Per-row-weights block: xs (bb, W, M), weight leaves (bb, ...); gate,
    query and head matmuls are batched GEMVs (one MXU dispatch per block,
    not one per target)."""
    h_ref[...] = jnp.zeros_like(h_ref)
    c_ref[...] = jnp.zeros_like(c_ref)
    xs = xs_ref[...].astype(jnp.float32)
    wx1 = wx1_ref[...]
    wh1 = wh1_ref[...]
    b1 = b1_ref[...].astype(jnp.float32)
    wx2 = wx2_ref[...]
    wh2 = wh2_ref[...]
    b2 = b2_ref[...].astype(jnp.float32)

    def step1(t, carry):
        x = jax.lax.dynamic_index_in_dim(xs, t, axis=1, keepdims=False)
        gx = jax.lax.dot_general(x, wx1, _BATCHED_GEMV,
                                 preferred_element_type=jnp.float32)
        gh = jax.lax.dot_general(h_ref[...], wh1, _BATCHED_GEMV,
                                 preferred_element_type=jnp.float32)
        h2, c2 = _gates(c_ref[...], gx, gh, b1, hidden=hidden)
        h_ref[...] = h2
        c_ref[...] = c2
        hs_ref[:, pl.ds(t, 1), :] = h2[:, None, :]
        return carry

    jax.lax.fori_loop(0, window, step1, 0)

    hs = hs_ref[...]                                     # (bb, W, H)
    q = jax.lax.dot_general(h_ref[...], wa_ref[...], _BATCHED_GEMV,
                            preferred_element_type=jnp.float32)
    scores = jnp.sum(hs * q[:, None, :], axis=-1) * (hidden ** -0.5)
    alpha = jax.nn.softmax(scores, axis=-1)
    ctx = alpha[:, :, None] * hs

    h_ref[...] = jnp.zeros_like(h_ref)
    c_ref[...] = jnp.zeros_like(c_ref)

    def step2(t, carry):
        a = jax.lax.dynamic_index_in_dim(ctx, t, axis=1, keepdims=False)
        gx = jax.lax.dot_general(a, wx2, _BATCHED_GEMV,
                                 preferred_element_type=jnp.float32)
        gh = jax.lax.dot_general(h_ref[...], wh2, _BATCHED_GEMV,
                                 preferred_element_type=jnp.float32)
        h2, c2 = _gates(c_ref[...], gx, gh, b2, hidden=hidden)
        h_ref[...] = h2
        c_ref[...] = c2
        return carry

    jax.lax.fori_loop(0, window, step2, 0)
    head = jax.lax.dot_general(jax.nn.relu(h_ref[...]), wo_ref[...],
                               _BATCHED_GEMV,
                               preferred_element_type=jnp.float32)
    out_ref[...] = (head + bo_ref[...].astype(jnp.float32)
                    ).astype(out_ref.dtype)


def _pad_rows(arrs, pad: int):
    if not pad:
        return arrs
    return [jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
            for a in arrs]


def _attn_seq_pallas(Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo, xs,
                     *, block_b, interpret):
    B, W, M = xs.shape
    H = Wh1.shape[0]
    n_out = Wo.shape[1]
    if B == 0:          # empty batch: match the scan path's contract
        return jnp.zeros((0, n_out), xs.dtype)
    block_b = max(min(block_b, B), 1)
    pad = (-B) % block_b
    xs, = _pad_rows([xs], pad)
    nb = xs.shape[0] // block_b
    kernel = functools.partial(_attn_seq_kernel, window=W, hidden=H)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_b, W, M), lambda i: (i, 0, 0)),
            pl.BlockSpec((M, 4 * H), lambda i: (0, 0)),
            pl.BlockSpec((H, 4 * H), lambda i: (0, 0)),
            pl.BlockSpec((4 * H,), lambda i: (0,)),
            pl.BlockSpec((H, H), lambda i: (0, 0)),
            pl.BlockSpec((H, 4 * H), lambda i: (0, 0)),
            pl.BlockSpec((H, 4 * H), lambda i: (0, 0)),
            pl.BlockSpec((4 * H,), lambda i: (0,)),
            pl.BlockSpec((H, n_out), lambda i: (0, 0)),
            pl.BlockSpec((n_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xs.shape[0], n_out), xs.dtype),
        scratch_shapes=[pltpu.VMEM((block_b, H), jnp.float32),
                        pltpu.VMEM((block_b, H), jnp.float32),
                        pltpu.VMEM((block_b, W, H), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(xs, Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo)
    return out[:B]


def _attn_seq_stacked_pallas(Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo, xs,
                             *, block_b, interpret):
    Z, W, M = xs.shape
    H = Wh1.shape[1]
    n_out = Wo.shape[2]
    if Z == 0:          # empty batch: match the vmap path's contract
        return jnp.zeros((0, n_out), xs.dtype)
    block_b = max(min(block_b, Z), 1)
    pad = (-Z) % block_b
    xs, Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo = _pad_rows(
        [xs, Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo], pad)
    nb = xs.shape[0] // block_b
    kernel = functools.partial(_attn_seq_stacked_kernel, window=W, hidden=H)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_b, W, M), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, M, 4 * H), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, H, 4 * H), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, 4 * H), lambda i: (i, 0)),
            pl.BlockSpec((block_b, H, H), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, H, 4 * H), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, H, 4 * H), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, 4 * H), lambda i: (i, 0)),
            pl.BlockSpec((block_b, H, n_out), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, n_out), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xs.shape[0], n_out), xs.dtype),
        scratch_shapes=[pltpu.VMEM((block_b, H), jnp.float32),
                        pltpu.VMEM((block_b, H), jnp.float32),
                        pltpu.VMEM((block_b, W, H), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(xs, Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo)
    return out[:Z]


# ------------------------------------------------------------- autodiff ---
# Checkpoint-style custom VJP, identical in shape to lstm_seq's: forward =
# the fused kernel, residuals = the raw inputs, backward = jax.vjp over the
# pure-jnp reference — no hand-written backward kernel, gradients exactly
# the non-Pallas formulation's.

@functools.partial(jax.custom_vjp, nondiff_argnums=(10, 11))
def _attn_seq_vjp(Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo, xs,
                  block_b, interpret):
    return _attn_seq_pallas(Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo, xs,
                            block_b=block_b, interpret=interpret)


def _attn_seq_fwd(Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo, xs,
                  block_b, interpret):
    out = _attn_seq_pallas(Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo, xs,
                           block_b=block_b, interpret=interpret)
    return out, (Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo, xs)


def _attn_seq_bwd(block_b, interpret, res, g):
    _, vjp = jax.vjp(ref.attn_lstm_seq, *res)
    return vjp(g)


_attn_seq_vjp.defvjp(_attn_seq_fwd, _attn_seq_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(10, 11))
def _attn_seq_stacked_vjp(Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo, xs,
                          block_b, interpret):
    return _attn_seq_stacked_pallas(Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo,
                                    xs, block_b=block_b, interpret=interpret)


def _attn_seq_stacked_fwd(Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo, xs,
                          block_b, interpret):
    out = _attn_seq_stacked_pallas(Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo,
                                   xs, block_b=block_b, interpret=interpret)
    return out, (Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo, xs)


def _attn_seq_stacked_bwd(block_b, interpret, res, g):
    _, vjp = jax.vjp(ref.attn_lstm_seq_stacked, *res)
    return vjp(g)


_attn_seq_stacked_vjp.defvjp(_attn_seq_stacked_fwd, _attn_seq_stacked_bwd)


# --------------------------------------------------------------- public ---
def attn_lstm_seq(Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo, xs,
                  *, block_b: int = 128, interpret: bool = False):
    """xs (B, W, M); Wx1 (M, 4H); Wh1/Wh2 (H, 4H); Wa (H, H); Wx2 (H, 4H);
    b1/b2 (4H,); Wo (H, n_out); bo (n_out,) -> (B, n_out).  Whole-window
    Attention-Double-LSTM + ReLU-dense head, one fused kernel;
    differentiable (checkpoint-style custom VJP)."""
    return _attn_seq_vjp(Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo, xs,
                         block_b, interpret)


def attn_lstm_seq_stacked(Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo, xs,
                          *, block_b: int = 32, interpret: bool = False):
    """Per-target layout: xs (Z, W, M) and a leading Z axis on every weight
    leaf -> (Z, n_out).  Z independently parameterised Attention-Double-
    LSTMs answered by ONE fused kernel (batched-GEMV matmuls per block)."""
    return _attn_seq_stacked_vjp(Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo,
                                 xs, block_b, interpret)
