"""Pallas TPU API compatibility.

JAX renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and
back-compat aliases have shifted between releases).  Every kernel imports
``CompilerParams`` from here so the repo runs on either side of the rename.
"""
from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
