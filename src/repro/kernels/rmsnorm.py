"""Fused RMSNorm for TPU (Pallas): one pass — f32 variance reduction and
scale applied in VMEM, bf16 in/out (the XLA path materialises the f32
upcast; see EXPERIMENTS.md §Perf iteration 1)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import compat


def _kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)            # (rows, D)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x, w, *, eps=1e-6, block_rows=256, interpret=False):
    """x (R, D), w (D,) -> (R, D)."""
    R, D = x.shape
    block_rows = min(block_rows, R)
    pad = (-R) % block_rows
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    nb = x.shape[0] // block_rows
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], D), x.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, w)
    return out[:R]
