"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels run with ``interpret=True`` (Pallas
executes the kernel body in Python for numerical validation); on a TPU
backend they compile to Mosaic.  ``KERNEL_INTERPRET`` can be forced for
tests.
"""
from __future__ import annotations

import functools

import jax

# Direct-from-module imports (not package-attribute submodule imports):
# the package __init__ rebinds names like ``lstm_seq`` to these jitted
# wrappers, so the submodule attributes of the same name must never be
# relied on after package init.
from repro.kernels.flash_attention import flash_attention as _fa_impl
from repro.kernels.decode_attention import decode_attention as _da_impl
from repro.kernels.ssd_scan import ssd_scan as _ssd_impl
from repro.kernels.lstm_cell import lstm_cell as _lstm_cell_impl
from repro.kernels.lstm_seq import (lstm_seq as _lseq_impl,
                                    lstm_seq_stacked as _lseq_stacked_impl)
from repro.kernels.attn_lstm_seq import (
    attn_lstm_seq as _aseq_impl,
    attn_lstm_seq_stacked as _aseq_stacked_impl)
from repro.kernels.rmsnorm import rmsnorm as _rms_impl


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "cap", "q_offset", "kv_valid", "scale",
    "block_q", "block_kv"))
def flash_attention(q, k, v, *, causal=True, window=None, cap=None,
                    q_offset=0, kv_valid=None, scale=None,
                    block_q=128, block_kv=128):
    return _fa_impl(
        q, k, v, causal=causal, window=window, cap=cap, q_offset=q_offset,
        kv_valid=kv_valid, scale=scale, block_q=block_q, block_kv=block_kv,
        interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("cap", "window", "scale",
                                             "block_s"))
def decode_attention(q, k, v, kv_valid, *, cap=None, window=None, scale=None,
                     block_s=256):
    return _da_impl(q, k, v, kv_valid=kv_valid, cap=cap,
                    window=window, scale=scale, block_s=block_s,
                    interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, Bm, Cm, D, *, chunk=128):
    return _ssd_impl(x, dt, A, Bm, Cm, D, chunk=chunk,
                     interpret=_interpret())


@jax.jit
def lstm_cell(Wx, Wh, b, h, c, x):
    return _lstm_cell_impl(Wx, Wh, b, h, c, x, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_b",))
def lstm_seq(Wx, Wh, b, Wo, bo, xs, *, block_b=128):
    """Fused whole-window LSTM + ReLU-dense head, shared weights:
    xs (B, W, M) -> (B, n_out).  Differentiable (custom VJP)."""
    return _lseq_impl(Wx, Wh, b, Wo, bo, xs, block_b=block_b,
                      interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_b",))
def lstm_seq_stacked(Wx, Wh, b, Wo, bo, xs, *, block_b=32):
    """Fused whole-window forward for Z stacked per-target LSTMs (leading
    Z axis on xs and every weight leaf) — ONE kernel dispatch per tick."""
    return _lseq_stacked_impl(Wx, Wh, b, Wo, bo, xs, block_b=block_b,
                              interpret=_interpret())


def lstm_seq_stacked_local(Wx, Wh, b, Wo, bo, xs, *, block_b=32):
    """Unjitted ``lstm_seq_stacked`` body for callers that own the jit
    boundary — in particular ``shard_map`` programs (the multi-device
    control plane, core/device_plane.py), where the kernel must trace on
    the per-device LOCAL block shapes rather than behind a nested jit.
    Backend interpret resolution is identical to the jitted wrapper."""
    return _lseq_stacked_impl(Wx, Wh, b, Wo, bo, xs, block_b=block_b,
                              interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_b",))
def attn_lstm_seq(Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo, xs, *,
                  block_b=128):
    """Fused Attention-Double-LSTM + ReLU-dense head, shared weights:
    xs (B, W, M) -> (B, n_out).  Differentiable (custom VJP)."""
    return _aseq_impl(Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo, xs,
                      block_b=block_b, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_b",))
def attn_lstm_seq_stacked(Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo, xs, *,
                          block_b=32):
    """Fused Attention-Double-LSTM forward for Z stacked per-target models
    (leading Z axis on xs and every weight leaf) — ONE kernel dispatch per
    tick per shard."""
    return _aseq_stacked_impl(Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo, xs,
                              block_b=block_b, interpret=_interpret())


def attn_lstm_seq_stacked_local(Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo, xs,
                                *, block_b=32):
    """Unjitted ``attn_lstm_seq_stacked`` body for callers that own the jit
    boundary (``shard_map`` programs — the multi-device control plane),
    mirroring ``lstm_seq_stacked_local``."""
    return _aseq_stacked_impl(Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo, xs,
                              block_b=block_b, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("eps",))
def rmsnorm(x, w, *, eps=1e-6):
    return _rms_impl(x, w, eps=eps, interpret=_interpret())
