"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels run with ``interpret=True`` (Pallas
executes the kernel body in Python for numerical validation); on a TPU
backend they compile to Mosaic.  ``KERNEL_INTERPRET`` can be forced for
tests.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import decode_attention as _da
from repro.kernels import ssd_scan as _ssd
from repro.kernels import lstm_cell as _lstm
from repro.kernels import lstm_seq as _lseq
from repro.kernels import rmsnorm as _rms


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "cap", "q_offset", "kv_valid", "scale",
    "block_q", "block_kv"))
def flash_attention(q, k, v, *, causal=True, window=None, cap=None,
                    q_offset=0, kv_valid=None, scale=None,
                    block_q=128, block_kv=128):
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, cap=cap, q_offset=q_offset,
        kv_valid=kv_valid, scale=scale, block_q=block_q, block_kv=block_kv,
        interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("cap", "window", "scale",
                                             "block_s"))
def decode_attention(q, k, v, kv_valid, *, cap=None, window=None, scale=None,
                     block_s=256):
    return _da.decode_attention(q, k, v, kv_valid=kv_valid, cap=cap,
                                window=window, scale=scale, block_s=block_s,
                                interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, Bm, Cm, D, *, chunk=128):
    return _ssd.ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk,
                         interpret=_interpret())


@jax.jit
def lstm_cell(Wx, Wh, b, h, c, x):
    return _lstm.lstm_cell(Wx, Wh, b, h, c, x, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_b",))
def lstm_seq(Wx, Wh, b, Wo, bo, xs, *, block_b=128):
    """Fused whole-window LSTM + ReLU-dense head, shared weights:
    xs (B, W, M) -> (B, n_out).  Differentiable (custom VJP)."""
    return _lseq.lstm_seq(Wx, Wh, b, Wo, bo, xs, block_b=block_b,
                          interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_b",))
def lstm_seq_stacked(Wx, Wh, b, Wo, bo, xs, *, block_b=32):
    """Fused whole-window forward for Z stacked per-target LSTMs (leading
    Z axis on xs and every weight leaf) — ONE kernel dispatch per tick."""
    return _lseq.lstm_seq_stacked(Wx, Wh, b, Wo, bo, xs, block_b=block_b,
                                  interpret=_interpret())


def lstm_seq_stacked_local(Wx, Wh, b, Wo, bo, xs, *, block_b=32):
    """Unjitted ``lstm_seq_stacked`` body for callers that own the jit
    boundary — in particular ``shard_map`` programs (the multi-device
    control plane, core/device_plane.py), where the kernel must trace on
    the per-device LOCAL block shapes rather than behind a nested jit.
    Backend interpret resolution is identical to the jitted wrapper."""
    return _lseq.lstm_seq_stacked(Wx, Wh, b, Wo, bo, xs, block_b=block_b,
                                  interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("eps",))
def rmsnorm(x, w, *, eps=1e-6):
    return _rms.rmsnorm(x, w, eps=eps, interpret=_interpret())
