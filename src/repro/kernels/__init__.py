# Pallas TPU kernels for the serving/training substrate's compute hot spots
# (+ ops.py jit wrappers, ref.py pure-jnp oracles).  Validated on CPU with
# interpret=True; TPU is the compile target (BlockSpec/VMEM tiling).
