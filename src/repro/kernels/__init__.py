# Pallas TPU kernels for the serving/training substrate's compute hot spots
# (+ ops.py jit wrappers, ref.py pure-jnp oracles).  Validated on CPU with
# interpret=True; TPU is the compile target (BlockSpec/VMEM tiling).
#
# The public fused-kernel entry points are re-exported here so callers can
# write ``from repro.kernels import lstm_seq, attn_lstm_seq`` instead of
# deep-module imports.  The assignments below intentionally rebind the
# package attributes the import system pointed at the implementation
# submodules of the same name, so those names are the jitted callables —
# internal code therefore imports implementations by full module path
# (see ops.py), never through package attributes.
from repro.kernels import compat, ref
from repro.kernels import ops as _ops

flash_attention = _ops.flash_attention
decode_attention = _ops.decode_attention
ssd_scan = _ops.ssd_scan
lstm_cell = _ops.lstm_cell
lstm_seq = _ops.lstm_seq
lstm_seq_stacked = _ops.lstm_seq_stacked
attn_lstm_seq = _ops.attn_lstm_seq
attn_lstm_seq_stacked = _ops.attn_lstm_seq_stacked
rmsnorm = _ops.rmsnorm

__all__ = [
    "compat", "ref",
    "flash_attention", "decode_attention", "ssd_scan", "lstm_cell",
    "lstm_seq", "lstm_seq_stacked",
    "attn_lstm_seq", "attn_lstm_seq_stacked",
    "rmsnorm",
]
