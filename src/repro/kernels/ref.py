"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention(q, k, v, *, causal=True, window=None, cap=None,
                    q_offset=0, kv_valid=None, scale=None):
    """q (B, Hq, Sq, D); k, v (B, Hkv, Skv, D) -> (B, Hq, Sq, D)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kk,
                   preferred_element_type=jnp.float32) * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    m = jnp.ones((Sq, Skv), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    if kv_valid is not None:
        m &= k_pos[None, :] < kv_valid
    s = jnp.where(m[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # attention over an empty (fully-masked) key set is defined as 0
    any_valid = m.any(axis=-1)[None, None, :, None]
    o = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), vv)
    return jnp.where(any_valid, o, 0.0).astype(q.dtype)


def decode_attention(q, k, v, *, kv_valid, cap=None, window=None, scale=None):
    """q (B, Hq, D); k, v (B, Hkv, S, D); kv_valid (B,) -> (B, Hq, D)."""
    B, Hq, D = q.shape
    _, Hkv, S, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhd,bhkd->bhk", q, kk,
                   preferred_element_type=jnp.float32) * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    k_pos = jnp.arange(S)
    m = k_pos[None, :] < jnp.asarray(kv_valid).reshape(-1, 1)    # (B, S)
    if window is not None:
        q_pos = jnp.asarray(kv_valid).reshape(-1, 1) - 1
        m &= (q_pos - k_pos[None, :]) < window
    s = jnp.where(m[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p.astype(v.dtype), vv).astype(q.dtype)


def ssd_scan(x, dt, A, Bm, Cm, D, h0=None):
    """Sequential SSD recurrence (the exact oracle, no chunking).
    x (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,N), D (H,)
    -> y (B,S,H,P), h_final (B,H,P,N)."""
    Bb, S, H, P = x.shape
    N = Bm.shape[-1]
    h = (jnp.zeros((Bb, H, P, N), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))

    def step(h, inp):
        xt, dtt, Bt, Ct = inp      # (B,H,P), (B,H), (B,N), (B,N)
        da = jnp.exp(dtt * A)      # (B,H)
        hx = jnp.einsum("bhp,bn->bhpn",
                        (xt * dtt[..., None]).astype(jnp.float32),
                        Bt.astype(jnp.float32))
        h = da[:, :, None, None] * h + hx
        y = jnp.einsum("bhpn,bn->bhp", h, Ct.astype(jnp.float32))
        return h, y

    h, ys = jax.lax.scan(
        step, h, (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
                  Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2, 3) + D[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), h


def lstm_cell(Wx, Wh, b, h, c, x):
    """x (B, In), h/c (B, H) -> (h', c')."""
    gates = x @ Wx + h @ Wh + b
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c2 = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h2 = jax.nn.sigmoid(o) * jnp.tanh(c2)
    return h2, c2


def lstm_seq(Wx, Wh, b, Wo, bo, xs):
    """xs (B, W, M) -> (B, n_out): whole-window LSTM scan + ReLU-dense
    head — op-for-op the forecaster's non-Pallas ``lstm_forward``, so the
    fused sequence kernel's custom-VJP backward (which replays this under
    ``jax.vjp``) yields exactly the non-Pallas gradients."""
    B = xs.shape[0]
    H = Wh.shape[0]
    h = jnp.zeros((B, H))
    c = jnp.zeros((B, H))

    def step(carry, x):
        h, c = carry
        gates = x @ Wx + h @ Wh + b
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), None

    (h, c), _ = jax.lax.scan(step, (h, c), jnp.swapaxes(xs, 0, 1))
    return jax.nn.relu(h) @ Wo + bo


def lstm_seq_stacked(Wx, Wh, b, Wo, bo, xs):
    """Per-target layout: xs (Z, W, M), weight leaves with a leading Z
    axis -> (Z, n_out) — the vmapped-per-target oracle."""
    def one(wx, wh, bb, wo, bo_, x):
        return lstm_seq(wx, wh, bb, wo, bo_, x[None])[0]
    return jax.vmap(one)(Wx, Wh, b, Wo, bo, xs)


def attn_lstm_seq(Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo, xs):
    """Attention-Double-LSTM forward (the temporal-attention forecaster,
    PAPERS.md "Mitigating Temporal Blindness"): xs (B, W, M) -> (B, n_out).

    Three stages, op-for-op the forecaster's non-Pallas ``_attn_body`` (so
    the fused kernel's custom-VJP backward, which replays this under
    ``jax.vjp``, yields exactly the non-Pallas gradients):

    1. first LSTM scan over the window, keeping every hidden state
       ``hs`` (B, W, H);
    2. window-length temporal attention: query = final hidden state
       projected by ``Wa``; scores = scaled dot against each ``hs``
       step; softmax over the window; the attention weights reweight the
       hidden sequence (the per-step context);
    3. second LSTM scan over the reweighted sequence + ReLU-dense head.
    """
    B = xs.shape[0]
    H = Wh1.shape[0]
    h = jnp.zeros((B, H))
    c = jnp.zeros((B, H))

    def step1(carry, x):
        h, c = carry
        gates = x @ Wx1 + h @ Wh1 + b1
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    (h1, _), hs = jax.lax.scan(step1, (h, c), jnp.swapaxes(xs, 0, 1))
    hs = jnp.swapaxes(hs, 0, 1)                      # (B, W, H)
    q = h1 @ Wa                                      # (B, H)
    scores = jnp.sum(hs * q[:, None, :], axis=-1) * (H ** -0.5)
    alpha = jax.nn.softmax(scores, axis=-1)          # (B, W)
    ctx = alpha[:, :, None] * hs                     # reweighted sequence

    h = jnp.zeros((B, H))
    c = jnp.zeros((B, H))

    def step2(carry, a):
        h, c = carry
        gates = a @ Wx2 + h @ Wh2 + b2
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), None

    (h2, _), _ = jax.lax.scan(step2, (h, c), jnp.swapaxes(ctx, 0, 1))
    return jax.nn.relu(h2) @ Wo + bo


def attn_lstm_seq_stacked(Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo, xs):
    """Per-target layout: xs (Z, W, M), every weight leaf with a leading Z
    axis -> (Z, n_out) — the vmapped-per-target oracle."""
    def one(wx1, wh1, bb1, wa, wx2, wh2, bb2, wo, bo_, x):
        return attn_lstm_seq(wx1, wh1, bb1, wa, wx2, wh2, bb2, wo, bo_,
                             x[None])[0]
    return jax.vmap(one)(Wx1, Wh1, b1, Wa, Wx2, Wh2, b2, Wo, bo, xs)


def rmsnorm(x, w, eps=1e-6):
    """x (R, D), w (D,) -> (R, D)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
            * w.astype(jnp.float32)).astype(x.dtype)
