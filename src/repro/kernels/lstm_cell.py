"""Fused LSTM cell for TPU (Pallas) — the PPA forecaster's hot loop.

One kernel fuses both gate matmuls (x·Wx + h·Wh + b) and the four gate
nonlinearities, so the (B, 4H) gate tensor never round-trips through HBM
(the Keras/XLA version materialises it).  Batch rows are tiled on the grid;
weights are small enough (H=50 for the paper's model) to sit whole in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import compat


def _kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref, h2_ref, c2_ref, *,
            hidden):
    x = x_ref[...]
    h = h_ref[...]
    c = c_ref[...].astype(jnp.float32)
    gates = (jax.lax.dot(x, wx_ref[...], preferred_element_type=jnp.float32)
             + jax.lax.dot(h, wh_ref[...], preferred_element_type=jnp.float32)
             + b_ref[...].astype(jnp.float32))
    i = jax.nn.sigmoid(gates[:, 0 * hidden:1 * hidden])
    f = jax.nn.sigmoid(gates[:, 1 * hidden:2 * hidden])
    g = jnp.tanh(gates[:, 2 * hidden:3 * hidden])
    o = jax.nn.sigmoid(gates[:, 3 * hidden:4 * hidden])
    c2 = f * c + i * g
    h2 = o * jnp.tanh(c2)
    h2_ref[...] = h2.astype(h2_ref.dtype)
    c2_ref[...] = c2.astype(c2_ref.dtype)


def lstm_cell(Wx, Wh, b, h, c, x, *, block_b=128, interpret=False):
    """x (B, In); h, c (B, H); Wx (In, 4H); Wh (H, 4H); b (4H,)
    -> (h', c')."""
    B, In = x.shape
    H = Wh.shape[0]
    block_b = min(block_b, B)
    pad = (-B) % block_b
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        h = jnp.pad(h, ((0, pad), (0, 0)))
        c = jnp.pad(c, ((0, pad), (0, 0)))
    nb = x.shape[0] // block_b
    kernel = functools.partial(_kernel, hidden=H)
    h2, c2 = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_b, In), lambda i: (i, 0)),
            pl.BlockSpec((block_b, H), lambda i: (i, 0)),
            pl.BlockSpec((block_b, H), lambda i: (i, 0)),
            pl.BlockSpec((In, 4 * H), lambda i: (0, 0)),
            pl.BlockSpec((H, 4 * H), lambda i: (0, 0)),
            pl.BlockSpec((4 * H,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, H), lambda i: (i, 0)),
            pl.BlockSpec((block_b, H), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((x.shape[0], H), h.dtype),
            jax.ShapeDtypeStruct((x.shape[0], H), c.dtype),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, h, c, Wx, Wh, b)
    return h2[:B], c2[:B]
