"""Fused block-batched LSTM *sequence* kernel for TPU (Pallas) — the
stacked forecast/fit hot path of the PPA control plane.

``lstm_cell.py`` fuses one timestep; the stacked per-target forward
(``_lstm_forward_stacked``) still re-dispatched it W times per tick through
a vmapped ``lax.scan``, so a Z-target tick cost Z×W kernel launches and the
(h, c) state round-tripped through HBM between steps.  This module fuses
the WHOLE window: one ``pallas_call`` grids over batch blocks (``block_b``
rows = stacked Z targets, E×Z ensemble members, or N training windows),
keeps (h, c) resident in VMEM scratch across an in-kernel ``fori_loop``
over the W timesteps, and fuses the input/hidden GEMMs, the four gate
nonlinearities and the ReLU-dense head per block — one kernel per tick per
shard.

Two layouts:

* ``lstm_seq``          — shared weights: xs (B, W, M) -> (B, n_out); the
  gate matmuls are plain (B, M)@(M, 4H) GEMMs on the MXU (the shared-model
  ``predict_batch`` and every fit-path forward);
* ``lstm_seq_stacked``  — per-row weights with a leading target axis:
  xs (Z, W, M), every param leaf (Z, ...) -> (Z, n_out); the gate matmuls
  are batched GEMVs expressed as ``dot_general`` with a batch dimension
  (Z independently trained per-target LSTMs in ONE dispatch).

Both are differentiable via ``jax.custom_vjp`` with a checkpoint-style
backward: the forward saves only its inputs and the backward replays the
pure-jnp reference (``ref.lstm_seq``) under ``jax.vjp`` — gradients are
exactly those of the non-Pallas formulation, so the fit path
(``_lstm_fit`` / ``lstm_fit_batch_stacked``) trains through the kernel
unchanged.  On CPU the kernels run with ``interpret=True`` (CI parity
tests vs ``ref.py``); on TPU they compile to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import compat, ref

# dot_general dims for per-row weights: (bb, K) x (bb, K, N) -> (bb, N)
_BATCHED_GEMV = (((1,), (1,)), ((0,), (0,)))


def _gates_step(c, gx, gh, b, *, hidden):
    """Shared gate math: pre-activations -> (h', c') in f32."""
    gates = gx + gh + b
    i = jax.nn.sigmoid(gates[:, 0 * hidden:1 * hidden])
    f = jax.nn.sigmoid(gates[:, 1 * hidden:2 * hidden])
    g = jnp.tanh(gates[:, 2 * hidden:3 * hidden])
    o = jax.nn.sigmoid(gates[:, 3 * hidden:4 * hidden])
    c2 = f * c + i * g
    return o * jnp.tanh(c2), c2


def _seq_kernel(xs_ref, wx_ref, wh_ref, b_ref, wo_ref, bo_ref, out_ref,
                h_ref, c_ref, *, window, hidden):
    """Shared-weights block: xs (bb, W, M); weights whole in VMEM."""
    h_ref[...] = jnp.zeros_like(h_ref)
    c_ref[...] = jnp.zeros_like(c_ref)
    xs = xs_ref[...].astype(jnp.float32)
    wx = wx_ref[...]
    wh = wh_ref[...]
    b = b_ref[...].astype(jnp.float32)

    def step(t, carry):
        x = jax.lax.dynamic_index_in_dim(xs, t, axis=1, keepdims=False)
        gx = jax.lax.dot(x, wx, preferred_element_type=jnp.float32)
        gh = jax.lax.dot(h_ref[...], wh,
                         preferred_element_type=jnp.float32)
        h2, c2 = _gates_step(c_ref[...], gx, gh, b, hidden=hidden)
        h_ref[...] = h2
        c_ref[...] = c2
        return carry

    jax.lax.fori_loop(0, window, step, 0)
    head = jax.lax.dot(jax.nn.relu(h_ref[...]), wo_ref[...],
                       preferred_element_type=jnp.float32)
    out_ref[...] = (head + bo_ref[...].astype(jnp.float32)
                    ).astype(out_ref.dtype)


def _seq_stacked_kernel(xs_ref, wx_ref, wh_ref, b_ref, wo_ref, bo_ref,
                        out_ref, h_ref, c_ref, *, window, hidden):
    """Per-row-weights block: xs (bb, W, M), weight leaves (bb, ...); the
    gate matmuls are batched GEMVs (one MXU dispatch per block, not one
    per target)."""
    h_ref[...] = jnp.zeros_like(h_ref)
    c_ref[...] = jnp.zeros_like(c_ref)
    xs = xs_ref[...].astype(jnp.float32)
    wx = wx_ref[...]
    wh = wh_ref[...]
    b = b_ref[...].astype(jnp.float32)

    def step(t, carry):
        x = jax.lax.dynamic_index_in_dim(xs, t, axis=1, keepdims=False)
        gx = jax.lax.dot_general(x, wx, _BATCHED_GEMV,
                                 preferred_element_type=jnp.float32)
        gh = jax.lax.dot_general(h_ref[...], wh, _BATCHED_GEMV,
                                 preferred_element_type=jnp.float32)
        h2, c2 = _gates_step(c_ref[...], gx, gh, b, hidden=hidden)
        h_ref[...] = h2
        c_ref[...] = c2
        return carry

    jax.lax.fori_loop(0, window, step, 0)
    head = jax.lax.dot_general(jax.nn.relu(h_ref[...]), wo_ref[...],
                               _BATCHED_GEMV,
                               preferred_element_type=jnp.float32)
    out_ref[...] = (head + bo_ref[...].astype(jnp.float32)
                    ).astype(out_ref.dtype)


def _pad_rows(arrs, pad: int):
    if not pad:
        return arrs
    return [jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
            for a in arrs]


def _seq_pallas(Wx, Wh, b, Wo, bo, xs, *, block_b, interpret):
    B, W, M = xs.shape
    H = Wh.shape[0]
    n_out = Wo.shape[1]
    if B == 0:          # empty batch: match the scan path's contract
        return jnp.zeros((0, n_out), xs.dtype)
    block_b = max(min(block_b, B), 1)
    pad = (-B) % block_b
    xs, = _pad_rows([xs], pad)
    nb = xs.shape[0] // block_b
    kernel = functools.partial(_seq_kernel, window=W, hidden=H)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_b, W, M), lambda i: (i, 0, 0)),
            pl.BlockSpec((M, 4 * H), lambda i: (0, 0)),
            pl.BlockSpec((H, 4 * H), lambda i: (0, 0)),
            pl.BlockSpec((4 * H,), lambda i: (0,)),
            pl.BlockSpec((H, n_out), lambda i: (0, 0)),
            pl.BlockSpec((n_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xs.shape[0], n_out), xs.dtype),
        scratch_shapes=[pltpu.VMEM((block_b, H), jnp.float32),
                        pltpu.VMEM((block_b, H), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(xs, Wx, Wh, b, Wo, bo)
    return out[:B]


def _seq_stacked_pallas(Wx, Wh, b, Wo, bo, xs, *, block_b, interpret):
    Z, W, M = xs.shape
    H = Wh.shape[1]
    n_out = Wo.shape[2]
    if Z == 0:          # empty batch: match the vmap path's contract
        return jnp.zeros((0, n_out), xs.dtype)
    block_b = max(min(block_b, Z), 1)
    pad = (-Z) % block_b
    xs, Wx, Wh, b, Wo, bo = _pad_rows([xs, Wx, Wh, b, Wo, bo], pad)
    nb = xs.shape[0] // block_b
    kernel = functools.partial(_seq_stacked_kernel, window=W, hidden=H)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_b, W, M), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, M, 4 * H), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, H, 4 * H), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, 4 * H), lambda i: (i, 0)),
            pl.BlockSpec((block_b, H, n_out), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, n_out), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xs.shape[0], n_out), xs.dtype),
        scratch_shapes=[pltpu.VMEM((block_b, H), jnp.float32),
                        pltpu.VMEM((block_b, H), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(xs, Wx, Wh, b, Wo, bo)
    return out[:Z]


# ------------------------------------------------------------- autodiff ---
# Checkpoint-style custom VJP: forward = the fused kernel, residuals = the
# raw inputs, backward = jax.vjp over the pure-jnp reference.  Gradients are
# exactly the non-Pallas formulation's (ref.lstm_seq is op-for-op the
# lax.scan forward), so the fit path differentiates through the kernel
# without a hand-written backward kernel.

@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def _lstm_seq_vjp(Wx, Wh, b, Wo, bo, xs, block_b, interpret):
    return _seq_pallas(Wx, Wh, b, Wo, bo, xs, block_b=block_b,
                       interpret=interpret)


def _lstm_seq_fwd(Wx, Wh, b, Wo, bo, xs, block_b, interpret):
    out = _seq_pallas(Wx, Wh, b, Wo, bo, xs, block_b=block_b,
                      interpret=interpret)
    return out, (Wx, Wh, b, Wo, bo, xs)


def _lstm_seq_bwd(block_b, interpret, res, g):
    _, vjp = jax.vjp(ref.lstm_seq, *res)
    return vjp(g)


_lstm_seq_vjp.defvjp(_lstm_seq_fwd, _lstm_seq_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def _lstm_seq_stacked_vjp(Wx, Wh, b, Wo, bo, xs, block_b, interpret):
    return _seq_stacked_pallas(Wx, Wh, b, Wo, bo, xs, block_b=block_b,
                               interpret=interpret)


def _lstm_seq_stacked_fwd(Wx, Wh, b, Wo, bo, xs, block_b, interpret):
    out = _seq_stacked_pallas(Wx, Wh, b, Wo, bo, xs, block_b=block_b,
                              interpret=interpret)
    return out, (Wx, Wh, b, Wo, bo, xs)


def _lstm_seq_stacked_bwd(block_b, interpret, res, g):
    _, vjp = jax.vjp(ref.lstm_seq_stacked, *res)
    return vjp(g)


_lstm_seq_stacked_vjp.defvjp(_lstm_seq_stacked_fwd, _lstm_seq_stacked_bwd)


# --------------------------------------------------------------- public ---
def lstm_seq(Wx, Wh, b, Wo, bo, xs, *, block_b: int = 128,
             interpret: bool = False):
    """xs (B, W, M); Wx (M, 4H); Wh (H, 4H); b (4H,); Wo (H, n_out);
    bo (n_out,) -> (B, n_out).  Whole-window LSTM + ReLU-dense head, one
    fused kernel; differentiable (checkpoint-style custom VJP)."""
    return _lstm_seq_vjp(Wx, Wh, b, Wo, bo, xs, block_b, interpret)


def lstm_seq_stacked(Wx, Wh, b, Wo, bo, xs, *, block_b: int = 32,
                     interpret: bool = False):
    """Per-target layout: xs (Z, W, M) and a leading Z axis on every weight
    leaf -> (Z, n_out).  Z independently parameterised LSTMs answered by
    ONE fused kernel (batched-GEMV gate matmuls per block)."""
    return _lstm_seq_stacked_vjp(Wx, Wh, b, Wo, bo, xs, block_b, interpret)
