"""Mamba2 SSD (state-space duality) chunk scan for TPU (Pallas).

TPU adaptation of the SSD GPU kernel: grid = (B, H, nc) with the chunk axis
last (sequential).  Each grid step computes the intra-chunk quadratic term on
the MXU (an (L,L) masked decay-weighted C·Bᵀ matmul) and advances the
inter-chunk state recurrence — the (P, N) state lives in VMEM scratch across
chunk steps, replacing the GPU version's cross-block shared-memory carry.
No warp-level primitives are needed; the sequential grid + VMEM scratch is
the TPU-idiomatic equivalent (DESIGN.md §2).

Chunk layout requirement: x (B, H, nc, L, P); B/C shared across heads
(n_groups=1): (B, nc, L, N); dt post-softplus (B, H, nc, L); A (H,) < 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import compat


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, hf_ref,
            state_ref, *, chunk, nc):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)        # (L, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)      # (L,)
    A = a_ref[0].astype(jnp.float32)              # scalar
    Bc = b_ref[0, 0].astype(jnp.float32)          # (L, N)
    Cc = c_ref[0, 0].astype(jnp.float32)          # (L, N)
    D = d_ref[0].astype(jnp.float32)

    da = dt * A                                   # (L,)
    cum = jnp.cumsum(da)                          # (L,)
    total = cum[-1]
    xdt = x * dt[:, None]                         # (L, P)

    # intra-chunk: M[t,s] = exp(cum[t]-cum[s]) (C_t·B_s), causal
    CB = jax.lax.dot_general(Cc, Bc, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    seg = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    M = jnp.where(tri, jnp.exp(jnp.where(tri, seg, 0.0)) * CB, 0.0)
    y = jax.lax.dot_general(M, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (L, P)

    # inter-chunk contribution from carried state: (L,N)@(N,P)
    h_prev = state_ref[...]                       # (N, P)
    y = y + jax.lax.dot_general(Cc * jnp.exp(cum)[:, None], h_prev,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0, 0, 0] = (y + D * x).astype(y_ref.dtype)

    # state update: h = exp(total) h_prev + sum_s exp(total-cum[s]) B_s ⊗ xdt_s
    w = jnp.exp(total - cum)[:, None]             # (L, 1)
    upd = jax.lax.dot_general(Bc * w, xdt, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (N, P)
    state_ref[...] = jnp.exp(total) * h_prev + upd

    @pl.when(j == nc - 1)
    def _final():
        hf_ref[0, 0] = state_ref[...]


def ssd_scan(x, dt, A, Bm, Cm, D, *, chunk=128, interpret=False):
    """x (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,N), D (H,)
    -> y (B,S,H,P), h_final (B,H,N,P).  S must be a chunk multiple."""
    Bb, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0
    nc = S // chunk
    xc = x.transpose(0, 2, 1, 3).reshape(Bb, H, nc, chunk, P)
    dtc = dt.transpose(0, 2, 1).reshape(Bb, H, nc, chunk)
    Bc = Bm.reshape(Bb, nc, chunk, N)
    Cc = Cm.reshape(Bb, nc, chunk, N)

    kernel = functools.partial(_kernel, chunk=chunk, nc=nc)
    y, hf = pl.pallas_call(
        kernel,
        grid=(Bb, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, P), lambda b, h, j: (b, h, j, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1,), lambda b, h, j: (h,)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, j: (b, j, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, j: (b, j, 0, 0)),
            pl.BlockSpec((1,), lambda b, h, j: (h,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, P), lambda b, h, j: (b, h, j, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, j: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, H, nc, chunk, P), x.dtype),
            jax.ShapeDtypeStruct((Bb, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xc, dtc, A, Bc, Cc, D)
    y = y.reshape(Bb, H, S, P).transpose(0, 2, 1, 3)
    return y, hf
