"""Multi-fleet serving: several ``ServingFleet`` pools sharing one chip budget.

The single-fleet model (serving/fleet.py) bounds replicas by its own chip
budget — Algorithm 1's "max_replicas limited by system resources" with chips
as the resource.  At production scale the binding constraint moves up a
level: *many* model fleets (chat, code, embeddings, ...) contend for one
accelerator pool, and the interesting control problem is reallocating chips
*between* fleets as their load curves move out of phase.

``MultiFleetSim`` drives N fleets from one batched controller — a
``FleetController`` or, at fleet-of-fleets scale, a ``ShardedControlPlane``
(DESIGN.md §5 — one forecast dispatch per controller shard answers every
fleet per tick; the staged ``begin_tick`` / ``finish_tick`` surface is used
when the controller exposes it, so per-tick host prep overlaps the
in-flight forecast and model refits run off the tick critical path) — and
a ``ChipBudgetArbiter`` that turns the controller's per-fleet replica
demands into a feasible chip allocation each tick:

1. every fleet is granted its floor (``min_replicas`` worth of chips);
2. if the remaining demand fits the remaining budget, grant it all;
3. otherwise split the remaining chips in proportion to ``weight x excess
   demand``, in whole-replica units, largest-remainder rounding (ties by
   fleet order) — deterministic, so seeded runs reproduce exactly.

The arbiter is deliberately myopic (per-tick, no carry-over): fairness over
time comes from the forecaster seeing each fleet's future, not from debt
bookkeeping.  Grants are the *scheduling* invariant (never exceed the
budget); when a shrink drains replicas, the drained replicas finish their
in-flight requests first — the same graceful-termination transient a
Kubernetes drain has — so instantaneous live occupancy (``chips_in_use``,
``usage_log``) can briefly exceed a fleet's new grant during handover.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving.fleet import FleetConfig, ServingFleet


@dataclasses.dataclass
class FleetSpec:
    """One named fleet under the shared budget."""

    name: str
    cfg: FleetConfig
    weight: float = 1.0  # arbiter priority under contention


class ChipBudgetArbiter:
    """Deterministic per-tick chip allocation across contending fleets."""

    def __init__(self, total_chips: int):
        self.total_chips = int(total_chips)

    def allocate(
        self,
        demands: dict[str, int],
        chips_per: dict[str, int],
        floors: dict[str, int],
        weights: dict[str, float],
    ) -> dict[str, int]:
        """Map per-fleet replica demands to granted chips.

        ``demands``/``floors`` are replica counts, ``chips_per`` the chip
        cost of one replica.  Returns whole-replica chip grants summing to
        at most ``total_chips``.
        """
        names = list(demands)
        grant = {n: min(floors[n], demands[n]) * chips_per[n] for n in names}
        budget = self.total_chips - sum(grant.values())
        if budget < 0:
            raise ValueError("replica floors exceed the chip budget")
        excess = {n: max(demands[n] - floors[n], 0) * chips_per[n] for n in names}
        total_excess = sum(excess.values())
        if total_excess <= budget:
            for n in names:
                grant[n] += excess[n]
            return grant
        # contention: weighted proportional share, whole replicas only.
        # A fleet's share is capped at its own demand; the freed surplus
        # cycles back (largest remainder first) until the budget is spent
        # or every demand is met — no chips sit idle while demand is unmet.
        wsum = sum(weights[n] * excess[n] for n in names)
        shares = {n: budget * weights[n] * excess[n] / wsum for n in names}
        cap_reps = {n: excess[n] // chips_per[n] for n in names}
        extra_reps = {}
        order = []
        for n in names:
            reps = min(int(shares[n] // chips_per[n]), cap_reps[n])
            extra_reps[n] = reps
            frac = shares[n] / chips_per[n] - reps
            order.append((-frac, names.index(n), n))
        left = budget - sum(extra_reps[n] * chips_per[n] for n in names)
        order.sort()
        progressed = True
        while left > 0 and progressed:
            progressed = False
            for _, _, n in order:
                if extra_reps[n] < cap_reps[n] and left >= chips_per[n]:
                    extra_reps[n] += 1
                    left -= chips_per[n]
                    progressed = True
        for n in names:
            grant[n] += extra_reps[n] * chips_per[n]
        return grant


class MultiFleetSim:
    """N discrete-event serving fleets + one batched controller + arbiter.

    ``controller`` is a ``FleetController`` whose target names match the
    fleet spec names (its per-target ``min_replicas`` are the arbiter
    floors).  Each tick: per-fleet metrics -> one batched ``control_step``
    -> arbiter -> ``set_chip_budget`` + ``scale_to`` per fleet.
    """

    def __init__(
        self, specs: list[FleetSpec], total_chips: int, controller, batch: bool = False
    ):
        if not specs:
            raise ValueError("MultiFleetSim needs at least one fleet")
        names = {s.name for s in specs}
        if names != set(controller.target_names):
            raise ValueError("controller targets must match fleet names")
        self.specs = {s.name: s for s in specs}
        self.controller = controller
        self.arbiter = ChipBudgetArbiter(total_chips)
        # batch=True puts every fleet on the windowed drain (DESIGN.md §6):
        # with a ShardedControlPlane on top the whole sim is per-event-free
        self.batch = bool(batch)
        self.fleets = {s.name: ServingFleet(s.cfg, batch=batch) for s in specs}
        self.alloc_log: list[tuple[float, dict[str, int]]] = []
        self.usage_log: list[tuple[float, int]] = []  # live-chip occupancy
        w = {s.cfg.control_interval_s for s in specs}
        if len(w) != 1:
            raise ValueError("fleets must share one control interval")
        self.window_s = w.pop()

    def chips_in_use(self) -> int:
        return sum(
            len(f.live_replicas()) * f.cfg.chips_per_replica
            for f in self.fleets.values()
        )

    def run(
        self, requests: dict[str, list[tuple[float, int]]], t_end: float
    ) -> "MultiFleetSim":
        """``requests``: per-fleet sorted (arrival_t, n_tokens) lists."""
        ctrl = self.controller
        for n, f in self.fleets.items():
            f.set_chip_budget(self.arbiter.total_chips, 0.0)
            f.scale_to(ctrl.min_replicas(n), 0.0)
            f.make_ready_now(0.0)
        if self.batch:
            from repro.serving.fleet import _as_request_arrays

            requests = {n: _as_request_arrays(requests.get(n, [])) for n in self.fleets}
        idx = {n: 0 for n in self.fleets}
        staged = hasattr(ctrl, "begin_tick")
        ticks = np.arange(self.window_s, t_end, self.window_s)
        for tick in ticks:
            tick = float(tick)
            cur, max_r = {}, {}
            for n, f in self.fleets.items():
                f._apply_events(tick)
                idx[n] = self._dispatch_until(n, tick, idx[n], requests)
                ctrl.observe(n, f.sample(tick))
                cur[n] = len(f.live_replicas())
                max_r[n] = self.arbiter.total_chips // f.cfg.chips_per_replica
            if staged:
                # staged plane: launch the forecasts, build the arbiter
                # inputs that don't depend on decisions while they are in
                # flight, barrier only at actuation (finish_tick)
                ctrl.begin_tick(tick, max_r, cur)
            chips_per = {n: f.cfg.chips_per_replica
                         for n, f in self.fleets.items()}
            floors = {n: ctrl.min_replicas(n) for n in self.fleets}
            weights = {n: self.specs[n].weight for n in self.fleets}
            results = (ctrl.finish_tick() if staged
                       else ctrl.control_step(tick, max_r, cur))
            demands = {
                n: max(results[n].replicas, ctrl.min_replicas(n))
                for n in self.fleets
            }
            grant = self.arbiter.allocate(demands, chips_per, floors, weights)
            for n, f in self.fleets.items():
                f.set_chip_budget(grant[n], tick)
                granted_reps = grant[n] // f.cfg.chips_per_replica
                f.scale_to(min(demands[n], granted_reps), tick)
                f.replica_log.append((tick, granted_reps))
            self.alloc_log.append((tick, grant))
            self.usage_log.append((tick, self.chips_in_use()))
            ctrl.maybe_update(tick)
        for n in self.fleets:
            idx[n] = self._dispatch_until(n, t_end, idx[n], requests)
        if hasattr(ctrl, "flush_updates"):
            ctrl.flush_updates()    # barrier any refit still in flight
        return self

    def _dispatch_until(self, name, t, i, requests) -> int:
        from repro.serving.fleet import ServeRequest

        fleet = self.fleets[name]
        if self.batch:
            times, ntoks = requests[name]
            hi = int(np.searchsorted(times, t, side="right"))
            fleet.dispatch_window(times[i:hi], ntoks[i:hi])
            fleet.completed_log.seal_window()
            return hi
        reqs = requests.get(name, [])
        while i < len(reqs) and reqs[i][0] <= t:
            at, ntok = reqs[i]
            fleet.dispatch(ServeRequest(at, ntok), at)
            i += 1
        return i

    # ----------------------------------------------------------- stats ----
    def response_times(self, name: str | None = None) -> np.ndarray:
        fleets = [self.fleets[name]] if name else list(self.fleets.values())
        return np.concatenate([f.response_times() for f in fleets])

    def peak_chips(self) -> int:
        return max((sum(g.values()) for _, g in self.alloc_log), default=0)
