"""Multi-fleet serving: several ``ServingFleet`` pools sharing one chip budget.

The single-fleet model (serving/fleet.py) bounds replicas by its own chip
budget — Algorithm 1's "max_replicas limited by system resources" with chips
as the resource.  At production scale the binding constraint moves up a
level: *many* model fleets (chat, code, embeddings, ...) contend for one
accelerator pool, and the interesting control problem is reallocating chips
*between* fleets as their load curves move out of phase.

``MultiFleetSim`` drives N fleets from one batched controller — a
``FleetController`` or, at fleet-of-fleets scale, a ``ShardedControlPlane``
(DESIGN.md §5 — one forecast dispatch per controller shard answers every
fleet per tick; the staged ``begin_tick`` / ``finish_tick`` surface is used
when the controller exposes it, so per-tick host prep overlaps the
in-flight forecast and model refits run off the tick critical path) — and
a ``ChipBudgetArbiter`` that turns the controller's per-fleet replica
demands into a feasible chip allocation each tick:

1. every fleet is granted its floor (``min_replicas`` worth of chips);
2. if the remaining demand fits the remaining budget, grant it all;
3. otherwise split the remaining chips in proportion to ``weight x excess
   demand``, in whole-replica units, largest-remainder rounding (ties by
   fleet order) — deterministic, so seeded runs reproduce exactly.

The arbiter is deliberately myopic (per-tick, no carry-over): fairness over
time comes from the forecaster seeing each fleet's future, not from debt
bookkeeping.  Grants are the *scheduling* invariant (never exceed the
budget); when a shrink drains replicas, the drained replicas finish their
in-flight requests first — the same graceful-termination transient a
Kubernetes drain has — so instantaneous live occupancy (``chips_in_use``,
``usage_log``) can briefly exceed a fleet's new grant during handover.

**Columnar federation** (DESIGN.md §12): the tick loop and the arbiter both
exist twice — the original per-fleet dict path (``columnar=False``, the
parity oracle) and a columnar path that holds per-fleet cur / max / demand
/ grant state as (F,) numpy arrays, feeds the control plane one
``observe_batch`` row block + array replica bounds per tick, reads the
decisions back as one ``TickResult.replicas_array()``, and pre-buckets
every fleet's arrival stream per control window (one ``searchsorted`` over
all tick boundaries at setup, a zero-copy slice per fleet per window
after).  ``ChipBudgetArbiter.allocate_batch`` is the arbiter's (F,)-array
twin — floors / excess / weighted shares / largest-remainder rounding as
numpy ops, bitwise-identical to ``allocate`` (property-tested in
tests/test_federation.py).  One process sustains 10^6 pods across >= 64
fleets this way; above ``serving.fleet.STREAMING_POD_THRESHOLD`` replicas
each fleet's ``CompletionLog`` switches to streaming retention so memory
stays bounded (read whole-run numbers from ``completion_stats()``).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving.fleet import FleetConfig, ServingFleet


@dataclasses.dataclass
class FleetSpec:
    """One named fleet under the shared budget."""

    name: str
    cfg: FleetConfig
    weight: float = 1.0  # arbiter priority under contention


class ChipBudgetArbiter:
    """Deterministic per-tick chip allocation across contending fleets.

    ``allocate`` is the original scalar dict path; ``allocate_batch`` the
    vectorised (F,)-array twin.  Both produce bitwise-identical grants on
    the same inputs (same IEEE op order for the weighted shares, stable
    argsort == the (-frac, index) tuple sort, and an exact round-robin
    water-fill for the largest-remainder loop).
    """

    def __init__(self, total_chips: int):
        self.total_chips = int(total_chips)

    def allocate(
        self,
        demands: dict[str, int],
        chips_per: dict[str, int],
        floors: dict[str, int],
        weights: dict[str, float],
    ) -> dict[str, int]:
        """Map per-fleet replica demands to granted chips.

        ``demands``/``floors`` are replica counts, ``chips_per`` the chip
        cost of one replica.  Returns whole-replica chip grants summing to
        at most ``total_chips``.
        """
        names = list(demands)
        grant = {n: min(floors[n], demands[n]) * chips_per[n] for n in names}
        budget = self.total_chips - sum(grant.values())
        if budget < 0:
            raise ValueError("replica floors exceed the chip budget")
        excess = {n: max(demands[n] - floors[n], 0) * chips_per[n] for n in names}
        total_excess = sum(excess.values())
        if total_excess <= budget:
            for n in names:
                grant[n] += excess[n]
            return grant
        # contention: weighted proportional share, whole replicas only.
        # A fleet's share is capped at its own demand; the freed surplus
        # cycles back (largest remainder first) until the budget is spent
        # or every demand is met — no chips sit idle while demand is unmet.
        wsum = sum(weights[n] * excess[n] for n in names)
        shares = {n: budget * weights[n] * excess[n] / wsum for n in names}
        cap_reps = {n: excess[n] // chips_per[n] for n in names}
        extra_reps = {}
        order = []
        for i, n in enumerate(names):
            reps = min(int(shares[n] // chips_per[n]), cap_reps[n])
            extra_reps[n] = reps
            frac = shares[n] / chips_per[n] - reps
            order.append((-frac, i, n))
        left = budget - sum(extra_reps[n] * chips_per[n] for n in names)
        order.sort()
        progressed = True
        while left > 0 and progressed:
            progressed = False
            for _, _, n in order:
                if extra_reps[n] < cap_reps[n] and left >= chips_per[n]:
                    extra_reps[n] += 1
                    left -= chips_per[n]
                    progressed = True
        for n in names:
            grant[n] += extra_reps[n] * chips_per[n]
        return grant

    def allocate_batch(self, demands, chips_per, floors, weights) -> np.ndarray:
        """``allocate`` on (F,) arrays: one numpy program per tick instead
        of O(F) dict arithmetic.  Bitwise-identical grants: the weighted
        shares repeat the scalar path's exact IEEE op order (sequential
        ``wsum`` accumulation, ``(budget * w) * excess / wsum``), the
        remainder order is a stable argsort on ``-frac`` (== sorting
        ``(-frac, index)`` tuples), and the round-robin grant loop is
        replaced by an exact water-fill when every fleet costs the same
        chips per replica (the common case) or an index-array replay of
        the scalar loop otherwise."""
        d = np.asarray(demands, np.int64)
        c = np.asarray(chips_per, np.int64)
        fl = np.asarray(floors, np.int64)
        w = np.asarray(weights, np.float64)
        grant = np.minimum(fl, d) * c
        budget = self.total_chips - int(grant.sum())
        if budget < 0:
            raise ValueError("replica floors exceed the chip budget")
        excess = np.maximum(d - fl, 0) * c
        if int(excess.sum()) <= budget:
            return grant + excess
        # weighted proportional shares — float op order mirrors the scalar
        # path exactly: wsum is a left-to-right sequential sum (numpy's
        # pairwise np.sum would round differently), shares left-associate
        we = w * excess
        wsum = float(sum(we.tolist()))
        shares = budget * w * excess / wsum
        cap_reps = excess // c
        extra = np.minimum((shares // c).astype(np.int64), cap_reps)
        frac = shares / c - extra
        order = np.argsort(-frac, kind="stable")  # ties fall back to index
        left = budget - int((extra * c).sum())
        extra = self._remainder_rounds(extra, cap_reps, c, left, order)
        return grant + extra * c

    @staticmethod
    def _remainder_rounds(extra, cap_reps, c, left, order) -> np.ndarray:
        """The scalar path's largest-remainder round-robin, vectorised.

        Pass semantics: every fleet with headroom takes one replica per
        pass, in remainder order, while the budget covers it.  With a
        homogeneous per-replica chip cost that is exactly round-robin with
        caps = a water-fill (full level ``q``, then one extra replica for
        the first ``rem`` still-unfilled fleets in remainder order) —
        closed form, no Python loop.  Heterogeneous costs replay the
        scalar loop over an index array (O(F) per pass, no dict/name
        lookups)."""
        if left <= 0:
            return extra
        head = cap_reps - extra                 # per-fleet headroom (reps)
        if not np.any(head > 0):
            return extra
        extra = extra.copy()
        if np.all(c == c[0]):
            c0 = int(c[0])
            R = min(left // c0, int(head.sum()))  # replicas still affordable
            if R <= 0:
                return extra
            hs = np.sort(head[head > 0])
            pre = np.concatenate([[0], np.cumsum(hs)])
            m = len(hs)
            # grants after completing the pass at level hs[i]:
            # everyone below is full, the rest paid hs[i] each
            full = pre[1:] + hs * (m - 1 - np.arange(m))
            i = int(np.searchsorted(full, R, side="right"))
            if i >= m:                          # everyone fills up
                return cap_reps.copy()
            q = int(hs[i - 1]) if i else 0      # last fully completed level
            base = int(pre[i]) + q * (m - i)
            # partial passes above level q: whole rounds over the fleets
            # with headroom > q, in remainder order, then the remainder
            open_idx = order[head[order] > q]   # remainder-ordered
            extra += np.minimum(head, q)
            rounds, rem = divmod(R - base, len(open_idx))
            extra[open_idx] += rounds
            extra[open_idx[:rem]] += 1
            return extra
        # heterogeneous chip costs: exact replay of the scalar loop
        extra_l, cap_l, c_l = extra.tolist(), cap_reps.tolist(), c.tolist()
        order_l = order.tolist()
        progressed = True
        while left > 0 and progressed:
            progressed = False
            for i in order_l:
                if extra_l[i] < cap_l[i] and left >= c_l[i]:
                    extra_l[i] += 1
                    left -= c_l[i]
                    progressed = True
        return np.asarray(extra_l, np.int64)


class MultiFleetSim:
    """N discrete-event serving fleets + one batched controller + arbiter.

    ``controller`` is a ``FleetController`` (or ``ShardedControlPlane``)
    whose target names match the fleet spec names (its per-target
    ``min_replicas`` are the arbiter floors).  Each tick: per-fleet
    metrics -> one batched ``control_step`` -> arbiter ->
    ``set_chip_budget`` + ``scale_to`` per fleet.

    ``batch=True`` puts every fleet on the windowed drain (DESIGN.md §6).
    ``columnar`` picks the federation tick implementation: the (F,)-array
    loop (default) or the retained per-fleet dict loop (``False``, the
    bitwise parity oracle — tests/test_federation.py).  Both produce
    identical ``alloc_log`` / ``usage_log`` / completion sequences on
    seeded runs.
    """

    def __init__(
        self, specs: list[FleetSpec], total_chips: int, controller,
        batch: bool = False, columnar: bool | None = None,
    ):
        if not specs:
            raise ValueError("MultiFleetSim needs at least one fleet")
        names = {s.name for s in specs}
        if names != set(controller.target_names):
            raise ValueError("controller targets must match fleet names")
        self.specs = {s.name: s for s in specs}
        self.controller = controller
        self.arbiter = ChipBudgetArbiter(total_chips)
        # batch=True puts every fleet on the windowed drain (DESIGN.md §6):
        # with a ShardedControlPlane on top the whole sim is per-event-free
        self.batch = bool(batch)
        self.columnar = True if columnar is None else bool(columnar)
        self.names: list[str] = [s.name for s in specs]   # fleet order
        self.fleets = {s.name: ServingFleet(s.cfg, batch=batch) for s in specs}
        self.alloc_log: list[tuple[float, dict[str, int]]] = []
        self.usage_log: list[tuple[float, int]] = []  # live-chip occupancy
        w = {s.cfg.control_interval_s for s in specs}
        if len(w) != 1:
            raise ValueError("fleets must share one control interval")
        self.window_s = w.pop()
        # tick-invariant federation state, hoisted out of the run loop
        # (satellite of DESIGN.md §12 — the scalar path reuses the dicts,
        # the columnar path the (F,) arrays)
        self._chips_per = {n: self.specs[n].cfg.chips_per_replica
                           for n in self.names}
        self._floors = {n: controller.min_replicas(n) for n in self.names}
        self._weights = {n: self.specs[n].weight for n in self.names}
        self._max_r = {n: self.arbiter.total_chips // self._chips_per[n]
                       for n in self.names}
        self._chips_arr = np.array([self._chips_per[n] for n in self.names],
                                   np.int64)
        self._floors_arr = np.array([self._floors[n] for n in self.names],
                                    np.int64)
        self._weights_arr = np.array([self._weights[n] for n in self.names],
                                     np.float64)
        self._max_arr = self.arbiter.total_chips // self._chips_arr
        # fleet order <-> controller target order permutations
        cnames = list(controller.target_names)
        fpos = {n: i for i, n in enumerate(self.names)}
        cpos = {n: i for i, n in enumerate(cnames)}
        self._to_ctrl = np.array([fpos[n] for n in cnames], np.int64)
        self._from_ctrl = np.array([cpos[n] for n in self.names], np.int64)

    def chips_in_use(self) -> int:
        return sum(
            f.live_count() * f.cfg.chips_per_replica
            for f in self.fleets.values()
        )

    # -------------------------------------------------------------- run ----
    def run(
        self, requests: dict[str, list[tuple[float, int]]], t_end: float,
        scenario=None,
    ) -> "MultiFleetSim":
        """``requests``: per-fleet sorted (arrival_t, n_tokens) lists (or
        in batch mode ``(times, n_tokens)`` array pairs).  ``scenario``
        (a ``workloads.scenarios.ChaosScenario``) replays a seeded fault
        tape over the run — node-failure storms, exporter blackouts
        (stale republished rows), forecaster stalls, shard crashes — and
        swaps any fleet named in ``scenario.clients`` onto its closed-loop
        retry-amplifying arrival generator (batch mode only: the client
        produces one window at a time from the fleet's observed p95)."""
        ctrl = self.controller
        if scenario is not None and scenario.clients and not self.batch:
            raise ValueError("closed-loop clients need batch=True "
                             "(windowed dispatch)")
        for n, f in self.fleets.items():
            f.set_chip_budget(self.arbiter.total_chips, 0.0)
            f.scale_to(ctrl.min_replicas(n), 0.0)
            f.make_ready_now(0.0)
        if self.batch:
            from repro.serving.fleet import _as_request_arrays

            requests = {n: _as_request_arrays(requests.get(n, []))
                        for n in self.fleets}
        ticks = np.arange(self.window_s, t_end, self.window_s)
        if self.columnar:
            return self._run_columnar(requests, ticks, t_end, scenario)
        return self._run_scalar(requests, ticks, t_end, scenario)

    def _chaos_events(self, chaos, tick, black_until, ctrl):
        """Pop this tick's due chaos events and apply them: fleet-level
        node kills (lowest live rids, ceil(frac * live)), blackout windows
        (extend the republish horizon), forecaster stalls and shard
        crashes (with resilience off the shard state is simply lost — the
        exact hazard the failover path is A/B-benched against)."""
        from repro.sim import chaos as CH

        F = len(self.names)
        for ev in chaos.pop_due(tick):
            kind = int(ev["kind"])
            if kind == CH.NODE_FAIL:
                zi = int(ev["target"]) % F
                f = self.fleets[self.names[zi]]
                if f._vec:
                    live = np.flatnonzero(f._rep_live_mask()).tolist()
                else:
                    live = sorted(r.rid for r in f.replicas
                                  if not r.dead and not r.draining)
                k = int(np.ceil(float(ev["arg"]) * len(live)))
                for rid in live[:k]:
                    f.inject_failure(float(ev["t"]), int(rid))
            elif kind == CH.BLACKOUT:
                zi = int(ev["target"]) % F
                until = float(ev["t"]) + float(ev["arg"])
                black_until[zi] = max(black_until[zi], until)
            elif kind == CH.STALL:
                if hasattr(ctrl, "inject_forecast_stall"):
                    ctrl.inject_forecast_stall(float(ev["arg"]))
            elif kind == CH.SHARD_CRASH and hasattr(ctrl, "crash_shard"):
                si = int(ev["target"]) % len(ctrl.shards)
                try:
                    ctrl.crash_shard(si, int(ev["arg"]))
                except RuntimeError:
                    # no resilience armed: nothing restores the shard —
                    # its window is simply gone (the degraded-off lane)
                    shard = ctrl.shards[si]
                    if getattr(shard, "vectorized", False):
                        shard.wipe()

    def _run_scalar(self, requests, ticks, t_end,
                    scenario=None) -> "MultiFleetSim":
        """The retained per-fleet dict tick (the parity oracle)."""
        from repro.core.metrics import N_METRICS, Snapshot

        ctrl = self.controller
        idx = {n: 0 for n in self.fleets}
        staged = hasattr(ctrl, "begin_tick")
        chips_per, floors, weights = self._chips_per, self._floors, \
            self._weights
        max_r = self._max_r
        chaos = scenario.chaos if scenario is not None else None
        clients = scenario.clients if scenario is not None else {}
        F = len(self.names)
        black_until = np.full(F, -np.inf)
        last_pub = np.zeros((F, N_METRICS))
        last_p95 = {n: 0.0 for n in clients}
        for tick in ticks:
            tick = float(tick)
            if chaos is not None:
                self._chaos_events(chaos, tick, black_until, ctrl)
            cur = {}
            for i, n in enumerate(self.names):
                f = self.fleets[n]
                f._apply_events(tick)
                if n in clients:
                    ts, toks = clients[n].next_window(tick, last_p95[n])
                    f.dispatch_window(ts, toks)
                    f.seal_window()
                else:
                    idx[n] = self._dispatch_until(n, tick, idx[n], requests)
                snap = f.sample(tick)
                if n in clients:   # clients feel the REAL latency, always
                    last_p95[n] = float(snap.values[1])
                if tick <= black_until[i]:
                    # blacked-out exporter: republish the last row; the
                    # freshness clock (stale TTL) does not advance
                    ctrl.observe(n, Snapshot(tick, last_pub[i].copy()),
                                 fresh=False)
                else:
                    last_pub[i] = snap.values
                    ctrl.observe(n, snap)
                cur[n] = f.live_count()
            if staged:
                # staged plane: launch the forecasts, barrier only at
                # actuation (finish_tick)
                ctrl.begin_tick(tick, max_r, cur)
            results = (ctrl.finish_tick() if staged
                       else ctrl.control_step(tick, max_r, cur))
            demands = {
                n: max(results[n].replicas, floors[n])
                for n in self.fleets
            }
            grant = self.arbiter.allocate(demands, chips_per, floors, weights)
            for n, f in self.fleets.items():
                f.set_chip_budget(grant[n], tick)
                granted_reps = grant[n] // f.cfg.chips_per_replica
                f.scale_to(min(demands[n], granted_reps), tick)
                f.replica_log.append((tick, granted_reps))
            self.alloc_log.append((tick, grant))
            self.usage_log.append((tick, self.chips_in_use()))
            ctrl.maybe_update(tick)
        for n in self.fleets:
            idx[n] = self._dispatch_until(n, t_end, idx[n], requests)
        if hasattr(ctrl, "flush_updates"):
            ctrl.flush_updates()    # barrier any refit still in flight
        return self

    def _run_columnar(self, requests, ticks, t_end,
                      scenario=None) -> "MultiFleetSim":
        """The (F,)-array federation tick (DESIGN.md §12).

        Per tick: F windowed drains (pre-bucketed offsets — one
        ``searchsorted`` over every boundary at setup, zero-copy slices
        after), ONE ``batched_p95`` percentile pass over every fleet's
        response window, ONE ``observe_batch`` row block, ONE
        ``begin_tick`` / ``finish_tick`` with array replica bounds,
        decisions back as ONE ``replicas_array()``, ONE
        ``allocate_batch`` — no per-fleet dict is built on the hot path.
        ``alloc_log`` / ``usage_log`` keep the scalar path's exact format
        (and values, bitwise)."""
        from repro.core.metrics import N_METRICS, Snapshot
        from repro.serving.fleet import batched_p95
        from repro.workloads.fleet_scale import window_offsets

        ctrl = self.controller
        names = self.names
        fleets = [self.fleets[n] for n in names]
        F = len(fleets)
        staged = hasattr(ctrl, "begin_tick")
        batched_obs = hasattr(ctrl, "observe_batch")
        chips, floors = self._chips_arr, self._floors_arr
        to_ctrl, from_ctrl = self._to_ctrl, self._from_ctrl
        max_ctrl = self._max_arr[to_ctrl]
        max_map = self._max_r       # dict fallback (FleetController)
        chaos = scenario.chaos if scenario is not None else None
        clients = scenario.clients if scenario is not None else {}
        cl = [clients.get(n) for n in names]
        black_until = np.full(F, -np.inf)
        last_pub = np.zeros((F, N_METRICS))
        last_p95 = np.zeros(F)
        if self.batch:
            streams = [requests[n] for n in names]
            offs = [window_offsets(t, self.window_s, t_end)
                    for t, _ in streams]
        else:
            reqs = [requests.get(n, []) for n in names]
            pos = np.zeros(F, np.int64)
        rows = np.empty((F, N_METRICS), np.float64)
        cur = np.empty(F, np.int64)
        snaps = [None] * F
        for w, tick in enumerate(ticks, start=1):
            tick = float(tick)
            if chaos is not None:
                self._chaos_events(chaos, tick, black_until, ctrl)
            for i, f in enumerate(fleets):
                f._apply_events(tick)
                if cl[i] is not None:
                    ts, toks = cl[i].next_window(tick, last_p95[i])
                    f.dispatch_window(ts, toks)
                    f.seal_window()
                elif self.batch:
                    lo, hi = int(offs[i][w - 1]), int(offs[i][w])
                    times, ntoks = streams[i]
                    f.dispatch_window(times[lo:hi], ntoks[lo:hi])
                    f.seal_window()
                else:
                    pos[i] = self._dispatch_legacy(f, reqs[i], tick,
                                                   int(pos[i]))
            if self.batch:
                # ONE fused percentile across all fleets' windows
                # (bitwise == per-fleet np.percentile; the parity oracle
                # above keeps the per-fleet path)
                p95s = batched_p95([f.take_window_resp() for f in fleets])
            for i, f in enumerate(fleets):
                snaps[i] = (f.sample(tick, p95=float(p95s[i]))
                            if self.batch else f.sample(tick))
                rows[i] = snaps[i].values
                cur[i] = f.live_count()
            # closed-loop clients feel the REAL latency even when the
            # exporter is blacked out (the blackout lies to the
            # controller, not to the users)
            last_p95[:] = rows[:, 1]
            fresh = None
            if chaos is not None:
                stale_m = black_until >= tick
                if stale_m.any():
                    rows[stale_m] = last_pub[stale_m]
                    fresh = ~stale_m
                last_pub[~stale_m] = rows[~stale_m]
            if batched_obs:
                if fresh is None:
                    ctrl.observe_batch(tick, rows[to_ctrl])
                else:
                    ctrl.observe_batch(tick, rows[to_ctrl],
                                       fresh=fresh[to_ctrl])
            else:
                for i, n in enumerate(names):
                    if fresh is not None and not fresh[i]:
                        ctrl.observe(n, Snapshot(tick, rows[i].copy()),
                                     fresh=False)
                    else:
                        ctrl.observe(n, snaps[i])
            cur_ctrl = cur[to_ctrl]
            if staged:
                ctrl.begin_tick(tick, max_ctrl, cur_ctrl)
                results = ctrl.finish_tick()
            else:
                results = ctrl.control_step(
                    tick, max_map, {n: int(c) for n, c in zip(names, cur)})
            if hasattr(results, "replicas_array"):
                reps = results.replicas_array()[from_ctrl]
            else:
                reps = np.array([results[n].replicas for n in names],
                                np.int64)
            demands = np.maximum(reps, floors)
            grants = self.arbiter.allocate_batch(
                demands, chips, floors, self._weights_arr)
            granted_reps = grants // chips
            targets = np.minimum(demands, granted_reps)
            for i, f in enumerate(fleets):
                f.set_chip_budget(int(grants[i]), tick)
                f.scale_to(int(targets[i]), tick)
                f.replica_log.append((tick, int(granted_reps[i])))
            self.alloc_log.append(
                (tick, {n: int(g) for n, g in zip(names, grants)}))
            self.usage_log.append((tick, self.chips_in_use()))
            ctrl.maybe_update(tick)
        for i, f in enumerate(fleets):
            if self.batch:
                lo, hi = int(offs[i][-2]), int(offs[i][-1])
                times, ntoks = streams[i]
                f.dispatch_window(times[lo:hi], ntoks[lo:hi])
                f.seal_window()
            else:
                pos[i] = self._dispatch_legacy(f, reqs[i], t_end,
                                               int(pos[i]))
        if hasattr(ctrl, "flush_updates"):
            ctrl.flush_updates()
        return self

    # ------------------------------------------------------- dispatching ---
    def _dispatch_until(self, name, t, i, requests) -> int:
        fleet = self.fleets[name]
        if self.batch:
            times, ntoks = requests[name]
            hi = int(np.searchsorted(times, t, side="right"))
            fleet.dispatch_window(times[i:hi], ntoks[i:hi])
            fleet.seal_window()
            return hi
        return self._dispatch_legacy(fleet, requests.get(name, []), t, i)

    @staticmethod
    def _dispatch_legacy(fleet, reqs, t, i) -> int:
        from repro.serving.fleet import ServeRequest

        while i < len(reqs) and reqs[i][0] <= t:
            at, ntok = reqs[i]
            fleet.dispatch(ServeRequest(at, ntok), at)
            i += 1
        return i

    # ----------------------------------------------------------- stats ----
    def response_times(self, name: str | None = None) -> np.ndarray:
        """Response times across fleets (or one fleet).  Zero-completion
        fleets contribute nothing; the all-empty case returns a typed
        empty array instead of tripping ``np.concatenate``.  Streaming
        fleets only retain their trailing windows — use
        ``completion_stats()`` for whole-run numbers there."""
        fleets = [self.fleets[name]] if name else list(self.fleets.values())
        parts = [np.asarray(f.response_times(), np.float64) for f in fleets]
        parts = [p for p in parts if p.size]
        if not parts:
            return np.zeros(0, np.float64)
        return parts[0] if len(parts) == 1 else np.concatenate(parts)

    def peak_chips(self) -> int:
        return int(max((sum(g.values()) for _, g in self.alloc_log),
                       default=0))

    def completion_stats(self) -> dict:
        """Whole-run completion aggregate across every fleet — exact in
        streaming mode (fold of the per-fleet ``CompletionLog.totals()``;
        the batch path's substitute for materialising 10^7+ response
        times at 10^6 pods)."""
        from repro.sim.core import CompletionLog

        totals = []
        for f in self.fleets.values():
            if f.completed_log is not None:
                totals.append(f.completed_log.totals())
            else:
                resp = np.asarray(f.response_times(), np.float64)
                totals.append((
                    len(f.completed),
                    sum(1 for r in f.completed if r.redispatched),
                    float(resp.sum()), float((resp * resp).sum()),
                    float(resp.min()) if resp.size else np.inf,
                    float(resp.max()) if resp.size else -np.inf))
        agg = (sum(t[0] for t in totals), sum(t[1] for t in totals),
               sum(t[2] for t in totals), sum(t[3] for t in totals),
               min((t[4] for t in totals), default=np.inf),
               max((t[5] for t in totals), default=-np.inf))
        return CompletionLog._stats_dict(agg)
