from repro.serving.engine import DecodeEngine
from repro.serving.batcher import ContinuousBatcher, Request
from repro.serving.fleet import ServingFleet, FleetConfig
from repro.serving.multi_fleet import (ChipBudgetArbiter, FleetSpec,
                                       MultiFleetSim)
