"""Batched decode engine with slot-based continuous batching.

One engine instance == one model replica (a model-axis mesh slice in
production).  The KV cache holds ``slots`` independent sequences with
per-slot lengths; requests are prefilled row-by-row and scattered into free
slots, decode steps advance every active slot at once, and finished slots
are recycled without stalling the rest of the batch — vLLM-style continuous
batching on a static JAX buffer.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.registry import build_model
from repro.models.transformer import init_decode_cache


@dataclasses.dataclass
class SlotState:
    request_id: int = -1
    remaining: int = 0
    generated: list = dataclasses.field(default_factory=list)

    @property
    def active(self) -> bool:
        return self.request_id >= 0


class DecodeEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 8,
                 max_len: int = 512, mesh=None, rules=None, temperature=0.0,
                 seed: int = 0):
        assert cfg.family != "encdec", "use EncDecEngine for enc-dec models"
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.mesh, self.rules = mesh, rules
        self.model = build_model(cfg)
        self.temperature = temperature
        self.rng = np.random.default_rng(seed)
        self.cache = init_decode_cache(cfg, slots, max_len)
        self.slot_state = [SlotState() for _ in range(slots)]
        self.tokens = jnp.zeros((slots, 1), jnp.int32)
        self._decode = jax.jit(
            lambda p, c, t: self.model.decode_step(p, c, t, mesh=mesh,
                                                   rules=rules))
        self._prefill = jax.jit(
            lambda p, t: self.model.prefill(p, t, max_len=max_len,
                                            mesh=mesh, rules=rules))
        self.steps = 0
        self.tokens_out = 0

    # ------------------------------------------------------------ slots ----
    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slot_state) if not s.active]

    def utilization(self) -> float:
        return 1.0 - len(self.free_slots()) / self.slots

    def insert(self, request_id: int, prompt: np.ndarray, max_new: int) -> int:
        """Prefill a prompt and scatter its cache into a free slot."""
        free = self.free_slots()
        assert free, "no free slot"
        slot = free[0]
        logits, row_cache = self._prefill(
            self.params, jnp.asarray(prompt, jnp.int32)[None])
        # scatter row 0 of the prefilled cache into `slot` of the live cache
        def put(full, new):
            if full.ndim == new.ndim:
                return jax.lax.dynamic_update_index_in_dim(
                    full, new[:, 0].astype(full.dtype), slot, 1)
            return full
        self.cache = jax.tree.map(put, self.cache, row_cache)
        first = self._select_token(logits[:, -1])[0]
        self.tokens = self.tokens.at[slot, 0].set(first)
        st = self.slot_state[slot]
        st.request_id = request_id
        st.remaining = max_new
        st.generated = [int(first)]
        return slot

    def _select_token(self, logits):
        if self.temperature <= 0:
            return np.asarray(jnp.argmax(logits, -1))
        g = -np.log(-np.log(self.rng.uniform(size=logits.shape)))
        z = np.asarray(logits, np.float32) / self.temperature + g
        return z.argmax(-1)

    # ------------------------------------------------------------- step ----
    def step(self) -> list[tuple[int, list[int]]]:
        """One decode step for all active slots; returns finished requests
        as (request_id, generated_tokens)."""
        if all(not s.active for s in self.slot_state):
            return []
        logits, self.cache = self._decode(self.params, self.cache, self.tokens)
        nxt = self._select_token(logits[:, 0])
        self.tokens = jnp.asarray(nxt, jnp.int32)[:, None]
        self.steps += 1
        finished = []
        for i, st in enumerate(self.slot_state):
            if not st.active:
                continue
            st.generated.append(int(nxt[i]))
            st.remaining -= 1
            self.tokens_out += 1
            if st.remaining <= 0:
                finished.append((st.request_id, st.generated))
                self.slot_state[i] = SlotState()
        return finished
