"""Beyond-paper integration: the PPA proactively autoscales TPU decode
replica groups (DESIGN.md §2's mapping of "pods" onto mesh slices).

Discrete-event fleet model: each replica = one model-parallel mesh slice
(``chips_per_replica``) running a slot-based decode engine; a request's
service time = prefill + n_tokens / per-slot decode rate.  Replica spawn
costs checkpoint-load + compile time (the TPU analogue of pod startup — this
is what proactive scaling hides).  Node failures kill replicas and requeue
their in-flight requests; stragglers run at a speed factor and their
deadline-missing requests are re-dispatched (straggler mitigation).

The PPA consumes [slot-utilisation, hbm, queue, tokens, request-rate] and
bounds replicas by the chip budget — Algorithm 1's "max_replicas limited by
system resources" with chips as the resource.

Like ClusterSim, this is a thin adapter over ``repro.sim.SimCore``
(DESIGN.md §3): replica selection is heap-based with the seed's exact
least-loaded-slot ordering, injected events live on a heap, and in-flight
requests are tracked per replica instead of re-scanning the whole
completion log on failure.
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict

import numpy as np

from repro.core.metrics import Snapshot
from repro.sim import SimCore

_GROUP = "fleet"


@dataclasses.dataclass
class FleetConfig:
    total_chips: int = 256
    chips_per_replica: int = 16       # one model-axis slice
    slots_per_replica: int = 8
    decode_tok_s: float = 30.0        # per-slot decode rate
    prefill_s: float = 0.4
    spawn_s: float = 45.0             # ckpt load + warmup
    control_interval_s: float = 15.0
    deadline_factor: float = 3.0      # straggler re-dispatch threshold
    seed: int = 0


@dataclasses.dataclass
class _Replica:
    rid: int
    ready_at: float
    speed: float = 1.0
    dead: bool = False
    draining: bool = False
    slot_free_at: list = None
    busy: dict = None
    queue: list = None                # inflight requests

    def __post_init__(self):
        self.slot_free_at = self.slot_free_at or []
        self.busy = self.busy or defaultdict(float)
        self.queue = self.queue or []


@dataclasses.dataclass
class ServeRequest:
    arrival: float
    n_tokens: int
    completion: float = math.nan
    replica: int = -1
    redispatched: bool = False

    @property
    def response(self) -> float:
        return self.completion - self.arrival


class ServingFleet:
    def __init__(self, cfg: FleetConfig | None = None):
        self.cfg = cfg or FleetConfig()
        self.chip_budget = self.cfg.total_chips
        self.core = SimCore(self.cfg.control_interval_s, two_phase=False,
                            ma_windows=1)
        self.replicas: list[_Replica] = self.core.servers
        self._by_rid: dict[int, _Replica] = {}
        self._next_rid = 0
        self.completed: list[ServeRequest] = []
        self.samples: list[tuple[float, np.ndarray]] = \
            self.core.exporter.samples[_GROUP]
        self.replica_log: list[tuple[float, int]] = []
        self.rng = np.random.default_rng(self.cfg.seed)

    # ----------------------------------------------------------- scaling ---
    @property
    def max_replicas(self) -> int:
        return self.chip_budget // self.cfg.chips_per_replica

    def set_chip_budget(self, chips: int, t: float):
        """Re-point this fleet's chip allocation (the multi-fleet arbiter's
        per-tick lever, serving/multi_fleet.py).  Shrinking below current
        usage drains the newest replicas immediately."""
        self.chip_budget = int(chips)
        cur = len(self.core.live(_GROUP))
        if cur > self.max_replicas:
            self.scale_to(self.max_replicas, t)

    @staticmethod
    def _effective(r: _Replica) -> float:
        """Selection key: when this replica could start a request."""
        return max(min(r.slot_free_at), r.ready_at)

    def live_replicas(self, t: float | None = None):
        rs = self.core.live(_GROUP)
        if t is not None:
            rs = [r for r in rs if r.ready_at <= t]
        return rs

    def scale_to(self, n: int, t: float):
        n = min(n, self.max_replicas)
        cur = self.core.live(_GROUP)
        if len(cur) < n:
            for _ in range(n - len(cur)):
                r = _Replica(self._next_rid, ready_at=t + self.cfg.spawn_s,
                             slot_free_at=[t] * self.cfg.slots_per_replica)
                self._next_rid += 1
                self._by_rid[r.rid] = r
                self.core.add_server(r, _GROUP, t, key=self._effective(r),
                                     ready_at=r.ready_at)
        elif len(cur) > n:
            for r in sorted(cur, key=lambda r: -r.ready_at)[:len(cur) - n]:
                r.draining = True
                self.core.pool(_GROUP).invalidate(r)

    def make_ready_now(self, t: float = 0.0):
        """Mark current replicas warm at ``t`` (pre-provisioned capacity)."""
        for r in self.core.live(_GROUP):
            r.ready_at = t
            self.core.pool(_GROUP).reset(r, self._effective(r))

    # -------------------------------------------------------- dispatching --
    def dispatch(self, req: ServeRequest, t: float):
        pool = self.core.pool(_GROUP)
        r = pool.select(t)
        in_pool = r is not None
        if r is None:
            # everything dead or draining: drain-last-resort, else cold-start
            draining = [x for x in self.replicas if not x.dead]
            if draining:
                r = min(draining,
                        key=lambda x: (max(self._effective(x), t), x.rid))
            else:
                self.scale_to(1, t)
                r = pool.select(t)
                in_pool = True
        bi = int(np.argmin(r.slot_free_at))
        start = max(r.slot_free_at[bi], r.ready_at, t)
        service = (self.cfg.prefill_s
                   + req.n_tokens / (self.cfg.decode_tok_s * r.speed))
        req.completion = start + service
        req.replica = r.rid
        r.slot_free_at[bi] = req.completion
        self.core.account_busy(r.busy, start, req.completion)
        r.queue.append(req)
        if in_pool:
            pool.update(r, self._effective(r))
        self.core.log_completion(self.completed, req)
        self.core.exporter.count(_GROUP)
        # straggler mitigation: re-dispatch if the deadline is blown
        nominal = (self.cfg.prefill_s
                   + req.n_tokens / self.cfg.decode_tok_s)
        if (not req.redispatched
                and req.completion - t > self.cfg.deadline_factor * nominal):
            healthy = [x for x in self.live_replicas(t)
                       if x.speed >= 0.9 and x.rid != r.rid]
            if healthy:
                req.redispatched = True
                h = healthy[int(np.argmin(
                    [min(x.slot_free_at) for x in healthy]))]
                j = int(np.argmin(h.slot_free_at))
                start2 = max(h.slot_free_at[j], h.ready_at, t)
                req.completion = start2 + nominal
                h.slot_free_at[j] = req.completion
                pool.update(h, self._effective(h))

    # ---------------------------------------------------------- failures ---
    def inject_failure(self, t: float, rid: int):
        self.core.events.push(t, "fail", rid=rid)

    def inject_straggler(self, t: float, rid: int, speed: float,
                         duration: float):
        self.core.events.push(t, "slow", rid=rid, speed=speed)
        self.core.events.push(t + duration, "slow", rid=rid, speed=1.0)

    def _apply_events(self, t: float):
        requeue: list[ServeRequest] = []
        for _, kind, arg in self.core.events.pop_due(t):
            r = self._by_rid.get(arg["rid"])
            if r is None:
                continue
            if kind == "fail" and not r.dead:
                r.dead = True
                self.core.pool(_GROUP).invalidate(r)
                requeue.extend(q for q in r.queue
                               if q.completion > t and not q.redispatched)
                r.queue.clear()
            elif kind == "slow":
                r.speed = arg["speed"]
        for req in requeue:
            req.redispatched = True
            self.dispatch(req, t)

    # ------------------------------------------------------------ metrics --
    def sample(self, t: float) -> Snapshot:
        w = self.cfg.control_interval_s
        exporter = self.core.exporter
        win = exporter.window_index(t)
        live = [r for r in self.replicas if not r.dead]
        cap = max(sum(self.cfg.slots_per_replica for r in live
                      if r.ready_at <= t), 1)
        busy = sum(r.busy.get(win, 0.0) for r in live) / w
        util = 100.0 * busy / cap
        rate = exporter.take_count(_GROUP) / w
        for r in live:
            if r.queue:
                r.queue = [q for q in r.queue if q.completion > t]
        vals = np.array([util * cap, 0.0, busy, rate * 10, rate])
        ma = exporter.push(_GROUP, t, vals)
        return Snapshot(t, ma)

    # --------------------------------------------------------------- run ---
    def run(self, requests: list[tuple[float, int]], scaler, kind: str,
            t_end: float, min_replicas: int = 1):
        """requests: sorted (arrival_t, n_tokens).  scaler: PPA or HPA."""
        self.scale_to(min_replicas, 0.0)
        self.make_ready_now(0.0)
        w = self.cfg.control_interval_s
        ticks = np.arange(w, t_end, w)
        ri = 0
        for tick in ticks:
            self._apply_events(tick)
            while ri < len(requests) and requests[ri][0] <= tick:
                at, ntok = requests[ri]
                self.dispatch(ServeRequest(at, ntok), at)
                ri += 1
            snap = self.sample(tick)
            cur = len(self.live_replicas(tick))
            if kind == "ppa":
                scaler.observe(snap)
                res = scaler.control_step(tick, self.max_replicas, cur)
                desired = max(res.replicas, min_replicas)
                scaler.maybe_update(tick)
            else:
                recent = np.stack([v for _, v in self.samples][-4:])
                desired = scaler.decide(tick, recent, self.max_replicas, cur)
            self.scale_to(max(desired, min_replicas), tick)
            self.replica_log.append((tick, desired))
        while ri < len(requests) and requests[ri][0] <= t_end:
            at, ntok = requests[ri]
            self.dispatch(ServeRequest(at, ntok), at)
            ri += 1
        return self

    def response_times(self) -> np.ndarray:
        return np.asarray([r.response for r in self.completed
                           if math.isfinite(r.completion)])

    def idle_fraction(self) -> float:
        w = self.cfg.control_interval_s
        total_busy, total_cap = 0.0, 0.0
        for t, _ in self.samples:
            win = self.core.exporter.window_index(t)
            live = [r for r in self.replicas if not r.dead
                    and r.ready_at <= t]
            total_cap += len(live) * self.cfg.slots_per_replica * w
            total_busy += sum(r.busy.get(win, 0.0) for r in live)
        return 1.0 - total_busy / max(total_cap, 1e-9)
