"""Beyond-paper integration: the PPA proactively autoscales TPU decode
replica groups (DESIGN.md §2's mapping of "pods" onto mesh slices).

Discrete-event fleet model: each replica = one model-parallel mesh slice
(``chips_per_replica``) running a slot-based decode engine; a request's
service time = prefill + n_tokens / per-slot decode rate.  Replica spawn
costs checkpoint-load + compile time (the TPU analogue of pod startup — this
is what proactive scaling hides).  Node failures kill replicas and requeue
their in-flight requests; stragglers run at a speed factor and their
deadline-missing requests are re-dispatched (straggler mitigation).

The PPA consumes [slot-utilisation, hbm, queue, tokens, request-rate] and
bounds replicas by the chip budget — Algorithm 1's "max_replicas limited by
system resources" with chips as the resource.
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict, deque

import numpy as np

from repro.core.metrics import Snapshot


@dataclasses.dataclass
class FleetConfig:
    total_chips: int = 256
    chips_per_replica: int = 16       # one model-axis slice
    slots_per_replica: int = 8
    decode_tok_s: float = 30.0        # per-slot decode rate
    prefill_s: float = 0.4
    spawn_s: float = 45.0             # ckpt load + warmup
    control_interval_s: float = 15.0
    deadline_factor: float = 3.0      # straggler re-dispatch threshold
    seed: int = 0


@dataclasses.dataclass
class _Replica:
    rid: int
    ready_at: float
    speed: float = 1.0
    dead: bool = False
    draining: bool = False
    slot_free_at: list = None
    busy: dict = None

    def __post_init__(self):
        self.slot_free_at = self.slot_free_at or []
        self.busy = self.busy or defaultdict(float)


@dataclasses.dataclass
class ServeRequest:
    arrival: float
    n_tokens: int
    completion: float = math.nan
    replica: int = -1
    redispatched: bool = False

    @property
    def response(self) -> float:
        return self.completion - self.arrival


class ServingFleet:
    def __init__(self, cfg: FleetConfig | None = None):
        self.cfg = cfg or FleetConfig()
        self.replicas: list[_Replica] = []
        self._next_rid = 0
        self.completed: list[ServeRequest] = []
        self._win_reqs = 0
        self.samples: list[tuple[float, np.ndarray]] = []
        self.replica_log: list[tuple[float, int]] = []
        self._events: list[tuple[float, str, dict]] = []
        self.rng = np.random.default_rng(self.cfg.seed)

    # ----------------------------------------------------------- scaling ---
    @property
    def max_replicas(self) -> int:
        return self.cfg.total_chips // self.cfg.chips_per_replica

    def live_replicas(self, t: float | None = None):
        rs = [r for r in self.replicas if not r.dead and not r.draining]
        if t is not None:
            rs = [r for r in rs if r.ready_at <= t]
        return rs

    def scale_to(self, n: int, t: float):
        n = min(n, self.max_replicas)
        cur = [r for r in self.replicas if not r.dead and not r.draining]
        if len(cur) < n:
            for _ in range(n - len(cur)):
                r = _Replica(self._next_rid, ready_at=t + self.cfg.spawn_s,
                             slot_free_at=[t] * self.cfg.slots_per_replica)
                self._next_rid += 1
                self.replicas.append(r)
        elif len(cur) > n:
            for r in sorted(cur, key=lambda r: -r.ready_at)[:len(cur) - n]:
                r.draining = True

    # -------------------------------------------------------- dispatching --
    def dispatch(self, req: ServeRequest, t: float):
        live = self.live_replicas() or [r for r in self.replicas
                                        if not r.dead]
        if not live:
            self.scale_to(1, t)
            live = [self.replicas[-1]]
        # least-loaded slot across replicas
        best, bi = None, -1
        for r in live:
            i = int(np.argmin(r.slot_free_at))
            ready = max(r.slot_free_at[i], r.ready_at, t)
            if best is None or ready < best[1]:
                best, bi = (r, ready), i
        r, start = best
        service = (self.cfg.prefill_s
                   + req.n_tokens / (self.cfg.decode_tok_s * r.speed))
        req.completion = start + service
        req.replica = r.rid
        r.slot_free_at[bi] = req.completion
        w = self.cfg.control_interval_s
        i0, i1 = int(start // w), int(req.completion // w)
        for i in range(i0, i1 + 1):
            lo, hi = max(start, i * w), min(req.completion, (i + 1) * w)
            if hi > lo:
                r.busy[i] += hi - lo
        self.completed.append(req)
        self._win_reqs += 1
        # straggler mitigation: re-dispatch if the deadline is blown
        nominal = (self.cfg.prefill_s
                   + req.n_tokens / self.cfg.decode_tok_s)
        if (not req.redispatched
                and req.completion - t > self.cfg.deadline_factor * nominal):
            healthy = [x for x in self.live_replicas(t)
                       if x.speed >= 0.9 and x.rid != r.rid]
            if healthy:
                self.completed.pop()
                req.redispatched = True
                h = healthy[int(np.argmin(
                    [min(x.slot_free_at) for x in healthy]))]
                j = int(np.argmin(h.slot_free_at))
                start2 = max(h.slot_free_at[j], h.ready_at, t)
                req.completion = start2 + nominal
                h.slot_free_at[j] = req.completion
                self.completed.append(req)

    # ---------------------------------------------------------- failures ---
    def inject_failure(self, t: float, rid: int):
        self._events.append((t, "fail", {"rid": rid}))

    def inject_straggler(self, t: float, rid: int, speed: float,
                         duration: float):
        self._events.append((t, "slow", {"rid": rid, "speed": speed}))
        self._events.append((t + duration, "slow", {"rid": rid, "speed": 1.0}))

    def _apply_events(self, t: float):
        fired = [e for e in self._events if e[0] <= t]
        self._events = [e for e in self._events if e[0] > t]
        requeue = []
        for _, kind, arg in fired:
            for r in self.replicas:
                if r.rid == arg["rid"]:
                    if kind == "fail" and not r.dead:
                        r.dead = True
                        for req in self.completed:
                            if (req.replica == r.rid and req.completion > t
                                    and not req.redispatched):
                                requeue.append(req)
                    elif kind == "slow":
                        r.speed = arg["speed"]
        for req in requeue:
            self.completed.remove(req)
            req.redispatched = True
            self.dispatch(req, t)

    # ------------------------------------------------------------ metrics --
    def sample(self, t: float) -> Snapshot:
        w = self.cfg.control_interval_s
        win = int((t - 1e-9) // w)
        live = [r for r in self.replicas if not r.dead]
        cap = max(sum(self.cfg.slots_per_replica for r in live
                      if r.ready_at <= t), 1)
        busy = sum(r.busy.get(win, 0.0) for r in live) / w
        util = 100.0 * busy / cap
        rate = self._win_reqs / w
        self._win_reqs = 0
        vals = np.array([util * cap, 0.0, busy, rate * 10, rate])
        snap = Snapshot(t, vals)
        self.samples.append((t, snap.values))
        return snap

    # --------------------------------------------------------------- run ---
    def run(self, requests: list[tuple[float, int]], scaler, kind: str,
            t_end: float, min_replicas: int = 1):
        """requests: sorted (arrival_t, n_tokens).  scaler: PPA or HPA."""
        self.scale_to(min_replicas, 0.0)
        for r in self.replicas:
            r.ready_at = 0.0
        w = self.cfg.control_interval_s
        ticks = np.arange(w, t_end, w)
        ri = 0
        for tick in ticks:
            self._apply_events(tick)
            while ri < len(requests) and requests[ri][0] <= tick:
                at, ntok = requests[ri]
                self.dispatch(ServeRequest(at, ntok), at)
                ri += 1
            snap = self.sample(tick)
            cur = len(self.live_replicas(tick))
            if kind == "ppa":
                scaler.observe(snap)
                res = scaler.control_step(tick, self.max_replicas, cur)
                desired = max(res.replicas, min_replicas)
                scaler.maybe_update(tick)
            else:
                recent = np.stack([v for _, v in self.samples][-4:])
                desired = scaler.decide(tick, recent, self.max_replicas, cur)
            self.scale_to(max(desired, min_replicas), tick)
            self.replica_log.append((tick, desired))
        while ri < len(requests) and requests[ri][0] <= t_end:
            at, ntok = requests[ri]
            self.dispatch(ServeRequest(at, ntok), at)
            ri += 1
        return self

    def response_times(self) -> np.ndarray:
        return np.asarray([r.response for r in self.completed
                           if math.isfinite(r.completion)])

    def idle_fraction(self) -> float:
        w = self.cfg.control_interval_s
        total_busy, total_cap = 0.0, 0.0
        for t, _ in self.samples:
            win = int((t - 1e-9) // w)
            live = [r for r in self.replicas if not r.dead
                    and r.ready_at <= t]
            total_cap += len(live) * self.cfg.slots_per_replica * w
            total_busy += sum(r.busy.get(win, 0.0) for r in live)
        return 1.0 - total_busy / max(total_cap, 1e-9)
