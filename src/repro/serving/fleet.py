"""Beyond-paper integration: the PPA proactively autoscales TPU decode
replica groups (DESIGN.md §2's mapping of "pods" onto mesh slices).

Discrete-event fleet model: each replica = one model-parallel mesh slice
(``chips_per_replica``) running a slot-based decode engine; a request's
service time = prefill + n_tokens / per-slot decode rate.  Replica spawn
costs checkpoint-load + compile time (the TPU analogue of pod startup — this
is what proactive scaling hides).  Node failures kill replicas and requeue
their in-flight requests; stragglers run at a speed factor and their
deadline-missing requests are re-dispatched (straggler mitigation).

The PPA consumes [slot-utilisation, hbm, queue, tokens, request-rate] and
bounds replicas by the chip budget — Algorithm 1's "max_replicas limited by
system resources" with chips as the resource.

Like ClusterSim, this is a thin adapter over ``repro.sim.SimCore``
(DESIGN.md §3): replica selection is heap-based with the seed's exact
least-loaded-slot ordering, injected events live on a heap, and in-flight
requests are tracked per replica instead of re-scanning the whole
completion log on failure.

Windowed batch mode (DESIGN.md §6, "Columnar"): ``ServingFleet(cfg,
batch=True)`` swaps the per-request heap dispatch for ``drain_window``
idle-chunk rounds over a slot-level ``ArrayServerPool`` — one server per
(replica, slot), replicas as pure array rows, completions in a
structured-numpy ``CompletionLog`` (the ``kind`` column carries an
int16-clipped copy of ``n_tokens`` for inspection; the authoritative
per-row token counts live in ``_ntok_rows``) and ``WindowAccumulator``
fleet-level busy accounting.  For
a fleet with homogeneous replica speeds the windowed drain produces the
*bitwise identical* (arrival, start, completion) sequence as per-event
dispatch whenever the deadline re-dispatch rule doesn't fire (mild
overload included — the busy fallback is exact); slot-level selection
order is provably the same as replica-then-slot selection
(tests/test_columnar.py property-checks it).  Known deviations mirror
ClusterSim's: replica *attribution* of a request may differ when a busy
slot frees mid-chunk (starts/completions unchanged), so deadline
re-dispatches — which exclude the original replica — and severe
stragglers are statistically equivalent rather than bitwise, and a dead
replica's already-executed busy time stays in the fleet-level metric.
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict

import numpy as np

from repro.core.metrics import Snapshot
from repro.sim import (ArrayServerPool, CompletionLog, SimCore,
                       WindowAccumulator)
from repro.sim.core import grow_to

_GROUP = "fleet"

# the CompletionLog kind column is int16; ntok readings are clipped into it
_NTOK_CLIP = np.iinfo(np.int16).max

# Above this many replicas-worth of chips the batch-mode CompletionLog
# defaults to streaming retention (DESIGN.md §12): the full log holds
# ~43 B/event, which a 10⁶-pod federation run would turn into tens of GB;
# streaming bounds memory to the trailing retain_windows span.  Whole-run
# numbers stay exact via CompletionLog.stats()/totals().
STREAMING_POD_THRESHOLD = 4096


@dataclasses.dataclass
class FleetConfig:
    total_chips: int = 256
    chips_per_replica: int = 16       # one model-axis slice
    slots_per_replica: int = 8
    decode_tok_s: float = 30.0        # per-slot decode rate
    prefill_s: float = 0.4
    spawn_s: float = 45.0             # ckpt load + warmup
    control_interval_s: float = 15.0
    deadline_factor: float = 3.0      # straggler re-dispatch threshold
    seed: int = 0
    # batch-mode completion-log retention: True/False forces streaming on
    # or off; None auto-enables it when the chip budget admits more than
    # STREAMING_POD_THRESHOLD replicas
    log_streaming: bool | None = None
    log_retain_windows: int = 8


@dataclasses.dataclass
class _Replica:
    rid: int
    ready_at: float
    speed: float = 1.0
    dead: bool = False
    draining: bool = False
    slot_free_at: list = None
    busy: dict = None
    queue: list = None                # inflight requests

    def __post_init__(self):
        self.slot_free_at = self.slot_free_at or []
        self.busy = self.busy or defaultdict(float)
        self.queue = self.queue or []


@dataclasses.dataclass
class ServeRequest:
    arrival: float
    n_tokens: int
    completion: float = math.nan
    replica: int = -1
    redispatched: bool = False

    @property
    def response(self) -> float:
        return self.completion - self.arrival


class ServingFleet:
    def __init__(self, cfg: FleetConfig | None = None, batch: bool = False):
        self.cfg = cfg or FleetConfig()
        self.chip_budget = self.cfg.total_chips
        self.core = SimCore(self.cfg.control_interval_s, two_phase=False,
                            ma_windows=1)
        self.replicas: list[_Replica] = self.core.servers
        self._by_rid: dict[int, _Replica] = {}
        self._next_rid = 0
        self.completed: list[ServeRequest] = []
        self.samples: list[tuple[float, np.ndarray]] = \
            self.core.exporter.samples[_GROUP]
        self.replica_log: list[tuple[float, int]] = []
        self.rng = np.random.default_rng(self.cfg.seed)
        # latency-window feedback (docs/guardrail.md): requests dispatched
        # since the last sample; their booked response times yield the
        # window p95 published in metric slot 1 (SLAPolicy's key metric)
        self._win_reqs: list[ServeRequest] = []
        # windowed batch mode: slot-level array pool + columnar replicas
        self._vec = bool(batch)
        self.completed_log: CompletionLog | None = None
        if self._vec:
            self._spool = ArrayServerPool()
            self._rep_ready = np.zeros(16)
            self._rep_speed = np.ones(16)
            self._rep_dead = np.zeros(16, np.bool_)
            self._rep_draining = np.zeros(16, np.bool_)
            self._rep_n = 0
            self._rep_base = None   # cached ~dead & ~draining (live mask)
            streaming = self.cfg.log_streaming
            if streaming is None:
                streaming = (self.cfg.total_chips
                             // self.cfg.chips_per_replica
                             > STREAMING_POD_THRESHOLD)
            self.completed_log = CompletionLog(
                streaming=streaming,
                retain_windows=self.cfg.log_retain_windows)
            # authoritative per-row n_tokens (the log's int16 kind column
            # only carries a clipped copy for inspection); row index ==
            # append order, so it stays aligned with the log's view().
            # Doubling buffer — an np.concatenate per window would make
            # total copying quadratic in run length
            self._ntok_buf = np.zeros(1024, np.float64)
            self._ntok_n = 0
            self._ntok_flushed = 0   # rows dropped in step with the log
            self._busy_acc = WindowAccumulator(self.cfg.control_interval_s)
            self._cap_log: list[tuple[float, int]] = []
            # batch-mode mirror of _win_reqs: per-chunk booked response
            # arrays (deadline re-dispatches included — the same multiset
            # the heap path sees, so the published p95 stays bitwise equal)
            self._win_resp: list[np.ndarray] = []

    # ----------------------------------------------------------- scaling ---
    @property
    def max_replicas(self) -> int:
        return self.chip_budget // self.cfg.chips_per_replica

    def set_chip_budget(self, chips: int, t: float):
        """Re-point this fleet's chip allocation (the multi-fleet arbiter's
        per-tick lever, serving/multi_fleet.py).  Shrinking below current
        usage drains the newest replicas immediately."""
        self.chip_budget = int(chips)
        cur = self.live_count()
        if cur > self.max_replicas:
            self.scale_to(self.max_replicas, t)

    @staticmethod
    def _effective(r: _Replica) -> float:
        """Selection key: when this replica could start a request."""
        return max(min(r.slot_free_at), r.ready_at)

    def live_replicas(self, t: float | None = None):
        """Live (not dead / not draining, optionally ready) replicas — the
        heap path returns ``_Replica`` objects, batch mode returns rids."""
        if self._vec:
            return np.flatnonzero(self._rep_live_mask(t)).tolist()
        rs = self.core.live(_GROUP)
        if t is not None:
            rs = [r for r in rs if r.ready_at <= t]
        return rs

    def live_count(self, t: float | None = None) -> int:
        """``len(live_replicas(t))`` without materialising the id list —
        the federation tick reads this once per fleet per window."""
        if self._vec:
            return int(np.count_nonzero(self._rep_live_mask(t)))
        return len(self.live_replicas(t))

    def seal_window(self):
        """Seal the batch-mode completion log's current control window and
        keep the side-car ``_ntok_buf`` (authoritative per-row n_tokens,
        indexed in append order) aligned with the log's post-flush view —
        streaming compaction drops the same leading rows from both, so
        ``_vec_requeue_row``'s view-local row indices stay valid."""
        log = self.completed_log
        log.seal_window()
        cut = log.n_flushed - self._ntok_flushed
        if cut > 0:
            keep = self._ntok_n - cut
            self._ntok_buf[:keep] = self._ntok_buf[cut:self._ntok_n]
            self._ntok_n = keep
            self._ntok_flushed = log.n_flushed

    def scale_to(self, n: int, t: float):
        if self._vec:
            return self._vec_scale_to(n, t)
        n = min(n, self.max_replicas)
        cur = self.core.live(_GROUP)
        if len(cur) < n:
            for _ in range(n - len(cur)):
                r = _Replica(self._next_rid, ready_at=t + self.cfg.spawn_s,
                             slot_free_at=[t] * self.cfg.slots_per_replica)
                self._next_rid += 1
                self._by_rid[r.rid] = r
                self.core.add_server(r, _GROUP, t, key=self._effective(r),
                                     ready_at=r.ready_at)
        elif len(cur) > n:
            for r in sorted(cur, key=lambda r: -r.ready_at)[:len(cur) - n]:
                r.draining = True
                self.core.pool(_GROUP).invalidate(r)

    def make_ready_now(self, t: float = 0.0):
        """Mark current replicas warm at ``t`` (pre-provisioned capacity)."""
        if self._vec:
            S = self.cfg.slots_per_replica
            live = np.flatnonzero(self._rep_live_mask())
            slots = (live[:, None] * S + np.arange(S)).ravel()
            old = np.repeat(self._rep_ready[live], S)
            key = self._spool.key
            # undispatched slots carry key == old ready; dispatched slots
            # keep their completion horizon (same as the heap reset)
            key[slots] = np.where(key[slots] == old, float(t), key[slots])
            self._rep_ready[live] = t
            return
        for r in self.core.live(_GROUP):
            r.ready_at = t
            self.core.pool(_GROUP).reset(r, self._effective(r))

    # ---------------------------------------------- batch-mode replicas ----
    def _rep_live_mask(self, t: float | None = None) -> np.ndarray:
        """Live = not dead and not draining.  The base mask only changes on
        spawn / drain / failure (each resets the cache), so steady-state
        ticks reuse one array instead of re-deriving two boolean ops per
        call — callers of the no-``t`` form must not mutate the result."""
        base = self._rep_base
        if base is None or base.size != self._rep_n:
            base = self._rep_base = (
                ~self._rep_dead[:self._rep_n]
                & ~self._rep_draining[:self._rep_n])
        if t is not None:
            return base & (self._rep_ready[:self._rep_n] <= t)
        return base

    def _grow_reps(self, need: int):
        for name in ("_rep_ready", "_rep_speed", "_rep_dead",
                     "_rep_draining"):
            setattr(self, name, grow_to(getattr(self, name), need))

    def _vec_scale_to(self, n: int, t: float):
        """Columnar scale: spawn is one batched array append (replica rows
        + S slots each), drain one metadata write + pool invalidate."""
        n = min(n, self.max_replicas)
        S = self.cfg.slots_per_replica
        live = np.flatnonzero(self._rep_live_mask())
        cur = len(live)
        if cur < n:
            k = n - cur
            self._grow_reps(self._rep_n + k)
            rids = np.arange(self._rep_n, self._rep_n + k)
            self._rep_ready[rids] = t + self.cfg.spawn_s
            self._rep_speed[rids] = 1.0
            self._rep_n += k
            self._rep_base = None
            # slot key = max(slot_free, ready) = ready until first dispatch;
            # pool ready stays 0 so selection is single-phase (the heap
            # fleet pool folds ready into the key the same way)
            self._spool.add_batch(k * S, key=t + self.cfg.spawn_s,
                                  ready_at=0.0)
        elif cur > n:
            # newest ready_at first, rid order within ties — the same
            # choice as the heap path's stable sort on -ready_at
            order = np.argsort(-self._rep_ready[live], kind="stable")
            victims = live[order][:cur - n]
            self._rep_draining[victims] = True
            self._rep_base = None
            self._spool.invalidate(
                (victims[:, None] * S + np.arange(S)).ravel())

    # -------------------------------------------------------- dispatching --
    def dispatch(self, req: ServeRequest, t: float):
        if self._vec:
            raise RuntimeError("batch-mode fleet: use dispatch_window")
        # failure-requeued requests arrive with redispatched already set —
        # they belong to their original dispatch window's latency sample
        # (the batch path likewise amends the log without re-sampling)
        fresh = not req.redispatched
        pool = self.core.pool(_GROUP)
        r = pool.select(t)
        in_pool = r is not None
        if r is None:
            # everything dead or draining: drain-last-resort, else cold-start
            draining = [x for x in self.replicas if not x.dead]
            if draining:
                r = min(draining,
                        key=lambda x: (max(self._effective(x), t), x.rid))
            else:
                self.scale_to(1, t)
                r = pool.select(t)
                in_pool = True
        bi = int(np.argmin(r.slot_free_at))
        start = max(r.slot_free_at[bi], r.ready_at, t)
        service = (self.cfg.prefill_s
                   + req.n_tokens / (self.cfg.decode_tok_s * r.speed))
        req.completion = start + service
        req.replica = r.rid
        r.slot_free_at[bi] = req.completion
        self.core.account_busy(r.busy, start, req.completion)
        r.queue.append(req)
        if in_pool:
            pool.update(r, self._effective(r))
        self.core.log_completion(self.completed, req)
        self.core.exporter.count(_GROUP)
        # straggler mitigation: re-dispatch if the deadline is blown
        nominal = (self.cfg.prefill_s
                   + req.n_tokens / self.cfg.decode_tok_s)
        if (not req.redispatched
                and req.completion - t > self.cfg.deadline_factor * nominal):
            healthy = [x for x in self.live_replicas(t)
                       if x.speed >= 0.9 and x.rid != r.rid]
            if healthy:
                req.redispatched = True
                h = healthy[int(np.argmin(
                    [min(x.slot_free_at) for x in healthy]))]
                j = int(np.argmin(h.slot_free_at))
                start2 = max(h.slot_free_at[j], h.ready_at, t)
                req.completion = start2 + nominal
                h.slot_free_at[j] = req.completion
                pool.update(h, self._effective(h))
        if fresh:
            self._win_reqs.append(req)

    # ------------------------------------------------- windowed dispatch ---
    def dispatch_window(self, times: np.ndarray, ntokens: np.ndarray):
        """Drain one sorted same-window arrival chunk through the slot
        array pool in vectorised idle rounds (``drain_window`` semantics,
        specialised so the per-event deadline re-dispatch rule runs inside
        the rounds): each round assigns the next k arrivals to the k idle
        slots at the chunk head — slot creation order IS the heap path's
        replica-then-slot order — and only the no-idle-slot fallback pays
        per-request Python.  Appends one ``CompletionLog`` batch; bitwise
        start/completion parity with per-event dispatch for homogeneous
        replica speeds while the deadline re-dispatch rule stays quiet
        (see the module docstring for the attribution caveat)."""
        cfg = self.cfg
        S = cfg.slots_per_replica
        pool = self._spool
        times = np.asarray(times, np.float64)
        ntok = np.asarray(ntokens, np.float64)
        n = len(times)
        if n == 0:
            # empty window: every append below is a no-op — skip the whole
            # setup (the 10⁶-pod federation tick visits each fleet every
            # window, loaded or not)
            return
        rids = np.full(n, -1, np.int64)
        starts = np.empty(n, np.float64)
        comps = np.empty(n, np.float64)
        svcs = np.empty(n, np.float64)
        redis = np.zeros(n, np.bool_)
        i = 0
        while i < n:
            t0 = float(times[i])
            idle = pool.idle_slots(t0, n - i)
            k = len(idle)
            if k:
                rid = idle // S
                st = times[i:i + k]
                sv = (cfg.prefill_s
                      + ntok[i:i + k] / (cfg.decode_tok_s
                                         * self._rep_speed[rid]))
                cm = st + sv
                pool.key[idle] = cm
                rids[i:i + k] = rid
                starts[i:i + k], comps[i:i + k] = st, cm
                svcs[i:i + k] = sv
                # busy credits the ORIGINAL interval (the heap path accounts
                # before any re-dispatch and never re-accounts)
                self._busy_acc.add_batch(st, cm)
                # severe-straggler re-dispatch: start == arrival here, so
                # only speed < 1/deadline_factor replicas can blow the
                # deadline — flagged at idle-round granularity
                nominal = cfg.prefill_s + ntok[i:i + k] / cfg.decode_tok_s
                for j in np.flatnonzero(sv > cfg.deadline_factor * nominal):
                    newc = self._vec_redispatch_req(
                        int(rid[j]), float(st[j]), float(nominal[j]))
                    if newc is not None:
                        comps[i + j] = newc
                        redis[i + j] = True
                i += k
                continue
            # vectorised busy round: assign the next r arrivals to the r
            # earliest slot horizons ((key, slot)-sorted = the per-event
            # min-key/first-index pick; pool ready is folded into key so
            # there is no pending branch).  Service times here are
            # deterministic in (ntok, replica speed), so the only parity
            # hazard is slot-choice divergence — excluded over the
            # committed prefix, where each next horizon strictly precedes
            # every earlier completion of the round.
            live = pool.live[:pool.n]
            keys = pool.key[:pool.n]
            busy = np.flatnonzero(live)
            if busy.size > 1:
                r0 = min(int(np.searchsorted(times[i:], keys[busy].min(),
                                             side="left")), busy.size)
                if r0 > 1:
                    order = np.argsort(keys[busy], kind="stable")[:r0]
                    hs = busy[order]
                    hk = keys[hs]
                    rid = hs // S
                    ts = times[i:i + r0]
                    sv = (cfg.prefill_s
                          + ntok[i:i + r0] / (cfg.decode_tok_s
                                              * self._rep_speed[rid]))
                    st = np.maximum(np.maximum(ts, hk),
                                    self._rep_ready[rid])
                    cm = st + sv
                    run_min = np.minimum.accumulate(cm)
                    viol = np.flatnonzero(hk[1:] >= run_min[:-1])
                    r = int(viol[0]) + 1 if viol.size else r0
                    hs, rid = hs[:r], rid[:r]
                    st, cm, svr = st[:r], cm[:r], sv[:r]
                    pool.key[hs] = cm
                    rids[i:i + r] = rid
                    starts[i:i + r], comps[i:i + r] = st, cm
                    svcs[i:i + r] = svr
                    self._busy_acc.add_batch(st, cm)
                    # per-event deadline rule on the committed prefix
                    nominal = (cfg.prefill_s
                               + ntok[i:i + r] / cfg.decode_tok_s)
                    for j in np.flatnonzero(
                            cm - ts[:r] > cfg.deadline_factor * nominal):
                        newc = self._vec_redispatch_req(
                            int(rid[j]), float(ts[j]), float(nominal[j]))
                        if newc is not None:
                            comps[i + j] = newc
                            redis[i + j] = True
                    i += r
                    continue
            # fallback: exact per-event selection (min-key slot; overload /
            # spin-up), deadline re-dispatch rule applied per request
            s = pool.select(t0)
            if s < 0:
                rid1, s = self._vec_last_resort(t0)
            else:
                rid1 = s // S
            st1 = max(t0, float(pool.key[s]), float(self._rep_ready[rid1]))
            sv1 = (cfg.prefill_s
                   + float(ntok[i]) / (cfg.decode_tok_s
                                       * float(self._rep_speed[rid1])))
            cm1 = st1 + sv1
            pool.key[s] = cm1
            self._busy_acc.add(st1, cm1)
            rids[i], starts[i], comps[i], svcs[i] = rid1, st1, cm1, sv1
            nominal1 = cfg.prefill_s + float(ntok[i]) / cfg.decode_tok_s
            if cm1 - t0 > cfg.deadline_factor * nominal1:
                newc = self._vec_redispatch_req(rid1, t0, nominal1)
                if newc is not None:
                    comps[i] = newc
                    redis[i] = True
            i += 1
        self.completed_log.append_batch(
            times, starts, comps, svcs, rids,
            kind=np.minimum(ntok, _NTOK_CLIP).astype(np.int16),
            redispatched=redis)
        if n:
            self._win_resp.append(comps - times)
        self._ntok_buf = grow_to(self._ntok_buf, self._ntok_n + n)
        self._ntok_buf[self._ntok_n:self._ntok_n + n] = ntok
        self._ntok_n += n
        self.core.exporter.count(_GROUP, n)

    def _slot_keys(self) -> np.ndarray:
        """(R, S) view of the slot selection keys."""
        S = self.cfg.slots_per_replica
        return self._spool.key[:self._rep_n * S].reshape(self._rep_n, S)

    def _vec_redispatch_req(self, orig_rid: int, t: float, nominal: float):
        """The per-event deadline re-dispatch rule on columnar state: pick
        the healthy replica whose earliest slot frees first (ties by rid),
        book ``nominal`` service there; the straggler keeps its abandoned
        work (same as the heap path).  Returns the new completion or None
        when no healthy replica exists."""
        S = self.cfg.slots_per_replica
        m = self._rep_live_mask(t)
        m &= self._rep_speed[:self._rep_n] >= 0.9
        if orig_rid < self._rep_n:
            m[orig_rid] = False
        healthy = np.flatnonzero(m)
        if not healthy.size:
            return None
        keys = self._slot_keys()
        h = int(healthy[int(np.argmin(keys[healthy].min(axis=1)))])
        j = int(np.argmin(keys[h]))
        start = max(float(keys[h, j]), float(self._rep_ready[h]), t)
        comp = start + nominal
        self._spool.key[h * S + j] = comp
        return comp

    def _vec_last_resort(self, t: float) -> tuple[int, int]:
        """Everything dead or draining: book onto the least-loaded
        not-dead replica (the heap path's drain-last-resort), else cold
        start one replica."""
        not_dead = np.flatnonzero(~self._rep_dead[:self._rep_n])
        if not_dead.size:
            keys = self._slot_keys()
            eff = np.maximum(keys[not_dead].min(axis=1), t)
            rid = int(not_dead[int(np.argmin(eff))])
            return rid, rid * self.cfg.slots_per_replica + int(
                np.argmin(keys[rid]))
        self._vec_scale_to(1, t)
        s = int(self._spool.select(t))
        return s // self.cfg.slots_per_replica, s

    def _vec_requeue_row(self, row: int, t: float):
        """Re-dispatch one orphaned completion-log row (replica failure) —
        the batch-mode mirror of ``dispatch(req, t)`` with
        ``redispatched=True``."""
        cfg = self.cfg
        pool = self._spool
        ntokens = float(self._ntok_buf[row])
        s = int(pool.select(t))
        if s < 0:
            rid, s = self._vec_last_resort(t)
        else:
            rid = s // cfg.slots_per_replica
        st = max(t, float(pool.key[s]), float(self._rep_ready[rid]))
        sv = (cfg.prefill_s
              + ntokens / (cfg.decode_tok_s * float(self._rep_speed[rid])))
        cm = st + sv
        pool.key[s] = cm
        self._busy_acc.add(st, cm)
        self.completed_log.amend(row, start=st, completion=cm, service=sv,
                                 server=rid, redispatched=True)
        self.core.exporter.count(_GROUP)

    def _vec_apply_events(self, t: float):
        S = self.cfg.slots_per_replica
        requeue: list[int] = []
        for _, kind, arg in self.core.events.pop_due(t):
            rid = int(arg["rid"])
            if rid >= self._rep_n:
                continue
            if kind == "fail" and not self._rep_dead[rid]:
                self._rep_dead[rid] = True
                self._rep_base = None
                self._spool.invalidate(np.arange(rid * S, rid * S + S))
                rows = self.completed_log.view()
                orphan = np.flatnonzero((rows["server"] == rid)
                                        & (rows["completion"] > t)
                                        & ~rows["redispatched"])
                if orphan.size:
                    # cancel the un-executed remainder of each orphan's old
                    # interval, then re-dispatch in log order
                    st = np.maximum(rows["start"][orphan], t)
                    self._busy_acc.add_batch(st, rows["completion"][orphan],
                                             sign=-1.0)
                    requeue.extend(int(r) for r in orphan)
            elif kind == "slow":
                self._rep_speed[rid] = arg["speed"]
        for r in requeue:
            self._vec_requeue_row(r, t)

    # ---------------------------------------------------------- failures ---
    def inject_failure(self, t: float, rid: int):
        self.core.events.push(t, "fail", rid=rid)

    def inject_straggler(self, t: float, rid: int, speed: float,
                         duration: float):
        self.core.events.push(t, "slow", rid=rid, speed=speed)
        self.core.events.push(t + duration, "slow", rid=rid, speed=1.0)

    def _apply_events(self, t: float):
        if self._vec:
            return self._vec_apply_events(t)
        requeue: list[ServeRequest] = []
        for _, kind, arg in self.core.events.pop_due(t):
            r = self._by_rid.get(arg["rid"])
            if r is None:
                continue
            if kind == "fail" and not r.dead:
                r.dead = True
                self.core.pool(_GROUP).invalidate(r)
                requeue.extend(q for q in r.queue
                               if q.completion > t and not q.redispatched)
                r.queue.clear()
            elif kind == "slow":
                r.speed = arg["speed"]
        for req in requeue:
            req.redispatched = True
            self.dispatch(req, t)

    # ------------------------------------------------------------ metrics --
    def take_window_resp(self) -> np.ndarray:
        """Drain this window's booked finite response times (batch mode) —
        the per-fleet half of the federation's batched percentile: the
        driver collects every fleet's array, runs ONE ``batched_p95`` over
        the concatenation and hands each fleet its value via
        ``sample(t, p95=...)``."""
        if not self._win_resp:
            return np.zeros(0)
        resp = (self._win_resp[0] if len(self._win_resp) == 1
                else np.concatenate(self._win_resp))
        self._win_resp.clear()
        return resp[np.isfinite(resp)]

    def sample(self, t: float, p95: float | None = None) -> Snapshot:
        """Publish the fleet metric vector for the control window ending at
        ``t``: ``[util*cap, window_p95, busy, rate*10, rate]``.  Slot 1 is
        the p95 of the *booked* response times of requests dispatched since
        the last sample (0.0 for an idle window) — the latency ground truth
        ``SLAPolicy`` targets with ``key_metric_idx=1``; heap and batch
        modes compute it over the identical request multiset, so the
        published vector stays bitwise equal between them.  ``p95`` (batch
        mode only) injects a precomputed window percentile — the federation
        driver's ``batched_p95`` across all fleets — after draining the
        window buffer with ``take_window_resp``."""
        if self._vec:
            return self._vec_sample(t, p95)
        if p95 is not None:
            raise RuntimeError("precomputed p95 requires batch mode")
        w = self.cfg.control_interval_s
        exporter = self.core.exporter
        win = exporter.window_index(t)
        live = [r for r in self.replicas if not r.dead]
        cap = max(sum(self.cfg.slots_per_replica for r in live
                      if r.ready_at <= t), 1)
        busy = sum(r.busy.get(win, 0.0) for r in live) / w
        util = 100.0 * busy / cap
        rate = exporter.take_count(_GROUP) / w
        for r in live:
            if r.queue:
                r.queue = [q for q in r.queue if q.completion > t]
        resp = np.array([q.response for q in self._win_reqs
                         if math.isfinite(q.completion)])
        self._win_reqs.clear()
        p95 = float(np.percentile(resp, 95)) if resp.size else 0.0
        vals = np.array([util * cap, p95, busy, rate * 10, rate])
        ma = exporter.push(_GROUP, t, vals)
        return Snapshot(t, ma)

    def _vec_sample(self, t: float, p95: float | None = None) -> Snapshot:
        """Fleet-level columnar readout: same metric vector as the heap
        path (draining replicas count toward capacity, dead ones don't;
        busy comes from the WindowAccumulator, the window p95 from the
        dispatch chunks since the last sample — or precomputed by the
        federation's ``batched_p95``, in which case the window buffer was
        already drained by ``take_window_resp``)."""
        cfg = self.cfg
        w = cfg.control_interval_s
        exporter = self.core.exporter
        win = exporter.window_index(t)
        not_dead = ~self._rep_dead[:self._rep_n]
        cap = int(np.count_nonzero(
            not_dead & (self._rep_ready[:self._rep_n] <= t))
        ) * cfg.slots_per_replica
        self._cap_log.append((t, cap))
        busy = self._busy_acc.get(win) / w
        util = 100.0 * busy / max(cap, 1)
        rate = exporter.take_count(_GROUP) / w
        if p95 is None:
            resp = self.take_window_resp()
            p95 = float(np.percentile(resp, 95)) if resp.size else 0.0
        else:
            p95 = float(p95)
        vals = np.array([util * max(cap, 1), p95, busy, rate * 10, rate])
        return Snapshot(t, exporter.push(_GROUP, t, vals))

    # --------------------------------------------------------------- run ---
    def run(self, requests, scaler, kind: str,
            t_end: float, min_replicas: int = 1):
        """requests: sorted (arrival_t, n_tokens) list, or in batch mode
        optionally a ``(times, n_tokens)`` array pair.  scaler: PPA or
        HPA.  Batch mode drains whole window chunks through
        ``dispatch_window`` — zero per-request Python on the hot path."""
        self.scale_to(min_replicas, 0.0)
        self.make_ready_now(0.0)
        w = self.cfg.control_interval_s
        ticks = np.arange(w, t_end, w)
        if self._vec:
            times, ntoks = _as_request_arrays(requests)
            lo = 0
        ri = 0
        for tick in ticks:
            self._apply_events(tick)
            if self._vec:
                hi = int(np.searchsorted(times, tick, side="right"))
                self.dispatch_window(times[lo:hi], ntoks[lo:hi])
                self.seal_window()
                lo = hi
            else:
                while ri < len(requests) and requests[ri][0] <= tick:
                    at, ntok = requests[ri]
                    self.dispatch(ServeRequest(at, ntok), at)
                    ri += 1
            snap = self.sample(tick)
            cur = len(self.live_replicas(tick))
            if kind == "ppa":
                scaler.observe(snap)
                res = scaler.control_step(tick, self.max_replicas, cur)
                desired = max(res.replicas, min_replicas)
                scaler.maybe_update(tick)
            else:
                recent = np.stack([v for _, v in self.samples][-4:])
                desired = scaler.decide(tick, recent, self.max_replicas, cur)
            self.scale_to(max(desired, min_replicas), tick)
            self.replica_log.append((tick, desired))
        if self._vec:
            hi = int(np.searchsorted(times, t_end, side="right"))
            self.dispatch_window(times[lo:hi], ntoks[lo:hi])
            self.seal_window()
            return self
        while ri < len(requests) and requests[ri][0] <= t_end:
            at, ntok = requests[ri]
            self.dispatch(ServeRequest(at, ntok), at)
            ri += 1
        return self

    def response_times(self) -> np.ndarray:
        if self._vec:
            return np.asarray(self.completed_log.response_times())
        return np.asarray([r.response for r in self.completed
                           if math.isfinite(r.completion)])

    def idle_fraction(self) -> float:
        w = self.cfg.control_interval_s
        if self._vec:
            total_busy = total_cap = 0.0
            for t, cap in self._cap_log:
                win = self.core.exporter.window_index(t)
                total_cap += cap * w
                total_busy += self._busy_acc.get(win)
            return 1.0 - total_busy / max(total_cap, 1e-9)
        total_busy, total_cap = 0.0, 0.0
        for t, _ in self.samples:
            win = self.core.exporter.window_index(t)
            live = [r for r in self.replicas if not r.dead
                    and r.ready_at <= t]
            total_cap += len(live) * self.cfg.slots_per_replica * w
            total_busy += sum(r.busy.get(win, 0.0) for r in live)
        return 1.0 - total_busy / max(total_cap, 1e-9)


def batched_p95(segments: list) -> np.ndarray:
    """95th percentile of many response-time segments in ONE sort: the
    federation's replacement for a per-fleet ``np.percentile`` loop.  A
    single lexsort over (segment id, value) orders every fleet's window at
    once; the linear-interpolation extraction replicates numpy's
    ``_lerp`` exactly (including its ``gamma >= 0.5`` rewrite), so each
    entry is BITWISE equal to ``np.percentile(seg, 95)``.  Empty segments
    publish 0.0 — the idle-window convention of ``sample``."""
    out = np.zeros(len(segments))
    sizes = np.array([s.size for s in segments], np.int64)
    nz = np.flatnonzero(sizes)
    if not nz.size:
        return out
    vals = np.concatenate([segments[i] for i in nz])
    seg = np.repeat(np.arange(nz.size), sizes[nz])
    svals = vals[np.lexsort((vals, seg))]
    ends = np.cumsum(sizes[nz])
    starts = ends - sizes[nz]
    v = 0.95 * (sizes[nz] - 1.0)
    prev = np.floor(v)
    g = v - prev
    a = svals[starts + prev.astype(np.int64)]
    b = svals[starts + np.minimum(prev.astype(np.int64) + 1,
                                  sizes[nz] - 1)]
    diff = b - a
    r = a + diff * g
    hi = g >= 0.5
    r[hi] = b[hi] - diff[hi] * (1.0 - g[hi])
    out[nz] = r
    return out


def _as_request_arrays(requests) -> tuple[np.ndarray, np.ndarray]:
    """Accept a legacy sorted [(t, n_tokens)] sequence or a
    (times, n_tokens) pair of numpy arrays; return float64 arrays.  The
    array-pair form is recognised by its ndarray elements — a tuple of
    two (t, n) request pairs would otherwise be ambiguous with a
    length-2 times vector."""
    if (isinstance(requests, tuple) and len(requests) == 2
            and isinstance(requests[0], np.ndarray)):
        return (np.asarray(requests[0], np.float64),
                np.asarray(requests[1], np.float64))
    if len(requests):
        arr = np.asarray(requests, np.float64)
        return arr[:, 0], arr[:, 1]
    return np.zeros(0), np.zeros(0)
