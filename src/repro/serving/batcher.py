"""Continuous batcher: request queue -> engine slots, with the metric
exporter the PPA consumes ([slot-utilisation, kv-memory, in, out, rate])."""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.core.metrics import Snapshot
from repro.serving.engine import DecodeEngine


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray
    max_new: int
    arrival: float = 0.0
    completed: float = float("nan")
    output: list | None = None


class ContinuousBatcher:
    def __init__(self, engine: DecodeEngine):
        self.engine = engine
        self.queue: deque[Request] = deque()
        self.done: list[Request] = []
        self._inflight: dict[int, Request] = {}
        self._window_reqs = 0
        self.t = 0.0

    def submit(self, req: Request):
        self.queue.append(req)
        self._window_reqs += 1

    def step(self, t: float | None = None):
        """Admit waiting requests into free slots, then decode one token."""
        if t is not None:
            self.t = t
        while self.queue and self.engine.free_slots():
            req = self.queue.popleft()
            self.engine.insert(req.request_id, req.prompt, req.max_new)
            self._inflight[req.request_id] = req
        for rid, toks in self.engine.step():
            req = self._inflight.pop(rid)
            req.output = toks
            req.completed = self.t
            self.done.append(req)

    def drain(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or self._inflight) and steps < max_steps:
            self.step()
            steps += 1
        return self.done

    # ------------------------------------------------------------ metrics --
    def snapshot(self, t: float, window_s: float) -> Snapshot:
        util = self.engine.utilization()
        rate = self._window_reqs / window_s
        self._window_reqs = 0
        kv_mb = 0.0  # static buffers; per-slot occupancy is the live signal
        vals = np.array([util * 100.0, kv_mb, len(self.queue),
                         self.engine.tokens_out, rate])
        return Snapshot(t, vals)
