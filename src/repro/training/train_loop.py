"""Fault-tolerant training loop: checkpoint/restart, grad accumulation,
failure injection hooks, elastic re-mesh recovery.

``train()`` is the single driver used by examples/train launcher: it builds
the jitted train step (optionally wrapped with int8-compressed gradient
all-reduce), restores the newest committed checkpoint if one exists, and
survives injected step failures by rolling back to the last checkpoint —
the same path a real fleet takes on node loss.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.checkpoint import (AsyncCheckpointer, latest_step, load_checkpoint,
                              save_checkpoint)
from repro.configs.base import ModelConfig
from repro.data import SyntheticLMData
from repro.launch.steps import make_train_step
from repro.training.optimizer import AdamWConfig, adamw_init


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    ckpt_every: int = 25
    ckpt_dir: str | None = None
    async_ckpt: bool = True
    grad_accum: int = 1
    log_every: int = 10
    seed: int = 0
    lr: float = 3e-4
    warmup_frac: float = 0.1


def train(cfg: ModelConfig, tc: TrainConfig, *, mesh=None, rules=None,
          fail_at: set[int] | None = None, log: Callable = print):
    """Returns (params, metrics_history).  ``fail_at``: steps at which a
    simulated node failure raises; the loop recovers from the checkpoint."""
    from repro.training.optimizer import AdamWConfig
    opt_cfg = AdamWConfig(lr=tc.lr, moments_dtype=cfg.opt_moments_dtype,
                          warmup_steps=max(int(tc.steps * tc.warmup_frac), 1),
                          total_steps=tc.steps)
    model, opt_cfg, step_fn = make_train_step(cfg, mesh, rules, opt_cfg)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    params = model.init(jax.random.PRNGKey(tc.seed), jnp.float32)
    params = jax.tree.map(lambda x: x.astype(jnp.bfloat16), params)
    opt_state = adamw_init(params, opt_cfg)

    start = 0
    ckpt = AsyncCheckpointer(tc.ckpt_dir) if (tc.ckpt_dir and tc.async_ckpt) else None
    if tc.ckpt_dir and latest_step(tc.ckpt_dir) is not None:
        (params, opt_state), start = load_checkpoint(
            tc.ckpt_dir, (params, opt_state))
        log(f"[train] restored checkpoint at step {start}")

    data = SyntheticLMData(cfg.vocab, tc.seq_len, tc.global_batch,
                           seed=tc.seed, mesh=mesh, rules=rules)
    history = []
    fail_at = set(fail_at or ())
    step = start
    t0 = time.time()
    while step < tc.steps:
        try:
            if step in fail_at:
                fail_at.discard(step)
                raise RuntimeError(f"injected node failure at step {step}")
            batch = data.batch_at(step)
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            step += 1
            if step % tc.log_every == 0 or step == tc.steps:
                m = {k: float(v) for k, v in metrics.items()}
                history.append({"step": step, **m})
                log(f"[train] step {step} loss={m['loss']:.4f} "
                    f"lr={m['lr']:.2e} gnorm={m['grad_norm']:.3f} "
                    f"({(time.time()-t0):.1f}s)")
            if tc.ckpt_dir and step % tc.ckpt_every == 0:
                if ckpt:
                    ckpt.save(step, (params, opt_state))
                else:
                    save_checkpoint(tc.ckpt_dir, step, (params, opt_state))
        except RuntimeError as e:
            log(f"[train] FAILURE: {e} — recovering from checkpoint")
            if ckpt:
                ckpt.wait()
            if tc.ckpt_dir and latest_step(tc.ckpt_dir) is not None:
                # re-init buffers (donated args were invalidated) then restore
                params = model.init(jax.random.PRNGKey(tc.seed), jnp.float32)
                params = jax.tree.map(lambda x: x.astype(jnp.bfloat16), params)
                opt_state = adamw_init(params, opt_cfg)
                (params, opt_state), step = load_checkpoint(
                    tc.ckpt_dir, (params, opt_state))
                log(f"[train] resumed at step {step}")
            else:
                raise
    if ckpt:
        ckpt.wait()
    return params, history
