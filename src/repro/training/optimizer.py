"""AdamW + schedules as pure pytree functions (optax is not available offline).

Moments dtype is configurable (``cfg.opt_moments_dtype='bfloat16'`` halves
optimizer HBM for llama3-405b).  Global-norm clipping and decoupled weight
decay match the standard AdamW definition.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0
    moments_dtype: str = "float32"
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(c: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(c.warmup_steps, 1)
    prog = jnp.clip((step - c.warmup_steps) /
                    jnp.maximum(c.total_steps - c.warmup_steps, 1), 0.0, 1.0)
    cos = c.min_lr_ratio + (1 - c.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return c.lr * jnp.where(step < c.warmup_steps, warm, cos)


def adamw_init(params, c: AdamWConfig):
    dt = jnp.dtype(c.moments_dtype)

    def zeros(p):
        return jnp.zeros(p.shape, dt)

    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(grads, state, params, c: AdamWConfig):
    step = state["step"] + 1
    lr = schedule(c, step)
    gnorm = global_norm(grads)
    if c.clip_norm is not None:
        scale = jnp.minimum(1.0, c.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1t = 1 - c.b1 ** step.astype(jnp.float32)
    b2t = 1 - c.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(c.moments_dtype)

    def upd(g, mu, nu, p):
        g32 = g.astype(jnp.float32)
        mu32 = c.b1 * mu.astype(jnp.float32) + (1 - c.b1) * g32
        nu32 = c.b2 * nu.astype(jnp.float32) + (1 - c.b2) * g32 * g32
        mhat = mu32 / b1t
        nhat = nu32 / b2t
        delta = mhat / (jnp.sqrt(nhat) + c.eps) + c.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), mu32.astype(mdt), nu32.astype(mdt)

    out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, {
        "lr": lr, "grad_norm": gnorm}
