# Closed-loop, latency-driven workload scenarios (DESIGN.md §13).
#
# The open-loop generators (poisson_arrivals, bursty_trace) fix the whole
# arrival tape up front, so the load is independent of how well the system
# serves it.  Real edge clients are not so polite: when a window's latency
# blows past their patience they *retry into the outage*, amplifying the
# very overload that slowed them down.  ``ClosedLoopClient`` models that
# feedback: each control window's base Poisson arrivals are joined by
# retries scheduled from earlier violated windows, with capped exponential
# backoff + uniform jitter, so a failure storm self-amplifies and then
# ring-downs realistically once latency recovers.
#
# The client is pulled one window at a time by the federation driver
# (MultiFleetSim), which feeds the fleet's *observed* p95 for the previous
# window back in — so the whole loop stays deterministic under seed: the
# arrivals are a pure function of (seed, feedback sequence) and the
# feedback is itself a deterministic function of the arrivals.
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sim.chaos import ChaosConfig, ChaosSchedule


@dataclasses.dataclass(frozen=True)
class ClientConfig:
    """Closed-loop client behaviour knobs."""

    rate_per_s: float                 # base Poisson arrival rate
    window_s: float = 15.0
    n_tokens: int = 80                # work size per request
    retry_threshold: float = 0.5      # p95 (s) above which clients retry
    retry_frac: float = 0.6           # retry propensity scale
    backoff_base_s: float = 2.0       # first-retry backoff
    backoff_cap_s: float = 60.0       # capped exponential ceiling
    jitter: float = 0.5               # uniform multiplicative jitter span
    max_retries: int = 3


class ClosedLoopClient:
    """Per-window arrival generator with retry/backoff amplification.

    ``next_window(t1, observed_p95)`` returns ``(times, n_tokens)`` for the
    window ``(t1 - window_s, t1]``: fresh Poisson arrivals plus any retries
    whose backoff lands in the window.  ``observed_p95`` is the latency the
    *previous* window delivered (the newest feedback a client could have);
    when it exceeds ``retry_threshold`` a binomial share of the previous
    window's arrivals re-enter after ``min(base * 2^a, cap) * (1 + jU)``
    seconds, attempt-capped so a dead backend cannot recruit an unbounded
    retry herd.
    """

    def __init__(self, cfg: ClientConfig, seed=0):
        self.cfg = cfg
        self.seed = seed  # int or SeedSequence; kept verbatim for reset()
        self._rng = np.random.default_rng(seed)
        # pending retries: parallel arrays of (due time, attempt number)
        self._due = np.zeros(0, np.float64)
        self._att = np.zeros(0, np.int64)
        # previous window's arrival attempts (retry recruitment pool)
        self._prev_att = np.zeros(0, np.int64)
        self.total_retries = 0

    def reset(self) -> None:
        self._rng = np.random.default_rng(self.seed)
        self._due = np.zeros(0, np.float64)
        self._att = np.zeros(0, np.int64)
        self._prev_att = np.zeros(0, np.int64)
        self.total_retries = 0

    def _schedule_retries(self, t0: float, observed_p95: float) -> None:
        cfg = self.cfg
        pool = self._prev_att[self._prev_att < cfg.max_retries]
        if pool.size == 0 or not np.isfinite(observed_p95) \
                or observed_p95 <= cfg.retry_threshold:
            return
        excess = observed_p95 / cfg.retry_threshold - 1.0
        p = min(cfg.retry_frac * excess, 0.95)
        mask = self._rng.random(pool.size) < p
        att = pool[mask] + 1
        if att.size == 0:
            return
        back = np.minimum(cfg.backoff_base_s * 2.0 ** (att - 1),
                          cfg.backoff_cap_s)
        back = back * (1.0 + cfg.jitter * self._rng.random(att.size))
        self._due = np.concatenate([self._due, t0 + back])
        self._att = np.concatenate([self._att, att])
        self.total_retries += int(att.size)

    def next_window(self, t1: float, observed_p95: float):
        """Arrivals for ``(t1 - window_s, t1]`` given last window's p95."""
        cfg = self.cfg
        t0 = t1 - cfg.window_s
        self._schedule_retries(t0, float(observed_p95))
        n_base = self._rng.poisson(cfg.rate_per_s * cfg.window_s)
        base_t = t0 + self._rng.random(n_base) * cfg.window_s
        ripe = self._due <= t1
        retry_t = np.maximum(self._due[ripe], t0 + 1e-9)
        retry_a = self._att[ripe]
        self._due, self._att = self._due[~ripe], self._att[~ripe]
        times = np.concatenate([base_t, retry_t])
        atts = np.concatenate([np.zeros(n_base, np.int64), retry_a])
        order = np.argsort(times, kind="stable")
        self._prev_att = atts[order]
        times = times[order]
        ntoks = np.full(times.size, cfg.n_tokens, np.int64)
        return times, ntoks


@dataclasses.dataclass
class ChaosScenario:
    """A bound (chaos tape, per-fleet closed-loop clients) pair."""

    chaos: ChaosSchedule
    clients: dict[str, ClosedLoopClient]

    def reset(self) -> "ChaosScenario":
        self.chaos.reset()
        for c in self.clients.values():
            c.reset()
        return self


def make_chaos_scenario(
    fleet_names: list[str],
    *,
    t_end: float,
    seed: int,
    chaos_cfg: ChaosConfig | None = None,
    client_cfg: ClientConfig | None = None,
    n_shards: int = 1,
) -> ChaosScenario:
    """One seeded scenario: a chaos tape over the fleets-as-zones plus one
    independent closed-loop client per fleet (child seeds, so adding a
    fleet never perturbs another fleet's draws)."""
    chaos_cfg = chaos_cfg or ChaosConfig()
    chaos = ChaosSchedule.build(chaos_cfg, n_zones=len(fleet_names),
                                t_end=t_end, seed=seed, n_shards=n_shards)
    clients = {}
    if client_cfg is not None:
        seeds = np.random.SeedSequence(seed + 1).spawn(len(fleet_names))
        clients = {n: ClosedLoopClient(client_cfg, seed=s)
                   for n, s in zip(fleet_names, seeds)}
    return ChaosScenario(chaos, clients)
