"""Serverless-style bursty trace: Markov-modulated Poisson arrivals.

Edge/serverless traffic is not diurnal-smooth like the NASA log — it is an
ON/OFF process with flash bursts: long quiet stretches, sudden sustained
activity episodes, and short spikes that decay over minutes (cold-start
storms, fan-out retries, event-triggered function chains).  This is the
regime where the Attention-Double-LSTM's temporal attention pays off: the
forecast signal lives in *where in the window* the burst onset happened
(a rising pulse and a decaying one can share the same height — only the
onset age disambiguates the next step), which a plain LSTM's
single final hidden state is "temporally blind" to (PAPERS.md).

``bursty_trace`` returns a per-minute request-count series (same contract
as ``nasa_trace``) driven by a two-state Markov chain:

* **OFF** — a low background rate (health checks, stragglers);
* **ON** — a sustained elevated rate with a ~3-minute onset ramp (the
  autoscaler-visible transient) and slow AR(1) wander;
* **flash bursts + retry echoes** — Poisson-seeded attack/decay pulses
  (more frequent while ON): a ~3-minute ramp to the peak, then a fast
  decay.  Every pulse spawns *retry echoes* — attenuated copies at fixed
  backoff lags (defaults 6 and 12 minutes), the retry-storm signature of
  event-driven fan-out.  Mid-pulse the next value depends on the burst's
  *age* (rising vs falling phase), and an echo's onset is predictable
  only from the position of its parent inside the window — the learnable
  window-position structure the A/B forecast lane measures.

``bursty_requests`` converts counts to sorted ``(t, kind, zone)`` arrival
tuples exactly like ``nasa_requests`` (piecewise-constant-rate Poisson,
Sort/Eigen 0.9/0.1, Eigen forwarded to the cloud).
"""
from __future__ import annotations

import numpy as np


def bursty_trace(days: int = 2, scale: float = 1.0, seed: int = 23,
                 p_on: float = 1 / 45.0, p_off: float = 1 / 30.0,
                 echo_lags: tuple = (6, 12), echo_amps: tuple = (0.6, 0.36)
                 ) -> np.ndarray:
    """Per-minute request counts, shape (days*1440,).

    ``p_on`` / ``p_off`` are the per-minute OFF->ON / ON->OFF transition
    probabilities (defaults: ~45 min mean quiet spells, ~30 min mean
    activity episodes).  ``echo_lags`` / ``echo_amps`` shape the retry
    storms: each seed pulse of amplitude A spawns echo pulses of
    ``A * echo_amps[k]`` at ``echo_lags[k]`` minutes after onset."""
    rng = np.random.default_rng(seed)
    n = int(days * 1440)
    # two-state Markov chain over minutes
    on = np.zeros(n, bool)
    state = False
    for i in range(n):
        if state:
            state = rng.random() >= p_off
        else:
            state = rng.random() < p_on
        on[i] = state
    # ON episodes ramp in over ~3 minutes (the scaling-relevant transient):
    # minutes-since-onset, reset at each OFF->ON edge
    age = np.zeros(n)
    run = 0.0
    for i in range(n):
        run = run + 1.0 if on[i] else 0.0
        age[i] = run
    ramp = np.minimum(age / 3.0, 1.0)
    # slow AR(1) wander modulates the ON plateau (what a forecaster can
    # track; without it ON is a flat line and persistence wins trivially)
    ar = np.zeros(n)
    for i in range(1, n):
        ar[i] = 0.97 * ar[i - 1] + rng.normal(0, 0.08)
    base = 4.0 + 60.0 * ramp * np.exp(ar)
    # flash bursts: Poisson-seeded attack/decay pulses — a ~3-minute ramp
    # to the peak, then a fast ~1.5-minute-half-life decay; 4x more
    # likely while ON (event-triggered chains).  The pulse is
    # deliberately NOT memoryless: mid-pulse the next value depends on
    # the burst's age (rising vs falling phase), not just its current
    # height.  Each seed pulse spawns retry echoes at fixed backoff lags
    # (attenuated copies): predicting an echo's onset requires knowing
    # *where in the window* its parent fired — the position signal the
    # temporal-attention forecaster reads out and a final-hidden-state
    # readout compresses away.
    pulse = np.concatenate([
        np.linspace(0.33, 1.0, 3),
        np.exp(-np.log(2.0) / 1.5 * np.arange(1, 6, dtype=float))])
    bursts = np.zeros(n)
    p_spike = np.where(on, 4.0, 1.0) * (days * 36.0) / n  # ~80 seeds/day
    spikes = rng.random(n) < p_spike

    def _add(c, amp):
        w = min(n - c, len(pulse))
        if w > 0:
            bursts[c:c + w] += amp * pulse[:w]

    for c in np.flatnonzero(spikes):
        amp = rng.uniform(80, 200)
        _add(c, amp)
        for lag, ea in zip(echo_lags, echo_amps):
            _add(c + int(lag), amp * ea)
    noise = rng.normal(0, 1.0, n)
    return np.clip(base + bursts + noise, 0.5, None) * scale


def bursty_requests(counts: np.ndarray, zones: list[str] | None = None,
                    seed: int = 29) -> list[tuple[float, str, str]]:
    """Poisson arrivals within each minute from the count series; requests
    split across edge zones; Eigen (10%) forwarded to the cloud — the same
    contract as ``nasa_requests``."""
    zones = zones or ["edge-0", "edge-1"]
    rng = np.random.default_rng(seed)
    tasks: list[tuple[float, str, str]] = []
    for m, lam in enumerate(counts):
        n = rng.poisson(lam)
        times = np.sort(rng.uniform(m * 60.0, (m + 1) * 60.0, n))
        for t in times:
            kind = "eigen" if rng.random() < 0.1 else "sort"
            zone = zones[int(rng.integers(len(zones)))]
            serve_zone = "cloud" if kind == "eigen" else zone
            tasks.append((float(t), kind, serve_zone))
    tasks.sort(key=lambda x: x[0])
    return tasks
