"""*Random Access* workload generator — faithful to paper Algorithm 2.

    while True:
        load_type   <- Random([light, medium, heavy])
        request_num <- Random(Range(20, 200))
        for i in 0..request_num:
            task <- Random([sort]*9 + [eigen])     # 0.9 / 0.1
            Request(task)
            sleep <- Range(0.1,0.3) heavy | Range(0.5,1) medium | Range(2,5) light
            Sleep(Random(sleep))

Sort tasks are served at the generating edge zone; Eigen tasks are forwarded
to the cloud (paper §5.1.2).  One generator per edge zone.
"""
from __future__ import annotations

import numpy as np

SLEEP_RANGES = {"heavy": (0.1, 0.3), "medium": (0.5, 1.0), "light": (2.0, 5.0)}


def random_access(t_end: float, zones: list[str] | None = None,
                  seed: int = 0) -> list[tuple[float, str, str]]:
    """Returns sorted [(arrival_t, kind, serving_zone)]."""
    zones = zones or ["edge-0", "edge-1"]
    rng = np.random.default_rng(seed)
    tasks: list[tuple[float, str, str]] = []
    for zone in zones:
        t = 0.0
        while t < t_end:
            load = rng.choice(["light", "medium", "heavy"])
            lo, hi = SLEEP_RANGES[load]
            n = int(rng.integers(20, 200))
            for _ in range(n):
                kind = "eigen" if rng.random() < 0.1 else "sort"
                serve_zone = "cloud" if kind == "eigen" else zone
                tasks.append((t, kind, serve_zone))
                t += float(rng.uniform(lo, hi))
                if t >= t_end:
                    break
    tasks.sort(key=lambda x: x[0])
    return tasks
