from repro.workloads.random_access import random_access
from repro.workloads.nasa import nasa_trace, nasa_requests
from repro.workloads.bursty import bursty_trace, bursty_requests
from repro.workloads.fleet_scale import WindowedArrivals, poisson_arrivals
from repro.workloads.scenarios import (ChaosScenario, ClientConfig,
                                       ClosedLoopClient, make_chaos_scenario)
