"""Fleet-scale arrival batching (DESIGN.md §3, "Fleet scale").

The seed workload generators emit one Python tuple per task and the driver
dispatches them one at a time — fine at 10² pods, the bottleneck at 10⁴–10⁵.
``WindowedArrivals`` keeps a whole trace as flat numpy arrays (times, kind
codes, zone codes) pre-indexed by control window, so the vectorised driver
(``ClusterSim`` batch mode) drains each (window, zone) chunk through the
array pool in a handful of numpy rounds instead of one Python iteration per
event.

Generation is vectorised too: ``poisson_arrivals`` draws per-window Poisson
counts and uniform offsets as arrays (millions of arrivals in milliseconds),
and ``WindowedArrivals.from_tasks`` converts any legacy ``[(t, kind, zone)]``
list so the existing Random Access / NASA generators ride the same path.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class WindowedArrivals:
    """A task trace as flat arrays, sliceable per (control window, zone).

    Window ``j`` (1-based, matching control tick ``j * window_s``) holds the
    arrivals in ``((j - 1) * window_s, j * window_s]`` — the same boundary
    the per-event driver uses (``t <= tick`` dispatches before the tick's
    control step).  ``times`` is globally sorted; kind/zone vocabularies are
    sorted name tuples so codes are deterministic.
    """

    times: np.ndarray  # (N,) float64, sorted
    kinds: np.ndarray  # (N,) int16 codes into kind_names
    zones: np.ndarray  # (N,) int16 codes into zone_names
    kind_names: tuple[str, ...]
    zone_names: tuple[str, ...]
    window_s: float

    def __post_init__(self):
        self.times = np.asarray(self.times, np.float64)
        self.kinds = np.asarray(self.kinds, np.int16)
        self.zones = np.asarray(self.zones, np.int16)
        if len(self.times) and np.any(np.diff(self.times) < 0):
            raise ValueError("arrival times must be sorted")
        t_max = float(self.times[-1]) if len(self.times) else 0.0
        n_win = int(np.ceil(t_max / self.window_s)) + 1
        bounds = self.window_s * np.arange(1, n_win + 1)
        offs = np.searchsorted(self.times, bounds, side="right")
        self._offsets = np.concatenate([[0], offs])

    def __len__(self):
        return len(self.times)

    @property
    def n_windows(self) -> int:
        return len(self._offsets) - 1

    def window_chunks(self, j: int):
        """Per-zone (zone_name, times, kinds) chunks for window ``j``,
        zones in code order; chunk times stay sorted."""
        if j < 1 or j > self.n_windows:
            return
        lo, hi = int(self._offsets[j - 1]), int(self._offsets[j])
        yield from self._zone_split(lo, hi)

    def tail_chunks(self, t_last_tick: float, t_end: float):
        """Per-zone chunks for the trailing arrivals in
        ``(t_last_tick, t_end]`` (the driver's post-tick drain)."""
        lo = int(np.searchsorted(self.times, t_last_tick, side="right"))
        hi = int(np.searchsorted(self.times, t_end, side="right"))
        yield from self._zone_split(lo, hi)

    def _zone_split(self, lo: int, hi: int):
        if hi <= lo:
            return
        zc = self.zones[lo:hi]
        if len(self.zone_names) == 1:
            yield self.zone_names[0], self.times[lo:hi], self.kinds[lo:hi]
            return
        for code, name in enumerate(self.zone_names):
            idx = np.flatnonzero(zc == code)
            if idx.size:
                yield name, self.times[lo:hi][idx], self.kinds[lo:hi][idx]

    @classmethod
    def from_tasks(cls, tasks, window_s: float) -> "WindowedArrivals":
        """Convert a legacy sorted ``[(t, kind, zone)]`` task list."""
        if not tasks:
            return cls(
                np.zeros(0),
                np.zeros(0, np.int16),
                np.zeros(0, np.int16),
                ("sort",),
                ("edge-0",),
                window_s,
            )
        times = np.asarray([t for t, _, _ in tasks], np.float64)
        kind_names = tuple(sorted({k for _, k, _ in tasks}))
        zone_names = tuple(sorted({z for _, _, z in tasks}))
        kcode = {k: i for i, k in enumerate(kind_names)}
        zcode = {z: i for i, z in enumerate(zone_names)}
        kinds = np.asarray([kcode[k] for _, k, _ in tasks], np.int16)
        zones = np.asarray([zcode[z] for _, _, z in tasks], np.int16)
        return cls(times, kinds, zones, kind_names, zone_names, window_s)


def window_offsets(times: np.ndarray, window_s: float,
                   t_end: float) -> np.ndarray:
    """Pre-bucket one sorted arrival stream by control window: one
    ``searchsorted`` over every tick boundary up front, zero-copy slices
    per window after (the columnar federation driver's per-fleet dispatch,
    DESIGN.md §12).

    ``offsets[j-1]:offsets[j]`` (1-based ``j``) slices window ``j``'s
    arrivals in ``((j-1)·w, j·w]`` — the same boundary the per-event
    driver uses — and the final slice ``offsets[-2]:offsets[-1]`` is the
    post-last-tick tail up to ``t_end``.  Arrivals after ``t_end`` are
    excluded, matching the per-event drivers."""
    times = np.asarray(times, np.float64)
    bounds = np.append(np.arange(window_s, t_end, window_s), t_end)
    offs = np.searchsorted(times, bounds, side="right")
    return np.concatenate([[0], offs]).astype(np.int64)


def poisson_arrivals(
    rate_per_s,
    t_end: float,
    window_s: float,
    zone: str = "fleet-0",
    kind: str = "sort",
    seed: int = 0,
) -> WindowedArrivals:
    """Vectorised piecewise-constant-rate Poisson arrival generator.

    ``rate_per_s`` is a scalar or a per-window array (diurnal profiles);
    counts are drawn per window, offsets uniformly within each window —
    all as single numpy calls, so 10⁷-event traces generate in ~seconds.
    """
    rng = np.random.default_rng(seed)
    n_win = int(np.ceil(t_end / window_s))
    rates = np.broadcast_to(np.asarray(rate_per_s, np.float64), (n_win,))
    counts = rng.poisson(rates * window_s)
    total = int(counts.sum())
    base = np.repeat(np.arange(n_win) * window_s, counts)
    times = base + rng.random(total) * window_s
    times = np.sort(times[times <= t_end])
    return WindowedArrivals(
        times,
        np.zeros(len(times), np.int16),
        np.zeros(len(times), np.int16),
        (kind,),
        (zone,),
        window_s,
    )
