from repro.distributed.sharding import (
    ShardingRules,
    DEFAULT_RULES,
    MULTIPOD_RULES,
    logical_to_pspec,
    shard_activation,
    tree_pspecs,
)
