"""Distributed-optimization tricks: int8-compressed gradient all-reduce with
error feedback, expressed with shard_map + psum so GSPMD keeps the collective
on the wire at 1/4 the bytes.

At 1000+ node scale the data-parallel gradient all-reduce dominates the
step's collective term (see EXPERIMENTS.md §Roofline for train_4k cells);
int8 quantisation cuts its wire bytes 4x (2x vs bf16), and the error-feedback
accumulator keeps SGD/Adam convergence (Seide et al. / 1-bit Adam lineage).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map


def quantize_int8(x: jax.Array):
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(x: jax.Array, axis_name: str):
    """int8-quantized psum: quantize locally, sum int32 on the wire (the
    all-reduce operand is 1/4 the f32 bytes), rescale with the max scale."""
    q, scale = quantize_int8(x)
    scale_max = jax.lax.pmax(scale, axis_name)
    # requantize against the shared scale so the integer sum is exact
    q2 = jnp.clip(jnp.round(x.astype(jnp.float32) / scale_max), -127, 127)
    total = jax.lax.psum(q2.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale_max


def make_compressed_grad_allreduce(mesh, data_axis: str = "data"):
    """Returns fn(grads_tree, err_tree) -> (reduced_grads, new_err) where
    grads are partial (per-data-shard) sums; error feedback accumulates the
    quantisation residual locally."""

    def one(g, err):
        def inner(g_shard, err_shard):
            total = compressed_psum(g_shard + err_shard, data_axis)
            mean = total / mesh.shape[data_axis]
            # local residual: what quantisation dropped this round
            new_err = (g_shard + err_shard) - mean
            return mean.astype(g_shard.dtype), new_err.astype(err_shard.dtype)

        spec = P()  # replicated-per-shard view; grads already sharded by pjit
        return shard_map(inner, mesh=mesh, in_specs=(spec, spec),
                         out_specs=(spec, spec), check_rep=False)(g, err)

    def allreduce(grads, err):
        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(err)
        out = [one(g, e) for g, e in zip(flat_g, flat_e)]
        gs = jax.tree.unflatten(tdef, [o[0] for o in out])
        es = jax.tree.unflatten(tdef, [o[1] for o in out])
        return gs, es

    return allreduce
