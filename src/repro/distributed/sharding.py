"""Logical-axis sharding rules (MaxText-style) mapped onto the production mesh.

Every parameter / activation dimension carries a *logical* axis name
('batch', 'heads', 'mlp', 'vocab', ...).  A ``ShardingRules`` table maps each
logical name to zero or more *physical* mesh axes.  ``logical_to_pspec``
resolves a tuple of logical names into a ``PartitionSpec``, enforcing the two
invariants that otherwise produce silent mis-sharding at scale:

* a physical mesh axis is used at most once per spec (first logical dim wins);
* a dimension is only sharded if its size is divisible by the product of the
  assigned mesh axis sizes (e.g. 8 KV heads on a 16-way model axis fall back
  to replication rather than erroring or padding implicitly).
"""
from __future__ import annotations

from typing import Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

ShardingRules = Mapping[str, tuple[str, ...]]

# Single-pod rules: mesh ('data', 'model').
DEFAULT_RULES: ShardingRules = {
    # activations
    "batch": ("data",),
    "seq": (),
    "kv_seq": (),
    "embed": (),
    "act_heads": ("model",),
    "act_kv_heads": ("model",),
    "act_mlp": ("model",),
    "act_vocab": ("model",),
    "act_experts": ("model",),
    "head_dim": (),
    "resid_seq": (),        # seq_shard_resid=True remaps to ('model',)
    "qk_dim": (),
    "state": (),
    # params
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": (),          # TP-MoE default: experts replicated, expert ffn sharded
    "expert_mlp": ("model",),
    "layers": (),
    "fsdp": (),             # extra FSDP dim for big models; enable via fsdp_rules()
    "norm": (),
}

# Multi-pod rules: mesh ('pod', 'data', 'model'); batch spans pod x data.
MULTIPOD_RULES: ShardingRules = dict(DEFAULT_RULES) | {
    "batch": ("pod", "data"),
}


def fsdp_rules(rules: ShardingRules) -> ShardingRules:
    """Enable FSDP: parameters additionally sharded over the data axis on the
    dimension tagged 'fsdp' (their non-model dim).  XLA inserts per-scan-step
    all-gathers at use — the standard weight-stationary-compatible ZeRO-3."""
    return dict(rules) | {"fsdp": ("data",)}


def ep_rules(rules: ShardingRules) -> ShardingRules:
    """Expert parallelism: shard the expert dim over 'model', replicate the
    per-expert ffn dim (each shard owns whole experts)."""
    return dict(rules) | {"experts": ("model",), "expert_mlp": (),
                          "act_experts": ("model",)}


def seqp_rules(rules: ShardingRules) -> ShardingRules:
    """Context/sequence parallelism for long-context cells: shard kv_seq over
    the data axis (used by long_500k decode where batch=1 cannot occupy it)."""
    return dict(rules) | {"kv_seq": ("data",), "batch": ()}


def _axis_size(mesh: Mesh, names: Sequence[str]) -> int:
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size


def logical_to_pspec(
    axes: Sequence[str | None],
    shape: Sequence[int],
    rules: ShardingRules,
    mesh: Mesh,
) -> P:
    assert len(axes) == len(shape), (axes, shape)
    used: set[str] = set()
    parts: list = []
    for name, dim in zip(axes, shape):
        if name is None:
            parts.append(None)
            continue
        assign = tuple(rules.get(name, ()) or ())
        assign = tuple(a for a in assign if a in mesh.shape and a not in used)
        # longest prefix of the assignment that divides the dim size
        while assign and dim % _axis_size(mesh, assign) != 0:
            assign = assign[:-1]
        if not assign:
            parts.append(None)
            continue
        used.update(assign)
        parts.append(assign if len(assign) > 1 else assign[0])
    return P(*parts)


def named_sharding(axes, shape, rules, mesh) -> NamedSharding:
    return NamedSharding(mesh, logical_to_pspec(axes, shape, rules, mesh))


def shard_activation(x: jax.Array, axes: Sequence[str | None], rules: ShardingRules,
                     mesh: Mesh | None = None) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op outside jit/mesh."""
    if mesh is None:
        return x
    spec = logical_to_pspec(axes, x.shape, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# Control-plane mesh: ONE physical axis 'shards' over which the sharded
# control plane partitions its target axis (core/device_plane.py).  Kept
# here so the plane reuses the same Mesh/NamedSharding vocabulary as the
# model meshes above.
CONTROL_AXIS = "shards"

CONTROL_RULES: ShardingRules = {
    "targets": (CONTROL_AXIS,),   # the leading Z axis of every plane array
    "ring": (),                   # per-target ring rows stay local
    "metric": (),
}


def control_mesh(n_devices: int | None = None) -> Mesh:
    """1-D ('shards',) mesh over the first ``n_devices`` local devices
    (all of them by default).  With
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` the CPU backend
    exposes N virtual devices, which is how CI exercises the multi-device
    control plane without accelerators."""
    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if not 1 <= n <= len(devs):
        raise ValueError(f"control_mesh: n_devices={n} outside "
                         f"[1, {len(devs)}] available devices")
    return Mesh(np.asarray(devs[:n]), (CONTROL_AXIS,))


def tree_pspecs(spec_tree, rules: ShardingRules, mesh: Mesh):
    """Map a tree of params.Spec (or of (shape, axes) pairs) to PartitionSpecs."""
    from repro.models.params import Spec

    def one(s):
        if isinstance(s, Spec):
            return logical_to_pspec(s.axes, s.shape, rules, mesh)
        shape, axes = s
        return logical_to_pspec(axes, shape, rules, mesh)

    return jax.tree.map(one, spec_tree,
                        is_leaf=lambda x: isinstance(x, Spec) or
                        (isinstance(x, tuple) and len(x) == 2 and
                         isinstance(x[0], tuple)))
