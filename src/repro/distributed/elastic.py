"""Elastic scaling: rebuild the mesh after losing a data slice and reshard
the training state onto the survivors.

On a real fleet, losing a host removes a row of the 'data' axis; training
resumes on an (n-k, model) mesh from the latest checkpoint, with the global
batch either shrunk or re-spread.  Here the same logic is exercised with
host placeholder devices: ``shrink_mesh`` builds the survivor mesh and
``reshard_tree`` device_puts a checkpointed pytree onto it.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.distributed.sharding import logical_to_pspec


def shrink_mesh(mesh: Mesh, axis: str, lost: int = 1) -> Mesh:
    """Survivor mesh with ``lost`` rows removed from ``axis``."""
    names = mesh.axis_names
    shape = dict(mesh.shape)
    assert shape[axis] > lost, "cannot lose every slice"
    devs = np.asarray(mesh.devices)
    ax = names.index(axis)
    take = [slice(None)] * devs.ndim
    take[ax] = slice(0, shape[axis] - lost)
    survivors = devs[tuple(take)]
    return Mesh(survivors, names)


def reshard_tree(tree, axes_tree, new_mesh: Mesh, rules):
    """device_put every leaf onto the survivor mesh per its logical axes."""
    def one(x, axes):
        spec = logical_to_pspec(axes, np.shape(x), rules, new_mesh)
        return jax.device_put(x, NamedSharding(new_mesh, spec))

    return jax.tree.map(one, tree, axes_tree,
                        is_leaf=lambda x: not isinstance(x, dict))


def elastic_batch_size(global_batch: int, old_data: int, new_data: int) -> int:
    """Keep per-shard batch constant: shrink the global batch with the mesh
    (the optimizer's lr schedule is tokens-based so resume stays smooth)."""
    per = global_batch // old_data
    return per * new_data
