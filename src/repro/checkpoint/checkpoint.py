"""Fault-tolerant checkpointing: npz shards + JSON manifest.

Layout: <dir>/step_<n>/arrays.npz + manifest.json (tree structure, shapes,
dtypes, completion marker).  Writes go to a temp dir and are atomically
renamed, so a crash mid-save never corrupts the latest checkpoint —
``latest_step`` only considers directories with a COMMITTED marker.
``AsyncCheckpointer`` overlaps the host write with training (the step tensor
tree is snapshotted to host first).
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path

import jax
import numpy as np

_NATIVE = set("bool int8 int16 int32 int64 uint8 uint16 uint32 uint64 "
              "float16 float32 float64 complex64 complex128".split())


def _to_savable(a: np.ndarray) -> np.ndarray:
    # npz cannot hold ml_dtypes (bfloat16, fp8); store the raw bits
    if a.dtype.name not in _NATIVE:
        return a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint8)
    return a


def _from_savable(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name not in _NATIVE:
        import ml_dtypes
        return a.view(np.dtype(getattr(ml_dtypes, dtype_name)))
    return a


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir, step: int, tree, keep_last: int = 3):
    ckpt_dir = Path(ckpt_dir)
    tmp = ckpt_dir / f".tmp_step_{step}"
    final = ckpt_dir / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(tree)
    np_leaves = [np.asarray(x) for x in leaves]
    arrays = {f"a{i}": _to_savable(x) for i, x in enumerate(np_leaves)}
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "shapes": [list(x.shape) for x in np_leaves],
        "dtypes": [x.dtype.name for x in np_leaves],
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    (tmp / "COMMITTED").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: Path, keep_last: int):
    steps = sorted(_committed_steps(ckpt_dir))
    for s in steps[:-keep_last]:
        shutil.rmtree(ckpt_dir / f"step_{s}", ignore_errors=True)


def _committed_steps(ckpt_dir: Path):
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    out = []
    for p in ckpt_dir.iterdir():
        if p.name.startswith("step_") and (p / "COMMITTED").exists():
            out.append(int(p.name.split("_")[1]))
    return out


def latest_step(ckpt_dir) -> int | None:
    steps = _committed_steps(ckpt_dir)
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir, example_tree, step: int | None = None):
    """Restore into the structure of ``example_tree`` (shape/dtype checked)."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    path = ckpt_dir / f"step_{step}"
    data = np.load(path / "arrays.npz")
    manifest = json.loads((path / "manifest.json").read_text())
    leaves, treedef = _flatten(example_tree)
    assert manifest["n_leaves"] == len(leaves), "tree structure mismatch"
    restored = []
    for i, ex in enumerate(leaves):
        a = _from_savable(data[f"a{i}"], manifest["dtypes"][i])
        assert tuple(a.shape) == tuple(np.shape(ex)), (i, a.shape, np.shape(ex))
        restored.append(jax.numpy.asarray(a).astype(ex.dtype))
    return jax.tree.unflatten(treedef, restored), step


class AsyncCheckpointer:
    """Snapshot-to-host then write on a background thread."""

    def __init__(self, ckpt_dir, keep_last: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree):
        host_tree = jax.tree.map(np.asarray, tree)   # sync copy off device
        self.wait()
        self._thread = threading.Thread(
            target=save_checkpoint,
            args=(self.ckpt_dir, step, host_tree, self.keep_last),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
