"""Reproduce the paper's §6.4 evaluation (Figs. 11-14): 48 h NASA trace,
optimal PPA (LSTM + finetune updates + CPU key metric) vs stock HPA.

    PYTHONPATH=src:. python examples/nasa_eval.py [--days 2]

Takes ~3 minutes for the full 2-day simulation.
"""
import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--days", type=int, default=2)
    args = ap.parse_args()

    from benchmarks import bench_evaluation
    out = bench_evaluation.run(days=args.days)
    print(json.dumps({"hpa": out["hpa"], "ppa": out["ppa"],
                      "claims": out["claims"]}, indent=2, default=float))
    ok = all(out["claims"].values())
    print("ALL PAPER §6.4 CLAIMS REPRODUCED" if ok
          else f"claims: {out['claims']}")


if __name__ == "__main__":
    main()
