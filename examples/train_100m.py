"""Train a ~100M-parameter LM with the full substrate (data pipeline, AdamW,
checkpointing, failure recovery).

Default runs a reduced ~20M config for 60 steps (CPU-feasible, ~10 min);
``--full`` selects the real ~100M config x 300 steps (hours on CPU — sized
for a TPU host).

    PYTHONPATH=src python examples/train_100m.py [--full] [--fail-at 40]
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--fail-at", type=int, action="append", default=[])
    ap.add_argument("--ckpt-dir", default="/tmp/train100m_ckpt")
    args = ap.parse_args()

    from repro.configs.base import ModelConfig
    from repro.training.train_loop import TrainConfig, train

    if args.full:
        # ~100M params: 12L, d=768, llama-style
        cfg = ModelConfig(name="lm-100m", n_layers=12, d_model=768,
                          n_heads=12, n_kv_heads=12, head_dim=64, d_ff=2048,
                          vocab=32000, attn_impl="blocked", remat="full")
        tc = TrainConfig(steps=args.steps or 300, global_batch=32,
                         seq_len=512, ckpt_every=50, ckpt_dir=args.ckpt_dir)
    else:
        cfg = ModelConfig(name="lm-20m", n_layers=6, d_model=384, n_heads=6,
                          n_kv_heads=6, head_dim=64, d_ff=1024, vocab=8192,
                          attn_impl="naive", remat="none")
        tc = TrainConfig(steps=args.steps or 60, global_batch=8, seq_len=256,
                         ckpt_every=20, ckpt_dir=args.ckpt_dir)

    from repro.models.registry import build_model
    from repro.models.params import param_count
    n = param_count(build_model(cfg).specs())
    print(f"model: {cfg.name} ({n/1e6:.1f}M params), steps={tc.steps}")
    _, hist = train(cfg, tc, fail_at=set(args.fail_at))
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
