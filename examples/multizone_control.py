"""Multi-zone batched autoscaling: one FleetController drives every edge
zone + the cloud with a single forecast dispatch per control tick.

The paper's deployment runs one PPA per scaling target; here 6 edge zones
and the cloud (7 targets) share one batched control plane (DESIGN.md §5):
per-zone LSTMs are pretrained on a static-provisioning collection run,
stacked, and vmapped — each 15 s tick costs one device dispatch instead
of 7.

Run: PYTHONPATH=src python examples/multizone_control.py
"""
from __future__ import annotations

import numpy as np

from repro.cluster import ClusterSim, SimConfig, paper_topology
from repro.core import (FleetController, PPAConfig, TargetSpec,
                        ThresholdPolicy, Updater, UpdatePolicy,
                        LSTMForecaster)
from repro.workloads import random_access

N_EDGE_ZONES = 6
ZONES = tuple(f"edge-{i}" for i in range(N_EDGE_ZONES)) + ("cloud",)
THRESHOLD = 350.0


def collect_pretrain(t_end: float = 1800.0) -> dict[str, np.ndarray]:
    """Static-provisioning collection run (paper §5.3.1, scaled to Z zones)."""
    sim = ClusterSim(paper_topology(n_edge_zones=N_EDGE_ZONES),
                     SimConfig(seed=42))
    for z in ZONES:
        sim.scale_to(z, 4, 0.0)
    sim.make_ready_now()
    tasks = random_access(t_end, zones=list(ZONES[:-1]), seed=99)
    w = sim.cfg.control_interval_s
    ti = 0
    for tick in np.arange(w, t_end, w):
        while ti < len(tasks) and tasks[ti][0] <= tick:
            at, kind, zone = tasks[ti]
            from repro.cluster.simulator import Task
            sim.dispatch(Task(at, kind, zone, 0.0), at)
            ti += 1
        for z in ZONES:
            sim.sample_zone(z, tick)
    return {z: np.stack([v for _, v in sim.samples[z]]) for z in ZONES}


def main(t_minutes: int = 30):
    print(f"collecting pretraining series for {len(ZONES)} zones ...")
    pre = collect_pretrain()
    specs = []
    for z in ZONES:
        model = LSTMForecaster(window=4, epochs=60, seed=0)
        model.fit(pre[z], from_scratch=True)
        specs.append(TargetSpec(z, ThresholdPolicy(THRESHOLD, 1),
                                min_replicas=1, model=model))
    ctrl = FleetController(
        PPAConfig(threshold=THRESHOLD, stabilization_s=120.0),
        specs, updater=Updater(UpdatePolicy.FINETUNE))

    T = t_minutes * 60
    tasks = random_access(T, zones=list(ZONES[:-1]), seed=7)
    sim = ClusterSim(paper_topology(n_edge_zones=N_EDGE_ZONES),
                     SimConfig(seed=1, startup_s=25.0))
    print(f"running {t_minutes} min, {len(tasks)} tasks, "
          f"one batched dispatch per {sim.cfg.control_interval_s:.0f}s tick")
    sim.run(tasks, ctrl, T, initial_replicas=2)

    rs, re_ = sim.response_times("sort"), sim.response_times("eigen")
    print(f"\nsort  p50={np.percentile(rs, 50):.3f}s "
          f"p95={np.percentile(rs, 95):.3f}s  (n={len(rs)})")
    if len(re_):
        print(f"eigen p50={np.percentile(re_, 50):.3f}s "
              f"p95={np.percentile(re_, 95):.3f}s  (n={len(re_)})")
    edge = [z for z in ZONES if z != "cloud"]
    print(f"RIR edge={sim.rir_stats(edge)[0]:.3f} "
          f"cloud={sim.rir_stats(['cloud'])[0]:.3f}")
    for z in ZONES:
        reps = [n for _, n in sim.replica_log[z]]
        pred = sum(1 for d in ctrl.decisions(z) if d.predicted)
        print(f"  {z:8s} replicas min/mean/max = "
              f"{min(reps)}/{np.mean(reps):.1f}/{max(reps)}  "
              f"proactive_ticks={pred}/{len(reps)}")


if __name__ == "__main__":
    main()
