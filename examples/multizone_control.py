"""Multi-zone batched autoscaling: one control plane drives every edge
zone + the cloud with one forecast dispatch per control tick.

The paper's deployment runs one PPA per scaling target; here 6 edge zones
and the cloud (7 targets) share one batched control plane (DESIGN.md §5):
per-zone LSTMs are pretrained on a static-provisioning collection run,
stacked, and vmapped — each 15 s tick costs one device dispatch instead
of 7.

``--shards S`` routes the zones through the ``ShardedControlPlane``
(staged collect -> formulate -> batched forecast -> evaluate -> actuate
tick, S controller shards); ``--async`` adds double-buffered ticks (the
window-t forecast overlaps window-(t+1) metric collection) and runs the
hourly vmapped batch refit off the tick critical path.  The workload is
the NASA + Random Access mixed trace: the bursty Random Access foreground
(paper Alg. 2) rides on the NASA-KSC diurnal background (paper §5.2.2).

Run: PYTHONPATH=src python examples/multizone_control.py
         [--shards 4] [--async] [--minutes 30]
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.cluster import ClusterSim, SimConfig, paper_topology
from repro.core import (FleetController, PPAConfig, ShardedControlPlane,
                        TargetSpec, ThresholdPolicy, Updater, UpdatePolicy,
                        LSTMForecaster)
from repro.workloads import nasa_requests, nasa_trace, random_access

N_EDGE_ZONES = 6
ZONES = tuple(f"edge-{i}" for i in range(N_EDGE_ZONES)) + ("cloud",)
THRESHOLD = 350.0


def mixed_trace(t_end: float, seed: int = 7) -> list[tuple[float, str, str]]:
    """NASA diurnal background + Random Access bursty foreground, merged
    and sorted — the heterogeneous-zone mix the federated-zone work
    evaluates on (ROADMAP)."""
    edge = list(ZONES[:-1])
    ra = random_access(t_end, zones=edge, seed=seed)
    minutes = int(np.ceil(t_end / 60.0))
    counts = nasa_trace(days=max(1, minutes // 1440 + 1),
                        scale=0.4, seed=seed)[:minutes]
    nasa = [(t, kind, zone) for t, kind, zone in
            nasa_requests(counts, zones=edge, seed=seed + 1) if t < t_end]
    tasks = ra + nasa
    tasks.sort(key=lambda x: x[0])
    return tasks


def collect_pretrain(t_end: float = 1800.0) -> dict[str, np.ndarray]:
    """Static-provisioning collection run (paper §5.3.1, scaled to Z zones)."""
    sim = ClusterSim(paper_topology(n_edge_zones=N_EDGE_ZONES),
                     SimConfig(seed=42))
    for z in ZONES:
        sim.scale_to(z, 4, 0.0)
    sim.make_ready_now()
    tasks = mixed_trace(t_end, seed=99)
    w = sim.cfg.control_interval_s
    ti = 0
    for tick in np.arange(w, t_end, w):
        while ti < len(tasks) and tasks[ti][0] <= tick:
            at, kind, zone = tasks[ti]
            from repro.cluster.simulator import Task
            sim.dispatch(Task(at, kind, zone, 0.0), at)
            ti += 1
        for z in ZONES:
            sim.sample_zone(z, tick)
    return {z: np.stack([v for _, v in sim.samples[z]]) for z in ZONES}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=int, default=30)
    ap.add_argument("--shards", type=int, default=0,
                    help="route through ShardedControlPlane with S shards")
    ap.add_argument("--async", dest="async_ticks", action="store_true",
                    help="double-buffered ticks + off-critical-path refits")
    args = ap.parse_args()

    print(f"collecting pretraining series for {len(ZONES)} zones ...")
    pre = collect_pretrain()
    specs = []
    for z in ZONES:
        model = LSTMForecaster(window=4, epochs=60, seed=0)
        model.fit(pre[z], from_scratch=True)
        specs.append(TargetSpec(z, ThresholdPolicy(THRESHOLD, 1),
                                min_replicas=1, model=model))
    cfg = PPAConfig(threshold=THRESHOLD, stabilization_s=120.0)
    updater = Updater(UpdatePolicy.FINETUNE)
    if args.shards > 0 or args.async_ticks:
        ctrl = ShardedControlPlane(cfg, specs, updater=updater,
                                   n_shards=max(args.shards, 1),
                                   async_ticks=args.async_ticks)
        kind = (f"ShardedControlPlane (S={ctrl.n_shards}, "
                f"async={'on' if args.async_ticks else 'off'})")
    else:
        ctrl = FleetController(cfg, specs, updater=updater)
        kind = "FleetController"

    T = args.minutes * 60
    tasks = mixed_trace(T, seed=7)
    sim = ClusterSim(paper_topology(n_edge_zones=N_EDGE_ZONES),
                     SimConfig(seed=1, startup_s=25.0))
    print(f"running {args.minutes} min NASA+RandomAccess mix, "
          f"{len(tasks)} tasks, {kind}, one batched dispatch per "
          f"{sim.cfg.control_interval_s:.0f}s tick")
    sim.run(tasks, ctrl, T, initial_replicas=2)
    if hasattr(ctrl, "flush_updates"):
        ctrl.flush_updates()
        if ctrl.refit_log:
            e = ctrl.refit_log[-1]
            print(f"batch refit: {'async' if e['async'] else 'inline'}, "
                  f"{(e['applied'] - e['submitted']) * 1e3:.0f} ms "
                  f"{'off' if e['async'] else 'on'} the tick path")

    rs, re_ = sim.response_times("sort"), sim.response_times("eigen")
    print(f"\nsort  p50={np.percentile(rs, 50):.3f}s "
          f"p95={np.percentile(rs, 95):.3f}s  (n={len(rs)})")
    if len(re_):
        print(f"eigen p50={np.percentile(re_, 50):.3f}s "
              f"p95={np.percentile(re_, 95):.3f}s  (n={len(re_)})")
    edge = [z for z in ZONES if z != "cloud"]
    print(f"RIR edge={sim.rir_stats(edge)[0]:.3f} "
          f"cloud={sim.rir_stats(['cloud'])[0]:.3f}")
    for z in ZONES:
        reps = [n for _, n in sim.replica_log[z]]
        pred = sum(1 for d in ctrl.decisions(z) if d.predicted)
        print(f"  {z:8s} replicas min/mean/max = "
              f"{min(reps)}/{np.mean(reps):.1f}/{max(reps)}  "
              f"proactive_ticks={pred}/{len(reps)}")
    if hasattr(ctrl, "shutdown"):
        ctrl.shutdown()


if __name__ == "__main__":
    main()
