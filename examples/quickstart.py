"""Quickstart: the three layers of the framework in ~a minute on CPU.

1. The paper's PPA autoscaling the simulated edge cluster (vs HPA).
2. A reduced LM training run with checkpoint-restart.
3. A continuous-batching decode engine serving requests.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np


def ppa_demo():
    from repro.core.experiments import collect_series, run_scenario
    from repro.workloads import random_access

    print("== 1. PPA vs HPA on the simulated edge cluster (20 min sim) ==")
    pre = collect_series(random_access(600 * 15, seed=99), 600 * 15)
    T = 20 * 60
    tasks = random_access(T, seed=3)
    for kind in ("hpa", "ppa"):
        kw = dict(pretrain=pre) if kind == "ppa" else {}
        r = run_scenario(tasks, T, scaler=kind, min_replicas=2, **kw)
        print(f"  {kind}: sort {r.sort_mean:.3f}s eigen {r.eigen_mean:.2f}s "
              f"idle_edge {r.rir_edge[0]:.3f}")


def train_demo():
    from repro.configs import smoke_config
    from repro.training.train_loop import TrainConfig, train

    print("== 2. LM training with checkpoint-restart (injected failure) ==")
    cfg = smoke_config("h2o-danube-1.8b")
    tc = TrainConfig(steps=20, global_batch=4, seq_len=64, ckpt_every=8,
                     ckpt_dir="/tmp/quickstart_ckpt", log_every=10)
    train(cfg, tc, fail_at={13})


def serve_demo():
    import jax
    import jax.numpy as jnp
    from repro.configs import smoke_config
    from repro.models.registry import build_model
    from repro.serving import ContinuousBatcher, DecodeEngine, Request

    print("== 3. Continuous-batching decode engine ==")
    cfg = smoke_config("mamba2-780m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), jnp.float32)
    engine = DecodeEngine(cfg, params, slots=4, max_len=64)
    batcher = ContinuousBatcher(engine)
    rng = np.random.default_rng(0)
    for i in range(6):
        batcher.submit(Request(i, rng.integers(0, cfg.vocab, 16), 8))
    done = batcher.drain()
    print(f"  served {len(done)} requests "
          f"({sum(len(r.output) for r in done)} tokens, "
          f"{engine.steps} decode steps)")


if __name__ == "__main__":
    ppa_demo()
    train_demo()
    serve_demo()
