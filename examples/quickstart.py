"""Quickstart: the four layers of the framework in a few minutes on CPU.

1. The hybrid proactive+reactive control plane (DESIGN.md §§5-10,
   docs/architecture.md): a guardrail-enabled ``ShardedControlPlane``
   scaling a continuous-batching serving fleet through a flash crowd,
   with the ``SLAPolicy`` p95 objective and the staged tick
   collect -> formulate -> forecast -> evaluate -> guard -> actuate.
2. The paper's PPA autoscaling the simulated edge cluster (vs HPA).
3. A reduced LM training run with checkpoint-restart.
4. A continuous-batching decode engine serving requests.

    PYTHONPATH=src python examples/quickstart.py [--quick]

``--quick`` (the CI smoke lane) shrinks the closed loops and skips the
PPA-vs-HPA scenario so the walkthrough exits in well under a minute;
the guardrail demo always runs.  docs/quickstart.md walks through the
output line by line.
"""
import argparse
import shutil

import numpy as np


def guardrail_demo(quick: bool = False, forecaster: str = "lstm"):
    """Collect -> fit -> proact -> guard, end to end on one service:

    * collect: a statically provisioned fleet serves a steady Poisson
      load while the metric exporter records per-window samples (slot 1
      is the window p95 of booked response times — the latency feed);
    * fit: a per-target forecaster (``--forecaster``: the plain LSTM or
      the Attention-Double-LSTM "attn" zoo entry) learns the series;
    * proact + guard: a ``ShardedControlPlane`` with ``SLAPolicy`` (p95
      objective, ``key_metric_idx=1``) and the reactive guardrail scales
      the fleet through a flash crowd the forecaster has never seen.
    """
    from repro.core import (GuardrailConfig, PPAConfig,
                            ShardedControlPlane, SLAPolicy, TargetSpec)
    from repro.serving.fleet import FleetConfig, ServingFleet
    from repro.workloads import poisson_arrivals

    print("== 1. Guardrail-enabled sharded control plane "
          "(SLA p95 objective, flash crowd) ==")
    w = 15.0
    t_end = 600.0 if quick else 1200.0
    spike = (t_end / 2, t_end / 2 + 120.0)
    base_rate, spike_rate, target_p95 = 6.0, 30.0, 6.0
    fcfg = FleetConfig(total_chips=1024, chips_per_replica=16, seed=0,
                      deadline_factor=1e9)
    rng = np.random.default_rng(0)

    def arrivals(rates, seed):
        arr = poisson_arrivals(rates, t_end, w, seed=seed)
        ntok = rng.integers(32, 64, len(arr.times)).astype(np.float64)
        return arr.times, ntok

    def closed_loop(fleet, times, ntok, step):
        lo = 0
        for tick in np.arange(w, t_end + w / 2, w):
            fleet._apply_events(tick)
            hi = int(np.searchsorted(times, tick, side="right"))
            fleet.dispatch_window(times[lo:hi], ntok[lo:hi])
            fleet.completed_log.seal_window()
            lo = hi
            step(tick, fleet.sample(tick))
        return fleet

    # -- collect: static provisioning, steady load ------------------------
    fleet = ServingFleet(fcfg, batch=True)
    fleet.scale_to(4, 0.0)
    fleet.make_ready_now(0.0)
    times, ntok = arrivals(base_rate, seed=99)
    closed_loop(fleet, times, ntok, lambda t, s: None)
    series = np.stack([v for _, v in fleet.samples])
    print(f"  collected {len(series)} control windows "
          f"(steady p95 ~{np.median(series[:, 1]):.2f}s)")

    # -- fit + build the guarded plane ------------------------------------
    fkw = dict(window=4)
    if forecaster not in ("arma", "arima", "arima_d1"):
        fkw["epochs"] = 20 if quick else 40
        if forecaster != "ensemble":     # members seed themselves (0..E-1)
            fkw["seed"] = 0
    cfg = PPAConfig(key_metric_idx=1,          # scale on the p95 feed
                    stabilization_s=60.0,
                    guard=GuardrailConfig(band=0.3, headroom=1.15,
                                          down_ticks=3),
                    forecaster=forecaster, forecaster_kw=fkw)
    model = cfg.build_forecaster()
    model.fit(series, from_scratch=True)
    plane = ShardedControlPlane(
        cfg, [TargetSpec("svc", SLAPolicy(target_p95, min_replicas=2),
                         model=model)],
        n_shards=1)

    # -- proact + guard through the flash crowd ---------------------------
    n_win = int(np.ceil(t_end / w))
    edges = np.arange(n_win) * w
    rates = np.where((edges >= spike[0]) & (edges < spike[1]),
                     spike_rate, base_rate)
    times, ntok = arrivals(rates, seed=1)
    fleet = ServingFleet(fcfg, batch=True)
    fleet.scale_to(2, 0.0)
    fleet.make_ready_now(0.0)
    stats = {"violation_s": 0.0, "pod_s": 0.0}

    def step(tick, snap):
        cur = len(fleet.live_replicas(tick))
        stats["pod_s"] += cur * w
        if snap.values[1] > target_p95:
            stats["violation_s"] += w
        plane.observe_batch(tick, snap.values[None, :])
        res = plane.control_step(tick, 64, cur)
        fleet.scale_to(max(res["svc"].replicas, 2), tick)

    closed_loop(fleet, times, ntok, step)
    g = plane.guard_stats()
    plane.shutdown()
    print(f"  flash crowd {spike_rate:.0f} req/s for "
          f"{spike[1] - spike[0]:.0f}s: SLA violation "
          f"{stats['violation_s']:.0f}s of {t_end:.0f}s, "
          f"{stats['pod_s'] / 3600:.2f} pod-hours, guard overrides "
          f"up={g['up_overrides']} down={g['down_overrides']}")


def ppa_demo():
    from repro.core.experiments import collect_series, run_scenario
    from repro.workloads import random_access

    print("== 2. PPA vs HPA on the simulated edge cluster (20 min sim) ==")
    pre = collect_series(random_access(600 * 15, seed=99), 600 * 15)
    T = 20 * 60
    tasks = random_access(T, seed=3)
    for kind in ("hpa", "ppa"):
        kw = dict(pretrain=pre) if kind == "ppa" else {}
        r = run_scenario(tasks, T, scaler=kind, min_replicas=2, **kw)
        print(f"  {kind}: sort {r.sort_mean:.3f}s eigen {r.eigen_mean:.2f}s "
              f"idle_edge {r.rir_edge[0]:.3f}")


def train_demo(quick: bool = False):
    from repro.configs import smoke_config
    from repro.training.train_loop import TrainConfig, train

    print("== 3. LM training with checkpoint-restart (injected failure) ==")
    cfg = smoke_config("h2o-danube-1.8b")
    steps = 12 if quick else 20
    ckpt_dir = "/tmp/quickstart_ckpt"
    shutil.rmtree(ckpt_dir, ignore_errors=True)   # stale runs confuse restart
    tc = TrainConfig(steps=steps, global_batch=4, seq_len=64, ckpt_every=8,
                     ckpt_dir=ckpt_dir, log_every=10)
    train(cfg, tc, fail_at={steps - 3})


def serve_demo():
    import jax
    import jax.numpy as jnp
    from repro.configs import smoke_config
    from repro.models.registry import build_model
    from repro.serving import ContinuousBatcher, DecodeEngine, Request

    print("== 4. Continuous-batching decode engine ==")
    cfg = smoke_config("mamba2-780m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), jnp.float32)
    engine = DecodeEngine(cfg, params, slots=4, max_len=64)
    batcher = ContinuousBatcher(engine)
    rng = np.random.default_rng(0)
    for i in range(6):
        batcher.submit(Request(i, rng.integers(0, cfg.vocab, 16), 8))
    done = batcher.drain()
    print(f"  served {len(done)} requests "
          f"({sum(len(r.output) for r in done)} tokens, "
          f"{engine.steps} decode steps)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke lane: shrink the closed loops, skip "
                         "the PPA-vs-HPA scenario")
    ap.add_argument("--forecaster", default="lstm",
                    choices=["lstm", "attn", "arma", "arima_d1", "ensemble"],
                    help="forecaster zoo entry for the guardrail demo "
                         "(make_forecaster kind; 'attn' = the fused "
                         "Attention-Double-LSTM)")
    args = ap.parse_args()
    guardrail_demo(quick=args.quick, forecaster=args.forecaster)
    if not args.quick:
        ppa_demo()
    else:
        print("== 2. PPA vs HPA scenario skipped (--quick; run without "
              "the flag for the full comparison) ==")
    train_demo(quick=args.quick)
    serve_demo()
