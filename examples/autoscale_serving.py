"""End-to-end serving driver (the paper's kind of system, on the TPU fleet):
a real continuous-batching decode engine serves batched requests while the
PPA — fed by the batcher's own metric exporter — makes the replica-count
decisions for the surrounding fleet.

    PYTHONPATH=src python examples/autoscale_serving.py [--requests 40]
"""
import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import smoke_config
    from repro.core import (PPA, PPAConfig, LSTMForecaster, MetricsHistory,
                            ThresholdPolicy, Updater, UpdatePolicy)
    from repro.models.registry import build_model
    from repro.serving import ContinuousBatcher, DecodeEngine, Request

    cfg = smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), jnp.float32)
    engine = DecodeEngine(cfg, params, slots=8, max_len=96)
    batcher = ContinuousBatcher(engine)

    ppa = PPA(PPAConfig(threshold=60.0, control_interval_s=5.0,
                        stabilization_s=30.0),
              LSTMForecaster(window=4, epochs=40),
              ThresholdPolicy(60.0, 1),
              Updater(UpdatePolicy.FINETUNE), MetricsHistory())

    rng = np.random.default_rng(0)
    t0 = time.time()
    submitted = 0
    decisions = []
    step = 0
    while len(batcher.done) < args.requests:
        now = time.time() - t0
        # bursty arrivals
        if submitted < args.requests and rng.random() < 0.4:
            n = int(rng.integers(1, 4))
            for _ in range(min(n, args.requests - submitted)):
                batcher.submit(Request(submitted,
                                       rng.integers(0, cfg.vocab, 24), 12,
                                       arrival=now))
                submitted += 1
        batcher.step(now)
        step += 1
        if step % 10 == 0:
            snap = batcher.snapshot(now, 5.0)
            ppa.observe(snap)
            res = ppa.control_step(now, max_replicas=16, current_replicas=1)
            decisions.append(res.replicas)
    dt = time.time() - t0
    toks = sum(len(r.output) for r in batcher.done)
    print(f"served {len(batcher.done)} requests / {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s)")
    print(f"PPA replica decisions over the run: min={min(decisions)} "
          f"max={max(decisions)} (proactive on the queue/rate metrics)")


if __name__ == "__main__":
    main()
