"""Shared benchmark plumbing: pretraining cache, timers, artifact output."""
from __future__ import annotations

import json
import time
from pathlib import Path


ROOT = Path(__file__).resolve().parent.parent
ART = ROOT / "artifacts" / "bench"

_PRE_CACHE = {}


def pretrain_series(records: int = 1800, seed: int = 99):
    """Paper §5.3.1: 10 h unconstrained-run collection (1800 records)."""
    key = (records, seed)
    if key not in _PRE_CACHE:
        from repro.core.experiments import collect_series
        from repro.workloads import random_access
        tasks = random_access(records * 15, seed=seed)
        _PRE_CACHE[key] = collect_series(tasks, records * 15)
    return _PRE_CACHE[key]


def save(name: str, payload: dict):
    ART.mkdir(parents=True, exist_ok=True)
    (ART / f"{name}.json").write_text(json.dumps(payload, indent=1, default=float))


def save_bench(name: str, payload: dict):
    """Artifact copy + a repo-root ``BENCH_<name>.json`` (the CI bench-smoke
    lane uploads the root files and diffs them against checked-in
    baselines)."""
    save(name, payload)
    (ROOT / f"BENCH_{name}.json").write_text(
        json.dumps(payload, indent=1, default=float))


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6  # us


def csv_row(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")
