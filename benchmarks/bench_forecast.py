"""Paper Fig. 7 / §6.1 — ARMA(1,1,1) vs LSTM prediction quality.

Both models are pretrained on the 1800-record unconstrained collection
(1200 train / 600 val, as §5.3.1), injected into a PPA, and run the example
application for 200 minutes under Random Access; one-step-ahead CPU
predictions are compared with realised values (MSE).

Paper result: LSTM 53 240.972 < ARMA 96 867.631 (LSTM wins).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import pretrain_series, save, timed, csv_row


def run(t_minutes: int = 200):
    from repro.core.experiments import run_scenario
    from repro.core.updater import UpdatePolicy
    from repro.workloads import random_access

    pre = pretrain_series()
    pre_train = {z: s[:1200] for z, s in pre.items()}
    T = t_minutes * 60
    tasks = random_access(T, seed=3)
    out = {}
    for kind in ("arma", "lstm"):
        res, us = timed(run_scenario, tasks, T, scaler="ppa", model_kind=kind,
                        pretrain=pre_train,
                        update_policy=UpdatePolicy.NEVER,
                        min_replicas=2)
        mse = float(np.mean(list(res.mse.values())))
        mse_n = float(np.mean(list(res.mse_norm.values())))
        out[kind] = {"mse_mean": mse, "mse_norm_mean": mse_n,
                     "mse_by_zone": res.mse, "mse_norm_by_zone": res.mse_norm,
                     "run_us": us}
        csv_row(f"forecast_{kind}", us, f"mse={mse:.1f} mse_norm={mse_n:.4f}")
    # zones differ 30:1 in metric scale; the variance-normalized aggregate is
    # the meaningful pooled number (EXPERIMENTS.md discusses both)
    out["lstm_beats_arma"] = (out["lstm"]["mse_norm_mean"]
                              < out["arma"]["mse_norm_mean"])
    save("forecast", out)
    return out


if __name__ == "__main__":
    r = run()
    print("LSTM beats ARMA:", r["lstm_beats_arma"])
