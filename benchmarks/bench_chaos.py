"""Chaos A/B benchmark: degraded-mode control plane ON vs OFF
(DESIGN.md §13, docs/resilience.md).

A fixed set of seeded fault tapes — spatially-correlated node-failure
storms, metric-exporter blackouts (frozen republished rows), forecaster
stalls and shard crash-restarts — plus per-fleet closed-loop
retry/backoff clients drives the *same* federation twice per tape:

  OFF  ``resilience=None`` — the plane trusts every republished stale
       row, waits forever on stalled forecasts, and a crashed shard's
       columnar state is simply gone (wipe, no restore);
  ON   ``ResilienceConfig`` armed — stale-TTL hold, forecast deadline
       -> reactive fallback, snapshot/restore shard failover.

Each tape is replayed bit-identically (``scenario.reset()`` between
lanes), so every delta is attributable to the degraded-mode machinery.
Scores aggregate over the seed set — a single tape's A/B delta is
dominated by where its storms happen to land.  Two acceptance bars,
both CI-guarded through the baseline JSON:

1. **SLA damage** — the ON lane must cut total SLA-violation seconds
   (control windows whose completed-request p95 exceeds the SLA, times
   the window length, summed over fleets and tapes) vs the OFF lane.
2. **Recovery** — after every node-kill storm the ON lane must return
   live-chip occupancy to 90 % of its pre-storm level within a bounded
   number of control ticks.

Run: PYTHONPATH=src python -m benchmarks.bench_chaos [--smoke]
         [--check-baseline benchmarks/baselines/chaos_baseline.json]

Results land in ``BENCH_chaos.json`` (root copy for the CI artifact).
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import csv_row, save_bench

WINDOW_S = 15.0
SLA_S = 2.0            # scored p95 SLA (s) — also the clients' retry trigger
POLICY_P95 = 1.2       # the plane's internal objective: an SLO safety margin,
#                        so quiet windows sit comfortably under the scored SLA
#                        and chaos, not capacity, drives the violations
N_TOKENS = 8           # with prefill 0.1 s: ~0.37 s service, so the policy's
#                        scale-down band (p95 < 0.7 * POLICY_P95) is reachable
PREFILL_S = 0.1
RATE_PER_S = 16.0      # ~3 busy replicas at 2 slots x ~5.4 req/s each
SPAWN_S = 30.0         # replica spawn latency (2 control ticks)
DOWN_MARGIN = 0.35     # scale-down trigger 0.42 s, just above the service
#                        floor: downs are rare, gentle (~11 %) steps, so the
#                        quiet plane is stable instead of spike-cycling
MIN_REPLICAS = 4       # ~= quiet-load capacity: fleets start (and floor) healthy
WARMUP_WIN = 8         # cold-start windows excluded from every score
RECOVERY_BOUND = 16    # ticks (4 sim-min): post-storm occupancy-recovery bar
#                        for the ON lane; generous because pre-storm usage can
#                        be transiently inflated by another storm's recovery
RECOVERY_FRAC = 0.9    # "recovered" = live chips back to 90 % of pre-storm
TAIL_SKIP_WIN = 10     # storms this close to t_end can't be scored fairly


def _resilience_on():
    from repro.core.policies import ResilienceConfig

    return ResilienceConfig(stale_ttl_s=20.0, forecast_deadline_s=2.0,
                            snapshot_every=2)


def _chaos_sim(F: int, resilience, budget: int | None = None,
               n_shards: int = 2, seed0: int = 0):
    """F serving fleets under one ShardedControlPlane running SLA policies
    on the windowed-p95 metric (slot 1) with the guardrail armed — the
    realistic hybrid plane the resilience layer sits inside."""
    from repro.core import (ARIMAD1Forecaster, GuardrailConfig, PPAConfig,
                            SLAPolicy)
    from repro.core.control_plane import ShardedControlPlane
    from repro.core.controller import TargetSpec
    from repro.serving.fleet import FleetConfig
    from repro.serving.multi_fleet import FleetSpec, MultiFleetSim

    # tight enough that one fleet blowing up to max (the OFF lane chasing
    # a frozen storm-inflated row for a whole blackout) contends real
    # capacity away from fleets fighting their own kill storms
    budget = budget or F * 16
    specs = [
        FleetSpec(f"fleet-{i}", FleetConfig(
            total_chips=budget, chips_per_replica=1, slots_per_replica=2,
            prefill_s=PREFILL_S, control_interval_s=WINDOW_S,
            spawn_s=SPAWN_S, seed=seed0 + i))
        for i in range(F)
    ]
    cfg = PPAConfig(threshold=POLICY_P95, key_metric_idx=1,
                    stabilization_s=60.0, guard=GuardrailConfig(),
                    resilience=resilience)
    plane = ShardedControlPlane(
        cfg,
        [TargetSpec(s.name, SLAPolicy(POLICY_P95, MIN_REPLICAS, DOWN_MARGIN),
                    min_replicas=MIN_REPLICAS) for s in specs],
        model=ARIMAD1Forecaster(), n_shards=n_shards, async_ticks=False)
    return MultiFleetSim(specs, budget, plane, batch=True, columnar=True)


def _scenario(F: int, t_end: float, seed: int, n_shards: int = 2):
    from repro.sim.chaos import ChaosConfig
    from repro.workloads.scenarios import ClientConfig, make_chaos_scenario

    ccfg = ChaosConfig(
        window_s=WINDOW_S,
        storm_start_p=0.10, storm_stop_p=0.5,      # short, frequent storms
        blackout_rate_per_h=10.0, blackout_lo_s=120.0, blackout_hi_s=300.0,
        stall_rate_per_h=3.0, stall_s=3.0,         # > the ON-lane deadline
        crash_rate_per_h=15.0, crash_down_ticks=2)
    # enough feedback to amplify real outages, tame enough that a single
    # kill window does not avalanche past any amount of recovered capacity
    client = ClientConfig(rate_per_s=RATE_PER_S, window_s=WINDOW_S,
                          n_tokens=N_TOKENS, retry_threshold=SLA_S,
                          retry_frac=0.3, max_retries=2, backoff_base_s=4.0)
    return make_chaos_scenario(
        [f"fleet-{i}" for i in range(F)], t_end=t_end, seed=seed,
        chaos_cfg=ccfg, client_cfg=client, n_shards=n_shards)


# ---------------------------------------------------------------- metrics ---
def _p95_matrix(sim, t_end: float) -> np.ndarray:
    """(F, n_win) realised p95 per fleet per control window — requests
    bucketed by *completion* time (the latency users felt, regardless of
    what the blacked-out exporter told the controller).  One fused
    ``batched_p95`` pass over every (fleet, window) segment; empty windows
    report 0.0 (never violating)."""
    from repro.serving.fleet import batched_p95

    w = sim.window_s
    n_win = int(np.ceil(t_end / w))
    segs = []
    for f in sim.fleets.values():
        rows = f.completed_log.view()
        done = rows[np.isfinite(rows["completion"])]
        resp = done["completion"] - done["arrival"]
        wi = np.minimum((done["completion"] // w).astype(np.int64), n_win - 1)
        order = np.argsort(wi, kind="stable")
        wi, resp = wi[order], resp[order]
        bounds = np.searchsorted(wi, np.arange(n_win + 1))
        segs.extend(resp[bounds[k]:bounds[k + 1]] for k in range(n_win))
    return batched_p95(segs).reshape(len(sim.fleets), n_win)


def _storm_bursts(chaos, window_s: float) -> list[tuple[float, float]]:
    """(start, end) times of node-kill storms, merging kill windows less
    than two control windows apart into one burst."""
    from repro.sim import chaos as CH

    kt = np.unique(chaos.events[chaos.events["kind"] == CH.NODE_FAIL]["t"])
    if kt.size == 0:
        return []
    bursts, start, end = [], float(kt[0]), float(kt[0])
    for t in kt[1:]:
        if t - end > 2.0 * window_s:
            bursts.append((start, end))
            start = float(t)
        end = float(t)
    bursts.append((start, end))
    return bursts


def _recovery_ticks(sim, chaos, t_end: float) -> list[int]:
    """Per storm burst: control ticks from the last kill until live-chip
    occupancy is back to ``RECOVERY_FRAC`` of its pre-burst level — the
    replica-respawn bound the failover path is benched against.  Bursts
    in the warmup or too close to ``t_end`` are skipped; a burst that
    never recovers inside the run scores the full remaining tick count."""
    usage = np.asarray(sim.usage_log, np.float64)
    t_u, u = usage[:, 0], usage[:, 1]
    out = []
    for start, end in _storm_bursts(chaos, sim.window_s):
        if (end > t_end - TAIL_SKIP_WIN * sim.window_s
                or end < WARMUP_WIN * sim.window_s):
            continue
        i_pre = int(np.searchsorted(t_u, start)) - 1
        i0 = int(np.searchsorted(t_u, end))
        if i_pre < 0 or i0 >= len(t_u):
            continue
        rec = np.flatnonzero(u[i0:] >= RECOVERY_FRAC * u[i_pre])
        out.append(int(rec[0]) + 1 if rec.size else len(t_u) - i0)
    return out


# ------------------------------------------------------------------ lanes ---
def _lane(F: int, t_end: float, scenario, resilience, seed0: int) -> dict:
    sim = _chaos_sim(F, resilience, seed0=seed0)
    t0 = time.perf_counter()
    sim.run({}, t_end, scenario=scenario.reset())
    wall = time.perf_counter() - t0
    p95 = _p95_matrix(sim, t_end)
    viol = p95[:, WARMUP_WIN:] > SLA_S
    stats = sim.completion_stats()
    out = {
        "wall_s": wall,
        "sla_violation_s": float(viol.sum() * sim.window_s),
        "sla_violation_ratio": float(viol.mean()),
        "completions": int(stats["count"]),
        "mean_resp_s": float(stats["resp_mean"]),
        "retries": int(sum(c.total_retries
                           for c in scenario.clients.values())),
        "recovery_ticks": _recovery_ticks(sim, scenario.chaos, t_end),
    }
    if hasattr(sim.controller, "degraded_stats"):
        out["degraded"] = sim.controller.degraded_stats()
    return out


def bench_chaos_pair(F: int, t_end: float, seed: int) -> dict:
    """The A/B pair on one seeded tape: resilience OFF then ON."""
    from repro.sim import chaos as CH

    scenario = _scenario(F, t_end, seed)
    kinds = {CH.KIND_NAMES[k]: int(n) for k, n in
             zip(*np.unique(scenario.chaos.events["kind"],
                            return_counts=True))}
    off = _lane(F, t_end, scenario, None, seed0=seed)
    on = _lane(F, t_end, scenario, _resilience_on(), seed0=seed)
    return {
        "seed": seed,
        "chaos_events": len(scenario.chaos), "chaos_kinds": kinds,
        "chaos_signature": scenario.chaos.signature(),
        "off": off, "on": on,
    }


def bench_chaos_suite(F: int = 4, t_end: float = 900.0,
                      seeds: tuple[int, ...] = (1, 3, 6)) -> dict:
    """A/B pairs over a fixed seed set; scores are seed-set aggregates
    (total violation seconds per lane, worst ON-lane storm recovery)."""
    pairs = [bench_chaos_pair(F, t_end, s) for s in seeds]
    off_s = sum(p["off"]["sla_violation_s"] for p in pairs)
    on_s = sum(p["on"]["sla_violation_s"] for p in pairs)
    rec_on = max((r for p in pairs for r in p["on"]["recovery_ticks"]),
                 default=0)
    deg = {}
    for p in pairs:
        for k, v in p["on"].get("degraded", {}).items():
            deg[k] = deg.get(k, 0) + v
    wall = sum(p["off"]["wall_s"] + p["on"]["wall_s"] for p in pairs)
    res = {
        "F": F, "t_end": t_end, "seeds": list(seeds),
        "pairs": pairs,
        "off_sla_violation_s": off_s, "on_sla_violation_s": on_s,
        "sla_violation_cut": (off_s - on_s) / max(off_s, WINDOW_S),
        "chaos_sla_violation_ratio": float(
            np.mean([p["on"]["sla_violation_ratio"] for p in pairs])),
        "chaos_recovery_ticks": rec_on,
        "degraded": deg,
    }
    csv_row(
        f"chaos_suite_F{F}x{len(seeds)}",
        wall * 1e6,
        f"violation {off_s:.0f}s off -> {on_s:.0f}s on "
        f"({res['sla_violation_cut']:.0%} cut over {len(seeds)} tapes), "
        f"recovery <= {rec_on} ticks",
    )
    return res


# ------------------------------------------------------- baseline / entry ---
def check_baseline(results: dict, path: Path) -> list[str]:
    """The ON lane may not degrade vs the checked-in baseline: violating
    fleet-window fraction within 1.5x (+ a small absolute slack for tiny
    smoke denominators), storm recovery within +2 ticks."""
    base = json.loads(path.read_text())
    errors = []
    suite = results["suite"]
    ref = base.get("chaos_sla_violation_ratio")
    got = suite["chaos_sla_violation_ratio"]
    if ref is not None and got > ref * 1.5 + 0.02:
        errors.append(
            f"chaos: ON-lane SLA-violation ratio {got:.3f} "
            f"> 1.5x baseline {ref:.3f}")
    ref = base.get("chaos_recovery_ticks")
    got = suite["chaos_recovery_ticks"]
    if ref is not None and got > ref + 2:
        errors.append(
            f"chaos: storm recovery {got} ticks > baseline {ref} + 2")
    return errors


def run(smoke: bool = False, baseline: Path | None = None) -> dict:
    suite = bench_chaos_suite(
        F=4, t_end=900.0,
        seeds=(1, 3, 6) if smoke else tuple(range(8)))
    results = {"mode": "smoke" if smoke else "full", "suite": suite}
    save_bench("chaos", results)
    assert suite["on_sla_violation_s"] < suite["off_sla_violation_s"], (
        f"degraded-mode ON must cut aggregate SLA-violation seconds: "
        f"on={suite['on_sla_violation_s']:.0f}s "
        f"off={suite['off_sla_violation_s']:.0f}s")
    assert suite["chaos_recovery_ticks"] <= RECOVERY_BOUND, (
        f"ON lane took {suite['chaos_recovery_ticks']} ticks to recover "
        f"from a kill storm (bar: <= {RECOVERY_BOUND})")
    deg = suite["degraded"]
    assert deg.get("failovers", 0) >= 1, \
        "the tapes must exercise at least one shard failover"
    assert deg.get("stale_targets", 0) >= 1, \
        "the tapes must exercise the stale-TTL hold"
    if baseline is not None:
        errors = check_baseline(results, baseline)
        if errors:
            raise SystemExit("baseline regression:\n  " + "\n  ".join(errors))
        print(f"baseline OK ({baseline})")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--check-baseline", type=Path, default=None)
    args = ap.parse_args()
    out = run(smoke=args.smoke, baseline=args.check_baseline)
    print(json.dumps(out, indent=1, default=float))
