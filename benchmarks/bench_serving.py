"""Beyond-paper benchmark: the PPA autoscaling a TPU decode fleet vs the HPA
baseline — response times, idle chip-time, resilience to a replica failure
and a straggler (DESIGN.md §2 serving integration)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, save, timed


def _requests(t_end: float, seed: int = 0):
    """Diurnal-ish request stream: rate ramps 2x over the run + bursts."""
    rng = np.random.default_rng(seed)
    reqs = []
    t = 0.0
    while t < t_end:
        phase = t / t_end
        rate = 8.0 + 14.0 * np.sin(np.pi * phase) + (18.0 if 0.45 < phase < 0.55
                                                     else 0.0)
        t += float(rng.exponential(1.0 / max(rate, 0.2)))
        reqs.append((t, int(rng.integers(16, 96))))
    return reqs


def run(t_end: float = 3600.0):
    from repro.core import (HPA, PPA, PPAConfig, LSTMForecaster,
                            MetricsHistory, ThresholdPolicy, Updater,
                            UpdatePolicy)
    from repro.serving.fleet import FleetConfig, ServingFleet

    reqs = _requests(t_end)
    out = {}
    for kind in ("hpa", "ppa"):
        fleet = ServingFleet(FleetConfig(total_chips=256, seed=0))
        fleet.inject_failure(t_end * 0.4, rid=0)
        fleet.inject_straggler(t_end * 0.7, rid=1, speed=0.25, duration=300.0)
        if kind == "ppa":
            scaler = PPA(PPAConfig(threshold=5.0, stabilization_s=120.0),
                         LSTMForecaster(window=4, epochs=60),
                         ThresholdPolicy(5.0, 1),
                         Updater(UpdatePolicy.FINETUNE), MetricsHistory())
        else:
            scaler = HPA(5.0, min_replicas=1)
        _, us = timed(fleet.run, reqs, scaler, kind, t_end)
        rt = fleet.response_times()
        out[kind] = {
            "n": len(rt), "p50_s": float(np.percentile(rt, 50)),
            "p99_s": float(np.percentile(rt, 99)),
            "mean_s": float(rt.mean()),
            "idle_fraction": fleet.idle_fraction(),
            "redispatched": int(sum(r.redispatched for r in fleet.completed)),
            "run_us": us,
        }
        csv_row(f"serving_{kind}", us,
                f"p50={out[kind]['p50_s']:.2f}s p99={out[kind]['p99_s']:.2f}s "
                f"idle={out[kind]['idle_fraction']:.3f}")
    out["ppa_p99_better_or_close"] = (out["ppa"]["p99_s"]
                                      <= out["hpa"]["p99_s"] * 1.05)
    save("serving", out)
    return out


if __name__ == "__main__":
    r = run()
    print("ppa p99 better/close:", r["ppa_p99_better_or_close"])
