"""Batched control plane + sim core benchmarks (DESIGN.md §3/§5).

Two claims are measured (the PR's acceptance bar):

1. **Control latency** — at Z=16 zones, one batched ``FleetController``
   tick (single vmapped/jitted forecast dispatch) is >= 5x faster than Z
   independent scalar ``PPA.control_step`` calls (Z separate dispatches).
2. **Sim-core parity** — a seeded ``ClusterSim`` run on the heap-based sim
   core reproduces the frozen seed engine's response-time distribution
   within 1 % at p50/p95 (it is in fact exact), while dispatching faster.

Run: PYTHONPATH=src python -m benchmarks.bench_control_plane [--quick]
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row, save_bench, timed


def _traces(Z, T=200, seed=0):
    rng = np.random.default_rng(seed)
    out = {}
    for i in range(Z):
        s = 200 + 80 * np.sin(np.linspace(0, 8, T) + i) + rng.normal(0, 5, T)
        out[f"z{i}"] = np.stack([s, s * 0.5, s * 0.1, s * 0.05, s / 50]).T
    return out


def bench_control_latency(Z: int = 16, window: int = 4, iters: int = 100):
    """Z scalar PPA dispatches vs one batched controller dispatch."""
    from repro.core import (PPA, PPAConfig, FleetController, TargetSpec,
                            ThresholdPolicy, Updater, UpdatePolicy,
                            MetricsHistory, LSTMForecaster, Snapshot)

    traces = _traces(Z)
    cfg = PPAConfig(threshold=100.0)

    def mk(z):
        m = LSTMForecaster(window=window, epochs=25, seed=0)
        m.fit(traces[z][:120], from_scratch=True)
        return m

    ppas = {z: PPA(cfg, mk(z), ThresholdPolicy(100.0, 1),
                   Updater(UpdatePolicy.NEVER), MetricsHistory())
            for z in traces}
    ctrl = FleetController(
        cfg, [TargetSpec(z, ThresholdPolicy(100.0, 1), model=mk(z))
              for z in traces])
    for k in range(120, 130):
        t = 15.0 * (k - 119)
        for z in traces:
            snap = Snapshot(t, traces[z][k])
            ppas[z].observe(snap)
            ctrl.observe(z, snap)
    # warmup (jit compile both paths)
    for z in traces:
        ppas[z].control_step(1e4, 16, 2)
    ctrl.control_step(1e4, 16, 2)

    t0 = time.perf_counter()
    for j in range(iters):
        for z in traces:
            ppas[z].control_step(1e4 + j, 16, 2)
    per_zone_us = (time.perf_counter() - t0) / iters * 1e6
    t0 = time.perf_counter()
    for j in range(iters):
        ctrl.control_step(1e4 + j, 16, 2)
    batched_us = (time.perf_counter() - t0) / iters * 1e6
    speedup = per_zone_us / batched_us
    csv_row("control_per_zone_tick", per_zone_us, f"Z={Z} dispatches")
    csv_row("control_batched_tick", batched_us,
            f"speedup={speedup:.1f}x (bar: >=5x)")
    return {"Z": Z, "per_zone_us": per_zone_us, "batched_us": batched_us,
            "speedup": speedup}


def bench_sim_core_parity(t_minutes: int = 20):
    """Heap-core ClusterSim vs the frozen seed engine: identical seeded
    response-time distribution, lower wall time."""
    from benchmarks.seed_reference_sim import (
        AutoscalerBinding as SeedBinding, ClusterSim as SeedSim,
        SimConfig as SeedConfig, paper_topology as seed_topology)
    from repro.cluster import (AutoscalerBinding, ClusterSim, SimConfig,
                               paper_topology)
    from repro.core.hpa import HPA
    from repro.workloads import random_access

    T = t_minutes * 60
    tasks = random_access(T, seed=5)
    zones = ("edge-0", "edge-1", "cloud")

    def run(sim_cls, cfg_cls, bind_cls, topo_fn):
        sim = sim_cls(topo_fn(), cfg_cls(seed=0))
        binds = [bind_cls(z, HPA(350.0, min_replicas=2), "hpa", 2)
                 for z in zones]
        sim.run(tasks, binds, T, initial_replicas=2)
        return sim

    new, new_us = timed(run, ClusterSim, SimConfig, AutoscalerBinding,
                        paper_topology)
    old, old_us = timed(run, SeedSim, SeedConfig, SeedBinding, seed_topology)
    rn, ro = np.sort(new.response_times()), np.sort(old.response_times())
    stats = {}
    for q in (50, 95):
        pn, po = float(np.percentile(rn, q)), float(np.percentile(ro, q))
        stats[f"p{q}_new"], stats[f"p{q}_seed"] = pn, po
        stats[f"p{q}_rel_err"] = abs(pn - po) / po
    ok = all(stats[f"p{q}_rel_err"] <= 0.01 for q in (50, 95))
    csv_row("sim_core_run", new_us,
            f"seed={old_us:.0f}us speedup={old_us / new_us:.2f}x")
    csv_row("sim_core_parity_p50", stats["p50_rel_err"] * 100,
            f"rel_err_% (bar: <=1%) ok={ok}")
    stats.update({"n_tasks": int(len(rn)), "parity_ok": ok,
                  "new_us": new_us, "seed_us": old_us,
                  "sim_speedup": old_us / new_us})
    return stats


def run(quick: bool = False):
    lat = bench_control_latency(Z=16, iters=30 if quick else 100)
    par = bench_sim_core_parity(t_minutes=10 if quick else 20)
    payload = {"control_latency": lat, "sim_core_parity": par}
    save_bench("control_plane", payload)
    assert lat["speedup"] >= 5.0, f"batched speedup {lat['speedup']:.1f}x < 5x"
    assert par["parity_ok"], f"sim-core parity broken: {par}"
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI bench-smoke lane: same as --quick")
    args = ap.parse_args()
    out = run(quick=args.quick or args.smoke)
    print(out)
