"""Batched + sharded control plane benchmarks (DESIGN.md §5).

Four claims are measured (the PRs' acceptance bars):

1. **Control latency** — at Z=16 zones, one batched ``FleetController``
   tick (single vmapped/jitted forecast dispatch) is >= 5x faster than Z
   independent scalar ``PPA.control_step`` calls (Z separate dispatches).
2. **Sim-core parity** — a seeded ``ClusterSim`` run on the heap-based sim
   core reproduces the frozen seed engine's response-time distribution
   within 1 % at p50/p95 (it is in fact exact), while dispatching faster.
3. **Shard sweep** — Z in {16, 64, 256, 1024} targets: the
   ``ShardedControlPlane`` (columnar staged tick, S shards) sustains
   >= 3x the single ``FleetController`` ticks/sec at Z >= 256.
4. **Refit overlap** — a vmapped batch refit of Z=64 per-target LSTMs runs
   off the tick critical path: the max tick latency while the refit is in
   flight stays far below the blocking (in-loop) refit stall.
5. **Policy dispatch** — a mixed Threshold/TargetUtilization policy set
   (which used to force the O(Z/S)-Python ``_CtrlShard`` fallback) ticks
   measurably faster on the columnar per-policy dispatch table
   (DESIGN.md §6) than on the forced fallback.
6. **Forecast device floor** — the fused block-batched Pallas LSTM
   sequence kernel (DESIGN.md §7) is no slower than the legacy
   per-timestep cell path at Z in {64, 256, 1024} (both interpret mode on
   CPU), with GFLOP/s + tick ms recorded per path (the vmapped-XLA figure
   is the CPU device floor; the kernel's own figure is the TPU follow-up
   record).
7. **Device scaling** — the mesh-mapped plane (DESIGN.md §9) at the
   control-plane-bound config (window=1, hidden=16, S=8): tick ms and
   ticks/s for D in {1, 2, 4, 8} devices at Z in {4096, 16384, 65536},
   measured in a subprocess under ``--xla_force_host_platform_device_
   count=8`` (the CI trick — no accelerator needed).  D=1 is the
   single-device plane (host per-shard path, the deployment a mesh
   replaces); D>=2 run the ``shard_map`` engine with device-resident
   ring/weights/scalers.  Bar: D=8 >= 2x D=1 ticks/s at Z=16384.  The
   lane also times a guarded D=8 plane whose band can never be left
   (``8g``): the quiet guardrail stage must add < 10 % tick overhead at
   Z=16384 (DESIGN.md §10).
8. **Forecast attn kernel** — the fused Attention-Double-LSTM sequence
   kernel (DESIGN.md §11): ONE ``pallas_call`` per tick runs LSTM-1, the
   window-length temporal attention and LSTM-2 + head in VMEM scratch.
   Bar: the fused kernel (jitted wrapper, interpret mode inside) is no
   slower than the eager jnp reference oracle it replaces; the jitted-XLA
   vmap figure is recorded alongside as the CPU device floor.
9. **Forecast A/B** — forecast skill + tick cost, plain LSTM vs the
   Attention-Double-LSTM, on three held-out traces (NASA diurnal,
   RandomAccess, serverless bursty MMPP).  Bar: attn beats the plain
   LSTM's one-step error on the bursty trace — the regime (burst onset /
   exponential decay inside the window) temporal attention exists for.
10. **Guardrail A/B** — a flash-crowd closed loop (docs/guardrail.md):
   one serving fleet driven by a sharded plane whose forecast is
   anchored wrong on purpose (over-provisioned in steady state, blind to
   the spike).  Guard off vs on, identical arrivals: the hybrid plane
   must cut SLA-violation seconds (window p95 over target) while
   spending no more pod-hours — the reactive up path catches the crowd,
   the stabilised down path pays for it in steady state.

Run: PYTHONPATH=src python -m benchmarks.bench_control_plane [--quick]
         [--check-baseline benchmarks/baselines/control_plane_baseline.json]
"""
from __future__ import annotations

import copy
import json
import os
import time
from pathlib import Path

import numpy as np

from benchmarks.common import csv_row, save_bench, timed


def _traces(Z, T=200, seed=0):
    rng = np.random.default_rng(seed)
    out = {}
    for i in range(Z):
        s = 200 + 80 * np.sin(np.linspace(0, 8, T) + i) + rng.normal(0, 5, T)
        out[f"z{i}"] = np.stack([s, s * 0.5, s * 0.1, s * 0.05, s / 50]).T
    return out


def bench_control_latency(Z: int = 16, window: int = 4, iters: int = 100):
    """Z scalar PPA dispatches vs one batched controller dispatch."""
    from repro.core import (PPA, PPAConfig, FleetController, TargetSpec,
                            ThresholdPolicy, Updater, UpdatePolicy,
                            MetricsHistory, LSTMForecaster, Snapshot)

    traces = _traces(Z)
    cfg = PPAConfig(threshold=100.0)

    def mk(z):
        m = LSTMForecaster(window=window, epochs=25, seed=0)
        m.fit(traces[z][:120], from_scratch=True)
        return m

    ppas = {z: PPA(cfg, mk(z), ThresholdPolicy(100.0, 1),
                   Updater(UpdatePolicy.NEVER), MetricsHistory())
            for z in traces}
    ctrl = FleetController(
        cfg, [TargetSpec(z, ThresholdPolicy(100.0, 1), model=mk(z))
              for z in traces])
    for k in range(120, 130):
        t = 15.0 * (k - 119)
        for z in traces:
            snap = Snapshot(t, traces[z][k])
            ppas[z].observe(snap)
            ctrl.observe(z, snap)
    # warmup (jit compile both paths)
    for z in traces:
        ppas[z].control_step(1e4, 16, 2)
    ctrl.control_step(1e4, 16, 2)

    t0 = time.perf_counter()
    for j in range(iters):
        for z in traces:
            ppas[z].control_step(1e4 + j, 16, 2)
    per_zone_us = (time.perf_counter() - t0) / iters * 1e6
    t0 = time.perf_counter()
    for j in range(iters):
        ctrl.control_step(1e4 + j, 16, 2)
    batched_us = (time.perf_counter() - t0) / iters * 1e6
    speedup = per_zone_us / batched_us
    csv_row("control_per_zone_tick", per_zone_us, f"Z={Z} dispatches")
    csv_row("control_batched_tick", batched_us,
            f"speedup={speedup:.1f}x (bar: >=5x)")
    return {"Z": Z, "per_zone_us": per_zone_us, "batched_us": batched_us,
            "speedup": speedup}


def bench_sim_core_parity(t_minutes: int = 20):
    """Heap-core ClusterSim vs the frozen seed engine: identical seeded
    response-time distribution, lower wall time."""
    from benchmarks.seed_reference_sim import (
        AutoscalerBinding as SeedBinding, ClusterSim as SeedSim,
        SimConfig as SeedConfig, paper_topology as seed_topology)
    from repro.cluster import (AutoscalerBinding, ClusterSim, SimConfig,
                               paper_topology)
    from repro.core.hpa import HPA
    from repro.workloads import random_access

    T = t_minutes * 60
    tasks = random_access(T, seed=5)
    zones = ("edge-0", "edge-1", "cloud")

    def run(sim_cls, cfg_cls, bind_cls, topo_fn):
        sim = sim_cls(topo_fn(), cfg_cls(seed=0))
        binds = [bind_cls(z, HPA(350.0, min_replicas=2), "hpa", 2)
                 for z in zones]
        sim.run(tasks, binds, T, initial_replicas=2)
        return sim

    new, new_us = timed(run, ClusterSim, SimConfig, AutoscalerBinding,
                        paper_topology)
    old, old_us = timed(run, SeedSim, SeedConfig, SeedBinding, seed_topology)
    rn, ro = np.sort(new.response_times()), np.sort(old.response_times())
    stats = {}
    for q in (50, 95):
        pn, po = float(np.percentile(rn, q)), float(np.percentile(ro, q))
        stats[f"p{q}_new"], stats[f"p{q}_seed"] = pn, po
        stats[f"p{q}_rel_err"] = abs(pn - po) / po
    ok = all(stats[f"p{q}_rel_err"] <= 0.01 for q in (50, 95))
    csv_row("sim_core_run", new_us,
            f"seed={old_us:.0f}us speedup={old_us / new_us:.2f}x")
    csv_row("sim_core_parity_p50", stats["p50_rel_err"] * 100,
            f"rel_err_% (bar: <=1%) ok={ok}")
    stats.update({"n_tasks": int(len(rn)), "parity_ok": ok,
                  "new_us": new_us, "seed_us": old_us,
                  "sim_speedup": old_us / new_us})
    return stats


def _clone_models(Z: int, traces, window: int = 4, hidden: int = 50,
                  n_base: int = 8, epochs: int = 20,
                  finetune_epochs: int = 30):
    """Z homogeneous fitted per-target LSTMs, cheaply: fit n_base distinct
    models, clone params, refit each clone's scaler on its own trace (the
    sweep measures tick throughput, not forecast skill)."""
    from repro.core import LSTMForecaster

    names = list(traces)
    base = []
    for i in range(min(n_base, Z)):
        m = LSTMForecaster(window=window, hidden=hidden, epochs=epochs,
                           finetune_epochs=finetune_epochs, seed=i)
        m.fit(traces[names[i]][:120], from_scratch=True)
        base.append(m)
    models = []
    for i in range(Z):
        m = copy.deepcopy(base[i % len(base)])
        m.scaler.fit(traces[names[i]][:120])
        models.append(m)
    return models


def bench_shard_sweep(zs=(16, 64, 256, 1024), n_shards: int = 8,
                      ticks: int = 30, warmup: int = 3, hidden: int = 16):
    """Single FleetController vs ShardedControlPlane (sync + async ticks)
    across the Z sweep; each point drives `ticks` full control ticks
    (observe every target + one control_step).

    The sweep's LSTMs default to ``hidden=16``: the point of the sweep is
    the control-plane host cost the sharded refactor removes, and on the
    2-core CI container the paper-faithful LSTM(50) batched-GEMV forward
    (identical device work on BOTH paths) would otherwise dominate the
    tick and mask it.  ``run()`` also records a paper-fidelity hidden=50
    reference point at Z=256 (no gate)."""
    from repro.core import (FleetController, PPAConfig, ShardedControlPlane,
                            Snapshot, TargetSpec, ThresholdPolicy)

    cfg = PPAConfig(threshold=100.0, stabilization_s=60.0)
    out = []
    for Z in zs:
        traces = _traces(Z)
        names = list(traces)
        models = _clone_models(Z, traces, hidden=hidden)

        def specs():
            return [TargetSpec(n, ThresholdPolicy(100.0, 1),
                               model=copy.deepcopy(m))
                    for n, m in zip(names, models)]

        # pre-build per-tick inputs so the timer sees only the plane APIs
        ks = [130 + (j % 60) for j in range(warmup + ticks)]
        snap_rows = [np.stack([traces[n][k] for n in names]) for k in ks]

        def drive_single():
            ctrl = FleetController(cfg, specs())
            for n in names:
                for k in range(120, 130):
                    ctrl.observe(n, Snapshot(15.0 * k, traces[n][k]))
            times = []
            for j, rows in enumerate(snap_rows):
                t = 1e4 + 15.0 * j
                t0 = time.perf_counter()
                for i, n in enumerate(names):
                    ctrl.observe(n, Snapshot(t, rows[i]))
                ctrl.control_step(t, 64, 2)
                times.append(time.perf_counter() - t0)
            return times[warmup:]

        def drive_sharded(async_ticks):
            plane = ShardedControlPlane(cfg, specs(), n_shards=n_shards,
                                        async_ticks=async_ticks)
            for n in names:
                for k in range(120, 130):
                    plane.observe(n, Snapshot(15.0 * k, traces[n][k]))
            times = []
            for j, rows in enumerate(snap_rows):
                t = 1e4 + 15.0 * j
                t0 = time.perf_counter()
                if async_ticks:
                    # double-buffered: window-t forecast in flight while
                    # window-(t+1) metrics are collected
                    plane.begin_tick(t, 64, 2)
                    plane.observe_batch(t + 15.0, rows)
                    plane.finish_tick()
                else:
                    plane.observe_batch(t, rows)
                    plane.control_step(t, 64, 2)
                times.append(time.perf_counter() - t0)
            plane.shutdown()
            return times[warmup:]

        single = float(np.mean(drive_single()))
        sync = float(np.mean(drive_sharded(False)))
        asy = float(np.mean(drive_sharded(True)))
        best = min(sync, asy)
        point = {
            "Z": Z, "n_shards": n_shards, "hidden": hidden,
            "single_tick_ms": single * 1e3,
            "sharded_tick_ms": sync * 1e3,
            "sharded_async_tick_ms": asy * 1e3,
            "single_ticks_per_s": 1.0 / single,
            "sharded_ticks_per_s": 1.0 / best,
            "speedup": single / best,
        }
        out.append(point)
        csv_row(f"shard_sweep_Z{Z}", best * 1e6,
                f"single={single * 1e3:.2f}ms sharded={best * 1e3:.2f}ms "
                f"= {point['speedup']:.1f}x (bar at Z>=256: >=3x)")
    return out


def bench_policy_dispatch(Z: int = 256, n_shards: int = 8, ticks: int = 30,
                          warmup: int = 3, hidden: int = 16):
    """The columnar-policy-engine claim (DESIGN.md §6): a heterogeneous
    policy set (mixed Threshold + TargetUtilization) used to force the
    O(Z/S)-Python ``_CtrlShard`` fallback; the per-policy dispatch table
    keeps it columnar.  Three configs on identical traces/models:

    * ``single``   — one FleetController (scalar per-target evaluate);
    * ``fallback`` — ShardedControlPlane forced onto _CtrlShard shards via
      an opaque policy wrapper (the pre-dispatch-table cost);
    * ``columnar`` — the same mixed built-in policies on the dispatch
      table (one evaluate_batch per policy type per tick).
    """
    from repro.core import (FleetController, PPAConfig, ShardedControlPlane,
                            Snapshot, TargetSpec, TargetUtilizationPolicy,
                            ThresholdPolicy)

    class _Opaque:
        """Scalar-only wrapper: forces the _CtrlShard fallback."""

        def __init__(self, inner):
            self._inner = inner

        def __call__(self, key, state=None):
            return self._inner(key, state)

    cfg = PPAConfig(threshold=100.0, stabilization_s=60.0)
    traces = _traces(Z)
    names = list(traces)
    models = _clone_models(Z, traces, hidden=hidden)

    def specs(opaque: bool):
        out = []
        for i, (n, m) in enumerate(zip(names, models)):
            pol = (ThresholdPolicy(100.0, 1) if i % 2
                   else TargetUtilizationPolicy(0.7, 1))
            out.append(TargetSpec(n, _Opaque(pol) if opaque else pol,
                                  model=copy.deepcopy(m)))
        return out

    ks = [130 + (j % 60) for j in range(warmup + ticks)]
    snap_rows = [np.stack([traces[n][k] for n in names]) for k in ks]

    def drive(plane):
        for n in names:
            for k in range(120, 130):
                plane.observe(n, Snapshot(15.0 * k, traces[n][k]))
        times = []
        for j, rows in enumerate(snap_rows):
            t = 1e4 + 15.0 * j
            t0 = time.perf_counter()
            if hasattr(plane, "observe_batch"):
                plane.observe_batch(t, rows)
            else:
                for i, n in enumerate(names):
                    plane.observe(n, Snapshot(t, rows[i]))
            plane.control_step(t, 64, 2)
            times.append(time.perf_counter() - t0)
        if hasattr(plane, "shutdown"):
            plane.shutdown()
        return float(np.mean(times[warmup:]))

    single = drive(FleetController(cfg, specs(False)))
    fallback_plane = ShardedControlPlane(cfg, specs(True),
                                         n_shards=n_shards)
    assert not any(s.vectorized for s in fallback_plane.shards)
    fallback = drive(fallback_plane)
    columnar_plane = ShardedControlPlane(cfg, specs(False),
                                         n_shards=n_shards)
    assert all(s.vectorized for s in columnar_plane.shards)
    columnar = drive(columnar_plane)
    out = {
        "Z": Z, "n_shards": n_shards, "hidden": hidden,
        "single_tick_ms": single * 1e3,
        "fallback_tick_ms": fallback * 1e3,
        "columnar_tick_ms": columnar * 1e3,
        "columnar_ticks_per_s": 1.0 / columnar,
        "speedup_vs_fallback": fallback / columnar,
        "speedup_vs_single": single / columnar,
    }
    csv_row("policy_dispatch", columnar * 1e6,
            f"mixed-policy Z={Z}: columnar={columnar * 1e3:.2f}ms vs "
            f"fallback={fallback * 1e3:.2f}ms "
            f"({out['speedup_vs_fallback']:.1f}x) vs "
            f"single={single * 1e3:.2f}ms")
    return out


def bench_refit_overlap(Z: int = 64, n_shards: int = 8, ticks: int = 60,
                        trigger: int = 20):
    """The updater-cadence claim: a vmapped batch refit of Z per-target
    LSTMs runs off the tick critical path.  Measures (a) the async plane's
    max tick latency while the refit is in flight, (b) the blocking
    in-loop refit stall on the single controller, (c) refit wall latency
    and how many ticks overlapped it."""
    from repro.core import (FleetController, MetricsHistory, PPAConfig,
                            ShardedControlPlane, Snapshot, TargetSpec,
                            ThresholdPolicy, Updater, UpdatePolicy)
    from repro.core.forecaster import lstm_fit_batch_stacked

    traces = _traces(Z, T=300)
    names = list(traces)
    models = _clone_models(Z, traces, finetune_epochs=60)
    cfg = PPAConfig(threshold=100.0, stabilization_s=60.0,
                    update_interval_s=trigger * 15.0)

    def specs():
        return [TargetSpec(n, ThresholdPolicy(100.0, 1),
                           model=copy.deepcopy(m))
                for n, m in zip(names, models)]

    # warm the vmapped-fit jit cache with the exact refit shapes so both
    # paths below time compute, not compilation
    warm = [copy.deepcopy(m) for m in models]
    series = {n: traces[n][130:130 + trigger] for n in names}
    lstm_fit_batch_stacked(warm, [series[n] for n in names])

    def drive(plane, async_mode):
        tick_s, inflight_ticks = [], 0
        for j in range(ticks):
            t = 15.0 * (j + 1)
            k = 130 + (j % 100)
            rows = np.stack([traces[n][k] for n in names])
            t0 = time.perf_counter()
            if async_mode:
                plane.observe_batch(t, rows)
            else:
                for i, n in enumerate(names):
                    plane.observe(n, Snapshot(t, rows[i]))
            plane.control_step(t, 64, 2)
            plane.maybe_update(t)
            dt = time.perf_counter() - t0
            tick_s.append(dt)
            if async_mode and plane.refit_inflight:
                inflight_ticks += 1
        return tick_s, inflight_ticks

    plane = ShardedControlPlane(cfg, specs(), n_shards=n_shards,
                                updater=Updater(UpdatePolicy.FINETUNE),
                                async_ticks=True)
    async_ticks_s, overlapped = drive(plane, True)
    plane.flush_updates()
    refit_wall_s = (plane.refit_log[-1]["applied"]
                    - plane.refit_log[-1]["submitted"]
                    if plane.refit_log else float("nan"))
    plane.shutdown()

    ctrl = FleetController(cfg, specs(),
                           updater=Updater(UpdatePolicy.FINETUNE))
    block_ticks_s, _ = drive(ctrl, False)

    baseline_tick = float(np.median(async_ticks_s))
    max_inflight = float(np.max(async_ticks_s[trigger:])
                         if len(async_ticks_s) > trigger
                         else np.max(async_ticks_s))
    block_stall = float(np.max(block_ticks_s))
    out = {
        "Z": Z, "n_shards": n_shards,
        "refit_wall_s": refit_wall_s,
        "ticks_overlapped": overlapped,
        "median_tick_ms": baseline_tick * 1e3,
        "max_tick_ms_refit_inflight": max_inflight * 1e3,
        "blocking_refit_stall_ms": block_stall * 1e3,
        "nonblocking": max_inflight < 0.5 * block_stall,
    }
    csv_row("refit_overlap", max_inflight * 1e6,
            f"async max tick {max_inflight * 1e3:.2f}ms vs blocking stall "
            f"{block_stall * 1e3:.1f}ms, refit={refit_wall_s * 1e3:.1f}ms "
            f"over {overlapped} ticks")
    return out


def bench_forecast_device(zs=(64, 256, 1024), window: int = 4,
                          hidden: int = 50, iters: int = 20,
                          cell_max_z: int = 256):
    """ROADMAP "next bottleneck" (b): the stacked per-target LSTM forward
    that dominates the sharded tick.  Three paths per Z:

    * ``xla``   — vmapped XLA forward (``use_pallas=False``), the device
      floor the fused kernel is lifting on TPU;
    * ``cell``  — the legacy Pallas path: per-target ``lax.scan`` over the
      single-step ``lstm_cell`` kernel, vmapped (W×Z kernel dispatches);
    * ``fused`` — the block-batched ``lstm_seq_stacked`` sequence kernel
      (ONE dispatch, (h, c) resident across the window, DESIGN.md §7).

    On CPU both Pallas paths run in interpret mode (Mosaic on TPU), so the
    meaningful CI bar is fused vs the legacy cell path; the GFLOP/s
    figures are the recorded floor for the TPU follow-up."""
    import jax
    import jax.numpy as jnp

    from repro.core.forecaster import _lstm_forward_stacked, _lstm_init
    from repro.kernels import ops as kops

    rng = np.random.default_rng(0)
    M = 5

    @jax.jit
    def legacy_cells(stacked, xs):
        def fwd(p, x):
            H = p["Wh"].shape[0]

            def step(carry, xt):
                h, c = carry
                h, c = kops.lstm_cell(p["Wx"], p["Wh"], p["b"], h, c,
                                      xt[None])
                return (h, c), None

            (h, _), _ = jax.lax.scan(
                step, (jnp.zeros((1, H)), jnp.zeros((1, H))), x)
            return (jax.nn.relu(h) @ p["Wo"] + p["bo"])[0]
        return jax.vmap(fwd)(stacked, xs)

    def timeit(fn, reps):
        fn().block_until_ready()                    # compile / warm
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn()
        out.block_until_ready()
        return (time.perf_counter() - t0) / reps

    out = []
    for Z in zs:
        keys = jax.random.split(jax.random.PRNGKey(0), Z)
        stacked = jax.vmap(
            lambda k: _lstm_init(k, M, hidden, M))(keys)
        xs = jnp.asarray(rng.normal(0, 1, (Z, window, M)), jnp.float32)
        flops = Z * (window * 2 * 4 * hidden * (M + hidden)
                     + 2 * hidden * M)
        xla_s = timeit(lambda: _lstm_forward_stacked(
            stacked, xs, use_pallas=False), iters)
        # the legacy path is ~100x slower in interpret mode — that IS the
        # result; skip re-measuring it where a single rep takes a minute
        cell_s = (timeit(lambda: legacy_cells(stacked, xs),
                         max(iters // 10, 1))
                  if Z <= cell_max_z else float("nan"))
        fused_s = timeit(lambda: _lstm_forward_stacked(
            stacked, xs, use_pallas=True), iters)
        measured = np.isfinite(cell_s)
        point = {
            "Z": Z, "window": window, "hidden": hidden,
            "flops_per_tick": flops,
            "xla_tick_ms": xla_s * 1e3,
            # None (JSON null), not NaN: the artifact must stay strict JSON
            "cell_tick_ms": cell_s * 1e3 if measured else None,
            "fused_tick_ms": fused_s * 1e3,
            "xla_gflops": flops / xla_s / 1e9,
            "cell_gflops": flops / cell_s / 1e9 if measured else None,
            "fused_gflops": flops / fused_s / 1e9,
            "fused_vs_cell": cell_s / fused_s if measured else None,
        }
        out.append(point)
        cell_txt = (f"cell={cell_s * 1e3:.2f}ms "
                    f"({point['fused_vs_cell']:.1f}x)" if measured
                    else "cell=skipped")
        csv_row(f"forecast_device_Z{Z}", fused_s * 1e6,
                f"fused={point['fused_gflops']:.2f} GF/s "
                f"({fused_s * 1e3:.2f}ms) vs {cell_txt} vs "
                f"xla={point['xla_gflops']:.2f} GF/s")
    return out


def bench_forecast_attn(zs=(64, 256), window: int = 8, hidden: int = 50,
                        iters: int = 10):
    """The second-generation forecast kernel (DESIGN.md §11): the fused
    Attention-Double-LSTM sequence kernel vs the jnp reference oracle it
    replaces.  Three paths per Z, stacked per-target layout:

    * ``ref``   — the eager (unjitted) ``kernels/ref.attn_lstm_seq_stacked``
      oracle: op-by-op dispatch, the math's un-fused cost;
    * ``xla``   — the jitted vmapped XLA forward (``use_pallas=False``),
      the CPU device floor the kernel is lifting on TPU;
    * ``fused`` — ``attn_lstm_seq_stacked`` through the forecaster entry
      point (jitted wrapper, interpret mode inside on CPU; Mosaic on TPU).

    CI bar: fused <= ref per tick (the fusion must at least pay for its
    own dispatch); GFLOP/s recorded per path for the TPU follow-up."""
    import jax
    import jax.numpy as jnp

    from repro.core.forecaster import _attn_init, _lstm_forward_stacked
    from repro.kernels import ref as kref

    rng = np.random.default_rng(2)
    M = 5
    leaf_order = ("Wx1", "Wh1", "b1", "Wa", "Wx2", "Wh2", "b2", "Wo", "bo")

    def timeit(fn, reps):
        jax.block_until_ready(fn())                 # compile / warm
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn()
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / reps

    out = []
    for Z in zs:
        keys = jax.random.split(jax.random.PRNGKey(1), Z)
        stacked = jax.vmap(lambda k: _attn_init(k, M, hidden, M))(keys)
        leaves = [stacked[k] for k in leaf_order]
        xs = jnp.asarray(rng.normal(0, 1, (Z, window, M)), jnp.float32)
        # LSTM-1 + query proj + scores/softmax/ctx + LSTM-2 + head
        flops = Z * (window * 2 * 4 * hidden * (M + hidden)
                     + 2 * hidden * hidden
                     + 4 * window * hidden
                     + window * 2 * 4 * hidden * (2 * hidden)
                     + 2 * hidden * M)
        ref_s = timeit(lambda: kref.attn_lstm_seq_stacked(*leaves, xs),
                       max(iters // 5, 1))
        xla_s = timeit(lambda: _lstm_forward_stacked(
            stacked, xs, use_pallas=False, arch="attn"), iters)
        fused_s = timeit(lambda: _lstm_forward_stacked(
            stacked, xs, use_pallas=True, arch="attn"), iters)
        point = {
            "Z": Z, "window": window, "hidden": hidden,
            "flops_per_tick": flops,
            "ref_tick_ms": ref_s * 1e3,
            "xla_tick_ms": xla_s * 1e3,
            "fused_tick_ms": fused_s * 1e3,
            "ref_gflops": flops / ref_s / 1e9,
            "xla_gflops": flops / xla_s / 1e9,
            "fused_gflops": flops / fused_s / 1e9,
            "fused_vs_ref": ref_s / fused_s,
        }
        out.append(point)
        csv_row(f"forecast_attn_Z{Z}", fused_s * 1e6,
                f"fused={point['fused_gflops']:.2f} GF/s "
                f"({fused_s * 1e3:.2f}ms) vs ref={ref_s * 1e3:.2f}ms "
                f"({point['fused_vs_ref']:.1f}x, bar: >=1x) vs "
                f"xla={point['xla_gflops']:.2f} GF/s")
    return out


def _ab_series(kind: str, quick: bool) -> np.ndarray:
    """(T, 5) metric series from a per-minute count trace, the `_traces`
    channel convention (counts + derived load columns).  The bursty trace
    ignores ``quick``: it carries the lane's CI bar, so its config (4
    days — enough windows that the architecture gap clears training
    noise) is fixed like the guardrail A/B's."""
    from repro.workloads import bursty_trace, nasa_trace, random_access

    if kind == "nasa":
        s = nasa_trace(days=1 if quick else 2, seed=7)
    elif kind == "bursty":
        s = bursty_trace(days=4, seed=23)
    else:                                   # random_access, binned per minute
        t_end = 720.0 * 60.0 if quick else 1440.0 * 60.0
        tasks = random_access(t_end, seed=3)
        times = np.array([t for t, _, _ in tasks])
        s = np.bincount((times // 60.0).astype(int),
                        minlength=int(t_end // 60.0)).astype(float)
    return np.stack([s, s * 0.5, s * 0.1, s * 0.05, s / 50]).T


def bench_forecast_ab(window: int = 8, epochs: int = 120, lr: float = 5e-3,
                      seeds=(0, 1, 2), quick: bool = False,
                      pred_iters: int = 30):
    """Forecast-skill + tick-cost A/B: plain LSTM vs Attention-Double-LSTM
    (identical window / training budget / seed set), one-step error on the
    held-out last 30 % of each trace.  Each arm is a small seed ensemble:
    ``seeds`` independently trained models whose *averaged* prediction is
    scored (symmetric to both arms; averaging subtracts cross-seed
    training variance from the MSE, so the architecture gap is measured
    instead of one run's optimisation luck — per-seed MSEs are recorded
    too).  ``persist_mse`` (last value carried forward) anchors the scale.

    The CI bar lives on the bursty trace: burst-onset age and the fixed
    retry-echo backoff (workloads/bursty.py) are window-*position*
    signals — exactly what the temporal-attention readout can see and a
    final-hidden-state readout compresses away.  Everything is seeded, so
    the numbers are exact, not statistical."""
    from repro.core.forecaster import AttnLSTMForecaster, LSTMForecaster

    out = {}
    for kind in ("nasa", "random_access", "bursty"):
        # nasa / random_access are recorded context, not gated: one seed
        # and a short budget keep the smoke lane fast
        arm_seeds = seeds if (kind == "bursty" or not quick) else seeds[:1]
        arm_epochs = epochs if (kind == "bursty" or not quick) else 60
        series = _ab_series(kind, quick)
        T = len(series)
        split = int(T * 0.7)
        idx = np.arange(split, T - window)
        X = np.stack([series[i:i + window] for i in idx])
        Y = series[idx + window]
        var = max(float(Y[:, 0].var()), 1e-9)
        point = {"T": int(T), "n_eval": int(len(idx)), "window": window,
                 "epochs": arm_epochs, "lr": lr, "n_seeds": len(arm_seeds),
                 "persist_mse": float(np.mean((X[:, -1, 0] - Y[:, 0]) ** 2))}
        for name, cls in (("lstm", LSTMForecaster),
                          ("attn", AttnLSTMForecaster)):
            preds, per_seed = [], []
            for seed in arm_seeds:
                m = cls(window=window, epochs=arm_epochs, lr=lr, seed=seed)
                m.fit(series[:split], from_scratch=True)
                p = m.predict_batch(X)[0]
                preds.append(p)
                per_seed.append(float(np.mean((p[:, 0] - Y[:, 0]) ** 2)))
            avg = np.mean(preds, axis=0)
            mse = float(np.mean((avg[:, 0] - Y[:, 0]) ** 2))
            recent = series[split - window:split]
            m.predict(recent)                       # warm the jit cache
            t0 = time.perf_counter()
            for _ in range(pred_iters):
                m.predict(recent)
            point[f"{name}_mse"] = mse
            point[f"{name}_mse_per_seed"] = per_seed
            point[f"{name}_mse_norm"] = mse / var
            point[f"{name}_tick_us"] = ((time.perf_counter() - t0)
                                        / pred_iters * 1e6)
        point["mse_ratio_lstm_over_attn"] = (point["lstm_mse"]
                                             / point["attn_mse"])
        out[kind] = point
        csv_row(f"forecast_ab_{kind}", point["attn_mse"],
                f"attn_mse vs lstm={point['lstm_mse']:.1f} "
                f"(ratio {point['mse_ratio_lstm_over_attn']:.2f}x"
                f"{', bar: >1x' if kind == 'bursty' else ''}) "
                f"persist={point['persist_mse']:.1f} "
                f"tick attn={point['attn_tick_us']:.0f}us "
                f"lstm={point['lstm_tick_us']:.0f}us")
    return out


def bench_guardrail_ab(t_end: float = 1200.0, spike=(600.0, 720.0),
                       base_rate: float = 6.0, spike_rate: float = 40.0,
                       target_p95: float = 6.0, anchor: float = 2500.0,
                       threshold: float = 500.0, seed: int = 0):
    """Flash-crowd A/B (DESIGN.md §10): the same batch ServingFleet and
    arrival trace, scaled by the same sharded plane with the guardrail
    off vs on.  The forecast is a fabricated LSTM whose scaler anchors
    the key-metric prediction at ``anchor`` (~5 replicas at the default
    threshold): comfortably above the steady-state load (~3 replicas),
    hopelessly below the flash crowd (~16+) — the failure mode the
    reactive stage exists for.  Guard off, the plane over-provisions for
    20 minutes and still melts during the 2-minute spike; guard on, the
    down path trims steady state after ``down_ticks`` overshoots and the
    up path tracks realised load within one tick.

    Reported per arm: SLA-violation seconds (15 s control windows whose
    booked-response p95 — metric slot 1, the latency feed — exceeds
    ``target_p95``) and pod-hours (live replicas x window).  Bars:
    violation_s(on) < violation_s(off) at pod_hours(on) <= (off)."""
    from repro.core import (GuardrailConfig, PPAConfig, ShardedControlPlane,
                            TargetSpec, ThresholdPolicy)
    from repro.core.forecaster import LSTMForecaster, Scaler
    from repro.core.metrics import N_METRICS
    from repro.serving.fleet import FleetConfig, ServingFleet
    from repro.workloads import poisson_arrivals

    w = 15.0
    n_win = int(np.ceil(t_end / w))
    edges = np.arange(n_win) * w
    rates = np.where((edges >= spike[0]) & (edges < spike[1]),
                     spike_rate, base_rate)
    arr = poisson_arrivals(rates, t_end, w, seed=seed)
    rng = np.random.default_rng(seed)
    ntoks = rng.integers(32, 64, len(arr.times)).astype(np.float64)

    def spec():
        m = LSTMForecaster.__new__(LSTMForecaster)
        m.__dict__.update(
            LSTMForecaster(window=4, hidden=16, seed=2).__dict__)
        sc = Scaler()
        sc.mean = np.full(N_METRICS, 100.0)
        sc.mean[0] = anchor
        sc.std, sc.fitted = 0.02 * sc.mean + 1.0, True
        m.scaler = sc
        m._fitted, m._fit_count = True, 1
        m._valid_cache = (1, True)
        return TargetSpec("svc", ThresholdPolicy(threshold, 2), model=m)

    def drive(guard):
        cfg = PPAConfig(threshold=threshold, stabilization_s=60.0,
                        guard=guard)
        plane = ShardedControlPlane(cfg, [spec()], n_shards=1)
        fleet = ServingFleet(
            FleetConfig(total_chips=1024, chips_per_replica=16,
                        seed=seed, deadline_factor=1e9), batch=True)
        fleet.scale_to(2, 0.0)
        fleet.make_ready_now(0.0)
        lo, violation_s, pod_s = 0, 0.0, 0.0
        for tick in np.arange(w, t_end + w / 2, w):
            fleet._apply_events(tick)
            hi = int(np.searchsorted(arr.times, tick, side="right"))
            fleet.dispatch_window(arr.times[lo:hi], ntoks[lo:hi])
            fleet.completed_log.seal_window()
            lo = hi
            snap = fleet.sample(tick)
            cur = len(fleet.live_replicas(tick))
            pod_s += cur * w                 # capacity over the window
            if snap.values[1] > target_p95:  # slot 1: window p95 feed
                violation_s += w
            plane.observe_batch(tick, snap.values[None, :])
            res = plane.control_step(tick, 64, cur)
            fleet.scale_to(max(res["svc"].replicas, 2), tick)
        stats = plane.guard_stats() if guard is not None else None
        plane.shutdown()
        return violation_s, pod_s / 3600.0, stats

    v_off, ph_off, _ = drive(None)
    v_on, ph_on, stats = drive(GuardrailConfig(band=0.3, headroom=1.15,
                                               down_ticks=3))
    out = {
        "t_end_s": t_end, "spike_s": list(spike),
        "base_rate": base_rate, "spike_rate": spike_rate,
        "target_p95_s": target_p95,
        "violation_s_off": v_off, "violation_s_on": v_on,
        "pod_hours_off": ph_off, "pod_hours_on": ph_on,
        "up_overrides": stats["up_overrides"],
        "down_overrides": stats["down_overrides"],
    }
    csv_row("guardrail_ab_violation_s", v_on,
            f"off={v_off:.0f}s pods on/off="
            f"{ph_on:.2f}/{ph_off:.2f} pod-h "
            f"overrides up={stats['up_overrides']} "
            f"down={stats['down_overrides']} "
            f"(bar: on<off at <= pod-hours)")
    return out


def _fab_targets(Z: int, window: int, hidden: int, seed: int = 0):
    """Z fabricated fitted per-target LSTMs without Z fits: one base model
    supplies params (shared ref — the lane measures tick plumbing, not
    forecast skill), each target gets its own scaler stats views.  The
    fabrication path is what makes Z=10^4..10^5 planes constructible in a
    bench subprocess."""
    from repro.core import TargetSpec, ThresholdPolicy
    from repro.core.forecaster import LSTMForecaster, Scaler

    base = LSTMForecaster(window=window, hidden=hidden, seed=seed)
    rng = np.random.default_rng(seed)
    from repro.core.metrics import N_METRICS
    means = rng.uniform(50.0, 400.0, (Z, N_METRICS))
    stds = 0.1 * means + 1.0
    specs = []
    for i in range(Z):
        m = LSTMForecaster.__new__(LSTMForecaster)
        m.__dict__.update(base.__dict__)
        sc = Scaler()
        sc.mean, sc.std, sc.fitted = means[i], stds[i], True
        m.scaler = sc
        m._fitted, m._fit_count = True, 1
        m._valid_cache = (1, True)
        specs.append(TargetSpec(f"z{i}", ThresholdPolicy(100.0, 1), model=m))
    return specs


def _device_lane_measure(Z: int, window: int, hidden: int, n_shards: int,
                         warmup: int, ticks: int, ds=(2, 4, 8)) -> dict:
    """Child-process body of the device_scaling lane (jax already sees the
    forced host devices here).  One point: the single-device plane (host
    per-shard path) as the D=1 row, the shard_map mesh engine for each
    D in ``ds``, all on identical fabricated targets and metric rows."""
    import jax

    from repro.core import GuardrailConfig, PPAConfig, ShardedControlPlane
    from repro.core.metrics import N_METRICS

    cfg = PPAConfig(threshold=100.0, stabilization_s=60.0)
    # quiet guard: armed every tick (arm + band compare on every shard)
    # but the band can never be left — measures the stage's fixed cost
    gcfg = PPAConfig(threshold=100.0, stabilization_s=60.0,
                     guard=GuardrailConfig(band=1e18))
    rng = np.random.default_rng(1)
    rows_seq = [rng.uniform(50.0, 400.0, (Z, N_METRICS))
                for _ in range(4)]
    # contiguous block assignment: skips Z crc32 hashes per plane build
    # and matches the mesh's contiguous row blocks
    assignment = {f"z{i}": i * n_shards // Z for i in range(Z)}

    def build(device_mesh, plane_cfg=cfg):
        plane = ShardedControlPlane(
            plane_cfg, _fab_targets(Z, window, hidden), n_shards=n_shards,
            assignment=assignment, coalesce_dispatch=False,
            device_mesh=device_mesh)
        for k in range(window + 1):      # fill rings to candidacy
            plane.observe_batch(15.0 * (k + 1), rows_seq[k % 4])
        return plane

    # all configs alive at once, timed ticks interleaved round-robin:
    # on a noisy box slow in-process drift hits every row equally, so
    # the D-ratios stay honest (sequential per-config runs do not)
    planes = {"1": build(None)}
    for d in ds:
        planes[str(d)] = build(int(d))
    d_max = str(max(ds))
    planes[d_max + "g"] = build(max(ds), gcfg)
    t = 15.0 * (window + 1)
    samples = {k: [] for k in planes}
    for j in range(warmup + ticks):
        t += 15.0
        rows = rows_seq[j % 4]
        for k, plane in planes.items():
            t0 = time.perf_counter()
            plane.observe_batch(t, rows)
            plane.control_step(t, 64, 2)
            samples[k].append(time.perf_counter() - t0)
    for plane in planes.values():
        plane.shutdown()
    tick_ms = {k: float(np.mean(v[warmup:])) * 1e3
               for k, v in samples.items()}
    ticks_per_s = {k: 1e3 / v for k, v in tick_ms.items()}
    return {
        "Z": Z, "window": window, "hidden": hidden, "n_shards": n_shards,
        "n_devices_visible": len(jax.devices()),
        "tick_ms": tick_ms, "ticks_per_s": ticks_per_s,
        "speedup_d8_vs_d1": ticks_per_s[d_max] / ticks_per_s["1"],
        "guard_overhead_d8": tick_ms[d_max + "g"] / tick_ms[d_max] - 1.0,
    }


def bench_device_scaling(zs=(4096, 16384, 65536), window: int = 1,
                         hidden: int = 16, n_shards: int = 8,
                         warmup: int = 2, ticks: int = 8,
                         n_devices: int = 8):
    """Cross-device tick scaling (DESIGN.md §9): the mesh-mapped plane vs
    the single-device plane at the control-plane-bound config.  Each Z
    point runs in its own subprocess with ``--xla_force_host_platform_
    device_count=8`` set before jax initialises (``force_host_devices_
    env``), so the lane works on any CPU-only CI box; all D rows of a
    point share one process, so their ratio cancels machine noise.

    window=1 / hidden=16 is the control-plane-bound config: with the
    paper-fidelity LSTM(50, W=4) the tick is forward-FLOP-bound on CPU
    and device count measures the GEMM, not the plane."""
    import subprocess
    import sys

    from repro.core.device_plane import force_host_devices_env

    root = Path(__file__).resolve().parent.parent
    env = force_host_devices_env(n_devices)
    env["PYTHONPATH"] = (str(root / "src")
                         + (os.pathsep + env["PYTHONPATH"]
                            if env.get("PYTHONPATH") else ""))
    out = []
    for Z in zs:
        spec = {"Z": int(Z), "window": window, "hidden": hidden,
                "n_shards": n_shards, "warmup": warmup, "ticks": ticks}
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_control_plane",
             "--device-lane", json.dumps(spec)],
            env=env, cwd=root, capture_output=True, text=True)
        if proc.returncode != 0:
            raise RuntimeError(
                f"device lane child Z={Z} failed:\n{proc.stderr[-2000:]}")
        point = json.loads(proc.stdout.strip().splitlines()[-1])
        out.append(point)
        tm = point["tick_ms"]
        csv_row(f"device_scaling_Z{Z}", tm["8"] * 1e3,
                f"D1(single-device)={tm['1']:.2f}ms "
                f"D2={tm['2']:.2f}ms D4={tm['4']:.2f}ms "
                f"D8={tm['8']:.2f}ms = "
                f"{point['speedup_d8_vs_d1']:.2f}x "
                f"(bar at Z>=16384: >=2x); quiet guard "
                f"D8={tm['8g']:.2f}ms "
                f"(+{point['guard_overhead_d8'] * 100:.1f}%, bar: <10%)")
    return out


def check_baseline(results: dict, path: Path) -> list[str]:
    """>2x ticks/sec regression vs the checked-in baseline fails CI (the
    same guard shape as bench_fleet_scale)."""
    base = json.loads(path.read_text())
    errors = []
    for point in results.get("shard_sweep", []):
        ref = base.get("sharded_ticks_per_s", {}).get(str(point["Z"]))
        if ref is None:
            continue
        if point["sharded_ticks_per_s"] < ref / 2.0:
            errors.append(
                f"Z={point['Z']}: {point['sharded_ticks_per_s']:,.0f} "
                f"ticks/s < half of baseline {ref:,.0f}")
    policy = results.get("policy_dispatch")
    ref = base.get("policy_dispatch_ticks_per_s", {}).get(
        str(policy["Z"]) if policy else None)
    if policy is not None and ref is not None:
        if policy["columnar_ticks_per_s"] < ref / 2.0:
            errors.append(
                f"policy dispatch Z={policy['Z']}: "
                f"{policy['columnar_ticks_per_s']:,.0f} ticks/s "
                f"< half of baseline {ref:,.0f}")
    for point in results.get("forecast_device", []):
        ref = base.get("forecast_fused_gflops", {}).get(str(point["Z"]))
        if ref is not None and point["fused_gflops"] < ref / 2.0:
            errors.append(
                f"forecast_device Z={point['Z']}: fused "
                f"{point['fused_gflops']:.2f} GFLOP/s "
                f"< half of baseline {ref}")
    for point in results.get("forecast_attn", []):
        ref = base.get("forecast_attn_fused_gflops", {}).get(str(point["Z"]))
        if ref is not None and point["fused_gflops"] < ref / 2.0:
            errors.append(
                f"forecast_attn Z={point['Z']}: fused "
                f"{point['fused_gflops']:.2f} GFLOP/s "
                f"< half of baseline {ref}")
    ab = results.get("forecast_ab", {}).get("bursty")
    rref = base.get("forecast_ab_bursty_mse_ratio")
    if ab is not None and rref is not None:
        floor = max(1.0, rref / 2.0)
        if ab["mse_ratio_lstm_over_attn"] < floor:
            errors.append(
                f"forecast_ab bursty: lstm/attn one-step MSE ratio "
                f"{ab['mse_ratio_lstm_over_attn']:.2f} < {floor:.2f} "
                f"(baseline {rref:.2f})")
    for point in results.get("device_scaling", []):
        z = str(point["Z"])
        ref = base.get("device_mesh_d8_ticks_per_s", {}).get(z)
        if ref is not None and point["ticks_per_s"]["8"] < ref / 2.0:
            errors.append(
                f"device_scaling Z={z}: mesh D=8 "
                f"{point['ticks_per_s']['8']:,.0f} ticks/s "
                f"< half of baseline {ref:,.0f}")
        rref = base.get("device_speedup_d8_vs_d1", {}).get(z)
        if rref is not None and point["speedup_d8_vs_d1"] < rref:
            errors.append(
                f"device_scaling Z={z}: D=8 only "
                f"{point['speedup_d8_vs_d1']:.2f}x the single-device "
                f"plane (bar: >={rref}x)")
        oref = base.get("device_guard_overhead_d8", {}).get(z)
        if oref is not None and point["guard_overhead_d8"] > oref:
            errors.append(
                f"device_scaling Z={z}: quiet guardrail adds "
                f"{point['guard_overhead_d8'] * 100:.1f}% to the D=8 "
                f"tick (bar: <={oref * 100:.0f}%)")
    g = results.get("guardrail_ab")
    if g is not None:
        vref = base.get("guardrail_violation_s_on")
        if vref is not None and g["violation_s_on"] > 2.0 * max(vref, 15.0):
            errors.append(
                f"guardrail_ab: {g['violation_s_on']:.0f}s SLA violation "
                f"with the guard on > 2x baseline {vref:.0f}s")
        pref = base.get("guardrail_pod_hours_on")
        if pref is not None and g["pod_hours_on"] > 1.5 * pref:
            errors.append(
                f"guardrail_ab: {g['pod_hours_on']:.2f} pod-hours with "
                f"the guard on > 1.5x baseline {pref:.2f}")
    return errors


def run(quick: bool = False, baseline: Path | None = None):
    lat = bench_control_latency(Z=16, iters=30 if quick else 100)
    par = bench_sim_core_parity(t_minutes=10 if quick else 20)
    sweep = bench_shard_sweep(zs=(16, 64, 256) if quick
                              else (16, 64, 256, 1024),
                              ticks=15 if quick else 30)
    # paper-fidelity reference: same sweep point with the LSTM(50) forward
    # (device-bound on the CI box; recorded, not gated)
    fidelity = bench_shard_sweep(zs=(256,), ticks=10 if quick else 20,
                                 hidden=50)[0]
    refit = bench_refit_overlap(Z=64, ticks=40 if quick else 60)
    policy = bench_policy_dispatch(Z=64 if quick else 256,
                                   ticks=15 if quick else 30)
    forecast = bench_forecast_device(zs=(64, 256) if quick
                                     else (64, 256, 1024),
                                     iters=5 if quick else 20)
    attn = bench_forecast_attn(zs=(64,) if quick else (64, 256),
                               iters=5 if quick else 10)
    ab = bench_forecast_ab(quick=quick)
    device = bench_device_scaling(zs=(4096, 16384) if quick
                                  else (4096, 16384, 65536))
    # one config for quick and full: the closed loop is seconds of wall
    # time, and the A/B bars need the full steady-state tail (the down
    # path's pod-hour savings pay for the spike's reactive capacity)
    guard = bench_guardrail_ab()
    payload = {"control_latency": lat, "sim_core_parity": par,
               "shard_sweep": sweep, "fidelity_point": fidelity,
               "refit_overlap": refit, "policy_dispatch": policy,
               "forecast_device": forecast, "forecast_attn": attn,
               "forecast_ab": ab, "device_scaling": device,
               "guardrail_ab": guard}
    save_bench("control_plane", payload)
    assert lat["speedup"] >= 5.0, f"batched speedup {lat['speedup']:.1f}x < 5x"
    assert par["parity_ok"], f"sim-core parity broken: {par}"
    assert refit["nonblocking"], f"refit blocked the tick loop: {refit}"
    assert policy["speedup_vs_fallback"] >= 1.5, \
        (f"columnar mixed-policy tick only "
         f"{policy['speedup_vs_fallback']:.1f}x vs fallback (bar: >=1.5x)")
    for p in forecast:
        if p["fused_vs_cell"] is not None:
            assert p["fused_vs_cell"] >= 1.0, \
                (f"forecast_device Z={p['Z']}: fused sequence kernel "
                 f"slower than the per-timestep cell path "
                 f"({p['fused_vs_cell']:.2f}x, bar: >=1x)")
    for p in attn:
        assert p["fused_vs_ref"] >= 1.0, \
            (f"forecast_attn Z={p['Z']}: fused attention kernel slower "
             f"than the eager jnp reference ({p['fused_vs_ref']:.2f}x, "
             f"bar: >=1x)")
    assert ab["bursty"]["mse_ratio_lstm_over_attn"] > 1.0, \
        (f"forecast_ab: attn did not beat the plain LSTM on the bursty "
         f"trace (attn={ab['bursty']['attn_mse']:.2f} vs "
         f"lstm={ab['bursty']['lstm_mse']:.2f})")
    for p in device:
        if p["Z"] == 16384:
            assert p["speedup_d8_vs_d1"] >= 2.0, \
                (f"device_scaling Z={p['Z']}: mesh D=8 only "
                 f"{p['speedup_d8_vs_d1']:.2f}x the single-device plane "
                 f"(bar: >=2x)")
            assert p["guard_overhead_d8"] < 0.10, \
                (f"device_scaling Z={p['Z']}: quiet guardrail adds "
                 f"{p['guard_overhead_d8'] * 100:.1f}% to the D=8 tick "
                 f"(bar: <10%)")
    assert guard["violation_s_on"] < guard["violation_s_off"], \
        (f"guardrail A/B: guard on did not cut SLA violation "
         f"({guard['violation_s_on']:.0f}s vs "
         f"{guard['violation_s_off']:.0f}s)")
    assert guard["pod_hours_on"] <= guard["pod_hours_off"], \
        (f"guardrail A/B: guard on spent more pod-hours "
         f"({guard['pod_hours_on']:.2f} vs {guard['pod_hours_off']:.2f})")
    if not quick:
        for p in sweep:
            if p["Z"] >= 256:
                assert p["speedup"] >= 3.0, \
                    f"Z={p['Z']}: sharded {p['speedup']:.1f}x < 3x"
    if baseline is not None:
        errors = check_baseline(payload, baseline)
        if errors:
            raise SystemExit("bench regression: " + "; ".join(errors))
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI bench-smoke lane: same as --quick")
    ap.add_argument("--check-baseline", type=Path, default=None)
    ap.add_argument("--device-lane", type=str, default=None,
                    help="internal: JSON spec for one device_scaling "
                         "point (run by bench_device_scaling in a "
                         "forced-host-device subprocess)")
    args = ap.parse_args()
    if args.device_lane is not None:
        print(json.dumps(_device_lane_measure(**json.loads(args.device_lane)),
                         default=float))
        raise SystemExit(0)
    out = run(quick=args.quick or args.smoke, baseline=args.check_baseline)
    print(json.dumps(out, indent=1, default=float))
