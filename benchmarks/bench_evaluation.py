"""Paper Figs. 11-14 / §6.4 — the 48 h NASA-trace evaluation: optimal PPA
(LSTM, finetune updates, CPU key metric) vs stock HPA.

Paper results:  sort  HPA 0.592±0.067  PPA 0.508±0.038   (p < 1e-3)
                eigen HPA 14.206±1.703 PPA 13.646±1.576  (p < 1e-3)
                RIR edge  HPA 0.3209   PPA 0.2988        (p < 1e-3)
                RIR cloud HPA 0.3373   PPA 0.3098        (p < 1e-3)
"""
from __future__ import annotations


from benchmarks.common import pretrain_series, save, timed, csv_row


def run(days: int = 2):
    from repro.core.experiments import (run_scenario, welch_t, NASA_SCALE)
    from repro.core.updater import UpdatePolicy
    from repro.workloads import nasa_trace, nasa_requests

    pre = pretrain_series()
    pre_train = {z: s[:1200] for z, s in pre.items()}
    counts = nasa_trace(days=days, scale=NASA_SCALE)
    tasks = nasa_requests(counts)
    T = days * 86400
    res = {}
    for scaler in ("hpa", "ppa"):
        kw = dict(scaler=scaler)
        if scaler == "ppa":
            kw.update(model_kind="lstm", pretrain=pre_train,
                      update_policy=UpdatePolicy.FINETUNE)
        r, us = timed(run_scenario, tasks, T, **kw)
        res[scaler] = r
        s = r.summary()
        csv_row(f"nasa_{scaler}", us,
                f"sort={s['sort_mean_s']:.3f} eigen={s['eigen_mean_s']:.3f} "
                f"rir_edge={s['rir_edge']:.3f} rir_cloud={s['rir_cloud']:.3f}")
    h, p = res["hpa"], res["ppa"]
    t_sort, p_sort = welch_t(h.sim.response_times("sort"),
                             p.sim.response_times("sort"))
    t_eig, p_eig = welch_t(h.sim.response_times("eigen"),
                           p.sim.response_times("eigen"))
    out = {
        "hpa": h.summary(), "ppa": p.summary(),
        "welch_sort": {"t": t_sort, "p": p_sort},
        "welch_eigen": {"t": t_eig, "p": p_eig},
        "claims": {
            "ppa_sort_faster": p.sort_mean < h.sort_mean and p_sort < 1e-3,
            "ppa_sort_stabler": p.sort_std < h.sort_std,
            "ppa_eigen_faster": p.eigen_mean < h.eigen_mean and p_eig < 1e-3,
            "ppa_eigen_stabler": p.eigen_std < h.eigen_std,
            "ppa_less_idle_edge": p.rir_edge[0] < h.rir_edge[0],
            "ppa_less_idle_cloud": p.rir_cloud[0] < h.rir_cloud[0],
        },
    }
    save("evaluation", out)
    return out


if __name__ == "__main__":
    r = run()
    print("claims:", r["claims"])
