# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
#   PYTHONPATH=src python -m benchmarks.run [--quick]
#
# Fig 7  -> bench_forecast        (ARMA vs LSTM prediction MSE)
# Fig 8  -> bench_update_policy   (P1/P2/P3 model-update policies)
# Fig 9/10 -> bench_key_metric    (CPU vs request-rate key metric)
# Fig 11-14 -> bench_evaluation   (48h NASA: PPA vs HPA)
# beyond-paper -> bench_serving   (PPA-scaled TPU decode fleet)
#              -> bench_control_plane (batched PPA + sim-core parity)
#              -> bench_kernels   (Pallas kernel us/call)
#              -> roofline        (per-cell terms from the dry-run artifacts)
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="shorter sims (CI-speed)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (bench_control_plane, bench_evaluation,
                            bench_forecast, bench_kernels, bench_key_metric,
                            bench_serving, bench_update_policy, roofline)

    t_min = 60 if args.quick else 200
    days = 1 if args.quick else 2
    jobs = [
        ("forecast", lambda: bench_forecast.run(t_min)),
        ("update_policy", lambda: bench_update_policy.run(t_min)),
        ("key_metric", lambda: bench_key_metric.run(t_min)),
        ("evaluation", lambda: bench_evaluation.run(days)),
        ("serving", lambda: bench_serving.run(1800.0 if args.quick else 3600.0)),
        ("control_plane", lambda: bench_control_plane.run(args.quick)),
        ("kernels", bench_kernels.run),
        ("roofline", roofline.main),
    ]
    print("name,us_per_call,derived")
    failed = []
    for name, fn in jobs:
        if args.only and name != args.only:
            continue
        try:
            fn()
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
