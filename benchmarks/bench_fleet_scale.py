"""Fleet-scale sim benchmark (DESIGN.md §3 "Fleet scale", §6 "Columnar").

Five claims are measured (the PRs' acceptance bars):

1. **Throughput** — the vectorised batch path (``WindowedArrivals`` +
   ``ArrayServerPool`` + ``CompletionLog``) sweeps P in {10^2..10^5} pods;
   at P = 10^4 it must complete a 2 h-sim-time run in < 60 s wall-clock and
   deliver >= 10x events/sec over the per-event heap path.
2. **Parity** — at small P the batched drain produces the *identical*
   completion sequence as the per-event engine (same RNG stream, same
   selection semantics).
3. **Multi-fleet** — several ``ServingFleet`` pools with out-of-phase load
   share one chip budget under a ``ChipBudgetArbiter``; the budget is never
   exceeded and chips actually move between fleets.
4. **Bulk scale-up** — ONE water-filling placement per scale-up decision
   (``waterfill_placement``) must beat the sequential per-pod argmax loop
   by >= 3x at P = 10^4, placements identical.
5. **Serving drain** — the windowed batch ``ServingFleet`` must beat
   per-event dispatch by >= 2x events/sec on a fleet-sized request trace.
6. **Federation tick** — the columnar ``MultiFleetSim`` tick + vectorised
   arbiter (DESIGN.md §12) vs the retained scalar dict loop at F = 64
   fleets, allocation sequence asserted bitwise-identical; plus the
   arbiter's scalar-vs-batch microbench at F = 1024.
7. **Digital twin** — real-time factor (sim-seconds per wall-second) of
   the full plane+fleet closed loop at 10^4 / 10^5 / 10^6 pods across 64
   fleets, prefit forecaster live, streaming completion logs above the
   pod threshold; the full lane requires RTF >= 1 at 10^5 pods and a
   completed 10^6-pod run.

Run: PYTHONPATH=src python -m benchmarks.bench_fleet_scale [--smoke]
         [--check-baseline benchmarks/baselines/fleet_scale_baseline.json]

``--smoke`` is the CI lane: small P only, plus a baseline diff that fails
on a >2x events/sec regression (all lanes).  Results land in
``BENCH_fleet_scale.json``.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import csv_row, save_bench

LOAD = 0.6  # offered load as a fraction of fleet capacity
SERVICE_S = 8.0  # mean task service time (fleet tasks, not the 0.45 s sort)
WINDOW_S = 15.0
ZONE = "fleet-0"

# (P, sim seconds): 2 h at the acceptance point, bounded at 10^5 so the
# completion log stays in memory (~10^7 events); smoke stays tiny for CI
FULL_SWEEP = [(100, 7200.0), (1000, 7200.0), (10_000, 7200.0), (100_000, 1200.0)]
SMOKE_SWEEP = [(100, 600.0), (1000, 600.0)]
LEGACY_CAP_EVENTS = 300_000  # bound the per-event engine's timed slice


def _legacy_cap(P: int, t_end: float) -> float:
    rate = LOAD * P / SERVICE_S
    return min(t_end, max(120.0, LEGACY_CAP_EVENTS / rate))


def _sim(P: int):
    from repro.cluster import ClusterSim, SimConfig
    from repro.cluster.topology import fleet_topology

    return ClusterSim(fleet_topology(P), SimConfig(seed=0, sort_service_s=SERVICE_S))


def _bindings(P: int):
    from repro.cluster import AutoscalerBinding
    from repro.core.hpa import HPA

    # fixed capacity: isolates dispatch cost from autoscaler dynamics
    return [AutoscalerBinding(ZONE, HPA(1e18, min_replicas=P), "hpa", P)]


def _arrivals(P: int, t_end: float):
    from repro.workloads import poisson_arrivals

    return poisson_arrivals(LOAD * P / SERVICE_S, t_end, WINDOW_S, zone=ZONE, seed=3)


def bench_point(P: int, t_end: float):
    """One sweep point: batched full run + per-event run on a bounded
    slice (events/sec is a rate, so the slice comparison is fair)."""
    arr = _arrivals(P, t_end)
    sim_b, binds_b = _sim(P), _bindings(P)  # imports stay out of the timer
    t0 = time.perf_counter()
    sim_b.run(arr, binds_b, t_end, initial_replicas=P)
    wall_b = time.perf_counter() - t0
    t_leg = _legacy_cap(P, t_end)
    arr_l = _arrivals(P, t_leg)
    tasks = [(float(t), "sort", ZONE) for t in arr_l.times]
    sim_l, binds_l = _sim(P), _bindings(P)
    t0 = time.perf_counter()
    sim_l.run(tasks, binds_l, t_leg, initial_replicas=P)
    wall_l = time.perf_counter() - t0
    eps_b, eps_l = len(arr) / wall_b, len(tasks) / wall_l
    csv_row(
        f"fleet_scale_P{P}",
        wall_b * 1e6,
        f"{eps_b:,.0f} ev/s batched vs {eps_l:,.0f} legacy "
        f"= {eps_b / eps_l:.1f}x",
    )
    return {
        "P": P,
        "sim_s": t_end,
        "events": len(arr),
        "wall_s_batched": wall_b,
        "events_per_s_batched": eps_b,
        "legacy_sim_s": t_leg,
        "legacy_events": len(tasks),
        "wall_s_legacy": wall_l,
        "events_per_s_legacy": eps_l,
        "eps_speedup": eps_b / eps_l,
    }


def bench_parity(P: int = 200, t_end: float = 900.0) -> dict:
    """Batched drain == per-event dispatch, completion for completion."""
    arr = _arrivals(P, t_end)
    vec = _sim(P).run(arr, _bindings(P), t_end, initial_replicas=P)
    tasks = [(float(t), "sort", ZONE) for t in arr.times]
    leg = _sim(P).run(tasks, _bindings(P), t_end, initial_replicas=P)
    cv = vec.completed_log.view()["completion"]
    cl = np.array([t.completion for t in leg.completed])
    ok = len(cv) == len(cl) and bool(np.array_equal(cv, cl))
    csv_row("fleet_scale_parity", float(len(cv)), f"identical={ok}")
    return {"P": P, "n_events": int(len(cv)), "identical": ok}


def bench_multi_fleet(t_end: float = 1800.0, budget: int = 192) -> dict:
    """Three fleets with out-of-phase diurnal load under one chip budget."""
    from repro.core import (
        ARIMAD1Forecaster,
        FleetController,
        PPAConfig,
        TargetSpec,
        ThresholdPolicy,
    )
    from repro.serving.fleet import FleetConfig
    from repro.serving.multi_fleet import FleetSpec, MultiFleetSim
    from repro.workloads import poisson_arrivals

    rng = np.random.default_rng(0)
    n_win = int(np.ceil(t_end / WINDOW_S))
    t_win = np.arange(n_win) * WINDOW_S
    specs, requests = [], {}
    for i in range(3):
        name = f"fleet-{i}"
        specs.append(
            FleetSpec(
                name,
                FleetConfig(total_chips=budget, chips_per_replica=16, seed=i),
                weight=1.0,
            )
        )
        phase = 2.0 * np.pi * i / 3.0
        rates = 2.0 * (1.0 + 0.8 * np.sin(2 * np.pi * t_win / t_end + phase))
        arr = poisson_arrivals(rates, t_end, WINDOW_S, seed=10 + i)
        ntok = rng.integers(16, 64, len(arr.times))
        requests[name] = [(float(t), int(n)) for t, n in zip(arr.times, ntok)]
    # slot-utilisation threshold: vals[0] = 100 * busy_slots, 8 slots per
    # replica -> 560 targets ~70 % slot utilisation per replica
    ctrl = FleetController(
        PPAConfig(threshold=560.0, stabilization_s=60.0),
        [TargetSpec(s.name, ThresholdPolicy(560.0, 1)) for s in specs],
        model=ARIMAD1Forecaster(),  # unfitted -> reactive decisions
    )
    sim = MultiFleetSim(specs, budget, ctrl)
    # straggler wave on fleet-0: its first replicas slow to 30 % mid-run
    wave = t_end / 3.0 + np.arange(3) * WINDOW_S
    events = sim.fleets["fleet-0"].core.events
    events.push_batch(wave, "slow", [{"rid": r, "speed": 0.3} for r in range(3)])
    events.push_batch(wave + 120, "slow", [{"rid": r, "speed": 1.0} for r in range(3)])
    sim.run(requests, t_end)
    grants = [g for _, g in sim.alloc_log]
    moves = sum(1 for a, b in zip(grants, grants[1:]) if a != b)
    rt = sim.response_times()
    out = {
        "fleets": len(specs),
        "budget_chips": budget,
        "peak_chips": sim.peak_chips(),
        "budget_respected": sim.peak_chips() <= budget,
        "peak_live_chips": max((c for _, c in sim.usage_log), default=0),
        "reallocations": moves,
        "n_requests": int(len(rt)),
        "p95_response_s": float(np.percentile(rt, 95)) if len(rt) else None,
    }
    csv_row(
        "fleet_scale_multi_fleet",
        float(len(rt)),
        f"peak={out['peak_chips']}/{budget} chips, {moves} reallocations",
    )
    return out


def bench_bulk_scale_up(P: int, trials: int = 3) -> dict:
    """One vectorised water-filling build-out vs the sequential per-pod
    argmax loop, placements asserted identical (DESIGN.md §6)."""
    from repro.cluster import ClusterSim, SimConfig
    from repro.cluster.topology import fleet_topology
    from repro.workloads import poisson_arrivals

    arr = poisson_arrivals(1.0, 30.0, WINDOW_S, zone=ZONE, seed=0)

    def mk():
        sim = ClusterSim(fleet_topology(P), SimConfig(seed=0))
        sim._vec_init(arr)
        sim._vec_zone(ZONE)
        return sim

    wall_b = wall_s = float("inf")
    for _ in range(trials):
        bulk, seq = mk(), mk()
        t0 = time.perf_counter()
        bulk._vec_scale_to(ZONE, P, 0.0)
        wall_b = min(wall_b, time.perf_counter() - t0)
        t0 = time.perf_counter()
        for _ in range(P):
            if seq._vec_schedule_pod(ZONE, 0.0) is None:
                break
        wall_s = min(wall_s, time.perf_counter() - t0)
        n = bulk._apools[ZONE].n
        assert n == seq._apools[ZONE].n == P, "build-out count mismatch"
        parity = np.array_equal(bulk._slot_node[ZONE][:n], seq._slot_node[ZONE][:n])
        assert parity, "bulk placement diverged from the sequential plan"
    out = {
        "P": P,
        "wall_s_bulk": wall_b,
        "wall_s_sequential": wall_s,
        "pods_per_s_bulk": P / wall_b,
        "pods_per_s_sequential": P / wall_s,
        "speedup": wall_s / wall_b,
    }
    csv_row(
        f"bulk_scale_up_P{P}",
        wall_b * 1e6,
        f"{out['pods_per_s_bulk']:,.0f} pods/s bulk vs "
        f"{out['pods_per_s_sequential']:,.0f} sequential "
        f"= {out['speedup']:.1f}x (bar at P=10^4: >=3x)",
    )
    return out


def bench_serving_drain(
    rate: float = 200.0, t_end: float = 1800.0, replicas: int = 64
) -> dict:
    """Windowed ``ServingFleet`` drain vs per-event dispatch on a
    fixed-capacity fleet (isolates dispatch cost), plus a bitwise
    completion-parity check."""
    from repro.core.hpa import HPA
    from repro.serving.fleet import FleetConfig, ServingFleet
    from repro.workloads import poisson_arrivals

    rng = np.random.default_rng(0)
    arr = poisson_arrivals(rate, t_end, WINDOW_S, seed=3)
    ntok = rng.integers(16, 64, len(arr.times))
    reqs = [(float(t), int(n)) for t, n in zip(arr.times, ntok)]
    cfg = FleetConfig(total_chips=replicas * 16, chips_per_replica=16, seed=0)

    t0 = time.perf_counter()
    pe = ServingFleet(cfg).run(
        list(reqs),
        HPA(560.0, min_replicas=replicas),
        "hpa",
        t_end,
        min_replicas=replicas,
    )
    wall_pe = time.perf_counter() - t0
    t0 = time.perf_counter()
    bt = ServingFleet(cfg, batch=True).run(
        (arr.times, ntok.astype(np.float64)),
        HPA(560.0, min_replicas=replicas),
        "hpa",
        t_end,
        min_replicas=replicas,
    )
    wall_bt = time.perf_counter() - t0
    identical = bool(
        np.array_equal(
            bt.completed_log.view()["completion"],
            np.array([r.completion for r in pe.completed]),
        )
    )
    out = {
        "events": len(reqs),
        "wall_s_per_event": wall_pe,
        "wall_s_batched": wall_bt,
        "events_per_s_per_event": len(reqs) / wall_pe,
        "events_per_s_batched": len(reqs) / wall_bt,
        "speedup": wall_pe / wall_bt,
        "identical": identical,
    }
    csv_row(
        "serving_drain",
        wall_bt * 1e6,
        f"{out['events_per_s_batched']:,.0f} ev/s batched vs "
        f"{out['events_per_s_per_event']:,.0f} per-event "
        f"= {out['speedup']:.1f}x, identical={identical}",
    )
    return out


def _federation_sim(F: int, budget: int, columnar: bool, batch: bool = True,
                    n_shards: int = 4, min_replicas: int = 1,
                    chips_per: int = 16, model=None, seed0: int = 0):
    """F fleets under one ShardedControlPlane + arbiter (DESIGN.md §12)."""
    from repro.core import ARIMAD1Forecaster, PPAConfig, ThresholdPolicy
    from repro.core.control_plane import ShardedControlPlane
    from repro.core.controller import TargetSpec
    from repro.serving.fleet import FleetConfig
    from repro.serving.multi_fleet import FleetSpec, MultiFleetSim

    specs = [
        FleetSpec(f"fleet-{i}", FleetConfig(
            total_chips=budget, chips_per_replica=chips_per, seed=seed0 + i))
        for i in range(F)
    ]
    # low threshold -> demands outrun the budget, so every tick exercises
    # the arbiter's weighted-contention branch, not just the floor grant
    plane = ShardedControlPlane(
        PPAConfig(threshold=100.0, stabilization_s=0.0),
        [TargetSpec(s.name, ThresholdPolicy(100.0, 1),
                    min_replicas=min_replicas) for s in specs],
        model=model or ARIMAD1Forecaster(),
        n_shards=n_shards, async_ticks=True)
    return MultiFleetSim(specs, budget, plane, batch=batch,
                         columnar=columnar)


def _federation_requests(F: int, t_end: float, rate: float, seed: int = 0):
    from repro.workloads import poisson_arrivals

    rng = np.random.default_rng(seed)
    reqs = {}
    for i in range(F):
        arr = poisson_arrivals(rate, t_end, WINDOW_S, seed=seed + 100 + i)
        reqs[f"fleet-{i}"] = (
            arr.times, rng.integers(16, 64, len(arr.times)).astype(float))
    return reqs


def bench_federation_tick(F: int = 64, t_end: float = 600.0) -> dict:
    """Columnar federation tick vs the retained scalar dict loop on the
    same F-fleet seeded workload (bitwise allocation parity asserted),
    plus the arbiter's scalar-vs-batch microbench at F=1024."""
    from repro.serving.multi_fleet import ChipBudgetArbiter

    budget = F * 3 * 16           # ~3 replicas per fleet under contention
    reqs = _federation_requests(F, t_end, rate=3.0)
    n_ticks = len(np.arange(WINDOW_S, t_end, WINDOW_S))

    sims, walls = {}, {}
    for key, columnar in (("scalar", False), ("columnar", True)):
        sim = _federation_sim(F, budget, columnar)
        t0 = time.perf_counter()
        sim.run(reqs, t_end)
        walls[key] = time.perf_counter() - t0
        sims[key] = sim
    identical = (sims["scalar"].alloc_log == sims["columnar"].alloc_log
                 and sims["scalar"].usage_log == sims["columnar"].usage_log)

    # arbiter microbench: one contended allocation at F=1024, both paths
    rng = np.random.default_rng(0)
    Fa = 1024
    names = [f"f{i}" for i in range(Fa)]
    d = rng.integers(1, 12, Fa)
    c = np.full(Fa, 16, np.int64)
    fl = np.ones(Fa, np.int64)
    w = rng.uniform(0.5, 4.0, Fa)
    arb = ChipBudgetArbiter(int(d.sum()) * 8)
    dd = {n: int(x) for n, x in zip(names, d)}
    cd = {n: 16 for n in names}
    fd = {n: 1 for n in names}
    wd = {n: float(x) for n, x in zip(names, w)}
    reps = 50
    t0 = time.perf_counter()
    for _ in range(reps):
        arb.allocate(dd, cd, fd, wd)
    wall_s = (time.perf_counter() - t0) / reps
    t0 = time.perf_counter()
    for _ in range(reps):
        arb.allocate_batch(d, c, fl, w)
    wall_b = (time.perf_counter() - t0) / reps

    out = {
        "F": F,
        "sim_s": t_end,
        "n_ticks": n_ticks,
        "events": int(sum(len(t) for t, _ in reqs.values())),
        "wall_s_scalar": walls["scalar"],
        "wall_s_columnar": walls["columnar"],
        "ticks_per_s_scalar": n_ticks / walls["scalar"],
        "ticks_per_s_columnar": n_ticks / walls["columnar"],
        "fleet_ticks_per_s": F * n_ticks / walls["columnar"],
        "speedup": walls["scalar"] / walls["columnar"],
        "identical": bool(identical),
        "arbiter_F": Fa,
        "arbiter_us_scalar": wall_s * 1e6,
        "arbiter_us_batch": wall_b * 1e6,
        "arbiter_speedup": wall_s / wall_b,
    }
    csv_row(
        f"federation_tick_F{F}",
        walls["columnar"] * 1e6,
        f"{out['fleet_ticks_per_s']:,.0f} fleet-ticks/s columnar = "
        f"{out['speedup']:.1f}x scalar, identical={identical}; arbiter "
        f"F={Fa}: {out['arbiter_speedup']:.1f}x",
    )
    return out


# digital-twin sweep: (P, sim seconds, offered load fraction) — horizons
# shrink with P so the full sweep stays tractable while every point still
# spans multiple control windows
DT_FULL = [(10_000, 600.0, 0.05), (100_000, 300.0, 0.05),
           (1_000_000, 60.0, 0.03)]
DT_SMOKE = [(10_000, 300.0, 0.05)]


def bench_digital_twin(P: int, t_end: float, load: float,
                       F: int = 64) -> dict:
    """Digital-twin real-time factor: sim-seconds per wall-second for the
    full closed loop (F windowed fleets + sharded plane + arbiter) at P
    pods.  The shared ARIMA-d1 forecaster is prefit so the proactive
    forecast path is live from tick 2 on; replica floors pin the fleet at
    P pods so the RTF measures the twin at scale, not a ramp.  Streaming
    completion logs kick in automatically above the pod threshold."""
    from repro.core import ARIMAD1Forecaster

    per = P // F                  # replicas per fleet, 1 chip each
    # prefit on a synthetic metric series: the twin's forecast lane must
    # run (one batched predict per shard per tick), not fall back reactive
    rng = np.random.default_rng(42)
    series = np.abs(rng.normal(100.0, 10.0, (32, 5)))
    model = ARIMAD1Forecaster().fit(series)
    # per-slot service ~2.1 s -> offered req/s per fleet at `load`
    rate = load * per * 8 / 2.1
    reqs = _federation_requests(F, t_end, rate=rate)
    events = int(sum(len(t) for t, _ in reqs.values()))
    sim = _federation_sim(F, budget=P, columnar=True, n_shards=8,
                          min_replicas=per, chips_per=1, model=model)
    t0 = time.perf_counter()
    sim.run(reqs, t_end)
    wall = time.perf_counter() - t0
    stats = sim.completion_stats()
    streaming = all(f.completed_log.streaming for f in sim.fleets.values())
    out = {
        "P": P,
        "fleets": F,
        "sim_s": t_end,
        "load": load,
        "events": events,
        "wall_s": wall,
        "rtf": t_end / wall,
        "events_per_s": events / wall,
        "completed": int(stats["count"]),
        "all_completed": bool(stats["count"] == events),
        "streaming_logs": bool(streaming),
        "budget_respected": bool(sim.peak_chips() <= P),
    }
    csv_row(
        f"digital_twin_P{P}",
        wall * 1e6,
        f"RTF {out['rtf']:.1f}x realtime ({events:,} events, "
        f"{out['events_per_s']:,.0f} ev/s, streaming={streaming})",
    )
    return out


def check_baseline(results: dict, path: Path) -> list[str]:
    """>2x events/sec regression vs the checked-in baseline fails CI."""
    base = json.loads(path.read_text())
    errors = []
    for point in results["sweep"]:
        ref = base.get("events_per_s_batched", {}).get(str(point["P"]))
        if ref is None:
            continue
        if point["events_per_s_batched"] < ref / 2.0:
            errors.append(
                f"P={point['P']}: {point['events_per_s_batched']:,.0f} ev/s "
                f"< half of baseline {ref:,.0f}"
            )
    for point in results.get("bulk_scale_up", []):
        ref = base.get("buildout_pods_per_s", {}).get(str(point["P"]))
        if ref is not None and point["pods_per_s_bulk"] < ref / 2.0:
            errors.append(
                f"bulk P={point['P']}: {point['pods_per_s_bulk']:,.0f} "
                f"pods/s < half of baseline {ref:,.0f}"
            )
    serving = results.get("serving_drain")
    ref = base.get("serving_events_per_s_batched")
    if serving is not None and ref is not None:
        if serving["events_per_s_batched"] < ref / 2.0:
            errors.append(
                f"serving drain: {serving['events_per_s_batched']:,.0f} "
                f"ev/s < half of baseline {ref:,.0f}"
            )
    fed = results.get("federation_tick")
    ref = base.get("federation_ticks_per_s")
    if fed is not None and ref is not None:
        if fed["fleet_ticks_per_s"] < ref / 2.0:
            errors.append(
                f"federation tick: {fed['fleet_ticks_per_s']:,.0f} "
                f"fleet-ticks/s < half of baseline {ref:,.0f}"
            )
    for point in results.get("digital_twin", []):
        ref = base.get("digital_twin_rtf", {}).get(str(point["P"]))
        if ref is not None and point["rtf"] < ref / 2.0:
            errors.append(
                f"digital twin P={point['P']}: RTF {point['rtf']:.1f} "
                f"< half of baseline {ref}"
            )
    return errors


def run(smoke: bool = False, baseline: Path | None = None) -> dict:
    sweep = SMOKE_SWEEP if smoke else FULL_SWEEP
    results = {
        "mode": "smoke" if smoke else "full",
        "load": LOAD,
        "service_s": SERVICE_S,
        "sweep": [bench_point(P, t) for P, t in sweep],
        "parity": bench_parity(),
        "multi_fleet": bench_multi_fleet(t_end=600.0 if smoke else 1800.0),
        "bulk_scale_up": [
            bench_bulk_scale_up(P) for P in ((1000,) if smoke else (1000, 10_000))
        ],
        "serving_drain": bench_serving_drain(
            rate=50.0 if smoke else 200.0,
            t_end=600.0 if smoke else 1800.0,
            replicas=16 if smoke else 64,
        ),
        "federation_tick": bench_federation_tick(
            F=16 if smoke else 64, t_end=300.0 if smoke else 600.0),
        "digital_twin": [bench_digital_twin(P, t, load)
                         for P, t, load in (DT_SMOKE if smoke else DT_FULL)],
    }
    save_bench("fleet_scale", results)
    assert results["parity"]["identical"], "batched drain lost seed parity"
    assert results["multi_fleet"]["budget_respected"], "chip budget exceeded"
    assert results["serving_drain"]["identical"], "serving drain lost parity"
    assert results["federation_tick"]["identical"], \
        "columnar federation tick lost allocation parity"
    for dt in results["digital_twin"]:
        assert dt["all_completed"], f"digital twin P={dt['P']} lost events"
        assert dt["budget_respected"], f"digital twin P={dt['P']} over budget"
    if not smoke:
        dt5 = next(p for p in results["digital_twin"] if p["P"] == 100_000)
        assert dt5["rtf"] >= 1.0, \
            f"digital twin RTF {dt5['rtf']:.2f} at 10^5 pods (bar: >=1)"
        dt6 = next(p for p in results["digital_twin"] if p["P"] == 1_000_000)
        assert dt6["streaming_logs"], "10^6-pod twin must stream its logs"
    if not smoke:
        p4 = next(p for p in results["sweep"] if p["P"] == 10_000)
        wall, speedup = p4["wall_s_batched"], p4["eps_speedup"]
        assert wall < 60.0, f"10^4-pod 2 h run took {wall:.1f}s (bar: <60s)"
        assert speedup >= 10.0, f"{speedup:.1f}x at P=10^4 (bar: >=10x)"
        b4 = next(p for p in results["bulk_scale_up"] if p["P"] == 10_000)
        assert b4["speedup"] >= 3.0, f"build-out {b4['speedup']:.1f}x (bar: >=3x)"
        sd = results["serving_drain"]["speedup"]
        assert sd >= 2.0, f"serving drain {sd:.1f}x (bar: >=2x)"
    if baseline is not None:
        errors = check_baseline(results, baseline)
        if errors:
            raise SystemExit("bench regression: " + "; ".join(errors))
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--check-baseline", type=Path, default=None)
    args = ap.parse_args()
    out = run(smoke=args.smoke, baseline=args.check_baseline)
    print(json.dumps(out, indent=1, default=float))
