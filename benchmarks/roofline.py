"""Roofline analysis (deliverable g): per (arch x shape) on the single-pod
16x16 mesh — compute / memory / collective terms, dominant bottleneck,
MODEL_FLOPS/HLO ratio, and a one-line improvement note.

Sources: analytic executed-FLOPs/bytes model (HLO-validated; scan bodies are
undercounted by XLA, see costs.py docstring) + collective wire bytes parsed
from the compiled dry-run HLO artifacts (artifacts/dryrun/*.json).
"""
from __future__ import annotations

import json
from pathlib import Path

ART = Path(__file__).resolve().parent.parent / "artifacts"

NOTE = {
    "compute": "raise arithmetic efficiency: fuse attention (Pallas flash), "
               "drop causal-mask waste, reduce remat recompute",
    "memory": "cut HBM traffic: int8 KV cache, fused norms, larger per-step "
              "arithmetic intensity (bigger microbatch)",
    "collective": "reshard: fewer all-gathers per layer (weight-stationary), "
                  "overlap collectives with compute, int8 gradient all-reduce",
}


def build_table(mesh: str = "16x16"):
    from repro.analysis.costs import analytic_cell
    from repro.configs import SHAPES, get_config
    from repro.configs.base import shape_applicable
    from repro.launch.mesh import kv_repeat_for

    class _M:  # kv_repeat_for needs .shape
        shape = {"data": 16, "model": 16}

    rows = []
    for f in sorted((ART / "dryrun").glob(f"*__{mesh}.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") == "error":
            continue
        arch, shape_name = rec["arch"], rec["shape"]
        cfg = get_config(arch).replace(kv_repeat=kv_repeat_for(
            get_config(arch), _M))
        if rec.get("overrides"):
            cfg = cfg.replace(**rec["overrides"])
        shape = SHAPES[shape_name]
        ok, why = shape_applicable(cfg, shape)
        if not ok:
            rows.append({"arch": arch, "shape": shape_name, "skip": why})
            continue
        cost = analytic_cell(cfg, shape)
        wire = rec["collectives"]["wire_bytes_per_device"]
        t = cost.terms(wire)
        rows.append({
            "arch": arch, "shape": shape_name,
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"], "dominant": t["dominant"],
            "usefulness": t["usefulness"],
            "roofline_fraction": t["roofline_fraction"],
            "peak_gib_dev": rec["memory"]["peak_per_device"] / 2**30,
            "note": NOTE[t["dominant"]],
        })
    return rows


def main():
    rows = build_table()
    out = ART / "roofline.json"
    out.write_text(json.dumps(rows, indent=1))
    hdr = (f"{'arch':22s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'coll_s':>10s} {'dominant':>10s} {'useful':>7s} {'roofl%':>7s}")
    print(hdr)
    for r in rows:
        if "skip" in r:
            print(f"{r['arch']:22s} {r['shape']:12s}  SKIP ({r['skip'][:48]})")
            continue
        print(f"{r['arch']:22s} {r['shape']:12s} {r['compute_s']:10.3e} "
              f"{r['memory_s']:10.3e} {r['collective_s']:10.3e} "
              f"{r['dominant']:>10s} {r['usefulness']:7.3f} "
              f"{100*r['roofline_fraction']:6.1f}%")
    return rows


if __name__ == "__main__":
    main()
