"""Paper Figs. 9-10 / §6.3 — key-metric choice: CPU utilisation vs request rate.

Both PPAs run the 200-minute Random Access scenario; response-time
distributions should overlap heavily (paper: 0.5156 s vs 0.5157 s) while the
CPU-keyed PPA wastes less (RIR 0.251 vs 0.317) and is more stable (lower
RIR std).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import pretrain_series, save, timed, csv_row


def run(t_minutes: int = 200):
    from repro.core.experiments import run_scenario, welch_t
    from repro.core.updater import UpdatePolicy
    from repro.workloads import random_access

    pre = pretrain_series()
    pre_train = {z: s[:1200] for z, s in pre.items()}
    T = t_minutes * 60
    tasks = random_access(T, seed=3)
    out = {}
    results = {}
    for key_idx, name in ((0, "cpu"), (4, "request_rate")):
        res, us = timed(run_scenario, tasks, T, scaler="ppa",
                        model_kind="lstm", pretrain=pre_train,
                        update_policy=UpdatePolicy.FINETUNE,
                        key_metric_idx=key_idx, rate_threshold=1.0,
                        min_replicas=2)
        results[name] = res
        rir_all = np.concatenate([
            [v for _, v in res.sim.rir_log[z]]
            for z in ("edge-0", "edge-1", "cloud")])
        out[name] = {
            "sort_mean_s": res.sort_mean, "sort_std_s": res.sort_std,
            "rir_mean": float(rir_all.mean()), "rir_std": float(rir_all.std()),
            "run_us": us,
        }
        csv_row(f"keymetric_{name}", us,
                f"sort={res.sort_mean:.4f}s rir={rir_all.mean():.3f}")
    t, p = welch_t(results["cpu"].sim.response_times("sort"),
                   results["request_rate"].sim.response_times("sort"))
    out["response_welch_t"] = t
    out["response_welch_p"] = p
    out["responses_equivalent"] = abs(
        out["cpu"]["sort_mean_s"] - out["request_rate"]["sort_mean_s"]) < 0.05
    out["cpu_more_efficient"] = out["cpu"]["rir_mean"] <= out["request_rate"]["rir_mean"]
    save("key_metric", out)
    return out


if __name__ == "__main__":
    r = run()
    print("responses equivalent:", r["responses_equivalent"],
          "| cpu more efficient:", r["cpu_more_efficient"])
