"""Paper Fig. 8 / §6.2 — the three model-update policies.

LSTM seed model, update loop every hour, 200-minute Random Access run.
Paper result (prediction MSE): P3 finetune 30 994 < P2 scratch 42 180 <
P1 never 64 770.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import pretrain_series, save, timed, csv_row


def run(t_minutes: int = 200):
    from repro.core.experiments import run_scenario
    from repro.core.updater import UpdatePolicy
    from repro.workloads import random_access

    pre = pretrain_series()
    pre_train = {z: s[:1200] for z, s in pre.items()}
    T = t_minutes * 60
    tasks = random_access(T, seed=3)
    out = {}
    for pol, name in ((UpdatePolicy.NEVER, "p1_never"),
                      (UpdatePolicy.SCRATCH, "p2_scratch"),
                      (UpdatePolicy.FINETUNE, "p3_finetune")):
        res, us = timed(run_scenario, tasks, T, scaler="ppa",
                        model_kind="lstm", pretrain=pre_train,
                        update_policy=pol, update_interval_s=3600.0,
                        min_replicas=2)
        mse = float(np.mean(list(res.mse.values())))
        mse_n = float(np.mean(list(res.mse_norm.values())))
        out[name] = {"mse_mean": mse, "mse_norm_mean": mse_n,
                     "mse_by_zone": res.mse, "run_us": us}
        csv_row(f"update_{name}", us, f"mse={mse:.1f} mse_norm={mse_n:.4f}")
    out["ordering_p3_best"] = (out["p3_finetune"]["mse_norm_mean"]
                               <= out["p2_scratch"]["mse_norm_mean"]
                               <= out["p1_never"]["mse_norm_mean"])
    save("update_policy", out)
    return out


if __name__ == "__main__":
    r = run()
    print("P3 <= P2 <= P1:", r["ordering_p3_best"])
