"""Kernel micro-benchmarks: us/call for each Pallas kernel (interpret mode on
CPU — numbers are correctness-path timings; TPU is the perf target) and the
XLA-path equivalents for reference."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, save


def _time(fn, *args, n=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def run():
    from repro.kernels import ops, ref
    rng = np.random.default_rng(0)
    out = {}

    B, Hq, Hkv, S, D = 1, 4, 2, 512, 64
    q = jnp.asarray(rng.normal(size=(B, Hq, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    us = _time(lambda: ops.flash_attention(q, k, v, block_q=128, block_kv=128))
    us_ref = _time(lambda: jax.jit(ref.flash_attention)(q, k, v))
    out["flash_attention"] = {"pallas_interpret_us": us, "xla_ref_us": us_ref}
    csv_row("kernel_flash_attention", us, f"xla_ref={us_ref:.1f}us")

    qd = jnp.asarray(rng.normal(size=(B, Hq, D)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(B, Hkv, 2048, D)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, Hkv, 2048, D)), jnp.float32)
    valid = jnp.asarray([1500], jnp.int32)
    us = _time(lambda: ops.decode_attention(qd, kc, vc, valid))
    us_ref = _time(lambda: jax.jit(
        lambda a, b, c: ref.decode_attention(a, b, c, kv_valid=valid))(qd, kc, vc))
    out["decode_attention"] = {"pallas_interpret_us": us, "xla_ref_us": us_ref}
    csv_row("kernel_decode_attention", us, f"xla_ref={us_ref:.1f}us")

    Bb, Ss, H, P, N = 1, 512, 4, 32, 16
    x = jnp.asarray(rng.normal(size=(Bb, Ss, H, P)), jnp.float32)
    dt = jnp.abs(jnp.asarray(rng.normal(size=(Bb, Ss, H)), jnp.float32)) * 0.1
    A = -jnp.abs(jnp.asarray(rng.normal(size=(H,)), jnp.float32))
    Bm = jnp.asarray(rng.normal(size=(Bb, Ss, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(Bb, Ss, N)), jnp.float32)
    Dv = jnp.asarray(rng.normal(size=(H,)), jnp.float32)
    us = _time(lambda: ops.ssd_scan(x, dt, A, Bm, Cm, Dv, chunk=128))
    us_ref = _time(lambda: jax.jit(ref.ssd_scan)(x, dt, A, Bm, Cm, Dv))
    out["ssd_scan"] = {"pallas_interpret_us": us, "xla_ref_us": us_ref}
    csv_row("kernel_ssd_scan", us, f"xla_ref={us_ref:.1f}us")

    In, H2, Bc = 5, 50, 64
    Wx = jnp.asarray(rng.normal(size=(In, 4 * H2)), jnp.float32)
    Wh = jnp.asarray(rng.normal(size=(H2, 4 * H2)), jnp.float32)
    b = jnp.zeros((4 * H2,))
    h = jnp.zeros((Bc, H2))
    c = jnp.zeros((Bc, H2))
    xx = jnp.asarray(rng.normal(size=(Bc, In)), jnp.float32)
    us = _time(lambda: ops.lstm_cell(Wx, Wh, b, h, c, xx))
    out["lstm_cell"] = {"pallas_interpret_us": us}
    csv_row("kernel_lstm_cell", us, "fused")

    xr = jnp.asarray(rng.normal(size=(2048, 512)), jnp.bfloat16)
    w = jnp.ones((512,), jnp.float32)
    us = _time(lambda: ops.rmsnorm(xr, w))
    out["rmsnorm"] = {"pallas_interpret_us": us}
    csv_row("kernel_rmsnorm", us, "fused")

    save("kernels", out)
    return out


if __name__ == "__main__":
    run()
