"""FROZEN seed-engine reference — the pre-refactor O(P)-scan ClusterSim,
kept verbatim so tests/benchmarks can assert that the heap-based sim core
(src/repro/sim/) reproduces the seed's seeded response-time distributions
exactly (tests/test_control_plane.py, benchmarks/bench_control_plane.py).

Do not modify except to track upstream API changes of its imports; it is a
parity oracle, not production code.
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict
from typing import Callable

import numpy as np

from repro.cluster.topology import Node, Topology, paper_topology
from repro.core.metrics import Snapshot


@dataclasses.dataclass
class Task:
    arrival: float
    kind: str              # 'sort' | 'eigen'
    zone: str              # serving zone ('cloud' for eigen)
    service_s: float
    start: float = math.nan
    completion: float = math.nan
    pod_id: int = -1
    redispatched: bool = False

    @property
    def response(self) -> float:
        return self.completion - self.arrival


@dataclasses.dataclass
class PodState:
    pid: int
    zone: str
    node: Node
    cpu_m: int
    created: float
    ready_at: float
    free_at: float = 0.0
    draining: bool = False
    dead: bool = False
    busy: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    queue: list = dataclasses.field(default_factory=list)  # inflight tasks

    def available(self, t: float) -> bool:
        return (not self.draining and not self.dead and t >= self.ready_at)


@dataclasses.dataclass
class SimConfig:
    pod_cpu_m: int = 500
    startup_s: float = 10.0
    control_interval_s: float = 15.0
    sort_service_s: float = 0.45
    eigen_service_s: float = 12.0
    service_jitter: float = 0.08           # lognormal sigma
    ram_per_pod_mb: float = 256.0
    straggler_redispatch_factor: float = 4.0   # deadline = factor * service
    seed: int = 0


@dataclasses.dataclass
class AutoscalerBinding:
    zone: str
    scaler: object          # PPA | HPA (duck-typed)
    kind: str               # 'ppa' | 'hpa'
    min_replicas: int = 1


class ClusterSim:
    def __init__(self, topo: Topology | None = None,
                 cfg: SimConfig | None = None):
        self.topo = topo or paper_topology()
        self.cfg = cfg or SimConfig()
        self.rng = np.random.default_rng(self.cfg.seed)
        self.pods: list[PodState] = []
        self._next_pid = 0
        self.completed: list[Task] = []
        self.samples: dict[str, list[tuple[float, np.ndarray]]] = defaultdict(list)
        self.replica_log: dict[str, list[tuple[float, int]]] = defaultdict(list)
        self.rir_log: dict[str, list[tuple[float, float]]] = defaultdict(list)
        self._win_tasks: dict[str, int] = defaultdict(int)
        self._raw: dict[str, list[np.ndarray]] = defaultdict(list)
        self._events: list[tuple[float, str, dict]] = []   # failures etc.

    # ------------------------------------------------------------ pods -----
    def _schedule_pod(self, zone: str, t: float) -> PodState | None:
        """Bin-pack a worker pod onto the zone node with most free capacity."""
        nodes = self.topo.zone_nodes(zone)
        nodes = [n for n in nodes if n.free_m >= self.cfg.pod_cpu_m]
        if not nodes:
            return None
        node = max(nodes, key=lambda n: n.free_m)
        node.alloc_m += self.cfg.pod_cpu_m
        pod = PodState(self._next_pid, zone, node, self.cfg.pod_cpu_m,
                       created=t, ready_at=t + self.cfg.startup_s,
                       free_at=t + self.cfg.startup_s)
        self._next_pid += 1
        self.pods.append(pod)
        return pod

    def _drain_pod(self, pod: PodState):
        pod.draining = True
        pod.node.alloc_m -= pod.cpu_m

    def zone_pods(self, zone: str, t: float | None = None):
        ps = [p for p in self.pods if p.zone == zone and not p.dead
              and not p.draining]
        if t is not None:
            ps = [p for p in ps if p.available(t)]
        return ps

    def scale_to(self, zone: str, n: int, t: float):
        cur = [p for p in self.pods if p.zone == zone and not p.dead
               and not p.draining]
        if len(cur) < n:
            for _ in range(n - len(cur)):
                if self._schedule_pod(zone, t) is None:
                    break
        elif len(cur) > n:
            # remove the newest pods first (graceful drain)
            for pod in sorted(cur, key=lambda p: -p.created)[:len(cur) - n]:
                self._drain_pod(pod)

    # ------------------------------------------------------- dispatching ---
    def _service_time(self, kind: str, node: Node) -> float:
        base = (self.cfg.sort_service_s if kind == "sort"
                else self.cfg.eigen_service_s)
        jit = float(self.rng.lognormal(0.0, self.cfg.service_jitter))
        return base * jit / max(node.speed_factor, 1e-3)

    def dispatch(self, task: Task, t: float):
        pods = self.zone_pods(task.zone, t)
        if not pods:
            # no ready pod: queue on the earliest-ready non-draining pod
            pods = [p for p in self.pods if p.zone == task.zone and not p.dead
                    and not p.draining]
            if not pods:
                # zone cold: best effort — spin one up (Kubernetes would have
                # min_replicas >= 1, so this is a safety net)
                pod = self._schedule_pod(task.zone, t)
                if pod is None:
                    task.completion = t + 60.0  # dropped/timeout sentinel
                    self.completed.append(task)
                    return
                pods = [pod]
        pod = min(pods, key=lambda p: max(p.free_at, t))
        service = self._service_time(task.kind, pod.node)
        start = max(t, pod.free_at, pod.ready_at)
        task.start, task.service_s = start, service
        task.completion = start + service
        task.pod_id = pod.pid
        pod.free_at = task.completion
        self._account_busy(pod, start, task.completion)
        pod.queue.append(task)
        self.completed.append(task)
        self._win_tasks[task.zone] += 1

    def _account_busy(self, pod: PodState, start: float, end: float):
        w = self.cfg.control_interval_s
        i0, i1 = int(start // w), int(end // w)
        for i in range(i0, i1 + 1):
            lo, hi = max(start, i * w), min(end, (i + 1) * w)
            if hi > lo:
                pod.busy[i] += hi - lo

    # ------------------------------------------------------ failures etc ---
    def inject_node_failure(self, t: float, node_name: str,
                            recover_after: float | None = None):
        self._events.append((t, "fail", {"node": node_name}))
        if recover_after is not None:
            self._events.append((t + recover_after, "recover",
                                 {"node": node_name}))

    def inject_straggler(self, t: float, node_name: str, factor: float,
                         duration: float):
        self._events.append((t, "slow", {"node": node_name, "factor": factor}))
        self._events.append((t + duration, "slow",
                             {"node": node_name, "factor": 1.0}))

    def _apply_events(self, t: float):
        fired = [e for e in self._events if e[0] <= t]
        self._events = [e for e in self._events if e[0] > t]
        for _, kind, arg in fired:
            node = next(n for n in self.topo.nodes if n.name == arg["node"])
            if kind == "fail":
                node.failed = True
                for p in self.pods:
                    if p.node is node and not p.dead:
                        p.dead = True
                        node.alloc_m = 0
                        # re-dispatch this pod's unfinished tasks
                        for task in p.queue:
                            if task.completion > t and not task.redispatched:
                                self.completed.remove(task)
                                task.redispatched = True
                                self.dispatch(task, t)
            elif kind == "recover":
                node.failed = False
            elif kind == "slow":
                node.speed_factor = arg["factor"]

    # --------------------------------------------------------- metrics -----
    def sample_zone(self, zone: str, t: float) -> Snapshot:
        """Window [t-w, t) exporter readout -> [CPU, RAM, NetIn, NetOut, rate]."""
        w = self.cfg.control_interval_s
        win = int((t - 1e-9) // w)
        pods = [p for p in self.pods if p.zone == zone and not p.dead]
        cpu_used_m = sum(p.busy.get(win, 0.0) / w * p.cpu_m for p in pods)
        # container RSS ~ worker-pool base + task working set (load-coupled,
        # so the forecaster's RAM feature is comparable between the static
        # pretraining collection and the autoscaled run)
        busy_avg = cpu_used_m / max(self.cfg.pod_cpu_m, 1)
        ram = self.cfg.ram_per_pod_mb * busy_avg
        n_req = self._win_tasks.get(zone, 0)
        rate = n_req / w
        net_in, net_out = n_req * 2.0, n_req * 1.0     # KB, synthetic
        self._win_tasks[zone] = 0
        # RIR_t = CPU_idle / CPU_requested   (paper Eq. 4)
        requested = sum(p.cpu_m for p in pods if p.available(t))
        if requested > 0:
            rir = max(requested - cpu_used_m, 0.0) / requested
            self.rir_log[zone].append((t, rir))
        # Prometheus-faithful export: rate()/avg over a 1-minute window
        # (4 control windows), not the raw 15 s instantaneous value
        raw = np.array([cpu_used_m, ram, net_in, net_out, rate])
        self._raw[zone].append(raw)
        ma = np.mean(self._raw[zone][-4:], axis=0)
        snap = Snapshot(t, ma)
        self.samples[zone].append((t, snap.values))
        return snap

    # ------------------------------------------------------------- run -----
    def run(self, tasks: list[tuple[float, str, str]],
            bindings: list[AutoscalerBinding], t_end: float,
            initial_replicas: int = 2):
        """tasks: sorted (arrival_t, kind, zone).  Runs arrivals + control
        ticks in time order; returns self for chaining."""
        cfg = self.cfg
        for b in bindings:
            self.scale_to(b.zone, max(initial_replicas, b.min_replicas), 0.0)
            for p in self.pods:      # initial pods are ready at t=0
                if p.zone == b.zone:
                    p.ready_at = 0.0
                    p.free_at = 0.0
        ticks = np.arange(cfg.control_interval_s, t_end,
                          cfg.control_interval_s)
        ti = 0
        for tick in ticks:
            self._apply_events(tick)
            while ti < len(tasks) and tasks[ti][0] <= tick:
                at, kind, zone = tasks[ti]
                self.dispatch(Task(at, kind, zone, 0.0), at)
                ti += 1
            for b in bindings:
                snap = self.sample_zone(b.zone, tick)
                cur = len(self.zone_pods(b.zone))
                max_rep = self.topo.max_replicas(b.zone, cfg.pod_cpu_m)
                if b.kind == "ppa":
                    b.scaler.observe(snap)
                    res = b.scaler.control_step(tick, max_rep, cur)
                    desired = max(res.replicas, b.min_replicas)
                    b.scaler.maybe_update(tick)
                else:
                    recent = np.stack([v for _, v in self.samples[b.zone]][-4:])
                    desired = b.scaler.decide(tick, recent, max_rep, cur)
                self.scale_to(b.zone, desired, tick)
                self.replica_log[b.zone].append((tick, desired))
        while ti < len(tasks) and tasks[ti][0] <= t_end:
            at, kind, zone = tasks[ti]
            self.dispatch(Task(at, kind, zone, 0.0), at)
            ti += 1
        return self

    # ------------------------------------------------------------ stats ----
    def response_times(self, kind: str | None = None) -> np.ndarray:
        ts = [t.response for t in self.completed
              if (kind is None or t.kind == kind) and math.isfinite(t.completion)]
        return np.asarray(ts)

    def rir_stats(self, zones: list[str]) -> tuple[float, float]:
        vals = np.concatenate([[v for _, v in self.rir_log[z]]
                               for z in zones if self.rir_log[z]])
        return float(vals.mean()), float(vals.std())
