#!/usr/bin/env python
"""Intra-repo markdown link checker (stdlib only) — the CI docs-lint.

Scans the docs site plus the root cross-reference files for markdown
links, resolves every non-external target relative to the containing
file, and fails on targets that don't exist.  External links
(http/https/mailto) are skipped — CI must not depend on the network.
In-page anchors (`#...`) are checked only for non-emptiness of the
target file; GitHub's slug algorithm is not reimplemented here.

    python tools/check_docs_links.py [root]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

SCAN = ("README.md", "DESIGN.md", "ROADMAP.md", "docs/*.md")
# [text](target) — target up to the first unescaped ')'; images share
# the syntax (leading '!' is irrelevant for resolution)
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
EXTERNAL = ("http://", "https://", "mailto:")


def iter_files(root: Path):
    for pat in SCAN:
        yield from sorted(root.glob(pat))


def check_file(path: Path, root: Path) -> list[str]:
    errors = []
    text = path.read_text(encoding="utf-8")
    in_code = False
    for ln, line in enumerate(text.splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_code = not in_code
            continue
        if in_code:
            continue
        for m in LINK.finditer(line):
            target = m.group(1)
            if target.startswith(EXTERNAL):
                continue
            target = target.split("#", 1)[0]
            if not target:           # pure in-page anchor
                continue
            resolved = (path.parent / target).resolve()
            if root.resolve() not in resolved.parents \
                    and resolved != root.resolve():
                errors.append(f"{path.relative_to(root)}:{ln}: "
                              f"link escapes the repo: {m.group(1)}")
            elif not resolved.exists():
                errors.append(f"{path.relative_to(root)}:{ln}: "
                              f"broken link: {m.group(1)}")
    return errors


def main() -> int:
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(".")
    files = list(iter_files(root))
    if not files:
        print(f"check_docs_links: no markdown files found under {root}")
        return 1
    errors = []
    for f in files:
        errors.extend(check_file(f, root))
    for e in errors:
        print(e)
    print(f"check_docs_links: {len(files)} files, "
          f"{len(errors)} broken links")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
