"""Checkpoint atomicity, roundtrip fidelity, garbage collection, async."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (AsyncCheckpointer, latest_step, load_checkpoint,
                              save_checkpoint)


def _tree():
    return {"w": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
            "opt": {"mu": jnp.ones((5,), jnp.float32),
                    "step": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 10, t)
    restored, step = load_checkpoint(tmp_path, t)
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_and_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, s, t, keep_last=3)
    assert latest_step(tmp_path) == 5
    kept = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir()
                  if p.name.startswith("step_"))
    assert kept == [3, 4, 5]


def test_uncommitted_ignored(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 1, t)
    save_checkpoint(tmp_path, 2, t)
    (tmp_path / "step_2" / "COMMITTED").unlink()   # simulate torn write
    assert latest_step(tmp_path) == 1
    _, step = load_checkpoint(tmp_path, t)
    assert step == 1


def test_structure_mismatch_rejected(tmp_path):
    save_checkpoint(tmp_path, 1, _tree())
    bad = {"only": jnp.zeros((2,))}
    try:
        load_checkpoint(tmp_path, bad)
        assert False, "should have raised"
    except AssertionError:
        pass


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(tmp_path)
    t = _tree()
    ck.save(42, t)
    ck.wait()
    restored, step = load_checkpoint(tmp_path, t)
    assert step == 42
