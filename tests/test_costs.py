"""Validate the analytic FLOP model against exact HLO counts on a small
UNROLLED config (scan bodies are undercounted by XLA — the reason the
analytic model exists; see costs.py)."""
import jax
import jax.numpy as jnp

from repro.analysis.costs import analytic_cell
from repro.configs.base import ModelConfig, ShapeSpec


def _hlo_flops(fn, *args):
    # cost_analysis() returns one dict per computation on newer JAX, a bare
    # dict on older releases — normalise to the flops total either way.
    ca = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(ca, dict):
        return ca.get("flops", 0)
    return sum(c.get("flops", 0) for c in ca)


def test_forward_flops_match_hlo_dense():
    cfg = ModelConfig(name="t", n_layers=2, d_model=128, n_heads=4,
                      n_kv_heads=4, head_dim=32, d_ff=256, vocab=512,
                      scan_layers=False, remat="none", attn_impl="naive")
    B, S = 2, 128
    shape = ShapeSpec("x", S, B, "prefill")
    from repro.models.registry import build_model
    model = build_model(cfg)
    params = model.abstract(jnp.float32)
    toks = jax.ShapeDtypeStruct((B, S), jnp.int32)

    def fwd(p, t):
        return model.forward(p, t)[0]

    measured = _hlo_flops(fwd, params, toks)
    est = analytic_cell(cfg, shape)
    # prefill executed == forward flops
    ratio = est.executed_flops / measured
    assert 0.6 < ratio < 1.7, (est.executed_flops, measured)


def test_train_flops_match_hlo_dense():
    cfg = ModelConfig(name="t", n_layers=2, d_model=128, n_heads=4,
                      n_kv_heads=4, head_dim=32, d_ff=256, vocab=512,
                      scan_layers=False, remat="none", attn_impl="naive")
    B, S = 2, 128
    shape = ShapeSpec("x", S, B, "train")
    from repro.models.registry import build_model
    model = build_model(cfg)
    params = model.abstract(jnp.float32)
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}

    def loss(p, b):
        return model.loss(p, b)[0]

    measured = _hlo_flops(lambda p, b: jax.grad(loss)(p, b), params, batch)
    est = analytic_cell(cfg, shape)
    ratio = est.executed_flops / measured
    assert 0.5 < ratio < 2.0, (est.executed_flops, measured)


def test_scan_undercount_documented():
    """The motivating fact: an 8-step scanned matmul reports ~1/8 the flops
    of the unrolled equivalent."""
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)

    def scanned(x, ws):
        return jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)[0]

    def unrolled(x, ws):
        for i in range(8):
            x = jnp.tanh(x @ ws[i])
        return x

    fs = _hlo_flops(scanned, x, ws)
    fu = _hlo_flops(unrolled, x, ws)
    assert fu > 5 * fs


def test_terms_and_dominance():
    cfg = ModelConfig(name="t", n_layers=2, d_model=128, n_heads=4,
                      n_kv_heads=4, head_dim=32, d_ff=256, vocab=512)
    c = analytic_cell(cfg, ShapeSpec("x", 4096, 8, "train"))
    t = c.terms(wire_bytes_per_device=1e9)
    assert t["dominant"] in ("compute", "memory", "collective")
    assert 0 < t["usefulness"] <= 1.2
    assert t["roofline_fraction"] <= 1.0 + 1e-6
