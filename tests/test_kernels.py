"""Per-kernel allclose sweeps (shapes x dtypes) against the ref.py oracles,
executed in interpret mode (TPU is the compile target)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def rand(*s, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(0, scale, s), dtype)


FLASH_SHAPES = [
    (1, 2, 1, 128, 128, 64),
    (2, 4, 2, 256, 128, 32),
    (1, 8, 2, 128, 256, 64),
]
VARIANTS = [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, window=64),
    dict(causal=True, cap=20.0),
    dict(causal=True, kv_valid=100),
]


@pytest.mark.parametrize("shape", FLASH_SHAPES)
@pytest.mark.parametrize("kw", VARIANTS, ids=lambda d: "_".join(d))
def test_flash_attention(shape, kw):
    B, Hq, Hkv, Sq, Skv, D = shape
    q, k, v = rand(B, Hq, Sq, D), rand(B, Hkv, Skv, D), rand(B, Hkv, Skv, D)
    o1 = ops.flash_attention(q, k, v, block_q=64, block_kv=64, **kw)
    o2 = ref.flash_attention(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = rand(1, 2, 128, 64, dtype=dtype)
    k = rand(1, 2, 128, 64, dtype=dtype)
    v = rand(1, 2, 128, 64, dtype=dtype)
    o1 = ops.flash_attention(q, k, v, block_q=64, block_kv=64)
    o2 = ref.flash_attention(q, k, v)
    tol = 3e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=tol, rtol=0.05)


@pytest.mark.parametrize("shape", [(2, 4, 2, 512, 64), (1, 8, 8, 256, 32),
                                   (3, 6, 3, 256, 16)])
def test_decode_attention(shape):
    B, Hq, Hkv, S, D = shape
    q, k, v = rand(B, Hq, D), rand(B, Hkv, S, D), rand(B, Hkv, S, D)
    kv_valid = jnp.asarray(RNG.integers(1, S, (B,)), jnp.int32)
    o1 = ops.decode_attention(q, k, v, kv_valid, block_s=128)
    o2 = ref.decode_attention(q, k, v, kv_valid=kv_valid)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=3e-5, rtol=1e-4)


def test_decode_attention_window():
    B, Hq, Hkv, S, D = 2, 4, 2, 256, 32
    q, k, v = rand(B, Hq, D), rand(B, Hkv, S, D), rand(B, Hkv, S, D)
    kv_valid = jnp.asarray([200, 130], jnp.int32)
    o1 = ops.decode_attention(q, k, v, kv_valid, window=64, block_s=64)
    o2 = ref.decode_attention(q, k, v, kv_valid=kv_valid, window=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("shape", [(2, 256, 3, 32, 16), (1, 128, 2, 16, 8)])
def test_ssd_scan(shape):
    B, S, H, P, N = shape
    x = rand(B, S, H, P)
    dt = jnp.abs(rand(B, S, H)) * 0.1
    A = -jnp.abs(rand(H))
    Bm, Cm, D = rand(B, S, N), rand(B, S, N), rand(H)
    y1, h1 = ops.ssd_scan(x, dt, A, Bm, Cm, D, chunk=64)
    y2, h2 = ref.ssd_scan(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h1),
                               np.asarray(h2.transpose(0, 1, 3, 2)),
                               atol=2e-4, rtol=1e-3)


@pytest.mark.parametrize("B,In,H", [(5, 5, 50), (130, 8, 32)])
def test_lstm_cell(B, In, H):
    Wx, Wh, b = rand(In, 4 * H), rand(H, 4 * H), rand(4 * H)
    h, c, x = rand(B, H), rand(B, H), rand(B, In)
    h1, c1 = ops.lstm_cell(Wx, Wh, b, h, c, x)
    h2, c2 = ref.lstm_cell(Wx, Wh, b, h, c, x)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-5)


@pytest.mark.parametrize("R,D", [(300, 128), (64, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(R, D, dtype):
    x, w = rand(R, D, dtype=dtype), rand(D)
    o1 = ops.rmsnorm(x, w)
    o2 = ref.rmsnorm(x, w)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               atol=1e-5 if dtype == jnp.float32 else 3e-2)
