"""Columnar federation engine (DESIGN.md §12).

Three contracts:

1. ``ChipBudgetArbiter.allocate_batch`` is bitwise-identical to the scalar
   dict path, and both satisfy the arbiter invariants (budget conserved,
   floors honoured, whole replicas, no chip idle while whole-replica
   demand is unmet, per-name determinism under insertion-order
   permutation) — hypothesis properties plus a seeded fuzz sweep so the
   properties run even where hypothesis isn't installed.
2. The columnar ``MultiFleetSim`` tick (default) reproduces the retained
   scalar oracle bitwise on seeded runs — allocation log, usage log,
   replica logs, completion sequences — for both controller kinds
   (``FleetController`` / ``ShardedControlPlane``) and both fleet modes
   (per-event / windowed batch).
3. Streaming completion logs (the 10⁶-pod memory bound): the auto-default
   above ``STREAMING_POD_THRESHOLD``, exact whole-run stats across the
   flush boundary, failure-requeue row alignment after compaction, and
   the zero-completion robustness fixes.
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serving.multi_fleet import ChipBudgetArbiter

WINDOW_S = 15.0


# ===================================================================== #
#  1. Arbiter: scalar == batch, invariants                              #
# ===================================================================== #
def _as_dicts(names, d, c, fl, w):
    return ({n: int(x) for n, x in zip(names, d)},
            {n: int(x) for n, x in zip(names, c)},
            {n: int(x) for n, x in zip(names, fl)},
            {n: float(x) for n, x in zip(names, w)})


def _both_paths(total, d, c, fl, w):
    """Run both arbiter paths on one case; assert bitwise equality and
    return the grants (fleet order)."""
    names = [f"f{i}" for i in range(len(d))]
    arb = ChipBudgetArbiter(total)
    dd, cd, fd, wd = _as_dicts(names, d, c, fl, w)
    scalar = arb.allocate(dd, cd, fd, wd)
    batch = arb.allocate_batch(d, c, fl, w)
    gs = np.array([scalar[n] for n in names], np.int64)
    assert np.array_equal(gs, batch), (d, c, fl, w, total, gs, batch)
    return batch


def _check_invariants(grant, total, d, c, fl):
    d, c, fl = (np.asarray(d, np.int64), np.asarray(c, np.int64),
                np.asarray(fl, np.int64))
    assert int(grant.sum()) <= total                    # budget conserved
    assert np.all(grant % c == 0)                       # whole replicas
    assert np.all(grant >= np.minimum(fl, d) * c)       # floors honoured
    assert np.all(grant <= d * c)                       # never over-granted
    # no chip idle while whole-replica demand is unmet: the leftover can't
    # cover one more replica of any fleet still short of its demand
    left = total - int(grant.sum())
    unmet = d * c - grant >= c
    assert np.all(left < c[unmet]), (left, c[unmet])


def _random_case(rng):
    F = int(rng.integers(1, 40))
    c = (np.full(F, int(rng.integers(1, 33))) if rng.random() < 0.5
         else rng.integers(1, 33, F))        # homogeneous and hetero costs
    d = rng.integers(0, 60, F)
    fl = rng.integers(0, 4, F)
    # integer weights with ~20% probability force remainder-fraction ties
    w = np.where(rng.random(F) < 0.2, rng.integers(1, 5, F).astype(float),
                 rng.uniform(0.1, 10.0, F))
    floor_chips = int((np.minimum(fl, d) * c).sum())
    total = floor_chips + int(rng.integers(
        0, max(int((d * c).sum()), 1) + 1))
    return total, d, c, fl, w


def test_arbiter_batch_matches_scalar_fuzz_sweep():
    """1500 seeded random cases (homogeneous + heterogeneous chip costs,
    tied + untied remainders): bitwise scalar/batch equality and every
    arbiter invariant.  This is the hypothesis property set, runnable
    without hypothesis installed."""
    rng = np.random.default_rng(7)
    for _ in range(1500):
        total, d, c, fl, w = _random_case(rng)
        grant = _both_paths(total, d, c, fl, w)
        _check_invariants(grant, total, d, c, fl)


def test_arbiter_permutation_determinism():
    """Per-name grants don't depend on dict insertion order (both paths
    agree with the permuted scalar run when remainder fractions are
    untied — continuous random weights make ties measure-zero)."""
    rng = np.random.default_rng(3)
    for _ in range(200):
        F = int(rng.integers(2, 20))
        c = np.full(F, 16)
        d = rng.integers(0, 40, F)
        fl = rng.integers(0, 3, F)
        w = rng.uniform(0.1, 10.0, F)
        total = int((np.minimum(fl, d) * c).sum()) + int(
            rng.integers(0, 400))
        names = [f"f{i}" for i in range(F)]
        dd, cd, fd, wd = _as_dicts(names, d, c, fl, w)
        base = ChipBudgetArbiter(total).allocate(dd, cd, fd, wd)
        perm = rng.permutation(F)
        pnames = [names[i] for i in perm]
        permuted = ChipBudgetArbiter(total).allocate(
            {n: dd[n] for n in pnames}, {n: cd[n] for n in pnames},
            {n: fd[n] for n in pnames}, {n: wd[n] for n in pnames})
        assert base == permuted
        batch_perm = ChipBudgetArbiter(total).allocate_batch(
            d[perm], c[perm], fl[perm], w[perm])
        assert np.array_equal(batch_perm,
                              np.array([base[n] for n in pnames]))


def test_arbiter_floors_over_budget_raise_in_both_paths():
    arb = ChipBudgetArbiter(16)
    with pytest.raises(ValueError):
        arb.allocate({"a": 2, "b": 2}, {"a": 16, "b": 16},
                     {"a": 1, "b": 1}, {"a": 1.0, "b": 1.0})
    with pytest.raises(ValueError):
        arb.allocate_batch([2, 2], [16, 16], [1, 1], [1.0, 1.0])


@settings(max_examples=200, deadline=None)
@given(st.data())
def test_arbiter_properties_hypothesis(data):
    """The same property set under hypothesis shrinking."""
    F = data.draw(st.integers(1, 24), label="F")
    homo = data.draw(st.booleans(), label="homogeneous")
    if homo:
        c = np.full(F, data.draw(st.integers(1, 32), label="c"))
    else:
        c = np.asarray(data.draw(
            st.lists(st.integers(1, 32), min_size=F, max_size=F),
            label="c"), np.int64)
    d = np.asarray(data.draw(
        st.lists(st.integers(0, 64), min_size=F, max_size=F),
        label="d"), np.int64)
    fl = np.asarray(data.draw(
        st.lists(st.integers(0, 4), min_size=F, max_size=F),
        label="fl"), np.int64)
    w = np.asarray(data.draw(
        st.lists(st.floats(0.05, 20.0, allow_nan=False), min_size=F,
                 max_size=F), label="w"), np.float64)
    floor_chips = int((np.minimum(fl, d) * c).sum())
    total = floor_chips + data.draw(
        st.integers(0, int((d * c).sum()) + 1), label="headroom")
    grant = _both_paths(total, d, c, fl, w)
    _check_invariants(grant, total, d, c, fl)


# ===================================================================== #
#  2. Columnar federation tick == scalar oracle                          #
# ===================================================================== #
def _mk_sim(columnar, batch, plane, budget=96, n_fleets=3, seed0=0,
            streaming=None):
    from repro.core import (ARIMAD1Forecaster, FleetController, PPAConfig,
                            TargetSpec, ThresholdPolicy)
    from repro.serving.fleet import FleetConfig
    from repro.serving.multi_fleet import FleetSpec, MultiFleetSim

    specs = [FleetSpec(f"f{i}", FleetConfig(
        total_chips=budget, chips_per_replica=16, seed=seed0 + i,
        log_streaming=streaming)) for i in range(n_fleets)]
    targets = [TargetSpec(s.name, ThresholdPolicy(560.0, 1)) for s in specs]
    cfg = PPAConfig(threshold=560.0, stabilization_s=0.0)
    if plane:
        from repro.core.control_plane import ShardedControlPlane
        ctrl = ShardedControlPlane(cfg, targets, model=ARIMAD1Forecaster(),
                                   n_shards=2, async_ticks=True)
    else:
        ctrl = FleetController(cfg, targets, model=ARIMAD1Forecaster())
    return MultiFleetSim(specs, budget, ctrl, batch=batch,
                         columnar=columnar)


def _requests(n_fleets=3, T=600.0, n=250, seed=1):
    rng = np.random.default_rng(seed)
    return {f"f{i}": sorted((float(t), int(rng.integers(16, 64)))
                            for t in rng.uniform(0, T, n))
            for i in range(n_fleets)}


@pytest.mark.parametrize("plane", [False, True],
                         ids=["fleet-controller", "sharded-plane"])
@pytest.mark.parametrize("batch", [False, True],
                         ids=["per-event", "windowed"])
def test_columnar_tick_matches_scalar_oracle(plane, batch):
    """Default (columnar) vs ``columnar=False`` oracle: allocation log,
    usage log, per-fleet replica logs and completion sequences bitwise."""
    T = 600.0
    reqs = _requests(T=T)
    a = _mk_sim(False, batch, plane).run(
        {k: list(v) for k, v in reqs.items()}, T)
    b = _mk_sim(True, batch, plane).run(
        {k: list(v) for k, v in reqs.items()}, T)
    assert a.alloc_log == b.alloc_log
    assert a.usage_log == b.usage_log
    for n in a.fleets:
        assert a.fleets[n].replica_log == b.fleets[n].replica_log
        assert np.array_equal(np.sort(a.response_times(n)),
                              np.sort(b.response_times(n)))
    if batch:
        for n in a.fleets:
            va = a.fleets[n].completed_log.view()
            vb = b.fleets[n].completed_log.view()
            assert np.array_equal(va, vb)
    assert a.completion_stats() == b.completion_stats()


def test_columnar_default_and_flag():
    sim = _mk_sim(None, False, False)
    assert sim.columnar is True          # columnar is the default
    assert _mk_sim(False, False, False).columnar is False


def test_replicas_array_matches_mapping_readout():
    """``TickResult.replicas_array()`` == per-name ``EvalResult`` gather,
    vectorized and fallback shards alike."""
    from repro.core import ARIMAD1Forecaster, PPAConfig, ThresholdPolicy
    from repro.core.control_plane import ShardedControlPlane
    from repro.core.controller import TargetSpec
    from repro.core.metrics import Snapshot

    names = [f"z{i}" for i in range(7)]
    plane = ShardedControlPlane(
        PPAConfig(threshold=50.0, stabilization_s=0.0),
        [TargetSpec(n, ThresholdPolicy(50.0, 1)) for n in names],
        model=ARIMAD1Forecaster(), n_shards=3)
    rng = np.random.default_rng(0)
    for t in (15.0, 30.0, 45.0):
        for n in names:
            plane.observe(n, Snapshot(t, rng.uniform(0, 200, 5)))
        res = plane.begin_tick(t, np.full(len(names), 64, np.int64),
                               np.ones(len(names), np.int64)).finish_tick()
        arr = res.replicas_array()
        assert arr.dtype == np.int64 and len(arr) == len(names)
        assert arr.tolist() == [res[n].replicas for n in names]


def test_window_offsets_match_per_tick_searchsorted():
    from repro.workloads.fleet_scale import window_offsets

    rng = np.random.default_rng(2)
    T = 100.0
    times = np.sort(rng.uniform(0, T + 10.0, 400))   # includes a post-T tail
    offs = window_offsets(times, WINDOW_S, T)
    ticks = np.arange(WINDOW_S, T, WINDOW_S)
    expect = [0] + [int(np.searchsorted(times, t, side="right"))
                    for t in ticks]
    expect.append(int(np.searchsorted(times, T, side="right")))
    assert offs.tolist() == expect
    assert offs.dtype == np.int64
    # empty stream and no-tick horizon degenerate cleanly
    assert window_offsets(np.zeros(0), WINDOW_S, T)[-1] == 0
    short = window_offsets(times, WINDOW_S, 10.0)
    assert short.tolist() == [0, int(np.searchsorted(times, 10.0, "right"))]


# ===================================================================== #
#  3. Streaming logs + robustness satellites                             #
# ===================================================================== #
def _assert_stats_equal(a: dict, b: dict):
    """Streaming folds per-window partial sums where the full log sums
    once globally, so the derived float stats agree to float-summation
    reassociation (~1e-12 relative), counts and extrema exactly."""
    assert a.keys() == b.keys()
    for k in ("count", "redispatched", "resp_min", "resp_max"):
        assert a[k] == b[k], (k, a[k], b[k])
    for k in ("resp_mean", "resp_std"):
        assert np.isclose(a[k], b[k], rtol=1e-9, atol=0.0,
                          equal_nan=True), (k, a[k], b[k])
def test_streaming_log_defaults_on_above_pod_threshold():
    from repro.serving.fleet import (STREAMING_POD_THRESHOLD, FleetConfig,
                                     ServingFleet)

    big = FleetConfig(total_chips=(STREAMING_POD_THRESHOLD + 1) * 16,
                      chips_per_replica=16)
    small = FleetConfig(total_chips=256, chips_per_replica=16)
    assert ServingFleet(big, batch=True).completed_log.streaming
    assert not ServingFleet(small, batch=True).completed_log.streaming
    # explicit override beats the auto threshold either way
    forced_off = dataclasses.replace(big, log_streaming=False)
    forced_on = dataclasses.replace(small, log_streaming=True)
    assert not ServingFleet(forced_off, batch=True).completed_log.streaming
    assert ServingFleet(forced_on, batch=True).completed_log.streaming


def test_streaming_fleet_stats_and_requeue_alignment():
    """A streaming fleet under failures: whole-run ``stats()`` match the
    full-log run exactly, and the ``_ntok_buf`` side-car stays aligned
    through flush compaction (the requeued rows book identical service
    times in both runs)."""
    from repro.core.hpa import HPA
    from repro.serving.fleet import FleetConfig, ServingFleet
    from repro.workloads import poisson_arrivals

    rng = np.random.default_rng(5)
    T = 900.0
    arr = poisson_arrivals(6.0, T, WINDOW_S, seed=9)
    ntok = rng.integers(16, 64, len(arr.times)).astype(np.float64)

    def run(streaming):
        cfg = FleetConfig(total_chips=8 * 16, chips_per_replica=16, seed=0,
                          log_streaming=streaming, log_retain_windows=3)
        f = ServingFleet(cfg, batch=True)
        f.inject_failure(T / 3, rid=0)       # orphans requeue mid-run
        f.inject_failure(2 * T / 3, rid=1)
        return f.run((arr.times, ntok), HPA(560.0, min_replicas=8), "hpa",
                     T, min_replicas=8)

    full, stream = run(False), run(True)
    assert stream.completed_log.streaming
    assert stream.completed_log.n_flushed > 0          # compaction happened
    assert len(stream.completed_log) == len(full.completed_log) == len(arr)
    assert stream._ntok_n == stream.completed_log.n    # side-car aligned
    _assert_stats_equal(full.completed_log.stats(),
                        stream.completed_log.stats())
    # retained tail rows are bitwise-equal to the full log's same rows
    tail = stream.completed_log.view()
    assert np.array_equal(tail,
                          full.completed_log.view()[-len(tail):])


def test_multi_fleet_zero_completion_fleets():
    """Satellite: idle fleets must not break the cross-fleet stats —
    typed empty arrays, ``peak_chips()`` == 0 before any run."""
    sim = _mk_sim(None, True, False)
    assert sim.peak_chips() == 0
    rt = sim.response_times()
    assert rt.dtype == np.float64 and rt.shape == (0,)
    # one loaded fleet among idle ones, both tick paths
    T = 300.0
    reqs = {"f0": _requests(n_fleets=1, T=T, n=60)["f0"]}
    for columnar in (False, True):
        s = _mk_sim(columnar, True, False).run(
            {k: list(v) for k, v in reqs.items()}, T)
        rt = s.response_times()
        assert len(rt) == 60 and np.isfinite(rt).all()
        assert s.response_times("f1").shape == (0,)
        assert s.completion_stats()["count"] == 60
        assert s.peak_chips() <= 96


def test_multi_fleet_streaming_matches_full_log_run():
    """Forcing streaming logs changes neither the control trajectory nor
    the whole-run completion stats (``completion_stats()`` folds the
    per-fleet aggregates exactly across flushed windows)."""
    T = 600.0
    reqs = _requests(T=T)
    full = _mk_sim(True, True, False, streaming=False).run(
        {k: list(v) for k, v in reqs.items()}, T)
    stream = _mk_sim(True, True, False, streaming=True).run(
        {k: list(v) for k, v in reqs.items()}, T)
    assert any(f.completed_log.n_flushed > 0
               for f in stream.fleets.values())
    assert full.alloc_log == stream.alloc_log
    assert full.usage_log == stream.usage_log
    _assert_stats_equal(full.completion_stats(), stream.completion_stats())


# ===================================================================== #
#  slow lane: the 10⁶-pod / 64-fleet acceptance point                    #
# ===================================================================== #
@pytest.mark.slow
def test_million_pod_federation_completes_under_streaming_logs():
    """10⁶ pods across 64 fleets, short horizon: the columnar tick + the
    streaming-by-default completion logs carry the run end to end with
    bounded memory, budget respected, every arrival completed."""
    from repro.core import (ARIMAD1Forecaster, PPAConfig, ThresholdPolicy)
    from repro.core.control_plane import ShardedControlPlane
    from repro.core.controller import TargetSpec
    from repro.serving.fleet import FleetConfig
    from repro.serving.multi_fleet import FleetSpec, MultiFleetSim
    from repro.workloads import poisson_arrivals

    F, P, T = 64, 1_000_000, 60.0
    per = P // F                          # 15625 replicas per fleet
    specs = [FleetSpec(f"f{i}", FleetConfig(
        total_chips=per, chips_per_replica=1, slots_per_replica=8,
        seed=i)) for i in range(F)]
    plane = ShardedControlPlane(
        PPAConfig(threshold=560.0, stabilization_s=0.0),
        [TargetSpec(s.name, ThresholdPolicy(560.0, 1), min_replicas=per)
         for s in specs],
        model=ARIMAD1Forecaster(), n_shards=8, async_ticks=True)
    rng = np.random.default_rng(0)
    reqs = {}
    for i, s in enumerate(specs):
        arr = poisson_arrivals(40.0, T, WINDOW_S, seed=100 + i)
        reqs[s.name] = (arr.times,
                        rng.integers(16, 64, len(arr.times)).astype(float))
    sim = MultiFleetSim(specs, P, plane, batch=True).run(reqs, T)
    assert all(f.completed_log.streaming for f in sim.fleets.values())
    assert all(f.live_count() == per for f in sim.fleets.values())
    assert sim.peak_chips() <= P
    n_arr = sum(len(t) for t, _ in reqs.values())
    st = sim.completion_stats()
    assert st["count"] == n_arr
    assert np.isfinite(st["resp_mean"])
