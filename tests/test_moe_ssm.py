"""MoE dispatch and SSD-scan correctness beyond the smoke level."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig
from repro.models import moe as M
from repro.models import ssm as S


def _moe_cfg(E=4, k=2, d=32, ff=64, cf=8.0):
    return ModelConfig(name="t", family="moe", d_model=d, n_experts=E,
                       top_k=k, d_ff_expert=ff, capacity_factor=cf)


def _moe_params(cfg, key):
    from repro.models.params import init_params
    return init_params(M.moe_specs(cfg), key, jnp.float32)


def test_moe_full_capacity_matches_dense():
    """At unlimited capacity, sort-dispatch MoE == dense weighted expert sum."""
    cfg = _moe_cfg()
    key = jax.random.PRNGKey(0)
    p = _moe_params(cfg, key)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    out, aux = M.moe_block(p, x, cfg)

    logits = x @ p["w_router"]
    probs = jax.nn.softmax(logits, -1)
    top_w, top_e = jax.lax.top_k(probs, cfg.top_k)
    top_w = top_w / top_w.sum(-1, keepdims=True)
    dense = jnp.zeros_like(x)
    for e in range(cfg.n_experts):
        h = jax.nn.silu(x @ p["w_gate"][e]) * (x @ p["w_up"][e])
        ye = h @ p["w_down"][e]
        w_e = jnp.where(top_e == e, top_w, 0.0).sum(-1)
        dense = dense + ye * w_e[..., None]
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               atol=2e-4, rtol=1e-3)


def test_moe_capacity_drops_bounded():
    cfg = _moe_cfg(cf=0.5)            # force drops
    key = jax.random.PRNGKey(1)
    p = _moe_params(cfg, key)
    x = jax.random.normal(key, (1, 64, cfg.d_model))
    out, _ = M.moe_block(p, x, cfg)
    assert bool(jnp.isfinite(out).all())


@given(st.integers(2, 8), st.integers(1, 4), st.integers(8, 64))
@settings(max_examples=15, deadline=None)
def test_moe_dispatch_conservation(E, k, S_):
    """Every kept (token, expert) slot holds a real token index; weights of
    kept slots are within [0, 1]."""
    k = min(k, E)
    rng = np.random.default_rng(E * 100 + k)
    x = jnp.asarray(rng.normal(size=(S_, 8)), jnp.float32)
    logits = jnp.asarray(rng.normal(size=(S_, E)), jnp.float32)
    cap = M._capacity(S_, k, E, 1.25)
    ein, idx, wgt = M.route_and_dispatch(x, logits, k, cap, E)
    assert ein.shape == (E, cap, 8)
    assert ((idx >= 0) & (idx <= S_)).all()
    assert ((wgt >= 0) & (wgt <= 1.0 + 1e-6)).all()
    kept = (np.asarray(idx) < S_).sum()
    assert kept <= S_ * k


def _ssm_cfg():
    return ModelConfig(name="t", family="ssm", d_model=32, ssm_state=16,
                       ssm_heads=4, ssm_head_dim=16, ssm_expand=2,
                       ssm_chunk=16)


def test_ssd_chunked_matches_sequential():
    from repro.kernels import ref
    B, S_, H, P, N = 2, 64, 3, 8, 16
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(B, S_, H, P)), jnp.float32)
    dt = jnp.abs(jnp.asarray(rng.normal(size=(B, S_, H)), jnp.float32)) * 0.2
    A = -jnp.abs(jnp.asarray(rng.normal(size=(H,)), jnp.float32))
    Bm = jnp.asarray(rng.normal(size=(B, S_, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S_, N)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(H,)), jnp.float32)
    y1, h1 = S.ssd_chunked(x, dt, A, Bm, Cm, D, chunk=16)
    y2, h2 = ref.ssd_scan(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=2e-4, rtol=1e-3)


def test_mamba_block_chunked_continuation():
    """Prefilling in two halves through the cache == one full pass."""
    cfg = _ssm_cfg()
    from repro.models.params import init_params
    key = jax.random.PRNGKey(2)
    p = init_params(S.mamba_specs(cfg), key, jnp.float32)
    u = jax.random.normal(key, (2, 64, cfg.d_model))
    full, cache_full = S.mamba_block(p, u, cfg)
    h1, c1 = S.mamba_block(p, u[:, :32], cfg)
    h2, c2 = S.mamba_block(p, u[:, 32:], cfg, cache=c1)
    np.testing.assert_allclose(np.asarray(full[:, 32:]), np.asarray(h2),
                               atol=3e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(cache_full["state"]),
                               np.asarray(c2["state"]), atol=3e-4, rtol=1e-3)


def test_mamba_decode_matches_block():
    cfg = _ssm_cfg()
    from repro.models.params import init_params
    key = jax.random.PRNGKey(3)
    p = init_params(S.mamba_specs(cfg), key, jnp.float32)
    u = jax.random.normal(key, (1, 17, cfg.d_model))
    full, _ = S.mamba_block(p, u, cfg)
    _, cache = S.mamba_block(p, u[:, :16], cfg)
    step, _ = S.mamba_decode(p, u[:, 16:17], cfg, cache)
    np.testing.assert_allclose(np.asarray(full[:, 16:17]), np.asarray(step),
                               atol=3e-4, rtol=1e-3)
