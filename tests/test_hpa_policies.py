"""HPA (Eq. 1) + static-policy properties."""
import math

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.hpa import HPA
from repro.core.policies import ThresholdPolicy


def _recent(metric):
    return np.tile(np.array([[metric, 0, 0, 0, 0]]), (5, 1))


@given(st.floats(0, 1e5, allow_nan=False), st.floats(1.0, 1e3))
@settings(max_examples=60, deadline=None)
def test_eq1_ceil(metric, thr):
    """NumOfReplicas = ceil(metric / threshold), pre-caps."""
    hpa = HPA(thr, tolerance=0.0, stabilization_s=0.0, staleness_windows=0,
              max_scale_up_pods=10**6, max_scale_up_factor=1e9)
    got = hpa.decide(0.0, _recent(metric), 10**6, current_replicas=10**5)
    # scale-down stabilization window contains only this rec
    assert got == max(1, math.ceil(metric / thr)) or got == 10**5


@given(st.floats(1.0, 1e3), st.integers(1, 100))
@settings(max_examples=40, deadline=None)
def test_tolerance_deadband(thr, cur):
    hpa = HPA(thr, tolerance=0.1, stabilization_s=0.0, staleness_windows=0)
    metric = thr * cur * 1.05          # within +-10% -> no change
    assert hpa.decide(0.0, _recent(metric), 10**6, cur) == cur


def test_scale_down_stabilization():
    hpa = HPA(100.0, stabilization_s=60.0, staleness_windows=0,
              max_scale_up_pods=100, max_scale_up_factor=100.0)
    assert hpa.decide(0.0, _recent(900.0), 100, 1) >= 9
    # load drops; within the window the old recommendation holds
    assert hpa.decide(30.0, _recent(100.0), 100, 9) == 9
    # after the window expires it may come down
    assert hpa.decide(120.0, _recent(100.0), 100, 9) < 9


def test_scale_up_rate_limit():
    hpa = HPA(1.0, stabilization_s=0.0, staleness_windows=0, tolerance=0.0)
    got = hpa.decide(0.0, _recent(1000.0), 10**6, current_replicas=2)
    assert got == max(2 + 4, 4)        # max(cur+4, 2*cur)


@given(st.floats(0, 1e5), st.floats(0, 1e5), st.floats(1.0, 1e3))
@settings(max_examples=60, deadline=None)
def test_threshold_policy_monotone(m1, m2, thr):
    pol = ThresholdPolicy(thr, tolerance=0.0)
    lo, hi = sorted([m1, m2])
    assert pol(lo, {"current": 1}) <= pol(hi, {"current": 1})


@given(st.floats(-1e308, 1e308) | st.just(float("nan")) | st.just(float("inf")))
@settings(max_examples=40, deadline=None)
def test_threshold_policy_total(metric):
    """Policy never crashes, always returns >= min_replicas."""
    pol = ThresholdPolicy(100.0, min_replicas=2, tolerance=0.0)
    try:
        n = pol(metric, {"current": 3})
    except OverflowError:              # inf -> documented: fall back
        n = pol(float("nan"), {"current": 3})
    assert n >= 2
