"""Chaos engine + degraded-mode control plane (DESIGN.md §13,
docs/resilience.md):

* seeded fault tapes are deterministic, replayable and content-addressed
  (``signature``), and ``pop_due``/``reset`` replay them bit-identically;
* a quiet tape with resilience armed is bitwise identical to resilience
  off — arming the machinery costs nothing until chaos actually strikes;
* the degraded stale-metric hold anchors at the last decision made on
  *fresh* metrics (the Kubernetes keep-desiredReplicas rule), not at the
  live count a kill storm is eating — scalar ``stage_degrade`` and the
  columnar ``decide`` are elementwise identical under randomised
  staleness (hypothesis);
* shard failover snapshots carry the hold anchor across a crash;
* a fast end-to-end A/B pair rides the ``chaos_smoke`` marker.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (ARIMAD1Forecaster, FleetController, PPAConfig,
                        ResilienceConfig, ShardedControlPlane, Snapshot,
                        TargetSpec, ThresholdPolicy)
from repro.core.metrics import N_METRICS
from repro.sim.chaos import ChaosConfig, ChaosSchedule
from repro.workloads.scenarios import ClientConfig, make_chaos_scenario

W = 15.0


def _row(v: float) -> np.ndarray:
    return np.full(N_METRICS, float(v))


# ------------------------------------------------------------- the tape ----
def _dense_cfg():
    return ChaosConfig(window_s=W, storm_start_p=0.15,
                       blackout_rate_per_h=10.0, stall_rate_per_h=3.0,
                       crash_rate_per_h=15.0)


def test_schedule_seed_determinism():
    cfg = _dense_cfg()
    a = ChaosSchedule.build(cfg, n_zones=4, t_end=1800.0, seed=3, n_shards=2)
    b = ChaosSchedule.build(cfg, n_zones=4, t_end=1800.0, seed=3, n_shards=2)
    c = ChaosSchedule.build(cfg, n_zones=4, t_end=1800.0, seed=4, n_shards=2)
    assert a == b
    assert a.signature() == b.signature()
    assert len(a) > 0
    assert a.signature() != c.signature()


def test_schedule_pop_due_reset_replay():
    sched = ChaosSchedule.build(_dense_cfg(), n_zones=3, t_end=900.0,
                                seed=11)

    def drain():
        out = []
        for k in range(1, 61):
            due = sched.pop_due(k * W)
            assert (due["t"] <= k * W).all()
            out.append(due)
        return np.concatenate(out)

    first = drain()
    assert len(first) == len(sched)            # everything delivered once
    assert sched.pop_due(1e9).size == 0        # cursor exhausted
    sched.reset()
    second = drain()
    assert np.array_equal(first, second)


# ------------------------------------------------- quiet tape == no tape ----
def _quiet_cfg():
    return ChaosConfig(window_s=W, storm_start_p=0.0, blackout_rate_per_h=0.0,
                       stall_rate_per_h=0.0, crash_rate_per_h=0.0)


def test_quiet_tape_resilience_armed_is_bitwise_noop():
    """With zero chaos the armed plane (finite TTL, periodic snapshots)
    must make bitwise the same decisions as ``resilience=None``: the
    degraded machinery is a pure fast-path no-op until a fault fires."""
    from benchmarks.bench_chaos import _chaos_sim

    t_end, F = 300.0, 2
    names = [f"fleet-{i}" for i in range(F)]
    client = ClientConfig(rate_per_s=8.0, window_s=W, n_tokens=8,
                          retry_threshold=2.0, retry_frac=0.3)
    logs = {}
    for key, res in (("off", None),
                     ("on", ResilienceConfig(stale_ttl_s=20.0,
                                             snapshot_every=2))):
        scen = make_chaos_scenario(names, t_end=t_end, seed=5,
                                   chaos_cfg=_quiet_cfg(),
                                   client_cfg=client, n_shards=2)
        assert len(scen.chaos) == 0
        sim = _chaos_sim(F, res)
        sim.run({}, t_end, scenario=scen)
        logs[key] = (sim.alloc_log, sim.completion_stats())
    assert logs["off"][0] == logs["on"][0]
    assert logs["off"][1] == logs["on"][1]


# ----------------------------------------------------- the degraded hold ----
def _armed_cfg():
    return PPAConfig(threshold=10.0, key_metric_idx=0, stabilization_s=0.0,
                     resilience=ResilienceConfig(stale_ttl_s=20.0))


def _spec(name):
    return TargetSpec(name, ThresholdPolicy(10.0, 1))


@pytest.mark.parametrize("make", [
    lambda: FleetController(_armed_cfg(), [_spec("z")],
                        model=ARIMAD1Forecaster()),
    lambda: ShardedControlPlane(_armed_cfg(), [_spec("z")],
                                model=ARIMAD1Forecaster(), n_shards=1),
])
def test_stale_hold_anchors_last_fresh_decision(make):
    """Fresh metric 80 -> desired 8.  Then the exporter blacks out (a
    frozen LOW row republished past the TTL) while node failures eat the
    fleet down to 2 live replicas.  The hold must stay at the last fresh
    decision (8) — not follow the live count down (the old ratchet), and
    not trust the frozen row (which would say 1)."""
    ctrl = make()
    for k in range(1, 7):
        ctrl.observe("z", Snapshot(k * W, _row(80.0)))
        out = ctrl.control_step(k * W, 16, {"z": 4})
    assert out["z"].replicas == 8
    # t=120: republished stale row, 30 s past the last fresh sample
    ctrl.observe("z", Snapshot(120.0, _row(5.0)), fresh=False)
    out = ctrl.control_step(120.0, 16, {"z": 2})
    assert out["z"].replicas == 8
    if hasattr(ctrl, "shutdown"):
        ctrl.shutdown()


def _parity_episode(n_ticks, draw_v, draw_fresh, draw_cur):
    """Drive scalar vs columnar planes through one randomised staleness
    episode and assert decision-for-decision equality."""
    names = [f"z{i}" for i in range(3)]
    ref = FleetController(_armed_cfg(), [_spec(n) for n in names],
                          model=ARIMAD1Forecaster())
    plane = ShardedControlPlane(_armed_cfg(), [_spec(n) for n in names],
                                model=ARIMAD1Forecaster(), n_shards=2)
    for k in range(1, n_ticks + 1):
        t = k * W
        cur = {}
        for n in names:
            fresh = draw_fresh(n, k)
            cur[n] = draw_cur(n, k)
            snap = Snapshot(t, _row(draw_v(n, k)))
            ref.observe(n, snap, fresh=fresh)
            plane.observe(n, snap, fresh=fresh)
        a = ref.control_step(t, 16, dict(cur))
        b = plane.control_step(t, 16, dict(cur))
        for n in names:
            assert a[n].replicas == b[n].replicas, (k, n)
    plane.shutdown()


def test_degraded_parity_scalar_vs_columnar_fuzz_sweep():
    """Seeded sweep of the parity property — runs even where hypothesis
    isn't installed."""
    for seed in range(8):
        rng = np.random.default_rng(seed)
        _parity_episode(int(rng.integers(6, 15)),
                        lambda n, k: float(rng.uniform(1.0, 120.0)),
                        lambda n, k: bool(rng.random() < 0.6),
                        lambda n, k: int(rng.integers(1, 13)))


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_degraded_parity_scalar_vs_columnar(data):
    """Under randomised metrics, live counts and blackout spans the
    columnar shard's vectorised hold must match the scalar staged
    pipeline (``stage_degrade``/``stage_guard``) decision-for-decision —
    the hypothesis variant with shrinking."""
    n_ticks = data.draw(st.integers(6, 14))
    _parity_episode(
        n_ticks,
        lambda n, k: data.draw(st.floats(1.0, 120.0), label=f"{n}@{k}"),
        lambda n, k: data.draw(st.booleans(), label=f"fresh {n}@{k}"),
        lambda n, k: data.draw(st.integers(1, 12), label=f"cur {n}@{k}"))


def test_failover_snapshot_carries_hold_anchor():
    """state_snapshot/wipe/restore round-trips the degraded hold's anchor:
    a restored shard keeps holding a stale target at the pre-crash desired
    count instead of falling back to the (storm-shrunk) live count."""
    plane = ShardedControlPlane(_armed_cfg(), [_spec("z")],
                                model=ARIMAD1Forecaster(), n_shards=1)
    for k in range(1, 7):
        plane.observe("z", Snapshot(k * W, _row(80.0)))
        plane.control_step(k * W, 16, {"z": 4})
    shard = plane.shards[0]
    snap = shard.state_snapshot()
    shard.wipe()
    assert (shard._deg_last == -1).all()
    shard.restore(snap)
    assert (shard._deg_last == 8).all()
    plane.observe("z", Snapshot(120.0, _row(5.0)), fresh=False)
    out = plane.control_step(120.0, 16, {"z": 2})
    assert out["z"].replicas == 8
    plane.shutdown()


# ------------------------------------------------------- end-to-end pair ----
@pytest.mark.chaos_smoke
def test_chaos_ab_pair_smoke():
    """One tiny A/B pair through the real bench harness: the tape fires,
    both lanes complete work, and the ON lane actually exercises the
    degraded machinery (holds + snapshots) on an identical replay."""
    from benchmarks.bench_chaos import bench_chaos_pair

    pair = bench_chaos_pair(F=2, t_end=450.0, seed=3)
    assert pair["chaos_events"] > 0
    assert pair["off"]["completions"] > 0
    assert pair["on"]["completions"] > 0
    assert np.isfinite(pair["on"]["sla_violation_ratio"])
    deg = pair["on"]["degraded"]
    assert deg.get("snapshots", 0) >= 1
    # the tape is content-addressed: same seed, same signature
    from benchmarks.bench_chaos import _scenario

    assert pair["chaos_signature"] == _scenario(2, 450.0, 3).chaos.signature()
