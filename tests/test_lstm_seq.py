"""Fused block-batched Pallas LSTM sequence kernel (DESIGN.md §7).

Parity obligations, all in interpret mode on CPU:

* forward — ``ops.lstm_seq`` / ``ops.lstm_seq_stacked`` == the ``ref.py``
  oracles == the forecaster's non-Pallas ``lstm_forward`` at tight
  tolerance, over random shapes including batch sizes that don't divide
  ``block_b`` (the pad-and-mask path) and E×Z ensemble stacking;
* gradients — the checkpoint-style custom VJP reproduces the non-Pallas
  formulation's gradients exactly (the backward replays ``ref.lstm_seq``);
* fit — ``_lstm_fit`` / ``lstm_fit_batch_stacked`` with ``use_pallas=True``
  land on the same refit params/losses as the non-Pallas stacked fit,
  ragged (pad-and-mask) batches included.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.forecaster import (LSTMForecaster, _lstm_forward_members,
                                   _lstm_forward_stacked, lstm_forward,
                                   lstm_fit_batch_stacked)
from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


def _rand(*s, scale=0.3):
    return jnp.asarray(RNG.normal(0, scale, s), jnp.float32)


def _shared_params(M, H, n_out):
    return (_rand(M, 4 * H), _rand(H, 4 * H), _rand(4 * H),
            _rand(H, n_out), _rand(n_out))


def _stacked_params(Z, M, H, n_out):
    return (_rand(Z, M, 4 * H), _rand(Z, H, 4 * H), _rand(Z, 4 * H),
            _rand(Z, H, n_out), _rand(Z, n_out))


# ------------------------------------------------------------- forward ----
@settings(max_examples=15, deadline=None)
@given(B=st.integers(1, 40), W=st.integers(1, 6), M=st.integers(1, 8),
       H=st.integers(1, 24), block_b=st.sampled_from([1, 3, 8, 16]))
def test_seq_forward_matches_ref(B, W, M, H, block_b):
    """Shared-weights layout, ragged batch blocks included (B need not
    divide block_b — padded rows are computed and sliced off)."""
    p = _shared_params(M, H, M)
    xs = _rand(B, W, M, scale=1.0)
    got = ops.lstm_seq(*p, xs, block_b=block_b)
    want = ref.lstm_seq(*p, xs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(Z=st.integers(1, 33), W=st.integers(1, 6), M=st.integers(1, 8),
       H=st.integers(1, 24), block_b=st.sampled_from([1, 4, 8]))
def test_seq_stacked_forward_matches_ref(Z, W, M, H, block_b):
    """Per-target layout: Z independently parameterised rows, one kernel."""
    p = _stacked_params(Z, M, H, M)
    xs = _rand(Z, W, M, scale=1.0)
    got = ops.lstm_seq_stacked(*p, xs, block_b=block_b)
    want = ref.lstm_seq_stacked(*p, xs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_seq_matches_lstm_forward_both_layouts():
    """The forecaster entry points: lstm_forward(use_pallas=True) and
    _lstm_forward_stacked(use_pallas=True) == their non-Pallas selves."""
    params = {"Wx": _rand(5, 200), "Wh": _rand(50, 200), "b": _rand(200),
              "Wo": _rand(50, 5), "bo": _rand(5)}
    xs = _rand(37, 4, 5, scale=1.0)
    np.testing.assert_allclose(
        np.asarray(lstm_forward(params, xs, use_pallas=True)),
        np.asarray(lstm_forward(params, xs, use_pallas=False)),
        rtol=1e-5, atol=1e-6)
    stacked = jax.tree.map(lambda leaf: jnp.stack([leaf] * 3), params)
    # perturb so the Z rows are genuinely distinct
    stacked = jax.tree.map(
        lambda leaf: leaf * jnp.arange(1, 4).reshape(
            (3,) + (1,) * (leaf.ndim - 1)), stacked)
    zxs = _rand(3, 4, 5, scale=1.0)
    np.testing.assert_allclose(
        np.asarray(_lstm_forward_stacked(stacked, zxs, use_pallas=True)),
        np.asarray(_lstm_forward_stacked(stacked, zxs, use_pallas=False)),
        rtol=1e-5, atol=1e-6)


def test_seq_members_exz_stacking():
    """E×Z ensemble layout: _lstm_forward_members vmaps the fused kernel
    over the member axis — matches the non-Pallas member forward."""
    E, Z, W, M, H = 3, 5, 4, 5, 12
    leaves = {"Wx": _rand(E, M, 4 * H), "Wh": _rand(E, H, 4 * H),
              "b": _rand(E, 4 * H), "Wo": _rand(E, H, M),
              "bo": _rand(E, M)}
    xs = _rand(E, Z, W, M, scale=1.0)
    np.testing.assert_allclose(
        np.asarray(_lstm_forward_members(leaves, xs, use_pallas=True)),
        np.asarray(_lstm_forward_members(leaves, xs, use_pallas=False)),
        rtol=1e-5, atol=1e-6)


def test_seq_empty_batch():
    """B=0 / Z=0 return empty outputs like the scan/vmap paths (callers
    such as a fully-reactive forecast tick may legitimately pass none)."""
    p = _shared_params(5, 12, 5)
    assert np.asarray(ops.lstm_seq(*p, jnp.zeros((0, 4, 5)))).shape == (0, 5)
    sp = _stacked_params(0, 5, 12, 5)
    assert np.asarray(
        ops.lstm_seq_stacked(*sp, jnp.zeros((0, 4, 5)))).shape == (0, 5)


# ------------------------------------------------------------ gradients ----
def test_seq_gradients_match_non_pallas():
    """The custom VJP replays the jnp reference, so grads equal the
    non-Pallas lstm_forward's — params and inputs both."""
    params = {"Wx": _rand(5, 80), "Wh": _rand(20, 80), "b": _rand(80),
              "Wo": _rand(20, 5), "bo": _rand(5)}
    xs = _rand(13, 4, 5, scale=1.0)
    y = _rand(13, 5, scale=1.0)

    def loss(p, x, use_pallas):
        pred = lstm_forward(p, x, use_pallas=use_pallas)
        return jnp.mean((pred - y) ** 2)

    gp_t, gx_t = jax.grad(loss, argnums=(0, 1))(params, xs, True)
    gp_f, gx_f = jax.grad(loss, argnums=(0, 1))(params, xs, False)
    for k in params:
        np.testing.assert_allclose(np.asarray(gp_t[k]), np.asarray(gp_f[k]),
                                   rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(gx_t), np.asarray(gx_f),
                               rtol=1e-5, atol=1e-7)


# ------------------------------------------------------------- fit path ----
def _series(n, i=0):
    rng = np.random.default_rng(100 + i)
    return np.abs(rng.normal(200, 40, (n, 5)))


@settings(max_examples=5, deadline=None)
@given(lens=st.lists(st.integers(14, 30), min_size=2, max_size=4),
       epochs=st.integers(2, 6))
def test_fit_batch_stacked_pallas_matches_plain(lens, epochs):
    """lstm_fit_batch_stacked with use_pallas=True (fused kernel inside the
    vmapped epoch scan) == the non-Pallas stacked fit, ragged pad-and-mask
    histories included."""
    serieses = [_series(n, i) for i, n in enumerate(lens)]

    def mk(up):
        return [LSTMForecaster(window=4, epochs=epochs, seed=i,
                               use_pallas=up) for i in range(len(lens))]

    ms_f, ms_t = mk(False), mk(True)
    assert lstm_fit_batch_stacked(ms_f, serieses, from_scratch=True)
    assert lstm_fit_batch_stacked(ms_t, serieses, from_scratch=True)
    for a, b in zip(ms_f, ms_t):
        np.testing.assert_allclose(a.last_losses, b.last_losses,
                                   rtol=1e-4, atol=1e-6)
        for k in a.params:
            np.testing.assert_allclose(np.asarray(a.params[k]),
                                       np.asarray(b.params[k]),
                                       rtol=2e-4, atol=2e-5)


def test_sequential_fit_and_predict_pallas_parity():
    """LSTMForecaster(use_pallas=True): fit + predict + predict_batch all
    ride the fused kernel and match the non-Pallas model."""
    s = _series(42)
    a = LSTMForecaster(window=4, epochs=8, seed=3)
    b = LSTMForecaster(window=4, epochs=8, seed=3, use_pallas=True)
    a.fit(s, from_scratch=True)
    b.fit(s, from_scratch=True)
    pa, _ = a.predict(s[-4:])
    pb, _ = b.predict(s[-4:])
    np.testing.assert_allclose(pa, pb, rtol=1e-4, atol=1e-5)
    recents = np.stack([s[-4:], s[-8:-4], s[-12:-8]])
    np.testing.assert_allclose(a.predict_batch(recents)[0],
                               b.predict_batch(recents)[0],
                               rtol=1e-4, atol=1e-5)


def test_ensemble_fit_predict_pallas_parity():
    """E×Z ensemble refit + Bayesian predict through the fused kernel."""
    from repro.core.forecaster import EnsembleForecaster
    s = _series(40)
    a = EnsembleForecaster(n_members=2, window=4, epochs=6)
    b = EnsembleForecaster(n_members=2, window=4, epochs=6, use_pallas=True)
    a.fit(s, from_scratch=True)
    b.fit(s, from_scratch=True)
    recents = np.stack([s[-4:], s[-9:-5]])
    ma, sa = a.predict_batch(recents)
    mb, sb = b.predict_batch(recents)
    np.testing.assert_allclose(ma, mb, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(sa, sb, rtol=1e-3, atol=1e-5)
