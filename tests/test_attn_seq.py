"""Fused Attention-Double-LSTM sequence kernel (DESIGN.md §11).

Parity obligations, all in interpret mode on CPU — the same contract as
``test_lstm_seq.py`` but for the second-generation forecast kernel
(LSTM-1 -> window-length temporal attention -> LSTM-2 -> ReLU head, all
inside ONE ``pallas_call``):

* forward — ``ops.attn_lstm_seq`` / ``ops.attn_lstm_seq_stacked`` == the
  ``ref.py`` oracles == the forecaster's non-Pallas ``_attn_body`` path,
  over random shapes including ragged batch blocks;
* gradients — the checkpoint-style custom VJP (backward replays
  ``ref.attn_lstm_seq``) reproduces the non-Pallas gradients;
* fit — ``lstm_fit_batch_stacked`` over ``AttnLSTMForecaster`` rows with
  ``use_pallas=True`` lands on the same params/losses as the plain path;
* plane — ``ShardedControlPlane(use_pallas=True, device_mesh=D)`` with
  attention forecasters is bitwise invariant across D in {1, 2, 8}
  (subprocess, forced host devices).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.forecaster import (AttnLSTMForecaster, LSTMForecaster,
                                   _lstm_forward_stacked, lstm_forward,
                                   lstm_fit_batch_stacked,
                                   lstm_stack_signature, make_forecaster)
from repro.kernels import ops, ref

RNG = np.random.default_rng(17)

LEAVES = ("Wx1", "Wh1", "b1", "Wa", "Wx2", "Wh2", "b2", "Wo", "bo")


def _rand(*s, scale=0.3):
    return jnp.asarray(RNG.normal(0, scale, s), jnp.float32)


def _shared_params(M, H, n_out):
    return (_rand(M, 4 * H), _rand(H, 4 * H), _rand(4 * H),   # LSTM-1
            _rand(H, H),                                      # attention Wa
            _rand(H, 4 * H), _rand(H, 4 * H), _rand(4 * H),   # LSTM-2
            _rand(H, n_out), _rand(n_out))                    # ReLU head


def _stacked_params(Z, M, H, n_out):
    return (_rand(Z, M, 4 * H), _rand(Z, H, 4 * H), _rand(Z, 4 * H),
            _rand(Z, H, H),
            _rand(Z, H, 4 * H), _rand(Z, H, 4 * H), _rand(Z, 4 * H),
            _rand(Z, H, n_out), _rand(Z, n_out))


def _dict_params(M, H, n_out):
    return dict(zip(LEAVES, _shared_params(M, H, n_out)))


# ------------------------------------------------------------- forward ----
@settings(max_examples=15, deadline=None)
@given(B=st.integers(1, 40), W=st.integers(1, 6), M=st.integers(1, 8),
       H=st.integers(1, 24), block_b=st.sampled_from([1, 3, 8, 16]))
def test_attn_forward_matches_ref(B, W, M, H, block_b):
    """Shared-weights layout, ragged batch blocks included (B need not
    divide block_b — padded rows are computed and sliced off)."""
    p = _shared_params(M, H, M)
    xs = _rand(B, W, M, scale=1.0)
    got = ops.attn_lstm_seq(*p, xs, block_b=block_b)
    want = ref.attn_lstm_seq(*p, xs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(Z=st.integers(1, 33), W=st.integers(1, 6), M=st.integers(1, 8),
       H=st.integers(1, 24), block_b=st.sampled_from([1, 4, 8]))
def test_attn_stacked_forward_matches_ref(Z, W, M, H, block_b):
    """Per-target layout: Z independently parameterised rows (batched-GEMV
    gate matmuls, per-row attention), one kernel."""
    p = _stacked_params(Z, M, H, M)
    xs = _rand(Z, W, M, scale=1.0)
    got = ops.attn_lstm_seq_stacked(*p, xs, block_b=block_b)
    want = ref.attn_lstm_seq_stacked(*p, xs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_attn_forward_matches_ref_fixed_shapes():
    """Deterministic ref-oracle parity (runs even without hypothesis):
    both layouts, a ragged block (B=11, block_b=4 -> pad 1) included."""
    p = _shared_params(5, 12, 5)
    xs = _rand(11, 4, 5, scale=1.0)
    np.testing.assert_allclose(
        np.asarray(ops.attn_lstm_seq(*p, xs, block_b=4)),
        np.asarray(ref.attn_lstm_seq(*p, xs)), rtol=1e-6, atol=1e-6)
    sp = _stacked_params(7, 5, 12, 5)
    zxs = _rand(7, 4, 5, scale=1.0)
    np.testing.assert_allclose(
        np.asarray(ops.attn_lstm_seq_stacked(*sp, zxs, block_b=3)),
        np.asarray(ref.attn_lstm_seq_stacked(*sp, zxs)),
        rtol=1e-6, atol=1e-6)


def test_attn_matches_forward_both_layouts():
    """The forecaster entry points: lstm_forward(arch='attn') and
    _lstm_forward_stacked(arch='attn') — Pallas == non-Pallas."""
    params = _dict_params(5, 24, 5)
    xs = _rand(37, 4, 5, scale=1.0)
    np.testing.assert_allclose(
        np.asarray(lstm_forward(params, xs, use_pallas=True, arch="attn")),
        np.asarray(lstm_forward(params, xs, use_pallas=False, arch="attn")),
        rtol=1e-5, atol=1e-6)
    stacked = jax.tree.map(lambda leaf: jnp.stack([leaf] * 3), params)
    # perturb so the Z rows are genuinely distinct
    stacked = jax.tree.map(
        lambda leaf: leaf * jnp.arange(1, 4).reshape(
            (3,) + (1,) * (leaf.ndim - 1)), stacked)
    zxs = _rand(3, 4, 5, scale=1.0)
    np.testing.assert_allclose(
        np.asarray(_lstm_forward_stacked(stacked, zxs, use_pallas=True,
                                         arch="attn")),
        np.asarray(_lstm_forward_stacked(stacked, zxs, use_pallas=False,
                                         arch="attn")),
        rtol=1e-5, atol=1e-6)


def test_attn_empty_batch():
    """B=0 / Z=0 return empty outputs like the scan/vmap paths."""
    p = _shared_params(5, 12, 5)
    assert np.asarray(
        ops.attn_lstm_seq(*p, jnp.zeros((0, 4, 5)))).shape == (0, 5)
    sp = _stacked_params(0, 5, 12, 5)
    assert np.asarray(
        ops.attn_lstm_seq_stacked(*sp, jnp.zeros((0, 4, 5)))).shape == (0, 5)


def test_attn_public_kernel_exports():
    """kernels/__init__.py exposes the jitted entry points under their
    public names (the submodule-name collision is resolved in favour of
    the callables)."""
    import repro.kernels as K
    assert K.attn_lstm_seq is ops.attn_lstm_seq
    assert K.attn_lstm_seq_stacked is ops.attn_lstm_seq_stacked
    assert K.lstm_seq is ops.lstm_seq
    assert callable(K.lstm_seq_stacked)


# ------------------------------------------------------------ gradients ----
def test_attn_gradients_match_non_pallas():
    """The custom VJP replays the jnp reference, so grads equal the
    non-Pallas ``_attn_body``'s — params and inputs both.  atol=1e-6: the
    deeper attn graph reassociates more under jit than the plain LSTM."""
    params = _dict_params(5, 20, 5)
    xs = _rand(13, 4, 5, scale=1.0)
    y = _rand(13, 5, scale=1.0)

    def loss(p, x, use_pallas):
        pred = lstm_forward(p, x, use_pallas=use_pallas, arch="attn")
        return jnp.mean((pred - y) ** 2)

    gp_t, gx_t = jax.grad(loss, argnums=(0, 1))(params, xs, True)
    gp_f, gx_f = jax.grad(loss, argnums=(0, 1))(params, xs, False)
    for k in params:
        np.testing.assert_allclose(np.asarray(gp_t[k]), np.asarray(gp_f[k]),
                                   rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(gx_t), np.asarray(gx_f),
                               rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------- fit path ----
def _series(n, i=0):
    rng = np.random.default_rng(200 + i)
    return np.abs(rng.normal(200, 40, (n, 5)))


@settings(max_examples=5, deadline=None)
@given(lens=st.lists(st.integers(14, 30), min_size=2, max_size=4),
       epochs=st.integers(2, 6))
def test_attn_fit_batch_stacked_pallas_matches_plain(lens, epochs):
    """lstm_fit_batch_stacked over AttnLSTMForecaster rows with
    use_pallas=True == the non-Pallas stacked fit, ragged pad-and-mask
    histories included — the stacked protocol is genuinely model-generic."""
    serieses = [_series(n, i) for i, n in enumerate(lens)]

    def mk(up):
        return [AttnLSTMForecaster(window=4, epochs=epochs, seed=i,
                                   use_pallas=up) for i in range(len(lens))]

    ms_f, ms_t = mk(False), mk(True)
    assert lstm_fit_batch_stacked(ms_f, serieses, from_scratch=True)
    assert lstm_fit_batch_stacked(ms_t, serieses, from_scratch=True)
    for a, b in zip(ms_f, ms_t):
        np.testing.assert_allclose(a.last_losses, b.last_losses,
                                   rtol=1e-4, atol=1e-6)
        for k in a.params:
            np.testing.assert_allclose(np.asarray(a.params[k]),
                                       np.asarray(b.params[k]),
                                       rtol=2e-4, atol=2e-5)


def test_attn_sequential_fit_and_predict_pallas_parity():
    """AttnLSTMForecaster(use_pallas=True): fit + predict + predict_batch
    all ride the fused kernel and match the non-Pallas model."""
    s = _series(42)
    a = AttnLSTMForecaster(window=4, epochs=8, seed=3)
    b = AttnLSTMForecaster(window=4, epochs=8, seed=3, use_pallas=True)
    a.fit(s, from_scratch=True)
    b.fit(s, from_scratch=True)
    pa, _ = a.predict(s[-4:])
    pb, _ = b.predict(s[-4:])
    np.testing.assert_allclose(pa, pb, rtol=1e-4, atol=1e-5)
    recents = np.stack([s[-4:], s[-8:-4], s[-12:-8]])
    np.testing.assert_allclose(a.predict_batch(recents)[0],
                               b.predict_batch(recents)[0],
                               rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------- zoo/plane ----
def test_make_forecaster_attn_and_stack_signature():
    """'attn' is a zoo entry; its stack signature leads with the arch so
    attn and lstm rows can never stack into one fused dispatch."""
    m = make_forecaster("attn", window=4, hidden=8, seed=0)
    assert isinstance(m, AttnLSTMForecaster)
    assert isinstance(m, LSTMForecaster)          # joins the LSTM protocol
    assert m.arch == "attn"
    assert set(m.PARAM_LEAVES) == set(LEAVES)
    assert set(m.params) == set(LEAVES)
    ls = make_forecaster("lstm", window=4, hidden=8, seed=0)
    assert lstm_stack_signature(m) != lstm_stack_signature(ls)
    # mixed-architecture batches fall back (no stacked fit)
    assert not lstm_fit_batch_stacked([m, ls], [_series(30), _series(30, 1)],
                                      from_scratch=True)


def test_attn_sharded_plane_matches_scalar_controller():
    """A pallas-backed attn plane (fused gang dispatch) makes the same
    decisions as the scalar per-target FleetController."""
    from repro.core import (FleetController, PPAConfig, ShardedControlPlane,
                            Snapshot, TargetSpec, ThresholdPolicy)
    from repro.core.metrics import N_METRICS

    def specs():
        out = []
        for i in range(6):
            m = AttnLSTMForecaster(window=2, hidden=6, epochs=3, seed=i,
                                   use_pallas=True)
            out.append(TargetSpec(f"t{i}", ThresholdPolicy(100.0, 1),
                                  model=m))
        return out

    cfg = PPAConfig(threshold=100.0, stabilization_s=60.0)
    plane = ShardedControlPlane(cfg, specs(), n_shards=2,
                                coalesce_dispatch=False)
    ctrl = FleetController(cfg, specs())
    rng = np.random.default_rng(5)
    t = 0.0
    for _ in range(8):
        t += 15.0
        rows = rng.uniform(50.0, 300.0, (6, N_METRICS))
        plane.observe_batch(t, rows)
        for i, n in enumerate(ctrl.targets):
            ctrl.observe(n, Snapshot(t, rows[i]))
        rp = plane.control_step(t, 32, 2)
        rc = ctrl.control_step(t, 32, 2)
        assert [rp[n].replicas for n in rp] == [rc[n].replicas for n in rc]
    plane.shutdown()


_CHILD = r"""
import hashlib, json
import numpy as np
from repro.core import (PPAConfig, ShardedControlPlane, TargetSpec,
                        ThresholdPolicy)
from repro.core.forecaster import AttnLSTMForecaster, Scaler
from repro.core.metrics import N_METRICS

Z, W, H, S = 16, 2, 8, 4

def fab_targets():
    base = AttnLSTMForecaster(window=W, hidden=H, seed=3, use_pallas=True)
    rng = np.random.default_rng(103)
    means = rng.uniform(50.0, 300.0, (Z, N_METRICS))
    stds = 0.1 * means + 1.0
    out = []
    for i in range(Z):
        m = AttnLSTMForecaster.__new__(AttnLSTMForecaster)
        m.__dict__.update(base.__dict__)
        m.params = {k: v * (1.0 + 0.01 * i) for k, v in base.params.items()}
        sc = Scaler(); sc.mean, sc.std, sc.fitted = means[i], stds[i], True
        m.scaler = sc; m._fitted, m._fit_count = True, 1
        m._valid_cache = (1, True)
        out.append(TargetSpec(f"t{i}", ThresholdPolicy(100.0, 1), model=m))
    return out

rng = np.random.default_rng(11)
rows_seq = [rng.uniform(50.0, 300.0, (Z, N_METRICS)) for _ in range(5)]

def digest(D):
    plane = ShardedControlPlane(
        PPAConfig(threshold=100.0, stabilization_s=60.0), fab_targets(),
        n_shards=S, coalesce_dispatch=False, device_mesh=D)
    h = hashlib.sha256()
    t = 0.0
    for rows in rows_seq:
        t += 15.0
        plane.observe_batch(t, rows)
        res = plane.control_step(t, 32, 2)
        for n in res:
            r = res[n]
            h.update(np.int64(r.replicas).tobytes())
            h.update(np.float64(r.key_metric).tobytes())
            if r.raw_prediction is not None:
                h.update(np.asarray(r.raw_prediction).tobytes())
    plane.shutdown()
    return h.hexdigest()

cells = {f"D{D}": digest(D) for D in (1, 2, 8)}
print("DIGESTS=" + json.dumps(cells))
"""


def test_attn_device_count_bitwise_invariance(forced_devices_runner):
    """ShardedControlPlane(use_pallas=True, device_mesh=D) with attention
    forecasters: tick results bitwise identical across D in {1, 2, 8} —
    per-target rows are independent, so the mesh partition (and the fused
    attn kernel's block boundaries inside each shard) cannot change
    numerics."""
    out = forced_devices_runner(_CHILD)
    line = next(ln for ln in out.splitlines() if ln.startswith("DIGESTS="))
    cells = json.loads(line[len("DIGESTS="):])
    assert len(cells) == 3
    vals = set(cells.values())
    assert len(vals) == 1, f"digest mismatch across device counts: {cells}"
