"""Training-substrate integration: loss decreases, clipping, schedules,
failure recovery produces bit-identical resumption of the data order."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update, schedule
from repro.training.train_loop import TrainConfig, train


@pytest.mark.slow
def test_loss_decreases_tiny_lm(tmp_path):
    cfg = smoke_config("h2o-danube-1.8b").replace(n_layers=2, d_ff=64,
                                                  d_model=64)
    tc = TrainConfig(steps=80, global_batch=8, seq_len=64, log_every=20,
                     lr=8e-3, ckpt_dir=None)
    _, hist = train(cfg, tc, log=lambda *a: None)
    init_entropy = np.log(cfg.vocab)          # untrained uniform baseline
    last = hist[-1]["loss"]
    assert last < init_entropy - 0.3, (init_entropy, last)


def test_failure_recovery_resumes(tmp_path):
    cfg = smoke_config("mamba2-780m").replace(n_layers=2, d_model=32,
                                              ssm_heads=2, ssm_state=8,
                                              ssm_head_dim=32, ssm_chunk=16)
    tc = TrainConfig(steps=30, global_batch=4, seq_len=32, ckpt_every=10,
                     ckpt_dir=str(tmp_path), async_ckpt=False, log_every=30)
    _, hist = train(cfg, tc, fail_at={17}, log=lambda *a: None)
    assert hist[-1]["step"] == 30
    # a run without failure reaches the same final loss (determinism)
    import shutil
    shutil.rmtree(tmp_path)
    _, hist2 = train(cfg, tc, log=lambda *a: None)
    assert abs(hist[-1]["loss"] - hist2[-1]["loss"]) < 1e-4


def test_grad_clipping_bounds_update():
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, warmup_steps=0)
    params = {"w": jnp.zeros((4,))}
    grads = {"w": jnp.full((4,), 1e6)}
    st = adamw_init(params, cfg)
    _, _, m = adamw_update(grads, st, params, cfg)
    assert m["grad_norm"] > 1e5          # reported pre-clip


def test_schedule_warmup_cosine():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(schedule(cfg, jnp.int32(10))) == pytest.approx(1.0, abs=1e-2)
    assert float(schedule(cfg, jnp.int32(100))) == pytest.approx(0.1, abs=1e-2)


def test_moments_dtype_bf16():
    cfg = AdamWConfig(moments_dtype="bfloat16")
    st = adamw_init({"w": jnp.zeros((3,), jnp.bfloat16)}, cfg)
    assert st["mu"]["w"].dtype == jnp.bfloat16
