"""Per-architecture smoke tests (deliverable f): every assigned arch's
reduced config runs one forward/train step on CPU with finite outputs and
the right shapes.  The FULL configs are exercised via the dry-run only."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs, smoke_config
from repro.models.layers import padded_vocab
from repro.models.registry import build_model

ARCHS = list_archs()


def _batch(cfg, key, B=2, S=32):
    if cfg.family == "encdec":
        return {"frames": jax.random.normal(key, (B, S, cfg.d_model)),
                "tokens": jnp.ones((B, S), jnp.int32),
                "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
         "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.frontend == "vision":
        b["extra_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_seq, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_loss_finite(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key, jnp.float32)
    loss, metrics = model.loss(params, _batch(cfg, key))
    assert jnp.isfinite(loss), (arch, loss)
    assert loss.shape == ()


@pytest.mark.parametrize("arch", ARCHS)
def test_logits_shape(arch):
    cfg = smoke_config(arch)
    if cfg.family == "encdec":
        pytest.skip("enc-dec logits covered in decode test")
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key, jnp.float32)
    B, S = 2, 32
    b = _batch(cfg, key, B, S)
    logits, _ = model.forward(params, b["tokens"],
                              extra_embeds=b.get("extra_embeds"))
    n_pos = S + (cfg.frontend_seq if cfg.frontend == "vision" else 0)
    assert logits.shape == (B, n_pos, padded_vocab(cfg.vocab))
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    from repro.launch.steps import make_train_step
    from repro.training.optimizer import adamw_init
    cfg = smoke_config(arch)
    model, opt_cfg, step_fn = make_train_step(cfg, None, None)
    key = jax.random.PRNGKey(2)
    params = model.init(key, jnp.float32)
    opt = adamw_init(params, opt_cfg)
    p2, o2, m = jax.jit(step_fn)(params, opt, _batch(cfg, key))
    assert jnp.isfinite(m["loss"])
    assert int(o2["step"]) == 1
    # parameters actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), params, p2)
    assert max(jax.tree.leaves(moved)) > 0


def test_full_configs_match_assignment():
    expect = {
        "zamba2-2.7b": dict(n_layers=54, d_model=2560, n_heads=32,
                            n_kv_heads=32, d_ff=10240, vocab=32000,
                            ssm_state=64),
        "h2o-danube-1.8b": dict(n_layers=24, d_model=2560, n_heads=32,
                                n_kv_heads=8, d_ff=6912, vocab=32000),
        "llama3-405b": dict(n_layers=126, d_model=16384, n_heads=128,
                            n_kv_heads=8, d_ff=53248, vocab=128256),
        "codeqwen1.5-7b": dict(n_layers=32, d_model=4096, n_heads=32,
                               n_kv_heads=32, d_ff=13440, vocab=92416),
        "gemma2-9b": dict(n_layers=42, d_model=3584, n_heads=16,
                          n_kv_heads=8, d_ff=14336, vocab=256000),
        "phi3.5-moe-42b-a6.6b": dict(n_layers=32, d_model=4096, n_heads=32,
                                     n_kv_heads=8, d_ff=6400, vocab=32064,
                                     n_experts=16, top_k=2),
        "granite-moe-1b-a400m": dict(n_layers=24, d_model=1024, n_heads=16,
                                     n_kv_heads=8, d_ff=512, vocab=49155,
                                     n_experts=32, top_k=8),
        "mamba2-780m": dict(n_layers=48, d_model=1536, vocab=50280,
                            ssm_state=128),
        "seamless-m4t-medium": dict(d_model=1024, n_heads=16, n_kv_heads=16,
                                    d_ff=4096, vocab=256206),
        "pixtral-12b": dict(n_layers=40, d_model=5120, n_heads=32,
                            n_kv_heads=8, d_ff=14336, vocab=131072),
    }
    for arch, fields in expect.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
