"""Batched control plane + sim core (DESIGN.md §3/§5):

* batched FleetController decisions == per-zone scalar PPA decisions on
  seeded multi-zone traces (per-target stacked mode and shared-model mode);
* heap-based dispatch reproduces the FROZEN seed engine's response-time
  distribution on seeded runs (parity oracle in
  benchmarks/seed_reference_sim.py);
* node-failure accounting regression: orphaned tasks are never re-dispatched
  onto sibling pods of the same failed node, and node CPU accounting stays
  consistent (the seed engine got both wrong).
"""
import numpy as np

from repro.cluster import AutoscalerBinding, ClusterSim, SimConfig, paper_topology
from repro.cluster.topology import Node, Topology
from repro.core import (PPA, PPAConfig, FleetController, TargetSpec,
                        ThresholdPolicy, Updater, UpdatePolicy,
                        MetricsHistory, LSTMForecaster, ARIMAD1Forecaster,
                        Snapshot)
from repro.core.hpa import HPA
from repro.sim import EventQueue, ServerPool
from repro.workloads import random_access


# ------------------------------------------------------------ helpers ------
# shared with the benchmark so tests and bench exercise identical traces
from benchmarks.bench_control_plane import _traces


def _fitted_lstm(series, window=4, epochs=25):
    m = LSTMForecaster(window=window, epochs=epochs, seed=0)
    m.fit(series, from_scratch=True)
    return m


# --------------------------------------- batched vs per-zone equivalence ---
def test_batched_equals_per_zone_ppa_stacked():
    """Per-target mode: Z independently trained LSTMs answered by one
    vmapped dispatch must give the same decisions as Z scalar PPAs."""
    Z = 3
    traces = _traces(Z)
    cfg = PPAConfig(threshold=100.0, stabilization_s=60.0)
    ppas = {z: PPA(cfg, _fitted_lstm(traces[z][:120]),
                   ThresholdPolicy(100.0, 1),
                   Updater(UpdatePolicy.NEVER), MetricsHistory())
            for z in traces}
    ctrl = FleetController(
        cfg, [TargetSpec(z, ThresholdPolicy(100.0, 1),
                         model=_fitted_lstm(traces[z][:120]))
              for z in traces])
    cur = {z: 2 for z in traces}
    for k in range(120, 160):
        t = 15.0 * (k - 119)
        for z in traces:
            snap = Snapshot(t, traces[z][k])
            ppas[z].observe(snap)
            ctrl.observe(z, snap)
        batched = ctrl.control_step(t, 16, dict(cur))
        for z in traces:
            single = ppas[z].control_step(t, 16, cur[z])
            assert batched[z].replicas == single.replicas, (t, z)
            assert batched[z].predicted == single.predicted, (t, z)
            if single.raw_prediction is None:
                assert batched[z].raw_prediction is None
            else:
                np.testing.assert_allclose(batched[z].raw_prediction,
                                           single.raw_prediction,
                                           rtol=1e-5, atol=1e-6)
            cur[z] = max(single.replicas, 1)


def test_batched_equals_per_zone_shared_model():
    """Shared-model mode: one forecaster, (Z, W, M) batch == Z loops."""
    Z = 4
    traces = _traces(Z, seed=3)
    model = _fitted_lstm(np.concatenate([traces[z][:80] for z in traces]))
    cfg = PPAConfig(threshold=100.0, stabilization_s=0.0)
    ctrl = FleetController(
        cfg, [TargetSpec(z, ThresholdPolicy(100.0, 1)) for z in traces],
        model=model)
    for k in range(100, 130):
        t = 15.0 * (k - 99)
        for z in traces:
            ctrl.observe(z, Snapshot(t, traces[z][k]))
        batched = ctrl.control_step(t, 32, 2)
        for z in traces:
            recent = np.stack(ctrl.targets[z].recent)
            if len(recent) < model.window + 1:
                assert batched[z].raw_prediction is None
                continue
            mean, _ = model.predict(recent)
            np.testing.assert_allclose(batched[z].raw_prediction, mean,
                                       rtol=1e-5, atol=1e-6)


def test_batched_arima_and_reactive_fallback():
    """Vectorised ARIMA batch matches scalar predict; an unfitted model
    falls back reactive for every target (Algorithm 1 robustness)."""
    traces = _traces(3, seed=5)
    model = ARIMAD1Forecaster()
    model.fit(np.concatenate([traces[z][:60] for z in traces]))
    recents = [traces[z][60:70] for z in traces]
    means, _ = model.predict_batch(recents)
    for i, z in enumerate(traces):
        np.testing.assert_allclose(means[i], model.predict(recents[i])[0],
                                   rtol=1e-6)
    ctrl = FleetController(PPAConfig(threshold=100.0),
                           [TargetSpec(z, ThresholdPolicy(100.0, 1))
                            for z in traces],
                           model=ARIMAD1Forecaster())   # never fitted
    for z in traces:
        ctrl.observe(z, Snapshot(0.0, traces[z][0]))
    res = ctrl.control_step(15.0, 8, 1)
    assert all(not r.predicted for r in res.values())


# ------------------------------------------- end-to-end batched sim run ----
def test_cluster_sim_runs_batched_controller():
    T = 10 * 60
    tasks = random_access(T, seed=11)
    zones = ("edge-0", "edge-1", "cloud")
    traces = {z: np.abs(_traces(1, seed=7)["z0"]) for z in zones}
    ctrl = FleetController(
        PPAConfig(threshold=350.0, stabilization_s=60.0),
        [TargetSpec(z, ThresholdPolicy(350.0, 1),
                    model=_fitted_lstm(traces[z][:60])) for z in zones],
        updater=Updater(UpdatePolicy.NEVER))
    sim = ClusterSim(paper_topology(), SimConfig(seed=0))
    sim.run(tasks, ctrl, T, initial_replicas=2)
    rt = sim.response_times()
    assert len(rt) > 0 and np.isfinite(rt).all()
    for z in zones:
        max_rep = sim.topo.max_replicas(z, sim.cfg.pod_cpu_m)
        assert all(1 <= n <= max_rep for _, n in sim.replica_log[z])
        assert len(ctrl.decisions(z)) == len(sim.replica_log[z])


# ------------------------------------------------ heap-dispatch parity -----
def test_heap_dispatch_parity_with_seed_engine():
    """Seeded runs on the heap-based core reproduce the frozen seed
    engine's response times exactly (same dispatch order, same RNG use)."""
    from benchmarks.seed_reference_sim import (
        AutoscalerBinding as SeedBinding, ClusterSim as SeedSim,
        SimConfig as SeedConfig, paper_topology as seed_topology)

    T = 15 * 60
    tasks = random_access(T, seed=5)

    def run(sim_cls, cfg_cls, bind_cls, topo_fn):
        sim = sim_cls(topo_fn(), cfg_cls(seed=0))
        binds = [bind_cls(z, HPA(350.0, min_replicas=2), "hpa", 2)
                 for z in ("edge-0", "edge-1", "cloud")]
        sim.run(tasks, binds, T, initial_replicas=2)
        return sim

    new = run(ClusterSim, SimConfig, AutoscalerBinding, paper_topology)
    old = run(SeedSim, SeedConfig, SeedBinding, seed_topology)
    rn = np.sort(new.response_times())
    ro = np.sort(old.response_times())
    assert len(rn) == len(ro)
    np.testing.assert_allclose(rn, ro, rtol=1e-9, atol=1e-12)
    for q in (50, 95):
        pn, po = np.percentile(rn, q), np.percentile(ro, q)
        assert abs(pn - po) <= 0.01 * po   # the ≥-bar: within 1 %
    for z in ("edge-0", "edge-1", "cloud"):
        assert new.replica_log[z] == old.replica_log[z]


# ------------------------------------------- node-failure accounting fix ---
def _failure_topology():
    # one big node (4 pods) + one small node (1 pod) in the same zone: the
    # seed bug re-dispatched big-node orphans onto sibling big-node pods
    return Topology([Node("big", "edge-0", 2000, 2048),
                     Node("small", "edge-0", 500, 512)])


def test_node_failure_no_redispatch_to_dying_sibling():
    cfg = SimConfig(seed=0, eigen_service_s=30.0)
    sim = ClusterSim(_failure_topology(), cfg)
    sim.scale_to("edge-0", 5, 0.0)
    sim.make_ready_now()
    big_pids = {p.pid for p in sim.pods if p.node.name == "big"}
    assert len(big_pids) == 4 and len(sim.pods) == 5
    # long tasks in flight on every pod when the big node dies
    from repro.cluster.simulator import Task
    for i in range(10):
        sim.dispatch(Task(float(i), "eigen", "edge-0", 0.0), float(i))
    t_fail = 15.0
    sim.inject_node_failure(t_fail, "big")
    sim._apply_events(t_fail)
    # every task still completing after the failure must be on the small
    # node's pod — never on any (dead) big-node pod
    for task in sim.completed:
        if task.completion > t_fail:
            assert task.pod_id not in big_pids, vars(task)
    assert any(t.redispatched for t in sim.completed)
    big = next(n for n in sim.topo.nodes if n.name == "big")
    assert big.alloc_m == 0
    small = next(n for n in sim.topo.nodes if n.name == "small")
    assert small.alloc_m == sum(p.cpu_m for p in sim.pods
                                if p.node is small and not p.dead
                                and not p.draining)


def test_node_failure_accounting_with_drained_pod():
    """A pod drained before the failure must not be double-credited back
    to the node's allocation when the node dies."""
    sim = ClusterSim(_failure_topology(), SimConfig(seed=0))
    sim.scale_to("edge-0", 5, 0.0)
    sim.make_ready_now()
    sim.scale_to("edge-0", 3, 1.0)          # drains 2 pods
    big = next(n for n in sim.topo.nodes if n.name == "big")
    alloc_before = big.alloc_m
    assert alloc_before == sum(p.cpu_m for p in sim.pods
                               if p.node is big and not p.draining)
    sim.inject_node_failure(5.0, "big")
    sim._apply_events(5.0)
    assert big.alloc_m == 0                  # not negative, not stale


# ---------------------------------------------- Pallas-backed batching -----
def test_predict_batch_pallas_matches_jnp():
    """The batched forecast paths ride the fused Pallas sequence kernel
    (interpret mode on CPU): shared-model batch and stacked batch must
    match the jnp scan."""
    from repro.core.forecaster import lstm_predict_batch_stacked
    rng = np.random.default_rng(0)
    recents = [np.abs(rng.normal(200, 40, (8, 5))) for _ in range(3)]

    def mk(seed):
        m = _fitted_lstm(np.abs(rng.normal(200, 40, (60, 5))), epochs=10)
        m.use_pallas = True
        return m

    m = mk(0)
    pallas_means, _ = m.predict_batch(recents)
    m.use_pallas = False
    ref = np.stack([m.predict(r)[0] for r in recents])
    np.testing.assert_allclose(pallas_means, ref, rtol=1e-4, atol=1e-5)

    models = [mk(i) for i in range(3)]
    stacked, _ = lstm_predict_batch_stacked(models, recents)
    for x in models:
        x.use_pallas = False
    ref = np.stack([mi.predict(r)[0] for mi, r in zip(models, recents)])
    np.testing.assert_allclose(stacked, ref, rtol=1e-4, atol=1e-5)


# ------------------------------------------------------- sim primitives ----
def test_event_queue_orders_and_drains():
    q = EventQueue()
    q.push(30.0, "b", x=1)
    q.push(10.0, "a", x=2)
    q.push(10.0, "c", x=3)
    assert len(q) == 3 and q.peek_t() == 10.0
    fired = q.pop_due(20.0)
    assert [k for _, k, _ in fired] == ["a", "c"]   # time, then insertion
    assert len(q) == 1
    assert q.pop_due(5.0) == []
    assert [k for _, k, _ in q.pop_due(100.0)] == ["b"]


class _Srv:
    def __init__(self):
        self.dead = False
        self.draining = False


def test_server_pool_selection_order():
    pool = ServerPool(two_phase=True)
    a, b, c = _Srv(), _Srv(), _Srv()
    pool.add(a, t=0.0, key=0.0, ready_at=0.0)    # ready, idle
    pool.add(b, t=0.0, key=0.0, ready_at=0.0)    # ready, idle
    pool.add(c, t=0.0, key=10.0, ready_at=10.0)  # pending
    # idle servers picked in creation order
    assert pool.select(1.0) is a
    pool.update(a, 5.0)                           # a busy until 5
    assert pool.select(1.0) is b
    pool.update(b, 3.0)                           # b busy until 3
    # both busy: earliest horizon wins; pending c is never preferred
    assert pool.select(2.0) is b
    pool.update(b, 7.0)
    # b drains -> a is the only ready server
    b.draining = True
    pool.invalidate(b)
    assert pool.select(2.0) is a
    pool.update(a, 9.0)
    a.dead = True
    pool.invalidate(a)
    # only the pending server remains -> fallback selects it
    s = pool.select(2.0)
    assert s is c
    pool.update(c, 12.0)
    assert pool.n_live == 1
    # after ready_at passes, c is promoted and served from the ready path
    assert pool.select(11.0) is c
    pool.update(c, 14.0)
