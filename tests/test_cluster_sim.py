"""Cluster-simulator invariants: task conservation, queueing discipline,
capacity limits, failure recovery and straggler behaviour."""
import math

from repro.cluster import AutoscalerBinding, ClusterSim, SimConfig, paper_topology
from repro.core.hpa import HPA
from repro.workloads import random_access


def _run(tasks, t_end, cfg=None, sim=None, min_replicas=2):
    sim = sim or ClusterSim(paper_topology(), cfg or SimConfig(seed=0))
    binds = [AutoscalerBinding(z, HPA(350.0, min_replicas=min_replicas),
                               "hpa", min_replicas)
             for z in ("edge-0", "edge-1", "cloud")]
    sim.run(tasks, binds, t_end, initial_replicas=min_replicas)
    return sim


def test_task_conservation():
    T = 30 * 60
    tasks = random_access(T, seed=5)
    sim = _run(tasks, T)
    dispatched = [t for t in sim.completed if math.isfinite(t.completion)]
    n_before_end = sum(1 for t in tasks if t[0] <= T - 15)
    assert len(dispatched) >= 0.98 * n_before_end


def test_response_at_least_service():
    T = 20 * 60
    sim = _run(random_access(T, seed=6), T)
    for t in sim.completed[:2000]:
        assert t.response >= t.service_s - 1e-9


def test_fifo_per_pod():
    T = 20 * 60
    sim = _run(random_access(T, seed=7), T)
    by_pod = {}
    for t in sim.completed:
        by_pod.setdefault(t.pod_id, []).append(t)
    for pod, ts in by_pod.items():
        ts = sorted(ts, key=lambda x: x.start)
        for a, b in zip(ts, ts[1:]):
            assert b.start >= a.completion - 1e-9  # single-server FIFO


def test_capacity_limits_respected():
    topo = paper_topology()
    sim = ClusterSim(topo, SimConfig(seed=0))
    max_rep = topo.max_replicas("edge-0", 500)
    assert max_rep == 8                       # 2 nodes x 2000m / 500m
    sim.scale_to("edge-0", 50, 0.0)
    assert len(sim.zone_pods("edge-0")) <= max_rep
    for n in topo.nodes:
        assert n.alloc_m <= n.cpu_m


def test_node_failure_redispatches_tasks():
    T = 10 * 60
    tasks = random_access(T, seed=8)
    sim = ClusterSim(paper_topology(), SimConfig(seed=0))
    sim.inject_node_failure(120.0, "edge0-0", recover_after=240.0)
    sim = _run(tasks, T, sim=sim)
    finite = all(math.isfinite(t.completion) for t in sim.completed)
    assert finite
    failed_node = next(n for n in sim.topo.nodes if n.name == "edge0-0")
    assert not failed_node.failed            # recovered


def test_straggler_slows_node():
    cfg = SimConfig(seed=0)
    sim = ClusterSim(paper_topology(), cfg)
    sim.inject_straggler(0.0, "edge0-0", factor=0.25, duration=600.0)
    sim._apply_events(1.0)
    node = next(n for n in sim.topo.nodes if n.name == "edge0-0")
    assert node.speed_factor == 0.25
    svc = sim._service_time("sort", node)
    assert svc > 2.5 * cfg.sort_service_s    # ~4x slower (mod jitter)
    sim._apply_events(601.0)
    assert node.speed_factor == 1.0


def test_rir_definition():
    """RIR_t = CPU_idle / CPU_requested in [0, 1] (paper Eq. 4)."""
    T = 20 * 60
    sim = _run(random_access(T, seed=9), T)
    for z in ("edge-0", "cloud"):
        vals = [v for _, v in sim.rir_log[z]]
        assert vals and all(0.0 <= v <= 1.0 for v in vals)
