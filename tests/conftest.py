# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 CPU device.
# Multi-device behaviour (dry-run, elastic) is tested via subprocesses.
import importlib.util
import sys
import types

import numpy as np
import pytest

# --------------------------------------------------------------------------
# Graceful degradation when `hypothesis` is absent (it is a test extra, not a
# runtime dep): the property-test modules import it unconditionally, which
# would otherwise be 5 collection errors.  Install a minimal stub whose
# @given-decorated tests skip at run time; plain tests in those modules still
# run.  With real hypothesis installed this block is inert.
if importlib.util.find_spec("hypothesis") is None:
    class _Strategy:
        def __init__(self, *args, **kwargs):
            pass

        def __or__(self, other):
            return self

        def __ror__(self, other):
            return self

        def map(self, fn):
            return self

        def filter(self, fn):
            return self

        def flatmap(self, fn):
            return self

        def __repr__(self):
            return "<hypothesis-stub strategy>"

    def _strategy(*args, **kwargs):
        return _Strategy()

    strategies = types.ModuleType("hypothesis.strategies")
    for _name in ("floats", "integers", "lists", "just", "booleans",
                  "sampled_from", "text", "tuples", "one_of", "none",
                  "data"):
        setattr(strategies, _name, _strategy)

    def given(*gargs, **gkwargs):
        def deco(fn):
            # zero-arg on purpose: pytest must not mistake the property
            # arguments for fixtures (no functools.wraps — __wrapped__
            # would expose the original signature)
            def skipper():
                pytest.skip("hypothesis not installed — property test "
                            "skipped (pip install .[test] to run)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    _stub = types.ModuleType("hypothesis")
    _stub.given = given
    _stub.settings = settings
    _stub.strategies = strategies
    _stub.assume = lambda *a, **k: True
    _stub.__stub__ = True
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = strategies


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def forced_devices_runner():
    """Run a python source under 8 forced host devices, in a subprocess
    (this process must keep seeing 1 device — jax pins its device count
    at first backend init, see the NOTE above).  Returns stdout; asserts
    a zero exit."""
    import subprocess

    from repro.core.device_plane import force_host_devices_env

    def run(source: str, timeout: float = 600.0) -> str:
        env = force_host_devices_env(8)
        env["PYTHONPATH"] = "src"
        r = subprocess.run([sys.executable, "-c", source],
                           capture_output=True, text=True,
                           timeout=timeout, env=env)
        assert r.returncode == 0, r.stderr[-3000:]
        return r.stdout
    return run
