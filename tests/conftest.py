# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 CPU device.
# Multi-device behaviour (dry-run, elastic) is tested via subprocesses.
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
