# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 CPU device.
# Multi-device behaviour (dry-run, elastic) is tested via subprocesses.
import importlib.util
import sys
import types

import numpy as np
import pytest

# --------------------------------------------------------------------------
# Graceful degradation when `hypothesis` is absent (it is a test extra, not a
# runtime dep): the property-test modules import it unconditionally, which
# would otherwise be 5 collection errors.  Install a minimal stub whose
# @given-decorated tests skip at run time; plain tests in those modules still
# run.  With real hypothesis installed this block is inert.
if importlib.util.find_spec("hypothesis") is None:
    class _Strategy:
        def __init__(self, *args, **kwargs):
            pass

        def __or__(self, other):
            return self

        def __ror__(self, other):
            return self

        def map(self, fn):
            return self

        def filter(self, fn):
            return self

        def flatmap(self, fn):
            return self

        def __repr__(self):
            return "<hypothesis-stub strategy>"

    def _strategy(*args, **kwargs):
        return _Strategy()

    strategies = types.ModuleType("hypothesis.strategies")
    for _name in ("floats", "integers", "lists", "just", "booleans",
                  "sampled_from", "text", "tuples", "one_of", "none"):
        setattr(strategies, _name, _strategy)

    def given(*gargs, **gkwargs):
        def deco(fn):
            # zero-arg on purpose: pytest must not mistake the property
            # arguments for fixtures (no functools.wraps — __wrapped__
            # would expose the original signature)
            def skipper():
                pytest.skip("hypothesis not installed — property test "
                            "skipped (pip install .[test] to run)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    _stub = types.ModuleType("hypothesis")
    _stub.given = given
    _stub.settings = settings
    _stub.strategies = strategies
    _stub.assume = lambda *a, **k: True
    _stub.__stub__ = True
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = strategies


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
