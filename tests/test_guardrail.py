"""Hybrid reactive-proactive guardrail layer (DESIGN.md §10,
docs/guardrail.md): the ``guard`` stage of the staged tick and the
SLA-constrained policy family.

The load-bearing properties:
* the vectorised shard guard (``_VecShard._guard_apply``) == the scalar
  ``Guardrail`` oracle, tick for tick, over random forecast-miss traces;
* the override fires iff the relative error leaves the configured band
  (up immediately, down only after ``down_ticks`` consecutive ticks);
* ``SLAPolicy.evaluate_batch`` == the scalar ``__call__`` elementwise
  over NaN/inf/zero p95 inputs;
* a guarded ``ShardedControlPlane`` == a guarded ``FleetController``
  decision for decision, and a quiet guard (huge band) is a no-op;
* the guarded device-mesh plane keeps sha256 bitwise invariance across
  D in {1, 2, 8} while the guard never fires.
"""
import json
import math

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (FleetController, GuardrailConfig, PPAConfig,
                        ShardedControlPlane, SLAPolicy, TargetSpec,
                        ThresholdPolicy)
from repro.core.control_plane import Guardrail, _VecShard
from repro.core.forecaster import LSTMForecaster, Scaler
from repro.core.metrics import N_METRICS
from repro.core.policies import policy_vectorizable
from repro.core.ppa import ScaleDownStabilizer


class _DummyModel:
    """decide() never touches the model — only its window matters."""
    window = 3
    is_bayesian = False

    def valid(self):
        return True


def _drive_pair(seed, band, down_ticks, headroom, n_ticks=24, Z=6,
                maxr=50):
    """Drive a guarded _VecShard and the scalar oracle chain (policy ->
    stabilizer -> Guardrail) over one random forecast-miss trace; assert
    equal replica decisions every tick."""
    cfg = PPAConfig(threshold=100.0, stabilization_s=60.0,
                    guard=GuardrailConfig(band=band, down_ticks=down_ticks,
                                          headroom=headroom))
    specs = [TargetSpec(f"t{i}", ThresholdPolicy(100.0)) for i in range(Z)]
    shard = _VecShard(cfg, specs, _DummyModel())
    oracles = [Guardrail(cfg.guard, s.policy) for s in specs]
    stabs = [ScaleDownStabilizer(cfg.stabilization_s) for _ in specs]
    rng = np.random.default_rng(seed)
    k = cfg.key_metric_idx
    cur = np.full(Z, 2)
    for tick in range(n_ticks):
        t = float((tick + 1) * 15.0)
        rows = rng.uniform(0.0, 1000.0, (Z, N_METRICS))
        shard.observe_batch(t, rows)
        means = np.full((Z, N_METRICS), np.nan)
        cand = rng.random(Z) < 0.8
        means[cand] = rng.uniform(0.0, 1000.0, (int(cand.sum()), N_METRICS))
        state = (shard.ring.copy(), shard.count.copy())
        rec = shard.decide(t, state, (means, None, False, cand), maxr,
                           {n: int(c) for n, c in zip(shard.names, cur)})
        for i, (s, g, stab) in enumerate(zip(specs, oracles, stabs)):
            realised = float(rows[i, k])
            predicted = bool(cand[i]) and math.isfinite(means[i, k])
            key = float(means[i, k]) if predicted else realised
            n = min(s.policy(key, {"current": int(cur[i])}), maxr)
            n = stab.apply(t, n, int(cur[i]), maxr)
            n = g.apply(realised, n, int(cur[i]), maxr)
            g.arm(key if predicted else float("nan"))
            assert n == rec[1][i], (tick, i, n, int(rec[1][i]))
        cur = rec[1].copy()
    up, down = shard.guard_counts()
    assert up == sum(g.up_fired for g in oracles)
    assert down == sum(g.down_fired for g in oracles)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000),
       band=st.floats(0.05, 0.6),
       down_ticks=st.integers(1, 4),
       headroom=st.floats(1.0, 1.5))
def test_guard_vectorized_matches_scalar_oracle(seed, band, down_ticks,
                                                headroom):
    _drive_pair(seed, band, down_ticks, headroom)


def test_guard_vectorized_matches_scalar_seeded():
    """Deterministic backstop (runs without hypothesis)."""
    _drive_pair(7, 0.2, 2, 1.1)
    _drive_pair(8, 0.4, 1, 1.0)


def test_guard_fires_iff_error_leaves_band():
    """Scalar semantics: no override while |err| <= band; immediate
    scale-up override past +band; scale-down override only after
    ``down_ticks`` CONSECUTIVE ticks past -band."""
    pol = ThresholdPolicy(100.0)
    g = Guardrail(GuardrailConfig(band=0.25, down_ticks=2), pol)

    # unarmed (no forecast yet): pass-through whatever the error would be
    assert g.apply(1000.0, 3, 3, 50) == 3 and g.up_fired == 0

    # in-band: realised within +-25% of the armed forecast -> pass-through
    g.arm(400.0)
    assert g.apply(480.0, 4, 4, 50) == 4            # err = +0.2
    assert (g.up_fired, g.down_fired) == (0, 0)

    # undershoot past the band: immediate reactive scale-up
    g.arm(400.0)
    assert g.apply(900.0, 4, 4, 50) == 9            # ceil(900/100) = 9
    assert (g.up_fired, g.down_fired) == (1, 0)

    # overshoot: first out-of-band tick holds, the second fires the trim
    g.arm(1000.0)
    assert g.apply(200.0, 10, 10, 50) == 10         # down_ct 1 of 2
    g.arm(1000.0)
    assert g.apply(200.0, 10, 10, 50) == 2          # fires: ceil(200/100)
    assert (g.up_fired, g.down_fired) == (1, 1)

    # an in-band tick resets the consecutive counter
    g.arm(1000.0)
    assert g.apply(200.0, 10, 10, 50) == 10         # down_ct 1 of 2
    g.arm(1000.0)
    assert g.apply(1000.0, 10, 10, 50) == 10        # in band: reset
    g.arm(1000.0)
    assert g.apply(200.0, 10, 10, 50) == 10         # back to 1 of 2
    assert (g.up_fired, g.down_fired) == (1, 1)

    # the guard never scales below the plan on the up path...
    g.arm(100.0)
    assert g.apply(200.0, 7, 2, 50) == 7            # max(plan 7, react 2)
    # ...and never above it on the down path, and respects max_replicas
    g.arm(100.0)
    assert g.apply(10_000.0, 3, 3, 5) == 5          # min(react 100, maxr)


# ------------------------------------------------------ SLA policy family --
def _p95_strategy():
    return st.lists(
        st.one_of(st.floats(0.0, 100.0),
                  st.sampled_from([float("nan"), float("inf"), 0.0, -1.0])),
        min_size=1, max_size=24)


@settings(max_examples=40, deadline=None)
@given(keys=_p95_strategy(),
       target=st.floats(0.1, 30.0),
       margin=st.floats(0.2, 0.9),
       cur=st.integers(1, 40),
       minr=st.integers(1, 5))
def test_sla_evaluate_batch_matches_scalar(keys, target, margin, cur, minr):
    pols = [SLAPolicy(target, min_replicas=minr, down_margin=margin)
            for _ in keys]
    karr = np.asarray(keys, np.float64)
    curs = np.full(len(keys), cur, np.int64)
    batch = SLAPolicy.evaluate_batch(SLAPolicy.stack(pols), karr, curs)
    scalar = [p(float(k), {"current": cur}) for p, k in zip(pols, keys)]
    np.testing.assert_array_equal(batch, np.asarray(scalar, np.int64))


def test_sla_policy_vectorizable_and_columnar():
    """SLAPolicy carries the stack/evaluate_batch protocol, so an all-SLA
    target set lands on the columnar shard, not the fallback."""
    assert policy_vectorizable(SLAPolicy(2.0))
    cfg = PPAConfig(key_metric_idx=1)
    specs = [TargetSpec(f"t{i}", SLAPolicy(2.0), model=m.model)
             for i, m in enumerate(_fab_targets(8))]
    plane = ShardedControlPlane(cfg, specs, n_shards=2)
    assert all(s.vectorized for s in plane.shards)
    plane.shutdown()


def test_sla_policy_semantics():
    p = SLAPolicy(target_p95=2.0, min_replicas=1, down_margin=0.5)
    assert p(0.0, {"current": 4}) == 4          # idle window: hold
    assert p(float("nan"), {"current": 4}) == 4
    assert p(4.0, {"current": 4}) == 8          # 2x over target
    assert p(1.5, {"current": 4}) == 4          # inside the hold band
    assert p(0.5, {"current": 4}) == 2          # ratio .25 / margin .5


# ----------------------------------------------- staged-plane integration --
def _fab_targets(Z, window=2, hidden=8, seed=3, policy=None):
    """Fabricated fitted per-target LSTMs (the bench/device-test pattern:
    shared params, per-target scaler stats — deterministic, fit-free)."""
    base = LSTMForecaster(window=window, hidden=hidden, seed=seed)
    rng = np.random.default_rng(seed + 100)
    means = rng.uniform(50.0, 300.0, (Z, N_METRICS))
    stds = 0.1 * means + 1.0
    out = []
    for i in range(Z):
        m = LSTMForecaster.__new__(LSTMForecaster)
        m.__dict__.update(base.__dict__)
        sc = Scaler()
        sc.mean, sc.std, sc.fitted = means[i], stds[i], True
        m.scaler = sc
        m._fitted, m._fit_count = True, 1
        m._valid_cache = (1, True)
        out.append(TargetSpec(
            f"t{i}", policy or ThresholdPolicy(100.0, 1), model=m))
    return out


def _drive(ctrl, rows_seq, cur=2, maxr=32):
    out = []
    t = 0.0
    for rows in rows_seq:
        t += 15.0
        if hasattr(ctrl, "observe_batch"):
            ctrl.observe_batch(t, rows)
        else:
            from repro.core import Snapshot
            for i, n in enumerate(ctrl.target_names):
                ctrl.observe(n, Snapshot(t, rows[i]))
        res = ctrl.control_step(t, maxr, cur)
        out.append(np.array([res[n].replicas for n in ctrl.target_names],
                            np.int64))
    if hasattr(ctrl, "shutdown"):
        ctrl.shutdown()
    return out


def test_guarded_plane_matches_guarded_controller():
    """ShardedControlPlane with the vectorised guard == FleetController
    with per-target scalar Guardrails, decision for decision, on a trace
    spiky enough to fire both override directions."""
    Z = 16
    cfg = PPAConfig(threshold=100.0, stabilization_s=60.0,
                    guard=GuardrailConfig(band=0.15, down_ticks=2))
    rng = np.random.default_rng(5)
    rows_seq = [rng.uniform(20.0, 800.0, (Z, N_METRICS)) for _ in range(10)]
    plane = ShardedControlPlane(cfg, _fab_targets(Z), n_shards=4)
    fc = FleetController(cfg, _fab_targets(Z))
    got = _drive(plane, rows_seq)
    want = _drive(fc, rows_seq)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


def test_quiet_guard_is_a_noop():
    """A guard whose band can never be left (band=inf) changes nothing:
    decisions match the unguarded plane bitwise and no override fires."""
    Z = 12
    rng = np.random.default_rng(9)
    rows_seq = [rng.uniform(20.0, 800.0, (Z, N_METRICS)) for _ in range(8)]
    base = PPAConfig(threshold=100.0, stabilization_s=60.0)
    quiet = PPAConfig(threshold=100.0, stabilization_s=60.0,
                      guard=GuardrailConfig(band=float("inf")))
    off = _drive(ShardedControlPlane(base, _fab_targets(Z), n_shards=3),
                 rows_seq)
    plane = ShardedControlPlane(quiet, _fab_targets(Z), n_shards=3)
    on = _drive(plane, rows_seq)
    for a, b in zip(off, on):
        np.testing.assert_array_equal(a, b)


def test_guard_stats_counts_overrides():
    """guard_stats() aggregates per-shard override counters; a plane whose
    forecasts are wildly wrong fires the up path."""
    Z = 8
    cfg = PPAConfig(threshold=100.0, stabilization_s=60.0,
                    guard=GuardrailConfig(band=0.1, down_ticks=1))
    plane = ShardedControlPlane(cfg, _fab_targets(Z), n_shards=2)
    rng = np.random.default_rng(2)
    # alternate low/high realised load: the forecast (trained on nothing,
    # scaler-anchored near the mean) misses the swings
    rows_seq = [np.full((Z, N_METRICS), 30.0 if i % 2 else 900.0)
                + rng.uniform(0, 1, (Z, N_METRICS)) for i in range(12)]
    _drive(plane, rows_seq)
    stats = plane.guard_stats()
    assert stats["up_overrides"] > 0
    assert set(stats) == {"up_overrides", "down_overrides"}


# -------------------------------------------- device-mesh D-invariance ----
_CHILD = r"""
import hashlib, json
import numpy as np
from repro.core import (GuardrailConfig, PPAConfig, ShardedControlPlane,
                        TargetSpec, ThresholdPolicy)
from repro.core.forecaster import LSTMForecaster, Scaler
from repro.core.metrics import N_METRICS

Z, W, H, S = 48, 2, 8, 4

def fab_targets():
    base = LSTMForecaster(window=W, hidden=H, seed=3)
    rng = np.random.default_rng(103)
    means = rng.uniform(50.0, 300.0, (Z, N_METRICS))
    stds = 0.1 * means + 1.0
    out = []
    for i in range(Z):
        m = LSTMForecaster.__new__(LSTMForecaster)
        m.__dict__.update(base.__dict__)
        sc = Scaler(); sc.mean, sc.std, sc.fitted = means[i], stds[i], True
        m.scaler = sc; m._fitted, m._fit_count = True, 1
        m._valid_cache = (1, True)
        out.append(TargetSpec(f"t{i}", ThresholdPolicy(100.0, 1), model=m))
    return out

rng = np.random.default_rng(11)
rows_seq = [rng.uniform(50.0, 300.0, (Z, N_METRICS)) for _ in range(6)]

def digest(D, coalesce):
    # quiet guard: the band can never be left, but the guard stage still
    # runs (arm + compare) every tick on every shard
    cfg = PPAConfig(threshold=100.0, stabilization_s=60.0,
                    guard=GuardrailConfig(band=1e18))
    plane = ShardedControlPlane(cfg, fab_targets(), n_shards=S,
                                coalesce_dispatch=coalesce, device_mesh=D)
    h = hashlib.sha256()
    t = 0.0
    for rows in rows_seq:
        t += 15.0
        plane.observe_batch(t, rows)
        res = plane.control_step(t, 32, 2)
        for n in res:
            r = res[n]
            h.update(np.int64(r.replicas).tobytes())
            h.update(np.float64(r.key_metric).tobytes())
            if r.raw_prediction is not None:
                h.update(np.asarray(r.raw_prediction).tobytes())
    up, down = plane.guard_stats()["up_overrides"], \
        plane.guard_stats()["down_overrides"]
    assert up == 0 and down == 0, (up, down)
    plane.shutdown()
    return h.hexdigest()

cells = {}
for D in (1, 2, 8):
    cells[f"D{D}-shardmap"] = digest(D, False)
    cells[f"D{D}-gang"] = digest(D, True)
print("DIGESTS=" + json.dumps(cells))
"""


def test_guarded_device_plane_bitwise_invariance(forced_devices_runner):
    """With the guard armed but quiet (band it can never leave), tick
    results stay sha256-bitwise identical across D in {1, 2, 8} on both
    dispatch modes: guard state is host-side per-shard arrays riding the
    shard views, so the mesh partition cannot change its numerics."""
    out = forced_devices_runner(_CHILD)
    line = next(ln for ln in out.splitlines() if ln.startswith("DIGESTS="))
    cells = json.loads(line[len("DIGESTS="):])
    assert len(cells) == 6
    assert len(set(cells.values())) == 1, f"digest mismatch: {cells}"


# ------------------------------------------------- latency-window feed ----
def test_fleet_publishes_window_p95():
    """ServingFleet metric slot 1 carries the window p95 of booked
    response times (0.0 for idle windows), equal between heap and batch
    modes and consistent with CompletionLog.window_percentile."""
    from repro.serving.fleet import FleetConfig, ServingFleet
    from repro.core.hpa import HPA
    from repro.workloads import poisson_arrivals

    arr = poisson_arrivals(3.0, 600.0, 15.0, seed=4)
    rng = np.random.default_rng(4)
    ntok = rng.integers(16, 64, len(arr.times))
    cfg = FleetConfig(total_chips=64, chips_per_replica=16, seed=0,
                      deadline_factor=1e9)
    pe = ServingFleet(cfg).run(
        [(float(t), int(n)) for t, n in zip(arr.times, ntok)],
        HPA(1e18, min_replicas=2), "hpa", 600.0, min_replicas=2)
    bt = ServingFleet(cfg, batch=True).run(
        (arr.times, ntok.astype(np.float64)),
        HPA(1e18, min_replicas=2), "hpa", 600.0, min_replicas=2)
    sp = np.stack([v for _, v in pe.samples])
    sb = np.stack([v for _, v in bt.samples])
    np.testing.assert_allclose(sp[:, 1], sb[:, 1], rtol=1e-12, atol=1e-12)
    assert (sp[:, 1] > 0).any()
    # cross-check one sampled window against the log's percentile helper
    log = bt.completed_log
    w = bt.core.exporter.window_index(15.0 * 3)
    rows = log.window_rows(w)
    if len(rows):
        resp = rows["completion"] - rows["arrival"]
        want = float(np.percentile(resp[np.isfinite(resp)], 95))
        assert abs(log.window_percentile(w, 95) - want) < 1e-12
