"""Property tests for Algorithm 1 (Evaluator) — the paper's five guarantees:
proactive, limitation-aware, robust, model-agnostic, confidence-considered."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.evaluator import Evaluator
from repro.core.forecaster import Forecaster
from repro.core.policies import ThresholdPolicy


class FixedModel(Forecaster):
    window = 1

    def __init__(self, value, std=None, bayes=False, broken=False,
                 invalid=False):
        self.value, self.std = value, std
        self.is_bayesian = bayes
        self.broken, self.invalid = broken, invalid

    def valid(self):
        return not self.invalid

    def predict(self, recent):
        if self.broken:
            raise IOError("model file corrupted")
        v = np.full(5, self.value)
        s = None if self.std is None else np.full(5, self.std)
        return v, s


metrics_rows = st.lists(
    st.lists(st.floats(0, 1e4, allow_nan=False), min_size=5, max_size=5),
    min_size=2, max_size=6)


@given(metrics_rows, st.floats(1.0, 1000.0), st.integers(1, 64),
       st.floats(0, 1e4))
@settings(max_examples=60, deadline=None)
def test_limitation_aware_never_exceeds_max(rows, thr, max_rep, pred):
    ev = Evaluator(ThresholdPolicy(thr), key_metric_idx=0)
    res = ev.evaluate(np.asarray(rows), FixedModel(pred), max_rep, 1)
    assert 1 <= res.replicas <= max_rep


@given(metrics_rows, st.floats(1.0, 1000.0))
@settings(max_examples=30, deadline=None)
def test_robust_fallback_on_broken_model(rows, thr):
    rows = np.asarray(rows)
    ev = Evaluator(ThresholdPolicy(thr), key_metric_idx=0)
    res_broken = ev.evaluate(rows, FixedModel(0, broken=True), 1000, 1)
    res_none = ev.evaluate(rows, None, 1000, 1)
    res_invalid = ev.evaluate(rows, FixedModel(0, invalid=True), 1000, 1)
    assert not res_broken.predicted and not res_invalid.predicted
    assert res_broken.replicas == res_none.replicas == res_invalid.replicas
    assert res_broken.key_metric == rows[-1, 0]


def test_proactive_uses_prediction():
    recent = np.array([[100.0, 0, 0, 0, 0], [100.0, 0, 0, 0, 0]])
    ev = Evaluator(ThresholdPolicy(100.0), key_metric_idx=0)
    res = ev.evaluate(recent, FixedModel(900.0), 100, 1)
    assert res.predicted and res.replicas == 9


@given(st.floats(0.0, 100.0), st.floats(0.1, 50.0))
@settings(max_examples=40, deadline=None)
def test_confidence_considered(conf_threshold, std):
    recent = np.array([[100.0, 0, 0, 0, 0], [100.0, 0, 0, 0, 0]])
    ev = Evaluator(ThresholdPolicy(100.0), 0,
                   confidence_threshold=conf_threshold)
    res = ev.evaluate(recent, FixedModel(900.0, std=std, bayes=True), 100, 1)
    if std <= conf_threshold:          # confident -> proactive
        assert res.replicas == 9 and res.confidence_ok
    else:                              # uncertain -> reactive on current
        assert res.replicas == 1 and not res.confidence_ok


def test_model_agnostic_duck_typing():
    """Anything with the protocol works (paper's helper-interface claim)."""
    class Weird:
        window = 1
        is_bayesian = False
        def valid(self): return True
        def predict(self, recent): return np.full(5, 350.0), None
    recent = np.array([[1.0, 0, 0, 0, 0], [1.0, 0, 0, 0, 0]])
    ev = Evaluator(ThresholdPolicy(100.0), 0)
    assert ev.evaluate(recent, Weird(), 100, 1).replicas == 4
