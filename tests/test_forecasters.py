"""Forecaster correctness: ARMA parameter recovery, LSTM learning, ensemble
confidence, serialization roundtrips, protocol compliance."""
import numpy as np
import pytest

from repro.core.forecaster import (ARMAForecaster, ARIMAD1Forecaster,
                                   EnsembleForecaster, LSTMForecaster, Scaler)
from repro.core.metrics import N_METRICS


def _ar1_series(phi=0.8, n=800, seed=0):
    rng = np.random.default_rng(seed)
    y = np.zeros(n)
    for t in range(1, n):
        y[t] = phi * y[t - 1] + rng.normal(0, 0.5)
    s = np.zeros((n, N_METRICS))
    for m in range(N_METRICS):
        s[:, m] = y * (m + 1) + 10 * m
    return s


def test_arma_recovers_ar_coefficient():
    s = _ar1_series(phi=0.8)
    m = ARMAForecaster(steps=600)
    m.fit(s)
    assert m.valid()
    # phi estimated on the standardized series should be near 0.8
    assert abs(m.theta[0, 1] - 0.8) < 0.15


def test_arma_one_step_beats_mean():
    s = _ar1_series(phi=0.9, n=1000)
    m = ARMAForecaster(steps=600)
    m.fit(s[:800])
    errs, base = [], []
    for i in range(800, 990):
        pred, _ = m.predict(s[i - 1:i + 1])
        errs.append((pred[0] - s[i + 1, 0]) ** 2)
        base.append((s[:800, 0].mean() - s[i + 1, 0]) ** 2)
    assert np.mean(errs) < 0.6 * np.mean(base)


def test_lstm_learns_structure():
    s = _ar1_series(phi=0.9, n=1000, seed=3)
    m = LSTMForecaster(window=4, epochs=150)
    m.fit(s[:800], from_scratch=True)
    errs, persist = [], []
    for i in range(804, 990):
        pred, _ = m.predict(s[i - 3:i + 1])
        errs.append((pred[0] - s[i + 1, 0]) ** 2)
        persist.append((s[i, 0] - s[i + 1, 0]) ** 2)
    assert np.mean(errs) < 1.2 * np.mean(persist)  # at least persistence-class


def test_ensemble_confidence_shrinks_with_agreement():
    s = _ar1_series(phi=0.5, n=400, seed=4)
    ens = EnsembleForecaster(n_members=3, window=2, epochs=60)
    ens.fit(s[:350], from_scratch=True)
    mean, std = ens.predict(s[348:352])
    assert ens.is_bayesian and std is not None and (std >= 0).all()


@pytest.mark.parametrize("cls,kw", [
    (LSTMForecaster, dict(window=2, epochs=30)),
    (ARMAForecaster, dict(steps=100)),
    (ARIMAD1Forecaster, dict(steps=100)),
])
def test_save_load_roundtrip(tmp_path, cls, kw):
    s = _ar1_series(n=300)
    m = cls(**kw)
    m.fit(s, from_scratch=True)
    p1, _ = m.predict(s[-4:])
    path = tmp_path / "model.pkl"
    m.save(path)
    m2 = cls(**kw)
    m2.load(path)
    p2, _ = m2.predict(s[-4:])
    np.testing.assert_allclose(p1, p2, rtol=1e-6)


def test_scaler_constant_column_safe():
    s = np.ones((100, N_METRICS))
    s[:, 0] = np.linspace(0, 100, 100)
    sc = Scaler()
    sc.fit(s)
    z = sc.transform(np.array([[50, 123456, 1, 1, 1]]))
    assert np.isfinite(z).all() and np.abs(z).max() <= 10.0


def test_protocol_window_shapes():
    """Model protocol §4.2.2: predict consumes the last `window` rows and
    emits all N_METRICS."""
    s = _ar1_series(n=200)
    m = LSTMForecaster(window=3, epochs=20)
    m.fit(s, from_scratch=True)
    pred, _ = m.predict(s[-3:])
    assert pred.shape == (N_METRICS,)
    pred2, _ = m.predict(s[-10:])   # extra history is fine; uses the tail
    np.testing.assert_allclose(pred, pred2)
