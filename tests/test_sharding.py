"""Sharding-rule properties: divisibility fallback, no double-use of a mesh
axis, multi-pod batch spanning; exercised on a subprocess-free 1-device mesh
plus pure-logic checks (hypothesis)."""
import numpy as np
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (DEFAULT_RULES, MULTIPOD_RULES,
                                        fsdp_rules, logical_to_pspec)


class FakeMesh:
    """Duck-typed mesh exposing only .shape (what logical_to_pspec needs)."""
    def __init__(self, **shape):
        self.shape = shape


MESH = FakeMesh(data=16, model=16)
MP = FakeMesh(pod=2, data=16, model=16)


def test_divisible_dims_shard():
    spec = logical_to_pspec(("batch", "seq"), (256, 4096), DEFAULT_RULES, MESH)
    assert spec == P("data", None)
    spec = logical_to_pspec(("fsdp", "mlp"), (2560, 6912), DEFAULT_RULES, MESH)
    assert spec == P(None, "model")


def test_indivisible_falls_back_to_replication():
    # 8 kv heads cannot shard over model=16
    spec = logical_to_pspec(("kv_heads", None), (8, 64), DEFAULT_RULES, MESH)
    assert spec == P(None, None)
    # 32 kv heads can
    spec = logical_to_pspec(("kv_heads", None), (32, 64), DEFAULT_RULES, MESH)
    assert spec == P("model", None)


def test_multipod_batch_spans_pod_and_data():
    spec = logical_to_pspec(("batch", "seq"), (256, 128), MULTIPOD_RULES, MP)
    assert spec == P(("pod", "data"), None)
    # batch=1 (long_500k) cannot shard at all
    spec = logical_to_pspec(("batch", "seq"), (1, 128), MULTIPOD_RULES, MP)
    assert spec == P(None, None)
    # batch=2 shards over pod only (longest divisible prefix)
    spec = logical_to_pspec(("batch", "seq"), (2, 128), MULTIPOD_RULES, MP)
    assert spec == P("pod", None)


def test_no_mesh_axis_used_twice():
    rules = fsdp_rules(DEFAULT_RULES)
    # batch takes 'data'; a second 'fsdp' dim in the same spec must not
    spec = logical_to_pspec(("batch", "fsdp"), (256, 2560), rules, MESH)
    flat = [a for part in spec if part for a in
            (part if isinstance(part, tuple) else (part,))]
    assert len(flat) == len(set(flat))


@given(st.integers(1, 4096), st.integers(1, 4096))
@settings(max_examples=80, deadline=None)
def test_spec_always_valid(d1, d2):
    spec = logical_to_pspec(("vocab", "mlp"), (d1, d2),
                            fsdp_rules(DEFAULT_RULES), MESH)
    for dim, part in zip((d1, d2), spec):
        if part is None:
            continue
        axes = part if isinstance(part, tuple) else (part,)
        size = int(np.prod([MESH.shape[a] for a in axes]))
        assert dim % size == 0


def test_vocab_padding_consistency():
    from repro.models.layers import padded_vocab, VOCAB_PAD
    for v in (32000, 49155, 128256, 256206, 92416):
        pv = padded_vocab(v)
        assert pv >= v and pv % VOCAB_PAD == 0 and pv - v < VOCAB_PAD
