"""Distributed extras: int8 compressed all-reduce (quantisation bounds,
error feedback), elastic re-mesh logic, and the multi-device paths via a
subprocess with placeholder devices."""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.distributed.collectives import dequantize_int8, quantize_int8


@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False, width=32),
                min_size=1, max_size=64))
@settings(max_examples=60, deadline=None)
def test_quantize_roundtrip_error_bound(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, scale = quantize_int8(x)
    back = dequantize_int8(q, scale)
    # error per element bounded by half a quantisation step
    assert float(jnp.max(jnp.abs(back - x))) <= float(scale) * 0.5 + 1e-6


def test_quantize_zero_safe():
    q, s = quantize_int8(jnp.zeros((8,)))
    assert float(jnp.abs(dequantize_int8(q, s)).max()) == 0.0


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distributed.collectives import make_compressed_grad_allreduce
from repro.distributed.elastic import shrink_mesh, reshard_tree, elastic_batch_size
from repro.distributed.sharding import DEFAULT_RULES

mesh = jax.make_mesh((4, 2), ("data", "model"))

# --- compressed all-reduce == plain mean within quantisation error
g = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, (4, 64)), jnp.float32)}
err = jax.tree.map(jnp.zeros_like, g)
allred = make_compressed_grad_allreduce(mesh)
out, new_err = allred(g, err)
# per-shard identical inputs -> mean == input
assert float(jnp.max(jnp.abs(out["w"] - g["w"]))) < 2e-2, "compressed mean off"

# --- elastic shrink: 4x2 -> 3x2, reshard a tree
small = shrink_mesh(mesh, "data", lost=1)
assert small.shape["data"] == 3 and small.shape["model"] == 2
tree = {"emb": np.ones((32, 16), np.float32)}
axes = {"emb": ("vocab", None)}
resharded = reshard_tree(tree, axes, small, DEFAULT_RULES)
assert resharded["emb"].shape == (32, 16)
assert elastic_batch_size(64, 4, 3) == 48
print("SUBPROC_OK")
"""


def test_multi_device_paths_subprocess():
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"}
    # forward backend selection — without e.g. JAX_PLATFORMS=cpu the child
    # probes for accelerator runtimes and can hang on TPU-toolchain hosts
    for k in ("JAX_PLATFORMS", "JAX_PLATFORM_NAME", "TPU_SKIP_MDS_QUERY"):
        if k in os.environ:
            env[k] = os.environ[k]
    r = subprocess.run([sys.executable, "-c", _SUBPROC],
                       capture_output=True, text=True, timeout=300, env=env)
    assert "SUBPROC_OK" in r.stdout, r.stderr[-2000:]


def test_data_pipeline_deterministic():
    from repro.data import SyntheticLMData
    d1 = SyntheticLMData(vocab=128, seq_len=16, global_batch=4, seed=7)
    d2 = SyntheticLMData(vocab=128, seq_len=16, global_batch=4, seed=7)
    b1, b2 = d1.batch_at(5), d2.batch_at(5)
    assert jnp.array_equal(b1["tokens"], b2["tokens"])
    b3 = d1.batch_at(6)
    assert not jnp.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    assert b1["tokens"].shape == b1["labels"].shape == (4, 16)
