"""Device-mesh control plane (DESIGN.md §9): the ``DevicePlaneEngine``
behind ``ShardedControlPlane(device_mesh=...)``.

In-process tests run on the single default CPU device (a 1-device mesh is
still the device-resident path); the cross-device-count bitwise-invariance
property needs real multiple devices, so it runs in a subprocess under
``--xla_force_host_platform_device_count=8`` via the session fixture."""
import json

import numpy as np
import pytest

from repro.core import (PPAConfig, ShardedControlPlane, Snapshot,
                        TargetSpec, ThresholdPolicy)
from repro.core.forecaster import LSTMForecaster, Scaler
from repro.core.metrics import N_METRICS

Z, W, H, S = 24, 2, 8, 4


def _fab_targets(Z=Z, window=W, hidden=H, seed=3):
    """Fabricated fitted per-target LSTMs (shared params, per-target
    scaler stats) — deterministic and fit-free, like the bench lane."""
    base = LSTMForecaster(window=window, hidden=hidden, seed=seed)
    rng = np.random.default_rng(seed + 100)
    means = rng.uniform(50.0, 300.0, (Z, N_METRICS))
    stds = 0.1 * means + 1.0
    out = []
    for i in range(Z):
        m = LSTMForecaster.__new__(LSTMForecaster)
        m.__dict__.update(base.__dict__)
        sc = Scaler()
        sc.mean, sc.std, sc.fitted = means[i], stds[i], True
        m.scaler = sc
        m._fitted, m._fit_count = True, 1
        m._valid_cache = (1, True)
        out.append(TargetSpec(f"t{i}", ThresholdPolicy(100.0, 1), model=m))
    return out


def _rows_seq(n=6, seed=11, z=Z):
    rng = np.random.default_rng(seed)
    return [rng.uniform(50.0, 300.0, (z, N_METRICS)) for _ in range(n)]


def _drive(plane, rows_seq, staged=False):
    """Fixed tick script; returns (replicas, key_metric, raw_means) per
    tick for every target in plane order."""
    out = []
    t = 0.0
    for rows in rows_seq:
        t += 15.0
        plane.observe_batch(t, rows)
        if staged:
            plane.begin_tick(t, 32, 2)
            res = plane.finish_tick()
        else:
            res = plane.control_step(t, 32, 2)
        names = list(res)
        out.append((
            np.array([res[n].replicas for n in names], np.int64),
            np.array([res[n].key_metric for n in names]),
            [res[n].raw_prediction for n in names],
        ))
    plane.shutdown()
    return out


def test_device_plane_matches_host_plane():
    """1-device mesh vs the host plane: identical decisions, predictions
    allclose (the engine computes f32 end-to-end, the host path f64)."""
    cfg = PPAConfig(threshold=100.0, stabilization_s=60.0)
    rows = _rows_seq()
    host = _drive(ShardedControlPlane(cfg, _fab_targets(), n_shards=S,
                                      coalesce_dispatch=False), rows)
    dev = _drive(ShardedControlPlane(cfg, _fab_targets(), n_shards=S,
                                     coalesce_dispatch=False,
                                     device_mesh=1), rows)
    for (hr, hk, hm), (dr, dk, dm) in zip(host, dev):
        np.testing.assert_array_equal(hr, dr)
        np.testing.assert_allclose(hk, dk, rtol=1e-4, atol=1e-3)
        for a, b in zip(hm, dm):
            assert (a is None) == (b is None)
            if a is not None:
                np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-3)


def test_device_plane_scalar_observe_matches_batch():
    """The scalar ``observe`` API (per-row device push) is bitwise equal
    to the one-shot ``observe_batch`` ring shift."""
    cfg = PPAConfig(threshold=100.0)
    rows = _rows_seq(4)

    def scalar_drive():
        plane = ShardedControlPlane(cfg, _fab_targets(), n_shards=S,
                                    coalesce_dispatch=False, device_mesh=1)
        out = []
        t = 0.0
        for r in rows:
            t += 15.0
            for i, n in enumerate(plane.target_names):
                plane.observe(n, Snapshot(t, r[i]))
            res = plane.control_step(t, 32, 2)
            out.append(np.array([res[n].replicas for n in res], np.int64))
        plane.shutdown()
        return out

    batch = _drive(ShardedControlPlane(cfg, _fab_targets(), n_shards=S,
                                       coalesce_dispatch=False,
                                       device_mesh=1), rows)
    for got, (want, _, _) in zip(scalar_drive(), batch):
        np.testing.assert_array_equal(got, want)


def test_device_plane_rejects_unstackable():
    """The device path only takes the homogeneous per-target stacked-LSTM
    shape: shared-model planes and scalar-only policies raise."""
    cfg = PPAConfig(threshold=100.0)
    shared = LSTMForecaster(window=W, hidden=H)
    with pytest.raises(ValueError, match="per-target"):
        ShardedControlPlane(
            cfg, [TargetSpec(f"t{i}", ThresholdPolicy(100.0, 1))
                  for i in range(4)],
            model=shared, n_shards=2, device_mesh=1)

    class Opaque:
        def __init__(self, inner):
            self._inner = inner

        def __call__(self, key, state=None):
            return self._inner(key, state)

    specs = _fab_targets(8)
    specs = [TargetSpec(sp.name, Opaque(sp.policy), model=sp.model)
             for sp in specs]
    with pytest.raises(ValueError, match="columnar"):
        ShardedControlPlane(cfg, specs, n_shards=2, device_mesh=1)


def test_device_plane_refit_epoch_invalidation():
    """Stacked weights re-upload iff the plane's refit epoch moves:
    mutated params are invisible until the commit bumps the epoch."""
    cfg = PPAConfig(threshold=100.0)
    rows = _rows_seq(5)
    plane = ShardedControlPlane(cfg, _fab_targets(), n_shards=S,
                                coalesce_dispatch=False, device_mesh=1)
    t = 0.0
    for r in rows[:3]:
        t += 15.0
        plane.observe_batch(t, r)
        res = plane.control_step(t, 32, 2)
    before = np.array([res[n].key_metric for n in res])

    # mutate every model's output head; same epoch -> device cache holds
    for m in plane._dev_models:
        m.params = dict(m.params)
        m.params["bo"] = m.params["bo"] + 1000.0
    t += 15.0
    plane.observe_batch(t, rows[3])
    res = plane.control_step(t, 32, 2)
    held = np.array([res[n].key_metric for n in res])
    assert np.all(np.isfinite(held))
    assert float(np.max(np.abs(held - before))) < 500.0  # no +1000 jump

    # commit: epoch bump -> refresh() restacks and the mutation lands
    plane._models_epoch += 1
    t += 15.0
    plane.observe_batch(t, rows[4])
    res = plane.control_step(t, 32, 2)
    applied = np.array([res[n].key_metric for n in res])
    assert np.all(applied > before + 100.0)
    plane.shutdown()


_CHILD = r"""
import hashlib, json
import numpy as np
from repro.core import PPAConfig, ShardedControlPlane
from repro.core.forecaster import LSTMForecaster, Scaler
from repro.core.metrics import N_METRICS

Z, W, H, S = 48, 2, 8, 4

def fab_targets():
    from repro.core import TargetSpec, ThresholdPolicy
    base = LSTMForecaster(window=W, hidden=H, seed=3)
    rng = np.random.default_rng(103)
    means = rng.uniform(50.0, 300.0, (Z, N_METRICS))
    stds = 0.1 * means + 1.0
    out = []
    for i in range(Z):
        m = LSTMForecaster.__new__(LSTMForecaster)
        m.__dict__.update(base.__dict__)
        sc = Scaler(); sc.mean, sc.std, sc.fitted = means[i], stds[i], True
        m.scaler = sc; m._fitted, m._fit_count = True, 1
        m._valid_cache = (1, True)
        out.append(TargetSpec(f"t{i}", ThresholdPolicy(100.0, 1), model=m))
    return out

rng = np.random.default_rng(11)
rows_seq = [rng.uniform(50.0, 300.0, (Z, N_METRICS)) for _ in range(6)]

def digest(D, coalesce, staged, explicit):
    assignment = ({f"t{i}": i * S // Z for i in range(Z)}
                  if explicit else None)
    plane = ShardedControlPlane(
        PPAConfig(threshold=100.0, stabilization_s=60.0), fab_targets(),
        n_shards=S, assignment=assignment, async_ticks=staged,
        coalesce_dispatch=coalesce, device_mesh=D)
    h = hashlib.sha256()
    t = 0.0
    for rows in rows_seq:
        t += 15.0
        plane.observe_batch(t, rows)
        if staged:
            plane.begin_tick(t, 32, 2)
            res = plane.finish_tick()
        else:
            res = plane.control_step(t, 32, 2)
        for n in res:
            r = res[n]
            h.update(np.int64(r.replicas).tobytes())
            h.update(np.float64(r.key_metric).tobytes())
            if r.raw_prediction is not None:
                h.update(np.asarray(r.raw_prediction).tobytes())
    plane.shutdown()
    return h.hexdigest()

cells = {}
for D in (1, 2, 8):
    cells[f"D{D}-shardmap-sync-block"] = digest(D, False, False, True)
    cells[f"D{D}-gang-sync-crc"] = digest(D, True, False, False)
    cells[f"D{D}-shardmap-async-crc"] = digest(D, False, True, False)
print("DIGESTS=" + json.dumps(cells))
"""


def test_device_count_bitwise_invariance(forced_devices_runner):
    """Tick results are bitwise identical across D in {1, 2, 8} devices,
    either dispatch mode (shard_map / gang GSPMD), sync and async staged
    ticks, any shard assignment: every per-target computation is
    row-independent, so the mesh partition cannot change numerics."""
    out = forced_devices_runner(_CHILD)
    line = next(ln for ln in out.splitlines() if ln.startswith("DIGESTS="))
    cells = json.loads(line[len("DIGESTS="):])
    assert len(cells) == 9
    vals = set(cells.values())
    assert len(vals) == 1, f"digest mismatch across cells: {cells}"
