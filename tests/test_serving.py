"""Serving engine + continuous batcher: slot isolation (the decisive
correctness property of continuous batching), recycling, throughput
accounting, and the PPA-scaled fleet."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models.registry import build_model
from repro.serving import ContinuousBatcher, DecodeEngine, Request
from repro.serving.fleet import FleetConfig, ServingFleet


def _engine(arch="h2o-danube-1.8b", slots=4, max_len=64, **kw):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), jnp.float32)
    return DecodeEngine(cfg, params, slots=slots, max_len=max_len, **kw)


def test_slot_isolation_greedy():
    """A request decoded alongside others yields the same greedy tokens as
    decoded alone — per-slot caches are independent."""
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 200, 12) for _ in range(3)]
    solo_outputs = []
    for p in prompts:
        e = _engine(slots=4)
        b = ContinuousBatcher(e)
        b.submit(Request(0, p, 6))
        done = b.drain()
        solo_outputs.append(done[0].output)
    e = _engine(slots=4)
    b = ContinuousBatcher(e)
    for i, p in enumerate(prompts):
        b.submit(Request(i, p, 6))
    done = {r.request_id: r.output for r in b.drain()}
    for i in range(3):
        assert done[i] == solo_outputs[i], i


def test_slot_recycling_serves_overflow():
    e = _engine(slots=2, max_len=48)
    b = ContinuousBatcher(e)
    rng = np.random.default_rng(1)
    for i in range(5):                       # 5 requests through 2 slots
        b.submit(Request(i, rng.integers(0, 200, 8), 4))
    done = b.drain()
    assert len(done) == 5
    assert all(len(r.output) == 5 for r in done)   # first + 4 decoded
    assert e.utilization() == 0.0


def test_fleet_ppa_scaling_and_failure():
    from repro.core import (PPA, PPAConfig, ThresholdPolicy, Updater,
                            UpdatePolicy, MetricsHistory, LSTMForecaster)
    cfg = FleetConfig(total_chips=128, chips_per_replica=16, seed=0)
    fleet = ServingFleet(cfg)
    rng = np.random.default_rng(2)
    T = 1800.0
    reqs = sorted((float(t), int(rng.integers(16, 64)))
                  for t in rng.uniform(0, T, 1200))
    ppa = PPA(PPAConfig(threshold=4.0, stabilization_s=60.0),
              LSTMForecaster(window=2, epochs=40),
              ThresholdPolicy(4.0, 1), Updater(UpdatePolicy.FINETUNE),
              MetricsHistory())
    fleet.inject_failure(600.0, rid=0)
    fleet.inject_straggler(900.0, rid=1, speed=0.2, duration=300.0)
    fleet.run(reqs, ppa, "ppa", T)
    rt = fleet.response_times()
    assert len(rt) == 1200                   # every request completes
    assert np.isfinite(rt).all()
    assert max(n for _, n in fleet.replica_log) <= fleet.max_replicas
    assert any(r.redispatched for r in fleet.completed)  # mitigation fired


def test_fleet_respects_chip_budget():
    fleet = ServingFleet(FleetConfig(total_chips=64, chips_per_replica=16))
    fleet.scale_to(100, 0.0)
    assert len(fleet.live_replicas()) <= 4
