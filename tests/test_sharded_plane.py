"""Sharded async control plane (DESIGN.md §5, "Sharded async"):

* seeded decision equivalence — ``ShardedControlPlane`` (any shard count,
  async ticks on or off, vectorised and fallback shards) produces identical
  decisions to the single ``FleetController`` on multi-zone traces;
* double-buffer semantics — observations arriving between ``begin_tick``
  and ``finish_tick`` belong to the next window and cannot change the
  in-flight tick's decisions;
* vmapped batch refits — ``lstm_fit_batch_stacked`` / ``update_batch``
  match Z sequential ``fit`` / ``update`` calls, and the plane's async
  refit never blocks the tick loop;
* satellites — per-target ``model_path`` templates, the ensemble's
  member-stacked single dispatch, the exporter's overlap-safe read API,
  and MultiFleetSim routing through the sharded plane.
"""
import copy

import numpy as np
import pytest

from repro.core import (FleetController, LSTMForecaster, MetricsHistory,
                        PPAConfig, ShardedControlPlane, Snapshot, TargetSpec,
                        ThresholdPolicy, TargetUtilizationPolicy, Updater,
                        UpdatePolicy)
from repro.core.control_plane import shard_assignment, stage_collect
from repro.core.forecaster import EnsembleForecaster, lstm_fit_batch_stacked

from benchmarks.bench_control_plane import _traces

Z = 4
CFG = PPAConfig(threshold=100.0, stabilization_s=60.0)


@pytest.fixture(scope="module")
def base():
    """Fitted per-target LSTMs + traces, deep-copied per test config so
    every controller sees identically initialised models."""
    traces = _traces(Z)
    models = {}
    for z in traces:
        m = LSTMForecaster(window=4, epochs=12, finetune_epochs=6, seed=0)
        m.fit(traces[z][:120], from_scratch=True)
        models[z] = m
    return traces, models


def _specs(models):
    return [TargetSpec(z, ThresholdPolicy(100.0, 1),
                       model=copy.deepcopy(models[z])) for z in models]


def _drive(traces, ref, plane, k0=120, k1=150, check=True):
    cur = {z: 2 for z in traces}
    for k in range(k0, k1):
        t = 15.0 * (k - k0 + 1)
        for z in traces:
            snap = Snapshot(t, traces[z][k])
            ref.observe(z, snap)
            plane.observe(z, snap)
        a = ref.control_step(t, 16, dict(cur))
        b = plane.control_step(t, 16, dict(cur))
        if check:
            for z in traces:
                assert a[z].replicas == b[z].replicas, (t, z)
                assert a[z].predicted == b[z].predicted, (t, z)
                assert a[z].confidence_ok == b[z].confidence_ok, (t, z)
                if a[z].raw_prediction is None:
                    assert b[z].raw_prediction is None
                else:
                    np.testing.assert_allclose(
                        a[z].raw_prediction, b[z].raw_prediction,
                        rtol=1e-5, atol=1e-6)
        for z in traces:
            cur[z] = max(a[z].replicas, 1)
        ref.maybe_update(t)
        plane.maybe_update(t)
    return cur


# ------------------------------------------------ decision equivalence ----
@pytest.mark.parametrize("n_shards", [1, 2, 3])
@pytest.mark.parametrize("async_ticks,coalesce", [
    (False, True),    # sync, fused gang dispatch (the default fast path)
    (True, True),     # async double-buffered, fused
    (False, False),   # per-shard (Z/S, W, M) dispatches (multi-device shape)
    (True, False),    # per-shard dispatches on the worker pool
])
def test_sharded_equals_single_per_target(base, n_shards, async_ticks,
                                          coalesce):
    """Per-target stacked mode: any S, async on/off, fused or per-shard
    dispatch — decisions identical."""
    traces, models = base
    ref = FleetController(CFG, _specs(models))
    plane = ShardedControlPlane(CFG, _specs(models), n_shards=n_shards,
                                async_ticks=async_ticks,
                                coalesce_dispatch=coalesce)
    _drive(traces, ref, plane)
    for z in traces:
        dref, dpl = ref.decisions(z), plane.decisions(z)
        assert len(dref) == len(dpl)
        assert [d.replicas for d in dref] == [d.replicas for d in dpl]
        assert len(ref.predictions(z)) == len(plane.predictions(z))
    plane.shutdown()


@pytest.mark.parametrize("coalesce", [True, False])
def test_sharded_use_pallas_equals_single(base, coalesce):
    """Plane-level ``use_pallas=True`` routes the stacked forecast
    dispatches (fused gang and per-shard alike) through the fused Pallas
    sequence kernel (interpret mode on CPU) — decisions identical to the
    XLA path's FleetController."""
    traces, models = base
    ref = FleetController(CFG, _specs(models))
    plane = ShardedControlPlane(CFG, _specs(models), n_shards=2,
                                coalesce_dispatch=coalesce,
                                use_pallas=True)
    _drive(traces, ref, plane, check=False)
    for z in traces:
        dref, dpl = ref.decisions(z), plane.decisions(z)
        assert [d.replicas for d in dref] == [d.replicas for d in dpl]
        assert [d.predicted for d in dref] == [d.predicted for d in dpl]
        pr, pp = ref.predictions(z), plane.predictions(z)
        assert len(pr) == len(pp)
        for (ta, a), (tb, b) in zip(pr, pp):
            assert ta == tb
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
    plane.shutdown()


def test_sharded_equals_single_shared_model(base):
    """Shared-model mode: one forecaster answering all targets per shard."""
    traces, _ = base
    model = LSTMForecaster(window=4, epochs=12, seed=0)
    model.fit(np.concatenate([traces[z][:100] for z in traces]),
              from_scratch=True)
    mk = lambda: copy.deepcopy(model)  # noqa: E731
    ref = FleetController(
        CFG, [TargetSpec(z, ThresholdPolicy(100.0, 1)) for z in traces],
        model=mk())
    plane = ShardedControlPlane(
        CFG, [TargetSpec(z, ThresholdPolicy(100.0, 1)) for z in traces],
        model=mk(), n_shards=2, async_ticks=True)
    _drive(traces, ref, plane)
    plane.shutdown()


def test_heterogeneous_policies_ride_columnar_and_match(base):
    """Mixed built-in policy types (Threshold + TargetUtilization) stay on
    the columnar shard via the per-policy dispatch table — and still match
    the scalar reference elementwise."""
    traces, models = base
    def specs():
        out = []
        for i, z in enumerate(models):
            pol = (TargetUtilizationPolicy(0.7, 1) if i == 0
                   else ThresholdPolicy(100.0, 1))
            out.append(TargetSpec(z, pol, model=copy.deepcopy(models[z])))
        return out
    ref = FleetController(CFG, specs())
    plane = ShardedControlPlane(CFG, specs(), n_shards=1)
    assert plane.shards[0].vectorized          # no _CtrlShard fallback
    assert len(plane.shards[0]._pol_groups) == 2
    _drive(traces, ref, plane)


class _OpaquePolicy:
    """A custom policy callable WITHOUT the stack/evaluate_batch protocol
    — the only policy shape left that forces the _CtrlShard fallback."""

    def __init__(self, threshold):
        self._inner = ThresholdPolicy(threshold, 1)

    def __call__(self, key_metric, state=None):
        return self._inner(key_metric, state)


def test_custom_policy_falls_back_and_matches(base):
    """A shard whose targets the columnar path can't take (an opaque
    custom callable) transparently falls back to an embedded
    FleetController — and still matches the reference."""
    traces, models = base
    def specs():
        out = []
        for i, z in enumerate(models):
            pol = (_OpaquePolicy(100.0) if i == 0
                   else ThresholdPolicy(100.0, 1))
            out.append(TargetSpec(z, pol, model=copy.deepcopy(models[z])))
        return out
    ref = FleetController(CFG, specs())
    plane = ShardedControlPlane(CFG, specs(), n_shards=1)
    assert not plane.shards[0].vectorized
    _drive(traces, ref, plane)


def test_async_tick_double_buffer_semantics(base):
    """Observations landing between begin_tick and finish_tick are next
    window's data: the in-flight tick decides on the snapshot."""
    traces, models = base
    ref = FleetController(CFG, _specs(models))
    plane = ShardedControlPlane(CFG, _specs(models), n_shards=2,
                                async_ticks=True)
    for k in range(120, 130):
        t = 15.0 * (k - 119)
        for z in traces:
            snap = Snapshot(t, traces[z][k])
            ref.observe(z, snap)
            plane.observe(z, snap)
    a = ref.control_step(150.0, 16, 2)
    plane.begin_tick(150.0, 16, 2)
    for z in traces:   # window-(t+1) metrics arrive while forecasting
        plane.observe(z, Snapshot(165.0, traces[z][135] * 7.0))
    b = plane.finish_tick()
    for z in traces:
        assert a[z].replicas == b[z].replicas
        np.testing.assert_allclose(a[z].raw_prediction,
                                   b[z].raw_prediction, rtol=1e-5)
    plane.shutdown()


def test_shard_assignment_deterministic_and_explicit():
    names = [f"z{i}" for i in range(12)]
    a1 = shard_assignment(names, 4)
    a2 = shard_assignment(names, 4)
    assert a1 == a2                       # crc32, not per-process hash()
    assert set(a1.values()) <= set(range(4))
    explicit = shard_assignment(names, 4, {"z0": 3, "z1": 3})
    assert explicit["z0"] == 3 and explicit["z1"] == 3
    with pytest.raises(ValueError):
        shard_assignment(names, 2, {"z0": 5})


# ------------------------------------------------- vmapped batch refits ---
def test_batch_refit_matches_sequential(base):
    """update_batch (one vmapped dispatch) == Z sequential update calls,
    for both FINETUNE and SCRATCH policies."""
    traces, models = base
    for policy in (UpdatePolicy.FINETUNE, UpdatePolicy.SCRATCH):
        seq = {z: copy.deepcopy(models[z]) for z in traces}
        bat = {z: copy.deepcopy(models[z]) for z in traces}
        hs = {z: MetricsHistory() for z in traces}
        hb = {z: MetricsHistory() for z in traces}
        for z in traces:
            for k in range(120, 150):
                hs[z].append(Snapshot(15.0 * k, traces[z][k]))
                hb[z].append(Snapshot(15.0 * k, traces[z][k]))
        us, ub = Updater(policy), Updater(policy)
        for z in traces:
            seq[z] = us.update(seq[z], hs[z], 1.0, target=z)
        ub.update_batch([bat[z] for z in traces],
                        [hb[z] for z in traces], 1.0, targets=list(traces))
        assert us.n_updates == ub.n_updates == Z
        for z in traces:
            assert len(hb[z]) == 0
            ps, _ = seq[z].predict(traces[z][150:160])
            pb, _ = bat[z].predict(traces[z][150:160])
            np.testing.assert_allclose(ps, pb, rtol=1e-5, atol=1e-6)


def test_batch_refit_ragged_pad_and_mask(base):
    """Unequal history lengths stay on the vmapped path (pad-and-mask):
    the batched refit matches Z sequential fits on the ragged histories."""
    traces, models = base
    seq = {z: copy.deepcopy(models[z]) for z in traces}
    bat = [copy.deepcopy(models[z]) for z in traces]
    hists = [MetricsHistory() for _ in bat]
    for i, z in enumerate(traces):
        for k in range(120, 140 + 4 * i):   # ragged lengths
            hists[i].append(Snapshot(15.0 * k, traces[z][k]))
    res = lstm_fit_batch_stacked(bat, [h.series() for h in hists])
    assert res is not None                  # no sequential fallback
    for i, z in enumerate(traces):
        seq[z].fit(hists[i].series())
        ps, _ = seq[z].predict(traces[z][150:160])
        pb, _ = bat[i].predict(traces[z][150:160])
        np.testing.assert_allclose(ps, pb, rtol=1e-5, atol=1e-6)
    u = Updater(UpdatePolicy.FINETUNE)
    u.update_batch(bat, hists, 1.0)
    assert u.n_updates == Z
    assert all(len(h) == 0 for h in hists)


def test_batch_refit_heterogeneous_archs_fall_back(base):
    """Architecturally heterogeneous model sets still can't stack ->
    sequential fallback with identical bookkeeping."""
    traces, models = base
    ms = [copy.deepcopy(models[z]) for z in traces]
    ms[0] = LSTMForecaster(window=4, hidden=13, epochs=12, seed=0)  # odd one
    hists = [MetricsHistory() for _ in ms]
    for i, z in enumerate(traces):
        for k in range(120, 140):
            hists[i].append(Snapshot(15.0 * k, traces[z][k]))
    assert lstm_fit_batch_stacked(ms, [h.series() for h in hists]) is None
    u = Updater(UpdatePolicy.FINETUNE)
    u.update_batch(ms, hists, 1.0)
    assert u.n_updates == Z
    assert all(len(h) == 0 for h in hists)


def test_plane_async_refit_off_critical_path(base):
    """The plane's maybe_update snapshots + submits the batch refit and
    returns without fitting; ticks keep running; poll/flush installs it."""
    traces, models = base
    cfg = PPAConfig(threshold=100.0, stabilization_s=60.0,
                    update_interval_s=120.0)
    plane = ShardedControlPlane(cfg, _specs(models), n_shards=2,
                                updater=Updater(UpdatePolicy.FINETUNE),
                                async_ticks=True)
    gen0 = [m._fit_count for m in plane._shard_of["z0"].target_models()]
    cur = 2
    for k in range(120, 145):
        t = 15.0 * (k - 119)
        for z in traces:
            plane.observe(z, Snapshot(t, traces[z][k]))
        res = plane.control_step(t, 16, cur)
        cur = max(res["z0"].replicas, 1)
        plane.maybe_update(t)
    assert plane.flush_updates() or plane.refit_log   # refit happened
    assert any(e["async"] and e["batched"] for e in plane.refit_log)
    gen1 = [m._fit_count for m in plane._shard_of["z0"].target_models()]
    assert all(g1 > g0 for g0, g1 in zip(gen0, gen1))
    # and the restacked params serve the next tick
    for z in traces:
        plane.observe(z, Snapshot(1e4, traces[z][150]))
    res = plane.control_step(1e4, 16, cur)
    assert any(res[z].predicted for z in traces)
    plane.shutdown()


def test_failed_async_refit_does_not_wedge_the_plane(base):
    """A refit whose compute raises on the worker is dropped: the plane
    keeps ticking and can refit again later (no sticky re-raise)."""
    traces, models = base
    cfg = PPAConfig(threshold=100.0, stabilization_s=60.0,
                    update_interval_s=120.0)
    plane = ShardedControlPlane(cfg, _specs(models), n_shards=2,
                                updater=Updater(UpdatePolicy.FINETUNE),
                                async_ticks=True)

    class _Boom:
        t = 0.0
        batched = False
        def compute(self):
            raise RuntimeError("corrupt history")
    plane._refit = (0.0, plane._pool.submit(_Boom().compute), _Boom())
    for k in range(120, 140):            # 20 rows: enough for min_records
        t = 15.0 * (k - 119)
        for z in traces:
            plane.observe(z, Snapshot(t, traces[z][k]))
        plane.control_step(t, 16, 2)     # must not raise, ever
    assert plane._refit is None
    assert any(e.get("failed") for e in plane.refit_log)
    # and a later healthy refit still goes through
    plane.maybe_update(1e4)
    assert plane.flush_updates()
    assert any(e.get("batched") for e in plane.refit_log)
    plane.shutdown()


def test_ctrl_shard_double_buffer_candidacy(base):
    """Fallback-shard async ticks judge forecast candidacy on the
    begin_tick snapshot: a target one row short at snapshot time stays
    reactive even if observations land mid-flight."""
    traces, models = base
    def specs():
        out = []
        for i, z in enumerate(models):
            pol = (_OpaquePolicy(100.0) if i == 0
                   else ThresholdPolicy(100.0, 1))
            out.append(TargetSpec(z, pol, model=copy.deepcopy(models[z])))
        return out
    plane = ShardedControlPlane(CFG, specs(), n_shards=1, async_ticks=True)
    assert not plane.shards[0].vectorized
    names = list(traces)
    window = models[names[0]].window
    # observe exactly `window` rows: one short of predictability
    for k in range(window):
        for z in names:
            plane.observe(z, Snapshot(15.0 * (k + 1), traces[z][120 + k]))
    plane.begin_tick(15.0 * (window + 1), 16, 2)
    for z in names:   # the row that would make targets predictable
        plane.observe(z, Snapshot(15.0 * (window + 1),
                                  traces[z][120 + window]))
    res = plane.finish_tick()
    assert all(not res[z].predicted for z in names)   # snapshot ruled
    # next tick (snapshot now has window+1 rows) does predict
    res2 = plane.control_step(15.0 * (window + 2), 16, 2)
    assert all(res2[z].predicted for z in names)
    plane.shutdown()


def test_maybe_update_deferred_while_tick_in_flight(base):
    """maybe_update between begin_tick and finish_tick must not mutate
    models under a live forecast — it defers to the next between-ticks
    call without consuming the update timer."""
    traces, models = base
    cfg = PPAConfig(threshold=100.0, stabilization_s=60.0,
                    update_interval_s=60.0)
    plane = ShardedControlPlane(cfg, _specs(models), n_shards=2,
                                updater=Updater(UpdatePolicy.FINETUNE),
                                async_ticks=True)
    for k in range(120, 140):
        t = 15.0 * (k - 119)
        for z in traces:
            plane.observe(z, Snapshot(t, traces[z][k]))
    plane.begin_tick(400.0, 16, 2)
    plane.maybe_update(400.0)            # mid-tick: must defer entirely
    assert not plane.refit_inflight and not plane.refit_log
    plane.finish_tick()
    plane.maybe_update(400.0)            # between ticks: goes through now
    assert plane.refit_inflight or plane.refit_log
    plane.flush_updates()
    plane.shutdown()


# ------------------------------------------------------------ satellites --
def test_updater_per_target_path_template(base, tmp_path):
    """A '{target}' template lifts the shared-model_path restriction: Z
    targets persist to Z files (and a literal shared path still raises)."""
    traces, models = base
    tmpl = str(tmp_path / "{target}.pkl")
    with pytest.raises(ValueError):
        FleetController(CFG, _specs(models),
                        updater=Updater(UpdatePolicy.FINETUNE,
                                        model_path=str(tmp_path / "one.pkl")))
    with pytest.raises(ValueError):
        ShardedControlPlane(CFG, _specs(models),
                            updater=Updater(UpdatePolicy.FINETUNE,
                                            model_path=str(tmp_path / "x")))
    ctrl = FleetController(CFG, _specs(models),
                           updater=Updater(UpdatePolicy.FINETUNE,
                                           model_path=tmpl))
    for z in traces:
        for k in range(120, 150):
            ctrl.observe(z, Snapshot(15.0 * k, traces[z][k]))
    ctrl.maybe_update(1e6)
    for z in traces:
        assert (tmp_path / f"{z}.pkl").exists()
        loaded = LSTMForecaster(window=4).load(tmp_path / f"{z}.pkl")
        want, _ = ctrl.model_for(z).predict(traces[z][150:160])
        got, _ = loaded.predict(traces[z][150:160])
        np.testing.assert_allclose(got, want, rtol=1e-6)
    # a template without a target name must fail loudly, not save to a
    # literal 'None' file
    with pytest.raises(ValueError):
        Updater(UpdatePolicy.FINETUNE, model_path=tmpl).path_for(None)


def test_ensemble_stacked_matches_member_loop(base):
    """EnsembleForecaster.predict_batch: E members x Z targets in one
    dispatch == the per-member loop."""
    traces, _ = base
    ens = EnsembleForecaster(n_members=3, window=4, epochs=8)
    ens.fit(traces["z0"][:100], from_scratch=True)
    recents = [traces[z][100:110] for z in traces]
    mean_one, std_one = ens.predict_batch(recents)
    member_means = np.stack([m.predict_batch(recents)[0]
                             for m in ens.members])
    np.testing.assert_allclose(mean_one, member_means.mean(0),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(std_one, member_means.std(0),
                               rtol=1e-4, atol=1e-6)
    # scalar path agrees too
    m0, s0 = ens.predict(recents[0])
    np.testing.assert_allclose(m0, mean_one[0], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(s0, std_one[0], rtol=1e-3, atol=1e-5)
    # pickle/deepcopy round-trip rebuilds members (no __init__ is run)
    import pickle
    for clone in (copy.deepcopy(ens), pickle.loads(pickle.dumps(ens))):
        mc, sc = clone.predict_batch(recents)
        np.testing.assert_allclose(mc, mean_one, rtol=1e-6)
        np.testing.assert_allclose(sc, std_one, rtol=1e-5, atol=1e-8)


def test_exporter_read_api_and_stage_collect(base):
    """WindowedExporter.latest / read_new are pure cursor reads; the
    collect stage feeds them into a controller without double-delivery."""
    from repro.sim.core import WindowedExporter
    traces, models = base
    exp = WindowedExporter(window_s=15.0, ma_windows=1)
    assert exp.latest("z0") is None
    assert exp.read_new("z0") == ([], 0)
    ctrl = FleetController(CFG, _specs(models))
    cursors = None
    seen = {z: 0 for z in traces}
    for k in range(120, 130):
        t = 15.0 * (k - 119)
        for z in traces:
            exp.push(z, t, traces[z][k])
        cursors = stage_collect(ctrl, exp, cursors=cursors)
        for z in traces:
            seen[z] += 1
            assert len(ctrl.targets[z].history) == seen[z]  # no replays
        tt, row = exp.latest("z0")
        assert tt == t
        np.testing.assert_allclose(row, traces["z0"][k])
    # an independent reader has its own cursor and sees everything
    rows, cur = exp.read_new("z0", 0)
    assert len(rows) == 10 and cur == 10


def test_multi_fleet_routes_through_sharded_plane():
    """MultiFleetSim with a ShardedControlPlane reproduces the
    FleetController allocation sequence exactly."""
    from repro.core import ARIMAD1Forecaster
    from repro.serving.fleet import FleetConfig
    from repro.serving.multi_fleet import FleetSpec, MultiFleetSim
    from repro.workloads import poisson_arrivals

    def build(ctrl_cls, **kw):
        specs = [FleetSpec(f"fleet-{i}",
                           FleetConfig(total_chips=96, chips_per_replica=16,
                                       seed=i)) for i in range(3)]
        ctrl = ctrl_cls(
            PPAConfig(threshold=560.0, stabilization_s=60.0),
            [TargetSpec(s.name, ThresholdPolicy(560.0, 1)) for s in specs],
            model=ARIMAD1Forecaster(), **kw)
        return MultiFleetSim(specs, 96, ctrl)

    rng = np.random.default_rng(0)
    requests = {}
    for i in range(3):
        arr = poisson_arrivals(2.0, 600.0, 15.0, seed=10 + i)
        ntok = rng.integers(16, 64, len(arr.times))
        requests[f"fleet-{i}"] = [(float(t), int(n))
                                  for t, n in zip(arr.times, ntok)]
    ref = build(FleetController).run(dict(requests), 600.0)
    shard = build(ShardedControlPlane, n_shards=2,
                  async_ticks=True).run(dict(requests), 600.0)
    assert ref.alloc_log == shard.alloc_log
    assert ref.peak_chips() == shard.peak_chips()
    np.testing.assert_allclose(np.sort(ref.response_times()),
                               np.sort(shard.response_times()))
