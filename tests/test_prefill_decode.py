"""Prefill <-> decode consistency: decoding one token after a prefill must
equal teacher-forcing the extended sequence (exact for dense/ssm/hybrid;
MoE requires full capacity to avoid drop differences)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import list_archs, smoke_config
from repro.models.registry import build_model


@pytest.mark.parametrize("arch", [a for a in list_archs()
                                  if smoke_config(a).family != "encdec"])
def test_decode_matches_prefill(arch):
    cfg = smoke_config(arch)
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=8.0)   # no capacity drops
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key, jnp.float32)
    B, S = 2, 32
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    extra = None
    if cfg.frontend == "vision":
        extra = jax.random.normal(key, (B, cfg.frontend_seq, cfg.d_model))
    _, cache = model.prefill(params, toks, max_len=S + 8, extra_embeds=extra)
    nxt = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab)
    lg_dec, _ = model.decode_step(params, cache, nxt)
    toks2 = jnp.concatenate([toks, nxt], axis=1)
    lg_full, _ = model.prefill(params, toks2, max_len=S + 9, extra_embeds=extra)
    err = float(jnp.max(jnp.abs(lg_dec[:, -1] - lg_full[:, -1])))
    assert err < 2e-2, (arch, err)


def test_encdec_decode_runs():
    cfg = smoke_config("seamless-m4t-medium")
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key, jnp.float32)
    B, S = 2, 16
    frames = jax.random.normal(key, (B, S, cfg.d_model))
    enc = model.encode(params, frames)
    cache = model.init_dec_cache(params, enc, B, max_len=S + 8)
    toks = jax.random.randint(key, (B, 1), 0, cfg.vocab)
    for _ in range(3):
        logits, cache = model.decode_step(params, cache, toks)
        assert bool(jnp.isfinite(logits).all())
        toks = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)


def test_multi_step_decode_consistency():
    """Greedy-decode 4 tokens stepwise == teacher-forced logits path."""
    cfg = smoke_config("h2o-danube-1.8b")
    model = build_model(cfg)
    key = jax.random.PRNGKey(3)
    params = model.init(key, jnp.float32)
    B, S, T = 1, 16, 4
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    _, cache = model.prefill(params, toks, max_len=S + T + 1)
    seq = toks
    cur = jax.random.randint(jax.random.PRNGKey(9), (B, 1), 0, cfg.vocab)
    for _ in range(T):
        lg, cache = model.decode_step(params, cache, cur)
        seq = jnp.concatenate([seq, cur], axis=1)
        cur = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
    lg_tf, _ = model.prefill(params, seq, max_len=seq.shape[1] + 1)
    nxt_tf = jnp.argmax(lg_tf[:, -1], -1)
    assert jnp.array_equal(cur[:, 0], nxt_tf)
