"""Columnar-everywhere layer (DESIGN.md §6): vectorised policy engine,
bulk water-filling placement, windowed serving drain, pad-and-mask ragged
refits, and the streaming CompletionLog.

The load-bearing properties:
* ``Policy.evaluate_batch`` == the scalar ``__call__``, elementwise, over
  NaN/inf/negative keys and any current-replica state;
* ``waterfill_placement`` == the sequential first-argmax greedy, placement
  for placement (bitwise on integral capacities);
* batch-mode ``ServingFleet`` == per-event dispatch, completion for
  completion (bitwise while the deadline re-dispatch rule is quiet);
* streaming ``CompletionLog`` stats == full-log stats with bounded memory.
"""
import copy
import math

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.hpa import HPA
from repro.core.policies import (TargetUtilizationPolicy, ThresholdPolicy,
                                 policy_vectorizable)
from repro.serving.fleet import FleetConfig, ServingFleet
from repro.sim import CompletionLog, waterfill_placement
from repro.workloads import poisson_arrivals


# ----------------------------------------------- policy evaluate_batch ----
def _keys_strategy():
    return st.lists(
        st.one_of(st.floats(-1e4, 1e6),
                  st.sampled_from([float("nan"), float("inf"),
                                   float("-inf"), 0.0, -5.0])),
        min_size=1, max_size=24)


@settings(max_examples=40, deadline=None)
@given(keys=_keys_strategy(),
       thr=st.floats(0.5, 1e4),
       minr=st.integers(1, 5),
       tol=st.floats(0.0, 0.5),
       cur=st.integers(0, 40))
def test_threshold_policy_batch_equals_scalar(keys, thr, minr, tol, cur):
    pols = [ThresholdPolicy(thr, minr, tol) for _ in keys]
    key = np.asarray(keys, np.float64)
    curs = np.full(len(keys), cur, np.int64)
    batch = ThresholdPolicy.evaluate_batch(ThresholdPolicy.stack(pols),
                                           key, curs)
    scalar = [p(k, {"current": cur}) for p, k in zip(pols, keys)]
    assert batch.tolist() == scalar


@settings(max_examples=40, deadline=None)
@given(keys=_keys_strategy(),
       target=st.floats(0.05, 5.0),
       minr=st.integers(1, 5),
       cur=st.integers(0, 40))
def test_target_util_policy_batch_equals_scalar(keys, target, minr, cur):
    pols = [TargetUtilizationPolicy(target, minr) for _ in keys]
    key = np.asarray(keys, np.float64)
    curs = np.full(len(keys), cur, np.int64)
    batch = TargetUtilizationPolicy.evaluate_batch(
        TargetUtilizationPolicy.stack(pols), key, curs)
    scalar = [p(k, {"current": cur}) for p, k in zip(pols, keys)]
    assert batch.tolist() == scalar


def test_policy_batch_mixed_params_deterministic():
    """Per-target parameters (the dispatch table stacks them) — a seeded
    backstop that runs without hypothesis."""
    rng = np.random.default_rng(0)
    pols = [ThresholdPolicy(float(t), int(m), float(tl))
            for t, m, tl in zip(rng.uniform(1, 500, 64),
                                rng.integers(1, 4, 64),
                                rng.uniform(0, 0.3, 64))]
    key = rng.uniform(-100, 2000, 64)
    key[::7] = np.nan
    cur = rng.integers(0, 30, 64)
    batch = ThresholdPolicy.evaluate_batch(ThresholdPolicy.stack(pols),
                                           key, cur)
    scalar = [p(float(k), {"current": int(c)})
              for p, k, c in zip(pols, key, cur)]
    assert batch.tolist() == scalar


def test_policy_vectorizable_protocol():
    assert policy_vectorizable(ThresholdPolicy(1.0))
    assert policy_vectorizable(TargetUtilizationPolicy(0.7))
    assert not policy_vectorizable(lambda k, s=None: 1)

    class Sub(ThresholdPolicy):      # overridden scalar, inherited batch
        def __call__(self, k, state=None):
            return 99
    assert not policy_vectorizable(Sub(1.0))


# ------------------------------------------------- water-filling plan -----
def _seq_greedy(free, unit, k):
    free = np.asarray(free, np.float64).copy()
    seq = []
    for _ in range(k):
        if free.size == 0:
            break
        ni = int(np.argmax(free))
        if free[ni] < unit:
            break
        seq.append(ni)
        free[ni] -= unit
    return np.asarray(seq, np.int64), free


@settings(max_examples=60, deadline=None)
@given(caps=st.lists(st.integers(0, 40), min_size=1, max_size=30),
       k=st.integers(0, 600),
       unit=st.sampled_from([100, 250, 500]),
       residue=st.integers(0, 99))
def test_waterfill_matches_sequential_greedy(caps, k, unit, residue):
    """Integral capacities (the cluster's millicores): bitwise placement
    parity with the first-argmax sequential loop, including the exhausted
    tail and tie-breaking."""
    free = np.asarray(caps, np.float64) * unit + residue
    seq_ref, free_ref = _seq_greedy(free, unit, k)
    seq, counts = waterfill_placement(free, unit, k)
    np.testing.assert_array_equal(seq, seq_ref)
    np.testing.assert_array_equal(free - counts * unit, free_ref)


@settings(max_examples=60, deadline=None)
@given(caps=st.lists(st.integers(0, 60), min_size=1, max_size=40),
       k=st.integers(0, 800),
       unit=st.sampled_from([1, 100, 250, 500]),
       residue=st.integers(0, 499))
def test_waterfill_level_search_matches_lexsort(caps, k, unit, residue):
    """The O(nodes log capacity) water-level binary search is bitwise
    identical to the slot-enumeration lexsort plan on integral
    capacities (sequence AND counts)."""
    from repro.sim.core import _waterfill_lexsort
    free = np.asarray(caps, np.float64) * unit + (residue % unit
                                                  if unit > 1 else 0)
    u = np.maximum(np.floor(free / unit), 0.0).astype(np.int64)
    k_eff = min(int(k), int(u.sum()))
    seq, counts = waterfill_placement(free, unit, k)
    assert len(seq) == k_eff
    if k_eff:
        seq_ref, counts_ref = _waterfill_lexsort(free, unit, u, k_eff)
        np.testing.assert_array_equal(seq, seq_ref)
        np.testing.assert_array_equal(counts, counts_ref)


def test_waterfill_float_capacities_fall_back_exactly():
    """Non-integral capacities keep the lexsort path — still exactly the
    sequential greedy."""
    free = np.array([1234.5, 777.25, 500.0, 1500.75])
    seq_ref, free_ref = _seq_greedy(free, 500.0, 5)
    seq, counts = waterfill_placement(free, 500.0, 5)
    np.testing.assert_array_equal(seq, seq_ref)
    np.testing.assert_array_equal(free - counts * 500.0, free_ref)


def test_waterfill_cluster_scale_to_parity():
    """End to end in the sim: bulk ``_vec_scale_to`` places exactly like a
    sequential ``_vec_schedule_pod`` loop (pids, nodes, free arrays)."""
    from repro.cluster import ClusterSim, SimConfig
    from repro.cluster.topology import fleet_topology

    arr = poisson_arrivals(1.0, 30.0, 15.0, zone="z", seed=0)

    def mk():
        s = ClusterSim(fleet_topology(500, zones=["z"], pods_per_node=16),
                       SimConfig(seed=0))
        s._vec_init(arr)
        s._vec_zone("z")
        return s

    capacity = 32 * 16                    # ceil(500/16) nodes x 16 pods
    for k in (1, 7, 160, 500, 800):       # incl. beyond-capacity
        bulk, seq = mk(), mk()
        bulk._vec_scale_to("z", k, 5.0)
        for _ in range(k):
            if seq._vec_schedule_pod("z", 5.0) is None:
                break
        n = seq._apools["z"].n
        assert bulk._apools["z"].n == n == min(k, capacity)
        np.testing.assert_array_equal(bulk._slot_node["z"][:n],
                                      seq._slot_node["z"][:n])
        np.testing.assert_array_equal(bulk._slot_pid["z"][:n],
                                      seq._slot_pid["z"][:n])
        np.testing.assert_array_equal(bulk._znode_free["z"],
                                      seq._znode_free["z"])
        np.testing.assert_array_equal(bulk._znode_alloc["z"],
                                      seq._znode_alloc["z"])
        # Node objects are lazy views over the columnar alloc array;
        # any pod-materialising accessor syncs them
        bulk.zone_pods("z")
        assert ([x.alloc_m for x in bulk._znodes["z"]]
                == [int(a) for a in bulk._znode_alloc["z"]])


# ------------------------------------------------ serving drain parity ----
def _run_pair(rate, t_end, minr, thr, deadline_factor=3.0, seed=7,
              chips=128):
    rng = np.random.default_rng(seed)
    arr = poisson_arrivals(rate, t_end, 15.0, seed=seed)
    ntok = rng.integers(16, 64, len(arr.times))
    reqs = [(float(t), int(n)) for t, n in zip(arr.times, ntok)]
    cfg = FleetConfig(total_chips=chips, chips_per_replica=16, seed=0,
                      deadline_factor=deadline_factor)
    pe = ServingFleet(cfg).run(list(reqs), HPA(thr, min_replicas=minr),
                               "hpa", t_end, min_replicas=minr)
    bt = ServingFleet(cfg, batch=True).run(
        (arr.times, ntok.astype(np.float64)), HPA(thr, min_replicas=minr),
        "hpa", t_end, min_replicas=minr)
    return pe, bt


def _assert_bitwise(pe, bt):
    cv = bt.completed_log.view()
    assert len(cv) == len(pe.completed)
    np.testing.assert_array_equal(
        cv["completion"], [r.completion for r in pe.completed])
    np.testing.assert_array_equal(
        cv["arrival"], [r.arrival for r in pe.completed])
    assert pe.replica_log == bt.replica_log
    sv = np.stack([v for _, v in pe.samples])
    sb = np.stack([v for _, v in bt.samples])
    np.testing.assert_allclose(sv, sb, rtol=1e-12, atol=1e-12)
    assert abs(pe.idle_fraction() - bt.idle_fraction()) < 1e-12


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000),
       load=st.floats(0.2, 2.0),      # > 1.0 exercises the busy fallback
       minr=st.integers(1, 4))
def test_serving_drain_identical_completion_sequence(seed, load, minr):
    """Windowed drain == per-event dispatch, completion for completion
    (deadline rule quiet -> bitwise even under overload)."""
    rate = load * minr * 8 / 1.9       # ~load x slot capacity
    pe, bt = _run_pair(rate, 450.0, minr, 1e18, deadline_factor=1e9,
                       seed=seed)
    _assert_bitwise(pe, bt)


def test_serving_drain_identical_seeded():
    """Deterministic backstop (runs without hypothesis): under load with
    HPA scaling, and heavy overload on a fixed fleet."""
    pe, bt = _run_pair(2.0, 900.0, 2, 560.0)
    assert not bt.completed_log.view()["redispatched"].any()
    _assert_bitwise(pe, bt)
    pe, bt = _run_pair(12.0, 300.0, 2, 1e18, deadline_factor=1e9)
    _assert_bitwise(pe, bt)


def test_serving_drain_redispatch_statistical():
    """With the deadline rule firing, attribution (and thus completions)
    may differ — the drain must stay statistically equivalent."""
    pe, bt = _run_pair(12.0, 300.0, 2, 1e18)
    assert bt.completed_log.view()["redispatched"].any()
    rp, rb = pe.response_times(), bt.response_times()
    assert len(rp) == len(rb)
    for q in (50, 95):
        assert (abs(np.percentile(rp, q) - np.percentile(rb, q))
                <= 0.01 * np.percentile(rp, q))


def test_serving_batch_failure_and_straggler():
    """Batch-mode event handling: replica failure re-dispatches in-flight
    requests off the dead replica; stragglers slow service and trigger
    deadline re-dispatches."""
    rng = np.random.default_rng(1)
    arr = poisson_arrivals(3.0, 600.0, 15.0, seed=11)
    ntok = rng.integers(16, 64, len(arr.times))
    bt = ServingFleet(FleetConfig(total_chips=128, chips_per_replica=16),
                      batch=True)
    bt.inject_failure(120.0, 0)
    bt.inject_straggler(200.0, 1, speed=0.2, duration=120.0)
    bt.run((arr.times, ntok.astype(np.float64)),
           HPA(560.0, min_replicas=3), "hpa", 600.0, min_replicas=3)
    rows = bt.completed_log.view()
    assert np.isfinite(rows["completion"]).all()
    assert rows["redispatched"].any()
    assert bt._rep_dead[0]
    # requests re-dispatched off the failure never land back on rid 0
    requeued = rows[rows["redispatched"] & (rows["start"] >= 120.0)]
    assert not (requeued["server"] == 0).any()


def test_multi_fleet_batch_mode_matches_per_event():
    """MultiFleetSim(batch=True): same arbiter allocation sequence and the
    same response-time distribution as the per-event fleets."""
    from repro.core import (ARIMAD1Forecaster, FleetController, PPAConfig,
                            TargetSpec, ThresholdPolicy)
    from repro.serving.multi_fleet import FleetSpec, MultiFleetSim

    def build(batch):
        specs = [FleetSpec(f"fleet-{i}",
                           FleetConfig(total_chips=96, chips_per_replica=16,
                                       seed=i)) for i in range(3)]
        ctrl = FleetController(
            PPAConfig(threshold=560.0, stabilization_s=60.0),
            [TargetSpec(s.name, ThresholdPolicy(560.0, 1)) for s in specs],
            model=ARIMAD1Forecaster())
        return MultiFleetSim(specs, 96, ctrl, batch=batch)

    rng = np.random.default_rng(0)
    requests = {}
    for i in range(3):
        arr = poisson_arrivals(2.0, 600.0, 15.0, seed=10 + i)
        ntok = rng.integers(16, 64, len(arr.times))
        requests[f"fleet-{i}"] = [(float(t), int(n))
                                  for t, n in zip(arr.times, ntok)]
    ref = build(False).run(dict(requests), 600.0)
    bat = build(True).run(dict(requests), 600.0)
    assert ref.alloc_log == bat.alloc_log
    assert ref.peak_chips() == bat.peak_chips()
    np.testing.assert_array_equal(np.sort(ref.response_times()),
                                  np.sort(bat.response_times()))


# ------------------------------------------- streaming CompletionLog ------
def _fill_log(log, n_windows=20, per_window=50, seed=0):
    rng = np.random.default_rng(seed)
    t = 0.0
    for w in range(n_windows):
        arr = np.sort(rng.uniform(t, t + 15.0, per_window))
        svc = rng.uniform(0.1, 5.0, per_window)
        log.append_batch(arr, arr, arr + svc, svc,
                         rng.integers(0, 8, per_window),
                         kind=rng.integers(0, 2, per_window).astype(np.int16))
        log.seal_window()
        t += 15.0
    return log


def test_streaming_log_stats_match_full_log():
    full = _fill_log(CompletionLog(), n_windows=40)
    stream = _fill_log(CompletionLog(streaming=True, retain_windows=4),
                       n_windows=40)
    assert len(full) == len(stream) == 40 * 50
    fs, ss = full.stats(), stream.stats()
    for key in fs:
        if isinstance(fs[key], float) and math.isnan(fs[key]):
            assert math.isnan(ss[key])
        else:
            np.testing.assert_allclose(ss[key], fs[key], rtol=1e-12)
    for w in range(40):
        fw, sw = full.window_stats(w), stream.window_stats(w)
        for key in fw:
            np.testing.assert_allclose(sw[key], fw[key], rtol=1e-12)
    # rows physically dropped: only the retention span stays resident
    assert stream.view().shape[0] <= 5 * 50
    assert len(stream._buf) < len(full._buf)
    # retained windows still expose raw rows; flushed ones are empty
    assert len(stream.window_rows(39)) == 50
    assert len(stream.window_rows(0)) == 0
    assert len(full.window_rows(0)) == 50


def test_streaming_log_amend_window_relative():
    """amend() coordinates come from view() within the current window —
    they stay valid across compaction."""
    stream = _fill_log(CompletionLog(streaming=True, retain_windows=2))
    rows = stream.view()
    idx = len(rows) - 3
    stream.amend(idx, completion=1e9, redispatched=True)
    assert stream.view()["redispatched"][idx]
    assert stream.view()["completion"][idx] == 1e9


def test_cluster_sim_streaming_log_mode():
    """ClusterSim batch mode with log_streaming: bounded retention, same
    totals/stats as the full log."""
    from repro.cluster import AutoscalerBinding, ClusterSim, SimConfig
    from repro.cluster.topology import fleet_topology

    P, t_end = 50, 1200.0
    arr = poisson_arrivals(10.0, t_end, 15.0, zone="z", seed=3)
    binds = lambda: [AutoscalerBinding("z", HPA(1e18, min_replicas=P),  # noqa: E731
                                      "hpa", P)]
    full = ClusterSim(fleet_topology(P, zones=["z"]),
                      SimConfig(seed=0, sort_service_s=2.0))
    full.run(arr, binds(), t_end, initial_replicas=P)
    stream = ClusterSim(fleet_topology(P, zones=["z"]),
                        SimConfig(seed=0, sort_service_s=2.0,
                                  log_streaming=True, log_retain_windows=4))
    stream.run(arr, binds(), t_end, initial_replicas=P)
    assert len(full.completed_log) == len(stream.completed_log) == len(arr)
    fs, ss = full.completed_log.stats(), stream.completed_log.stats()
    np.testing.assert_allclose(
        [ss[k] for k in ("count", "resp_mean", "resp_min", "resp_max")],
        [fs[k] for k in ("count", "resp_mean", "resp_min", "resp_max")],
        rtol=1e-12)
    assert len(stream.completed_log._buf) < len(full.completed_log._buf)


# ----------------------------------------- ensemble member-stacked fit ----
def test_ensemble_stacked_fit_matches_member_loop():
    """EnsembleForecaster.fit routes all E members through one vmapped
    ``lstm_fit_batch_stacked`` dispatch == the sequential member loop, and
    scratch refits keep members diverse (per-member seeds)."""
    from repro.core.forecaster import EnsembleForecaster

    rng = np.random.default_rng(0)
    s = 200 + 50 * np.sin(np.linspace(0, 8, 120))[:, None] * np.ones(5)
    s = s + rng.normal(0, 3, s.shape)
    batched = EnsembleForecaster(n_members=3, window=4, epochs=10)
    loop = copy.deepcopy(batched)
    batched.fit(s, from_scratch=True)
    for m in loop.members:
        m.fit(s, from_scratch=True)
    recent = s[100:110]
    for mb, ml in zip(batched.members, loop.members):
        pb, _ = mb.predict(recent)
        pl, _ = ml.predict(recent)
        np.testing.assert_allclose(pb, pl, rtol=1e-5, atol=1e-6)
    # diversity: distinct member seeds -> a real (non-degenerate) std
    _, std = batched.predict(recent)
    assert float(np.max(std)) > 0.0


def test_updater_batches_per_target_ensembles():
    """Z per-target ensembles refit as ONE E x Z stacked dispatch through
    Updater.update_batch (batched bookkeeping, members updated)."""
    from repro.core import (MetricsHistory, Snapshot, Updater, UpdatePolicy)
    from repro.core.forecaster import EnsembleForecaster

    rng = np.random.default_rng(1)
    Z, E = 3, 2
    models = [EnsembleForecaster(n_members=E, window=4, epochs=8)
              for _ in range(Z)]
    hists = [MetricsHistory() for _ in range(Z)]
    for i in range(Z):
        trace = 100 + 20 * np.sin(np.linspace(0, 6, 40) + i)
        for k, v in enumerate(trace):
            hists[i].append(Snapshot(15.0 * k,
                                     v * np.ones(5) + rng.normal(0, 1, 5)))
    gens = [[m._fit_count for m in ens.members] for ens in models]
    u = Updater(UpdatePolicy.FINETUNE)
    pending = u.begin_update_batch(models, hists, 1.0)
    pending.compute()
    assert pending.batched            # E x Z stacked, no sequential fits
    pending.commit()
    assert u.n_updates == Z
    for ens, g0 in zip(models, gens):
        assert all(m._fit_count > g for m, g in zip(ens.members, g0))
        assert ens.valid()
