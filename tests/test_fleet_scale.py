"""Fleet-scale sim layer (DESIGN.md §3, "Fleet scale"): array-backed
completion log, vectorised arrival batching, and the multi-fleet chip
arbiter.

The load-bearing property: for a fixed pool with homogeneous node speeds,
the batched drain produces the *identical* completion sequence as
one-at-a-time dispatch — same RNG stream, same selection semantics —
overload (busy/pending fallback) included.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cluster import AutoscalerBinding, ClusterSim, SimConfig
from repro.cluster.topology import fleet_topology
from repro.core.hpa import HPA
from repro.sim import ArrayServerPool, CompletionLog, WindowAccumulator
from repro.sim.core import account_busy
from repro.workloads import WindowedArrivals, poisson_arrivals


def _fixed_bindings(zone, P):
    return [AutoscalerBinding(zone, HPA(1e18, min_replicas=P), "hpa", P)]


def _run_pair(P, t_end, rate, seed, svc=2.0):
    """The same trace through the batched and the per-event engine."""
    arr = poisson_arrivals(rate, t_end, 15.0, zone="z", seed=seed)
    cfg = dict(seed=0, sort_service_s=svc)
    vec = ClusterSim(fleet_topology(P, zones=["z"]), SimConfig(**cfg))
    vec.run(arr, _fixed_bindings("z", P), t_end, initial_replicas=P)
    tasks = [(float(t), "sort", "z") for t in arr.times]
    leg = ClusterSim(fleet_topology(P, zones=["z"]), SimConfig(**cfg))
    leg.run(tasks, _fixed_bindings("z", P), t_end, initial_replicas=P)
    return vec, leg


# ------------------------------------------------- batched == sequential ---
@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    P=st.integers(2, 40),
    load=st.floats(0.2, 2.5),  # > 1.0 exercises the busy/pending fallback
)
def test_batched_drain_identical_completion_sequence(seed, P, load):
    svc = 2.0
    rate = load * P / svc
    vec, leg = _run_pair(P, 450.0, rate, seed, svc)
    cv = vec.completed_log.view()["completion"]
    cl = np.array([t.completion for t in leg.completed])
    assert len(cv) == len(cl)
    np.testing.assert_array_equal(cv, cl)
    av = vec.completed_log.view()["arrival"]
    al = np.array([t.arrival for t in leg.completed])
    np.testing.assert_array_equal(av, al)


def test_batched_drain_identical_seeded():
    """Deterministic backstop for the hypothesis property (runs even when
    hypothesis is not installed), overloaded and underloaded."""
    for seed, load in [(1, 0.5), (2, 1.8), (3, 0.9)]:
        P = 20
        vec, leg = _run_pair(P, 600.0, load * P / 2.0, seed)
        np.testing.assert_array_equal(
            vec.completed_log.view()["completion"],
            np.array([t.completion for t in leg.completed]),
        )


def test_batched_metrics_match_per_event_engine():
    """Exporter samples, RIR and replica logs agree with the per-event
    engine on a dynamic (HPA-scaled) run."""
    P = 16
    svc = 2.0
    arr = poisson_arrivals(3.0, 900.0, 15.0, zone="z", seed=7)

    def binds():
        return [AutoscalerBinding("z", HPA(800.0, min_replicas=2), "hpa", 2)]

    def sim():
        return ClusterSim(
            fleet_topology(P, zones=["z"]), SimConfig(seed=0, sort_service_s=svc)
        )

    vec = sim().run(arr, binds(), 900.0, initial_replicas=2)
    tasks = [(float(t), "sort", "z") for t in arr.times]
    leg = sim().run(tasks, binds(), 900.0, initial_replicas=2)
    sv = np.stack([v for _, v in vec.samples["z"]])
    sl = np.stack([v for _, v in leg.samples["z"]])
    np.testing.assert_allclose(sv, sl, rtol=1e-12, atol=1e-12)
    assert vec.replica_log["z"] == leg.replica_log["z"]
    rv, rl = np.sort(vec.response_times()), np.sort(leg.response_times())
    assert len(rv) == len(rl)
    for q in (50, 95):
        pv, pl = np.percentile(rv, q), np.percentile(rl, q)
        assert abs(pv - pl) <= 0.01 * pl


def test_batched_failure_and_straggler_path():
    """Vec-mode event handling: node failure orphans are re-dispatched
    (never back onto a dead pod), stragglers slow service."""
    P = 8
    t_end = 600.0
    arr = poisson_arrivals(2.0, t_end, 15.0, zone="z", seed=11)
    sim = ClusterSim(
        fleet_topology(P, zones=["z"], pods_per_node=4),
        SimConfig(seed=0, sort_service_s=6.0),
    )
    sim.inject_node_failure(120.0, "z-n0", recover_after=240.0)
    sim.inject_straggler(300.0, "z-n1", factor=0.25, duration=120.0)
    sim.run(arr, _fixed_bindings("z", P), t_end, initial_replicas=P)
    rows = sim.completed_log.view()
    assert np.isfinite(rows["completion"]).all()
    assert rows["redispatched"].any()
    dead_pids = {p.pid for p in sim.pods if p.dead}
    redis = rows[rows["redispatched"]]
    assert not set(redis["server"].tolist()) & dead_pids
    node = next(n for n in sim.topo.nodes if n.name == "z-n0")
    assert not node.failed  # recovered


# ------------------------------------------------------- CompletionLog -----
def test_completion_log_append_amend_and_windows():
    log = CompletionLog(capacity=4)
    s = log.append_batch(
        arrival=np.array([1.0, 2.0, 3.0]),
        start=np.array([1.0, 2.0, 3.0]),
        completion=np.array([2.0, 4.0, 6.0]),
        service=np.array([1.0, 2.0, 3.0]),
        server=np.array([0, 1, 2]),
        kind=np.array([0, 1, 0], np.int16),
    )
    assert (s.start, s.stop) == (0, 3)
    log.seal_window()
    for i in range(20):  # force several growth doublings
        log.append(10.0 + i, 10.0 + i, 11.0 + i, 1.0, i)
    log.seal_window()
    assert len(log) == 23
    assert len(log.window_rows(0)) == 3
    assert len(log.window_rows(1)) == 20
    assert len(log.window_rows(7)) == 0
    np.testing.assert_array_equal(
        log.window_rows(0)["completion"], [2.0, 4.0, 6.0]
    )
    log.amend(1, completion=9.0, redispatched=True)
    assert log.view()["completion"][1] == 9.0
    assert log.view()["redispatched"][1]
    rt = log.response_times()
    assert len(rt) == 23 and rt[0] == 1.0
    assert len(log.response_times(kind=1)) == 1


def test_window_accumulator_matches_scalar_account_busy():
    rng = np.random.default_rng(0)
    w = 15.0
    starts = rng.uniform(0, 300, 200)
    ends = starts + rng.uniform(0.1, 40, 200)  # spans multiple windows
    acc = WindowAccumulator(w, n_windows=4)  # force growth
    acc.add_batch(starts, ends)
    ref: dict = {}
    from collections import defaultdict

    ref = defaultdict(float)
    for s, e in zip(starts, ends):
        account_busy(ref, s, e, w)
    for win, val in ref.items():
        assert abs(acc.get(win) - val) < 1e-9, win
    # sign=-1 cancels exactly
    acc.add_batch(starts, ends, sign=-1.0)
    for win in ref:
        assert abs(acc.get(win)) < 1e-9


def test_array_pool_selection_priority():
    """Mirror of the heap ServerPool ordering test: idle in creation
    order, then earliest busy, then earliest pending."""
    pool = ArrayServerPool(capacity=2)  # force growth too
    a = pool.add(0.0, key=0.0, ready_at=0.0)
    b = pool.add(0.0, key=0.0, ready_at=0.0)
    c = pool.add(0.0, key=10.0, ready_at=10.0)
    assert pool.select(1.0) == a
    pool.update(a, 5.0)
    assert pool.select(1.0) == b
    pool.update(b, 3.0)
    assert pool.select(2.0) == b  # both busy: earliest horizon
    pool.update(b, 7.0)
    pool.invalidate(b)
    assert pool.select(2.0) == a
    pool.update(a, 9.0)
    pool.invalidate(a)
    assert pool.select(2.0) == c  # pending fallback
    assert pool.n_live == 1
    assert pool.select(11.0) == c  # promoted after ready_at
    assert pool.ready_live_count(11.0) == 1
    # before any ready_at the only live (pending) server is still selected
    assert pool.select(-1.0) == c


def _drain_oracle(pool, times, service_fn, cold_timeout_s=60.0):
    """One-at-a-time dispatch with the exact per-event semantics the
    batched ``drain_window`` must reproduce (idle first-index, then
    min-key busy, then pending; one service draw per task in order)."""
    n = len(times)
    slots = np.empty(n, np.int64)
    starts = np.full(n, np.nan)
    comps = np.empty(n, np.float64)
    svcs = np.full(n, np.nan)
    for i in range(n):
        t = float(times[i])
        idle = pool.idle_slots(t, 1)
        s = int(idle[0]) if len(idle) else pool.select(t)
        if s < 0:
            slots[i], comps[i] = -1, t + cold_timeout_s
            continue
        st = max(t, float(pool.key[s]), float(pool.ready[s]))
        sv = float(service_fn(np.asarray([s]), i, i + 1)[0])
        pool.key[s] = st + sv
        slots[i], starts[i] = s, st
        comps[i], svcs[i] = st + sv, sv
    return slots, starts, comps, svcs


def test_drain_window_busy_round_oracle_parity():
    """The vectorised busy round (no idle slot, sustained overload,
    pending spin-ups joining mid-chunk) keeps ``drain_window``'s contract
    vs per-event dispatch: the (start, completion, service) sequence —
    RNG stream included — is bitwise-identical.  Slot *labels* may
    permute inside an idle chunk (the chunk assigns the slots idle at its
    head, the oracle may reuse one freed mid-chunk), so slots are instead
    checked for exact per-slot feasibility: every start is precisely
    ``max(arrival, slot's previous completion, slot ready)``."""
    from repro.sim.core import drain_window

    for seed in range(8):
        rng = np.random.default_rng(seed)
        P = int(rng.integers(2, 12))
        n = 400
        # bursty arrivals: tight clusters force long busy rounds
        times = np.sort(rng.uniform(0, 60.0, n))
        mean_svc = float(rng.uniform(2.0, 6.0))  # heavy overload

        def build():
            pool = ArrayServerPool()
            pool.add_batch(P, key=0.0, ready_at=0.0)
            # pending servers that come up inside the chunk
            for j in range(int(rng.integers(0, 3))):
                pool.add(0.0, key=10.0 + 7 * j, ready_at=10.0 + 7 * j)
            return pool

        state = rng.bit_generator.state
        r1 = np.random.default_rng(99 + seed)
        svc1 = lambda s, i0, i1: r1.exponential(mean_svc, i1 - i0)  # noqa: E731
        got = drain_window(build(), times, svc1)
        rng.bit_generator.state = state
        r2 = np.random.default_rng(99 + seed)
        svc2 = lambda s, i0, i1: r2.exponential(mean_svc, i1 - i0)  # noqa: E731
        want = _drain_oracle(build(), times, svc2)
        for g, w in zip(got[1:], want[1:]):   # starts, comps, services
            np.testing.assert_array_equal(g, w)
        # slot assignment feasibility: replay each slot's task sequence
        rng.bit_generator.state = state
        ref = build()
        slots, starts, comps, _ = got
        horizon = ref.key[:ref.n].copy()
        for i in range(n):
            s = int(slots[i])
            assert 0 <= s < ref.n
            exp = max(float(times[i]), float(horizon[s]),
                      float(ref.ready[s]))
            assert starts[i] == exp
            horizon[s] = comps[i]


# ----------------------------------------------------- WindowedArrivals ----
def test_windowed_arrivals_boundaries_and_conversion():
    tasks = [(0.0, "sort", "a"), (7.5, "eigen", "b"), (15.0, "sort", "a"),
             (15.1, "sort", "b"), (29.9, "eigen", "a")]
    arr = WindowedArrivals.from_tasks(tasks, 15.0)
    assert arr.n_windows >= 2
    w1 = list(arr.window_chunks(1))
    # t == 15.0 lands in window 1 (dispatched before the tick's control
    # step), exactly like the per-event driver's ``t <= tick``
    got = sorted((z, float(t)) for z, ts, _ in w1 for t in ts)
    assert got == [("a", 0.0), ("a", 15.0), ("b", 7.5)]
    w2 = list(arr.window_chunks(2))
    got2 = sorted((z, float(t)) for z, ts, _ in w2 for t in ts)
    assert got2 == [("a", 29.9), ("b", 15.1)]
    tail = list(arr.tail_chunks(15.0, 29.9))
    assert sorted((z, float(t)) for z, ts, _ in tail for t in ts) == got2


def test_poisson_arrivals_deterministic_and_windowed():
    a = poisson_arrivals(5.0, 300.0, 15.0, seed=4)
    b = poisson_arrivals(5.0, 300.0, 15.0, seed=4)
    np.testing.assert_array_equal(a.times, b.times)
    assert np.all(np.diff(a.times) >= 0)
    assert a.times[-1] <= 300.0
    rates = np.zeros(20)
    rates[3] = 10.0  # only window 4 (t in (45, 60]) has load
    c = poisson_arrivals(rates, 300.0, 15.0, seed=4)
    assert len(c) > 0
    assert np.all((c.times > 45.0 - 15.0) & (c.times <= 60.0))


def test_event_queue_push_batch_orders_with_payloads():
    from repro.sim import EventQueue

    q = EventQueue()
    q.push_batch([30.0, 10.0], "slow", [{"rid": 0}, {"rid": 1}])
    q.push_batch([20.0], "fail", [{"rid": 2}])
    fired = q.pop_due(40.0)
    assert [(t, k, p["rid"]) for t, k, p in fired] == [
        (10.0, "slow", 1), (20.0, "fail", 2), (30.0, "slow", 0)]


# ------------------------------------------------------- multi-fleet -------
def test_chip_arbiter_floors_weights_and_conservation():
    from repro.serving.multi_fleet import ChipBudgetArbiter

    arb = ChipBudgetArbiter(96)
    names = ["a", "b", "c"]
    chips_per = {n: 16 for n in names}
    floors = {n: 1 for n in names}
    # no contention: everyone gets their demand
    grant = arb.allocate({"a": 2, "b": 1, "c": 2}, chips_per, floors,
                         {n: 1.0 for n in names})
    assert grant == {"a": 32, "b": 16, "c": 32}
    # contention: floors respected, whole replicas, budget conserved
    grant = arb.allocate({"a": 6, "b": 6, "c": 6}, chips_per, floors,
                         {"a": 1.0, "b": 1.0, "c": 4.0})
    assert sum(grant.values()) <= 96
    assert all(grant[n] >= 16 and grant[n] % 16 == 0 for n in names)
    assert grant["c"] >= grant["a"]  # weight bias
    # surplus recycling: a high-weight fleet with tiny demand must not
    # strand budget — the other fleet's unmet demand absorbs it
    grant = arb.allocate({"a": 1, "b": 6}, chips_per, {"a": 0, "b": 0},
                         {"a": 100.0, "b": 1.0})
    assert grant == {"a": 16, "b": 80}   # all 96 chips placed
    with pytest.raises(ValueError):
        ChipBudgetArbiter(16).allocate(
            {"a": 2, "b": 2}, {"a": 16, "b": 16},
            {"a": 1, "b": 1}, {"a": 1.0, "b": 1.0})


def test_multi_fleet_budget_and_completion():
    from repro.core import (ARIMAD1Forecaster, FleetController, PPAConfig,
                            TargetSpec, ThresholdPolicy)
    from repro.serving.fleet import FleetConfig
    from repro.serving.multi_fleet import FleetSpec, MultiFleetSim

    rng = np.random.default_rng(1)
    T = 600.0
    specs = [FleetSpec(f"f{i}", FleetConfig(total_chips=96,
                                            chips_per_replica=16, seed=i))
             for i in range(2)]
    ctrl = FleetController(
        PPAConfig(threshold=560.0, stabilization_s=0.0),
        [TargetSpec(s.name, ThresholdPolicy(560.0, 1)) for s in specs],
        model=ARIMAD1Forecaster())
    reqs = {s.name: sorted((float(t), int(rng.integers(16, 64)))
                           for t in rng.uniform(0, T, 300))
            for s in specs}
    sim = MultiFleetSim(specs, total_chips=64, controller=ctrl).run(reqs, T)
    assert sim.peak_chips() <= 64
    rt = sim.response_times()
    assert len(rt) == 600 and np.isfinite(rt).all()
    for _, grant in sim.alloc_log:
        assert sum(grant.values()) <= 64
        assert all(g % 16 == 0 for g in grant.values())


# ----------------------------------------------------------- slow lane -----
@pytest.mark.slow
def test_ten_thousand_pod_run_under_a_minute():
    """The acceptance bar's scale point: 10^4 pods, 2 h sim < 60 s."""
    import time

    P, T, svc = 10_000, 7200.0, 8.0
    arr = poisson_arrivals(0.6 * P / svc, T, 15.0, zone="z", seed=3)
    sim = ClusterSim(fleet_topology(P, zones=["z"]),
                     SimConfig(seed=0, sort_service_s=svc))
    t0 = time.time()
    sim.run(arr, _fixed_bindings("z", P), T, initial_replicas=P)
    wall = time.time() - t0
    assert wall < 60.0, wall
    assert len(sim.completed_log) == len(arr)
    assert np.isfinite(sim.completed_log.view()["completion"]).all()
    # the fixed fleet absorbs the offered load: responses stay ~service
    assert np.percentile(sim.response_times(), 95) < 5 * svc


@pytest.mark.slow
def test_multi_fleet_long_run_reallocates_chips():
    from benchmarks.bench_fleet_scale import bench_multi_fleet

    out = bench_multi_fleet(t_end=1800.0, budget=192)
    assert out["budget_respected"]
    assert out["reallocations"] > 0
    assert out["n_requests"] > 0
