"""The paper's §7 future work: automatic model + key-metric selection."""
import numpy as np

from repro.core.autotune import autotune
from repro.core.forecaster import LSTMForecaster, ARMAForecaster
from repro.core.metrics import N_METRICS


def _series(n=600, seed=0, nonlinear=True):
    rng = np.random.default_rng(seed)
    y = np.zeros(n)
    for t in range(1, n):
        drive = np.sin(t / 17.0) * 2 + np.sin(t / 5.0)
        y[t] = 0.7 * y[t - 1] + (np.tanh(y[t - 1]) if nonlinear else 0.0) \
            + drive + rng.normal(0, 0.3)
    s = np.zeros((n, N_METRICS))
    for m in range(N_METRICS):
        s[:, m] = y * (m + 1) + 5 * m + rng.normal(0, 0.05, n)
    return s


def test_autotune_returns_valid_model():
    cands = {"arma": lambda: ARMAForecaster(steps=150),
             "lstm_w4": lambda: LSTMForecaster(window=4, epochs=60)}
    rep = autotune(_series(), candidates=cands)
    assert rep.best_kind in cands
    assert rep.model.valid()
    assert rep.key_metric_idx in (0, 4)
    assert all(np.isfinite(v) or v == float("inf")
               for v in rep.val_mse.values())


def test_autotune_prefers_better_model():
    """The winner's validation MSE is the minimum by construction, and the
    selected model predicts the structured series better than the series
    mean (sanity that 'best' means something)."""
    cands = {"arma": lambda: ARMAForecaster(steps=150),
             "lstm_w4": lambda: LSTMForecaster(window=4, epochs=80)}
    s = _series(seed=3)
    rep = autotune(s, candidates=cands)
    assert rep.val_mse[rep.best_kind] == min(rep.val_mse.values())
    assert rep.val_mse[rep.best_kind] < 1.0   # beats variance baseline


def test_autotune_key_metric_prefers_predictable():
    """Make the custom metric pure white noise -> CPU must win."""
    s = _series(seed=5)
    rng = np.random.default_rng(9)
    s[:, 4] = rng.normal(0, 1, len(s))        # unpredictable custom metric
    cands = {"arma": lambda: ARMAForecaster(steps=150)}
    rep = autotune(s, candidates=cands)
    assert rep.key_metric_idx == 0
