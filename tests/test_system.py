"""End-to-end behaviour tests for the paper's system: the PPA control loop
against the simulated edge cluster, the reproduction orderings on short runs,
and the fault-tolerance story."""
import numpy as np
import pytest

from repro.core.experiments import collect_series, run_scenario, welch_t
from repro.core.updater import UpdatePolicy
from repro.workloads import nasa_requests, nasa_trace, random_access


@pytest.fixture(scope="module")
def pretrain():
    tasks = random_access(600 * 15, seed=99)
    return collect_series(tasks, 600 * 15)


def test_ppa_end_to_end_short(pretrain):
    T = 30 * 60
    tasks = random_access(T, seed=3)
    res = run_scenario(tasks, T, scaler="ppa", model_kind="lstm",
                       pretrain=pretrain, min_replicas=2)
    assert np.isfinite(res.sort_mean) and res.sort_mean < 5.0
    assert all(np.isfinite(v) for v in res.mse.values())
    # the PPA actually predicted (proactive mode), not just fell back
    ppa = res.ppas["edge-0"]
    frac_pred = np.mean([d.predicted for d in ppa.decisions])
    assert frac_pred > 0.9


def test_hpa_baseline_reasonable():
    T = 30 * 60
    tasks = random_access(T, seed=3)
    res = run_scenario(tasks, T, scaler="hpa", min_replicas=2)
    assert 0.4 < res.sort_mean < 2.0          # ~service time + small queueing
    assert res.eigen_mean < 60.0


@pytest.mark.slow
def test_nasa_ppa_not_worse_than_hpa():
    """Short (6 h) version of the §6.4 comparison: PPA response must not be
    worse than HPA beyond noise, and idle resources must be comparable."""
    counts = nasa_trace(days=2, scale=3.5)[:360]    # 6 hours
    tasks = nasa_requests(counts)
    T = 360 * 60
    pre = collect_series(random_access(600 * 15, seed=99), 600 * 15)
    h = run_scenario(tasks, T, scaler="hpa")
    p = run_scenario(tasks, T, scaler="ppa", model_kind="lstm", pretrain=pre,
                     update_policy=UpdatePolicy.FINETUNE)
    assert p.eigen_mean < h.eigen_mean * 1.1
    assert p.rir_cloud[0] < h.rir_cloud[0] * 1.15


def test_failure_injection_recovers(pretrain):
    T = 20 * 60
    tasks = random_access(T, seed=4)
    res = run_scenario(tasks, T, scaler="ppa", model_kind="lstm",
                       pretrain=pretrain, min_replicas=2,
                       failures=[("fail", 300.0, "edge0-0", 300.0),
                                 ("slow", 600.0, "cloud-0", 0.3, 200.0)])
    assert np.isfinite(res.sort_mean)
    n_redis = sum(1 for t in res.sim.completed if t.redispatched)
    assert n_redis >= 0                     # tasks rescued, run completes


def test_welch_t_sanity():
    a = np.random.default_rng(0).normal(0, 1, 2000)
    b = np.random.default_rng(1).normal(0.2, 1, 2000)
    t, p = welch_t(a, b)
    assert t < -3 and p < 1e-3
    t2, p2 = welch_t(a, a)
    assert abs(t2) < 1e-6 and p2 > 0.99
